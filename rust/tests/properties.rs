//! Property-based tests on the paper's mathematical invariants, driven
//! by the in-repo prop-test harness (util::proptest).

use sketchboost::data::binning::BinnedDataset;
use sketchboost::data::dataset::{Dataset, FeatureKind, Targets};
use sketchboost::data::synthetic::{make_multiclass, FeatureSpec};
use sketchboost::engine::{ComputeEngine, MissingPolicy, NativeEngine, ScanSpec, ScoreMode};
use sketchboost::prelude::*;
use sketchboost::sketch::{column_sq_norms, SketchConfig};
use sketchboost::tree::builder::{build_tree, BuildParams};
use sketchboost::util::proptest::{run_prop, Gen};
use sketchboost::util::rng::Rng;

fn random_binned(g: &mut Gen, n: usize, m: usize, bins: usize) -> BinnedDataset {
    let feats = g.vec_gaussian(n * m, 1.5);
    let ds = Dataset::new(
        n,
        m,
        feats,
        Targets::Regression { values: vec![0.0; n], n_targets: 1 },
    );
    BinnedDataset::from_dataset(&ds, bins)
}

/// Lemma A.1 quantity: ||G Gᵀ - G_k G_kᵀ||_F (upper-bounds the operator
/// norm the propositions bound).
fn gram_fro_error(gm: &[f32], gk: &[f32], n: usize, d: usize, k: usize) -> f64 {
    let mut err = 0.0f64;
    for i in 0..n {
        for j in 0..n {
            let mut a = 0.0f64;
            for c in 0..d {
                a += gm[i * d + c] as f64 * gm[j * d + c] as f64;
            }
            let mut b = 0.0f64;
            for c in 0..k {
                b += gk[i * k + c] as f64 * gk[j * k + c] as f64;
            }
            err += (a - b) * (a - b);
        }
    }
    err.sqrt()
}

#[test]
fn prop_top_outputs_error_bound_a3() {
    // Prop A.3: ||GGᵀ - G_kG_kᵀ|| <= sum of dropped column sq-norms.
    // (We check the Frobenius form against sqrt(n)*bound, a valid
    // relaxation since ||.||_F <= sqrt(rank)*||.||_2.)
    run_prop("prop A.3 bound", 15, |g| {
        let n = g.usize_in(5, 25);
        let d = g.usize_in(3, 12);
        let k = g.usize_in(1, d - 1);
        let gm = g.vec_gaussian(n * d, 1.0);
        let mut rng = Rng::new(g.seed);
        let mut eng = NativeEngine::new();
        let Some((gk, kk)) =
            SketchConfig::TopOutputs { k }.apply(&gm, n, d, &mut rng, &mut eng)
        else {
            return;
        };
        let norms = column_sq_norms(&gm, n, d);
        let mut sorted = norms.clone();
        sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let dropped: f64 = sorted[k..].iter().sum();
        let err = gram_fro_error(&gm, &gk, n, d, kk);
        assert!(
            err <= (n as f64).sqrt() * dropped + 1e-3,
            "A.3 violated: err {err} > sqrt(n)*dropped {dropped}"
        );
    });
}

#[test]
fn prop_random_sampling_unbiased_diag() {
    // E[G_k G_kᵀ] = G Gᵀ: check the trace (= total sq norm) across seeds.
    run_prop("RS unbiasedness", 5, |g| {
        let n = g.usize_in(4, 12);
        let d = g.usize_in(4, 10);
        let gm = g.vec_gaussian(n * d, 1.0);
        let total: f64 = gm.iter().map(|&x| (x as f64) * (x as f64)).sum();
        let mut eng = NativeEngine::new();
        let mut est = 0.0f64;
        let trials = 400;
        for s in 0..trials {
            let mut rng = Rng::new(g.seed ^ s);
            let (gk, k) = SketchConfig::RandomSampling { k: 3 }
                .apply(&gm, n, d, &mut rng, &mut eng)
                .unwrap();
            est += gk[..n * k].iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>();
        }
        est /= trials as f64;
        assert!(
            (est - total).abs() < 0.25 * total,
            "RS trace biased: {est} vs {total}"
        );
    });
}

#[test]
fn prop_histogram_mass_conservation() {
    // sum over (node, bin) of any histogram channel = sum over rows of
    // that channel, for every feature.
    run_prop("hist mass conservation", 15, |g| {
        let n = g.usize_in(20, 300);
        let m = g.usize_in(1, 4);
        let bins = *g.choose(&[8usize, 32]);
        let slots = g.usize_in(1, 6);
        let binned = random_binned(g, n, m, bins);
        let k1 = g.usize_in(2, 5);
        let chan = g.vec_gaussian(n * k1, 1.0);
        let slot_of_row = g.vec_u32_below(n, slots);
        let rows: Vec<u32> = (0..n as u32).collect();
        let (prows, pchan, segs) = sketchboost::engine::reference::partition_inputs(
            &rows,
            &slot_of_row,
            &chan,
            k1,
            slots,
        );
        let mut out = vec![0.0f32; slots * m * bins * k1];
        NativeEngine::new().histograms(&binned, &prows, &pchan, k1, &segs, slots, &mut out);
        for f in 0..m {
            for c in 0..k1 {
                let mut total = 0.0f64;
                for s in 0..slots {
                    for b in 0..bins {
                        total += out[((s * m + f) * bins + b) * k1 + c] as f64;
                    }
                }
                let want: f64 = (0..n).map(|i| chan[i * k1 + c] as f64).sum();
                assert!(
                    (total - want).abs() < 1e-2 + 1e-4 * want.abs(),
                    "feature {f} channel {c}: {total} vs {want}"
                );
            }
        }
    });
}

#[test]
fn prop_split_gain_superadditive_at_small_lambda() {
    // At lambda -> 0 (and non-empty children), Cauchy-Schwarz gives
    // (u+v)^2/(a+b) <= u^2/a + v^2/b per output, so S(L)+S(R) >= S(parent)
    // for every candidate. (With a real lambda > 0 this can fail — the
    // regularizer penalizes small leaves — which is exactly why the
    // splitter filters on `gain - parent_score > min_gain`.)
    run_prop("gain superadditivity (lambda->0)", 20, |g| {
        let bins = *g.choose(&[4usize, 16]);
        let k = g.usize_in(1, 4);
        let k1 = k + 1;
        let m = 1usize;
        let mut hist = g.vec_gaussian(m * bins * k1, 1.0);
        for b in 0..bins {
            hist[b * k1 + k] = g.usize_in(1, 20) as f32; // every bin non-empty
        }
        let lam = 1e-4f32;
        let mut eng = NativeEngine::new();
        let mut gains = Vec::new();
        let mut defaults = Vec::new();
        let kinds = vec![FeatureKind::Numeric; m];
        let spec = ScanSpec {
            n_slots: 1,
            m,
            bins,
            k1,
            lam,
            mode: ScoreMode::CountL2,
            kinds: &kinds,
            missing: MissingPolicy::AlwaysLeft,
        };
        eng.split_gains(&hist, &spec, &mut gains, &mut defaults);
        let (pscore, _) = sketchboost::tree::splitter::node_score(
            &hist,
            0,
            m,
            bins,
            k1,
            lam,
            ScoreMode::CountL2,
            &mut Vec::new(),
        );
        // candidates with both children non-empty: all b < bins-1 here
        for b in 0..bins - 1 {
            let gain = gains[b] as f64;
            assert!(
                gain >= pscore - 1e-3 * pscore.abs() - 1e-3,
                "candidate b={b}: gain {gain} < parent {pscore}"
            );
        }
    });
}

#[test]
fn prop_tree_partitions_and_depth_bounded() {
    run_prop("tree partition invariants", 10, |g| {
        let n = g.usize_in(60, 400);
        let m = g.usize_in(1, 4);
        let binned = random_binned(g, n, m, 16);
        let grad = g.vec_gaussian(n, 1.0);
        let h = vec![1.0f32; n];
        let rows: Vec<u32> = (0..n as u32).collect();
        let depth = g.usize_in(1, 5);
        let min_data = g.usize_in(1, 10);
        let p = BuildParams {
            binned: &binned,
            rows: &rows,
            g: &grad,
            h: &h,
            d: 1,
            score_g: &grad,
            kc: 1,
            score_h: None,
            mode: ScoreMode::CountL2,
            max_depth: depth,
            lambda: 1.0,
            min_data_in_leaf: min_data,
            min_gain: 0.0,
            feature_mask: None,
            sparse_topk: None,
            row_weights: None,
            missing: MissingPolicy::Learn,
        };
        let mut eng = NativeEngine::new();
        let (tree, leaf_of_row) = build_tree(&p, &mut eng);
        tree.validate().unwrap();
        assert!(tree.depth() <= depth);
        // each leaf holds >= min_data rows and the leaves partition rows
        let mut counts = vec![0usize; tree.n_leaves];
        for r in 0..n {
            counts[leaf_of_row[r] as usize] += 1;
        }
        assert_eq!(counts.iter().sum::<usize>(), n);
        if tree.n_leaves > 1 {
            assert!(
                counts.iter().all(|&c| c >= min_data),
                "leaf below min_data: {counts:?}"
            );
        }
        // binned routing agrees with raw-value routing on training data
        for r in (0..n).step_by(7) {
            let raw: Vec<f32> = (0..m).map(|f| binned.codes[f * n + r] as f32).collect();
            let _ = raw; // raw-value recheck happens in tree unit tests
            assert_eq!(tree.leaf_for_binned(&binned, r), leaf_of_row[r] as usize);
        }
    });
}

#[test]
fn prop_missing_and_categorical_codes_bin_consistently() {
    // Bin-layout invariants with NaN placement and categorical codes:
    // code 0 <=> the raw value is missing; numeric candidates b >= 1
    // satisfy (code <= b) == (x <= threshold); categorical codes are
    // exactly id + 1.
    run_prop("missing/categorical bin layout", 20, |g| {
        let n = g.usize_in(30, 300);
        let nan_rate = *g.choose(&[0.05f32, 0.3]);
        let num = g.vec_gaussian_nan(n, 2.0, nan_rate);
        let cards = g.usize_in(2, 12);
        let cat = g.vec_cat_values(n, cards, nan_rate);
        let mut cols = num.clone();
        cols.extend(cat.clone());
        let mut ds = Dataset::new(
            n,
            2,
            cols,
            Targets::Regression { values: vec![0.0; n], n_targets: 1 },
        );
        ds.mark_categorical(&[1]);
        let bins = *g.choose(&[16usize, 64]);
        let b = BinnedDataset::from_dataset(&ds, bins);
        for i in 0..n {
            assert_eq!(b.column(0)[i] == 0, num[i].is_nan(), "numeric row {i}");
            if cat[i].is_nan() {
                assert_eq!(b.column(1)[i], 0, "cat row {i}");
            } else {
                assert_eq!(b.column(1)[i], cat[i] as u8 + 1, "cat row {i}");
            }
        }
        for cand in 1..=b.edges[0].len() {
            let t = b.threshold_value(0, cand);
            for i in 0..n {
                if num[i].is_nan() {
                    continue;
                }
                assert_eq!(
                    b.column(0)[i] as usize <= cand,
                    num[i] <= t,
                    "x={} cand={cand} t={t}",
                    num[i]
                );
            }
        }
    });
}

#[test]
fn prop_binned_and_raw_routing_agree_with_missing_and_categorical() {
    // The satellite invariant: for a tree trained on NaN-bearing data
    // with categorical columns, the binned split decision equals the
    // raw-value decision for EVERY row — including missing cells —
    // through the builder's leaf map, the per-row walker, and the
    // FlatForest serving path.
    run_prop("binned == raw routing", 12, |g| {
        let n = g.usize_in(80, 400);
        let m_num = g.usize_in(1, 3);
        let m_cat = g.usize_in(1, 3);
        let m = m_num + m_cat;
        let nan_rate = *g.choose(&[0.0f32, 0.1, 0.3]);
        let cards = g.usize_in(2, 10);
        let mut cols = Vec::with_capacity(n * m);
        for _ in 0..m_num {
            cols.extend(g.vec_gaussian_nan(n, 2.0, nan_rate));
        }
        for _ in 0..m_cat {
            cols.extend(g.vec_cat_values(n, cards, nan_rate));
        }
        let mut ds = Dataset::new(
            n,
            m,
            cols,
            Targets::Regression { values: vec![0.0; n], n_targets: 1 },
        );
        let cat_cols: Vec<usize> = (m_num..m).collect();
        ds.mark_categorical(&cat_cols);
        let binned = BinnedDataset::from_dataset(&ds, 16);
        let grad = g.vec_gaussian(n, 1.0);
        let h = vec![1.0f32; n];
        let rows: Vec<u32> = (0..n as u32).collect();
        let p = BuildParams {
            binned: &binned,
            rows: &rows,
            g: &grad,
            h: &h,
            d: 1,
            score_g: &grad,
            kc: 1,
            score_h: None,
            mode: ScoreMode::CountL2,
            max_depth: g.usize_in(1, 4),
            lambda: 1.0,
            min_data_in_leaf: g.usize_in(1, 5),
            min_gain: 0.0,
            feature_mask: None,
            sparse_topk: None,
            row_weights: None,
            missing: *g.choose(&[MissingPolicy::Learn, MissingPolicy::AlwaysLeft]),
        };
        let mut eng = NativeEngine::new();
        let (tree, leaf_of_row) = build_tree(&p, &mut eng);
        tree.validate().unwrap();
        let model = Ensemble {
            loss: LossKind::MSE,
            n_outputs: 1,
            base_score: vec![0.0],
            trees: vec![tree.clone()],
            history: Default::default(),
        };
        let flat = FlatForest::from_ensemble(&model);
        for r in 0..n {
            let raw = ds.row(r);
            let via_bins = tree.leaf_for_binned(&binned, r);
            assert_eq!(leaf_of_row[r] as usize, via_bins, "row {r} builder map");
            assert_eq!(tree.leaf_for_raw(&raw), via_bins, "row {r} raw walker");
            assert_eq!(flat.leaf_of(0, &raw), via_bins, "row {r} flat path");
        }
    });
}

#[test]
fn prop_coalesced_serving_is_bit_identical_to_per_row_walks() {
    // The serving-path extension of the binned==raw property: random
    // request sizes, arrival orders, and batch boundaries through the
    // serve::Coalescer + score_batch pipeline produce results that are
    // bit-identical to naive per-row walks — batching is invisible.
    use sketchboost::serve::{score_batch, Coalescer, Job, ServeStats};
    use std::time::Duration;
    run_prop("coalesced serving == per-row walks", 10, |g| {
        let n = g.usize_in(40, 120);
        let m = g.usize_in(3, 8);
        let d = g.usize_in(1, 4);
        let nan_rate = *g.choose(&[0.0f32, 0.2]);
        let mut cols = Vec::with_capacity(n * m);
        for _ in 0..m {
            cols.extend(g.vec_gaussian_nan(n, 1.5, nan_rate));
        }
        let ds = Dataset::new(
            n,
            m,
            cols,
            Targets::Regression { values: g.vec_gaussian(n * d, 1.0), n_targets: d },
        );
        let mut cfg = GBDTConfig::multitask(d);
        cfg.n_rounds = 3;
        cfg.max_depth = 3;
        cfg.max_bins = 16;
        cfg.seed = g.seed;
        let model = GBDT::fit(&cfg, &ds, None);
        let naive = model.predict_raw_naive(&ds);
        // serving scores through the Predictor facade; v2 keeps the
        // bit-identity property intact
        let layout = *g.choose(&[ForestLayout::V1, ForestLayout::V2Exact]);
        let pred =
            Predictor::compile(&model, PredictOptions::default().with_layout(layout));

        // random requests (rows sampled with replacement; some rows in
        // no request, some in several), some padded with junk features
        // past the model's required width
        let n_requests = g.usize_in(1, 25);
        let mut requests: Vec<(Vec<usize>, usize)> = Vec::new();
        for _ in 0..n_requests {
            let rows: Vec<usize> = (0..g.usize_in(1, 5)).map(|_| g.usize_in(0, n - 1)).collect();
            let width = m + g.usize_in(0, 2);
            requests.push((rows, width));
        }
        g.rng.shuffle(&mut requests); // random arrival order

        let coalescer = Coalescer::new(n_requests);
        let mut tickets = Vec::new();
        for (rows, width) in &requests {
            let mut vals = Vec::with_capacity(rows.len() * width);
            for &i in rows {
                vals.extend(ds.row(i));
                vals.extend(g.vec_gaussian(width - m, 1.0)); // ignored padding
            }
            let (job, ticket) = Job::new(vals, rows.len(), *width);
            coalescer.submit(job).unwrap();
            tickets.push((ticket, rows.clone()));
        }
        coalescer.close();

        // drain with random batch budgets and block sizes
        let stats = ServeStats::new();
        let mut tile = Vec::new();
        while let Some(batch) = coalescer.next_batch(g.usize_in(1, 64), Duration::ZERO) {
            let block = *g.choose(&[1usize, 3, 17, 512]);
            score_batch(&pred, batch, block, &mut tile, &stats);
        }

        for (ticket, rows) in tickets {
            let got = ticket.wait().unwrap();
            assert_eq!(got.len(), rows.len() * d);
            for (j, &i) in rows.iter().enumerate() {
                for c in 0..d {
                    let want = naive[i * d + c];
                    let have = got[j * d + c];
                    assert!(
                        want.to_bits() == have.to_bits(),
                        "row {i} output {c}: {want:?} vs {have:?}"
                    );
                }
            }
        }
    });
}

#[test]
fn prop_leaf_values_shrink_with_lambda() {
    // larger lambda => smaller |leaf value| (eq. 3 regularization)
    run_prop("lambda shrinkage", 10, |g| {
        let ds = make_multiclass(200, FeatureSpec::guyon(6), 3, 2.0, g.seed);
        let mut cfg = GBDTConfig::multiclass(3);
        cfg.n_rounds = 1;
        cfg.max_depth = 2;
        cfg.max_bins = 16;
        let small = GBDT::fit(&cfg, &ds, None);
        cfg.lambda_l2 = 100.0;
        let large = GBDT::fit(&cfg, &ds, None);
        let max_abs = |m: &Ensemble| {
            m.trees[0]
                .leaf_values
                .iter()
                .fold(0.0f32, |a, &v| a.max(v.abs()))
        };
        assert!(
            max_abs(&large) <= max_abs(&small) + 1e-6,
            "lambda=100 leaves larger than lambda=1"
        );
    });
}

#[test]
fn prop_predictions_finite_everywhere() {
    run_prop("finite predictions", 8, |g| {
        let d = g.usize_in(2, 6);
        let ds = make_multiclass(300, FeatureSpec::guyon(8), d, 1.5, g.seed);
        let mut cfg = GBDTConfig::multiclass(d);
        cfg.n_rounds = 10;
        cfg.max_bins = 16;
        cfg.learning_rate = 0.5;
        cfg.sketch = *g.choose(&[
            SketchConfig::None,
            SketchConfig::RandomProjection { k: 2 },
            SketchConfig::RandomSampling { k: 2 },
        ]);
        let model = GBDT::fit(&cfg, &ds, None);
        // also probe far outside the training distribution (and NaN)
        let probe = Dataset::new(
            3,
            8,
            vec![
                1e6, -1e6, f32::NAN, 0.0, 1e6, -1e6, f32::NAN, 0.0,
                0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0,
                -1e6, 1e6, 1e-30, -1e-30, f32::NAN, f32::NAN, 1.0, -1.0,
            ],
            Targets::Multiclass { labels: vec![0, 0, 0], n_classes: d },
        );
        for v in model.predict(&probe) {
            assert!(v.is_finite(), "non-finite prediction");
        }
    });
}
