//! Allocation accounting for the pooled training core.
//!
//! The refactored builder keeps every per-level buffer in a
//! [`TreeWorkspace`] and the engine pools its scratch, so steady-state
//! tree building must stop allocating once the buffers reach their
//! high-water mark: after a warm-up build, the only allocations left per
//! tree are the returned artifact itself (the `Tree`'s node and
//! leaf-value vectors, plus the debug-build `validate` walk) — all
//! independent of how many levels the per-level hot loop runs.
//!
//! A counting `#[global_allocator]` (this test binary only) enforces
//! both properties: the steady-state per-build allocation count is (a)
//! constant across repeated builds and (b) tiny compared to the cold
//! first build.
//!
//! Threaded engines are excluded on purpose: `std::thread::scope` spawn
//! machinery allocates per parallel op, which is a property of the
//! scoped-pool design (util/threading.rs), not of the training core.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

struct CountingAlloc;

static ALLOC_CALLS: AtomicUsize = AtomicUsize::new(0);

// SAFETY: pure pass-through to the System allocator plus a relaxed
// counter increment — all GlobalAlloc contract obligations are
// inherited unchanged from System.
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: delegates to System.alloc with the caller's layout.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    // SAFETY: delegates to System.dealloc; caller guarantees `ptr` came
    // from this allocator with this layout.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    // SAFETY: delegates to System.realloc under the caller's contract.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn alloc_count() -> usize {
    ALLOC_CALLS.load(Ordering::Relaxed)
}

use sketchboost::data::binning::BinnedDataset;
use sketchboost::data::synthetic::{make_multiclass, FeatureSpec};
use sketchboost::engine::{MissingPolicy, NativeEngine, ScoreMode};
use sketchboost::tree::builder::{build_tree_in, BuildParams};
use sketchboost::tree::workspace::TreeWorkspace;

#[test]
fn steady_state_builds_allocate_only_the_tree_artifact() {
    let n = 2000;
    let d = 8;
    let ds = make_multiclass(n, FeatureSpec::guyon(12), d, 1.6, 5);
    let binned = BinnedDataset::from_dataset(&ds, 32);
    let rows: Vec<u32> = (0..n as u32).collect();
    // deterministic pseudo-gradients: same tree every build
    let mut g = vec![0.0f32; n * d];
    for (i, v) in g.iter_mut().enumerate() {
        *v = ((i * 2654435761) % 1000) as f32 / 500.0 - 1.0;
    }
    let h = vec![1.0f32; n * d];
    let params = BuildParams {
        binned: &binned,
        rows: &rows,
        g: &g,
        h: &h,
        d,
        score_g: &g,
        kc: d,
        score_h: None,
        mode: ScoreMode::CountL2,
        max_depth: 6,
        lambda: 1.0,
        min_data_in_leaf: 1,
        min_gain: 0.0,
        feature_mask: None,
        sparse_topk: None,
        row_weights: None,
        missing: MissingPolicy::Learn,
    };

    let mut engine = NativeEngine::new();
    let mut ws = TreeWorkspace::new();

    // cold build: grows every pooled buffer to its high-water mark
    let before_cold = alloc_count();
    let tree0 = build_tree_in(&params, &mut engine, &mut ws);
    let cold = alloc_count() - before_cold;
    assert!(tree0.n_leaves > 1, "workload must actually grow a tree");

    // steady state: identical inputs -> identical tree -> identical,
    // small, constant allocation count per build
    let mut steady = Vec::new();
    for _ in 0..4 {
        let before = alloc_count();
        let tree = build_tree_in(&params, &mut engine, &mut ws);
        steady.push(alloc_count() - before);
        assert_eq!(tree.n_leaves, tree0.n_leaves);
    }
    assert!(
        steady.windows(2).all(|w| w[0] == w[1]),
        "steady-state builds must allocate identically: {steady:?}"
    );
    // artifact-only budget: tree node vec growth (~log2(63) reallocs),
    // the leaf-value vec, and the debug-build validate() walk (3 vecs +
    // stack growth). The per-level loop itself (histograms, gains,
    // routing, sibling subtraction) contributes zero.
    assert!(
        steady[0] <= 32,
        "steady-state build allocates {} times (> artifact budget); \
         a pooled buffer is being reallocated",
        steady[0]
    );
    assert!(
        steady[0] < cold,
        "cold build ({cold}) should exceed steady state ({})",
        steady[0]
    );
}

#[test]
fn steady_state_allocations_do_not_scale_with_depth() {
    // The per-level loop must be allocation-free: a depth-6 build (up to
    // 6 levels, 32-wide frontier) may not allocate more in steady state
    // than the artifact of its own tree shape requires. We check that
    // doubling the level count does not add per-level allocations by
    // comparing two steady-state builds of the *same* depth against each
    // other at depths 3 and 6 — both must be internally constant (the
    // cross-depth counts differ only through the tree artifact size).
    let n = 1500;
    let ds = make_multiclass(n, FeatureSpec::guyon(10), 4, 1.6, 9);
    let binned = BinnedDataset::from_dataset(&ds, 16);
    let rows: Vec<u32> = (0..n as u32).collect();
    let mut g = vec![0.0f32; n * 4];
    for (i, v) in g.iter_mut().enumerate() {
        *v = ((i * 40503) % 997) as f32 / 500.0 - 1.0;
    }
    let h = vec![1.0f32; n * 4];

    for depth in [3usize, 6] {
        let params = BuildParams {
            binned: &binned,
            rows: &rows,
            g: &g,
            h: &h,
            d: 4,
            score_g: &g,
            kc: 4,
            score_h: None,
            mode: ScoreMode::CountL2,
            max_depth: depth,
            lambda: 1.0,
            min_data_in_leaf: 1,
            min_gain: 0.0,
            feature_mask: None,
            sparse_topk: None,
            row_weights: None,
            missing: MissingPolicy::Learn,
        };
        let mut engine = NativeEngine::new();
        let mut ws = TreeWorkspace::new();
        build_tree_in(&params, &mut engine, &mut ws); // warm up
        build_tree_in(&params, &mut engine, &mut ws);
        let mut counts = Vec::new();
        for _ in 0..3 {
            let before = alloc_count();
            build_tree_in(&params, &mut engine, &mut ws);
            counts.push(alloc_count() - before);
        }
        assert!(
            counts.windows(2).all(|w| w[0] == w[1]) && counts[0] <= 32,
            "depth {depth}: steady-state counts {counts:?}"
        );
    }
}
