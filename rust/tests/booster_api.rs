//! Public training-API gate (PR 4): the `Booster` builder/session must
//! be the same trainer `GBDT::fit` always was — bitwise — and the new
//! extension points (Objective / EvalMetric / Callback) must work end
//! to end without touching core files.
//!
//! * builder-vs-`GBDT::fit` bitwise equivalence across all five sketch
//!   strategies × all three built-in losses × 1/2/4 engine threads;
//! * early-stopping-as-a-callback matches the `early_stopping_rounds`
//!   config field round for round (same stop round, same truncation,
//!   same history);
//! * a user-defined quantile objective trains through the public trait
//!   and survives a save→load round trip;
//! * `Checkpoint` files are complete models: they re-load and predict
//!   the bit-exact prefix of the final ensemble.

use sketchboost::boosting::sampling::RowSampling;
use sketchboost::prelude::*;

fn dataset_for(loss: LossKind, seed: u64) -> (Dataset, GBDTConfig) {
    use sketchboost::data::synthetic::{
        make_multiclass, make_multilabel, make_multitask, FeatureSpec,
    };
    let (ds, mut cfg) = match loss {
        LossKind::MulticlassCE => {
            let ds = make_multiclass(400, FeatureSpec::guyon(10), 6, 2.0, seed);
            (ds, GBDTConfig::multiclass(6))
        }
        LossKind::BCE => {
            let ds = make_multilabel(400, FeatureSpec::guyon(10), 6, 2, seed);
            (ds, GBDTConfig::multilabel(6))
        }
        LossKind::MSE => {
            let ds = make_multitask(400, FeatureSpec::guyon(10), 6, 2, 0.1, seed);
            (ds, GBDTConfig::multitask(6))
        }
    };
    cfg.n_rounds = 6;
    cfg.learning_rate = 0.3;
    cfg.max_depth = 3;
    cfg.max_bins = 16;
    (ds, cfg)
}

fn assert_bitwise(a: &Ensemble, b: &Ensemble, label: &str) {
    assert_eq!(a.base_score, b.base_score, "{label}: base score");
    assert_eq!(a.n_trees(), b.n_trees(), "{label}: tree count");
    for (i, (ta, tb)) in a.trees.iter().zip(&b.trees).enumerate() {
        assert_eq!(ta.nodes, tb.nodes, "{label}: tree {i} structure");
        assert_eq!(ta.leaf_values, tb.leaf_values, "{label}: tree {i} leaf values");
    }
}

/// The gate: Booster-built ensembles are bitwise-identical to
/// `GBDT::fit` for every sketch × built-in loss × thread count.
#[test]
fn builder_matches_gbdt_fit_bitwise_across_sketches_losses_threads() {
    for loss in [LossKind::MulticlassCE, LossKind::BCE, LossKind::MSE] {
        for sketch in [
            SketchConfig::None,
            SketchConfig::TopOutputs { k: 2 },
            SketchConfig::RandomSampling { k: 2 },
            SketchConfig::RandomProjection { k: 2 },
            SketchConfig::TruncatedSvd { k: 2, iters: 4 },
        ] {
            let (ds, mut cfg) = dataset_for(loss, 31);
            cfg.sketch = sketch;
            let baseline = {
                let mut c = cfg.clone();
                c.n_threads = 1;
                GBDT::fit(&c, &ds, None)
            };
            for threads in [1usize, 2, 4] {
                cfg.n_threads = threads;
                let label =
                    format!("loss={} sketch={} threads={threads}", loss.name(), sketch.name());
                let via_fit = GBDT::fit(&cfg, &ds, None);
                let via_builder = Booster::new(&cfg).fit(&ds, None);
                assert_bitwise(&via_fit, &via_builder, &label);
                // and thread count never changes the bits of either path
                assert_bitwise(&baseline, &via_builder, &label);
                assert_eq!(
                    via_fit.predict_raw(&ds),
                    via_builder.predict_raw(&ds),
                    "{label}: predictions"
                );
                assert_eq!(
                    via_fit.history.train_loss, via_builder.history.train_loss,
                    "{label}: history"
                );
            }
        }
    }
}

/// Sampling paths (uniform / GOSS / MVS) draw from the same per-round
/// RNG fork points in the session — the builder must not disturb them.
#[test]
fn builder_matches_gbdt_fit_under_row_sampling() {
    for (label, sampling, subsample) in [
        ("subsample", RowSampling::None, 0.7f32),
        ("goss", RowSampling::Goss { top_rate: 0.2, other_rate: 0.2 }, 1.0),
        ("mvs", RowSampling::Mvs { rate: 0.5 }, 1.0),
    ] {
        let (ds, mut cfg) = dataset_for(LossKind::MulticlassCE, 57);
        cfg.row_sampling = sampling;
        cfg.subsample = subsample;
        cfg.colsample = 0.6;
        cfg.sketch = SketchConfig::RandomSampling { k: 2 };
        let a = GBDT::fit(&cfg, &ds, None);
        let b = Booster::new(&cfg).fit(&ds, None);
        assert_bitwise(&a, &b, label);
    }
}

/// Early stopping as an attached callback == the config field, round
/// for round: same stop point, same best round, same truncated trees,
/// same recorded history.
#[test]
fn early_stopping_callback_matches_config_round_for_round() {
    let (ds, mut cfg) = dataset_for(LossKind::MulticlassCE, 11);
    let (train, valid) = split::train_test_split(&ds, 0.3, 1);
    cfg.n_rounds = 200;
    cfg.learning_rate = 0.5; // aggressive: overfits, so stopping triggers
    for patience in [3usize, 5] {
        cfg.early_stopping_rounds = patience;
        let via_config = GBDT::fit(&cfg, &train, Some(&valid));
        assert!(via_config.n_trees() < cfg.n_rounds, "stopping must trigger");
        let mut cfg_cb = cfg.clone();
        cfg_cb.early_stopping_rounds = 0;
        let via_callback = Booster::new(&cfg_cb)
            .callback(EarlyStopping::new(patience))
            .fit(&train, Some(&valid));
        let label = format!("patience={patience}");
        assert_bitwise(&via_config, &via_callback, &label);
        assert_eq!(
            via_config.history.valid_loss, via_callback.history.valid_loss,
            "{label}: same rounds ran, same scores"
        );
        assert_eq!(
            via_config.history.best_round, via_callback.history.best_round,
            "{label}: best round"
        );
        assert_eq!(via_config.n_trees(), via_config.history.best_round + 1, "{label}");
    }
}

/// A custom objective + metric defined right here (zero edits to
/// `boosting/`), trained through the public API, saved, re-loaded.
///
/// Deliberately a standalone copy of the pinball math rather than an
/// include of `examples/custom_objective.rs`: the test must prove the
/// trait surface is sufficient *on its own*, and the example stays a
/// didactic artifact free to drift toward readability. Both are
/// CI-executed, so neither copy can rot silently.
struct QuantileLoss {
    tau: f32,
}

impl Objective for QuantileLoss {
    fn name(&self) -> &str {
        "quantile"
    }

    fn base_score(&self, targets: &Targets, d: usize) -> Vec<f32> {
        let values = match targets {
            Targets::Regression { values, .. } => values,
            _ => panic!("quantile needs regression targets"),
        };
        let n = values.len() / d;
        let idx = (((n - 1) as f32) * self.tau).round() as usize;
        (0..d)
            .map(|j| {
                let mut col: Vec<f32> = (0..n).map(|i| values[i * d + j]).collect();
                col.sort_by(f32::total_cmp);
                col[idx]
            })
            .collect()
    }

    fn grad_hess(
        &mut self,
        preds: &[f32],
        targets: &Targets,
        _d: usize,
        g: &mut [f32],
        h: &mut [f32],
    ) -> f64 {
        let values = match targets {
            Targets::Regression { values, .. } => values,
            _ => panic!("quantile needs regression targets"),
        };
        let tau = self.tau;
        let mut loss = 0.0f64;
        for i in 0..values.len() {
            let under = preds[i] <= values[i];
            g[i] = if under { -tau } else { 1.0 - tau };
            h[i] = 1.0;
            let e = (values[i] - preds[i]) as f64;
            loss += if under { tau as f64 * e } else { (tau as f64 - 1.0) * e };
        }
        loss / values.len() as f64
    }

    fn default_metric(&self) -> Box<dyn EvalMetric> {
        Box::new(Pinball { tau: self.tau })
    }
}

struct Pinball {
    tau: f32,
}

impl EvalMetric for Pinball {
    fn name(&self) -> &str {
        "pinball"
    }

    fn eval(&self, preds: &[f32], targets: &Targets) -> f64 {
        let values = match targets {
            Targets::Regression { values, .. } => values,
            _ => panic!("pinball needs regression targets"),
        };
        let tau = self.tau as f64;
        let mut total = 0.0f64;
        for i in 0..values.len() {
            let e = values[i] as f64 - preds[i] as f64;
            total += if e >= 0.0 { tau * e } else { (tau - 1.0) * e };
        }
        total / values.len() as f64
    }
}

#[test]
fn custom_quantile_objective_trains_and_roundtrips() {
    let (ds, mut cfg) = dataset_for(LossKind::MSE, 23);
    cfg.n_rounds = 25;
    cfg.learning_rate = 0.2;
    let model = Booster::new(&cfg)
        .objective(QuantileLoss { tau: 0.8 })
        .metric(Pinball { tau: 0.8 })
        .fit(&ds, None);
    assert_eq!(model.n_trees(), 25);
    let hist = &model.history.train_loss;
    assert!(
        hist.first().unwrap() > hist.last().unwrap(),
        "pinball loss must decrease: {hist:?}"
    );
    // custom objectives default to the identity link, serialized as mse
    assert_eq!(model.loss, LossKind::MSE);

    let dir = std::env::temp_dir().join("sb_booster_api_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("quantile.json");
    model.save(&path).unwrap();
    let back = Ensemble::load(&path).unwrap();
    assert_eq!(back.predict_raw(&ds), model.predict_raw(&ds), "round trip bits");
    // identity link: predict == predict_raw for the loaded model
    assert_eq!(back.predict(&ds), back.predict_raw(&ds));

    // determinism holds for custom objectives too (pure grad_hess)
    let again = Booster::new(&cfg)
        .objective(QuantileLoss { tau: 0.8 })
        .metric(Pinball { tau: 0.8 })
        .fit(&ds, None);
    assert_bitwise(&model, &again, "custom objective reruns");
}

/// A higher quantile must predict (weakly) higher values on average —
/// the objective actually steers the trees, not just the base score.
#[test]
fn quantile_tau_orders_predictions() {
    let (ds, mut cfg) = dataset_for(LossKind::MSE, 41);
    cfg.n_rounds = 30;
    cfg.learning_rate = 0.2;
    let mean_pred = |tau: f32| {
        let m = Booster::new(&cfg).objective(QuantileLoss { tau }).fit(&ds, None);
        let p = m.predict_raw(&ds);
        p.iter().map(|&x| x as f64).sum::<f64>() / p.len() as f64
    };
    let (lo, hi) = (mean_pred(0.2), mean_pred(0.8));
    assert!(lo < hi, "q20 mean {lo} must sit below q80 mean {hi}");
}

#[test]
fn checkpoint_files_reload_and_predict_the_prefix() {
    let (ds, mut cfg) = dataset_for(LossKind::BCE, 19);
    cfg.n_rounds = 10;
    let dir = std::env::temp_dir().join("sb_booster_api_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    let tpl = dir.join("bce_{round}.json");
    let full = Booster::new(&cfg)
        .callback(Checkpoint::every(tpl.to_str().unwrap(), 4))
        .fit(&ds, None);
    assert_eq!(full.n_trees(), 10);
    for done in [4usize, 8] {
        let path = dir.join(format!("bce_{done}.json"));
        let ck = Ensemble::load(&path).unwrap_or_else(|e| panic!("{path:?}: {e}"));
        assert_eq!(ck.n_trees(), done, "checkpoint at {done} completed rounds");
        assert_eq!(ck.loss, LossKind::BCE);
        let mut prefix = full.clone();
        prefix.trees.truncate(done);
        assert_eq!(
            ck.predict_raw(&ds),
            prefix.predict_raw(&ds),
            "checkpoint {done} is the bit-exact prefix"
        );
    }
}

/// Callbacks observe but never steer the numerics: a model trained
/// with a logger + time budget that never fires + checkpoints is
/// bit-identical to a bare run.
#[test]
fn passive_callbacks_do_not_change_bits() {
    let (ds, cfg) = dataset_for(LossKind::MulticlassCE, 67);
    let bare = Booster::new(&cfg).fit(&ds, None);
    let dir = std::env::temp_dir().join("sb_booster_api_passive");
    std::fs::create_dir_all(&dir).unwrap();
    let decorated = Booster::new(&cfg)
        .callback(EvalLogger::every(2))
        .callback(TimeBudget::seconds(1e9))
        .callback(Checkpoint::every(dir.join("p.json").to_str().unwrap(), 3))
        .fit(&ds, None);
    assert_bitwise(&bare, &decorated, "passive callbacks");
}

/// The wart fix: with no validation set and `eval_train` off, history
/// still records a per-round train loss — the gradient pass's free
/// (pre-update) loss — and the trees are unchanged.
#[test]
fn no_valid_cheap_eval_reuses_grad_pass_loss() {
    for loss in [LossKind::MulticlassCE, LossKind::BCE, LossKind::MSE] {
        let (ds, mut cfg) = dataset_for(loss, 73);
        cfg.eval_train = false;
        let cheap = GBDT::fit(&cfg, &ds, None);
        assert_eq!(
            cheap.history.train_loss.len(),
            cfg.n_rounds,
            "{}: cheap mode still records history",
            loss.name()
        );
        let mut cfg_eval = cfg.clone();
        cfg_eval.eval_train = true;
        let evaled = GBDT::fit(&cfg_eval, &ds, None);
        assert_bitwise(&cheap, &evaled, loss.name());
        // the free loss is one round stale: entry r of the cheap run
        // scores the ensemble entry r-1 of the evaluated run scored
        // (approximately — f32 vs f64 softmax intermediates for CE)
        for r in 1..cfg.n_rounds {
            let (a, b) = (cheap.history.train_loss[r], evaled.history.train_loss[r - 1]);
            assert!(
                (a - b).abs() < 1e-3 * b.abs().max(1.0),
                "{} round {r}: stale loss {a} vs eval {b}",
                loss.name()
            );
        }
    }
}
