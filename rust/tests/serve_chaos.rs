//! Chaos suite for `sketchboost serve`: seeded fault plans drive the
//! named fault points (`rust/src/util/fault.rs`) while real clients
//! hammer a real daemon on a loopback port.
//!
//! Two invariants hold under **every** plan in this file:
//!
//! 1. every response that is not a structured `!<code>` error is
//!    **bitwise-equal** to offline `FlatForest` predict on the same
//!    rows, and
//! 2. the daemon drains cleanly — `Server::stop` returns (a per-test
//!    watchdog aborts the process if anything deadlocks).
//!
//! Runs only with the fault points armed:
//!
//! ```text
//! cargo test --features fault-injection --test serve_chaos
//! ```
//!
//! Seeds come from `SB_CHAOS_SEED` (default 0) so CI can replay the
//! probabilistic plans across several fixed seeds. Counter-triggered
//! plans (`@k`) are seed-independent by construction.
#![cfg(feature = "fault-injection")]

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use sketchboost::data::synthetic::{make_multilabel, FeatureSpec};
use sketchboost::prelude::*;
use sketchboost::serve::{ServeOptions, Server};
use sketchboost::util::fault::{self, FaultPlan};
use sketchboost::util::json::Json;

// -----------------------------------------------------------------
// harness
// -----------------------------------------------------------------

/// Seed for the probabilistic plans (CI replays a few fixed values).
fn chaos_seed() -> u64 {
    std::env::var("SB_CHAOS_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0)
}

/// Abort the whole process if `f` runs longer than `secs` — a deadlock
/// in a drain path must fail the suite, not hang it forever.
fn with_watchdog<F: FnOnce()>(secs: u64, f: F) {
    let done = Arc::new(AtomicBool::new(false));
    let flag = done.clone();
    std::thread::spawn(move || {
        let deadline = Instant::now() + Duration::from_secs(secs);
        while Instant::now() < deadline {
            if flag.load(Ordering::SeqCst) {
                return;
            }
            std::thread::sleep(Duration::from_millis(50));
        }
        eprintln!("chaos watchdog: test exceeded {secs}s — aborting (deadlocked drain?)");
        std::process::abort();
    });
    f();
    done.store(true, Ordering::SeqCst);
}

/// Train a small multilabel model and save it where the server loads it.
fn train_and_save(dir: &str, seed: u64) -> (Dataset, Ensemble, PathBuf) {
    let ds = make_multilabel(150, FeatureSpec::guyon(10), 4, 3, seed);
    let mut cfg = GBDTConfig::multilabel(4);
    cfg.n_rounds = 4;
    cfg.max_depth = 4;
    cfg.max_bins = 16;
    cfg.seed = seed;
    let model = GBDT::fit(&cfg, &ds, None);
    let d = std::env::temp_dir().join(dir);
    std::fs::create_dir_all(&d).unwrap();
    let path = d.join(format!("model_{seed}.json"));
    model.save(&path).unwrap();
    (ds, model, path)
}

fn row_line(ds: &Dataset, i: usize) -> String {
    ds.row(i).iter().map(|v| format!("{v}")).collect::<Vec<_>>().join(",")
}

/// Split a response into scores, or the structured error after `!`.
fn scores_or_err(line: &str) -> Result<Vec<f32>, String> {
    if let Some(err) = line.strip_prefix('!') {
        return Err(err.to_string());
    }
    Ok(line
        .split(';')
        .flat_map(|row| row.split(','))
        .map(|c| c.parse::<f32>().unwrap())
        .collect())
}

fn assert_bits_eq(want: &[f32], got: &[f32], ctx: &str) {
    assert_eq!(want.len(), got.len(), "{ctx}: length");
    for (i, (a, b)) in want.iter().zip(got).enumerate() {
        assert!(a.to_bits() == b.to_bits(), "{ctx}: cell {i} differs ({a:?} vs {b:?})");
    }
}

/// Blocking request/response client on one connection.
struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        stream.set_nodelay(true).unwrap();
        Client { reader: BufReader::new(stream.try_clone().unwrap()), writer: stream }
    }

    fn send(&mut self, line: &str) {
        self.writer.write_all(line.as_bytes()).unwrap();
        self.writer.write_all(b"\n").unwrap();
        self.writer.flush().unwrap();
    }

    fn recv(&mut self) -> String {
        let mut resp = String::new();
        self.reader.read_line(&mut resp).unwrap();
        assert!(resp.ends_with('\n'), "truncated response: {resp:?}");
        resp.trim_end().to_string()
    }

    fn request(&mut self, line: &str) -> String {
        self.send(line);
        self.recv()
    }

    fn stats(&mut self) -> Json {
        Json::parse(&self.request("/stats")).unwrap()
    }
}

fn stat(stats: &Json, key: &str) -> usize {
    stats.get(key).unwrap_or_else(|| panic!("/stats missing {key}")).as_usize().unwrap()
}

// -----------------------------------------------------------------
// size caps and malformed input (no injected faults — empty plan held
// so a concurrent chaos test cannot contaminate this server)
// -----------------------------------------------------------------

#[test]
fn oversized_and_malformed_requests_degrade_structurally() {
    with_watchdog(90, || {
        let _guard = fault::install(FaultPlan::empty());
        let (ds, model, path) = train_and_save("sb_chaos_caps", 11);
        let naive = model.predict_raw_naive(&ds);
        let d = model.n_outputs;
        let opts = ServeOptions {
            n_workers: 1,
            max_rows: 2,
            max_line_bytes: 4096,
            ..ServeOptions::default()
        };
        let server = Server::start(&path, &opts).unwrap();
        let mut client = Client::connect(server.addr());

        // over the row cap: rejected before any cell parses
        let resp = client.request("1;2;3");
        assert!(resp.starts_with("!too_large"), "{resp}");

        // a line over the byte cap: one !too_large, bounded memory, and
        // the connection recovers for the next (pipelined) request
        let huge = "1,".repeat(8000); // ~16 KB >> 4 KB cap
        client.send(&huge);
        client.send(&row_line(&ds, 5));
        let resp = client.recv();
        assert!(resp.starts_with("!too_large"), "{resp}");
        let got = scores_or_err(&client.recv()).unwrap();
        assert_bits_eq(&naive[5 * d..6 * d], &got, "after oversized line");

        // plain garbage still gets a plain parse error
        assert!(client.request("1,spam").starts_with('!'));

        let stats = client.stats();
        assert_eq!(stat(&stats, "too_large"), 2);
        assert_eq!(stat(&stats, "n_errors"), 3);
        assert_eq!(stat(&stats, "shed"), 0);
        server.stop();
    });
}

// -----------------------------------------------------------------
// slow-loris / half-open clients
// -----------------------------------------------------------------

#[test]
fn idle_connections_are_reaped_without_disturbing_active_ones() {
    with_watchdog(90, || {
        let _guard = fault::install(FaultPlan::empty());
        let (ds, model, path) = train_and_save("sb_chaos_idle", 12);
        let naive = model.predict_raw_naive(&ds);
        let d = model.n_outputs;
        let opts =
            ServeOptions { n_workers: 1, idle_timeout_ms: 150, ..ServeOptions::default() };
        let server = Server::start(&path, &opts).unwrap();
        let addr = server.addr();

        // a slow loris: dribbles half a line, then goes quiet
        let mut loris = TcpStream::connect(addr).unwrap();
        loris.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        loris.write_all(b"1,2,3").unwrap(); // no newline, ever
        loris.flush().unwrap();

        // a half-open peer: connects and sends nothing at all
        let half_open = TcpStream::connect(addr).unwrap();

        // both must be closed by the reaper: the loris reads the
        // timeout notice then EOF
        let mut text = String::new();
        loris.read_to_string(&mut text).unwrap(); // returns only on EOF
        assert!(text.starts_with("!timeout"), "loris got {text:?}");
        drop(half_open);

        // an active client on the same server was never disturbed
        let mut client = Client::connect(addr);
        let got = scores_or_err(&client.request(&row_line(&ds, 7))).unwrap();
        assert_bits_eq(&naive[7 * d..8 * d], &got, "active client");
        assert!(stat(&client.stats(), "idle_closed") >= 1);
        server.stop();
    });
}

// -----------------------------------------------------------------
// queue saturation: shed policies
// -----------------------------------------------------------------

#[test]
fn full_queue_sheds_with_drop_policy_and_blocks_with_default() {
    with_watchdog(120, || {
        let (ds, model, path) = train_and_save("sb_chaos_shed", 13);
        let naive = model.predict_raw_naive(&ds);
        let d = model.n_outputs;
        let n_req = 10usize;

        // drop policy: a slow worker (50ms per request) + a 2-deep queue
        // forces overload on a pipelined burst
        {
            let _guard = fault::install(
                FaultPlan::parse("serve.worker.score:delay-50", chaos_seed()).unwrap(),
            );
            let opts = ServeOptions {
                n_workers: 1,
                block_rows: 1,
                max_wait_us: 0,
                queue_cap: 2,
                shed: sketchboost::serve::ShedPolicy::Drop,
                ..ServeOptions::default()
            };
            let server = Server::start(&path, &opts).unwrap();
            let mut client = Client::connect(server.addr());
            for i in 0..n_req {
                client.send(&row_line(&ds, i));
            }
            let (mut ok, mut overloaded) = (0usize, 0usize);
            for i in 0..n_req {
                match scores_or_err(&client.recv()) {
                    Ok(got) => {
                        ok += 1;
                        assert_bits_eq(&naive[i * d..(i + 1) * d], &got, &format!("row {i}"));
                    }
                    Err(e) => {
                        overloaded += 1;
                        assert!(e.starts_with("overloaded"), "row {i}: {e}");
                    }
                }
            }
            assert!(ok >= 1, "the first request always fits");
            assert!(overloaded >= 6, "a 2-deep queue cannot hold a burst of {n_req}");
            let stats = client.stats();
            assert_eq!(stat(&stats, "shed"), overloaded, "shed counter matches responses");
            assert!(stat(&stats, "queue_depth_hwm") >= 2, "the queue visibly filled");
            server.stop();
        }

        // block policy (the default): same burst, nothing is shed —
        // backpressure parks the reader instead
        {
            let _guard = fault::install(
                FaultPlan::parse("serve.worker.score:delay-50", chaos_seed()).unwrap(),
            );
            let opts = ServeOptions {
                n_workers: 1,
                block_rows: 1,
                max_wait_us: 0,
                queue_cap: 2,
                ..ServeOptions::default()
            };
            let server = Server::start(&path, &opts).unwrap();
            let mut client = Client::connect(server.addr());
            for i in 0..n_req {
                client.send(&row_line(&ds, i));
            }
            for i in 0..n_req {
                let got = scores_or_err(&client.recv()).unwrap();
                assert_bits_eq(&naive[i * d..(i + 1) * d], &got, &format!("blocked row {i}"));
            }
            assert_eq!(stat(&client.stats(), "shed"), 0);
            server.stop();
        }
    });
}

// -----------------------------------------------------------------
// worker panic isolation
// -----------------------------------------------------------------

#[test]
fn worker_panic_poisons_only_the_affected_request() {
    with_watchdog(90, || {
        // the third scored request panics, exactly once
        let _guard = fault::install(
            FaultPlan::parse("serve.worker.score:panic@3", chaos_seed()).unwrap(),
        );
        let (ds, model, path) = train_and_save("sb_chaos_panic", 14);
        let naive = model.predict_raw_naive(&ds);
        let d = model.n_outputs;
        let opts = ServeOptions { n_workers: 1, ..ServeOptions::default() };
        let server = Server::start(&path, &opts).unwrap();
        let mut client = Client::connect(server.addr());

        // sequential requests on one worker: hit order == request order
        for i in 0..6usize {
            let resp = client.request(&row_line(&ds, i));
            if i == 2 {
                // the victim gets a structured internal error...
                assert!(resp.starts_with("!internal"), "request 3 got {resp}");
            } else {
                // ...and everyone else, before and after, exact bits —
                // same connection, worker still alive
                let got = scores_or_err(&resp).unwrap();
                assert_bits_eq(&naive[i * d..(i + 1) * d], &got, &format!("row {i}"));
            }
        }
        let stats = client.stats();
        assert_eq!(stat(&stats, "worker_panics"), 1, "exactly the planned panic");
        assert_eq!(stat(&stats, "n_requests"), 5, "five requests scored cleanly");
        assert_eq!(fault::hits("serve.worker.score"), 6, "every score hit the point");
        server.stop();
    });
}

/// A plan that panics on *every* scoring attempt from the second on:
/// the drain must still terminate (each victim resolves to `!internal`,
/// nothing hangs) — the "no deadlock under any plan" half of the
/// invariant.
#[test]
fn drain_terminates_while_panics_keep_firing() {
    with_watchdog(90, || {
        let _guard = fault::install(
            FaultPlan::parse("serve.worker.score:panic@2+", chaos_seed()).unwrap(),
        );
        let (ds, model, path) = train_and_save("sb_chaos_drain", 15);
        let naive = model.predict_raw_naive(&ds);
        let d = model.n_outputs;
        let opts = ServeOptions { n_workers: 2, ..ServeOptions::default() };
        let server = Server::start(&path, &opts).unwrap();
        let mut client = Client::connect(server.addr());

        let mut ok = 0usize;
        for i in 0..12usize {
            match scores_or_err(&client.request(&row_line(&ds, i))) {
                Ok(got) => {
                    ok += 1;
                    assert_bits_eq(&naive[i * d..(i + 1) * d], &got, &format!("row {i}"));
                }
                Err(e) => assert!(e.starts_with("internal"), "row {i}: {e}"),
            }
        }
        assert_eq!(ok, 1, "only the first score precedes the @2+ panic storm");
        let stats = client.stats();
        assert_eq!(stat(&stats, "worker_panics"), 11);
        drop(client);
        server.stop(); // must return: the watchdog is the assertion
    });
}

// -----------------------------------------------------------------
// hot-swap failures: injected load failure + same-length rewrite
// -----------------------------------------------------------------

#[test]
fn swap_survives_injected_load_failure_and_same_length_rewrite() {
    with_watchdog(120, || {
        // the first reload attempt fails; the retry must succeed
        let _guard = fault::install(
            FaultPlan::parse("serve.swap.load:fail@1", chaos_seed()).unwrap(),
        );
        let (ds, model_a, path) = train_and_save("sb_chaos_swap", 16);
        let (_, model_b, path_b) = train_and_save("sb_chaos_swap", 17);
        let naive_a = model_a.predict_raw_naive(&ds);
        let naive_b = model_b.predict_raw_naive(&ds);
        let d = model_a.n_outputs;

        // craft the fingerprint-race regression pair: pad the shorter
        // model JSON with trailing whitespace (the parser tolerates it)
        // so the two files have the SAME byte length — (mtime, len)
        // alone could miss this rewrite on coarse-mtime filesystems
        let mut bytes_a = std::fs::read(&path).unwrap();
        let mut bytes_b = std::fs::read(&path_b).unwrap();
        let target = bytes_a.len().max(bytes_b.len());
        bytes_a.resize(target, b' ');
        bytes_b.resize(target, b' ');
        std::fs::write(&path, &bytes_a).unwrap();
        std::fs::remove_file(&path_b).unwrap();

        let opts = ServeOptions { n_workers: 2, poll_ms: 10, ..ServeOptions::default() };
        let server = Server::start(&path, &opts).unwrap();
        let addr = server.addr();
        assert_eq!(server.model_version(), 1);

        std::thread::scope(|s| {
            // hammer the server across the whole failure + retry window
            let stop = Arc::new(AtomicBool::new(false));
            let mut hammers = Vec::new();
            for t in 0..2usize {
                let (ds, naive_a, naive_b, stop) = (&ds, &naive_a, &naive_b, stop.clone());
                hammers.push(s.spawn(move || {
                    let mut client = Client::connect(addr);
                    let mut k = 0usize;
                    while !stop.load(Ordering::SeqCst) {
                        let i = (t * 37 + k * 7) % ds.n_rows;
                        let got = scores_or_err(&client.request(&row_line(ds, i))).unwrap();
                        let want_a = &naive_a[i * d..(i + 1) * d];
                        let want_b = &naive_b[i * d..(i + 1) * d];
                        let eq = |w: &[f32]| {
                            w.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits())
                        };
                        assert!(eq(want_a) || eq(want_b), "client {t} req {k}: torn response");
                        k += 1;
                    }
                }));
            }

            // same-length rewrite of the watched file, atomically
            // (write-new + rename) so the only load failure the watcher
            // can see is the injected one
            std::thread::sleep(Duration::from_millis(50));
            let old_len = std::fs::metadata(&path).unwrap().len();
            let tmp = path.with_extension("json.tmp");
            std::fs::write(&tmp, &bytes_b).unwrap();
            std::fs::rename(&tmp, &path).unwrap();
            assert_eq!(std::fs::metadata(&path).unwrap().len(), old_len, "same-length pair");

            // attempt 1 is injected to fail (old model keeps serving),
            // the backoff retry must land the swap
            let deadline = Instant::now() + Duration::from_secs(30);
            let swapped = loop {
                if server.model_version() >= 2 {
                    break true;
                }
                if Instant::now() >= deadline {
                    break false;
                }
                std::thread::sleep(Duration::from_millis(5));
            };
            // release the hammers before asserting, so a failure here
            // reports as a test failure rather than a watchdog abort
            stop.store(true, Ordering::SeqCst);
            for h in hammers {
                h.join().unwrap();
            }
            assert!(swapped, "swap never recovered from the injected failure");
        });

        // post-swap traffic is model B, bit-for-bit
        let mut client = Client::connect(addr);
        for i in (0..ds.n_rows).step_by(29) {
            let got = scores_or_err(&client.request(&row_line(&ds, i))).unwrap();
            assert_bits_eq(&naive_b[i * d..(i + 1) * d], &got, &format!("post-swap row {i}"));
        }
        let stats = client.stats();
        assert_eq!(stat(&stats, "swap_failures"), 1, "exactly the injected failure");
        assert_eq!(stat(&stats, "n_reloads"), 1);
        assert!(fault::hits("serve.swap.load") >= 2, "failed attempt + successful retry");
        server.stop();
    });
}

// -----------------------------------------------------------------
// deadlines
// -----------------------------------------------------------------

#[test]
fn requests_queued_past_their_deadline_are_shed_with_timeout() {
    with_watchdog(120, || {
        // every score takes ~1s; with a 250ms deadline only the request
        // a worker picks up immediately survives
        let _guard = fault::install(
            FaultPlan::parse("serve.worker.score:delay-1000", chaos_seed()).unwrap(),
        );
        let (ds, model, path) = train_and_save("sb_chaos_deadline", 18);
        let naive = model.predict_raw_naive(&ds);
        let d = model.n_outputs;
        let opts = ServeOptions {
            n_workers: 1,
            block_rows: 1,
            max_wait_us: 0,
            deadline_ms: 250,
            ..ServeOptions::default()
        };
        let server = Server::start(&path, &opts).unwrap();
        let mut client = Client::connect(server.addr());

        for i in 0..4usize {
            client.send(&row_line(&ds, i));
        }
        // request 0: popped at once, scored (slowly), exact bits
        let got = scores_or_err(&client.recv()).unwrap();
        assert_bits_eq(&naive[0..d], &got, "request 0");
        // requests 1-3: each popped ~1s after submission, way past the
        // 250ms deadline — shed with a structured timeout, not scored
        for i in 1..4usize {
            let err = scores_or_err(&client.recv()).unwrap_err();
            assert!(err.starts_with("timeout"), "request {i}: {err}");
        }
        let stats = client.stats();
        assert_eq!(stat(&stats, "timeouts"), 3);
        assert_eq!(stat(&stats, "n_requests"), 1);
        assert_eq!(fault::hits("serve.worker.score"), 1, "shed requests never score");
        server.stop();
    });
}

// -----------------------------------------------------------------
// probabilistic plans replay bit-for-bit
// -----------------------------------------------------------------

#[test]
fn probabilistic_fault_pattern_is_reproducible_for_a_seed() {
    with_watchdog(120, || {
        let (ds, model, path) = train_and_save("sb_chaos_prob", 19);
        let naive = model.predict_raw_naive(&ds);
        let d = model.n_outputs;
        let seed = chaos_seed().wrapping_add(7); // any fixed seed works

        // one sequential pass: per-request success/failure pattern
        let run = || -> Vec<bool> {
            let _guard =
                fault::install(FaultPlan::parse("serve.worker.score:fail%0.4", seed).unwrap());
            let opts = ServeOptions { n_workers: 1, ..ServeOptions::default() };
            let server = Server::start(&path, &opts).unwrap();
            let mut client = Client::connect(server.addr());
            let pattern: Vec<bool> = (0..30usize)
                .map(|i| match scores_or_err(&client.request(&row_line(&ds, i))) {
                    Ok(got) => {
                        assert_bits_eq(&naive[i * d..(i + 1) * d], &got, &format!("row {i}"));
                        true
                    }
                    Err(e) => {
                        assert!(e.starts_with("internal"), "row {i}: {e}");
                        false
                    }
                })
                .collect();
            server.stop();
            pattern
        };

        let first = run();
        let second = run();
        assert_eq!(first, second, "same (plan, seed) must replay the same fault pattern");
        assert!(first.iter().any(|&ok| ok), "p=0.4 over 30 requests should pass some");
        assert!(first.iter().any(|&ok| !ok), "p=0.4 over 30 requests should fail some");
    });
}
