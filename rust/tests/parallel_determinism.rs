//! The parallel engine's determinism contract, end to end: training with
//! `n_threads > 1` must produce **bit-identical** ensembles to the
//! single-thread path — same splits, same leaf values, same predictions —
//! because the engine's histogram sharding and reduction order are fixed
//! functions of the data shape, never of the thread count (see
//! `engine/native.rs` module docs and DESIGN.md "Threading model").
//!
//! These tests use row counts large enough to actually exercise the
//! sharded histogram path (>= 2 shards at the root level).

use sketchboost::data::profiles::Profile;
use sketchboost::engine::{ComputeEngine, NativeEngine};
use sketchboost::prelude::*;

/// `SB_TEST_SCALE` in (0, 1] shrinks the workload for slow
/// instrumented builds (ThreadSanitizer/AddressSanitizer run this suite
/// 5–20× slower); unset means full size.
fn test_scale() -> f64 {
    std::env::var("SB_TEST_SCALE")
        .ok()
        .and_then(|v| v.parse::<f64>().ok())
        .map(|s| s.clamp(0.05, 1.0))
        .unwrap_or(1.0)
}

/// A synthetic profile big enough to shard (otto: 9 classes, 93
/// features; 6000 rows ≈ 3 histogram shards at the root). The floor
/// keeps scaled runs above the 2·2048-row sharding threshold so the
/// parallel histogram path — the thing under test — still executes.
fn workload() -> Dataset {
    let rows = ((6000.0 * test_scale()) as usize).max(4200);
    Profile::by_name("otto").expect("otto profile").generate_sized(rows, 9)
}

fn assert_ensembles_identical(a: &Ensemble, b: &Ensemble, label: &str) {
    assert_eq!(a.n_trees(), b.n_trees(), "{label}: tree count");
    for (i, (ta, tb)) in a.trees.iter().zip(&b.trees).enumerate() {
        assert_eq!(ta.nodes.len(), tb.nodes.len(), "{label}: tree {i} node count");
        for (na, nb) in ta.nodes.iter().zip(&tb.nodes) {
            assert_eq!(na.feature, nb.feature, "{label}: tree {i} split feature");
            assert_eq!(na.bin, nb.bin, "{label}: tree {i} split bin");
            assert_eq!(na.left, nb.left, "{label}: tree {i} topology");
            assert_eq!(na.right, nb.right, "{label}: tree {i} topology");
        }
        // bitwise: no tolerance
        assert_eq!(ta.leaf_values, tb.leaf_values, "{label}: tree {i} leaf values");
    }
}

#[test]
fn ensembles_bit_identical_across_thread_counts() {
    let ds = workload();
    let mut cfg = GBDTConfig::for_dataset(&ds);
    cfg.n_rounds = 6;
    cfg.learning_rate = 0.3;
    cfg.max_depth = 5;
    cfg.max_bins = 32;
    cfg.sketch = SketchConfig::RandomProjection { k: 3 };

    cfg.n_threads = 1;
    let serial = GBDT::fit(&cfg, &ds, None);
    let serial_preds = serial.predict_raw(&ds);

    for threads in [2usize, 4] {
        cfg.n_threads = threads;
        let parallel = GBDT::fit(&cfg, &ds, None);
        assert_ensembles_identical(&serial, &parallel, &format!("n_threads={threads}"));
        assert_eq!(
            serial_preds,
            parallel.predict_raw(&ds),
            "n_threads={threads}: predictions must be bit-identical"
        );
        assert_eq!(serial.history.train_loss, parallel.history.train_loss);
    }
}

#[test]
fn every_sketch_strategy_is_thread_invariant() {
    // One round each: the sketches feed different channel widths (k1)
    // through the parallel histogram path, including the dyn fallback.
    let ds = workload();
    for sketch in [
        SketchConfig::None,
        SketchConfig::TopOutputs { k: 2 },
        SketchConfig::RandomSampling { k: 2 },
        SketchConfig::RandomProjection { k: 5 },
        SketchConfig::TruncatedSvd { k: 2, iters: 4 },
    ] {
        let mut cfg = GBDTConfig::for_dataset(&ds);
        cfg.n_rounds = 2;
        cfg.max_depth = 4;
        cfg.max_bins = 32;
        cfg.sketch = sketch;
        cfg.n_threads = 1;
        let a = GBDT::fit(&cfg, &ds, None);
        cfg.n_threads = 4;
        let b = GBDT::fit(&cfg, &ds, None);
        assert_eq!(
            a.predict_raw(&ds),
            b.predict_raw(&ds),
            "sketch {} must be thread-invariant",
            sketch.name()
        );
    }
}

#[test]
fn engine_histograms_thread_invariant_on_training_shapes() {
    // Engine-level check on a realistic shape: the builder's root-level
    // call (one segment, every row) is the biggest sharded histogram.
    use sketchboost::data::binning::BinnedDataset;
    use sketchboost::engine::SlotRange;

    let ds = workload();
    let binned = BinnedDataset::from_dataset(&ds, 64);
    let n = ds.n_rows;
    let k1 = 4usize;
    let mut chan = vec![0.0f32; n * k1];
    for (i, v) in chan.iter_mut().enumerate() {
        // deterministic, sign-alternating channel values
        *v = ((i * 2654435761) % 1000) as f32 / 500.0 - 1.0;
    }
    let rows: Vec<u32> = (0..n as u32).collect();
    let segs = [SlotRange::new(0, 0, n as u32)];
    let size = binned.n_features * binned.max_bins * k1;

    let mut base = vec![0.0f32; size];
    NativeEngine::with_threads(1).histograms(&binned, &rows, &chan, k1, &segs, 1, &mut base);
    for threads in [2usize, 4, 8] {
        let mut out = vec![0.0f32; size];
        NativeEngine::with_threads(threads)
            .histograms(&binned, &rows, &chan, k1, &segs, 1, &mut out);
        assert_eq!(out, base, "histograms differ at n_threads={threads}");
    }
}
