//! Fixture tests for every `sblint` rule: one known-bad snippet per
//! rule asserting the exact diagnostic, one known-good asserting
//! silence, temp-tree fixtures for the cross-registry checks, and a
//! self-test that the lint runs clean on the repo's own tree (both via
//! the library API and the built `sblint` binary's exit code).

use std::fs;
use std::path::{Path, PathBuf};

use sketchboost::lint;
use sketchboost::lint::registry::check_registries;
use sketchboost::lint::rules::{check_file, Diagnostic};
use sketchboost::lint::scan::scan_source;

fn check(rel: &str, src: &str) -> Vec<Diagnostic> {
    check_file(&scan_source(rel, PathBuf::from(rel), src))
}

fn the_one(diags: &[Diagnostic]) -> &Diagnostic {
    assert_eq!(diags.len(), 1, "expected exactly one diagnostic, got {diags:#?}");
    &diags[0]
}

// ---------------------------------------------------------------- R1

#[test]
fn r1_unsafe_without_safety_comment() {
    let d = check("rust/src/util/x.rs", "fn f() {\n    unsafe { g() }\n}\n");
    let d = the_one(&d);
    assert_eq!((d.rule, d.line), ("unsafe-safety", 2));
    assert!(d.message.contains("`unsafe` without a `// SAFETY:` comment"), "{}", d.message);
}

#[test]
fn r1_safety_comment_silences() {
    let src = "fn f() {\n    // SAFETY: g has no preconditions here\n    unsafe { g() }\n}\n";
    assert!(check("rust/src/util/x.rs", src).is_empty());
    // trailing same-line comments count too
    let inline = "fn f() {\n    unsafe { g() } // SAFETY: g has no preconditions here\n}\n";
    assert!(check("rust/src/util/x.rs", inline).is_empty());
}

#[test]
fn r1_applies_inside_test_mods_too() {
    let src = "#[cfg(test)]\nmod tests {\n    fn t() { unsafe { g() } }\n}\n";
    let d = check("rust/src/util/x.rs", src);
    assert_eq!(the_one(&d).rule, "unsafe-safety");
}

#[test]
fn r1_word_unsafe_in_strings_and_comments_is_ignored() {
    let src = "// this mentions unsafe code\nlet s = \"unsafe { }\";\n";
    assert!(check("rust/src/util/x.rs", src).is_empty());
}

// ---------------------------------------------------------------- R2

#[test]
fn r2_range_mut_without_disjoint_comment() {
    let src = "// SAFETY: in bounds\nlet d = unsafe { s.range_mut(0..n) };\n";
    let d = check("rust/src/engine/x.rs", src);
    let d = the_one(&d);
    assert_eq!((d.rule, d.line), ("disjoint", 2));
    assert!(d.message.contains("`// DISJOINT:` comment naming the partition"), "{}", d.message);
}

#[test]
fn r2_disjoint_comment_silences() {
    let src = "// SAFETY: in bounds\n// DISJOINT: partitioned by shard index\nlet d = unsafe { s.range_mut(0..n) };\n";
    assert!(check("rust/src/engine/x.rs", src).is_empty());
}

#[test]
fn r2_definition_site_is_exempt() {
    // the declaration carries `# Safety` docs; R2 targets call sites
    let src = "/// # Safety\n/// disjoint ranges only\npub unsafe fn range_mut(&self, r: Range<usize>) -> &mut [T] {\n    body()\n}\n";
    assert!(check("rust/src/util/x.rs", src).is_empty());
}

// ---------------------------------------------------------------- R3

#[test]
fn r3_hashmap_in_deterministic_module() {
    let d = check("rust/src/tree/x.rs", "use std::collections::HashMap;\n");
    let d = the_one(&d);
    assert_eq!((d.rule, d.line), ("determinism", 1));
    assert!(d.message.contains("`HashMap`"), "{}", d.message);
    assert!(d.message.contains("deterministic module"), "{}", d.message);
}

#[test]
fn r3_clock_reads_in_deterministic_module() {
    let d = check("rust/src/sketch/x.rs", "fn f() { let t = Instant::now(); }\n");
    assert_eq!(the_one(&d).rule, "determinism");
    let d = check("rust/src/predict/x.rs", "fn f() { let v = std::env::var(\"X\"); }\n");
    assert_eq!(the_one(&d).rule, "determinism");
}

#[test]
fn r3_silent_outside_deterministic_modules_and_in_tests() {
    assert!(check("rust/src/serve/x.rs", "use std::collections::HashMap;\n").is_empty());
    let in_test = "#[cfg(test)]\nmod tests {\n    fn t() { let t = Instant::now(); }\n}\n";
    assert!(check("rust/src/engine/x.rs", in_test).is_empty());
}

#[test]
fn r3_lint_allow_with_reason_silences() {
    let src = "// LINT-ALLOW(determinism): telemetry only, nothing reads it\nlet t = Instant::now();\n";
    assert!(check("rust/src/boosting/x.rs", src).is_empty());
}

// ---------------------------------------------------------------- R4

#[test]
fn r4_unwrap_on_request_path() {
    let d = check("rust/src/serve/queue.rs", "fn f() { let g = m.lock().unwrap(); }\n");
    let d = the_one(&d);
    assert_eq!((d.rule, d.line), ("serve-unwrap", 1));
    assert!(d.message.contains("`.unwrap()` on the serve request path"), "{}", d.message);
}

#[test]
fn r4_expect_on_request_path() {
    let d = check("rust/src/serve/server.rs", "fn f() { x.expect(\"boom\"); }\n");
    assert_eq!(the_one(&d).rule, "serve-unwrap");
}

#[test]
fn r4_poison_recovery_and_off_path_files_are_silent() {
    let src = "fn f() { let g = m.lock().unwrap_or_else(|e| e.into_inner()); }\n";
    assert!(check("rust/src/serve/queue.rs", src).is_empty());
    // stats.rs is not on the request path
    assert!(check("rust/src/serve/stats.rs", "fn f() { x.unwrap(); }\n").is_empty());
    // test mods are exempt (they assert, they don't serve)
    let in_test = "#[cfg(test)]\nmod tests {\n    fn t() { x.unwrap(); }\n}\n";
    assert!(check("rust/src/serve/server.rs", in_test).is_empty());
}

// ------------------------------------------------------------ pragma

#[test]
fn pragma_must_be_well_formed() {
    let d = check("rust/src/serve/queue.rs", "// LINT-ALLOW(serve-unwrap) missing colon\nf();\n");
    let d = the_one(&d);
    assert_eq!(d.rule, "pragma");
    assert!(d.message.contains("unclosed `(`") || d.message.contains("needs a reason"), "{}", d.message);
}

#[test]
fn pragma_unknown_rule_and_missing_reason_are_diagnostics() {
    let d = check("rust/src/x.rs", "// LINT-ALLOW(no-such-rule): whatever\n");
    assert!(the_one(&d).message.contains("unknown rule"), "{:?}", d);
    let d = check("rust/src/x.rs", "// LINT-ALLOW(determinism):\n");
    assert!(the_one(&d).message.contains("needs a reason"), "{:?}", d);
}

#[test]
fn pragma_only_suppresses_its_named_rule() {
    // a determinism allow must not hide the serve-unwrap finding
    let src = "// LINT-ALLOW(determinism): wrong rule for this line\nlet g = m.lock().unwrap();\n";
    let d = check("rust/src/serve/queue.rs", src);
    assert_eq!(the_one(&d).rule, "serve-unwrap");
}

// ---------------------------------------------------------------- R5

/// A minimal tree where every registry agrees. Each breaking test
/// perturbs exactly one file.
fn consistent_tree() -> Vec<(&'static str, String)> {
    vec![
        (
            "rust/src/util/fault.rs",
            "//! | point | kind | effect |\n\
             //! |-------|------|--------|\n\
             //! | `a.b` | failpoint | boom |\n\
             pub fn failpoint(_p: &str) {}\n"
                .to_string(),
        ),
        (
            "rust/src/serve/protocol.rs",
            "pub const ERR_TIMEOUT: &str = \"timeout\";\n".to_string(),
        ),
        (
            "rust/src/serve/server.rs",
            // a real call site + a use of the error constant
            format!("fn f() {{ {}(\"a.b\"); let _ = ERR_TIMEOUT; }}\n", "fault::failpoint"),
        ),
        (
            "rust/src/serve/stats.rs",
            "pub fn emit() { set(\"timeouts\"); }\n".to_string(),
        ),
        (
            "rust/tests/serve_chaos.rs",
            "// covers point a.b and asserts a structural !timeout response\n".to_string(),
        ),
        (
            "BENCH_x.json",
            "{\n  \"schema\": \"x/v1\",\n  \"claim\": { \"metric\": \"m\", \"measured\": null }\n}\n"
                .to_string(),
        ),
        (
            "benches/x.rs",
            "fn main() { emit(\"x/v1\"); emit(\"claim\"); }\n".to_string(),
        ),
    ]
}

fn write_tree(case: &str, files: &[(&str, String)]) -> PathBuf {
    let base = std::env::temp_dir().join(format!("sblint_fixture_{case}"));
    let _ = fs::remove_dir_all(&base);
    for (rel, text) in files {
        let p = base.join(rel);
        fs::create_dir_all(p.parent().unwrap()).unwrap();
        fs::write(&p, text).unwrap();
    }
    base
}

fn perturbed(case: &str, rel: &str, text: &str) -> PathBuf {
    let mut files = consistent_tree();
    files.retain(|(r, _)| *r != rel);
    files.push((Box::leak(rel.to_string().into_boxed_str()), text.to_string()));
    write_tree(case, &files)
}

#[test]
fn r5_consistent_tree_is_clean() {
    let root = write_tree("clean", &consistent_tree());
    let d = check_registries(&root);
    assert!(d.is_empty(), "{d:#?}");
}

#[test]
fn r5_documented_point_without_call_site() {
    let root = perturbed(
        "nocall",
        "rust/src/serve/server.rs",
        "fn f() { let _ = ERR_TIMEOUT; }\n",
    );
    let d = check_registries(&root);
    let hit = d
        .iter()
        .find(|d| d.message.contains("no fault::point/failpoint call site"))
        .unwrap_or_else(|| panic!("{d:#?}"));
    assert_eq!(hit.rule, "registry");
    assert!(hit.message.contains("`a.b`"));
    assert_eq!(hit.line, 3, "points at the doc-table row");
}

#[test]
fn r5_armed_point_missing_from_table() {
    let root = perturbed(
        "notable",
        "rust/src/util/fault.rs",
        "//! no table here\npub fn failpoint(_p: &str) {}\n",
    );
    let d = check_registries(&root);
    assert!(
        d.iter().any(|d| d.message.contains("missing from the registry table")
            && d.message.contains("`a.b`")
            && d.rel_path == "rust/src/serve/server.rs"),
        "{d:#?}"
    );
}

#[test]
fn r5_point_without_chaos_coverage() {
    let root = perturbed(
        "nochaos",
        "rust/tests/serve_chaos.rs",
        "// asserts a structural !timeout response but never arms the fault point\n",
    );
    let d = check_registries(&root);
    assert!(
        d.iter().any(|d| d.message.contains("no coverage in rust/tests/serve_chaos.rs")
            && d.message.contains("`a.b`")),
        "{d:#?}"
    );
}

#[test]
fn r5_error_code_must_be_used_covered_and_counted() {
    // unused constant
    let root = perturbed("unused", "rust/src/serve/server.rs", "fn f() { fault::failpoint(\"a.b\"); }\n");
    let d = check_registries(&root);
    assert!(d.iter().any(|d| d.message.contains("defined but never used")), "{d:#?}");

    // code whose counter key is missing from stats.rs
    let root = perturbed("nostat", "rust/src/serve/stats.rs", "pub fn emit() {}\n");
    let d = check_registries(&root);
    assert!(
        d.iter().any(|d| d.message.contains("never emits that key") && d.message.contains("\"timeouts\"")),
        "{d:#?}"
    );

    // a code outside the CODE_COUNTERS map: the new-failure-mode guard
    let root = perturbed(
        "unmapped",
        "rust/src/serve/protocol.rs",
        "pub const ERR_TIMEOUT: &str = \"timeout\";\npub const ERR_WEIRD: &str = \"weird\";\n",
    );
    let d = check_registries(&root);
    assert!(
        d.iter().any(|d| d.message.contains("CODE_COUNTERS") && d.message.contains("\"weird\"")),
        "{d:#?}"
    );
}

#[test]
fn r5_bench_claims_and_schema_must_exist_in_bench_source() {
    // bench stops emitting a tracked claim key
    let root = perturbed("noclaim", "benches/x.rs", "fn main() { emit(\"x/v1\"); }\n");
    let d = check_registries(&root);
    assert!(
        d.iter().any(|d| d.message.contains("claim key \"claim\"") && d.rel_path == "benches/x.rs"),
        "{d:#?}"
    );

    // schema tag drift
    let root = perturbed("noschema", "benches/x.rs", "fn main() { emit(\"x/v2\"); emit(\"claim\"); }\n");
    let d = check_registries(&root);
    assert!(d.iter().any(|d| d.message.contains("does not emit schema tag \"x/v1\"")), "{d:#?}");

    // schema naming a bench that does not exist
    let mut files = consistent_tree();
    files.retain(|(r, _)| *r != "benches/x.rs");
    let root = write_tree("nobench", &files);
    let d = check_registries(&root);
    assert!(d.iter().any(|d| d.message.contains("benches/x.rs, which does not exist")), "{d:#?}");
}

// ------------------------------------------------------- self-tests

fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("..")
}

#[test]
fn sblint_runs_clean_on_this_repo() {
    let diags = lint::run(&repo_root());
    for d in &diags {
        eprintln!("{}", d.render());
    }
    assert!(diags.is_empty(), "sblint found {} violation(s) in the repo tree", diags.len());
}

#[test]
fn sblint_binary_exit_codes() {
    use std::process::Command;
    let bin = env!("CARGO_BIN_EXE_sblint");

    // clean repo tree -> exit 0
    let ok = Command::new(bin).arg("--root").arg(repo_root()).output().unwrap();
    assert!(
        ok.status.success(),
        "sblint on the repo tree failed:\n{}",
        String::from_utf8_lossy(&ok.stdout)
    );

    // one injected violation -> exit nonzero, diagnostic on stdout
    let mut files = consistent_tree();
    files.push(("rust/src/util/bad.rs", "fn f() { unsafe { g() } }\n".to_string()));
    let root = write_tree("binary_bad", &files);
    let bad = Command::new(bin).arg("--root").arg(&root).output().unwrap();
    assert!(!bad.status.success(), "sblint must exit nonzero on a violation");
    let out = String::from_utf8_lossy(&bad.stdout);
    assert!(out.contains("[unsafe-safety]"), "stdout was:\n{out}");
    assert!(out.contains("rust/src/util/bad.rs:1"), "stdout was:\n{out}");
}
