//! Integration tests over the full training stack: every task family,
//! every sketch strategy, CV, serialization round-trips, baselines, and
//! generalization sanity on held-out data.

use sketchboost::baselines::one_vs_all::fit_one_vs_all;
use sketchboost::baselines::{gbdt_mo_full_config, gbdt_mo_sparse_config};
use sketchboost::data::profiles::Profile;
use sketchboost::data::synthetic::{make_multiclass, make_multilabel, make_multitask, FeatureSpec};
use sketchboost::prelude::*;

fn fast(mut cfg: GBDTConfig) -> GBDTConfig {
    cfg.n_rounds = 30;
    cfg.learning_rate = 0.25;
    cfg.max_depth = 4;
    cfg.max_bins = 32;
    cfg
}

#[test]
fn multiclass_generalizes_on_holdout() {
    let ds = make_multiclass(
        1500,
        FeatureSpec { n_informative: 6, n_linear: 3, n_redundant: 3 },
        5,
        2.0,
        1,
    );
    let (train, test) = split::train_test_split(&ds, 0.25, 0);
    let mut cfg = fast(GBDTConfig::multiclass(5));
    cfg.n_rounds = 60;
    let model = GBDT::fit(&cfg, &train, Some(&test));
    let acc = Metric::Accuracy.eval(&model.predict_raw(&test), &test.targets);
    assert!(acc > 0.75, "holdout accuracy {acc}");
}

#[test]
fn every_sketch_strategy_generalizes() {
    let ds = make_multiclass(1200, FeatureSpec::guyon(12), 8, 2.0, 2);
    let (train, test) = split::train_test_split(&ds, 0.25, 0);
    let uniform_ce = (8.0f64).ln();
    for sketch in [
        SketchConfig::None,
        SketchConfig::TopOutputs { k: 3 },
        SketchConfig::RandomSampling { k: 3 },
        SketchConfig::RandomProjection { k: 3 },
        SketchConfig::TruncatedSvd { k: 3, iters: 5 },
    ] {
        let mut cfg = fast(GBDTConfig::multiclass(8));
        cfg.sketch = sketch;
        let model = GBDT::fit(&cfg, &train, Some(&test));
        let ce = Metric::CrossEntropy.eval(&model.predict_raw(&test), &test.targets);
        assert!(
            ce < uniform_ce * 0.7,
            "{}: holdout ce {ce} vs uniform {uniform_ce}",
            sketch.name()
        );
    }
}

#[test]
fn multilabel_beats_base_rate() {
    let ds = make_multilabel(1000, FeatureSpec::guyon(10), 10, 3, 3);
    let (train, test) = split::train_test_split(&ds, 0.25, 0);
    let mut cfg = fast(GBDTConfig::multilabel(10));
    cfg.sketch = SketchConfig::RandomProjection { k: 3 };
    let model = GBDT::fit(&cfg, &train, Some(&test));
    // base-rate-only model = BCE at the base scores; trained must beat it
    let base_model = Ensemble {
        loss: model.loss,
        n_outputs: model.n_outputs,
        base_score: model.base_score.clone(),
        trees: vec![],
        history: Default::default(),
    };
    let bce_model = Metric::BceLogLoss.eval(&model.predict_raw(&test), &test.targets);
    let bce_base = Metric::BceLogLoss.eval(&base_model.predict_raw(&test), &test.targets);
    assert!(bce_model < bce_base * 0.95, "model {bce_model} vs base {bce_base}");
}

#[test]
fn multitask_r2_on_holdout() {
    let ds = make_multitask(1500, FeatureSpec::guyon(10), 6, 2, 0.2, 4);
    let (train, test) = split::train_test_split(&ds, 0.25, 0);
    let mut cfg = fast(GBDTConfig::multitask(6));
    cfg.n_rounds = 60;
    cfg.sketch = SketchConfig::RandomSampling { k: 2 };
    let model = GBDT::fit(&cfg, &train, Some(&test));
    let r2 = Metric::R2.eval(&model.predict_raw(&test), &test.targets);
    assert!(r2 > 0.5, "holdout r2 {r2}");
}

#[test]
fn serialization_preserves_predictions() {
    let ds = make_multiclass(500, FeatureSpec::guyon(8), 4, 2.0, 5);
    let mut cfg = fast(GBDTConfig::multiclass(4));
    cfg.sketch = SketchConfig::RandomProjection { k: 2 };
    let model = GBDT::fit(&cfg, &ds, None);
    let dir = std::env::temp_dir().join("sb_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("model.json");
    model.save(&path).unwrap();
    let back = Ensemble::load(&path).unwrap();
    assert_eq!(model.predict_raw(&ds), back.predict_raw(&ds));
}

#[test]
fn cv_losses_are_consistent() {
    let ds = make_multiclass(600, FeatureSpec::guyon(8), 3, 2.0, 6);
    let mut cfg = fast(GBDTConfig::multiclass(3));
    cfg.n_rounds = 15;
    let folds = GBDT::fit_cv(&cfg, &ds, 5);
    assert_eq!(folds.len(), 5);
    let losses: Vec<f64> = folds.iter().map(|(_, l)| *l).collect();
    let mean = losses.iter().sum::<f64>() / 5.0;
    for l in &losses {
        assert!((l - mean).abs() < mean, "fold loss {l} far from mean {mean}");
        assert!(*l < (3.0f64).ln(), "fold loss {l} worse than uniform");
    }
}

#[test]
fn ova_vs_single_tree_quality_comparable() {
    let ds = make_multiclass(1000, FeatureSpec::guyon(10), 4, 2.0, 7);
    let (train, test) = split::train_test_split(&ds, 0.25, 0);
    let cfg = fast(GBDTConfig::multiclass(4));
    let st = GBDT::fit(&cfg, &train, Some(&test));
    let ova = fit_one_vs_all(&cfg, &train, Some(&test));
    let ce_st = Metric::CrossEntropy.eval(&st.predict_raw(&test), &test.targets);
    let ce_ova = Metric::CrossEntropy.eval(&ova.predict_raw(&test), &test.targets);
    // both learn; neither degenerates (paper: single-tree usually wins)
    assert!(ce_st < 1.0 && ce_ova < 1.0, "st {ce_st} ova {ce_ova}");
}

#[test]
fn gbdt_mo_baselines_behave() {
    let ds = make_multitask(800, FeatureSpec::guyon(8), 6, 2, 0.2, 8);
    let (train, test) = split::train_test_split(&ds, 0.25, 0);
    let mut full_cfg = fast(gbdt_mo_full_config(&train));
    full_cfg.n_rounds = 40;
    let full = GBDT::fit(&full_cfg, &train, Some(&test));
    let mut sparse_cfg = fast(gbdt_mo_sparse_config(&train, 3));
    sparse_cfg.n_rounds = 40;
    let sparse = GBDT::fit(&sparse_cfg, &train, Some(&test));
    let r_full = Metric::R2.eval(&full.predict_raw(&test), &test.targets);
    let r_sparse = Metric::R2.eval(&sparse.predict_raw(&test), &test.targets);
    assert!(r_full > 0.4, "gbdt-mo full r2 {r_full}");
    assert!(r_sparse > 0.2, "gbdt-mo sparse r2 {r_sparse}");
}

#[test]
fn profile_workloads_train_end_to_end() {
    // every profile must be trainable out of the box (tiny row budget)
    for name in ["otto", "sf-crime", "rf1", "mnist"] {
        let p = Profile::by_name(name).unwrap();
        let ds = p.generate_sized(300, 9);
        let mut cfg = fast(GBDTConfig::for_dataset(&ds));
        cfg.n_rounds = 5;
        cfg.sketch = SketchConfig::RandomProjection { k: 2 };
        let model = GBDT::fit(&cfg, &ds, None);
        assert_eq!(model.n_trees(), 5, "{name}");
        let h = &model.history.train_loss;
        assert!(h.first().unwrap() >= h.last().unwrap(), "{name} did not improve");
    }
}

#[test]
fn subsampled_training_still_learns() {
    let ds = make_multiclass(1000, FeatureSpec::guyon(10), 4, 2.0, 10);
    let (train, test) = split::train_test_split(&ds, 0.25, 0);
    let mut cfg = fast(GBDTConfig::multiclass(4));
    cfg.subsample = 0.6;
    cfg.colsample = 0.7;
    cfg.sketch = SketchConfig::RandomSampling { k: 2 };
    let model = GBDT::fit(&cfg, &train, Some(&test));
    let acc = Metric::Accuracy.eval(&model.predict_raw(&test), &test.targets);
    assert!(acc > 0.7, "subsampled holdout accuracy {acc}");
}
