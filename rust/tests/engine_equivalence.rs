//! Integration: the XLA engine (PJRT-executed artifacts lowered from the
//! JAX/Pallas layers) must be numerically equivalent to the native rust
//! engine on every op, and full training through either engine must
//! produce equivalent models.
//!
//! These tests skip (with a notice) when `make artifacts` hasn't run.

use sketchboost::boosting::losses::LossKind;
use sketchboost::boosting::trainer::{GBDTConfig, GBDT};
use sketchboost::data::binning::BinnedDataset;
use sketchboost::data::dataset::{Dataset, Targets};
use sketchboost::data::synthetic::{make_multiclass, FeatureSpec};
use sketchboost::engine::{
    ComputeEngine, FeatureKind, MissingPolicy, NativeEngine, ScanSpec, ScoreMode, XlaEngine,
};
use sketchboost::runtime::registry::artifacts_available;
use sketchboost::sketch::SketchConfig;
use sketchboost::util::proptest::assert_close;
use sketchboost::util::rng::Rng;

/// The "test" artifact family shapes (see python/compile/aot.py).
const D: usize = 4;
const K: usize = 2;
const M: usize = 6;
const BINS: usize = 16;

fn xla() -> Option<XlaEngine> {
    if !artifacts_available() || cfg!(not(feature = "pjrt")) {
        eprintln!("skipping: needs `make artifacts` and --features pjrt");
        return None;
    }
    Some(XlaEngine::new("test").expect("open test artifacts"))
}

/// Dataset matching the test artifact family: m=6 features, 4 classes.
fn test_dataset(n: usize, seed: u64) -> Dataset {
    make_multiclass(
        n,
        FeatureSpec { n_informative: 3, n_linear: 2, n_redundant: 1 },
        D,
        1.5,
        seed,
    )
}

#[test]
fn grad_ce_matches_native() {
    let Some(mut xeng) = xla() else { return };
    let mut neng = NativeEngine::new();
    let n = 700; // not a multiple of chunk=256: exercises tail padding
    let mut rng = Rng::new(1);
    let mut preds = vec![0.0f32; n * D];
    rng.fill_gaussian(&mut preds, 2.0);
    let labels: Vec<u32> = (0..n).map(|_| rng.next_below(D) as u32).collect();
    let t = Targets::Multiclass { labels, n_classes: D };
    let (mut g1, mut h1) = (vec![0.0f32; n * D], vec![0.0f32; n * D]);
    let (mut g2, mut h2) = (vec![0.0f32; n * D], vec![0.0f32; n * D]);
    neng.grad_hess(LossKind::MulticlassCE, &preds, &t, &mut g1, &mut h1);
    xeng.grad_hess(LossKind::MulticlassCE, &preds, &t, &mut g2, &mut h2);
    assert_close(&g1, &g2, 1e-4, 1e-5);
    assert_close(&h1, &h2, 1e-4, 1e-5);
}

#[test]
fn grad_bce_and_mse_match_native() {
    let Some(mut xeng) = xla() else { return };
    let mut neng = NativeEngine::new();
    let n = 300;
    let mut rng = Rng::new(2);
    let mut preds = vec![0.0f32; n * D];
    rng.fill_gaussian(&mut preds, 1.5);

    let labels: Vec<f32> = (0..n * D).map(|_| (rng.next_u64() & 1) as f32).collect();
    let t = Targets::Multilabel { labels, n_labels: D };
    let (mut g1, mut h1) = (vec![0.0f32; n * D], vec![0.0f32; n * D]);
    let (mut g2, mut h2) = (vec![0.0f32; n * D], vec![0.0f32; n * D]);
    neng.grad_hess(LossKind::BCE, &preds, &t, &mut g1, &mut h1);
    xeng.grad_hess(LossKind::BCE, &preds, &t, &mut g2, &mut h2);
    assert_close(&g1, &g2, 1e-4, 1e-5);
    assert_close(&h1, &h2, 1e-4, 1e-5);

    let mut values = vec![0.0f32; n * D];
    rng.fill_gaussian(&mut values, 1.0);
    let t = Targets::Regression { values, n_targets: D };
    neng.grad_hess(LossKind::MSE, &preds, &t, &mut g1, &mut h1);
    xeng.grad_hess(LossKind::MSE, &preds, &t, &mut g2, &mut h2);
    assert_close(&g1, &g2, 1e-5, 1e-6);
    assert_close(&h1, &h2, 1e-5, 1e-6);
}

#[test]
fn sketch_projection_matches_native() {
    let Some(mut xeng) = xla() else { return };
    let mut neng = NativeEngine::new();
    let n = 513; // tail chunk
    let mut rng = Rng::new(3);
    let mut g = vec![0.0f32; n * D];
    rng.fill_gaussian(&mut g, 1.0);
    let mut proj = vec![0.0f32; D * K];
    rng.fill_gaussian(&mut proj, 0.7);
    let mut o1 = vec![0.0f32; n * K];
    let mut o2 = vec![0.0f32; n * K];
    neng.sketch_project(&g, n, D, &proj, K, &mut o1);
    xeng.sketch_project(&g, n, D, &proj, K, &mut o2);
    assert_close(&o1, &o2, 1e-4, 1e-5);
}

#[test]
fn histograms_match_native() {
    let Some(mut xeng) = xla() else { return };
    let mut neng = NativeEngine::new();
    let n = 600;
    let ds = test_dataset(n, 4);
    let binned = BinnedDataset::from_dataset(&ds, BINS);
    let mut rng = Rng::new(5);
    let n_slots = 4;
    let slot_of_row: Vec<u32> = (0..n).map(|_| rng.next_below(n_slots) as u32).collect();
    let k1 = K + 1;
    let mut chan = vec![0.0f32; n * k1];
    rng.fill_gaussian(&mut chan, 1.0);
    for i in 0..n {
        chan[i * k1 + k1 - 1] = 1.0;
    }
    let rows: Vec<u32> = (0..n as u32).filter(|&r| r % 5 != 4).collect();
    let (prows, pchan, segs) =
        sketchboost::engine::reference::partition_inputs(&rows, &slot_of_row, &chan, k1, n_slots);
    let size = 8 * M * BINS * k1; // artifact supports 8 slots
    let mut h1 = vec![0.0f32; size];
    let mut h2 = vec![0.0f32; size];
    neng.histograms(&binned, &prows, &pchan, k1, &segs, 8, &mut h1);
    xeng.histograms(&binned, &prows, &pchan, k1, &segs, 8, &mut h2);
    assert_close(&h1, &h2, 1e-3, 1e-3);
}

#[test]
fn split_gains_match_native() {
    let Some(mut xeng) = xla() else { return };
    let mut neng = NativeEngine::new();
    let k1 = K + 1;
    let n_slots = 8;
    let mut rng = Rng::new(6);
    let mut hist = vec![0.0f32; n_slots * M * BINS * k1];
    rng.fill_gaussian(&mut hist, 1.0);
    // counts must be non-negative
    for s in 0..n_slots {
        for f in 0..M {
            for b in 0..BINS {
                let i = ((s * M + f) * BINS + b) * k1 + k1 - 1;
                hist[i] = rng.next_below(20) as f32;
            }
        }
    }
    let lam = 1.0; // must match the lambda baked into the artifact
    // the artifact bakes the all-numeric missing-left prefix scan; the
    // native engine reproduces it exactly under the same spec
    let kinds = vec![FeatureKind::Numeric; M];
    let spec = ScanSpec {
        n_slots,
        m: M,
        bins: BINS,
        k1,
        lam,
        mode: ScoreMode::CountL2,
        kinds: &kinds,
        missing: MissingPolicy::AlwaysLeft,
    };
    let mut g1 = Vec::new();
    let mut d1 = Vec::new();
    let mut g2 = Vec::new();
    let mut d2 = Vec::new();
    neng.split_gains(&hist, &spec, &mut g1, &mut d1);
    xeng.split_gains(&hist, &spec, &mut g2, &mut d2);
    assert_close(&g1, &g2, 2e-3, 2e-3);
    assert_eq!(d1, d2, "AlwaysLeft defaults are all-left on both engines");
}

#[test]
fn leaf_sums_match_native() {
    let Some(mut xeng) = xla() else { return };
    let mut neng = NativeEngine::new();
    let n = 520;
    let mut rng = Rng::new(7);
    let n_leaves = 7;
    let leaf_of_row: Vec<u32> = (0..n).map(|_| rng.next_below(n_leaves) as u32).collect();
    let mut g = vec![0.0f32; n * D];
    let mut h = vec![0.0f32; n * D];
    rng.fill_gaussian(&mut g, 1.0);
    rng.fill_gaussian(&mut h, 0.3);
    for v in h.iter_mut() {
        *v = v.abs();
    }
    let rows: Vec<u32> = (0..n as u32).collect();
    let mut s1 = sketchboost::engine::LeafSums::new();
    let mut s2 = sketchboost::engine::LeafSums::new();
    neng.leaf_sums(&rows, &leaf_of_row, &g, &h, D, n_leaves, &mut s1);
    xeng.leaf_sums(&rows, &leaf_of_row, &g, &h, D, n_leaves, &mut s2);
    assert_close(&s1.gsum, &s2.gsum, 1e-3, 1e-3);
    assert_close(&s1.hsum, &s2.hsum, 1e-3, 1e-3);
    assert_close(&s1.count, &s2.count, 1e-6, 1e-6);
}

#[test]
fn full_training_equivalent_across_engines() {
    let Some(mut xeng) = xla() else { return };
    let ds = test_dataset(500, 8);
    let mut cfg = GBDTConfig::multiclass(D);
    cfg.n_rounds = 5;
    cfg.max_depth = 3; // frontier <= 8 slots = artifact capacity
    cfg.max_bins = BINS;
    cfg.learning_rate = 0.3;
    cfg.lambda_l2 = 1.0; // matches baked lambda
    cfg.sketch = SketchConfig::TopOutputs { k: K }; // deterministic sketch
    // keep the gain artifact on the training path (MissingPolicy::Learn
    // would route split_gains through the documented native fallback)
    cfg.missing_policy = MissingPolicy::AlwaysLeft;

    let native_model = GBDT::fit(&cfg, &ds, None);
    let xla_model = GBDT::fit_with_engine(&cfg, &ds, None, &mut xeng);
    assert!(xeng.n_executions > 0, "xla engine was never exercised");

    // Per-op equivalence is asserted exactly by the other tests in this
    // file. End-to-end, near-tie splits may break differently between the
    // f64 native accumulators and the f32 artifact arithmetic and cascade
    // into different (equal-quality) trees — so here we require the same
    // round count, the same first split, and matching training quality.
    assert_eq!(native_model.n_trees(), xla_model.n_trees());
    let (a0, b0) = (&native_model.trees[0], &xla_model.trees[0]);
    assert_eq!(a0.nodes[0].feature, b0.nodes[0].feature, "first split feature");
    assert_eq!(a0.nodes[0].bin, b0.nodes[0].bin, "first split bin");
    let la = *native_model.history.train_loss.last().unwrap();
    let lb = *xla_model.history.train_loss.last().unwrap();
    assert!(
        (la - lb).abs() < 0.02 * la.max(lb),
        "final train loss differs: native {la} vs xla {lb}"
    );
}

#[test]
fn xla_engine_rejects_mismatched_shapes() {
    let Some(mut xeng) = xla() else { return };
    // wrong d for the grad artifact must panic, not silently misbehave
    let t = Targets::Multiclass { labels: vec![0, 1], n_classes: 2 };
    let preds = vec![0.0f32; 2 * 2];
    let mut g = vec![0.0f32; 4];
    let mut h = vec![0.0f32; 4];
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        xeng.grad_hess(LossKind::MulticlassCE, &preds, &t, &mut g, &mut h);
    }));
    assert!(r.is_err(), "shape mismatch must be rejected");
}
