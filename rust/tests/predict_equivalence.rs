//! The batched FlatForest inference path must be **bit-identical** to
//! the per-row reference walker (`predict_raw_naive`) — across every
//! sketch strategy, tree depth 1–6, 1/2/4 prediction threads, all three
//! losses, the one-vs-all baseline, the leaf-index output, and a
//! save→load→predict round trip. The same matrix runs under every
//! [`ForestLayout`]: `v1`, `v2`, and `v2q` with exact leaves must
//! reproduce the walker bits exactly (quantized thresholds route
//! identically by construction); `v2q` with f16 leaves must stay within
//! the model's computed [`FlatForest::leaf_quant_error`] bound. NaN
//! routing through per-split default directions is pinned by a
//! handcrafted-tree unit test here (the default-left case) and
//! exercised adversarially — learned defaults, categorical sets — in
//! `rust/tests/missing_categorical.rs`.

use sketchboost::baselines::one_vs_all::fit_one_vs_all;
use sketchboost::boosting::ensemble::{Ensemble, TrainHistory};
use sketchboost::data::dataset::{Dataset, Targets};
use sketchboost::data::synthetic::{make_multiclass, make_multilabel, make_multitask, FeatureSpec};
use sketchboost::predict::{FlatForest, ForestLayout, LayoutOptions, PredictOptions, Predictor};
use sketchboost::prelude::*;
use sketchboost::tree::tree::{encode_leaf, Tree, TreeNode};

/// Every compile-time layout, with whether its output must be *bitwise*
/// equal to the v1/naive reference (f16 leaves are bounded, not exact).
fn layouts() -> [(LayoutOptions, &'static str, bool); 4] {
    [
        (LayoutOptions::v1(), "v1", true),
        (LayoutOptions::v2_exact(), "v2", true),
        (LayoutOptions::v2_quantized().with_exact_leaves(true), "v2q-exact", true),
        (LayoutOptions::v2_quantized(), "v2q-f16", false),
    ]
}

fn assert_close(want: &[f32], got: &[f32], tol: f32, ctx: &str) {
    assert_eq!(want.len(), got.len(), "{ctx}: length");
    for (i, (a, b)) in want.iter().zip(got).enumerate() {
        assert!(
            (a - b).abs() <= tol || (a.is_nan() && b.is_nan()),
            "{ctx}: cell {i} differs beyond {tol:e} ({a:?} vs {b:?})"
        );
    }
}

/// All five sketch strategies (k = 2 keeps them all active at d = 5).
fn sketches() -> [SketchConfig; 5] {
    [
        SketchConfig::None,
        SketchConfig::TopOutputs { k: 2 },
        SketchConfig::RandomSampling { k: 2 },
        SketchConfig::RandomProjection { k: 2 },
        SketchConfig::TruncatedSvd { k: 2, iters: 4 },
    ]
}

fn assert_bits_eq(want: &[f32], got: &[f32], ctx: &str) {
    assert_eq!(want.len(), got.len(), "{ctx}: length");
    for (i, (a, b)) in want.iter().zip(got).enumerate() {
        assert!(
            a.to_bits() == b.to_bits(),
            "{ctx}: cell {i} differs ({a:?} vs {b:?})"
        );
    }
}

/// Train at every (sketch, depth) cell and compare flat vs naive at
/// 1/2/4 threads with a ragged block size plus the default blocking —
/// under every forest layout.
fn check_matrix(mut cfg: GBDTConfig, ds: &Dataset, loss_name: &str) {
    cfg.n_rounds = 4;
    cfg.learning_rate = 0.3;
    cfg.max_bins = 16;
    for sketch in sketches() {
        for depth in 1..=6 {
            let mut c = cfg.clone();
            c.sketch = sketch;
            c.max_depth = depth;
            let model = GBDT::fit(&c, ds, None);
            let naive = model.predict_raw_naive(ds);
            for (lo, lname, exact) in layouts() {
                let flat = FlatForest::compile(&model, lo);
                for threads in [1usize, 2, 4] {
                    for block in [37usize, 512] {
                        let got = flat.predict_raw(
                            ds,
                            &PredictOptions::threads(threads).with_block_rows(block),
                        );
                        let ctx = format!(
                            "{loss_name} sketch={} depth={depth} layout={lname} t={threads} block={block}",
                            c.sketch.name()
                        );
                        if exact {
                            assert_bits_eq(&naive, &got, &ctx);
                        } else {
                            // accumulation order is identical per cell, so
                            // the summed per-tree f16 error bounds the gap
                            assert_close(&naive, &got, flat.leaf_quant_error() + 1e-5, &ctx);
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn flat_matches_naive_multiclass_ce() {
    let ds = make_multiclass(240, FeatureSpec::guyon(10), 5, 1.5, 11);
    check_matrix(GBDTConfig::multiclass(5), &ds, "ce");
}

#[test]
fn flat_matches_naive_multilabel_bce() {
    let ds = make_multilabel(240, FeatureSpec::guyon(10), 5, 2, 12);
    check_matrix(GBDTConfig::multilabel(5), &ds, "bce");
}

#[test]
fn flat_matches_naive_multitask_mse() {
    let ds = make_multitask(240, FeatureSpec::guyon(10), 5, 2, 0.2, 13);
    check_matrix(GBDTConfig::multitask(5), &ds, "mse");
}

#[test]
fn ova_flat_matches_naive_across_threads() {
    let ds = make_multiclass(300, FeatureSpec::guyon(8), 4, 2.0, 14);
    let mut cfg = GBDTConfig::multiclass(4);
    cfg.n_rounds = 6;
    cfg.max_depth = 4;
    cfg.max_bins = 16;
    let model = fit_one_vs_all(&cfg, &ds, None);
    let naive = model.predict_raw_naive(&ds);
    for threads in [1usize, 2, 4] {
        let got = model
            .predict_raw_with(&ds, &PredictOptions::threads(threads).with_block_rows(53));
        assert_bits_eq(&naive, &got, &format!("ova t={threads}"));
    }
    // the OVA facade honors layouts too: v2 stays bitwise
    let opts = PredictOptions::threads(2)
        .with_block_rows(53)
        .with_layout(ForestLayout::V2Exact);
    assert_bits_eq(&naive, &Predictor::compile_ova(&model, opts).raw(&ds), "ova v2");
}

#[test]
fn leaf_indices_flat_matches_naive() {
    let ds = make_multiclass(250, FeatureSpec::guyon(10), 4, 1.5, 15);
    let mut cfg = GBDTConfig::multiclass(4);
    cfg.n_rounds = 6;
    cfg.max_depth = 5;
    cfg.max_bins = 16;
    let model = GBDT::fit(&cfg, &ds, None);
    let naive = model.predict_leaf_indices_naive(&ds);
    for threads in [1usize, 2, 4] {
        let got = model
            .predict_leaf_indices_with(&ds, &PredictOptions::threads(threads).with_block_rows(41));
        assert_eq!(naive, got, "leaf indices t={threads}");
    }
    // leaf identity is layout-invariant (quantized thresholds route the
    // same rows to the same leaves)
    for layout in [ForestLayout::V2Exact, ForestLayout::V2Quantized] {
        let opts = PredictOptions::threads(2).with_block_rows(41).with_layout(layout);
        let got = Predictor::compile(&model, opts).leaf_indices(&ds);
        assert_eq!(naive, got, "leaf indices layout={}", layout.as_str());
    }
}

#[test]
fn save_load_predict_round_trip_is_bit_identical() {
    let ds = make_multiclass(260, FeatureSpec::guyon(10), 5, 1.5, 16);
    let mut cfg = GBDTConfig::multiclass(5);
    cfg.n_rounds = 8;
    cfg.max_depth = 4;
    cfg.max_bins = 16;
    cfg.sketch = SketchConfig::RandomProjection { k: 2 };
    let model = GBDT::fit(&cfg, &ds, None);
    let naive = model.predict_raw_naive(&ds);

    let dir = std::env::temp_dir().join("sb_predict_equiv");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("model.json");
    model.save(&path).unwrap();
    let loaded = Ensemble::load(&path).unwrap();

    // the JSON round trip preserves every f32 bit pattern, so the flat
    // path over the reloaded model must reproduce the original bits
    let flat = FlatForest::from_ensemble(&loaded);
    for threads in [1usize, 4] {
        let got = flat.predict_raw(&ds, &PredictOptions::threads(threads));
        assert_bits_eq(&naive, &got, &format!("save/load t={threads}"));
    }
    assert_bits_eq(&naive, &loaded.predict_raw_naive(&ds), "save/load naive");

    // quantized layouts recompile from the reloaded JSON with identical
    // behavior: routing is exact, so exact leaves give back the bits
    // and f16 leaves stay inside the recomputed error bound
    let q = FlatForest::compile(
        &loaded,
        LayoutOptions::v2_quantized().with_exact_leaves(true),
    );
    assert_bits_eq(
        &naive,
        &q.predict_raw(&ds, &PredictOptions::threads(2)),
        "save/load v2q-exact",
    );
    let qh = FlatForest::compile(&loaded, LayoutOptions::v2_quantized());
    assert_close(
        &naive,
        &qh.predict_raw(&ds, &PredictOptions::threads(2)),
        qh.leaf_quant_error() + 1e-5,
        "save/load v2q-f16",
    );
}

/// The Predictor facade is a thin veneer: its outputs are the legacy
/// entry points' outputs, bit for bit, and `apply_link` matches
/// `Ensemble::predict`.
#[test]
fn predictor_facade_matches_legacy_entry_points() {
    let ds = make_multiclass(220, FeatureSpec::guyon(9), 4, 1.8, 17);
    let mut cfg = GBDTConfig::multiclass(4);
    cfg.n_rounds = 6;
    cfg.max_depth = 4;
    cfg.max_bins = 16;
    let model = GBDT::fit(&cfg, &ds, None);
    let opts = PredictOptions::threads(2).with_block_rows(29);
    let pred = Predictor::compile(&model, opts);
    assert_bits_eq(&model.predict_raw_with(&ds, &opts), &pred.raw(&ds), "raw");
    assert_bits_eq(&model.predict_with(&ds, &opts), &pred.predict(&ds), "predict");
    assert_eq!(model.predict_leaf_indices_with(&ds, &opts), pred.leaf_indices(&ds));
}

/// x0 <= 0.5 ? leaf0 : (x1 <= 2.0 ? leaf1 : leaf2) — NaN must follow
/// `default_left = true` at *every* node in both paths (the behavior
/// legacy models load with).
#[test]
fn nan_features_route_left_identically() {
    let tree = Tree {
        n_outputs: 2,
        nodes: vec![
            TreeNode { feature: 0, bin: 3, threshold: 0.5, default_left: true, cats: None, left: encode_leaf(0), right: 1, gain: 1.0 },
            TreeNode { feature: 1, bin: 1, threshold: 2.0, default_left: true, cats: None, left: encode_leaf(1), right: encode_leaf(2), gain: 0.5 },
        ],
        leaf_values: vec![1.0, -1.0, 2.0, -2.0, 3.0, -3.0],
        n_leaves: 3,
    };
    let model = Ensemble {
        loss: LossKind::MSE,
        n_outputs: 2,
        base_score: vec![0.0, 0.0],
        trees: vec![tree],
        history: TrainHistory::default(),
    };
    // column-major features for rows:
    // [NaN, 9]   -> NaN at the root        -> leaf 0
    // [1, NaN]   -> NaN at the inner node  -> leaf 1
    // [NaN, NaN] -> NaN everywhere         -> leaf 0
    // [1, 5]     -> no NaN                 -> leaf 2
    let features = vec![
        f32::NAN, 1.0, f32::NAN, 1.0, // feature 0
        9.0, f32::NAN, f32::NAN, 5.0, // feature 1
    ];
    let ds = Dataset::new(
        4,
        2,
        features,
        Targets::Regression { values: vec![0.0; 8], n_targets: 2 },
    );

    for (lo, lname, _) in layouts() {
        let flat = FlatForest::compile(&model, lo);
        for (row, want_leaf) in [(0usize, 0usize), (1, 1), (2, 0), (3, 2)] {
            assert_eq!(
                model.trees[0].leaf_for_raw(&ds.row(row)),
                want_leaf,
                "naive row {row}"
            );
            assert_eq!(flat.leaf_of(0, &ds.row(row)), want_leaf, "{lname} row {row}");
        }
        for threads in [1usize, 2] {
            let opts = PredictOptions::threads(threads).with_block_rows(3);
            // the handcrafted leaves are f16-representable, so even the
            // quantized-leaf layout reproduces the bits here
            assert_bits_eq(
                &model.predict_raw_naive(&ds),
                &flat.predict_raw(&ds, &opts),
                &format!("nan layout={lname} t={threads}"),
            );
            assert_eq!(
                model.predict_leaf_indices_naive(&ds),
                flat.predict_leaf_indices(&ds, &opts),
                "nan leaf indices layout={lname} t={threads}"
            );
        }
    }
}
