//! Sparsity-aware splits, end-to-end: learned missing-value routing and
//! native categorical features must behave identically across every
//! layer that touches them —
//!
//! * handcrafted-tree oracle: flat vs naive routing is **bitwise** on
//!   NaN-bearing rows through mixed default directions and category
//!   sets;
//! * training on the NaN-injected profile is bit-deterministic across
//!   1/2/4 engine threads, and flat-vs-naive prediction on NaN-bearing
//!   inputs is bitwise across 1/2/4 prediction threads;
//! * save→load→predict round-trips categorical splits and
//!   `default_left` exactly;
//! * full training through the `ReferenceEngine` (from-scratch naive
//!   split scan + pinned historical histograms) is bit-identical to the
//!   `NativeEngine`;
//! * **acceptance**: on a profile whose generative rule is categorical,
//!   native categorical splits reach strictly lower validation loss
//!   than the same data treated as ordinal codes.

use sketchboost::boosting::ensemble::{Ensemble, TrainHistory};
use sketchboost::boosting::metrics::Metric;
use sketchboost::data::dataset::{Dataset, FeatureKind, Targets};
use sketchboost::data::profiles::Profile;
use sketchboost::data::split::train_test_split;
use sketchboost::data::synthetic::make_categorical_multitask;
use sketchboost::engine::reference::ReferenceEngine;
use sketchboost::prelude::*;
use sketchboost::tree::tree::{encode_leaf, CatSet, Tree, TreeNode};

fn assert_bits_eq(want: &[f32], got: &[f32], ctx: &str) {
    assert_eq!(want.len(), got.len(), "{ctx}: length");
    for (i, (a, b)) in want.iter().zip(got).enumerate() {
        assert!(
            a.to_bits() == b.to_bits(),
            "{ctx}: cell {i} differs ({a:?} vs {b:?})"
        );
    }
}

/// Two-tree model exercising every routing rule: numeric default-left,
/// numeric default-right, and a categorical set with default-right.
fn handcrafted_model() -> Ensemble {
    let t0 = Tree {
        n_outputs: 2,
        nodes: vec![
            // root: numeric on f0, NaN -> right
            TreeNode { feature: 0, bin: 2, threshold: 0.0, default_left: false, cats: None, left: encode_leaf(0), right: 1, gain: 1.0 },
            // inner: numeric on f1, NaN -> left
            TreeNode { feature: 1, bin: 1, threshold: 1.5, default_left: true, cats: None, left: encode_leaf(1), right: encode_leaf(2), gain: 0.5 },
        ],
        leaf_values: vec![0.1, -0.1, 0.2, -0.2, 0.3, -0.3],
        n_leaves: 3,
    };
    let t1 = Tree {
        n_outputs: 2,
        nodes: vec![
            // root: categorical on f2, ids {1, 4} left, NaN -> right
            TreeNode { feature: 2, bin: 0, threshold: 0.0, default_left: false, cats: Some(CatSet::from_ids([1u32, 4])), left: encode_leaf(0), right: 1, gain: 0.8 },
            // inner: categorical, id {0} left, NaN -> left
            TreeNode { feature: 2, bin: 0, threshold: 0.0, default_left: true, cats: Some(CatSet::from_ids([0u32])), left: encode_leaf(1), right: encode_leaf(2), gain: 0.2 },
        ],
        leaf_values: vec![1.0, -1.0, 2.0, -2.0, 3.0, -3.0],
        n_leaves: 3,
    };
    Ensemble {
        loss: LossKind::MSE,
        n_outputs: 2,
        base_score: vec![0.5, -0.5],
        trees: vec![t0, t1],
        history: TrainHistory::default(),
    }
}

/// Rows poking every branch: NaN at each node, category members,
/// non-members, unseen ids, non-integer values.
fn adversarial_rows() -> Vec<Vec<f32>> {
    vec![
        vec![-1.0, 0.0, 1.0],
        vec![-1.0, 0.0, 4.0],
        vec![1.0, 1.0, 0.0],
        vec![1.0, 2.0, 2.0],
        vec![f32::NAN, 1.0, 1.0],          // NaN at t0 root -> right
        vec![1.0, f32::NAN, f32::NAN],     // NaN at t0 inner + t1 root
        vec![f32::NAN, f32::NAN, f32::NAN],
        vec![0.0, 0.0, 7.0],               // unseen category -> right, then right
        vec![0.0, 0.0, 1.5],               // non-integer -> not a member
        vec![0.0, 1.5, 0.0],
    ]
}

fn dataset_from_rows(rows: &[Vec<f32>]) -> Dataset {
    let n = rows.len();
    let m = rows[0].len();
    let mut flat = vec![0.0f32; n * m];
    for (i, r) in rows.iter().enumerate() {
        for (f, &v) in r.iter().enumerate() {
            flat[i * m + f] = v;
        }
    }
    Dataset::from_row_major(n, m, &flat, Targets::Regression { values: vec![0.0; n * 2], n_targets: 2 })
}

#[test]
fn handcrafted_default_direction_oracle_flat_vs_naive_bitwise() {
    let model = handcrafted_model();
    let rows = adversarial_rows();
    let ds = dataset_from_rows(&rows);

    // explicit leaf expectations for the default-direction rules
    let t0 = &model.trees[0];
    assert_eq!(t0.leaf_for_raw(&rows[4]), 1, "NaN at root defaults right, f1=1 <= 1.5");
    assert_eq!(t0.leaf_for_raw(&rows[5]), 1, "NaN at inner defaults left");
    assert_eq!(t0.leaf_for_raw(&rows[6]), 1, "all-NaN: right then left");
    let t1 = &model.trees[1];
    assert_eq!(t1.leaf_for_raw(&rows[0]), 0, "id 1 in {{1,4}}");
    assert_eq!(t1.leaf_for_raw(&rows[5]), 1, "NaN: right at cat root, left at inner");
    assert_eq!(t1.leaf_for_raw(&rows[7]), 2, "unseen id: right, not id 0 -> right");
    assert_eq!(t1.leaf_for_raw(&rows[8]), 2, "non-integer is not a member");

    let naive = model.predict_raw_naive(&ds);
    // the adversarial default-direction + categorical oracle must hold
    // bitwise in every exact layout (v2q routing is exact by
    // construction; exact_leaves keeps the f32 leaf values)
    for lo in [
        LayoutOptions::v1(),
        LayoutOptions::v2_exact(),
        LayoutOptions::v2_quantized().with_exact_leaves(true),
    ] {
        let flat = FlatForest::compile(&model, lo);
        for threads in [1usize, 2, 4] {
            for block in [1usize, 3, 512] {
                let got =
                    flat.predict_raw(&ds, &PredictOptions::threads(threads).with_block_rows(block));
                assert_bits_eq(
                    &naive,
                    &got,
                    &format!("layout={} t={threads} block={block}", lo.layout.as_str()),
                );
            }
        }
    }
}

#[test]
fn nan_injected_profile_trains_bit_identically_across_threads() {
    let ds = Profile::by_name("moa-nan").unwrap().generate_sized(400, 7);
    assert!(ds.features.iter().any(|v| v.is_nan()), "profile must carry NaN");
    let mut cfg = GBDTConfig::multilabel(ds.n_outputs());
    cfg.n_rounds = 3;
    cfg.max_depth = 3;
    cfg.max_bins = 16;
    cfg.learning_rate = 0.3;
    cfg.sketch = SketchConfig::RandomProjection { k: 2 };

    cfg.n_threads = 1;
    let base = GBDT::fit(&cfg, &ds, None);
    assert!(
        base.trees.iter().any(|t| t.nodes.iter().any(|n| !n.default_left)),
        "25% missing cells should teach at least one default-right split"
    );
    for threads in [2usize, 4] {
        let mut c = cfg.clone();
        c.n_threads = threads;
        let m = GBDT::fit(&c, &ds, None);
        assert_eq!(base.trees, m.trees, "training threads = {threads}");
    }

    // flat vs naive prediction on the NaN-bearing inputs, 1/2/4 threads
    let naive = base.predict_raw_naive(&ds);
    let flat = FlatForest::from_ensemble(&base);
    for threads in [1usize, 2, 4] {
        let got = flat.predict_raw(&ds, &PredictOptions::threads(threads).with_block_rows(37));
        assert_bits_eq(&naive, &got, &format!("predict threads = {threads}"));
    }
}

#[test]
fn categorical_model_save_load_predict_round_trip() {
    let ds = Profile::by_name("cat-rule").unwrap().generate_sized(600, 11);
    let mut cfg = GBDTConfig::multitask(ds.n_outputs());
    cfg.n_rounds = 6;
    cfg.max_depth = 3;
    cfg.max_bins = 32;
    cfg.learning_rate = 0.3;
    let model = GBDT::fit(&cfg, &ds, None);
    assert!(
        model.trees.iter().any(|t| t.nodes.iter().any(|n| n.cats.is_some())),
        "categorical profile must produce category-set splits"
    );

    let dir = std::env::temp_dir().join("sb_missing_categorical");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("cat_model.json");
    model.save(&path).unwrap();
    let loaded = Ensemble::load(&path).unwrap();
    assert_eq!(model.trees, loaded.trees, "category sets and defaults round-trip");
    assert_bits_eq(
        &model.predict_raw_naive(&ds),
        &loaded.predict_raw(&ds),
        "save/load predictions",
    );

    // the handcrafted mixed-rule model round-trips too (deterministic
    // default-right + cat-set coverage, independent of training)
    let hc = handcrafted_model();
    let path2 = dir.join("handcrafted.json");
    hc.save(&path2).unwrap();
    let hc2 = Ensemble::load(&path2).unwrap();
    assert_eq!(hc.trees, hc2.trees);
    let rows = adversarial_rows();
    let hd = dataset_from_rows(&rows);
    assert_bits_eq(&hc.predict_raw_naive(&hd), &hc2.predict_raw_naive(&hd), "handcrafted");
}

#[test]
fn reference_engine_matches_native_on_missing_and_categorical_training() {
    // full training: the from-scratch naive scan + pinned historical
    // histogram path must reproduce the native engine bit-for-bit on a
    // NaN-bearing categorical dataset, for both missing policies
    let ds = Profile::by_name("cat-rule").unwrap().generate_sized(500, 13);
    for policy in ["learn", "left"] {
        let mut cfg = GBDTConfig::multitask(ds.n_outputs());
        cfg.n_rounds = 4;
        cfg.max_depth = 4;
        cfg.max_bins = 32;
        cfg.learning_rate = 0.3;
        cfg.missing_policy = sketchboost::engine::MissingPolicy::parse(policy).unwrap();
        let native = GBDT::fit(&cfg, &ds, None);
        let mut reference = ReferenceEngine::new();
        let via_ref = GBDT::fit_with_engine(&cfg, &ds, None, &mut reference);
        assert_eq!(native.trees, via_ref.trees, "policy = {policy}");
        assert_eq!(native.base_score, via_ref.base_score);
    }
}

#[test]
fn categorical_splits_beat_codes_as_ordinal_on_validation_loss() {
    // ACCEPTANCE: the generative rule is categorical (scattered category
    // subsets drive the targets), so category-set splits must reach
    // strictly lower validation loss than the identical data with its
    // id columns treated as ordinal codes.
    let ds = make_categorical_multitask(2500, 4, 12, 2, 4, 0.1, 17);
    let (train, valid) = train_test_split(&ds, 0.3, 3);

    let mut cfg = GBDTConfig::multitask(4);
    cfg.n_rounds = 30;
    cfg.max_depth = 3;
    cfg.max_bins = 32;
    cfg.learning_rate = 0.2;

    let cat_model = GBDT::fit(&cfg, &train, Some(&valid));

    // same rows, same ids — but the kind marks dropped: ordinal scan
    let strip = |d: &Dataset| {
        let mut o = d.clone();
        o.kinds = vec![FeatureKind::Numeric; o.n_features];
        o
    };
    let (train_ord, valid_ord) = (strip(&train), strip(&valid));
    let ord_model = GBDT::fit(&cfg, &train_ord, Some(&valid_ord));

    let metric = Metric::Rmse;
    let cat_loss = metric.eval(&cat_model.predict_raw(&valid), &valid.targets);
    let ord_loss = metric.eval(&ord_model.predict_raw(&valid_ord), &valid_ord.targets);
    assert!(
        cat_loss < ord_loss,
        "categorical splits must beat ordinal codes: {cat_loss} vs {ord_loss}"
    );
    // and the win must come from actual category-set splits
    assert!(cat_model.trees.iter().any(|t| t.nodes.iter().any(|n| n.cats.is_some())));
    assert!(ord_model.trees.iter().all(|t| t.nodes.iter().all(|n| n.cats.is_none())));
}
