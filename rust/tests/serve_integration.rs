//! The serving daemon must extend the repo's determinism story to the
//! network edge: every response — under any interleaving of concurrent
//! single-row and multi-row requests, for every worker count and block
//! size, and across a mid-load model hot-swap — is **bitwise-equal** to
//! offline `FlatForest` predict on the same rows. The tests here run
//! the real daemon (`serve::Server`) on loopback ephemeral ports.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::time::Duration;

use sketchboost::data::synthetic::{make_multilabel, FeatureSpec};
use sketchboost::prelude::*;
use sketchboost::serve::{ServeOptions, Server};

/// Train a small multilabel model and save it where the server loads it.
fn train_and_save(dir: &str, seed: u64) -> (Dataset, Ensemble, PathBuf) {
    let ds = make_multilabel(200, FeatureSpec::guyon(12), 6, 3, seed);
    let mut cfg = GBDTConfig::multilabel(6);
    cfg.n_rounds = 5;
    cfg.max_depth = 4;
    cfg.max_bins = 16;
    cfg.seed = seed;
    let model = GBDT::fit(&cfg, &ds, None);
    let d = std::env::temp_dir().join(dir);
    std::fs::create_dir_all(&d).unwrap();
    let path = d.join(format!("model_{seed}.json"));
    model.save(&path).unwrap();
    (ds, model, path)
}

/// `SB_TEST_SCALE` in (0, 1] shrinks per-client request counts for
/// slow instrumented builds (ThreadSanitizer/AddressSanitizer); the
/// floor of 5 keeps every interleaving class (single-row, multi-row,
/// cross-batch) represented.
fn scaled(n: usize) -> usize {
    let s = std::env::var("SB_TEST_SCALE")
        .ok()
        .and_then(|v| v.parse::<f64>().ok())
        .map(|s| s.clamp(0.05, 1.0))
        .unwrap_or(1.0);
    ((n as f64 * s) as usize).max(5)
}

/// One request line for row `i` (Display round-trips every f32 bit).
fn row_line(ds: &Dataset, i: usize) -> String {
    ds.row(i)
        .iter()
        .map(|v| format!("{v}"))
        .collect::<Vec<_>>()
        .join(",")
}

/// One multi-row request line for `rows`.
fn multi_line(ds: &Dataset, rows: &[usize]) -> String {
    rows.iter().map(|&i| row_line(ds, i)).collect::<Vec<_>>().join(";")
}

/// Parse a response line back into row-major scores.
fn parse_scores(line: &str) -> Vec<f32> {
    assert!(!line.starts_with('!'), "error response: {line}");
    line.split(';')
        .flat_map(|row| row.split(','))
        .map(|c| c.parse::<f32>().unwrap())
        .collect()
}

/// Blocking request/response client on one connection.
struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        stream.set_nodelay(true).unwrap();
        Client { reader: BufReader::new(stream.try_clone().unwrap()), writer: stream }
    }

    fn request(&mut self, line: &str) -> String {
        self.writer.write_all(line.as_bytes()).unwrap();
        self.writer.write_all(b"\n").unwrap();
        self.writer.flush().unwrap();
        let mut resp = String::new();
        self.reader.read_line(&mut resp).unwrap();
        assert!(resp.ends_with('\n'), "truncated response: {resp:?}");
        resp.trim_end().to_string()
    }
}

fn assert_bits_eq(want: &[f32], got: &[f32], ctx: &str) {
    assert_eq!(want.len(), got.len(), "{ctx}: length");
    for (i, (a, b)) in want.iter().zip(got).enumerate() {
        assert!(a.to_bits() == b.to_bits(), "{ctx}: cell {i} differs ({a:?} vs {b:?})");
    }
}

/// Expected bits for a multi-row request: the offline per-row reference
/// concatenated in request order.
fn expected(naive: &[f32], d: usize, rows: &[usize]) -> Vec<f32> {
    rows.iter().flat_map(|&i| naive[i * d..(i + 1) * d].to_vec()).collect()
}

/// The tentpole matrix: every worker count × block size, six concurrent
/// clients interleaving single-row and multi-row requests — every
/// response bitwise-equal to offline predict.
#[test]
fn concurrent_interleavings_match_offline_predict_bitwise() {
    let (ds, model, path) = train_and_save("sb_serve_matrix", 21);
    let naive = model.predict_raw_naive(&ds);
    let d = model.n_outputs;
    for workers in [1usize, 2, 4] {
        for block in [1usize, 64, 512] {
            let opts = ServeOptions {
                n_workers: workers,
                block_rows: block,
                max_wait_us: 500,
                ..ServeOptions::default()
            };
            let server = Server::start(&path, &opts).unwrap();
            let addr = server.addr();
            std::thread::scope(|s| {
                for t in 0..6usize {
                    let (ds, naive) = (&ds, &naive);
                    s.spawn(move || {
                        let mut client = Client::connect(addr);
                        for k in 0..scaled(20) {
                            let rows: Vec<usize> = if (k + t) % 3 == 0 {
                                // multi-row request of varying length
                                (0..(k % 4) + 2).map(|j| (t * 31 + k * 7 + j * 13) % ds.n_rows).collect()
                            } else {
                                vec![(t * 31 + k * 7) % ds.n_rows]
                            };
                            let resp = client.request(&multi_line(ds, &rows));
                            let got = parse_scores(&resp);
                            assert_bits_eq(
                                &expected(naive, d, &rows),
                                &got,
                                &format!("workers={workers} block={block} client={t} req={k} rows={rows:?}"),
                            );
                        }
                    });
                }
            });
            server.stop();
        }
    }
}

/// Hot-swap under load: while clients hammer the server, the watched
/// model file is atomically replaced. Every in-flight response must
/// match the old or the new model *exactly* (no torn forest), and
/// post-drain traffic must match only the new one.
#[test]
fn hot_swap_mid_load_never_tears_a_response() {
    let (ds, model_a, path) = train_and_save("sb_serve_swap", 31);
    // same shape, different seed -> different trees, same save path dir
    let (_, model_b, path_b) = train_and_save("sb_serve_swap", 32);
    let naive_a = model_a.predict_raw_naive(&ds);
    let naive_b = model_b.predict_raw_naive(&ds);
    let d = model_a.n_outputs;
    assert!(
        naive_a.iter().zip(&naive_b).any(|(a, b)| a.to_bits() != b.to_bits()),
        "models must differ for the swap to be observable"
    );

    let opts = ServeOptions {
        n_workers: 2,
        block_rows: 8,
        max_wait_us: 300,
        poll_ms: 10,
        ..ServeOptions::default()
    };
    let server = Server::start(&path, &opts).unwrap();
    let addr = server.addr();
    assert_eq!(server.model_version(), 1);

    std::thread::scope(|s| {
        let mut loaders = Vec::new();
        for t in 0..4usize {
            let (ds, naive_a, naive_b) = (&ds, &naive_a, &naive_b);
            loaders.push(s.spawn(move || {
                let mut client = Client::connect(addr);
                for k in 0..scaled(60) {
                    let rows: Vec<usize> = if k % 4 == 0 {
                        (0..3).map(|j| (t * 17 + k * 5 + j * 11) % ds.n_rows).collect()
                    } else {
                        vec![(t * 17 + k * 5) % ds.n_rows]
                    };
                    let got = parse_scores(&client.request(&multi_line(ds, &rows)));
                    let want_a = expected(naive_a, d, &rows);
                    let want_b = expected(naive_b, d, &rows);
                    let matches =
                        |w: &[f32]| w.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits());
                    // the whole response matches exactly one model: a
                    // torn forest would blend the two
                    assert!(
                        matches(&want_a) || matches(&want_b),
                        "client {t} req {k}: response matches neither model entirely"
                    );
                }
            }));
        }
        // let traffic flow, then atomically replace the watched file
        std::thread::sleep(Duration::from_millis(50));
        std::fs::rename(&path_b, &path).unwrap();
        // the watcher (10ms poll) must pick it up while load continues
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while server.model_version() < 2 {
            assert!(std::time::Instant::now() < deadline, "hot swap never happened");
            std::thread::sleep(Duration::from_millis(5));
        }
        for l in loaders {
            l.join().unwrap();
        }
    });

    // post-drain: every batch now snapshots the new forest, so fresh
    // traffic matches model B only
    let mut client = Client::connect(addr);
    for i in (0..ds.n_rows).step_by(17) {
        let got = parse_scores(&client.request(&row_line(&ds, i)));
        assert_bits_eq(&naive_b[i * d..(i + 1) * d], &got, &format!("post-swap row {i}"));
    }
    server.stop();
}

/// Control verbs, error responses, and the clean shutdown path.
#[test]
fn protocol_stats_and_clean_shutdown() {
    let (ds, model, path) = train_and_save("sb_serve_proto", 41);
    let naive = model.predict_raw_naive(&ds);
    let d = model.n_outputs;
    let opts = ServeOptions { n_workers: 1, max_wait_us: 100, ..ServeOptions::default() };
    let server = Server::start(&path, &opts).unwrap();
    let addr = server.addr();
    let mut client = Client::connect(addr);

    assert_eq!(client.request("/ping"), "ok");

    let info = sketchboost::util::json::Json::parse(&client.request("/model")).unwrap();
    assert_eq!(info.get("n_outputs").unwrap().as_usize().unwrap(), d);
    assert_eq!(info.get("model_version").unwrap().as_usize().unwrap(), 1);
    assert!(info.get("n_trees").unwrap().as_usize().unwrap() > 0);

    // a real request, then garbage, then a too-narrow row: the
    // connection keeps answering in order
    let got = parse_scores(&client.request(&row_line(&ds, 3)));
    assert_bits_eq(&naive[3 * d..4 * d], &got, "single row");
    assert!(client.request("1,2,oops").starts_with('!'), "garbage must error");
    // sanity: the trained model really needs more than one feature, so
    // the width-1 row below must come back as an error response
    assert!(FlatForest::from_ensemble(&model).n_features_required() > 1);
    assert!(client.request("0.5").starts_with('!'), "narrow row must error");
    let got = parse_scores(&client.request(&row_line(&ds, 4)));
    assert_bits_eq(&naive[4 * d..5 * d], &got, "after errors");

    let stats = sketchboost::util::json::Json::parse(&client.request("/stats")).unwrap();
    assert!(stats.get("n_requests").unwrap().as_usize().unwrap() >= 2);
    assert_eq!(stats.get("n_errors").unwrap().as_usize().unwrap(), 2);
    assert!(stats.get("n_batches").unwrap().as_usize().unwrap() >= 1);
    assert_eq!(stats.get("model_version").unwrap().as_usize().unwrap(), 1);

    assert_eq!(client.request("/shutdown"), "ok shutting down");
    server.wait(); // returns because /shutdown signalled
    server.stop();
    // the listener is gone: new connections are refused
    assert!(TcpStream::connect(addr).is_err(), "server should be down");
}

/// Empty lines are skipped, and a pipelined burst (many requests
/// written before any response is read) comes back in order.
#[test]
fn pipelined_burst_responds_in_order() {
    let (ds, model, path) = train_and_save("sb_serve_pipeline", 51);
    let naive = model.predict_raw_naive(&ds);
    let d = model.n_outputs;
    let opts = ServeOptions {
        n_workers: 2,
        block_rows: 16,
        max_wait_us: 2000,
        ..ServeOptions::default()
    };
    let server = Server::start(&path, &opts).unwrap();
    let mut client = Client::connect(server.addr());

    // write a burst: rows 0..40 pipelined with blank lines sprinkled in
    let mut burst = String::new();
    for i in 0..40usize {
        burst.push_str(&row_line(&ds, i));
        burst.push('\n');
        if i % 7 == 0 {
            burst.push('\n'); // blank line: skipped, no response
        }
    }
    client.writer.write_all(burst.as_bytes()).unwrap();
    client.writer.flush().unwrap();
    for i in 0..40usize {
        let mut resp = String::new();
        client.reader.read_line(&mut resp).unwrap();
        let got = parse_scores(resp.trim_end());
        assert_bits_eq(&naive[i * d..(i + 1) * d], &got, &format!("burst row {i}"));
    }
    server.stop();
}
