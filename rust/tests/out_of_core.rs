//! Out-of-core training contract: a chunked on-disk store round-trips
//! every byte of the binned matrix, and training from it is
//! bitwise-identical to the in-RAM fit on the same codes — across
//! profiles (missing values, categorical splits), chunk plans (single
//! chunk, ragged tail, one-row chunks), and engine thread counts.

use sketchboost::data::binning::{BinnedDataset, BinnedSource};
use sketchboost::data::chunked::ChunkedBinned;
use sketchboost::data::profiles::Profile;
use sketchboost::data::store::{self, StoreError};
use sketchboost::prelude::*;

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("sb_out_of_core_test");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// The exact binned matrix training would build for this config.
fn binned_for(ds: &Dataset, cfg: &GBDTConfig) -> BinnedDataset {
    BinnedDataset::from_dataset_with_kinds(ds, cfg.max_bins, &cfg.merged_kinds(ds))
}

fn fast_cfg(ds: &Dataset) -> GBDTConfig {
    let mut cfg = GBDTConfig::for_dataset(ds);
    cfg.n_rounds = 3;
    cfg.max_depth = 3;
    cfg.max_bins = 32;
    cfg.learning_rate = 0.3;
    // subsample leaves SENTINEL rows for the prediction update, so the
    // chunked leaf_for_chunk routing arm is exercised every round
    cfg.subsample = 0.8;
    cfg
}

#[test]
fn store_round_trips_header_and_every_chunk_byte() {
    // moa-nan: multilabel with 25% missing — MISSING_BIN codes included
    let ds = Profile::by_name("moa-nan").unwrap().generate_sized(240, 5);
    let cfg = fast_cfg(&ds);
    let binned = binned_for(&ds, &cfg);
    let path = tmp("roundtrip.sbbin");
    store::write_binned(&path, &binned, &ds.targets, 64).unwrap();

    let cb = ChunkedBinned::open_verified(&path, 4).unwrap();
    assert_eq!(cb.n_rows(), binned.n_rows);
    assert_eq!(cb.n_features(), binned.n_features);
    assert_eq!(cb.max_bins(), binned.max_bins);
    assert_eq!(cb.kinds(), &binned.kinds[..]);
    assert_eq!(cb.targets(), &ds.targets);
    // bin edges survive JSON bit-exactly (stored as u32 bit patterns)
    let spec = cb.spec();
    for f in 0..binned.n_features {
        let want: Vec<u32> = binned.edges[f].iter().map(|e| e.to_bits()).collect();
        let got: Vec<u32> = spec.edges[f].iter().map(|e| e.to_bits()).collect();
        assert_eq!(got, want, "feature {f} edges");
    }
    // every chunk byte equals the in-RAM columns; ragged tail included
    assert_eq!(cb.n_chunks(), 4); // 240 rows / 64 = 3 full + 48-row tail
    let mut seen_rows = 0usize;
    for c in 0..cb.n_chunks() {
        let r = cb.chunk_range(c);
        cb.with_chunk(c, &mut |cols| {
            assert_eq!((cols.start, cols.len), (r.start, r.len()));
            for f in 0..binned.n_features {
                assert_eq!(cols.col(f), &binned.column(f)[r.clone()], "chunk {c} feature {f}");
            }
            seen_rows += cols.len;
        });
    }
    assert_eq!(seen_rows, binned.n_rows);
}

#[test]
fn chunked_training_is_bitwise_identical_to_in_ram() {
    for (profile, n, seed) in [("moa-nan", 240usize, 11u64), ("cat-rule", 300, 13)] {
        let ds = Profile::by_name(profile).unwrap().generate_sized(n, seed);
        let base = fast_cfg(&ds);
        let want = GBDT::fit(&base, &ds, None);

        for chunk_rows in [n, 64, 1] {
            let binned = binned_for(&ds, &base);
            let path = tmp(&format!("train_{profile}_{chunk_rows}.sbbin"));
            store::write_binned(&path, &binned, &ds.targets, chunk_rows).unwrap();
            let chunked = ChunkedBinned::open(&path, 3).unwrap();
            for threads in [1usize, 2, 4] {
                let mut cfg = base.clone();
                cfg.n_threads = threads;
                let got = GBDT::fit_chunked(&cfg, &chunked, None);
                assert_eq!(
                    got.trees, want.trees,
                    "{profile}: chunk_rows={chunk_rows} threads={threads}"
                );
                assert_eq!(got.base_score, want.base_score);
                let (a, b) = (got.predict_raw(&ds), want.predict_raw(&ds));
                assert_eq!(a, b, "{profile}: predictions chunk_rows={chunk_rows}");
            }
        }
    }
}

#[test]
fn truncated_and_corrupted_stores_fail_with_structured_errors() {
    let ds = Profile::by_name("cat-rule").unwrap().generate_sized(200, 3);
    let cfg = fast_cfg(&ds);
    let binned = binned_for(&ds, &cfg);

    // truncation: the JSON header (at the tail) is gone -> Format error
    let path = tmp("damage.sbbin");
    store::write_binned(&path, &binned, &ds.targets, 64).unwrap();
    let len = std::fs::metadata(&path).unwrap().len();
    let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
    f.set_len(len / 2).unwrap();
    drop(f);
    match ChunkedBinned::open(&path, 2) {
        Err(StoreError::Format(_)) | Err(StoreError::Io(_)) => {}
        other => panic!("truncated store: expected Format/Io error, got {other:?}"),
    }

    // bit rot inside a chunk payload: checksums name the chunk
    store::write_binned(&path, &binned, &ds.targets, 64).unwrap();
    let mut bytes = std::fs::read(&path).unwrap();
    let hdr = {
        let mut file = std::fs::File::open(&path).unwrap();
        store::read_header(&mut file).unwrap()
    };
    let victim = hdr.chunks[1].offset as usize + 7;
    bytes[victim] ^= 0x40;
    std::fs::write(&path, &bytes).unwrap();
    match ChunkedBinned::open_verified(&path, 2) {
        Err(StoreError::Corrupt { chunk, .. }) => assert_eq!(chunk, 1),
        other => panic!("corrupted chunk: expected Corrupt{{chunk: 1}}, got {other:?}"),
    }
}
