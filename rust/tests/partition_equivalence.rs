//! Pre/post-refactor equivalence for the row-partition training core.
//!
//! The range-partitioned builder + engine (PR 2) must produce ensembles
//! **bit-identical** to the historical flag-routed implementation, whose
//! numerics are pinned verbatim in `engine/reference.rs`
//! ([`ReferenceEngine`]): the stable partition preserves each node's
//! ascending row order (so per-histogram-cell f32 accumulation order is
//! unchanged), and the engine's merged-rank shard alignment reproduces
//! the historical shard grouping exactly. These tests train full
//! ensembles through both implementations — across tree depths 1–6,
//! 1/2/4 engine threads, and all five sketch strategies — and compare
//! every split, every leaf value, and every prediction bitwise.

use sketchboost::data::profiles::Profile;
use sketchboost::engine::reference::ReferenceEngine;
use sketchboost::prelude::*;

/// A synthetic profile big enough to shard (otto: 9 classes, 93
/// features; 6000 rows ≈ 3 histogram shards at the root), matching the
/// parallel-determinism workload.
fn workload() -> Dataset {
    Profile::by_name("otto").expect("otto profile").generate_sized(6000, 9)
}

fn assert_ensembles_identical(a: &Ensemble, b: &Ensemble, label: &str) {
    assert_eq!(a.n_trees(), b.n_trees(), "{label}: tree count");
    for (i, (ta, tb)) in a.trees.iter().zip(&b.trees).enumerate() {
        assert_eq!(ta.nodes.len(), tb.nodes.len(), "{label}: tree {i} node count");
        for (na, nb) in ta.nodes.iter().zip(&tb.nodes) {
            assert_eq!(na.feature, nb.feature, "{label}: tree {i} split feature");
            assert_eq!(na.bin, nb.bin, "{label}: tree {i} split bin");
            assert_eq!(na.left, nb.left, "{label}: tree {i} topology");
            assert_eq!(na.right, nb.right, "{label}: tree {i} topology");
        }
        // bitwise: no tolerance
        assert_eq!(ta.leaf_values, tb.leaf_values, "{label}: tree {i} leaf values");
    }
}

fn fit_reference(cfg: &GBDTConfig, ds: &Dataset) -> Ensemble {
    let mut eng = ReferenceEngine::with_threads(1);
    GBDT::fit_with_engine(cfg, ds, None, &mut eng)
}

#[test]
fn bit_identical_to_prerefactor_across_depths() {
    let ds = workload();
    for depth in 1..=6usize {
        let mut cfg = GBDTConfig::for_dataset(&ds);
        cfg.n_rounds = 1;
        cfg.learning_rate = 0.3;
        cfg.max_depth = depth;
        cfg.max_bins = 32;
        cfg.sketch = SketchConfig::RandomProjection { k: 3 };

        let reference = fit_reference(&cfg, &ds);
        for threads in [1usize, 4] {
            cfg.n_threads = threads;
            let model = GBDT::fit(&cfg, &ds, None);
            let label = format!("depth={depth} threads={threads}");
            assert_ensembles_identical(&reference, &model, &label);
            assert_eq!(
                reference.predict_raw(&ds),
                model.predict_raw(&ds),
                "{label}: predictions"
            );
        }
    }
}

#[test]
fn bit_identical_to_prerefactor_across_sketches_and_threads() {
    let ds = workload();
    for sketch in [
        SketchConfig::None,
        SketchConfig::TopOutputs { k: 2 },
        SketchConfig::RandomSampling { k: 2 },
        SketchConfig::RandomProjection { k: 5 },
        SketchConfig::TruncatedSvd { k: 2, iters: 4 },
    ] {
        let mut cfg = GBDTConfig::for_dataset(&ds);
        cfg.n_rounds = 1;
        cfg.max_depth = 4;
        cfg.max_bins = 32;
        cfg.sketch = sketch;

        let reference = fit_reference(&cfg, &ds);
        for threads in [1usize, 2, 4] {
            cfg.n_threads = threads;
            let model = GBDT::fit(&cfg, &ds, None);
            let label = format!("sketch={} threads={threads}", sketch.name());
            assert_ensembles_identical(&reference, &model, &label);
        }
    }
}

#[test]
fn bit_identical_under_row_sampling_and_weights() {
    // GOSS/MVS up-weighting routes weighted channel rows through the
    // stable partition; plain subsampling shrinks the sampled set. Both
    // must stay bit-identical to the historical path.
    let ds = workload();
    for (label, set) in [
        ("subsample", (|c: &mut GBDTConfig| c.subsample = 0.7) as fn(&mut GBDTConfig)),
        ("mvs", |c: &mut GBDTConfig| {
            c.row_sampling = sketchboost::boosting::sampling::RowSampling::Mvs { rate: 0.5 }
        }),
        ("goss", |c: &mut GBDTConfig| {
            c.row_sampling = sketchboost::boosting::sampling::RowSampling::Goss {
                top_rate: 0.2,
                other_rate: 0.3,
            }
        }),
    ] {
        let mut cfg = GBDTConfig::for_dataset(&ds);
        cfg.n_rounds = 2;
        cfg.max_depth = 4;
        cfg.max_bins = 32;
        cfg.sketch = SketchConfig::TopOutputs { k: 3 };
        set(&mut cfg);

        let reference = fit_reference(&cfg, &ds);
        cfg.n_threads = 4;
        let model = GBDT::fit(&cfg, &ds, None);
        assert_ensembles_identical(&reference, &model, label);
    }
}
