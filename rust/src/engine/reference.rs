//! Pre-partitioning reference engine — the historical numerics, pinned.
//!
//! The range-based [`NativeEngine`](super::NativeEngine) (see
//! `engine/native.rs` "Range-based accumulation and shard alignment")
//! claims bit-identity with the engine that preceded it, which took an
//! *interleaved, globally ascending* row list plus a per-row
//! `slot_of_row` map, gathered channel rows into a scratch buffer every
//! level, and sharded the interleaved list directly. This module keeps
//! that implementation — verbatim, including its thread-sharded
//! accumulation and deterministic reduction — so the claim stays
//! mechanically checkable:
//!
//! * [`histograms_flagged`] is the old accumulation path, byte for byte,
//!   callable with old-style inputs (used by `benches/hot_paths.rs` for
//!   the before/after measurement);
//! * [`ReferenceEngine`] adapts the old path to the new range-based
//!   [`ComputeEngine`] contract by merging the segments back into the
//!   historical ascending order, so full training runs can be compared
//!   bit-for-bit (`rust/tests/partition_equivalence.rs`);
//! * [`partition_inputs`] converts old-style `(rows, slot_of_row)`
//!   fixtures into partition order for tests and benches.
//!
//! This module is test/bench support, not a training backend — hence
//! `#[doc(hidden)]` on the module. It allocates per call and should
//! never sit on a hot path.

use crate::boosting::losses::LossKind;
use crate::data::binning::{BinnedDataset, BinnedSource};
use crate::data::dataset::{FeatureKind, Targets};
use crate::util::threading::{reduce_shards, shard_bounds, DisjointSlice, ThreadPool};

use super::native::{hist_shards, missing_direction_scores};
use super::{
    categorical_order, denom_of, CatScratch, ComputeEngine, EngineOpts, LeafSums,
    MissingPolicy, NativeEngine, ScanSpec, ScoreMode, SlotRange,
};

/// The historical histogram path: gather channel rows and per-row slice
/// bases into compact buffers, shard the (interleaved) row list with
/// [`hist_shards`]/[`shard_bounds`], accumulate thread-locally, and
/// reduce in ascending shard order. `slot_of_row` maps *global* row
/// index -> frontier slot and `chan` is the row-major `[n, k1]` channel
/// matrix — exactly the pre-refactor `ComputeEngine::histograms`
/// contract.
#[allow(clippy::too_many_arguments)]
pub fn histograms_flagged(
    pool: &ThreadPool,
    binned: &BinnedDataset,
    rows: &[u32],
    slot_of_row: &[u32],
    chan: &[f32],
    k1: usize,
    n_slots: usize,
    out: &mut [f32],
) {
    let n = binned.n_rows;
    let m = binned.n_features;
    let bins = binned.max_bins;
    debug_assert_eq!(out.len(), n_slots * m * bins * k1);
    debug_assert_eq!(chan.len(), n * k1);

    let nr = rows.len();
    let mut scratch_chan = vec![0.0f32; nr * k1];
    let mut slot_base = Vec::with_capacity(nr);
    let slice = m * bins * k1;
    for (j, &r) in rows.iter().enumerate() {
        let r = r as usize;
        scratch_chan[j * k1..(j + 1) * k1].copy_from_slice(&chan[r * k1..(r + 1) * k1]);
        slot_base.push(slot_of_row[r] as usize * slice);
    }
    let n_shards = hist_shards(nr, n_slots * bins);
    if n_shards == 1 {
        hist_dispatch_flagged(binned, rows, &slot_base, &scratch_chan, k1, out);
        return;
    }

    let total = out.len();
    let mut scratch_shards = vec![0.0f32; n_shards * total];
    let chan_g = &scratch_chan;
    let shard_bufs = DisjointSlice::new(&mut scratch_shards);
    pool.for_each_chunk(n_shards, 1, |shard_range| {
        for s in shard_range {
            // SAFETY: `s < n_shards` and the buffer holds
            // `n_shards * total` cells, so the range is in bounds.
            // DISJOINT: partitioned by shard index — the queue hands
            // each `s` to exactly one worker.
            let buf = unsafe { shard_bufs.range_mut(s * total..(s + 1) * total) };
            buf.fill(0.0);
            let (j0, j1) = shard_bounds(nr, n_shards, s);
            hist_dispatch_flagged(
                binned,
                &rows[j0..j1],
                &slot_base[j0..j1],
                &chan_g[j0 * k1..j1 * k1],
                k1,
                buf,
            );
        }
    });
    reduce_shards(pool, &scratch_shards, n_shards, out);
}

/// The historical per-row-slot-base pass dispatch (pre-refactor
/// `hist_dispatch`).
fn hist_dispatch_flagged(
    binned: &BinnedDataset,
    rows: &[u32],
    slot_base: &[usize],
    chan_g: &[f32],
    k1: usize,
    out: &mut [f32],
) {
    match k1 {
        2 => hist_pass_flagged::<2>(binned, rows, slot_base, chan_g, out),
        3 => hist_pass_flagged::<3>(binned, rows, slot_base, chan_g, out),
        6 => hist_pass_flagged::<6>(binned, rows, slot_base, chan_g, out),
        11 => hist_pass_flagged::<11>(binned, rows, slot_base, chan_g, out),
        _ => hist_pass_flagged_dyn(binned, rows, slot_base, chan_g, k1, out),
    }
}

fn hist_pass_flagged<const K1: usize>(
    binned: &BinnedDataset,
    rows: &[u32],
    slot_base: &[usize],
    chan_g: &[f32],
    out: &mut [f32],
) {
    let m = binned.n_features;
    let bins = binned.max_bins;
    for f in 0..m {
        let col = binned.column(f);
        let fbase = f * bins * K1;
        for (j, &r) in rows.iter().enumerate() {
            let b = col[r as usize] as usize;
            let dst = slot_base[j] + fbase + b * K1;
            let src = &chan_g[j * K1..j * K1 + K1];
            let out_s = &mut out[dst..dst + K1];
            for c in 0..K1 {
                out_s[c] += src[c];
            }
        }
    }
}

fn hist_pass_flagged_dyn(
    binned: &BinnedDataset,
    rows: &[u32],
    slot_base: &[usize],
    chan_g: &[f32],
    k1: usize,
    out: &mut [f32],
) {
    let m = binned.n_features;
    let bins = binned.max_bins;
    for f in 0..m {
        let col = binned.column(f);
        let fbase = f * bins * k1;
        for (j, &r) in rows.iter().enumerate() {
            let b = col[r as usize] as usize;
            let dst = slot_base[j] + fbase + b * k1;
            let src = &chan_g[j * k1..(j + 1) * k1];
            let out_s = &mut out[dst..dst + k1];
            for (o, &s) in out_s.iter_mut().zip(src.iter()) {
                *o += s;
            }
        }
    }
}

/// Convert old-style `(rows, slot_of_row, chan_by_global_row)` fixtures
/// into the partition-ordered `(rows, chan_by_position, segs)` inputs of
/// the range-based contract. Rows are grouped by slot in ascending slot
/// order, preserving their relative order within each slot (exactly what
/// the builder's stable partition produces from an ascending row list).
pub fn partition_inputs(
    rows: &[u32],
    slot_of_row: &[u32],
    chan: &[f32],
    k1: usize,
    n_slots: usize,
) -> (Vec<u32>, Vec<f32>, Vec<SlotRange>) {
    let mut prows = Vec::with_capacity(rows.len());
    let mut pchan = Vec::with_capacity(rows.len() * k1);
    let mut segs = Vec::with_capacity(n_slots);
    for slot in 0..n_slots as u32 {
        let start = prows.len() as u32;
        for &r in rows {
            if slot_of_row[r as usize] == slot {
                prows.push(r);
                let r = r as usize;
                pchan.extend_from_slice(&chan[r * k1..(r + 1) * k1]);
            }
        }
        segs.push(SlotRange::new(slot, start, prows.len() as u32));
    }
    (prows, pchan, segs)
}

/// Naive split-gain oracle: every candidate of every (slot, feature)
/// pair recomputed **from scratch** with plain per-candidate loops — no
/// prefix accumulators, no worker queue, no shared per-pair state. The
/// per-side f64 sums fold the same cell sequence in the same ascending
/// order as `NativeEngine`'s incremental scan (sequential left-folds of
/// the same sequence are bit-identical), and the final candidate score
/// reuses [`missing_direction_scores`], so `rust/tests/missing_categorical.rs`
/// can require **bitwise** equality between this oracle and the
/// native scan across feature kinds, missing policies, and thread
/// counts. Allocates per call — test/bench support only.
pub fn split_gains_naive(
    hist: &[f32],
    spec: &ScanSpec,
    out: &mut Vec<f32>,
    defaults: &mut Vec<u8>,
) {
    let (n_slots, m, bins, k1) = (spec.n_slots, spec.m, spec.bins, spec.k1);
    let (lam, mode) = (spec.lam as f64, spec.mode);
    let k = mode.scoring_k(k1);
    out.clear();
    out.resize(n_slots * m * bins, 0.0);
    defaults.clear();
    defaults.resize(n_slots * m * bins, 1);
    if n_slots * m == 0 || bins == 0 {
        return;
    }
    // per-candidate from-scratch left-side sums over an explicit bin list
    let side = |ph: &[f32], left_bins: &[u8]| -> (Vec<f64>, f64) {
        let mut g = vec![0.0f64; k];
        let mut d = 0.0f64;
        for &b in left_bins {
            let cell = &ph[b as usize * k1..(b as usize + 1) * k1];
            for c in 0..k {
                g[c] += cell[c] as f64;
            }
            d += denom_of(cell, k, k1, mode);
        }
        (g, d)
    };
    let all_bins: Vec<u8> = (0..bins as u16).map(|b| b as u8).collect();
    let mut cat = CatScratch::default();
    for pair in 0..n_slots * m {
        let ph = &hist[pair * bins * k1..(pair + 1) * bins * k1];
        let (tot_g, tot_d) = side(ph, &all_bins);
        let (miss_g, miss_d) = side(ph, &[0]);
        let dst = &mut out[pair * bins..(pair + 1) * bins];
        let dfl = &mut defaults[pair * bins..(pair + 1) * bins];
        match spec.kinds[pair % m] {
            FeatureKind::Numeric => match spec.missing {
                MissingPolicy::AlwaysLeft => {
                    // classic prefix scan: candidate b = bins 0..=b left
                    for b in 0..bins {
                        let (acc_g, acc_d) = side(ph, &all_bins[..=b]);
                        let mut sl = 0.0f64;
                        let mut sr = 0.0f64;
                        for c in 0..k {
                            let l = acc_g[c];
                            let r = tot_g[c] - l;
                            sl += l * l;
                            sr += r * r;
                        }
                        sl /= acc_d + lam;
                        sr /= (tot_d - acc_d) + lam;
                        dst[b] = (sl + sr) as f32;
                    }
                }
                MissingPolicy::Learn => {
                    for b in 1..bins {
                        let (acc_g, acc_d) = side(ph, &all_bins[1..=b]);
                        let (gl, gr) = missing_direction_scores(
                            &acc_g, &miss_g, &tot_g, acc_d, miss_d, tot_d, lam, k,
                        );
                        if gl >= gr {
                            dst[b] = gl as f32;
                        } else {
                            dst[b] = gr as f32;
                            dfl[b] = 0;
                        }
                    }
                }
            },
            FeatureKind::Categorical => {
                categorical_order(ph, bins, k1, mode, spec.lam, &mut cat);
                let order = cat.order.clone();
                for j in 0..order.len() {
                    let (acc_g, acc_d) = side(ph, &order[..=j]);
                    let (gl, gr) = missing_direction_scores(
                        &acc_g, &miss_g, &tot_g, acc_d, miss_d, tot_d, lam, k,
                    );
                    match spec.missing {
                        MissingPolicy::AlwaysLeft => dst[j] = gl as f32,
                        MissingPolicy::Learn => {
                            if gl >= gr {
                                dst[j] = gl as f32;
                            } else {
                                dst[j] = gr as f32;
                                dfl[j] = 0;
                            }
                        }
                    }
                }
            }
        }
    }
}

/// A [`ComputeEngine`] whose `histograms` reproduces the pre-refactor
/// bits by merging the range-based inputs back into the historical
/// globally ascending interleaved order and running
/// [`histograms_flagged`]; `split_gains` runs the from-scratch
/// [`split_gains_naive`] oracle. Every other op delegates to a normal
/// [`NativeEngine`] (those ops did not change in the refactor).
pub struct ReferenceEngine {
    pool: ThreadPool,
    inner: NativeEngine,
}

impl ReferenceEngine {
    pub fn new() -> ReferenceEngine {
        ReferenceEngine::with_threads(1)
    }

    pub fn with_threads(n_threads: usize) -> ReferenceEngine {
        ReferenceEngine {
            pool: ThreadPool::new(n_threads),
            inner: NativeEngine::with_opts(EngineOpts::threads(n_threads)),
        }
    }
}

impl Default for ReferenceEngine {
    fn default() -> Self {
        ReferenceEngine::new()
    }
}

impl ComputeEngine for ReferenceEngine {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn grad_hess(
        &mut self,
        loss: LossKind,
        preds: &[f32],
        targets: &Targets,
        g: &mut [f32],
        h: &mut [f32],
    ) -> f64 {
        self.inner.grad_hess(loss, preds, targets, g, h)
    }

    fn sketch_project(
        &mut self,
        g_mat: &[f32],
        n: usize,
        d: usize,
        proj: &[f32],
        k: usize,
        out: &mut [f32],
    ) {
        self.inner.sketch_project(g_mat, n, d, proj, k, out);
    }

    fn histograms(
        &mut self,
        binned: &dyn BinnedSource,
        rows: &[u32],
        chan: &[f32],
        k1: usize,
        segs: &[SlotRange],
        n_slots: usize,
        out: &mut [f32],
    ) {
        // The oracle pins the historical in-RAM numerics; chunked
        // sources are NativeEngine's concern (out_of_core.rs compares
        // the two paths through NativeEngine itself).
        let binned = binned.as_in_ram().expect("ReferenceEngine requires in-RAM binned data");
        // Reconstruct the historical inputs: the globally ascending
        // interleaved row list, the per-global-row slot map, and the
        // [n, k1] channel matrix indexed by global row.
        let n = binned.n_rows;
        let mut triples: Vec<(u32, u32, u32)> = Vec::new(); // (row, slot, pos)
        for seg in segs {
            for pos in seg.range() {
                triples.push((rows[pos], seg.slot, pos as u32));
            }
        }
        triples.sort_unstable_by_key(|t| t.0);
        let mut merged_rows = Vec::with_capacity(triples.len());
        let mut slot_of_row = vec![0u32; n];
        let mut chan_by_row = vec![0.0f32; n * k1];
        for &(r, slot, pos) in &triples {
            merged_rows.push(r);
            slot_of_row[r as usize] = slot;
            let (r, pos) = (r as usize, pos as usize);
            chan_by_row[r * k1..(r + 1) * k1]
                .copy_from_slice(&chan[pos * k1..(pos + 1) * k1]);
        }
        histograms_flagged(
            &self.pool,
            binned,
            &merged_rows,
            &slot_of_row,
            &chan_by_row,
            k1,
            n_slots,
            out,
        );
    }

    fn split_gains(
        &mut self,
        hist: &[f32],
        spec: &ScanSpec,
        out: &mut Vec<f32>,
        defaults: &mut Vec<u8>,
    ) {
        split_gains_naive(hist, spec, out, defaults);
    }

    fn leaf_sums(
        &mut self,
        rows: &[u32],
        leaf_of_row: &[u32],
        g: &[f32],
        h: &[f32],
        d: usize,
        n_leaves: usize,
        out: &mut LeafSums,
    ) {
        self.inner.leaf_sums(rows, leaf_of_row, g, h, d, n_leaves, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Dataset;
    use crate::util::rng::Rng;

    fn tiny_binned(n: usize, m: usize, bins: usize, seed: u64) -> BinnedDataset {
        let mut rng = Rng::new(seed);
        let mut feats = vec![0.0f32; n * m];
        rng.fill_gaussian(&mut feats, 1.0);
        let ds = Dataset::new(
            n,
            m,
            feats,
            Targets::Regression { values: vec![0.0; n], n_targets: 1 },
        );
        BinnedDataset::from_dataset(&ds, bins)
    }

    #[test]
    fn partition_inputs_groups_by_slot_stably() {
        let rows = vec![0u32, 1, 2, 3, 4];
        let slot_of_row = vec![1u32, 0, 1, 0, 0];
        let k1 = 2;
        let chan: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let (pr, pc, segs) = partition_inputs(&rows, &slot_of_row, &chan, k1, 2);
        assert_eq!(pr, vec![1, 3, 4, 0, 2]);
        assert_eq!(segs, vec![SlotRange::new(0, 0, 3), SlotRange::new(1, 3, 5)]);
        // channel rows follow their rows
        assert_eq!(&pc[0..2], &chan[2..4]); // row 1
        assert_eq!(&pc[6..8], &chan[0..2]); // row 0
    }

    /// The from-scratch naive scan must agree with the native prefix
    /// scan bit-for-bit across feature kinds and missing policies.
    #[test]
    fn naive_scan_matches_native_bitwise() {
        use crate::util::proptest::run_prop;
        run_prop("naive scan == native", 20, |gen| {
            let slots = gen.usize_in(1, 3);
            let m = gen.usize_in(1, 4);
            let bins = *gen.choose(&[4usize, 8, 32]);
            let k = gen.usize_in(1, 3);
            let k1 = k + 1;
            let mut hist = gen.vec_gaussian(slots * m * bins * k1, 1.0);
            for cell in 0..slots * m * bins {
                hist[cell * k1 + k] = gen.usize_in(0, 10) as f32;
            }
            let kinds: Vec<FeatureKind> = (0..m)
                .map(|_| if gen.bool() { FeatureKind::Categorical } else { FeatureKind::Numeric })
                .collect();
            for missing in [MissingPolicy::Learn, MissingPolicy::AlwaysLeft] {
                let spec = ScanSpec {
                    n_slots: slots,
                    m,
                    bins,
                    k1,
                    lam: 1.0,
                    mode: ScoreMode::CountL2,
                    kinds: &kinds,
                    missing,
                };
                let (mut a, mut da) = (Vec::new(), Vec::new());
                NativeEngine::new().split_gains(&hist, &spec, &mut a, &mut da);
                let (mut b, mut db) = (Vec::new(), Vec::new());
                split_gains_naive(&hist, &spec, &mut b, &mut db);
                assert_eq!(a, b, "{missing:?} gains");
                assert_eq!(da, db, "{missing:?} defaults");
            }
        });
    }

    /// The range-based NativeEngine must agree with the pinned historical
    /// path bit-for-bit — including on shapes large enough to shard.
    #[test]
    fn native_matches_reference_bitwise() {
        let n = 3 * crate::engine::native::SHARD_TARGET_ROWS;
        let (m, bins, n_slots, k1) = (3usize, 16usize, 4usize, 3usize);
        let binned = tiny_binned(n, m, bins, 21);
        let mut rng = Rng::new(22);
        let slot_of_row: Vec<u32> = (0..n).map(|_| rng.next_below(n_slots) as u32).collect();
        let mut chan = vec![0.0f32; n * k1];
        rng.fill_gaussian(&mut chan, 1.0);
        let rows: Vec<u32> = (0..n as u32).filter(|&r| r % 5 != 3).collect();
        let (prows, pchan, segs) = partition_inputs(&rows, &slot_of_row, &chan, k1, n_slots);

        let size = n_slots * m * bins * k1;
        for threads in [1usize, 2, 4] {
            let mut want = vec![0.0f32; size];
            ReferenceEngine::with_threads(threads)
                .histograms(&binned, &prows, &pchan, k1, &segs, n_slots, &mut want);
            let mut got = vec![0.0f32; size];
            NativeEngine::with_threads(threads)
                .histograms(&binned, &prows, &pchan, k1, &segs, n_slots, &mut got);
            assert_eq!(got, want, "threads = {threads}"); // bitwise
        }
    }
}
