//! Pure-rust compute engine — the performance path.
//!
//! Numerics are defined by `python/compile/kernels/ref.py`; this file
//! reimplements them with cache-conscious loops. The integration tests
//! cross-check every op against the XLA artifacts compiled from the JAX
//! reference, so drift is caught mechanically.
//!
//! ## Parallel execution
//!
//! The two dominant per-level costs — histogram accumulation and the
//! split-gain scan (`benches/hot_paths.rs`) — run on an internal
//! [`ThreadPool`]:
//!
//! * **Histograms** partition the active rows into *shards* whose count
//!   and boundaries depend only on the row count and histogram shape
//!   ([`hist_shards`], [`shard_bounds`]) — never on the thread count.
//!   Workers accumulate each shard into a thread-local buffer, then
//!   [`reduce_shards`] adds the shards into the output in ascending shard
//!   order, parallel across cells. Because both the partition and the
//!   per-cell addition order are fixed, the result is bit-identical for
//!   any `n_threads` (f32 addition is non-associative, so this is the
//!   property that keeps `seed`-reproducibility intact).
//! * **Split scan** fans `(slot, feature)` pairs out over a chunked work
//!   queue; each pair writes its own disjoint `bins`-wide gain range and
//!   is a pure function of the histogram, so determinism is free.
//!
//! Everything else (derivatives, gemm, leaf sums) stays serial — those
//! ops are O(n·d) streams that the trainer amortizes, and the profile in
//! EXPERIMENTS.md §Perf shows them off the critical path.
//!
//! ## Range-based accumulation and shard alignment
//!
//! `histograms` receives the builder's partition-ordered row buffer and a
//! list of [`SlotRange`] segments (DESIGN.md "Memory model & row
//! partitioning"), so each segment streams with a constant output base —
//! no per-row slot lookup and no channel re-gather. To stay bit-identical
//! to the historical implementation (which sharded the *globally
//! ascending interleaved* row list), the shard boundaries are aligned to
//! **merged ranks**: shard `s` covers the rows whose rank in the
//! ascending merge of all segments falls in `shard_bounds(nr, S, s)`.
//! Segments are ascending (stable partition of an ascending input), so
//! each shard cuts every segment at one position, found by binary search
//! on the global row id ([`align_shard_cuts`]). Every histogram cell is
//! slot-local, so per-cell f32 addition order — and therefore every bit
//! of the result — matches the pre-partitioning engine exactly
//! (`rust/tests/partition_equivalence.rs` enforces this against
//! [`super::reference::ReferenceEngine`]).

use crate::boosting::losses::LossKind;
use crate::data::binning::{BinnedDataset, BinnedSource, ChunkCols};
use crate::data::dataset::{FeatureKind, Targets};
use crate::util::threading::{reduce_shards, shard_bounds, DisjointSlice, ThreadPool};

use super::{
    categorical_order, denom_of, CatScratch, ComputeEngine, EngineOpts, LeafSums,
    MissingPolicy, ScanSpec, ScoreMode, SlotRange,
};

/// Rows per histogram shard (below 2·this, the build stays serial).
pub(crate) const SHARD_TARGET_ROWS: usize = 2048;
/// Upper bound on shards, i.e. on usable histogram parallelism.
pub(crate) const MAX_SHARDS: usize = 16;

/// Number of histogram shards for `nr` active rows and a per-slot scan
/// width of `slots_bins = n_slots * bins` cells. Pure in its inputs (and
/// in particular independent of the thread count — see module docs):
/// bounded so each shard keeps >= [`SHARD_TARGET_ROWS`] rows and so the
/// deterministic reduction costs at most ~25% of the accumulation pass.
pub(crate) fn hist_shards(nr: usize, slots_bins: usize) -> usize {
    let by_rows = nr / SHARD_TARGET_ROWS;
    let by_reduce = nr / (4 * slots_bins).max(1);
    by_rows.min(by_reduce).clamp(1, MAX_SHARDS)
}

/// Pure-rust engine. Stateless apart from scratch reuse: every scratch
/// buffer below is grown once to its high-water mark and reused, so
/// steady-state training performs no heap allocation in the histogram /
/// split-scan hot loop (`rust/tests/alloc_free.rs`).
#[derive(Default)]
pub struct NativeEngine {
    pool: ThreadPool,
    /// scratch: thread-local histogram shards, reduced deterministically
    scratch_shards: Vec<f32>,
    /// scratch: per-(shard boundary, segment) cut positions
    scratch_cuts: Vec<u32>,
    /// scratch: per-worker f64 accumulators for the split scan
    /// (layout: [tot_g k][acc_g k][miss_g k] per worker)
    scratch_gain: Vec<f64>,
    /// scratch: per-worker categorical ordering buffers
    scratch_cat: Vec<CatScratch>,
}

impl NativeEngine {
    /// Serial engine (`EngineOpts::default()`).
    pub fn new() -> Self {
        NativeEngine::default()
    }

    /// Engine with explicit options (thread count).
    pub fn with_opts(opts: EngineOpts) -> Self {
        NativeEngine { pool: ThreadPool::new(opts.n_threads), ..NativeEngine::default() }
    }

    /// Engine with an explicit thread count (`0` = all cores).
    pub fn with_threads(n_threads: usize) -> Self {
        NativeEngine::with_opts(EngineOpts::threads(n_threads))
    }

    pub fn n_threads(&self) -> usize {
        self.pool.n_threads()
    }
}

impl ComputeEngine for NativeEngine {
    fn name(&self) -> &'static str {
        "native"
    }

    fn grad_hess(
        &mut self,
        loss: LossKind,
        preds: &[f32],
        targets: &Targets,
        g: &mut [f32],
        h: &mut [f32],
    ) -> f64 {
        // the canonical derivative math lives with the losses (it is
        // also the built-in Objective implementation); this keeps the
        // two routes — engine-dispatched builtins and trait-dispatched
        // custom objectives — bit-identical by construction
        crate::boosting::losses::grad_hess_into(loss, preds, targets, g, h)
    }

    fn sketch_project(
        &mut self,
        g_mat: &[f32],
        n: usize,
        d: usize,
        proj: &[f32],
        k: usize,
        out: &mut [f32],
    ) {
        debug_assert_eq!(g_mat.len(), n * d);
        debug_assert_eq!(proj.len(), d * k);
        debug_assert_eq!(out.len(), n * k);
        // monomorphized accumulator-in-registers kernels for the paper's
        // k grid; generic fallback otherwise (EXPERIMENTS.md §Perf)
        match k {
            1 => gemm_k::<1>(g_mat, n, d, proj, out),
            2 => gemm_k::<2>(g_mat, n, d, proj, out),
            5 => gemm_k::<5>(g_mat, n, d, proj, out),
            10 => gemm_k::<10>(g_mat, n, d, proj, out),
            20 => gemm_k::<20>(g_mat, n, d, proj, out),
            _ => gemm_dyn(g_mat, n, d, proj, k, out),
        }
    }

    fn histograms(
        &mut self,
        binned: &dyn BinnedSource,
        rows: &[u32],
        chan: &[f32],
        k1: usize,
        segs: &[SlotRange],
        n_slots: usize,
        out: &mut [f32],
    ) {
        let m = binned.n_features();
        let bins = binned.max_bins();
        let slice = m * bins * k1;
        debug_assert_eq!(out.len(), n_slots * slice);
        debug_assert_eq!(chan.len(), rows.len() * k1);
        debug_assert!(segs.iter().all(|s| (s.slot as usize) < n_slots
            && s.start <= s.end
            && (s.end as usize) <= rows.len()));
        let nr: usize = segs.iter().map(|s| s.len()).sum();
        if nr == 0 {
            return;
        }

        // The in-RAM fast path keeps the historical hot loops (and their
        // `get_unchecked` column walks) byte-for-byte intact; the chunked
        // path below visits the same rows in the same ascending per-cell
        // order — chunks partition the row space ascending and every
        // segment is ascending — so per-cell f32 addition order, and
        // therefore every result bit, is identical between the two
        // (`rust/tests/out_of_core.rs` enforces this end to end).
        let ram = binned.as_in_ram();

        let n_shards = hist_shards(nr, n_slots * bins);
        if n_shards == 1 {
            // small level: one serial pass straight into `out`, segment by
            // segment with a constant slot base (sharding only ever
            // changes results when it actually splits the rows)
            if let Some(ram) = ram {
                for seg in segs {
                    let (a, b) = (seg.start as usize, seg.end as usize);
                    hist_dispatch(
                        ram,
                        &rows[a..b],
                        &chan[a * k1..b * k1],
                        k1,
                        seg.slot as usize * slice,
                        out,
                    );
                }
            } else {
                // chunk-outer so each chunk is paged in exactly once per
                // pass; segment rows are ascending, so the chunk's slice
                // of a segment is one contiguous position sub-range
                for c in 0..binned.n_chunks() {
                    let cr = binned.chunk_range(c);
                    binned.with_chunk(c, &mut |cols| {
                        for seg in segs {
                            let (a, b) = (seg.start as usize, seg.end as usize);
                            let sr = &rows[a..b];
                            let lo = a + sr.partition_point(|&r| (r as usize) < cr.start);
                            let hi = a + sr.partition_point(|&r| (r as usize) < cr.end);
                            if lo < hi {
                                hist_dispatch_chunk(
                                    &cols,
                                    m,
                                    bins,
                                    &rows[lo..hi],
                                    &chan[lo * k1..hi * k1],
                                    k1,
                                    seg.slot as usize * slice,
                                    out,
                                );
                            }
                        }
                    });
                }
            }
            return;
        }

        // Merged-rank shard alignment (module docs): shard s covers, in
        // every segment, the rows whose rank in the ascending merge of
        // all segments lies in shard_bounds(nr, S, s). Pure in the inputs
        // and independent of the thread count — and of the chunk plan,
        // which only tiles each shard's row ranges.
        let ns = segs.len();
        align_shard_cuts(rows, segs, nr, n_shards, &mut self.scratch_cuts);
        let cuts = &self.scratch_cuts;

        // Thread-local shards over the fixed partition, then a
        // deterministic ascending-order reduction.
        let total = out.len();
        self.scratch_shards.clear();
        self.scratch_shards.resize(n_shards * total, 0.0);
        let pool = &self.pool;
        let shard_bufs = DisjointSlice::new(&mut self.scratch_shards);
        pool.for_each_chunk(n_shards, 1, |shard_range| {
            for s in shard_range {
                // SAFETY: `s < n_shards` and the buffer holds
                // `n_shards * total` cells, so the range is in bounds.
                // DISJOINT: partitioned by shard index — the queue hands
                // each `s` to exactly one worker.
                let buf = unsafe { shard_bufs.range_mut(s * total..(s + 1) * total) };
                buf.fill(0.0);
                if let Some(ram) = ram {
                    for (t, seg) in segs.iter().enumerate() {
                        let a = cuts[s * ns + t] as usize;
                        let b = cuts[(s + 1) * ns + t] as usize;
                        if a < b {
                            hist_dispatch(
                                ram,
                                &rows[a..b],
                                &chan[a * k1..b * k1],
                                k1,
                                seg.slot as usize * slice,
                                buf,
                            );
                        }
                    }
                } else {
                    // chunk-outer within the shard: for each resident
                    // chunk, accumulate its intersection with every
                    // segment's shard cut range. Rows stay ascending per
                    // (segment, feature) stream, so shard contents — and
                    // result bits — match the in-RAM arm exactly.
                    for c in 0..binned.n_chunks() {
                        let cr = binned.chunk_range(c);
                        binned.with_chunk(c, &mut |cols| {
                            for (t, seg) in segs.iter().enumerate() {
                                let a = cuts[s * ns + t] as usize;
                                let b = cuts[(s + 1) * ns + t] as usize;
                                if a >= b {
                                    continue;
                                }
                                let sr = &rows[a..b];
                                let lo =
                                    a + sr.partition_point(|&r| (r as usize) < cr.start);
                                let hi = a + sr.partition_point(|&r| (r as usize) < cr.end);
                                if lo < hi {
                                    hist_dispatch_chunk(
                                        &cols,
                                        m,
                                        bins,
                                        &rows[lo..hi],
                                        &chan[lo * k1..hi * k1],
                                        k1,
                                        seg.slot as usize * slice,
                                        buf,
                                    );
                                }
                            }
                        });
                    }
                }
            }
        });
        reduce_shards(pool, &self.scratch_shards, n_shards, out);
    }

    fn split_gains(
        &mut self,
        hist: &[f32],
        spec: &ScanSpec,
        out: &mut Vec<f32>,
        defaults: &mut Vec<u8>,
    ) {
        let (n_slots, m, bins, k1) = (spec.n_slots, spec.m, spec.bins, spec.k1);
        debug_assert_eq!(spec.kinds.len(), m);
        let k = spec.mode.scoring_k(k1);
        out.clear();
        out.resize(n_slots * m * bins, 0.0);
        defaults.clear();
        defaults.resize(n_slots * m * bins, 1);
        let n_pairs = n_slots * m;
        if n_pairs == 0 || bins == 0 {
            return;
        }
        // Per-worker f64 accumulators + categorical ordering buffers,
        // pooled on the engine and reused across levels and trees.
        let nw = self.pool.n_threads().max(1);
        self.scratch_gain.clear();
        self.scratch_gain.resize(nw * 3 * k, 0.0);
        if self.scratch_cat.len() < nw {
            self.scratch_cat.resize_with(nw, CatScratch::default);
        }
        const PAIR_CHUNK: usize = 8;
        // Tiny frontiers (deep levels, small datasets) run serially on
        // the caller — thread spawns would cost more than the scan.
        if nw == 1 || hist.len() < 16 * 1024 || n_pairs <= PAIR_CHUNK {
            let ws = &mut self.scratch_gain[..3 * k];
            let cat = &mut self.scratch_cat[0];
            for pair in 0..n_pairs {
                let (dst, dfl) = (
                    &mut out[pair * bins..(pair + 1) * bins],
                    &mut defaults[pair * bins..(pair + 1) * bins],
                );
                scan_pair(hist, pair, spec, k, ws, cat, dst, dfl);
            }
            return;
        }
        // Chunked queue over (slot, feature) pairs. Each pair is a pure
        // function of `hist` writing its own disjoint `bins`-wide gain +
        // default range, so the scan is deterministic for any thread
        // count; the queue only balances load.
        use std::sync::atomic::{AtomicUsize, Ordering};
        let cursor = AtomicUsize::new(0);
        let dst_all = DisjointSlice::new(out.as_mut_slice());
        let dfl_all = DisjointSlice::new(defaults.as_mut_slice());
        let scratch = DisjointSlice::new(&mut self.scratch_gain);
        let cat_all = DisjointSlice::new(&mut self.scratch_cat);
        self.pool.broadcast(|w| {
            // SAFETY: `w < pool.n_workers()` and both scratch buffers are
            // sized per worker, so the ranges are in bounds.
            // DISJOINT: partitioned by worker id — `broadcast` hands each
            // `w` out exactly once.
            let ws = unsafe { scratch.range_mut(w * 3 * k..(w + 1) * 3 * k) };
            // SAFETY: same per-worker bounds argument as `ws` above.
            // DISJOINT: same worker-id partition as `ws` above.
            let cats = unsafe { cat_all.range_mut(w..w + 1) };
            let cat = &mut cats[0];
            loop {
                let start = cursor.fetch_add(PAIR_CHUNK, Ordering::Relaxed);
                if start >= n_pairs {
                    break;
                }
                for pair in start..(start + PAIR_CHUNK).min(n_pairs) {
                    // SAFETY: `pair < n_pairs` and both outputs hold
                    // `n_pairs * bins` cells, so the ranges are in bounds.
                    // DISJOINT: partitioned by pair index — the atomic
                    // cursor hands each `pair` to exactly one worker.
                    let dst = unsafe { dst_all.range_mut(pair * bins..(pair + 1) * bins) };
                    // SAFETY: same bounds argument as `dst` above.
                    // DISJOINT: same pair-index partition as `dst`.
                    let dfl = unsafe { dfl_all.range_mut(pair * bins..(pair + 1) * bins) };
                    scan_pair(hist, pair, spec, k, ws, cat, dst, dfl);
                }
            }
        });
    }

    fn leaf_sums(
        &mut self,
        rows: &[u32],
        leaf_of_row: &[u32],
        g: &[f32],
        h: &[f32],
        d: usize,
        n_leaves: usize,
        out: &mut LeafSums,
    ) {
        out.reset(n_leaves, d);
        for &r in rows {
            let r = r as usize;
            let leaf = leaf_of_row[r] as usize;
            debug_assert!(leaf < n_leaves);
            out.count[leaf] += 1.0;
            let gs = &mut out.gsum[leaf * d..(leaf + 1) * d];
            let gr = &g[r * d..(r + 1) * d];
            for c in 0..d {
                gs[c] += gr[c];
            }
            let hs = &mut out.hsum[leaf * d..(leaf + 1) * d];
            let hr = &h[r * d..(r + 1) * d];
            for c in 0..d {
                hs[c] += hr[c];
            }
        }
    }
}

/// Compute the merged-rank shard cut positions for range-based
/// accumulation (module docs). On return `cuts` holds `(n_shards + 1) *
/// segs.len()` absolute positions into `rows`: shard `s` covers
/// `rows[cuts[s * ns + t] .. cuts[(s + 1) * ns + t]]` of segment `t`.
///
/// Row ids are unique and every segment is ascending (the builder's
/// stable partition preserves the ascending order of the sampled row
/// list), so the rank-`j` boundary of the merged list is found by binary
/// searching the smallest row id `v` with `count(<= v) == j`; each
/// segment's cut is then its partition point at `v`.
fn align_shard_cuts(
    rows: &[u32],
    segs: &[SlotRange],
    nr: usize,
    n_shards: usize,
    cuts: &mut Vec<u32>,
) {
    let ns = segs.len();
    cuts.clear();
    cuts.resize((n_shards + 1) * ns, 0);
    for (t, seg) in segs.iter().enumerate() {
        cuts[t] = seg.start;
        cuts[n_shards * ns + t] = seg.end;
        debug_assert!(rows[seg.range()].windows(2).all(|w| w[0] < w[1]),
            "segments must be ascending for merged-rank shard alignment");
    }
    for s in 1..n_shards {
        let (rank, _) = shard_bounds(nr, n_shards, s);
        // binary search over the row-id domain for the rank-th boundary
        let mut lo = 0u32;
        let mut hi = u32::MAX;
        while lo < hi {
            let mid = lo + (hi - lo) / 2;
            let cnt: usize = segs
                .iter()
                .map(|seg| rows[seg.range()].partition_point(|&r| r <= mid))
                .sum();
            if cnt >= rank {
                hi = mid;
            } else {
                lo = mid + 1;
            }
        }
        for (t, seg) in segs.iter().enumerate() {
            let p = rows[seg.range()].partition_point(|&r| r <= lo);
            cuts[s * ns + t] = seg.start + p as u32;
        }
    }
}

/// Projection gemm with a compile-time k: the K accumulators live in
/// registers across the full d-loop instead of round-tripping memory.
fn gemm_k<const K: usize>(g_mat: &[f32], n: usize, d: usize, proj: &[f32], out: &mut [f32]) {
    for i in 0..n {
        let mut acc = [0.0f32; K];
        let gi = &g_mat[i * d..(i + 1) * d];
        for (j, &gv) in gi.iter().enumerate() {
            let pj = &proj[j * K..j * K + K];
            for c in 0..K {
                acc[c] += gv * pj[c];
            }
        }
        out[i * K..(i + 1) * K].copy_from_slice(&acc);
    }
}

/// Generic projection gemm fallback.
fn gemm_dyn(g_mat: &[f32], n: usize, d: usize, proj: &[f32], k: usize, out: &mut [f32]) {
    out.fill(0.0);
    for i in 0..n {
        let gi = &g_mat[i * d..(i + 1) * d];
        let oi = &mut out[i * k..(i + 1) * k];
        for (j, &gv) in gi.iter().enumerate() {
            if gv == 0.0 {
                continue;
            }
            let pj = &proj[j * k..(j + 1) * k];
            for (o, &p) in oi.iter_mut().zip(pj.iter()) {
                *o += gv * p;
            }
        }
    }
}

/// Scan one (slot, feature) pair's candidates into `out`/`dfl` (`bins`
/// entries each), dispatching on the feature kind and missing policy
/// (see the `ComputeEngine::split_gains` contract). `ws` is a
/// caller-owned `3k`-wide f64 scratch (`[tot_g][acc_g][miss_g]`), `cat`
/// the caller-owned categorical ordering scratch.
#[allow(clippy::too_many_arguments)]
fn scan_pair(
    hist: &[f32],
    pair: usize,
    spec: &ScanSpec,
    k: usize,
    ws: &mut [f64],
    cat: &mut CatScratch,
    out: &mut [f32],
    dfl: &mut [u8],
) {
    let (bins, k1) = (spec.bins, spec.k1);
    let ph = &hist[pair * bins * k1..(pair + 1) * bins * k1];
    let (tot_g, rest) = ws.split_at_mut(k);
    let (acc_g, miss_g) = rest.split_at_mut(k);
    match spec.kinds[pair % spec.m] {
        FeatureKind::Numeric => match spec.missing {
            MissingPolicy::AlwaysLeft => {
                scan_numeric_prefix(ph, spec, k, tot_g, acc_g, out)
            }
            MissingPolicy::Learn => {
                scan_numeric_learn(ph, spec, k, tot_g, acc_g, miss_g, out, dfl)
            }
        },
        FeatureKind::Categorical => {
            scan_categorical(ph, spec, k, tot_g, acc_g, miss_g, cat, out, dfl)
        }
    }
}

/// The classic prefix scan over all bins — the missing bin participates
/// as the smallest value (`MissingPolicy::AlwaysLeft`): a totals pass,
/// then the prefix scan emitting S(left) + S(right) per candidate.
/// `dfl` stays at its all-left initialization.
fn scan_numeric_prefix(
    ph: &[f32],
    spec: &ScanSpec,
    k: usize,
    tot_g: &mut [f64],
    acc_g: &mut [f64],
    out: &mut [f32],
) {
    let (bins, k1, lam, mode) = (spec.bins, spec.k1, spec.lam, spec.mode);
    tot_g.fill(0.0);
    let mut tot_d = 0.0f64;
    for b in 0..bins {
        let cell = &ph[b * k1..(b + 1) * k1];
        for c in 0..k {
            tot_g[c] += cell[c] as f64;
        }
        tot_d += denom_of(cell, k, k1, mode);
    }
    acc_g.fill(0.0);
    let mut acc_d = 0.0f64;
    for b in 0..bins {
        let cell = &ph[b * k1..(b + 1) * k1];
        for c in 0..k {
            acc_g[c] += cell[c] as f64;
        }
        acc_d += denom_of(cell, k, k1, mode);
        let mut s_left = 0.0f64;
        let mut s_right = 0.0f64;
        for c in 0..k {
            let l = acc_g[c];
            let r = tot_g[c] - l;
            s_left += l * l;
            s_right += r * r;
        }
        s_left /= acc_d + lam as f64;
        s_right /= (tot_d - acc_d) + lam as f64;
        out[b] = (s_left + s_right) as f32;
    }
}

/// Score one candidate with missing routed left and right (in that
/// order): `acc_*` are the non-missing left-side sums, `miss_*` the
/// missing bin's, `tot_*` the node totals. Shared by the numeric
/// learned-default scan and the categorical scan — and by the
/// `reference` oracle, so the leaf formula cannot drift between them
/// (the *scan structure* around it is what the oracle independently
/// recomputes).
#[allow(clippy::too_many_arguments)]
#[inline]
pub(crate) fn missing_direction_scores(
    acc_g: &[f64],
    miss_g: &[f64],
    tot_g: &[f64],
    acc_d: f64,
    miss_d: f64,
    tot_d: f64,
    lam: f64,
    k: usize,
) -> (f64, f64) {
    let mut sl = 0.0f64;
    let mut sr = 0.0f64;
    for c in 0..k {
        let l = acc_g[c] + miss_g[c];
        let r = tot_g[c] - l;
        sl += l * l;
        sr += r * r;
    }
    let ld = acc_d + miss_d;
    let gain_left = sl / (ld + lam) + sr / ((tot_d - ld) + lam);
    let mut sl2 = 0.0f64;
    let mut sr2 = 0.0f64;
    for c in 0..k {
        let l = acc_g[c];
        let r = tot_g[c] - l;
        sl2 += l * l;
        sr2 += r * r;
    }
    let gain_right = sl2 / (acc_d + lam) + sr2 / ((tot_d - acc_d) + lam);
    (gain_left, gain_right)
}

/// Shared prologue of the learned-default scans: node totals (f64 fold
/// over all bins, ascending — the canonical order the `reference`
/// oracle mirrors) into `tot_g`, the missing bin's channel sums into
/// `miss_g`; returns `(tot_d, miss_d)`. One implementation so the
/// numeric and categorical scans cannot drift apart.
fn node_totals(
    ph: &[f32],
    bins: usize,
    k1: usize,
    k: usize,
    mode: ScoreMode,
    tot_g: &mut [f64],
    miss_g: &mut [f64],
) -> (f64, f64) {
    tot_g.fill(0.0);
    let mut tot_d = 0.0f64;
    for b in 0..bins {
        let cell = &ph[b * k1..(b + 1) * k1];
        for c in 0..k {
            tot_g[c] += cell[c] as f64;
        }
        tot_d += denom_of(cell, k, k1, mode);
    }
    let mcell = &ph[0..k1];
    for c in 0..k {
        miss_g[c] = mcell[c] as f64;
    }
    (tot_d, denom_of(mcell, k, k1, mode))
}

/// XGBoost-style sparsity-aware numeric scan: prefix over the value
/// bins (1..bins), each candidate scored with the missing bin routed
/// left and right; the max wins and its direction lands in `dfl`. Ties
/// — including every NaN-free node, where both scores are bit-equal —
/// go left, preserving the legacy behavior exactly. Candidate 0 (left =
/// missing only) has no representable threshold and stays 0/left.
#[allow(clippy::too_many_arguments)]
fn scan_numeric_learn(
    ph: &[f32],
    spec: &ScanSpec,
    k: usize,
    tot_g: &mut [f64],
    acc_g: &mut [f64],
    miss_g: &mut [f64],
    out: &mut [f32],
    dfl: &mut [u8],
) {
    let (bins, k1, lam, mode) = (spec.bins, spec.k1, spec.lam as f64, spec.mode);
    let (tot_d, miss_d) = node_totals(ph, bins, k1, k, mode, tot_g, miss_g);
    acc_g.fill(0.0);
    let mut acc_d = 0.0f64;
    out[0] = 0.0;
    for b in 1..bins {
        let cell = &ph[b * k1..(b + 1) * k1];
        for c in 0..k {
            acc_g[c] += cell[c] as f64;
        }
        acc_d += denom_of(cell, k, k1, mode);
        let (gl, gr) = missing_direction_scores(
            acc_g, miss_g, tot_g, acc_d, miss_d, tot_d, lam, k,
        );
        if gl >= gr {
            out[b] = gl as f32;
            dfl[b] = 1;
        } else {
            out[b] = gr as f32;
            dfl[b] = 0;
        }
    }
}

/// LightGBM-style categorical scan: prefix over [`categorical_order`]'s
/// sorted categories; candidate `j` = "first j+1 sorted categories
/// left", scored with missing routed per policy (both directions under
/// `Learn`). Entries past the number of present categories stay 0.
#[allow(clippy::too_many_arguments)]
fn scan_categorical(
    ph: &[f32],
    spec: &ScanSpec,
    k: usize,
    tot_g: &mut [f64],
    acc_g: &mut [f64],
    miss_g: &mut [f64],
    cat: &mut CatScratch,
    out: &mut [f32],
    dfl: &mut [u8],
) {
    let (bins, k1, lam, mode) = (spec.bins, spec.k1, spec.lam as f64, spec.mode);
    categorical_order(ph, bins, k1, mode, spec.lam, cat);
    let (tot_d, miss_d) = node_totals(ph, bins, k1, k, mode, tot_g, miss_g);
    acc_g.fill(0.0);
    let mut acc_d = 0.0f64;
    for (j, &b) in cat.order.iter().enumerate() {
        let cell = &ph[b as usize * k1..(b as usize + 1) * k1];
        for c in 0..k {
            acc_g[c] += cell[c] as f64;
        }
        acc_d += denom_of(cell, k, k1, mode);
        let (gl, gr) = missing_direction_scores(
            acc_g, miss_g, tot_g, acc_d, miss_d, tot_d, lam, k,
        );
        match spec.missing {
            MissingPolicy::AlwaysLeft => {
                out[j] = gl as f32;
                dfl[j] = 1;
            }
            MissingPolicy::Learn => {
                if gl >= gr {
                    out[j] = gl as f32;
                    dfl[j] = 1;
                } else {
                    out[j] = gr as f32;
                    dfl[j] = 0;
                }
            }
        }
    }
}

/// Histogram pass dispatch: monomorphize the common channel widths so the
/// inner accumulation unrolls and vectorizes (k=1 scoring -> k1=2; k=5
/// default -> k1=6; HessL2 k=5 -> k1=11). `rows`/`chan_g` are one
/// segment (or a shard cut of one segment); `base` is the segment slot's
/// absolute slice offset into `out` — constant across the whole pass,
/// which is the payoff of range-based partitioning over the historical
/// per-row `slot_base` lookup.
pub(crate) fn hist_dispatch(
    binned: &BinnedDataset,
    rows: &[u32],
    chan_g: &[f32],
    k1: usize,
    base: usize,
    out: &mut [f32],
) {
    match k1 {
        2 => hist_pass::<2>(binned, rows, chan_g, base, out),
        3 => hist_pass::<3>(binned, rows, chan_g, base, out),
        6 => hist_pass::<6>(binned, rows, chan_g, base, out),
        11 => hist_pass::<11>(binned, rows, chan_g, base, out),
        _ => hist_pass_dyn(binned, rows, chan_g, k1, base, out),
    }
}

/// One histogram pass with a compile-time channel width.
fn hist_pass<const K1: usize>(
    binned: &BinnedDataset,
    rows: &[u32],
    chan_g: &[f32],
    base: usize,
    out: &mut [f32],
) {
    let m = binned.n_features;
    let bins = binned.max_bins;
    for f in 0..m {
        let col = binned.column(f);
        let fbase = base + f * bins * K1;
        for (j, &r) in rows.iter().enumerate() {
            debug_assert!((r as usize) < col.len(), "row index out of bounds");
            // SAFETY: `r` comes from the node's row-index partition,
            // which only holds indices `< n_rows == col.len()`; the
            // debug_assert above lets Miri/debug builds verify what
            // release elides.
            let b = unsafe { *col.get_unchecked(r as usize) } as usize;
            let dst = fbase + b * K1;
            let src = &chan_g[j * K1..j * K1 + K1];
            let out_s = &mut out[dst..dst + K1];
            for c in 0..K1 {
                out_s[c] += src[c];
            }
        }
    }
}

/// Fallback histogram pass for arbitrary channel widths (large-d Full
/// runs hit this path); zip-iterated so the compiler elides bounds
/// checks. (An explicit 8-wide blocked variant measured *slower* — see
/// EXPERIMENTS.md §Perf iteration log.)
fn hist_pass_dyn(
    binned: &BinnedDataset,
    rows: &[u32],
    chan_g: &[f32],
    k1: usize,
    base: usize,
    out: &mut [f32],
) {
    let m = binned.n_features;
    let bins = binned.max_bins;
    for f in 0..m {
        let col = binned.column(f);
        let fbase = base + f * bins * k1;
        for (j, &r) in rows.iter().enumerate() {
            let b = col[r as usize] as usize;
            let dst = fbase + b * k1;
            let src = &chan_g[j * k1..(j + 1) * k1];
            let out_s = &mut out[dst..dst + k1];
            for (o, &s) in out_s.iter_mut().zip(src.iter()) {
                *o += s;
            }
        }
    }
}

/// Chunked mirror of [`hist_dispatch`]: the same monomorphized channel
/// widths, reading codes from one resident [`ChunkCols`] instead of the
/// whole in-RAM column. `rows` must lie inside the chunk's row range.
/// Feature-outer / row-inner like the in-RAM pass, so per-cell addition
/// order is identical.
#[allow(clippy::too_many_arguments)]
pub(crate) fn hist_dispatch_chunk(
    cols: &ChunkCols<'_>,
    m: usize,
    bins: usize,
    rows: &[u32],
    chan_g: &[f32],
    k1: usize,
    base: usize,
    out: &mut [f32],
) {
    match k1 {
        2 => hist_chunk_pass::<2>(cols, m, bins, rows, chan_g, base, out),
        3 => hist_chunk_pass::<3>(cols, m, bins, rows, chan_g, base, out),
        6 => hist_chunk_pass::<6>(cols, m, bins, rows, chan_g, base, out),
        11 => hist_chunk_pass::<11>(cols, m, bins, rows, chan_g, base, out),
        _ => hist_chunk_pass_dyn(cols, m, bins, rows, chan_g, k1, base, out),
    }
}

/// One chunk histogram pass with a compile-time channel width.
fn hist_chunk_pass<const K1: usize>(
    cols: &ChunkCols<'_>,
    m: usize,
    bins: usize,
    rows: &[u32],
    chan_g: &[f32],
    base: usize,
    out: &mut [f32],
) {
    for f in 0..m {
        let col = cols.col(f);
        let fbase = base + f * bins * K1;
        for (j, &r) in rows.iter().enumerate() {
            let b = col[r as usize - cols.start] as usize;
            let dst = fbase + b * K1;
            let src = &chan_g[j * K1..j * K1 + K1];
            let out_s = &mut out[dst..dst + K1];
            for c in 0..K1 {
                out_s[c] += src[c];
            }
        }
    }
}

/// Fallback chunk histogram pass for arbitrary channel widths.
#[allow(clippy::too_many_arguments)]
fn hist_chunk_pass_dyn(
    cols: &ChunkCols<'_>,
    m: usize,
    bins: usize,
    rows: &[u32],
    chan_g: &[f32],
    k1: usize,
    base: usize,
    out: &mut [f32],
) {
    for f in 0..m {
        let col = cols.col(f);
        let fbase = base + f * bins * k1;
        for (j, &r) in rows.iter().enumerate() {
            let b = col[r as usize - cols.start] as usize;
            let dst = fbase + b * k1;
            let src = &chan_g[j * k1..(j + 1) * k1];
            let out_s = &mut out[dst..dst + k1];
            for (o, &s) in out_s.iter_mut().zip(src.iter()) {
                *o += s;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Dataset;
    use crate::util::proptest::{assert_close, run_prop};
    use crate::util::rng::Rng;

    fn softmax_ref(row: &[f32]) -> Vec<f32> {
        let mx = row.iter().fold(f32::MIN, |a, &b| a.max(b));
        let e: Vec<f32> = row.iter().map(|&z| (z - mx).exp()).collect();
        let s: f32 = e.iter().sum();
        e.iter().map(|&x| x / s).collect()
    }

    #[test]
    fn ce_grad_hess_matches_formula() {
        let mut eng = NativeEngine::new();
        let preds = vec![1.0f32, 2.0, 0.5, -1.0, 0.0, 3.0];
        let t = Targets::Multiclass { labels: vec![2, 0], n_classes: 3 };
        let mut g = vec![0.0f32; 6];
        let mut h = vec![0.0f32; 6];
        eng.grad_hess(LossKind::MulticlassCE, &preds, &t, &mut g, &mut h);
        for i in 0..2 {
            let p = softmax_ref(&preds[i * 3..(i + 1) * 3]);
            for j in 0..3 {
                let y = if (i == 0 && j == 2) || (i == 1 && j == 0) { 1.0 } else { 0.0 };
                assert!((g[i * 3 + j] - (p[j] - y)).abs() < 1e-6);
                assert!((h[i * 3 + j] - p[j] * (1.0 - p[j])).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn ce_gradient_rows_sum_to_zero() {
        run_prop("ce grad sums to 0", 20, |gen| {
            let n = gen.usize_in(1, 50);
            let d = gen.usize_in(2, 20);
            let preds = gen.vec_gaussian(n * d, 2.0);
            let labels = gen.vec_u32_below(n, d);
            let t = Targets::Multiclass { labels, n_classes: d };
            let mut g = vec![0.0f32; n * d];
            let mut h = vec![0.0f32; n * d];
            NativeEngine::new().grad_hess(LossKind::MulticlassCE, &preds, &t, &mut g, &mut h);
            for i in 0..n {
                let s: f32 = g[i * d..(i + 1) * d].iter().sum();
                assert!(s.abs() < 1e-4, "row {i} sums to {s}");
            }
            assert!(h.iter().all(|&x| x > 0.0 && x <= 0.25 + 1e-6));
        });
    }

    #[test]
    fn bce_and_mse_derivatives() {
        let mut eng = NativeEngine::new();
        let preds = vec![0.0f32, 2.0];
        let t = Targets::Multilabel { labels: vec![1.0, 0.0], n_labels: 2 };
        let mut g = vec![0.0f32; 2];
        let mut h = vec![0.0f32; 2];
        eng.grad_hess(LossKind::BCE, &preds, &t, &mut g, &mut h);
        assert!((g[0] - (0.5 - 1.0)).abs() < 1e-6);
        assert!((h[0] - 0.25).abs() < 1e-6);

        let t = Targets::Regression { values: vec![1.0, -1.0], n_targets: 2 };
        eng.grad_hess(LossKind::MSE, &[3.0, 1.0], &t, &mut g, &mut h);
        assert_close(&g, &[2.0, 2.0], 1e-6, 1e-6);
        assert_close(&h, &[1.0, 1.0], 1e-6, 1e-6);
    }

    #[test]
    #[should_panic]
    fn loss_target_mismatch_panics() {
        let t = Targets::Regression { values: vec![0.0], n_targets: 1 };
        NativeEngine::new().grad_hess(
            LossKind::MulticlassCE,
            &[0.0],
            &t,
            &mut [0.0],
            &mut [0.0],
        );
    }

    #[test]
    fn projection_matches_naive() {
        run_prop("native gemm", 20, |gen| {
            let n = gen.usize_in(1, 40);
            let d = gen.usize_in(1, 20);
            let k = gen.usize_in(1, 8);
            let g = gen.vec_gaussian(n * d, 1.0);
            let p = gen.vec_gaussian(d * k, 1.0);
            let mut out = vec![0.0f32; n * k];
            NativeEngine::new().sketch_project(&g, n, d, &p, k, &mut out);
            let mut want = vec![0.0f32; n * k];
            for i in 0..n {
                for c in 0..k {
                    let mut s = 0.0f64;
                    for j in 0..d {
                        s += g[i * d + j] as f64 * p[j * k + c] as f64;
                    }
                    want[i * k + c] = s as f32;
                }
            }
            assert_close(&out, &want, 1e-4, 1e-5);
        });
    }

    fn tiny_binned(n: usize, m: usize, bins: usize, seed: u64) -> BinnedDataset {
        let mut rng = Rng::new(seed);
        let mut feats = vec![0.0f32; n * m];
        rng.fill_gaussian(&mut feats, 1.0);
        let ds = Dataset::new(
            n,
            m,
            feats,
            Targets::Regression { values: vec![0.0; n], n_targets: 1 },
        );
        BinnedDataset::from_dataset(&ds, bins)
    }

    #[test]
    fn histogram_matches_naive() {
        run_prop("native hist", 15, |gen| {
            let n = gen.usize_in(10, 200);
            let m = gen.usize_in(1, 5);
            let bins = *gen.choose(&[4usize, 16, 64]);
            let slots = gen.usize_in(1, 4);
            let k1 = gen.usize_in(2, 5);
            let binned = tiny_binned(n, m, bins, gen.seed);
            let slot_of_row = gen.vec_u32_below(n, slots);
            let mut chan = gen.vec_gaussian(n * k1, 1.0);
            for i in 0..n {
                chan[i * k1 + k1 - 1] = 1.0;
            }
            let rows: Vec<u32> = (0..n as u32).filter(|&r| r % 3 != 2).collect();
            let (prows, pchan, segs) =
                crate::engine::reference::partition_inputs(&rows, &slot_of_row, &chan, k1, slots);
            let mut out = vec![0.0f32; slots * m * bins * k1];
            NativeEngine::new().histograms(&binned, &prows, &pchan, k1, &segs, slots, &mut out);
            let mut want = vec![0.0f32; slots * m * bins * k1];
            for &r in &rows {
                let r = r as usize;
                let slot = slot_of_row[r] as usize;
                for f in 0..m {
                    let b = binned.column(f)[r] as usize;
                    let base = ((slot * m + f) * bins + b) * k1;
                    for c in 0..k1 {
                        want[base + c] += chan[r * k1 + c];
                    }
                }
            }
            assert_close(&out, &want, 1e-4, 1e-4);
        });
    }

    #[test]
    fn histogram_count_channel_totals_rows() {
        let n = 100;
        let binned = tiny_binned(n, 2, 8, 1);
        let k1 = 3;
        let mut chan = vec![0.5f32; n * k1];
        for i in 0..n {
            chan[i * k1 + 2] = 1.0;
        }
        let rows: Vec<u32> = (0..n as u32).collect();
        let segs = [SlotRange::new(0, 0, n as u32)];
        let mut out = vec![0.0f32; 2 * 8 * k1];
        NativeEngine::new().histograms(&binned, &rows, &chan, k1, &segs, 1, &mut out);
        for f in 0..2 {
            let total: f32 = (0..8).map(|b| out[(f * 8 + b) * k1 + 2]).sum();
            assert!((total - n as f32).abs() < 1e-4);
        }
    }

    #[test]
    fn histogram_skips_slots_outside_segments() {
        // sibling subtraction passes only the small children: untouched
        // slots must stay exactly zero
        let n = 60;
        let binned = tiny_binned(n, 2, 8, 3);
        let k1 = 2;
        let chan = vec![1.0f32; n * k1];
        let rows: Vec<u32> = (0..n as u32).collect();
        // only slot 1 of 3 gets accumulated, from rows 10..40
        let segs = [SlotRange::new(1, 10, 40)];
        let slice = 2 * 8 * k1;
        let mut out = vec![0.0f32; 3 * slice];
        NativeEngine::new().histograms(&binned, &rows, &chan, k1, &segs, 3, &mut out);
        assert!(out[..slice].iter().all(|&v| v == 0.0), "slot 0 untouched");
        assert!(out[2 * slice..].iter().all(|&v| v == 0.0), "slot 2 untouched");
        let total: f32 = (0..8).map(|b| out[slice + b * k1 + 1]).sum();
        assert!((total - 30.0).abs() < 1e-4, "slot 1 holds its 30 rows");
    }

    #[test]
    fn align_shard_cuts_partitions_by_merged_rank() {
        // two ascending segments with interleaved row ids
        let rows: Vec<u32> = vec![0, 2, 4, 6, 8, 10, 1, 3, 5, 7, 9, 11];
        let segs = [SlotRange::new(0, 0, 6), SlotRange::new(1, 6, 12)];
        let nr = 12;
        let n_shards = 3;
        let mut cuts = Vec::new();
        align_shard_cuts(&rows, &segs, nr, n_shards, &mut cuts);
        // shard boundaries at merged ranks 4 and 8 = row-id thresholds 4, 8
        // segment 0 (evens): ids 0,2 < 4 -> cut at pos 2; 0,2,4,6 < 8 -> 4
        // segment 1 (odds):  ids 1,3 < 4 -> cut at pos 8; 1,3,5,7 < 8 -> 10
        assert_eq!(&cuts[0..2], &[0, 6]); // shard 0 starts
        assert_eq!(&cuts[2..4], &[2, 8]); // shard 1 starts
        assert_eq!(&cuts[4..6], &[4, 10]); // shard 2 starts
        assert_eq!(&cuts[6..8], &[6, 12]); // ends
        // every shard covers shard_bounds-many rows in total
        for s in 0..n_shards {
            let (a, b) = shard_bounds(nr, n_shards, s);
            let covered: usize = (0..2)
                .map(|t| (cuts[(s + 1) * 2 + t] - cuts[s * 2 + t]) as usize)
                .sum();
            assert_eq!(covered, b - a, "shard {s}");
        }
    }

    /// Scan spec over all-numeric features with the legacy missing
    /// policy — the shape under which the classic prefix-scan tests
    /// below stay valid verbatim.
    fn legacy_spec(
        n_slots: usize,
        m: usize,
        bins: usize,
        k1: usize,
        lam: f32,
        mode: ScoreMode,
        kinds: &[FeatureKind],
    ) -> ScanSpec<'_> {
        ScanSpec {
            n_slots,
            m,
            bins,
            k1,
            lam,
            mode,
            kinds,
            missing: MissingPolicy::AlwaysLeft,
        }
    }

    #[test]
    fn split_gains_match_scalar_reference() {
        run_prop("native gains", 15, |gen| {
            let slots = gen.usize_in(1, 3);
            let m = gen.usize_in(1, 3);
            let bins = *gen.choose(&[2usize, 8, 16]);
            let k = gen.usize_in(1, 4);
            let lam = *gen.choose(&[0.5f32, 1.0, 5.0]);
            let k1 = k + 1;
            let mut hist = gen.vec_gaussian(slots * m * bins * k1, 1.0);
            // counts >= 0
            for s in 0..slots {
                for f in 0..m {
                    for b in 0..bins {
                        let i = ((s * m + f) * bins + b) * k1 + k;
                        hist[i] = gen.usize_in(0, 30) as f32;
                    }
                }
            }
            let kinds = vec![FeatureKind::Numeric; m];
            let mut gains = Vec::new();
            let mut dfl = Vec::new();
            NativeEngine::new().split_gains(
                &hist,
                &legacy_spec(slots, m, bins, k1, lam, ScoreMode::CountL2, &kinds),
                &mut gains,
                &mut dfl,
            );
            assert!(dfl.iter().all(|&d| d == 1), "AlwaysLeft fills defaults left");
            // scalar reference
            for s in 0..slots {
                for f in 0..m {
                    let base = (s * m + f) * bins * k1;
                    for b in 0..bins {
                        let mut gl = vec![0.0f64; k];
                        let mut cl = 0.0f64;
                        let mut gt = vec![0.0f64; k];
                        let mut ct = 0.0f64;
                        for bb in 0..bins {
                            for c in 0..k {
                                let v = hist[base + bb * k1 + c] as f64;
                                gt[c] += v;
                                if bb <= b {
                                    gl[c] += v;
                                }
                            }
                            ct += hist[base + bb * k1 + k] as f64;
                            if bb <= b {
                                cl += hist[base + bb * k1 + k] as f64;
                            }
                        }
                        let sl: f64 =
                            gl.iter().map(|x| x * x).sum::<f64>() / (cl + lam as f64);
                        let sr: f64 = gl
                            .iter()
                            .zip(gt.iter())
                            .map(|(l, t)| (t - l) * (t - l))
                            .sum::<f64>()
                            / ((ct - cl) + lam as f64);
                        let want = (sl + sr) as f32;
                        let got = gains[(s * m + f) * bins + b];
                        assert!(
                            (got - want).abs() <= 1e-3 + 1e-3 * want.abs(),
                            "slot {s} f {f} b {b}: {got} vs {want}"
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn hess_mode_uses_hessian_denominator() {
        // one slot, one feature, two bins, k=1: g channels [1, 3],
        // h channels [2, 4], counts [10, 10], lam = 1
        let k1 = 3;
        let hist = vec![
            1.0, 2.0, 10.0, // bin 0: g=1 h=2 count=10
            3.0, 4.0, 10.0, // bin 1
        ];
        let kinds = [FeatureKind::Numeric];
        let mut gains = Vec::new();
        let mut dfl = Vec::new();
        NativeEngine::new().split_gains(
            &hist,
            &legacy_spec(1, 1, 2, k1, 1.0, ScoreMode::HessL2, &kinds),
            &mut gains,
            &mut dfl,
        );
        // split at b=0: left g=1 h=2 -> 1/(2+1); right g=3 h=4 -> 9/(4+1)
        let want0 = 1.0 / 3.0 + 9.0 / 5.0;
        assert!((gains[0] - want0).abs() < 1e-5, "{} vs {want0}", gains[0]);
    }

    #[test]
    fn learned_defaults_match_always_left_on_nan_free_histograms() {
        // with an empty missing bin the learned-default scan must emit
        // bit-identical gains to the legacy prefix scan (shifted
        // semantics coincide) and default every candidate left
        run_prop("learn == left when no missing", 15, |gen| {
            let slots = gen.usize_in(1, 3);
            let m = gen.usize_in(1, 3);
            let bins = *gen.choose(&[4usize, 8, 16]);
            let k = gen.usize_in(1, 4);
            let k1 = k + 1;
            let mut hist = gen.vec_gaussian(slots * m * bins * k1, 1.0);
            for s in 0..slots {
                for f in 0..m {
                    for b in 0..bins {
                        let cell = ((s * m + f) * bins + b) * k1;
                        hist[cell + k] = gen.usize_in(1, 20) as f32;
                        if b == 0 {
                            // empty missing bin
                            hist[cell..cell + k1].fill(0.0);
                        }
                    }
                }
            }
            let kinds = vec![FeatureKind::Numeric; m];
            let mut spec = legacy_spec(slots, m, bins, k1, 1.0, ScoreMode::CountL2, &kinds);
            let mut legacy = Vec::new();
            let mut d0 = Vec::new();
            NativeEngine::new().split_gains(&hist, &spec, &mut legacy, &mut d0);
            spec.missing = MissingPolicy::Learn;
            let mut learned = Vec::new();
            let mut d1 = Vec::new();
            NativeEngine::new().split_gains(&hist, &spec, &mut learned, &mut d1);
            assert!(d1.iter().all(|&d| d == 1), "ties must default left");
            for pair in 0..slots * m {
                for b in 1..bins {
                    assert_eq!(
                        learned[pair * bins + b],
                        legacy[pair * bins + b],
                        "pair {pair} candidate {b}"
                    );
                }
                assert_eq!(learned[pair * bins], 0.0, "candidate 0 is invalid");
            }
        });
    }

    #[test]
    fn learned_default_picks_the_better_direction() {
        // one feature, 3 bins (0 = missing), k = 1, lam = 1.
        // missing: g=+4, cnt 4; bin1: g=+4, cnt 4; bin2: g=-8, cnt 8.
        // candidate b=1 (left = bin1): missing belongs with the positive
        // gradients on the left.
        let k1 = 2;
        let hist = vec![
            4.0, 4.0, // missing
            4.0, 4.0, // bin 1
            -8.0, 8.0, // bin 2
        ];
        let kinds = [FeatureKind::Numeric];
        let spec = ScanSpec {
            n_slots: 1,
            m: 1,
            bins: 3,
            k1,
            lam: 1.0,
            mode: ScoreMode::CountL2,
            kinds: &kinds,
            missing: MissingPolicy::Learn,
        };
        let mut gains = Vec::new();
        let mut dfl = Vec::new();
        NativeEngine::new().split_gains(&hist, &spec, &mut gains, &mut dfl);
        // missing left:  left g=8 cnt 8 -> 64/9;  right g=-8 cnt 8 -> 64/9
        // missing right: left g=4 cnt 4 -> 16/5; right g=-4 cnt 12 -> 16/13
        let want_left = 64.0 / 9.0 + 64.0 / 9.0;
        assert_eq!(dfl[1], 1, "missing must default left here");
        assert!((gains[1] as f64 - want_left).abs() < 1e-4, "{}", gains[1]);

        // flip the missing gradient: now it belongs right
        let hist2 = vec![
            -4.0, 4.0, // missing
            4.0, 4.0, //
            -8.0, 8.0, //
        ];
        NativeEngine::new().split_gains(&hist2, &spec, &mut gains, &mut dfl);
        // missing right: left g=4 cnt 4 -> 16/5; right g=-12 cnt 12 -> 144/13
        // missing left:  left g=0 cnt 8 -> 0;    right g=-8 cnt 8 -> 64/9
        assert_eq!(dfl[1], 0, "missing must default right here");
        let want_right = 16.0 / 5.0 + 144.0 / 13.0;
        assert!((gains[1] as f64 - want_right).abs() < 1e-4, "{}", gains[1]);
    }

    #[test]
    fn categorical_scan_scores_sorted_prefixes() {
        // one categorical feature, 4 bins (0 = missing, empty), k = 1:
        // cat ids 0..=2 at bins 1..=3 with g = [+6, -6, +2], cnt 4 each.
        // order by stat(c) = g_c / (cnt + lam):
        // bin1 (6/5) > bin3 (2/5) > bin2 (-6/5).
        let k1 = 2;
        let hist = vec![
            0.0, 0.0, // missing
            6.0, 4.0, // bin 1
            -6.0, 4.0, // bin 2
            2.0, 4.0, // bin 3
        ];
        let kinds = [FeatureKind::Categorical];
        let spec = ScanSpec {
            n_slots: 1,
            m: 1,
            bins: 4,
            k1,
            lam: 1.0,
            mode: ScoreMode::CountL2,
            kinds: &kinds,
            missing: MissingPolicy::Learn,
        };
        let mut gains = Vec::new();
        let mut dfl = Vec::new();
        NativeEngine::new().split_gains(&hist, &spec, &mut gains, &mut dfl);
        // candidate 0: left = {bin1}: 36/5 + 16/9
        let want0 = 36.0 / 5.0 + 16.0 / 9.0;
        assert!((gains[0] as f64 - want0).abs() < 1e-4, "{}", gains[0]);
        // candidate 1: left = {bin1, bin3}: 64/9 + 36/5
        let want1 = 64.0 / 9.0 + 36.0 / 5.0;
        assert!((gains[1] as f64 - want1).abs() < 1e-4, "{}", gains[1]);
        // candidate 2 = all cats left (right would be empty) and the
        // padding stay in the buffer but are never admissible; padding = 0
        assert_eq!(gains[3], 0.0);
        // the best candidate isolates {bin1, bin3} — a category set that
        // is NOT contiguous in id order, which an ordinal scan cannot hit
        assert!(gains[1] > gains[0]);
    }

    #[test]
    fn sharded_histograms_bit_identical_across_thread_counts() {
        // enough rows that hist_shards() actually splits the work
        let n = 3 * SHARD_TARGET_ROWS;
        let (m, bins, slots, k1) = (3usize, 16usize, 2usize, 3usize);
        let binned = tiny_binned(n, m, bins, 5);
        let mut rng = Rng::new(9);
        let slot_of_row: Vec<u32> = (0..n).map(|_| rng.next_below(slots) as u32).collect();
        let mut chan = vec![0.0f32; n * k1];
        rng.fill_gaussian(&mut chan, 1.0);
        for i in 0..n {
            chan[i * k1 + k1 - 1] = 1.0;
        }
        let rows: Vec<u32> = (0..n as u32).filter(|&r| r % 7 != 6).collect();
        assert!(hist_shards(rows.len(), slots * bins) >= 2, "test must exercise sharding");
        let (prows, pchan, segs) =
            crate::engine::reference::partition_inputs(&rows, &slot_of_row, &chan, k1, slots);

        let size = slots * m * bins * k1;
        let mut base = vec![0.0f32; size];
        NativeEngine::with_threads(1)
            .histograms(&binned, &prows, &pchan, k1, &segs, slots, &mut base);
        for t in [2usize, 4, 8] {
            let mut out = vec![0.0f32; size];
            NativeEngine::with_threads(t)
                .histograms(&binned, &prows, &pchan, k1, &segs, slots, &mut out);
            assert_eq!(out, base, "threads = {t}"); // bitwise, not approximate
        }

        // the sharded result is still the right histogram
        let mut want = vec![0.0f32; size];
        for &r in &rows {
            let r = r as usize;
            let slot = slot_of_row[r] as usize;
            for f in 0..m {
                let b = binned.column(f)[r] as usize;
                let cell = ((slot * m + f) * bins + b) * k1;
                for c in 0..k1 {
                    want[cell + c] += chan[r * k1 + c];
                }
            }
        }
        assert_close(&base, &want, 1e-3, 1e-3);
    }

    /// Test-only chunked source: serves a [`BinnedDataset`] in fixed-size
    /// row chunks (materializing each chunk's column-major slab on
    /// demand), with `as_in_ram()` disabled so the engine takes the real
    /// chunked path.
    struct FakeChunks {
        b: BinnedDataset,
        chunk: usize,
    }

    impl BinnedSource for FakeChunks {
        fn n_rows(&self) -> usize {
            self.b.n_rows
        }
        fn n_features(&self) -> usize {
            self.b.n_features
        }
        fn max_bins(&self) -> usize {
            self.b.max_bins
        }
        fn kinds(&self) -> &[FeatureKind] {
            &self.b.kinds
        }
        fn threshold_value(&self, f: usize, b: usize) -> f32 {
            self.b.threshold_value(f, b)
        }
        fn n_chunks(&self) -> usize {
            (self.b.n_rows + self.chunk - 1) / self.chunk
        }
        fn chunk_range(&self, c: usize) -> std::ops::Range<usize> {
            let start = c * self.chunk;
            start..(start + self.chunk).min(self.b.n_rows)
        }
        fn with_chunk(&self, c: usize, body: &mut dyn FnMut(ChunkCols<'_>)) {
            let r = self.chunk_range(c);
            let len = r.len();
            let mut codes = vec![0u8; self.b.n_features * len];
            for f in 0..self.b.n_features {
                codes[f * len..(f + 1) * len]
                    .copy_from_slice(&self.b.column(f)[r.start..r.end]);
            }
            body(ChunkCols { codes: &codes, start: r.start, len });
        }
    }

    #[test]
    fn chunked_histograms_bit_identical_to_in_ram() {
        // chunk plans {1 chunk, ragged tail, 1-row chunks} x thread
        // counts, against the in-RAM fast path — bitwise
        let n = 2 * SHARD_TARGET_ROWS + 57;
        let (m, bins, slots, k1) = (4usize, 16usize, 3usize, 3usize);
        let binned = tiny_binned(n, m, bins, 13);
        let mut rng = Rng::new(21);
        let slot_of_row: Vec<u32> = (0..n).map(|_| rng.next_below(slots) as u32).collect();
        let mut chan = vec![0.0f32; n * k1];
        rng.fill_gaussian(&mut chan, 1.0);
        for i in 0..n {
            chan[i * k1 + k1 - 1] = 1.0;
        }
        let rows: Vec<u32> = (0..n as u32).filter(|&r| r % 5 != 4).collect();
        let (prows, pchan, segs) =
            crate::engine::reference::partition_inputs(&rows, &slot_of_row, &chan, k1, slots);
        let size = slots * m * bins * k1;
        let mut want = vec![0.0f32; size];
        NativeEngine::with_threads(1)
            .histograms(&binned, &prows, &pchan, k1, &segs, slots, &mut want);
        for chunk in [n, 1000, 1] {
            let src = FakeChunks { b: binned.clone(), chunk };
            for t in [1usize, 2, 4] {
                let mut got = vec![0.0f32; size];
                NativeEngine::with_threads(t)
                    .histograms(&src, &prows, &pchan, k1, &segs, slots, &mut got);
                assert_eq!(got, want, "chunk={chunk} threads={t}");
            }
        }
    }

    #[test]
    fn split_gains_bit_identical_across_thread_counts() {
        // big enough (hist.len() >= 16k) to take the parallel branch;
        // mixed feature kinds + learned defaults to cover every scan
        let (slots, m, bins, k1) = (8usize, 8usize, 64usize, 4usize);
        let mut rng = Rng::new(11);
        let mut hist = vec![0.0f32; slots * m * bins * k1];
        rng.fill_gaussian(&mut hist, 1.0);
        for cell in 0..slots * m * bins {
            hist[cell * k1 + k1 - 1] = rng.next_below(30) as f32;
        }
        let kinds: Vec<FeatureKind> = (0..m)
            .map(|f| if f % 3 == 0 { FeatureKind::Categorical } else { FeatureKind::Numeric })
            .collect();
        let spec = ScanSpec {
            n_slots: slots,
            m,
            bins,
            k1,
            lam: 1.0,
            mode: ScoreMode::CountL2,
            kinds: &kinds,
            missing: MissingPolicy::Learn,
        };
        let mut base = Vec::new();
        let mut base_d = Vec::new();
        NativeEngine::with_threads(1).split_gains(&hist, &spec, &mut base, &mut base_d);
        for t in [2usize, 4] {
            let mut got = Vec::new();
            let mut got_d = Vec::new();
            NativeEngine::with_threads(t).split_gains(&hist, &spec, &mut got, &mut got_d);
            assert_eq!(got, base, "threads = {t}");
            assert_eq!(got_d, base_d, "threads = {t} defaults");
        }
    }

    #[test]
    fn hist_shards_ignores_thread_count_and_caps_reduction() {
        // pure in (rows, shape): small inputs stay serial
        assert_eq!(hist_shards(100, 64), 1);
        assert_eq!(hist_shards(2 * SHARD_TARGET_ROWS, 8), 2);
        // wide frontiers bound the shard count to keep reduction cheap
        assert!(hist_shards(20_000, 32 * 64) <= 20_000 / (4 * 32 * 64) + 1);
        // and the cap holds
        assert!(hist_shards(10_000_000, 8) <= MAX_SHARDS);
    }

    #[test]
    fn leaf_sums_accumulate() {
        let rows = vec![0u32, 1, 2, 3];
        let leaf_of_row = vec![1u32, 0, 1, 0];
        let g = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]; // d=2
        let h = vec![0.1f32; 8];
        let mut s = LeafSums::new();
        NativeEngine::new().leaf_sums(&rows, &leaf_of_row, &g, &h, 2, 2, &mut s);
        assert_close(&s.gsum, &[3.0 + 7.0, 4.0 + 8.0, 1.0 + 5.0, 2.0 + 6.0], 1e-6, 1e-6);
        assert_close(&s.count, &[2.0, 2.0], 1e-6, 1e-6);
        assert!((s.hsum[0] - 0.2).abs() < 1e-6);
    }
}
