//! Pure-rust compute engine — the performance path.
//!
//! Numerics are defined by `python/compile/kernels/ref.py`; this file
//! reimplements them with cache-conscious loops. The integration tests
//! cross-check every op against the XLA artifacts compiled from the JAX
//! reference, so drift is caught mechanically.
//!
//! ## Parallel execution
//!
//! The two dominant per-level costs — histogram accumulation and the
//! split-gain scan (`benches/hot_paths.rs`) — run on an internal
//! [`ThreadPool`]:
//!
//! * **Histograms** partition the active rows into *shards* whose count
//!   and boundaries depend only on the row count and histogram shape
//!   ([`hist_shards`], [`shard_bounds`]) — never on the thread count.
//!   Workers accumulate each shard into a thread-local buffer, then
//!   [`reduce_shards`] adds the shards into the output in ascending shard
//!   order, parallel across cells. Because both the partition and the
//!   per-cell addition order are fixed, the result is bit-identical for
//!   any `n_threads` (f32 addition is non-associative, so this is the
//!   property that keeps `seed`-reproducibility intact).
//! * **Split scan** fans `(slot, feature)` pairs out over a chunked work
//!   queue; each pair writes its own disjoint `bins`-wide gain range and
//!   is a pure function of the histogram, so determinism is free.
//!
//! Everything else (derivatives, gemm, leaf sums) stays serial — those
//! ops are O(n·d) streams that the trainer amortizes, and the profile in
//! EXPERIMENTS.md §Perf shows them off the critical path.

use crate::boosting::losses::LossKind;
use crate::data::binning::BinnedDataset;
use crate::data::dataset::Targets;
use crate::util::threading::{reduce_shards, shard_bounds, DisjointSlice, ThreadPool};

use super::{ComputeEngine, EngineOpts, LeafSums, ScoreMode};

/// Rows per histogram shard (below 2·this, the build stays serial).
const SHARD_TARGET_ROWS: usize = 2048;
/// Upper bound on shards, i.e. on usable histogram parallelism.
const MAX_SHARDS: usize = 16;

/// Number of histogram shards for `nr` active rows and a per-slot scan
/// width of `slots_bins = n_slots * bins` cells. Pure in its inputs (and
/// in particular independent of the thread count — see module docs):
/// bounded so each shard keeps >= [`SHARD_TARGET_ROWS`] rows and so the
/// deterministic reduction costs at most ~25% of the accumulation pass.
fn hist_shards(nr: usize, slots_bins: usize) -> usize {
    let by_rows = nr / SHARD_TARGET_ROWS;
    let by_reduce = nr / (4 * slots_bins).max(1);
    by_rows.min(by_reduce).clamp(1, MAX_SHARDS)
}

/// Pure-rust engine. Stateless apart from scratch reuse.
#[derive(Default)]
pub struct NativeEngine {
    pool: ThreadPool,
    /// scratch: per-level gathered channel rows (see `histograms`)
    scratch_chan: Vec<f32>,
    /// scratch: thread-local histogram shards, reduced deterministically
    scratch_shards: Vec<f32>,
}

impl NativeEngine {
    /// Serial engine (`EngineOpts::default()`).
    pub fn new() -> Self {
        NativeEngine::default()
    }

    /// Engine with explicit options (thread count).
    pub fn with_opts(opts: EngineOpts) -> Self {
        NativeEngine { pool: ThreadPool::new(opts.n_threads), ..NativeEngine::default() }
    }

    /// Engine with an explicit thread count (`0` = all cores).
    pub fn with_threads(n_threads: usize) -> Self {
        NativeEngine::with_opts(EngineOpts::threads(n_threads))
    }

    pub fn n_threads(&self) -> usize {
        self.pool.n_threads()
    }
}

impl ComputeEngine for NativeEngine {
    fn name(&self) -> &'static str {
        "native"
    }

    fn grad_hess(
        &mut self,
        loss: LossKind,
        preds: &[f32],
        targets: &Targets,
        g: &mut [f32],
        h: &mut [f32],
    ) {
        match (loss, targets) {
            (LossKind::MulticlassCE, Targets::Multiclass { labels, n_classes }) => {
                let d = *n_classes;
                let n = labels.len();
                debug_assert_eq!(preds.len(), n * d);
                for i in 0..n {
                    let row = &preds[i * d..(i + 1) * d];
                    let gi = &mut g[i * d..(i + 1) * d];
                    let hi = &mut h[i * d..(i + 1) * d];
                    // numerically stable softmax
                    let mut mx = f32::MIN;
                    for &z in row {
                        mx = mx.max(z);
                    }
                    let mut sum = 0.0f32;
                    for (j, &z) in row.iter().enumerate() {
                        let e = (z - mx).exp();
                        gi[j] = e;
                        sum += e;
                    }
                    let inv = 1.0 / sum;
                    for j in 0..d {
                        let p = gi[j] * inv;
                        gi[j] = p;
                        hi[j] = p * (1.0 - p);
                    }
                    gi[labels[i] as usize] -= 1.0;
                }
            }
            (LossKind::BCE, Targets::Multilabel { labels, n_labels }) => {
                let total = labels.len();
                debug_assert_eq!(preds.len(), total);
                debug_assert_eq!(total % n_labels, 0);
                for i in 0..total {
                    let p = 1.0 / (1.0 + (-preds[i]).exp());
                    g[i] = p - labels[i];
                    h[i] = p * (1.0 - p);
                }
            }
            (LossKind::MSE, Targets::Regression { values, .. }) => {
                debug_assert_eq!(preds.len(), values.len());
                for i in 0..values.len() {
                    g[i] = preds[i] - values[i];
                    h[i] = 1.0;
                }
            }
            (l, t) => panic!("loss {:?} incompatible with targets {:?}", l, kind_name(t)),
        }
    }

    fn sketch_project(
        &mut self,
        g_mat: &[f32],
        n: usize,
        d: usize,
        proj: &[f32],
        k: usize,
        out: &mut [f32],
    ) {
        debug_assert_eq!(g_mat.len(), n * d);
        debug_assert_eq!(proj.len(), d * k);
        debug_assert_eq!(out.len(), n * k);
        // monomorphized accumulator-in-registers kernels for the paper's
        // k grid; generic fallback otherwise (EXPERIMENTS.md §Perf)
        match k {
            1 => gemm_k::<1>(g_mat, n, d, proj, out),
            2 => gemm_k::<2>(g_mat, n, d, proj, out),
            5 => gemm_k::<5>(g_mat, n, d, proj, out),
            10 => gemm_k::<10>(g_mat, n, d, proj, out),
            20 => gemm_k::<20>(g_mat, n, d, proj, out),
            _ => gemm_dyn(g_mat, n, d, proj, k, out),
        }
    }

    fn histograms(
        &mut self,
        binned: &BinnedDataset,
        rows: &[u32],
        slot_of_row: &[u32],
        chan: &[f32],
        k1: usize,
        n_slots: usize,
        out: &mut [f32],
    ) {
        let n = binned.n_rows;
        let m = binned.n_features;
        let bins = binned.max_bins;
        debug_assert_eq!(out.len(), n_slots * m * bins * k1);
        debug_assert_eq!(chan.len(), n * k1);

        // Gather channel rows and the per-row histogram slice base once
        // into compact buffers so the per-feature pass streams
        // sequentially instead of chasing `rows` indirection through the
        // full [n, k1] matrix m times (perf log in EXPERIMENTS.md §Perf).
        let nr = rows.len();
        self.scratch_chan.clear();
        self.scratch_chan.resize(nr * k1, 0.0);
        let mut slot_base = Vec::with_capacity(nr);
        let slice = m * bins * k1;
        for (j, &r) in rows.iter().enumerate() {
            let r = r as usize;
            self.scratch_chan[j * k1..(j + 1) * k1]
                .copy_from_slice(&chan[r * k1..(r + 1) * k1]);
            slot_base.push(slot_of_row[r] as usize * slice);
        }
        let n_shards = hist_shards(nr, n_slots * bins);
        if n_shards == 1 {
            // small level: one serial pass straight into `out` (also the
            // historical path — sharding only ever changes results when
            // it actually splits the rows)
            hist_dispatch(binned, rows, &slot_base, &self.scratch_chan, k1, out);
            return;
        }

        // Thread-local shards over a fixed row partition, then a
        // deterministic ascending-order reduction (module docs).
        let total = out.len();
        self.scratch_shards.clear();
        self.scratch_shards.resize(n_shards * total, 0.0);
        let pool = &self.pool;
        let chan_g = &self.scratch_chan;
        let shard_bufs = DisjointSlice::new(&mut self.scratch_shards);
        pool.for_each_chunk(n_shards, 1, |shard_range| {
            for s in shard_range {
                // Safety: shard `s`'s buffer is written by exactly one
                // worker (the queue hands out each shard index once).
                let buf = unsafe { shard_bufs.range_mut(s * total..(s + 1) * total) };
                buf.fill(0.0);
                let (j0, j1) = shard_bounds(nr, n_shards, s);
                hist_dispatch(
                    binned,
                    &rows[j0..j1],
                    &slot_base[j0..j1],
                    &chan_g[j0 * k1..j1 * k1],
                    k1,
                    buf,
                );
            }
        });
        reduce_shards(pool, &self.scratch_shards, n_shards, out);
    }

    fn split_gains(
        &mut self,
        hist: &[f32],
        n_slots: usize,
        m: usize,
        bins: usize,
        k1: usize,
        lam: f32,
        mode: ScoreMode,
    ) -> Vec<f32> {
        let k = match mode {
            ScoreMode::CountL2 => k1 - 1,
            ScoreMode::HessL2 => (k1 - 1) / 2,
        };
        let mut gains = vec![0.0f32; n_slots * m * bins];
        let n_pairs = n_slots * m;
        if n_pairs == 0 || bins == 0 {
            return gains;
        }
        // Chunked queue over (slot, feature) pairs. Each pair is a pure
        // function of `hist` writing its own disjoint `bins`-wide range,
        // so the scan is deterministic for any thread count; the queue
        // only balances load. A whole-scan chunk routes tiny frontiers
        // (deep levels, small datasets) through the pool's inline serial
        // path — thread spawns would cost more than the scan itself.
        const PAIR_CHUNK: usize = 8;
        let chunk = if hist.len() < 16 * 1024 { n_pairs } else { PAIR_CHUNK };
        let out = DisjointSlice::new(&mut gains);
        self.pool.for_each_chunk(n_pairs, chunk, |pairs| {
            // per-chunk f64 scratch: k <= ~2d+1, negligible next to the
            // bins-wide scans it serves
            let mut tot_g = vec![0.0f64; k];
            let mut acc_g = vec![0.0f64; k];
            for pair in pairs {
                // Safety: pair ranges are disjoint and the queue hands
                // each pair index to exactly one worker.
                let dst = unsafe { out.range_mut(pair * bins..(pair + 1) * bins) };
                scan_pair(hist, pair, bins, k1, k, lam, mode, &mut tot_g, &mut acc_g, dst);
            }
        });
        gains
    }

    fn leaf_sums(
        &mut self,
        rows: &[u32],
        leaf_of_row: &[u32],
        g: &[f32],
        h: &[f32],
        d: usize,
        n_leaves: usize,
    ) -> LeafSums {
        let mut gsum = vec![0.0f32; n_leaves * d];
        let mut hsum = vec![0.0f32; n_leaves * d];
        let mut count = vec![0.0f32; n_leaves];
        for &r in rows {
            let r = r as usize;
            let leaf = leaf_of_row[r] as usize;
            debug_assert!(leaf < n_leaves);
            count[leaf] += 1.0;
            let gs = &mut gsum[leaf * d..(leaf + 1) * d];
            let gr = &g[r * d..(r + 1) * d];
            for c in 0..d {
                gs[c] += gr[c];
            }
            let hs = &mut hsum[leaf * d..(leaf + 1) * d];
            let hr = &h[r * d..(r + 1) * d];
            for c in 0..d {
                hs[c] += hr[c];
            }
        }
        LeafSums { gsum, hsum, count }
    }
}

/// Projection gemm with a compile-time k: the K accumulators live in
/// registers across the full d-loop instead of round-tripping memory.
fn gemm_k<const K: usize>(g_mat: &[f32], n: usize, d: usize, proj: &[f32], out: &mut [f32]) {
    for i in 0..n {
        let mut acc = [0.0f32; K];
        let gi = &g_mat[i * d..(i + 1) * d];
        for (j, &gv) in gi.iter().enumerate() {
            let pj = &proj[j * K..j * K + K];
            for c in 0..K {
                acc[c] += gv * pj[c];
            }
        }
        out[i * K..(i + 1) * K].copy_from_slice(&acc);
    }
}

/// Generic projection gemm fallback.
fn gemm_dyn(g_mat: &[f32], n: usize, d: usize, proj: &[f32], k: usize, out: &mut [f32]) {
    out.fill(0.0);
    for i in 0..n {
        let gi = &g_mat[i * d..(i + 1) * d];
        let oi = &mut out[i * k..(i + 1) * k];
        for (j, &gv) in gi.iter().enumerate() {
            if gv == 0.0 {
                continue;
            }
            let pj = &proj[j * k..(j + 1) * k];
            for (o, &p) in oi.iter_mut().zip(pj.iter()) {
                *o += gv * p;
            }
        }
    }
}

/// Accumulate one (slot, feature) pair's candidate scores into `out`
/// (`bins` entries). The hoisted body of the historical serial scan: a
/// totals pass, then the prefix scan emitting S(left) + S(right) per
/// split candidate. `tot_g`/`acc_g` are caller-owned k-wide scratch.
#[allow(clippy::too_many_arguments)]
fn scan_pair(
    hist: &[f32],
    pair: usize,
    bins: usize,
    k1: usize,
    k: usize,
    lam: f32,
    mode: ScoreMode,
    tot_g: &mut [f64],
    acc_g: &mut [f64],
    out: &mut [f32],
) {
    let base = pair * bins * k1;
    tot_g.fill(0.0);
    let mut tot_d = 0.0f64;
    for b in 0..bins {
        let cell = &hist[base + b * k1..base + (b + 1) * k1];
        for c in 0..k {
            tot_g[c] += cell[c] as f64;
        }
        tot_d += denom_of(cell, k, k1, mode);
    }
    acc_g.fill(0.0);
    let mut acc_d = 0.0f64;
    for b in 0..bins {
        let cell = &hist[base + b * k1..base + (b + 1) * k1];
        for c in 0..k {
            acc_g[c] += cell[c] as f64;
        }
        acc_d += denom_of(cell, k, k1, mode);
        let mut s_left = 0.0f64;
        let mut s_right = 0.0f64;
        for c in 0..k {
            let l = acc_g[c];
            let r = tot_g[c] - l;
            s_left += l * l;
            s_right += r * r;
        }
        s_left /= acc_d + lam as f64;
        s_right /= (tot_d - acc_d) + lam as f64;
        out[b] = (s_left + s_right) as f32;
    }
}

/// Histogram pass dispatch: monomorphize the common channel widths so the
/// inner accumulation unrolls and vectorizes (k=1 scoring -> k1=2; k=5
/// default -> k1=6; HessL2 k=5 -> k1=11). `rows`/`slot_base`/`chan_g` may
/// be shard sub-slices; `slot_base` entries stay absolute offsets into
/// `out`, which is always a full `[n_slots, m, bins, k1]` buffer.
fn hist_dispatch(
    binned: &BinnedDataset,
    rows: &[u32],
    slot_base: &[usize],
    chan_g: &[f32],
    k1: usize,
    out: &mut [f32],
) {
    match k1 {
        2 => hist_pass::<2>(binned, rows, slot_base, chan_g, out),
        3 => hist_pass::<3>(binned, rows, slot_base, chan_g, out),
        6 => hist_pass::<6>(binned, rows, slot_base, chan_g, out),
        11 => hist_pass::<11>(binned, rows, slot_base, chan_g, out),
        _ => hist_pass_dyn(binned, rows, slot_base, chan_g, k1, out),
    }
}

/// One histogram pass with a compile-time channel width.
fn hist_pass<const K1: usize>(
    binned: &BinnedDataset,
    rows: &[u32],
    slot_base: &[usize],
    chan_g: &[f32],
    out: &mut [f32],
) {
    let m = binned.n_features;
    let bins = binned.max_bins;
    for f in 0..m {
        let col = binned.column(f);
        let fbase = f * bins * K1;
        for (j, &r) in rows.iter().enumerate() {
            let b = unsafe { *col.get_unchecked(r as usize) } as usize;
            let dst = slot_base[j] + fbase + b * K1;
            let src = &chan_g[j * K1..j * K1 + K1];
            let out_s = &mut out[dst..dst + K1];
            for c in 0..K1 {
                out_s[c] += src[c];
            }
        }
    }
}

/// Fallback histogram pass for arbitrary channel widths (large-d Full
/// runs hit this path); zip-iterated so the compiler elides bounds
/// checks. (An explicit 8-wide blocked variant measured *slower* — see
/// EXPERIMENTS.md §Perf iteration log.)
fn hist_pass_dyn(
    binned: &BinnedDataset,
    rows: &[u32],
    slot_base: &[usize],
    chan_g: &[f32],
    k1: usize,
    out: &mut [f32],
) {
    let m = binned.n_features;
    let bins = binned.max_bins;
    for f in 0..m {
        let col = binned.column(f);
        let fbase = f * bins * k1;
        for (j, &r) in rows.iter().enumerate() {
            let b = col[r as usize] as usize;
            let dst = slot_base[j] + fbase + b * k1;
            let src = &chan_g[j * k1..(j + 1) * k1];
            let out_s = &mut out[dst..dst + k1];
            for (o, &s) in out_s.iter_mut().zip(src.iter()) {
                *o += s;
            }
        }
    }
}

#[inline]
fn denom_of(cell: &[f32], k: usize, k1: usize, mode: ScoreMode) -> f64 {
    match mode {
        // count channel
        ScoreMode::CountL2 => cell[k1 - 1] as f64,
        // GBDT-MO: sum of hessian channels (per-output denominators are
        // approximated by the summed hessian, as GBDT-MO's shared-
        // denominator formulation does)
        ScoreMode::HessL2 => {
            let mut s = 0.0f64;
            for c in k..2 * k {
                s += cell[c] as f64;
            }
            s
        }
    }
}

fn kind_name(t: &Targets) -> &'static str {
    match t {
        Targets::Multiclass { .. } => "multiclass",
        Targets::Multilabel { .. } => "multilabel",
        Targets::Regression { .. } => "regression",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Dataset;
    use crate::util::proptest::{assert_close, run_prop};
    use crate::util::rng::Rng;

    fn softmax_ref(row: &[f32]) -> Vec<f32> {
        let mx = row.iter().fold(f32::MIN, |a, &b| a.max(b));
        let e: Vec<f32> = row.iter().map(|&z| (z - mx).exp()).collect();
        let s: f32 = e.iter().sum();
        e.iter().map(|&x| x / s).collect()
    }

    #[test]
    fn ce_grad_hess_matches_formula() {
        let mut eng = NativeEngine::new();
        let preds = vec![1.0f32, 2.0, 0.5, -1.0, 0.0, 3.0];
        let t = Targets::Multiclass { labels: vec![2, 0], n_classes: 3 };
        let mut g = vec![0.0f32; 6];
        let mut h = vec![0.0f32; 6];
        eng.grad_hess(LossKind::MulticlassCE, &preds, &t, &mut g, &mut h);
        for i in 0..2 {
            let p = softmax_ref(&preds[i * 3..(i + 1) * 3]);
            for j in 0..3 {
                let y = if (i == 0 && j == 2) || (i == 1 && j == 0) { 1.0 } else { 0.0 };
                assert!((g[i * 3 + j] - (p[j] - y)).abs() < 1e-6);
                assert!((h[i * 3 + j] - p[j] * (1.0 - p[j])).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn ce_gradient_rows_sum_to_zero() {
        run_prop("ce grad sums to 0", 20, |gen| {
            let n = gen.usize_in(1, 50);
            let d = gen.usize_in(2, 20);
            let preds = gen.vec_gaussian(n * d, 2.0);
            let labels = gen.vec_u32_below(n, d);
            let t = Targets::Multiclass { labels, n_classes: d };
            let mut g = vec![0.0f32; n * d];
            let mut h = vec![0.0f32; n * d];
            NativeEngine::new().grad_hess(LossKind::MulticlassCE, &preds, &t, &mut g, &mut h);
            for i in 0..n {
                let s: f32 = g[i * d..(i + 1) * d].iter().sum();
                assert!(s.abs() < 1e-4, "row {i} sums to {s}");
            }
            assert!(h.iter().all(|&x| x > 0.0 && x <= 0.25 + 1e-6));
        });
    }

    #[test]
    fn bce_and_mse_derivatives() {
        let mut eng = NativeEngine::new();
        let preds = vec![0.0f32, 2.0];
        let t = Targets::Multilabel { labels: vec![1.0, 0.0], n_labels: 2 };
        let mut g = vec![0.0f32; 2];
        let mut h = vec![0.0f32; 2];
        eng.grad_hess(LossKind::BCE, &preds, &t, &mut g, &mut h);
        assert!((g[0] - (0.5 - 1.0)).abs() < 1e-6);
        assert!((h[0] - 0.25).abs() < 1e-6);

        let t = Targets::Regression { values: vec![1.0, -1.0], n_targets: 2 };
        eng.grad_hess(LossKind::MSE, &[3.0, 1.0], &t, &mut g, &mut h);
        assert_close(&g, &[2.0, 2.0], 1e-6, 1e-6);
        assert_close(&h, &[1.0, 1.0], 1e-6, 1e-6);
    }

    #[test]
    #[should_panic]
    fn loss_target_mismatch_panics() {
        let t = Targets::Regression { values: vec![0.0], n_targets: 1 };
        NativeEngine::new().grad_hess(
            LossKind::MulticlassCE,
            &[0.0],
            &t,
            &mut [0.0],
            &mut [0.0],
        );
    }

    #[test]
    fn projection_matches_naive() {
        run_prop("native gemm", 20, |gen| {
            let n = gen.usize_in(1, 40);
            let d = gen.usize_in(1, 20);
            let k = gen.usize_in(1, 8);
            let g = gen.vec_gaussian(n * d, 1.0);
            let p = gen.vec_gaussian(d * k, 1.0);
            let mut out = vec![0.0f32; n * k];
            NativeEngine::new().sketch_project(&g, n, d, &p, k, &mut out);
            let mut want = vec![0.0f32; n * k];
            for i in 0..n {
                for c in 0..k {
                    let mut s = 0.0f64;
                    for j in 0..d {
                        s += g[i * d + j] as f64 * p[j * k + c] as f64;
                    }
                    want[i * k + c] = s as f32;
                }
            }
            assert_close(&out, &want, 1e-4, 1e-5);
        });
    }

    fn tiny_binned(n: usize, m: usize, bins: usize, seed: u64) -> BinnedDataset {
        let mut rng = Rng::new(seed);
        let mut feats = vec![0.0f32; n * m];
        rng.fill_gaussian(&mut feats, 1.0);
        let ds = Dataset::new(
            n,
            m,
            feats,
            Targets::Regression { values: vec![0.0; n], n_targets: 1 },
        );
        BinnedDataset::from_dataset(&ds, bins)
    }

    #[test]
    fn histogram_matches_naive() {
        run_prop("native hist", 15, |gen| {
            let n = gen.usize_in(10, 200);
            let m = gen.usize_in(1, 5);
            let bins = *gen.choose(&[4usize, 16, 64]);
            let slots = gen.usize_in(1, 4);
            let k1 = gen.usize_in(2, 5);
            let binned = tiny_binned(n, m, bins, gen.seed);
            let slot_of_row = gen.vec_u32_below(n, slots);
            let mut chan = gen.vec_gaussian(n * k1, 1.0);
            for i in 0..n {
                chan[i * k1 + k1 - 1] = 1.0;
            }
            let rows: Vec<u32> = (0..n as u32).filter(|&r| r % 3 != 2).collect();
            let mut out = vec![0.0f32; slots * m * bins * k1];
            NativeEngine::new().histograms(
                &binned, &rows, &slot_of_row, &chan, k1, slots, &mut out,
            );
            let mut want = vec![0.0f32; slots * m * bins * k1];
            for &r in &rows {
                let r = r as usize;
                let slot = slot_of_row[r] as usize;
                for f in 0..m {
                    let b = binned.column(f)[r] as usize;
                    let base = ((slot * m + f) * bins + b) * k1;
                    for c in 0..k1 {
                        want[base + c] += chan[r * k1 + c];
                    }
                }
            }
            assert_close(&out, &want, 1e-4, 1e-4);
        });
    }

    #[test]
    fn histogram_count_channel_totals_rows() {
        let n = 100;
        let binned = tiny_binned(n, 2, 8, 1);
        let slot_of_row = vec![0u32; n];
        let k1 = 3;
        let mut chan = vec![0.5f32; n * k1];
        for i in 0..n {
            chan[i * k1 + 2] = 1.0;
        }
        let rows: Vec<u32> = (0..n as u32).collect();
        let mut out = vec![0.0f32; 2 * 8 * k1];
        NativeEngine::new().histograms(&binned, &rows, &slot_of_row, &chan, k1, 1, &mut out);
        for f in 0..2 {
            let total: f32 = (0..8).map(|b| out[(f * 8 + b) * k1 + 2]).sum();
            assert!((total - n as f32).abs() < 1e-4);
        }
    }

    #[test]
    fn split_gains_match_scalar_reference() {
        run_prop("native gains", 15, |gen| {
            let slots = gen.usize_in(1, 3);
            let m = gen.usize_in(1, 3);
            let bins = *gen.choose(&[2usize, 8, 16]);
            let k = gen.usize_in(1, 4);
            let lam = *gen.choose(&[0.5f32, 1.0, 5.0]);
            let k1 = k + 1;
            let mut hist = gen.vec_gaussian(slots * m * bins * k1, 1.0);
            // counts >= 0
            for s in 0..slots {
                for f in 0..m {
                    for b in 0..bins {
                        let i = ((s * m + f) * bins + b) * k1 + k;
                        hist[i] = gen.usize_in(0, 30) as f32;
                    }
                }
            }
            let gains = NativeEngine::new().split_gains(
                &hist, slots, m, bins, k1, lam, ScoreMode::CountL2,
            );
            // scalar reference
            for s in 0..slots {
                for f in 0..m {
                    let base = (s * m + f) * bins * k1;
                    for b in 0..bins {
                        let mut gl = vec![0.0f64; k];
                        let mut cl = 0.0f64;
                        let mut gt = vec![0.0f64; k];
                        let mut ct = 0.0f64;
                        for bb in 0..bins {
                            for c in 0..k {
                                let v = hist[base + bb * k1 + c] as f64;
                                gt[c] += v;
                                if bb <= b {
                                    gl[c] += v;
                                }
                            }
                            ct += hist[base + bb * k1 + k] as f64;
                            if bb <= b {
                                cl += hist[base + bb * k1 + k] as f64;
                            }
                        }
                        let sl: f64 =
                            gl.iter().map(|x| x * x).sum::<f64>() / (cl + lam as f64);
                        let sr: f64 = gl
                            .iter()
                            .zip(gt.iter())
                            .map(|(l, t)| (t - l) * (t - l))
                            .sum::<f64>()
                            / ((ct - cl) + lam as f64);
                        let want = (sl + sr) as f32;
                        let got = gains[(s * m + f) * bins + b];
                        assert!(
                            (got - want).abs() <= 1e-3 + 1e-3 * want.abs(),
                            "slot {s} f {f} b {b}: {got} vs {want}"
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn hess_mode_uses_hessian_denominator() {
        // one slot, one feature, two bins, k=1: g channels [1, 3],
        // h channels [2, 4], counts [10, 10], lam = 1
        let k1 = 3;
        let hist = vec![
            1.0, 2.0, 10.0, // bin 0: g=1 h=2 count=10
            3.0, 4.0, 10.0, // bin 1
        ];
        let gains = NativeEngine::new().split_gains(&hist, 1, 1, 2, k1, 1.0, ScoreMode::HessL2);
        // split at b=0: left g=1 h=2 -> 1/(2+1); right g=3 h=4 -> 9/(4+1)
        let want0 = 1.0 / 3.0 + 9.0 / 5.0;
        assert!((gains[0] - want0).abs() < 1e-5, "{} vs {want0}", gains[0]);
    }

    #[test]
    fn sharded_histograms_bit_identical_across_thread_counts() {
        // enough rows that hist_shards() actually splits the work
        let n = 3 * SHARD_TARGET_ROWS;
        let (m, bins, slots, k1) = (3usize, 16usize, 2usize, 3usize);
        let binned = tiny_binned(n, m, bins, 5);
        let mut rng = Rng::new(9);
        let slot_of_row: Vec<u32> = (0..n).map(|_| rng.next_below(slots) as u32).collect();
        let mut chan = vec![0.0f32; n * k1];
        rng.fill_gaussian(&mut chan, 1.0);
        for i in 0..n {
            chan[i * k1 + k1 - 1] = 1.0;
        }
        let rows: Vec<u32> = (0..n as u32).filter(|&r| r % 7 != 6).collect();
        assert!(hist_shards(rows.len(), slots * bins) >= 2, "test must exercise sharding");

        let size = slots * m * bins * k1;
        let mut base = vec![0.0f32; size];
        NativeEngine::with_threads(1)
            .histograms(&binned, &rows, &slot_of_row, &chan, k1, slots, &mut base);
        for t in [2usize, 4, 8] {
            let mut out = vec![0.0f32; size];
            NativeEngine::with_threads(t)
                .histograms(&binned, &rows, &slot_of_row, &chan, k1, slots, &mut out);
            assert_eq!(out, base, "threads = {t}"); // bitwise, not approximate
        }

        // the sharded result is still the right histogram
        let mut want = vec![0.0f32; size];
        for &r in &rows {
            let r = r as usize;
            let slot = slot_of_row[r] as usize;
            for f in 0..m {
                let b = binned.column(f)[r] as usize;
                let cell = ((slot * m + f) * bins + b) * k1;
                for c in 0..k1 {
                    want[cell + c] += chan[r * k1 + c];
                }
            }
        }
        assert_close(&base, &want, 1e-3, 1e-3);
    }

    #[test]
    fn split_gains_bit_identical_across_thread_counts() {
        // big enough (hist.len() >= 16k) to take the parallel branch
        let (slots, m, bins, k1) = (8usize, 8usize, 64usize, 4usize);
        let mut rng = Rng::new(11);
        let mut hist = vec![0.0f32; slots * m * bins * k1];
        rng.fill_gaussian(&mut hist, 1.0);
        for cell in 0..slots * m * bins {
            hist[cell * k1 + k1 - 1] = rng.next_below(30) as f32;
        }
        let base = NativeEngine::with_threads(1)
            .split_gains(&hist, slots, m, bins, k1, 1.0, ScoreMode::CountL2);
        for t in [2usize, 4] {
            let got = NativeEngine::with_threads(t)
                .split_gains(&hist, slots, m, bins, k1, 1.0, ScoreMode::CountL2);
            assert_eq!(got, base, "threads = {t}");
        }
    }

    #[test]
    fn hist_shards_ignores_thread_count_and_caps_reduction() {
        // pure in (rows, shape): small inputs stay serial
        assert_eq!(hist_shards(100, 64), 1);
        assert_eq!(hist_shards(2 * SHARD_TARGET_ROWS, 8), 2);
        // wide frontiers bound the shard count to keep reduction cheap
        assert!(hist_shards(20_000, 32 * 64) <= 20_000 / (4 * 32 * 64) + 1);
        // and the cap holds
        assert!(hist_shards(10_000_000, 8) <= MAX_SHARDS);
    }

    #[test]
    fn leaf_sums_accumulate() {
        let rows = vec![0u32, 1, 2, 3];
        let leaf_of_row = vec![1u32, 0, 1, 0];
        let g = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]; // d=2
        let h = vec![0.1f32; 8];
        let s = NativeEngine::new().leaf_sums(&rows, &leaf_of_row, &g, &h, 2, 2);
        assert_close(&s.gsum, &[3.0 + 7.0, 4.0 + 8.0, 1.0 + 5.0, 2.0 + 6.0], 1e-6, 1e-6);
        assert_close(&s.count, &[2.0, 2.0], 1e-6, 1e-6);
        assert!((s.hsum[0] - 0.2).abs() < 1e-6);
    }
}
