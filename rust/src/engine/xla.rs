//! PJRT-backed compute engine: every op executes the AOT HLO artifact
//! lowered from the L2 JAX graph (with its L1 Pallas kernels inside).
//!
//! Artifacts are shape-monomorphic; dynamic `n` is handled by fixed-size
//! chunking with zero-padded tails (zero gradient rows are exact no-ops
//! for histograms/sums, and padded outputs are simply not read back).
//! The engine is constructed for one manifest *tag* (shape family) —
//! `"e2e"` or `"test"` — and panics with a clear message if the training
//! configuration disagrees with the artifact shapes, because silently
//! falling back would invalidate the engine-ablation benchmarks.
//!
//! Documented exceptions: the gain artifact bakes the classic
//! all-numeric missing-left prefix scan, so `split_gains` delegates to
//! the native scan for `ScoreMode::HessL2` (the GBDT-MO baseline), for
//! `MissingPolicy::Learn` (learned missing-value directions), and for
//! datasets with categorical features.
//!
//! Requires the `pjrt` build feature (see `runtime/` and DESIGN.md
//! section "Build features"); without it, construction fails with an
//! error pointing at the feature — callers surface that error (there is
//! no silent fallback; pick the default [`NativeEngine`] explicitly).

use crate::boosting::losses::LossKind;
use crate::data::binning::BinnedSource;
use crate::data::dataset::Targets;
use crate::runtime::registry::{ArtifactRegistry, Signature};
use crate::runtime::{literal_f32, literal_i32};
use crate::util::error::Result;

use super::{
    ComputeEngine, EngineOpts, FeatureKind, LeafSums, MissingPolicy, NativeEngine, ScanSpec,
    ScoreMode, SlotRange,
};

/// Engine executing PJRT artifacts; see module docs.
pub struct XlaEngine {
    reg: ArtifactRegistry,
    tag: String,
    native_fallback: NativeEngine,
    /// number of artifact executions (for diagnostics/benches)
    pub n_executions: usize,
}

impl XlaEngine {
    /// Open the default artifact directory with the given shape tag and
    /// default [`EngineOpts`].
    pub fn new(tag: &str) -> Result<XlaEngine> {
        XlaEngine::with_opts(tag, EngineOpts::default())
    }

    /// Open with explicit engine options. The thread count applies to the
    /// host-side native fallback (HessL2 split gains); artifact execution
    /// itself is scheduled by the PJRT client.
    pub fn with_opts(tag: &str, opts: EngineOpts) -> Result<XlaEngine> {
        let reg = ArtifactRegistry::open_default()?;
        let eng = XlaEngine {
            reg,
            tag: tag.to_string(),
            native_fallback: NativeEngine::with_opts(opts),
            n_executions: 0,
        };
        // fail fast if the family is incomplete
        for op in ["grad_ce", "grad_bce", "grad_mse", "sketch_rp", "hist", "gain", "leaf_sums"] {
            let name = format!("{op}_{tag}");
            crate::ensure!(
                eng.reg.signature(&name).is_some(),
                "artifact {name} missing from manifest"
            );
        }
        Ok(eng)
    }

    fn sig(&self, op: &str) -> Signature {
        self.reg
            .signature(&format!("{op}_{}", self.tag))
            .unwrap_or_else(|| panic!("artifact {op}_{} missing", self.tag))
            .clone()
    }

    fn name_of(&self, op: &str) -> String {
        format!("{op}_{}", self.tag)
    }

    /// The lambda baked into this family's gain artifact.
    pub fn lambda(&self) -> f32 {
        self.reg.lambda
    }

    /// Shape constraints a GBDT config must satisfy to run on this tag.
    pub fn describe(&self) -> String {
        let h = self.sig("hist");
        let g = self.sig("grad_ce");
        format!(
            "tag={} chunk={} d={} m={} bins={} nodes={} k1={} lambda={}",
            self.tag, g.chunk, g.d, h.m, h.bins, h.nodes, h.k1, self.reg.lambda
        )
    }
}

impl ComputeEngine for XlaEngine {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn grad_hess(
        &mut self,
        loss: LossKind,
        preds: &[f32],
        targets: &Targets,
        g: &mut [f32],
        h: &mut [f32],
    ) -> f64 {
        let op = match loss {
            LossKind::MulticlassCE => "grad_ce",
            LossKind::BCE => "grad_bce",
            LossKind::MSE => "grad_mse",
        };
        let sig = self.sig(op);
        let d = sig.d;
        let n = targets.len();
        assert_eq!(preds.len(), n * d, "{op}: artifact d={d} vs preds len");
        let chunk = sig.chunk;
        let name = self.name_of(op);

        let mut logits_buf = vec![0.0f32; chunk * d];
        for start in (0..n).step_by(chunk) {
            let len = chunk.min(n - start);
            logits_buf[..len * d].copy_from_slice(&preds[start * d..(start + len) * d]);
            logits_buf[len * d..].fill(0.0);
            let logits = literal_f32(&logits_buf, &[chunk as i64, d as i64]).unwrap();
            let tgt = match (loss, targets) {
                (LossKind::MulticlassCE, Targets::Multiclass { labels, .. }) => {
                    let mut lab = vec![0i32; chunk];
                    for i in 0..len {
                        lab[i] = labels[start + i] as i32;
                    }
                    literal_i32(&lab, &[chunk as i64]).unwrap()
                }
                (LossKind::BCE, Targets::Multilabel { labels, .. }) => {
                    let mut t = vec![0.0f32; chunk * d];
                    t[..len * d].copy_from_slice(&labels[start * d..(start + len) * d]);
                    literal_f32(&t, &[chunk as i64, d as i64]).unwrap()
                }
                (LossKind::MSE, Targets::Regression { values, .. }) => {
                    let mut t = vec![0.0f32; chunk * d];
                    t[..len * d].copy_from_slice(&values[start * d..(start + len) * d]);
                    literal_f32(&t, &[chunk as i64, d as i64]).unwrap()
                }
                _ => panic!("loss/targets mismatch"),
            };
            let exe = self.reg.get(&name).expect("compile artifact");
            let outs = exe.run(&[logits, tgt]).expect("execute grad artifact");
            self.n_executions += 1;
            let gq = outs[0].to_vec::<f32>().expect("grad output");
            let hq = outs[1].to_vec::<f32>().expect("hess output");
            g[start * d..(start + len) * d].copy_from_slice(&gq[..len * d]);
            h[start * d..(start + len) * d].copy_from_slice(&hq[..len * d]);
        }
        // the grad artifacts return derivatives only; score the loss
        // host-side so this engine honors the fused-loss contract.
        // This pass runs unconditionally, though the session consumes
        // the value only in cheap mode without a validation set — a
        // known redundancy in every other configuration, accepted
        // here: one O(n*d) host stream is noise against this engine's
        // PJRT dispatches, and the performance path (NativeEngine)
        // computes its loss genuinely fused at zero extra cost. If
        // this ever matters, thread a want_loss flag through the
        // trait instead of skipping the computation.
        loss.primary_metric().eval(preds, targets)
    }

    fn sketch_project(
        &mut self,
        g_mat: &[f32],
        n: usize,
        d: usize,
        proj: &[f32],
        k: usize,
        out: &mut [f32],
    ) {
        let sig = self.sig("sketch_rp");
        assert_eq!(d, sig.d, "sketch_rp artifact d={} vs {}", sig.d, d);
        assert_eq!(k, sig.k, "sketch_rp artifact k={} vs {}", sig.k, k);
        let chunk = sig.chunk;
        let name = self.name_of("sketch_rp");
        let proj_lit = literal_f32(proj, &[d as i64, k as i64]).unwrap();
        let mut buf = vec![0.0f32; chunk * d];
        for start in (0..n).step_by(chunk) {
            let len = chunk.min(n - start);
            buf[..len * d].copy_from_slice(&g_mat[start * d..(start + len) * d]);
            buf[len * d..].fill(0.0);
            let g_lit = literal_f32(&buf, &[chunk as i64, d as i64]).unwrap();
            let exe = self.reg.get(&name).expect("compile sketch_rp");
            let gk = exe
                .run_f32(&[g_lit, proj_lit.reshape(&[d as i64, k as i64]).unwrap()])
                .expect("execute sketch_rp");
            self.n_executions += 1;
            out[start * k..(start + len) * k].copy_from_slice(&gk[..len * k]);
        }
    }

    fn histograms(
        &mut self,
        binned: &dyn BinnedSource,
        rows: &[u32],
        chan: &[f32],
        k1: usize,
        segs: &[SlotRange],
        n_slots: usize,
        out: &mut [f32],
    ) {
        // The artifact path packs whole code rows into device literals;
        // it has no out-of-core story (train chunked with --engine native).
        let binned = binned.as_in_ram().expect("XlaEngine requires in-RAM binned data");
        let sig = self.sig("hist");
        let m = binned.n_features;
        let bins = binned.max_bins;
        assert_eq!(m, sig.m, "hist artifact m={} vs dataset m={}", sig.m, m);
        assert_eq!(bins, sig.bins, "hist artifact bins={} vs {}", sig.bins, bins);
        assert_eq!(k1, sig.k1, "hist artifact k1={} vs {}", sig.k1, k1);
        assert!(
            n_slots <= sig.nodes,
            "hist artifact supports {} slots, need {n_slots}",
            sig.nodes
        );
        let chunk = sig.chunk;
        let nodes = sig.nodes;
        let name = self.name_of("hist");

        // Pack fixed-size chunks from the virtual concatenation of the
        // requested segments. Each row's slot comes from its segment —
        // the partition-ordered contract removes the per-row map lookup
        // here too (the channel rows are parallel to `rows` by position).
        let mut bin_buf = vec![0i32; chunk * m];
        let mut node_buf = vec![0i32; chunk];
        let mut chan_buf = vec![0.0f32; chunk * k1];
        let mut fill = 0usize;
        let mut flush = |fill: usize,
                         bin_buf: &mut [i32],
                         node_buf: &mut [i32],
                         chan_buf: &mut [f32],
                         n_exec: &mut usize,
                         out: &mut [f32]| {
            if fill == 0 {
                return;
            }
            // padding rows: zero channels => no-ops
            bin_buf[fill * m..].fill(0);
            node_buf[fill..].fill(0);
            chan_buf[fill * k1..].fill(0.0);
            let exe = self.reg.get(&name).expect("compile hist");
            let hist = exe
                .run_f32(&[
                    literal_i32(bin_buf, &[chunk as i64, m as i64]).unwrap(),
                    literal_i32(node_buf, &[chunk as i64]).unwrap(),
                    literal_f32(chan_buf, &[chunk as i64, k1 as i64]).unwrap(),
                ])
                .expect("execute hist");
            *n_exec += 1;
            // artifact layout: [m, nodes * bins, k1] -> ours: [slot, f, bin, k1]
            for f in 0..m {
                for slot in 0..n_slots {
                    let src = (f * nodes * bins + slot * bins) * k1;
                    let dst = ((slot * m + f) * bins) * k1;
                    for i in 0..bins * k1 {
                        out[dst + i] += hist[src + i];
                    }
                }
            }
        };
        let mut n_exec = 0usize;
        for seg in segs {
            for pos in seg.range() {
                let r = rows[pos] as usize;
                for f in 0..m {
                    bin_buf[fill * m + f] = binned.codes[f * binned.n_rows + r] as i32;
                }
                node_buf[fill] = seg.slot as i32;
                chan_buf[fill * k1..(fill + 1) * k1]
                    .copy_from_slice(&chan[pos * k1..(pos + 1) * k1]);
                fill += 1;
                if fill == chunk {
                    flush(fill, &mut bin_buf, &mut node_buf, &mut chan_buf, &mut n_exec, out);
                    fill = 0;
                }
            }
        }
        flush(fill, &mut bin_buf, &mut node_buf, &mut chan_buf, &mut n_exec, out);
        self.n_executions += n_exec;
    }

    fn split_gains(
        &mut self,
        hist: &[f32],
        spec: &ScanSpec,
        out: &mut Vec<f32>,
        defaults: &mut Vec<u8>,
    ) {
        // Documented fallbacks: the gain artifact bakes the classic
        // all-numeric prefix scan — no HessL2 variant, no learned
        // missing-direction scan, no categorical-set scan. Those modes
        // run the native scan host-side (split decisions are O(slots *
        // m * bins), far off the artifact-dispatch critical path).
        let artifact_scan = spec.mode == ScoreMode::CountL2
            && spec.missing == MissingPolicy::AlwaysLeft
            && spec.kinds.iter().all(|k| *k == FeatureKind::Numeric);
        if !artifact_scan {
            self.native_fallback.split_gains(hist, spec, out, defaults);
            return;
        }
        let (n_slots, m, bins, k1, lam) = (spec.n_slots, spec.m, spec.bins, spec.k1, spec.lam);
        let sig = self.sig("gain");
        assert_eq!(m, sig.m, "gain artifact m={} vs {}", sig.m, m);
        assert_eq!(bins, sig.bins);
        assert_eq!(k1, sig.k1);
        assert!(n_slots <= sig.nodes);
        assert!(
            (lam - sig.lam).abs() < 1e-6,
            "gain artifact bakes lambda={}, config uses {lam}",
            sig.lam
        );
        let nodes = sig.nodes;
        let name = self.name_of("gain");

        // transpose ours [slot, f, bin, k1] -> artifact [m, nodes, bins, k1]
        let mut buf = vec![0.0f32; m * nodes * bins * k1];
        for slot in 0..n_slots {
            for f in 0..m {
                let src = ((slot * m + f) * bins) * k1;
                let dst = ((f * nodes + slot) * bins) * k1;
                buf[dst..dst + bins * k1].copy_from_slice(&hist[src..src + bins * k1]);
            }
        }
        let exe = self.reg.get(&name).expect("compile gain");
        let gains_art = exe
            .run_f32(&[literal_f32(
                &buf,
                &[m as i64, nodes as i64, bins as i64, k1 as i64],
            )
            .unwrap()])
            .expect("execute gain");
        self.n_executions += 1;
        // artifact [m, nodes, bins] -> ours [slot, f, bin]; defaults are
        // all-left by definition of the AlwaysLeft prefix scan
        out.clear();
        out.resize(n_slots * m * bins, 0.0);
        defaults.clear();
        defaults.resize(n_slots * m * bins, 1);
        for slot in 0..n_slots {
            for f in 0..m {
                let src = (f * nodes + slot) * bins;
                let dst = (slot * m + f) * bins;
                out[dst..dst + bins].copy_from_slice(&gains_art[src..src + bins]);
            }
        }
    }

    fn leaf_sums(
        &mut self,
        rows: &[u32],
        leaf_of_row: &[u32],
        g: &[f32],
        h: &[f32],
        d: usize,
        n_leaves: usize,
        out: &mut LeafSums,
    ) {
        let sig = self.sig("leaf_sums");
        assert_eq!(d, sig.d, "leaf_sums artifact d={} vs {}", sig.d, d);
        assert!(n_leaves <= sig.nodes, "leaf_sums artifact nodes={}", sig.nodes);
        let chunk = sig.chunk;
        let nodes = sig.nodes;
        let c = 2 * d + 1;
        let name = self.name_of("leaf_sums");

        let mut node_buf = vec![0i32; chunk];
        let mut ghv = vec![0.0f32; chunk * c];
        let mut acc = vec![0.0f32; nodes * c];
        for start in (0..rows.len()).step_by(chunk) {
            let len = chunk.min(rows.len() - start);
            node_buf.fill(0);
            ghv.fill(0.0);
            for i in 0..len {
                let r = rows[start + i] as usize;
                node_buf[i] = leaf_of_row[r] as i32;
                let dst = &mut ghv[i * c..(i + 1) * c];
                dst[..d].copy_from_slice(&g[r * d..(r + 1) * d]);
                dst[d..2 * d].copy_from_slice(&h[r * d..(r + 1) * d]);
                dst[c - 1] = 1.0;
            }
            let exe = self.reg.get(&name).expect("compile leaf_sums");
            let sums = exe
                .run_f32(&[
                    literal_i32(&node_buf, &[chunk as i64]).unwrap(),
                    literal_f32(&ghv, &[chunk as i64, c as i64]).unwrap(),
                ])
                .expect("execute leaf_sums");
            self.n_executions += 1;
            for i in 0..nodes * c {
                acc[i] += sums[i];
            }
        }
        out.reset(n_leaves, d);
        for l in 0..n_leaves {
            out.gsum[l * d..(l + 1) * d].copy_from_slice(&acc[l * c..l * c + d]);
            out.hsum[l * d..(l + 1) * d].copy_from_slice(&acc[l * c + d..l * c + 2 * d]);
            out.count[l] = acc[l * c + c - 1];
        }
    }
}
