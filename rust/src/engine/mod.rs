//! Compute engines: the numeric ops of one boosting round behind a trait.
//!
//! Two interchangeable backends implement [`ComputeEngine`]:
//!
//! * [`NativeEngine`] — pure rust, cache-tuned; the performance path.
//! * [`XlaEngine`] — executes the AOT-compiled HLO artifacts (lowered from
//!   the L2 JAX graph with its L1 Pallas kernels) on the PJRT CPU client.
//!
//! Both backends are required to be numerically equivalent (integration
//! tests in `rust/tests/` cross-check them); `benches/hot_paths.rs`
//! compares their throughput. The tree builder and trainer are written
//! against the trait only.
//!
//! ## Histogram tensor layout
//!
//! `hist[((slot * m + f) * bins + b) * k1 + c]` where `slot` indexes the
//! tree level's frontier nodes, `f` the feature, `b` the bin, and `c` the
//! channel. Channels are `[g_0..g_k)` sketched-gradient sums, then (in
//! `HessL2` mode) `[h_0..h_k)` hessian sums, then one count channel.
//!
//! ## Row partitioning
//!
//! The tree builder keeps the active rows **stably partitioned into
//! contiguous per-node segments** (see `tree/workspace.rs` and DESIGN.md
//! "Memory model & row partitioning"): every frontier node owns a
//! `[start, end)` range of one shared row-index buffer, with the gathered
//! channel matrix kept in the same partition order alongside it.
//! [`ComputeEngine::histograms`] therefore takes a list of [`SlotRange`]
//! segments instead of a per-row `slot_of_row` map — the accumulation
//! streams each segment sequentially with a constant output base, with no
//! per-row slot lookup and no per-level re-gather of channel rows.
//!
//! ## Threading and determinism
//!
//! Engines are constructed with [`EngineOpts`] and may execute the hot
//! ops (histogram accumulation, split scan) on an internal thread pool.
//! The contract is strict: **results must be a pure function of the
//! inputs — bit-identical for every thread count** — so the tree builder
//! and trainer stay oblivious to parallelism and `seed`-reproducibility
//! is preserved. `NativeEngine` achieves this with a fixed row-shard
//! partition and an ascending-shard-order reduction (DESIGN.md, section
//! "Threading model"); `rust/tests/parallel_determinism.rs` enforces it,
//! and `rust/tests/partition_equivalence.rs` pins the result bits to the
//! pre-partitioning implementation preserved in [`reference`].

pub mod native;
#[doc(hidden)]
pub mod reference;
pub mod xla;

pub use self::native::NativeEngine;
pub use self::xla::XlaEngine;

/// A contiguous segment of the partition-ordered row buffer belonging to
/// one frontier slot: rows `rows[start..end]` (and the channel rows
/// `chan[start*k1..end*k1]` parallel to them) all fall in histogram slot
/// `slot`. Produced by the builder's stable partition
/// (`tree/workspace.rs`); consumed by [`ComputeEngine::histograms`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SlotRange {
    /// Frontier slot (histogram slice index into `out`).
    pub slot: u32,
    /// First row position (into the `rows`/`chan` buffers).
    pub start: u32,
    /// One past the last row position.
    pub end: u32,
}

impl SlotRange {
    pub fn new(slot: u32, start: u32, end: u32) -> SlotRange {
        debug_assert!(start <= end);
        SlotRange { slot, start, end }
    }

    /// Number of rows in the segment.
    pub fn len(&self) -> usize {
        (self.end - self.start) as usize
    }

    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }

    /// The segment as a `usize` position range.
    pub fn range(&self) -> std::ops::Range<usize> {
        self.start as usize..self.end as usize
    }
}

/// Engine construction options, shared by every [`ComputeEngine`] backend
/// (and by the baselines, which build engines internally).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EngineOpts {
    /// Worker threads for the parallel ops; `0` = all available cores,
    /// `1` (the default) = the serial path.
    pub n_threads: usize,
}

impl Default for EngineOpts {
    fn default() -> Self {
        EngineOpts { n_threads: 1 }
    }
}

impl EngineOpts {
    /// Options with an explicit thread count (`0` = all cores).
    pub fn threads(n_threads: usize) -> EngineOpts {
        EngineOpts { n_threads }
    }
}

use crate::boosting::losses::LossKind;
use crate::data::binning::BinnedDataset;
use crate::data::dataset::Targets;

/// Split-scoring denominator (paper section 3 "best practices").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScoreMode {
    /// S(R) = sum_j (sum g)^2 / (|R| + lambda) — CatBoost/SketchBoost
    /// regime: hessians ignored during the search.
    CountL2,
    /// S(R) = sum_j (sum g)^2 / (sum h + lambda) — GBDT-MO regime:
    /// hessian histograms double the accumulation cost.
    HessL2,
}

impl ScoreMode {
    /// Number of histogram channels for `k` scoring outputs.
    pub fn channels(&self, k: usize) -> usize {
        match self {
            ScoreMode::CountL2 => k + 1,
            ScoreMode::HessL2 => 2 * k + 1,
        }
    }
}

/// Per-leaf sums of full-dimensional derivatives, for exact leaf values.
/// Pooled by the caller (the tree workspace) and refilled via
/// [`LeafSums::reset`] so steady-state training reuses the buffers.
#[derive(Default)]
pub struct LeafSums {
    /// row-major [n_leaves, d]
    pub gsum: Vec<f32>,
    pub hsum: Vec<f32>,
    pub count: Vec<f32>,
}

impl LeafSums {
    pub fn new() -> LeafSums {
        LeafSums::default()
    }

    /// Resize for `n_leaves` leaves of `d` outputs and zero the contents
    /// (allocation-free once capacity has grown to the high-water mark).
    pub fn reset(&mut self, n_leaves: usize, d: usize) {
        self.gsum.clear();
        self.gsum.resize(n_leaves * d, 0.0);
        self.hsum.clear();
        self.hsum.resize(n_leaves * d, 0.0);
        self.count.clear();
        self.count.resize(n_leaves, 0.0);
    }
}

/// The numeric core of one boosting round. Implementations may keep
/// internal state (compiled executables, scratch buffers).
pub trait ComputeEngine {
    fn name(&self) -> &'static str;

    /// Loss derivatives (paper eq. 2, diagonal hessian) for all rows.
    /// `preds` is row-major [n, d]; outputs are written into g/h.
    ///
    /// Returns the loss of `preds` (on the loss's default-metric
    /// scale: mean logloss for CE/BCE, RMSE for MSE), fused into the
    /// same pass — the trainer reuses it as a free train metric when
    /// no separate evaluation pass is configured, so implementations
    /// must not skip it. The g/h writes remain the bit-exactness
    /// surface; the returned f64 is informational only and never feeds
    /// tree construction — accordingly, its low decimal places may
    /// differ between engines (NativeEngine fuses it from the f32
    /// softmax intermediates; XlaEngine scores the metric in f64), so
    /// do not diff cheap-mode history across engines bitwise.
    fn grad_hess(
        &mut self,
        loss: LossKind,
        preds: &[f32],
        targets: &Targets,
        g: &mut [f32],
        h: &mut [f32],
    ) -> f64;

    /// Random Projection sketch: out = g_mat @ proj, shapes [n,d]@[d,k].
    fn sketch_project(
        &mut self,
        g_mat: &[f32],
        n: usize,
        d: usize,
        proj: &[f32],
        k: usize,
        out: &mut [f32],
    );

    /// Accumulate histograms for the requested row segments into `out`
    /// (layout above; `out` holds `n_slots` slices and the caller zeroes
    /// it — accumulate-into semantics).
    ///
    /// `rows` is the partition-ordered row-index buffer (*global* row ids
    /// into `binned`); `chan` is the `[rows.len(), k1]` channel matrix
    /// **parallel to `rows` by position** (trailing channel must be the
    /// valid/count indicator). Each [`SlotRange`] in `segs` names one
    /// contiguous run of `rows` and the frontier slot it belongs to;
    /// segments must be pairwise disjoint. With sibling subtraction only
    /// the smaller child of each split appears in `segs`, while `n_slots`
    /// stays the full frontier width (it sizes `out` and the deterministic
    /// shard partition).
    #[allow(clippy::too_many_arguments)]
    fn histograms(
        &mut self,
        binned: &BinnedDataset,
        rows: &[u32],
        chan: &[f32],
        k1: usize,
        segs: &[SlotRange],
        n_slots: usize,
        out: &mut [f32],
    );

    /// Split scores S(left)+S(right) for every (slot, feature, bin),
    /// written into `out` (cleared and resized to `n_slots * m * bins`;
    /// candidate b means "left = bins <= b"). The caller owns the buffer
    /// so steady-state training reuses its capacity across levels and
    /// trees (see `tree/workspace.rs`).
    #[allow(clippy::too_many_arguments)]
    fn split_gains(
        &mut self,
        hist: &[f32],
        n_slots: usize,
        m: usize,
        bins: usize,
        k1: usize,
        lam: f32,
        mode: ScoreMode,
        out: &mut Vec<f32>,
    );

    /// Per-leaf sums of the full gradient/hessian matrices over `rows`,
    /// written into `out` (reset to `[n_leaves, d]` by the callee).
    #[allow(clippy::too_many_arguments)]
    fn leaf_sums(
        &mut self,
        rows: &[u32],
        leaf_of_row: &[u32],
        g: &[f32],
        h: &[f32],
        d: usize,
        n_leaves: usize,
        out: &mut LeafSums,
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn channel_counts() {
        assert_eq!(ScoreMode::CountL2.channels(5), 6);
        assert_eq!(ScoreMode::HessL2.channels(5), 11);
        assert_eq!(ScoreMode::CountL2.channels(1), 2);
    }

    #[test]
    fn engine_opts_default_is_serial() {
        assert_eq!(EngineOpts::default().n_threads, 1);
        assert_eq!(EngineOpts::threads(4), EngineOpts { n_threads: 4 });
    }

    #[test]
    fn slot_range_len_and_range() {
        let s = SlotRange::new(3, 10, 25);
        assert_eq!(s.len(), 15);
        assert!(!s.is_empty());
        assert_eq!(s.range(), 10..25);
        assert!(SlotRange::new(0, 7, 7).is_empty());
    }

    #[test]
    fn leaf_sums_reset_zeroes() {
        let mut s = LeafSums::new();
        s.reset(2, 3);
        s.gsum[0] = 5.0;
        s.count[1] = 2.0;
        s.reset(2, 3);
        assert!(s.gsum.iter().all(|&v| v == 0.0));
        assert!(s.count.iter().all(|&v| v == 0.0));
        assert_eq!(s.hsum.len(), 6);
    }
}
