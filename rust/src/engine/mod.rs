//! Compute engines: the numeric ops of one boosting round behind a trait.
//!
//! Two interchangeable backends implement [`ComputeEngine`]:
//!
//! * [`NativeEngine`] — pure rust, cache-tuned; the performance path.
//! * [`XlaEngine`] — executes the AOT-compiled HLO artifacts (lowered from
//!   the L2 JAX graph with its L1 Pallas kernels) on the PJRT CPU client.
//!
//! Both backends are required to be numerically equivalent (integration
//! tests in `rust/tests/` cross-check them); `benches/hot_paths.rs`
//! compares their throughput. The tree builder and trainer are written
//! against the trait only.
//!
//! ## Histogram tensor layout
//!
//! `hist[((slot * m + f) * bins + b) * k1 + c]` where `slot` indexes the
//! tree level's frontier nodes, `f` the feature, `b` the bin, and `c` the
//! channel. Channels are `[g_0..g_k)` sketched-gradient sums, then (in
//! `HessL2` mode) `[h_0..h_k)` hessian sums, then one count channel.
//!
//! ## Row partitioning
//!
//! The tree builder keeps the active rows **stably partitioned into
//! contiguous per-node segments** (see `tree/workspace.rs` and DESIGN.md
//! "Memory model & row partitioning"): every frontier node owns a
//! `[start, end)` range of one shared row-index buffer, with the gathered
//! channel matrix kept in the same partition order alongside it.
//! [`ComputeEngine::histograms`] therefore takes a list of [`SlotRange`]
//! segments instead of a per-row `slot_of_row` map — the accumulation
//! streams each segment sequentially with a constant output base, with no
//! per-row slot lookup and no per-level re-gather of channel rows.
//!
//! ## Threading and determinism
//!
//! Engines are constructed with [`EngineOpts`] and may execute the hot
//! ops (histogram accumulation, split scan) on an internal thread pool.
//! The contract is strict: **results must be a pure function of the
//! inputs — bit-identical for every thread count** — so the tree builder
//! and trainer stay oblivious to parallelism and `seed`-reproducibility
//! is preserved. `NativeEngine` achieves this with a fixed row-shard
//! partition and an ascending-shard-order reduction (DESIGN.md, section
//! "Threading model"); `rust/tests/parallel_determinism.rs` enforces it,
//! and `rust/tests/partition_equivalence.rs` pins the result bits to the
//! pre-partitioning implementation preserved in [`reference`].

pub mod native;
#[doc(hidden)]
pub mod reference;
pub mod xla;

pub use self::native::NativeEngine;
pub use self::xla::XlaEngine;

/// A contiguous segment of the partition-ordered row buffer belonging to
/// one frontier slot: rows `rows[start..end]` (and the channel rows
/// `chan[start*k1..end*k1]` parallel to them) all fall in histogram slot
/// `slot`. Produced by the builder's stable partition
/// (`tree/workspace.rs`); consumed by [`ComputeEngine::histograms`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SlotRange {
    /// Frontier slot (histogram slice index into `out`).
    pub slot: u32,
    /// First row position (into the `rows`/`chan` buffers).
    pub start: u32,
    /// One past the last row position.
    pub end: u32,
}

impl SlotRange {
    pub fn new(slot: u32, start: u32, end: u32) -> SlotRange {
        debug_assert!(start <= end);
        SlotRange { slot, start, end }
    }

    /// Number of rows in the segment.
    pub fn len(&self) -> usize {
        (self.end - self.start) as usize
    }

    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }

    /// The segment as a `usize` position range.
    pub fn range(&self) -> std::ops::Range<usize> {
        self.start as usize..self.end as usize
    }
}

/// Engine construction options, shared by every [`ComputeEngine`] backend
/// (and by the baselines, which build engines internally).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EngineOpts {
    /// Worker threads for the parallel ops; `0` = all available cores,
    /// `1` (the default) = the serial path.
    pub n_threads: usize,
}

impl Default for EngineOpts {
    fn default() -> Self {
        EngineOpts { n_threads: 1 }
    }
}

impl EngineOpts {
    /// Options with an explicit thread count (`0` = all cores).
    pub fn threads(n_threads: usize) -> EngineOpts {
        EngineOpts { n_threads }
    }
}

use crate::boosting::losses::LossKind;
use crate::data::binning::BinnedSource;
use crate::data::dataset::Targets;

pub use crate::data::dataset::FeatureKind;

/// Split-scoring denominator (paper section 3 "best practices").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScoreMode {
    /// S(R) = sum_j (sum g)^2 / (|R| + lambda) — CatBoost/SketchBoost
    /// regime: hessians ignored during the search.
    CountL2,
    /// S(R) = sum_j (sum g)^2 / (sum h + lambda) — GBDT-MO regime:
    /// hessian histograms double the accumulation cost.
    HessL2,
}

impl ScoreMode {
    /// Number of histogram channels for `k` scoring outputs.
    pub fn channels(&self, k: usize) -> usize {
        match self {
            ScoreMode::CountL2 => k + 1,
            ScoreMode::HessL2 => 2 * k + 1,
        }
    }

    /// Number of scoring channels `k` for `k1` histogram channels.
    pub fn scoring_k(&self, k1: usize) -> usize {
        match self {
            ScoreMode::CountL2 => k1 - 1,
            ScoreMode::HessL2 => (k1 - 1) / 2,
        }
    }
}

/// How split search treats the missing bin (bin 0 of every feature —
/// `data/binning.rs`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum MissingPolicy {
    /// XGBoost-style sparsity-aware search: every candidate is evaluated
    /// with missing routed left *and* right, and the winning direction
    /// is recorded on the split as `default_left`.
    #[default]
    Learn,
    /// Legacy policy: missing always routes left (the historical
    /// "NaN is the smallest value" behavior, now explicit).
    AlwaysLeft,
}

impl MissingPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            MissingPolicy::Learn => "learn",
            MissingPolicy::AlwaysLeft => "left",
        }
    }

    pub fn parse(s: &str) -> Option<MissingPolicy> {
        match s {
            "learn" => Some(MissingPolicy::Learn),
            "left" | "always_left" => Some(MissingPolicy::AlwaysLeft),
            _ => None,
        }
    }
}

/// Shape + semantics of one split-gain scan, shared by every
/// [`ComputeEngine::split_gains`] backend and by the splitter that
/// consumes the gain tensor.
#[derive(Clone, Copy, Debug)]
pub struct ScanSpec<'a> {
    pub n_slots: usize,
    /// feature count
    pub m: usize,
    /// histogram bins per feature (bin 0 = missing)
    pub bins: usize,
    /// histogram channels
    pub k1: usize,
    /// L2 regularizer in the candidate scores
    pub lam: f32,
    pub mode: ScoreMode,
    /// per-feature interpretation (`spec.kinds.len() == m`)
    pub kinds: &'a [FeatureKind],
    pub missing: MissingPolicy,
}

/// Pooled scratch for [`categorical_order`] (per-bin stats, per-channel
/// totals, and the output permutation). One lives in every engine worker
/// and one in the tree workspace, so the categorical scan allocates only
/// up to its high-water mark.
#[derive(Default)]
pub struct CatScratch {
    stats: Vec<f64>,
    /// Value bins (codes >= 1) with any mass, in scan order; filled by
    /// [`categorical_order`].
    pub order: Vec<u8>,
}

/// Deterministic category ordering for the LightGBM-style categorical
/// split scan: the value bins (codes >= 1) of one (slot, feature) pair
/// that carry any mass, sorted by
///
/// `stat(c) = g_c[0] / (denom_c + lam)`
///
/// descending (ties broken by ascending bin) — the category's *leading
/// scoring channel* over its regularized denominator. For a single
/// scoring channel this is exactly LightGBM's
/// gradient-over-denominator order; for sketched multi-channel scoring
/// channel 0 is the sketch's leading direction (largest-norm output
/// for TopOutputs, leading singular vector for SVD), which keeps the
/// order scalar and — unlike a projection onto the node's *total*
/// gradient, which is ~0 at any well-centered node — non-degenerate.
/// The sorted *prefixes* are the candidate category sets — candidate 0
/// is the classic one-vs-rest split. Pure in `pair_hist`, so every
/// engine and the splitter reconstruct the identical order.
pub fn categorical_order(
    pair_hist: &[f32], // one (slot, feature): bins * k1 cells
    bins: usize,
    k1: usize,
    mode: ScoreMode,
    lam: f32,
    scratch: &mut CatScratch,
) {
    debug_assert_eq!(pair_hist.len(), bins * k1);
    let k = mode.scoring_k(k1);
    let CatScratch { stats, order, .. } = scratch;
    stats.clear();
    stats.resize(bins, 0.0);
    order.clear();
    for b in 1..bins {
        let cell = &pair_hist[b * k1..(b + 1) * k1];
        if cell[k1 - 1] <= 0.0 {
            continue; // empty category
        }
        stats[b] = cell[0] as f64 / (denom_of(cell, k, k1, mode) + lam as f64);
        order.push(b as u8);
    }
    order.sort_unstable_by(|&a, &b| {
        stats[b as usize].total_cmp(&stats[a as usize]).then(a.cmp(&b))
    });
}

/// Scoring denominator of one histogram cell (count channel in CountL2;
/// summed hessian channels in HessL2 — GBDT-MO's shared-denominator
/// formulation).
#[inline]
pub(crate) fn denom_of(cell: &[f32], k: usize, k1: usize, mode: ScoreMode) -> f64 {
    match mode {
        ScoreMode::CountL2 => cell[k1 - 1] as f64,
        ScoreMode::HessL2 => {
            let mut s = 0.0f64;
            for c in k..2 * k {
                s += cell[c] as f64;
            }
            s
        }
    }
}

/// Per-leaf sums of full-dimensional derivatives, for exact leaf values.
/// Pooled by the caller (the tree workspace) and refilled via
/// [`LeafSums::reset`] so steady-state training reuses the buffers.
#[derive(Default)]
pub struct LeafSums {
    /// row-major [n_leaves, d]
    pub gsum: Vec<f32>,
    pub hsum: Vec<f32>,
    pub count: Vec<f32>,
}

impl LeafSums {
    pub fn new() -> LeafSums {
        LeafSums::default()
    }

    /// Resize for `n_leaves` leaves of `d` outputs and zero the contents
    /// (allocation-free once capacity has grown to the high-water mark).
    pub fn reset(&mut self, n_leaves: usize, d: usize) {
        self.gsum.clear();
        self.gsum.resize(n_leaves * d, 0.0);
        self.hsum.clear();
        self.hsum.resize(n_leaves * d, 0.0);
        self.count.clear();
        self.count.resize(n_leaves, 0.0);
    }
}

/// The numeric core of one boosting round. Implementations may keep
/// internal state (compiled executables, scratch buffers).
pub trait ComputeEngine {
    fn name(&self) -> &'static str;

    /// Loss derivatives (paper eq. 2, diagonal hessian) for all rows.
    /// `preds` is row-major [n, d]; outputs are written into g/h.
    ///
    /// Returns the loss of `preds` (on the loss's default-metric
    /// scale: mean logloss for CE/BCE, RMSE for MSE), fused into the
    /// same pass — the trainer reuses it as a free train metric when
    /// no separate evaluation pass is configured, so implementations
    /// must not skip it. The g/h writes remain the bit-exactness
    /// surface; the returned f64 is informational only and never feeds
    /// tree construction — accordingly, its low decimal places may
    /// differ between engines (NativeEngine fuses it from the f32
    /// softmax intermediates; XlaEngine scores the metric in f64), so
    /// do not diff cheap-mode history across engines bitwise.
    fn grad_hess(
        &mut self,
        loss: LossKind,
        preds: &[f32],
        targets: &Targets,
        g: &mut [f32],
        h: &mut [f32],
    ) -> f64;

    /// Random Projection sketch: out = g_mat @ proj, shapes [n,d]@[d,k].
    fn sketch_project(
        &mut self,
        g_mat: &[f32],
        n: usize,
        d: usize,
        proj: &[f32],
        k: usize,
        out: &mut [f32],
    );

    /// Accumulate histograms for the requested row segments into `out`
    /// (layout above; `out` holds `n_slots` slices and the caller zeroes
    /// it — accumulate-into semantics).
    ///
    /// `rows` is the partition-ordered row-index buffer (*global* row ids
    /// into `binned`); `chan` is the `[rows.len(), k1]` channel matrix
    /// **parallel to `rows` by position** (trailing channel must be the
    /// valid/count indicator). Each [`SlotRange`] in `segs` names one
    /// contiguous run of `rows` and the frontier slot it belongs to;
    /// segments must be pairwise disjoint. With sibling subtraction only
    /// the smaller child of each split appears in `segs`, while `n_slots`
    /// stays the full frontier width (it sizes `out` and the deterministic
    /// shard partition).
    ///
    /// `binned` is any [`BinnedSource`] — the in-RAM [`BinnedDataset`]
    /// (its `as_in_ram` fast path keeps the historical hot loops intact)
    /// or the out-of-core `ChunkedBinned` store. The determinism
    /// contract is source-independent: same codes + same chunk plan ⇒
    /// bit-identical histograms (`rust/tests/out_of_core.rs`).
    #[allow(clippy::too_many_arguments)]
    fn histograms(
        &mut self,
        binned: &dyn BinnedSource,
        rows: &[u32],
        chan: &[f32],
        k1: usize,
        segs: &[SlotRange],
        n_slots: usize,
        out: &mut [f32],
    );

    /// Split scores S(left)+S(right) for every (slot, feature,
    /// candidate), written into `out`, with the winning missing-value
    /// direction per candidate in `defaults` (1 = left). Both buffers
    /// are cleared and resized to `n_slots * m * bins`; the caller owns
    /// them so steady-state training reuses capacity across levels and
    /// trees (see `tree/workspace.rs`).
    ///
    /// Candidate semantics per feature kind (bin 0 is the missing bin):
    ///
    /// * **Numeric**: candidate `b >= 1` means "left = value bins <= b",
    ///   with the missing bin routed per `defaults` (under
    ///   [`MissingPolicy::Learn`] both directions are scored and the max
    ///   wins; ties and NaN-free nodes default left, preserving the
    ///   legacy behavior bit-for-bit). Candidate 0 (left = missing only)
    ///   has no representable raw threshold and is never selected by the
    ///   splitter; under [`MissingPolicy::AlwaysLeft`] the scan is the
    ///   classic prefix scan over all bins with `defaults` all-left.
    /// * **Categorical**: candidate `j` means "left = the first `j + 1`
    ///   categories of [`categorical_order`]", i.e. sorted one-vs-rest
    ///   prefixes; entries past the number of present categories are 0.
    fn split_gains(
        &mut self,
        hist: &[f32],
        spec: &ScanSpec,
        out: &mut Vec<f32>,
        defaults: &mut Vec<u8>,
    );

    /// Per-leaf sums of the full gradient/hessian matrices over `rows`,
    /// written into `out` (reset to `[n_leaves, d]` by the callee).
    #[allow(clippy::too_many_arguments)]
    fn leaf_sums(
        &mut self,
        rows: &[u32],
        leaf_of_row: &[u32],
        g: &[f32],
        h: &[f32],
        d: usize,
        n_leaves: usize,
        out: &mut LeafSums,
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn channel_counts() {
        assert_eq!(ScoreMode::CountL2.channels(5), 6);
        assert_eq!(ScoreMode::HessL2.channels(5), 11);
        assert_eq!(ScoreMode::CountL2.channels(1), 2);
        assert_eq!(ScoreMode::CountL2.scoring_k(6), 5);
        assert_eq!(ScoreMode::HessL2.scoring_k(11), 5);
    }

    #[test]
    fn missing_policy_parse_roundtrip() {
        for p in [MissingPolicy::Learn, MissingPolicy::AlwaysLeft] {
            assert_eq!(MissingPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(MissingPolicy::parse("always_left"), Some(MissingPolicy::AlwaysLeft));
        assert!(MissingPolicy::parse("bogus").is_none());
    }

    #[test]
    fn categorical_order_sorts_by_leading_channel_stat() {
        // one pair, 5 bins (bin 0 missing), k1 = 2 (one grad channel +
        // count). Category gradients: bin1 +4 (cnt 2), bin2 -6 (cnt 2),
        // bin3 empty, bin4 +1 (cnt 1): stats 4/3, -2, 1/2.
        let k1 = 2;
        let hist = vec![
            0.0, 0.0, // missing
            4.0, 2.0, // bin 1: stat = 4/3
            -6.0, 2.0, // bin 2: stat = -2
            0.0, 0.0, // bin 3: empty, excluded
            1.0, 1.0, // bin 4: stat = 1/2
        ];
        let mut scratch = CatScratch::default();
        categorical_order(&hist, 5, k1, ScoreMode::CountL2, 1.0, &mut scratch);
        assert_eq!(scratch.order, vec![1, 4, 2]);
    }

    #[test]
    fn categorical_order_breaks_ties_by_bin() {
        // two identical categories must order by ascending bin id
        let k1 = 2;
        let hist = vec![
            0.0, 0.0, //
            1.0, 1.0, //
            1.0, 1.0, //
        ];
        let mut scratch = CatScratch::default();
        categorical_order(&hist, 3, k1, ScoreMode::CountL2, 1.0, &mut scratch);
        assert_eq!(scratch.order, vec![1, 2]);
    }

    #[test]
    fn engine_opts_default_is_serial() {
        assert_eq!(EngineOpts::default().n_threads, 1);
        assert_eq!(EngineOpts::threads(4), EngineOpts { n_threads: 4 });
    }

    #[test]
    fn slot_range_len_and_range() {
        let s = SlotRange::new(3, 10, 25);
        assert_eq!(s.len(), 15);
        assert!(!s.is_empty());
        assert_eq!(s.range(), 10..25);
        assert!(SlotRange::new(0, 7, 7).is_empty());
    }

    #[test]
    fn leaf_sums_reset_zeroes() {
        let mut s = LeafSums::new();
        s.reset(2, 3);
        s.gsum[0] = 5.0;
        s.count[1] = 2.0;
        s.reset(2, 3);
        assert!(s.gsum.iter().all(|&v| v == 0.0));
        assert!(s.count.iter().all(|&v| v == 0.0));
        assert_eq!(s.hsum.len(), 6);
    }
}
