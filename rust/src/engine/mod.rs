//! Compute engines: the numeric ops of one boosting round behind a trait.
//!
//! Two interchangeable backends implement [`ComputeEngine`]:
//!
//! * [`NativeEngine`] — pure rust, cache-tuned; the performance path.
//! * [`XlaEngine`] — executes the AOT-compiled HLO artifacts (lowered from
//!   the L2 JAX graph with its L1 Pallas kernels) on the PJRT CPU client.
//!
//! Both backends are required to be numerically equivalent (integration
//! tests in `rust/tests/` cross-check them); `benches/hot_paths.rs`
//! compares their throughput. The tree builder and trainer are written
//! against the trait only.
//!
//! ## Histogram tensor layout
//!
//! `hist[((slot * m + f) * bins + b) * k1 + c]` where `slot` indexes the
//! tree level's frontier nodes, `f` the feature, `b` the bin, and `c` the
//! channel. Channels are `[g_0..g_k)` sketched-gradient sums, then (in
//! `HessL2` mode) `[h_0..h_k)` hessian sums, then one count channel.
//!
//! ## Threading and determinism
//!
//! Engines are constructed with [`EngineOpts`] and may execute the hot
//! ops (histogram accumulation, split scan) on an internal thread pool.
//! The contract is strict: **results must be a pure function of the
//! inputs — bit-identical for every thread count** — so the tree builder
//! and trainer stay oblivious to parallelism and `seed`-reproducibility
//! is preserved. `NativeEngine` achieves this with a fixed row-shard
//! partition and an ascending-shard-order reduction (DESIGN.md, section
//! "Threading model"); `rust/tests/parallel_determinism.rs` enforces it.

pub mod native;
pub mod xla;

pub use self::native::NativeEngine;
pub use self::xla::XlaEngine;

/// Engine construction options, shared by every [`ComputeEngine`] backend
/// (and by the baselines, which build engines internally).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EngineOpts {
    /// Worker threads for the parallel ops; `0` = all available cores,
    /// `1` (the default) = the serial path.
    pub n_threads: usize,
}

impl Default for EngineOpts {
    fn default() -> Self {
        EngineOpts { n_threads: 1 }
    }
}

impl EngineOpts {
    /// Options with an explicit thread count (`0` = all cores).
    pub fn threads(n_threads: usize) -> EngineOpts {
        EngineOpts { n_threads }
    }
}

use crate::boosting::losses::LossKind;
use crate::data::binning::BinnedDataset;
use crate::data::dataset::Targets;

/// Split-scoring denominator (paper section 3 "best practices").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScoreMode {
    /// S(R) = sum_j (sum g)^2 / (|R| + lambda) — CatBoost/SketchBoost
    /// regime: hessians ignored during the search.
    CountL2,
    /// S(R) = sum_j (sum g)^2 / (sum h + lambda) — GBDT-MO regime:
    /// hessian histograms double the accumulation cost.
    HessL2,
}

impl ScoreMode {
    /// Number of histogram channels for `k` scoring outputs.
    pub fn channels(&self, k: usize) -> usize {
        match self {
            ScoreMode::CountL2 => k + 1,
            ScoreMode::HessL2 => 2 * k + 1,
        }
    }
}

/// Per-leaf sums of full-dimensional derivatives, for exact leaf values.
pub struct LeafSums {
    /// row-major [n_leaves, d]
    pub gsum: Vec<f32>,
    pub hsum: Vec<f32>,
    pub count: Vec<f32>,
}

/// The numeric core of one boosting round. Implementations may keep
/// internal state (compiled executables, scratch buffers).
pub trait ComputeEngine {
    fn name(&self) -> &'static str;

    /// Loss derivatives (paper eq. 2, diagonal hessian) for all rows.
    /// `preds` is row-major [n, d]; outputs are written into g/h.
    fn grad_hess(
        &mut self,
        loss: LossKind,
        preds: &[f32],
        targets: &Targets,
        g: &mut [f32],
        h: &mut [f32],
    );

    /// Random Projection sketch: out = g_mat @ proj, shapes [n,d]@[d,k].
    fn sketch_project(
        &mut self,
        g_mat: &[f32],
        n: usize,
        d: usize,
        proj: &[f32],
        k: usize,
        out: &mut [f32],
    );

    /// Accumulate histograms for `rows` into `out` (layout above).
    /// `slot_of_row` maps *global* row index -> frontier slot; `chan` is
    /// the row-major [n, k1] channel matrix (trailing channel must be the
    /// valid/count indicator).
    fn histograms(
        &mut self,
        binned: &BinnedDataset,
        rows: &[u32],
        slot_of_row: &[u32],
        chan: &[f32],
        k1: usize,
        n_slots: usize,
        out: &mut [f32],
    );

    /// Split scores S(left)+S(right) for every (slot, feature, bin).
    /// Returns [n_slots * m * bins]; candidate b means "left = bins <= b".
    fn split_gains(
        &mut self,
        hist: &[f32],
        n_slots: usize,
        m: usize,
        bins: usize,
        k1: usize,
        lam: f32,
        mode: ScoreMode,
    ) -> Vec<f32>;

    /// Per-leaf sums of the full gradient/hessian matrices over `rows`.
    fn leaf_sums(
        &mut self,
        rows: &[u32],
        leaf_of_row: &[u32],
        g: &[f32],
        h: &[f32],
        d: usize,
        n_leaves: usize,
    ) -> LeafSums;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn channel_counts() {
        assert_eq!(ScoreMode::CountL2.channels(5), 6);
        assert_eq!(ScoreMode::HessL2.channels(5), 11);
        assert_eq!(ScoreMode::CountL2.channels(1), 2);
    }

    #[test]
    fn engine_opts_default_is_serial() {
        assert_eq!(EngineOpts::default().n_threads, 1);
        assert_eq!(EngineOpts::threads(4), EngineOpts { n_threads: 4 });
    }
}
