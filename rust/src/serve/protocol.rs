//! Line-delimited wire format for `sketchboost serve`.
//!
//! One request per line, one response line per request, in request
//! order per connection. Two request shapes:
//!
//! * **Data line** — comma-separated f32 feature values; multiple rows
//!   in one request are joined with `;`. Empty cells and `nan` parse as
//!   missing (NaN). The response has the same shape: `n_outputs`
//!   comma-separated scores per row, rows joined with `;`.
//! * **Control line** — starts with `/`: `/ping`, `/stats`, `/model`,
//!   `/shutdown`.
//!
//! Error responses are one line prefixed `!`.
//!
//! The format is bitwise-faithful for f32: values are printed with
//! Rust's `Display`, which emits the shortest string that parses back
//! to the identical bit pattern (including `-0`, subnormals, and
//! `inf`; NaN prints as `NaN` and parses back to a quiet NaN — the
//! same canonical NaN the offline CSV path produces). The protocol
//! round-trip test below pins this.

/// A parsed request line.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// `n_rows` feature rows of `width` values each, row-major.
    Rows { rows: Vec<f32>, n_rows: usize, width: usize },
    Ping,
    Stats,
    ModelInfo,
    Shutdown,
}

/// Parse one non-empty request line (the server skips blank lines).
pub fn parse_request(line: &str) -> Result<Request, String> {
    let line = line.trim();
    if line.is_empty() {
        return Err("empty request".to_string());
    }
    if let Some(verb) = line.strip_prefix('/') {
        return match verb {
            "ping" => Ok(Request::Ping),
            "stats" => Ok(Request::Stats),
            "model" => Ok(Request::ModelInfo),
            "shutdown" => Ok(Request::Shutdown),
            other => Err(format!("unknown control verb /{other}")),
        };
    }
    let mut rows = Vec::new();
    let mut width = 0usize;
    let mut n_rows = 0usize;
    for (r, row) in line.split(';').enumerate() {
        let start = rows.len();
        for cell in row.split(',') {
            rows.push(parse_cell(cell).map_err(|e| format!("row {r}: {e}"))?);
        }
        let w = rows.len() - start;
        if r == 0 {
            width = w;
        } else if w != width {
            return Err(format!("row {r} has {w} values, row 0 has {width}"));
        }
        n_rows += 1;
    }
    Ok(Request::Rows { rows, n_rows, width })
}

/// One feature cell: empty or `nan` (any case) means missing.
fn parse_cell(cell: &str) -> Result<f32, String> {
    let cell = cell.trim();
    if cell.is_empty() || cell.eq_ignore_ascii_case("nan") {
        return Ok(f32::NAN);
    }
    cell.parse::<f32>().map_err(|_| format!("bad value {cell:?}"))
}

/// Format a response for `n_rows = out.len() / d` scored rows: `d`
/// scores per row joined with `,`, rows joined with `;`.
pub fn format_scores(out: &[f32], d: usize) -> String {
    debug_assert!(d > 0 && out.len() % d == 0);
    let mut s = String::with_capacity(out.len() * 8);
    for (r, row) in out.chunks(d).enumerate() {
        if r > 0 {
            s.push(';');
        }
        for (c, v) in row.iter().enumerate() {
            if c > 0 {
                s.push(',');
            }
            // Display prints the shortest round-trip repr (bit-exact)
            s.push_str(&format!("{v}"));
        }
    }
    s
}

/// Format an error response line.
pub fn format_error(msg: &str) -> String {
    format!("!{}", msg.replace('\n', " "))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_control_verbs() {
        assert_eq!(parse_request("/ping"), Ok(Request::Ping));
        assert_eq!(parse_request("  /stats "), Ok(Request::Stats));
        assert_eq!(parse_request("/model"), Ok(Request::ModelInfo));
        assert_eq!(parse_request("/shutdown"), Ok(Request::Shutdown));
        assert!(parse_request("/nope").is_err());
    }

    #[test]
    fn parses_single_and_multi_row_requests() {
        match parse_request("1.5,2,3").unwrap() {
            Request::Rows { rows, n_rows, width } => {
                assert_eq!((n_rows, width), (1, 3));
                assert_eq!(rows, vec![1.5, 2.0, 3.0]);
            }
            other => panic!("{other:?}"),
        }
        match parse_request("1,2;3,4;5,6").unwrap() {
            Request::Rows { rows, n_rows, width } => {
                assert_eq!((n_rows, width), (3, 2));
                assert_eq!(rows, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn missing_cells_parse_as_nan() {
        match parse_request("1,,nan,NaN").unwrap() {
            Request::Rows { rows, width, .. } => {
                assert_eq!(width, 4);
                assert!(rows[1].is_nan() && rows[2].is_nan() && rows[3].is_nan());
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn rejects_ragged_and_garbage_rows() {
        assert!(parse_request("1,2;3").is_err());
        assert!(parse_request("1,abc").is_err());
        assert!(parse_request("").is_err());
    }

    /// The wire format must preserve every f32 bit pattern: print with
    /// Display, parse back, compare bits (NaN canonicalizes to the one
    /// quiet NaN `"NaN".parse()` yields, same as the offline CSV path).
    #[test]
    fn text_round_trip_is_bit_exact() {
        let adversarial = [
            0.0f32,
            -0.0,
            1.0,
            -1.5,
            f32::MIN_POSITIVE,
            f32::MIN_POSITIVE / 2.0, // subnormal
            f32::MAX,
            f32::EPSILON,
            0.1,
            1.0 / 3.0,
            core::f32::consts::PI,
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::NAN,
            12345.678,
            -9.869604e-18,
        ];
        let formatted = format_scores(&adversarial, adversarial.len());
        match parse_request(&formatted).unwrap() {
            Request::Rows { rows, n_rows, width } => {
                assert_eq!((n_rows, width), (1, adversarial.len()));
                for (i, (a, b)) in adversarial.iter().zip(&rows).enumerate() {
                    let same = a.to_bits() == b.to_bits()
                        || (a.is_nan() && b.to_bits() == f32::NAN.to_bits());
                    assert!(same, "cell {i}: {a:?} vs {b:?}");
                }
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn multi_row_scores_format() {
        assert_eq!(format_scores(&[1.0, -2.5, 3.0, 4.0], 2), "1,-2.5;3,4");
        assert_eq!(format_error("bad\nthing"), "!bad thing");
    }
}
