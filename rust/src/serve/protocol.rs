//! Line-delimited wire format for `sketchboost serve`.
//!
//! One request per line, one response line per request, in request
//! order per connection. Two request shapes:
//!
//! * **Data line** — comma-separated f32 feature values; multiple rows
//!   in one request are joined with `;`. Empty cells and `nan` parse as
//!   missing (NaN). The response has the same shape: `n_outputs`
//!   comma-separated scores per row, rows joined with `;`.
//! * **Control line** — starts with `/`: `/ping`, `/stats`, `/model`,
//!   `/shutdown`.
//!
//! Error responses are one line prefixed `!`.
//!
//! The format is bitwise-faithful for f32: values are printed with
//! Rust's `Display`, which emits the shortest string that parses back
//! to the identical bit pattern (including `-0`, subnormals, and
//! `inf`; NaN prints as `NaN` and parses back to a quiet NaN — the
//! same canonical NaN the offline CSV path produces). The protocol
//! round-trip test below pins this.

/// A parsed request line.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// `n_rows` feature rows of `width` values each, row-major.
    Rows { rows: Vec<f32>, n_rows: usize, width: usize },
    Ping,
    Stats,
    ModelInfo,
    Shutdown,
}

// Machine-readable error codes. Degraded-mode responses lead with one
// of these (`!<code>: <detail>` on the wire), so clients can branch on
// the code without parsing prose — and chaos tests can count each
// degradation path exactly.

/// The request expired before a worker scored it (`--deadline-ms`), or
/// an idle connection was reaped (`--idle-timeout-ms`).
pub const ERR_TIMEOUT: &str = "timeout";
/// The intake queue was full and the shed policy is `drop`.
pub const ERR_OVERLOADED: &str = "overloaded";
/// The request exceeded `--max-rows` or `--max-line-bytes`.
pub const ERR_TOO_LARGE: &str = "too_large";
/// A worker failed while scoring this request (panic isolation).
pub const ERR_INTERNAL: &str = "internal";

/// Compose a structured error message: `<code>: <detail>` (or just the
/// code). [`format_error`] prefixes the `!` when it goes on the wire.
pub fn error_msg(code: &str, detail: &str) -> String {
    if detail.is_empty() {
        code.to_string()
    } else {
        format!("{code}: {detail}")
    }
}

/// Parse one non-empty request line (the server skips blank lines).
/// Unlimited row count — the daemon calls
/// [`parse_request_limited`] with its configured cap.
pub fn parse_request(line: &str) -> Result<Request, String> {
    parse_request_limited(line, usize::MAX)
}

/// [`parse_request`] with a row cap: a data line with more than
/// `max_rows` rows is rejected *before* any cell is parsed (a
/// structured [`ERR_TOO_LARGE`] error, never an allocation
/// proportional to the oversized request).
pub fn parse_request_limited(line: &str, max_rows: usize) -> Result<Request, String> {
    let line = line.trim();
    if line.is_empty() {
        return Err("empty request".to_string());
    }
    if let Some(verb) = line.strip_prefix('/') {
        return match verb {
            "ping" => Ok(Request::Ping),
            "stats" => Ok(Request::Stats),
            "model" => Ok(Request::ModelInfo),
            "shutdown" => Ok(Request::Shutdown),
            other => Err(format!("unknown control verb /{other}")),
        };
    }
    let claimed_rows = line.as_bytes().iter().filter(|&&b| b == b';').count() + 1;
    if claimed_rows > max_rows {
        return Err(error_msg(
            ERR_TOO_LARGE,
            &format!("request has {claimed_rows} rows, limit is {max_rows}"),
        ));
    }
    let mut rows = Vec::new();
    let mut width = 0usize;
    let mut n_rows = 0usize;
    for (r, row) in line.split(';').enumerate() {
        let start = rows.len();
        for cell in row.split(',') {
            rows.push(parse_cell(cell).map_err(|e| format!("row {r}: {e}"))?);
        }
        let w = rows.len() - start;
        if r == 0 {
            width = w;
        } else if w != width {
            return Err(format!("row {r} has {w} values, row 0 has {width}"));
        }
        n_rows += 1;
    }
    Ok(Request::Rows { rows, n_rows, width })
}

/// One feature cell: empty or `nan` (any case) means missing.
fn parse_cell(cell: &str) -> Result<f32, String> {
    let cell = cell.trim();
    if cell.is_empty() || cell.eq_ignore_ascii_case("nan") {
        return Ok(f32::NAN);
    }
    cell.parse::<f32>().map_err(|_| format!("bad value {cell:?}"))
}

/// Format a response for `n_rows = out.len() / d` scored rows: `d`
/// scores per row joined with `,`, rows joined with `;`.
pub fn format_scores(out: &[f32], d: usize) -> String {
    debug_assert!(d > 0 && out.len() % d == 0);
    let mut s = String::with_capacity(out.len() * 8);
    for (r, row) in out.chunks(d).enumerate() {
        if r > 0 {
            s.push(';');
        }
        for (c, v) in row.iter().enumerate() {
            if c > 0 {
                s.push(',');
            }
            // Display prints the shortest round-trip repr (bit-exact)
            s.push_str(&format!("{v}"));
        }
    }
    s
}

/// Format an error response line.
pub fn format_error(msg: &str) -> String {
    format!("!{}", msg.replace('\n', " "))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_control_verbs() {
        assert_eq!(parse_request("/ping"), Ok(Request::Ping));
        assert_eq!(parse_request("  /stats "), Ok(Request::Stats));
        assert_eq!(parse_request("/model"), Ok(Request::ModelInfo));
        assert_eq!(parse_request("/shutdown"), Ok(Request::Shutdown));
        assert!(parse_request("/nope").is_err());
    }

    #[test]
    fn parses_single_and_multi_row_requests() {
        match parse_request("1.5,2,3").unwrap() {
            Request::Rows { rows, n_rows, width } => {
                assert_eq!((n_rows, width), (1, 3));
                assert_eq!(rows, vec![1.5, 2.0, 3.0]);
            }
            other => panic!("{other:?}"),
        }
        match parse_request("1,2;3,4;5,6").unwrap() {
            Request::Rows { rows, n_rows, width } => {
                assert_eq!((n_rows, width), (3, 2));
                assert_eq!(rows, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn missing_cells_parse_as_nan() {
        match parse_request("1,,nan,NaN").unwrap() {
            Request::Rows { rows, width, .. } => {
                assert_eq!(width, 4);
                assert!(rows[1].is_nan() && rows[2].is_nan() && rows[3].is_nan());
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn rejects_ragged_and_garbage_rows() {
        assert!(parse_request("1,2;3").is_err());
        assert!(parse_request("1,abc").is_err());
        assert!(parse_request("").is_err());
    }

    /// The wire format must preserve every f32 bit pattern: print with
    /// Display, parse back, compare bits (NaN canonicalizes to the one
    /// quiet NaN `"NaN".parse()` yields, same as the offline CSV path).
    #[test]
    fn text_round_trip_is_bit_exact() {
        let adversarial = [
            0.0f32,
            -0.0,
            1.0,
            -1.5,
            f32::MIN_POSITIVE,
            f32::MIN_POSITIVE / 2.0, // subnormal
            f32::MAX,
            f32::EPSILON,
            0.1,
            1.0 / 3.0,
            core::f32::consts::PI,
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::NAN,
            12345.678,
            -9.869604e-18,
        ];
        let formatted = format_scores(&adversarial, adversarial.len());
        match parse_request(&formatted).unwrap() {
            Request::Rows { rows, n_rows, width } => {
                assert_eq!((n_rows, width), (1, adversarial.len()));
                for (i, (a, b)) in adversarial.iter().zip(&rows).enumerate() {
                    let same = a.to_bits() == b.to_bits()
                        || (a.is_nan() && b.to_bits() == f32::NAN.to_bits());
                    assert!(same, "cell {i}: {a:?} vs {b:?}");
                }
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn multi_row_scores_format() {
        assert_eq!(format_scores(&[1.0, -2.5, 3.0, 4.0], 2), "1,-2.5;3,4");
        assert_eq!(format_error("bad\nthing"), "!bad thing");
    }

    #[test]
    fn error_codes_compose_structured_lines() {
        assert_eq!(error_msg(ERR_OVERLOADED, ""), "overloaded");
        assert_eq!(
            format_error(&error_msg(ERR_TIMEOUT, "queued past deadline")),
            "!timeout: queued past deadline"
        );
    }

    #[test]
    fn row_cap_rejects_oversized_requests_before_parsing_cells() {
        // under the cap: parses normally
        assert!(parse_request_limited("1,2;3,4", 2).is_ok());
        // over the cap: structured too_large, even though every cell is garbage
        // (the cap check must run before cell parsing)
        let err = parse_request_limited("x;y;z", 2).unwrap_err();
        assert!(err.starts_with(ERR_TOO_LARGE), "{err}");
        assert!(err.contains("3 rows"), "{err}");
        // control verbs are exempt
        assert_eq!(parse_request_limited("/ping", 1), Ok(Request::Ping));
    }
}
