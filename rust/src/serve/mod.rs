//! `sketchboost serve` — a micro-batching model server on [`FlatForest`].
//!
//! A dependency-free TCP daemon (std `TcpListener` only) that extends
//! the repo's determinism story to the network edge: any interleaving
//! of requests returns responses **bitwise-equal** to offline
//! [`FlatForest`](crate::predict::FlatForest) predict on the same rows.
//!
//! Structure:
//!
//! * [`protocol`] — the line-delimited wire format: one request per
//!   line (CSV rows or a `/`-prefixed control verb), one response line
//!   per request, in order. f32 values survive the text round trip
//!   bit-for-bit because Rust's `Display` prints the shortest
//!   round-trip representation.
//! * [`queue`] — the intake side: per-request completion slots plus the
//!   [`Coalescer`](queue::Coalescer), which merges concurrent requests
//!   into one cache-sized block for the PR 3 batch driver.
//! * [`server`] — the daemon: accept loop, per-connection reader/writer
//!   pair (pipelined, responses stay FIFO per connection), scoring
//!   workers with warm tile buffers, model hot-swap watcher, graceful
//!   drain on shutdown.
//! * [`stats`] — lock-free counters and log-bucket latency histograms
//!   behind the `/stats` verb.
//!
//! ## Correctness invariants (pinned by `rust/tests/serve_integration.rs`
//! and the serving property in `rust/tests/properties.rs`)
//!
//! 1. **Bit-equality**: workers score through the same
//!    [`predict_block_into`](crate::predict::FlatForest::predict_block_into)
//!    the offline driver uses, and a row's score depends only on that
//!    row — so batching decisions can never change a single bit.
//! 2. **No torn responses**: the coalescing unit is the whole request;
//!    a request's rows are never split across two forest snapshots, so
//!    under a hot-swap every response matches exactly one model.
//! 3. **Graceful drain**: shutdown stops intake first, then drains
//!    every queued job before workers exit — no request is dropped
//!    after its submission succeeded.
//!
//! ## Failure model (pinned by `rust/tests/serve_chaos.rs` under the
//! `fault-injection` feature)
//!
//! Overload, slow clients, oversized requests, worker panics, and
//! failed hot-swaps all degrade into *structured*, *bounded*, *counted*
//! behavior — deadlines shed with `!timeout`, full queues with
//! `!overloaded` (policy [`ShedPolicy`]), size caps with `!too_large`,
//! isolated worker panics with `!internal` — and two meta-invariants
//! hold under **any** injected fault plan: every non-error response is
//! still bitwise-equal to offline predict, and the drain still
//! terminates. See `DESIGN.md` §4e for the full failure model.

pub mod protocol;
pub mod queue;
pub mod server;
pub mod stats;

pub use queue::{Coalescer, Job, JobTicket};
pub use server::{score_batch, ServeOptions, Server, ShedPolicy};
pub use stats::ServeStats;
