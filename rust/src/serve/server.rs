//! The `sketchboost serve` daemon: accept loop, pipelined connection
//! handlers, micro-batching scoring workers, and the model hot-swap
//! watcher — std networking and threads only, no external crates.
//!
//! ## Thread layout
//!
//! * **accept** — one thread on a nonblocking listener; spawns a
//!   handler per connection and joins them all before it exits, so the
//!   drain in [`Server::stop`] only has to join this one handle to know
//!   every connection is gone.
//! * **per connection** — a *reader* (parses lines, submits jobs,
//!   answers control verbs) feeding a *writer* over an in-order
//!   channel. Responses stay FIFO per connection while the client
//!   pipelines requests — which is exactly what lets concurrent
//!   single-row clients coalesce server-side.
//! * **workers** — `n_workers` scoring loops: pull a batch from the
//!   [`Coalescer`], snapshot the [`SharedForest`] once per batch, score
//!   through the shared offline block kernel
//!   ([`FlatForest::accumulate_block`](crate::predict::FlatForest)
//!   behind the [`Predictor`]) with a warm per-worker tile.
//!   A panic while scoring is **isolated**: it poisons only the jobs of
//!   the affected request (their clients get `!internal`), the worker
//!   respawns, and the connection stays usable.
//! * **watcher** (optional) — polls the model path's content
//!   fingerprint (mtime, len, head/tail hash) and atomically swaps in
//!   freshly loaded models; a failed load keeps the old model serving
//!   and retries with capped exponential backoff. Writers are expected
//!   to replace the file atomically (write-new + rename).
//!
//! ## Degraded modes
//!
//! Every way the server departs from normal service is structured,
//! bounded, and counted in [`ServeStats`]:
//!
//! * `--deadline-ms` — a request that waits in the queue past its
//!   deadline is shed with `!timeout` instead of scored late.
//! * `--shed drop` — when the intake queue is full, answer
//!   `!overloaded` immediately instead of parking the reader
//!   (`--shed block`, the default, keeps bounded-blocking backpressure).
//! * `--max-rows` / `--max-line-bytes` — oversized requests get
//!   `!too_large` before any proportional allocation happens.
//! * `--idle-timeout-ms` — connections with no complete request for
//!   that long are reaped (slow-loris / half-open defense).
//!
//! The invariant underneath all of them: a degraded request gets a
//! structured `!<code>` line or a closed connection — **every response
//! that is not an error is still bitwise-equal to offline predict**,
//! and the drain in [`Server::stop`] terminates under any mix of these
//! modes (the chaos suite in `rust/tests/serve_chaos.rs` drives this
//! with injected faults).
//!
//! ## Shutdown ordering (deadlock-free drain)
//!
//! `stop` sets the flag, then joins in dependency order: the accept
//! loop stops; readers notice the flag within one read-timeout tick,
//! stop submitting, and join their writers (which block on outstanding
//! [`JobTicket`]s — workers are still running here, so those tickets
//! all complete); once every connection is joined the coalescer is
//! closed; workers drain the remaining queue and exit; the watcher
//! exits on its next poll tick. No request whose submission succeeded
//! is ever left hanging: scored, shed with a structured error, or —
//! if a worker dies with it — poisoned by the [`Job`] drop backstop.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant, SystemTime};

use crate::boosting::ensemble::Ensemble;
use crate::predict::{ForestLayout, PredictOptions, Predictor, SharedForest, DEFAULT_BLOCK_ROWS};
use crate::serve::protocol::{
    error_msg, format_error, format_scores, parse_request_limited, Request, ERR_INTERNAL,
    ERR_OVERLOADED, ERR_TIMEOUT, ERR_TOO_LARGE,
};
use crate::serve::queue::{Coalescer, Job, JobTicket};
use crate::serve::stats::ServeStats;
use crate::util::fault;
use crate::util::fault::fnv1a64_with;
use crate::util::json::Json;
use crate::util::threading::TryPush;

/// What to do with a request when the intake queue is full.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedPolicy {
    /// Block the connection's reader until there is room (bounded
    /// backpressure — the pre-hardening behavior, and the default).
    Block,
    /// Refuse immediately with a structured `!overloaded` error.
    Drop,
}

impl ShedPolicy {
    /// Parse the CLI spelling (`block` | `drop`).
    pub fn parse(s: &str) -> Result<ShedPolicy, String> {
        match s {
            "block" => Ok(ShedPolicy::Block),
            "drop" => Ok(ShedPolicy::Drop),
            other => Err(format!("unknown shed policy {other:?} (expected block|drop)")),
        }
    }

    /// The CLI spelling (inverse of [`ShedPolicy::parse`]).
    pub fn as_str(&self) -> &'static str {
        match self {
            ShedPolicy::Block => "block",
            ShedPolicy::Drop => "drop",
        }
    }
}

/// Knobs for the serving daemon (CLI: `sketchboost serve`).
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Address to bind (default loopback).
    pub bind: String,
    /// TCP port; `0` asks the OS for an ephemeral port (tests use this).
    pub port: u16,
    /// Scoring worker threads (each owns a warm tile buffer).
    pub n_workers: usize,
    /// Rows per scoring block — the coalescing target: a batch closes
    /// as soon as it holds this many rows.
    pub block_rows: usize,
    /// How long a batch waits for more requests once it has its first
    /// one, in microseconds. `0` still coalesces already-queued jobs.
    pub max_wait_us: u64,
    /// Bounded intake queue capacity, in jobs (backpressure bound).
    pub queue_cap: usize,
    /// Model-file poll interval for hot-swap; `0` disables watching.
    pub poll_ms: u64,
    /// Per-request deadline in milliseconds, measured from submission;
    /// a request still queued past it is shed with `!timeout`.
    /// `0` disables deadlines.
    pub deadline_ms: u64,
    /// Full-queue policy (see [`ShedPolicy`]).
    pub shed: ShedPolicy,
    /// Maximum rows per request; larger data lines get `!too_large`
    /// before their cells are parsed.
    pub max_rows: usize,
    /// Maximum bytes per request line; longer lines get `!too_large`
    /// and are discarded without buffering (never OOM on one line).
    pub max_line_bytes: usize,
    /// Reap a connection after this long with no complete request
    /// (slow-loris / half-open defense). `0` disables reaping.
    pub idle_timeout_ms: u64,
    /// Node/leaf layout the model compiles into (`v1` | `v2` | `v2q`);
    /// hot-swapped models recompile into the same layout. `v1` and
    /// `v2` are bitwise-identical; `v2q` quantizes leaf values unless
    /// [`ServeOptions::exact_leaves`] is set.
    pub layout: ForestLayout,
    /// Keep f32 leaf values under the `v2q` layout (bitwise-exactness
    /// escape hatch; no effect on other layouts).
    pub exact_leaves: bool,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            bind: "127.0.0.1".to_string(),
            port: 0,
            n_workers: 1,
            block_rows: DEFAULT_BLOCK_ROWS,
            max_wait_us: 250,
            queue_cap: 1024,
            poll_ms: 0,
            deadline_ms: 0,
            shed: ShedPolicy::Block,
            max_rows: 4096,
            max_line_bytes: 1 << 20,
            idle_timeout_ms: 0,
            layout: ForestLayout::V1,
            exact_leaves: false,
        }
    }
}

/// Everything the server's threads share.
struct Shared {
    forest: SharedForest,
    coalescer: Coalescer,
    stats: ServeStats,
    shutdown: AtomicBool,
    shutdown_cv: (Mutex<bool>, Condvar),
    model_path: PathBuf,
    /// `deadline_ms` as a duration (`None` = no deadlines).
    deadline: Option<Duration>,
    shed: ShedPolicy,
    max_rows: usize,
    max_line_bytes: usize,
    /// `idle_timeout_ms` as a duration (`None` = never reap).
    idle_timeout: Option<Duration>,
    /// Layout + batching knobs hot-swapped models recompile with.
    predict_opts: PredictOptions,
}

impl Shared {
    fn signal_shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        let (lock, cvar) = &self.shutdown_cv;
        // the guarded value is a single bool; recover a poisoned lock so
        // shutdown always propagates even after a panicked thread
        *lock.lock().unwrap_or_else(|e| e.into_inner()) = true;
        cvar.notify_all();
    }
}

/// A running daemon; drop-in for tests via an ephemeral port.
pub struct Server {
    shared: Arc<Shared>,
    addr: SocketAddr,
    accept: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    watcher: Option<JoinHandle<()>>,
}

impl Server {
    /// Load the model at `model_path` and start serving. Returns once
    /// the listener is bound and every thread is up.
    pub fn start(model_path: &Path, opts: &ServeOptions) -> Result<Server, String> {
        let model = Ensemble::load(model_path)?;
        let predict_opts = PredictOptions::default()
            .with_layout(opts.layout)
            .with_exact_leaves(opts.exact_leaves);
        let predictor = Predictor::compile(&model, predict_opts);
        let listener = TcpListener::bind((opts.bind.as_str(), opts.port))
            .map_err(|e| format!("bind {}:{}: {e}", opts.bind, opts.port))?;
        let addr = listener.local_addr().map_err(|e| e.to_string())?;
        listener.set_nonblocking(true).map_err(|e| e.to_string())?;

        let shared = Arc::new(Shared {
            forest: SharedForest::new(predictor),
            coalescer: Coalescer::new(opts.queue_cap.max(1)),
            stats: ServeStats::new(),
            shutdown: AtomicBool::new(false),
            shutdown_cv: (Mutex::new(false), Condvar::new()),
            model_path: model_path.to_path_buf(),
            deadline: (opts.deadline_ms > 0).then(|| Duration::from_millis(opts.deadline_ms)),
            shed: opts.shed,
            max_rows: opts.max_rows.max(1),
            max_line_bytes: opts.max_line_bytes.max(64),
            idle_timeout: (opts.idle_timeout_ms > 0)
                .then(|| Duration::from_millis(opts.idle_timeout_ms)),
            predict_opts,
        });

        let mut workers = Vec::new();
        let block_rows = opts.block_rows.max(1);
        let max_wait = Duration::from_micros(opts.max_wait_us);
        for _ in 0..opts.n_workers.max(1) {
            let sh = shared.clone();
            workers.push(std::thread::spawn(move || worker_loop(&sh, block_rows, max_wait)));
        }

        let watcher = if opts.poll_ms > 0 {
            let sh = shared.clone();
            let poll = Duration::from_millis(opts.poll_ms);
            Some(std::thread::spawn(move || watcher_loop(&sh, poll)))
        } else {
            None
        };

        let sh = shared.clone();
        let accept = std::thread::spawn(move || accept_loop(listener, &sh));

        Ok(Server { shared, addr, accept: Some(accept), workers, watcher })
    }

    /// The bound address (read the real port here when `port = 0`).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Version of the currently installed model (bumps on hot-swap).
    pub fn model_version(&self) -> u64 {
        self.shared.forest.version()
    }

    /// Block until shutdown is requested (`/shutdown` or [`Server::stop`]).
    pub fn wait(&self) {
        let (lock, cvar) = &self.shared.shutdown_cv;
        // poison-recovered like signal_shutdown: the bool is trivially
        // consistent, and wait() must return once shutdown is signalled
        let mut down = lock.lock().unwrap_or_else(|e| e.into_inner());
        while !*down {
            down = cvar.wait(down).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Drain and stop every thread (see the module docs for the order).
    pub fn stop(mut self) {
        self.shared.signal_shutdown();
        if let Some(h) = self.accept.take() {
            let _ = h.join(); // joins every connection handler too
        }
        self.shared.coalescer.close();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        if let Some(h) = self.watcher.take() {
            let _ = h.join();
        }
    }
}

/// Accept connections until shutdown; join every handler before exit.
fn accept_loop(listener: TcpListener, shared: &Arc<Shared>) {
    let mut handlers: Vec<JoinHandle<()>> = Vec::new();
    while !shared.shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let sh = shared.clone();
                handlers.push(std::thread::spawn(move || handle_connection(stream, &sh)));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    }
    for h in handlers {
        let _ = h.join();
    }
}

/// A response the writer thread will emit, in submission order.
enum Pending {
    /// Already-formatted response line.
    Immediate(String),
    /// A scored request: wait on the ticket, then format.
    Scored { ticket: JobTicket, n_rows: usize },
}

/// Reader half of one connection: parse lines, submit jobs, keep the
/// writer fed in request order.
///
/// Two connection-level defenses live here:
///
/// * **Line cap** — once the buffered partial line exceeds
///   `max_line_bytes`, the buffer is dropped, one `!too_large` response
///   is queued, and the reader switches to *discard mode*: bytes are
///   thrown away until the newline that ends the oversized line. Memory
///   stays bounded by one read chunk no matter how long the line is.
/// * **Idle reaping** — with `idle_timeout_ms` set, a connection that
///   completes no request for that long (slow loris dribbling bytes, a
///   half-open peer sending nothing) is closed after one `!timeout`
///   notice.
fn handle_connection(mut stream: TcpStream, shared: &Arc<Shared>) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(50)));
    let write_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let (tx, rx) = mpsc::channel::<Pending>();
    let writer = std::thread::spawn(move || writer_loop(write_half, rx));

    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 4096];
    let mut discarding = false;
    let mut last_line = Instant::now();
    'read: loop {
        if shared.shutdown.load(Ordering::SeqCst) {
            break;
        }
        let n = match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => n,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if let Some(idle) = shared.idle_timeout {
                    if last_line.elapsed() >= idle {
                        shared.stats.n_idle_closed.fetch_add(1, Ordering::Relaxed);
                        let _ = tx.send(Pending::Immediate(format_error(&error_msg(
                            ERR_TIMEOUT,
                            "idle connection closed",
                        ))));
                        break;
                    }
                }
                continue;
            }
            Err(_) => break,
        };
        if discarding {
            // inside an oversized line: drop bytes until its newline
            match chunk[..n].iter().position(|&b| b == b'\n') {
                Some(eol) => {
                    discarding = false;
                    buf.extend_from_slice(&chunk[eol + 1..n]);
                }
                None => continue,
            }
        } else {
            buf.extend_from_slice(&chunk[..n]);
        }
        // process every complete line; keep the partial tail buffered
        while let Some(eol) = buf.iter().position(|&b| b == b'\n') {
            let line: Vec<u8> = buf.drain(..=eol).collect();
            let line = String::from_utf8_lossy(&line[..eol]);
            let line = line.trim();
            last_line = Instant::now();
            if line.is_empty() {
                continue;
            }
            if !handle_line(line, shared, &tx) {
                break 'read;
            }
        }
        if buf.len() > shared.max_line_bytes {
            // the partial line is already over budget: refuse it now
            // and stop buffering its bytes
            shared.stats.n_too_large.fetch_add(1, Ordering::Relaxed);
            shared.stats.n_errors.fetch_add(1, Ordering::Relaxed);
            let _ = tx.send(Pending::Immediate(format_error(&error_msg(
                ERR_TOO_LARGE,
                &format!("request line exceeds {} bytes", shared.max_line_bytes),
            ))));
            buf.clear();
            buf.shrink_to_fit();
            discarding = true;
            last_line = Instant::now();
        }
    }
    drop(tx);
    let _ = writer.join();
}

/// Handle one request line; returns `false` when the connection's read
/// loop should end (shutdown requested).
fn handle_line(line: &str, shared: &Arc<Shared>, tx: &mpsc::Sender<Pending>) -> bool {
    match parse_request_limited(line, shared.max_rows) {
        Err(e) => {
            if e.starts_with(ERR_TOO_LARGE) {
                shared.stats.n_too_large.fetch_add(1, Ordering::Relaxed);
            }
            shared.stats.n_errors.fetch_add(1, Ordering::Relaxed);
            let _ = tx.send(Pending::Immediate(format_error(&e)));
        }
        Ok(Request::Rows { rows, n_rows, width }) => {
            let (mut job, ticket) = Job::new(rows, n_rows, width);
            job.deadline = shared.deadline.map(|d| job.enqueued + d);
            let submitted = match shared.shed {
                ShedPolicy::Block => match shared.coalescer.submit(job) {
                    Ok(depth) => Ok(depth),
                    Err(job) => Err((job, "server is shutting down".to_string())),
                },
                ShedPolicy::Drop => match shared.coalescer.try_submit(job) {
                    TryPush::Pushed(depth) => Ok(depth),
                    TryPush::Full(job) => {
                        shared.stats.n_shed.fetch_add(1, Ordering::Relaxed);
                        Err((job, error_msg(ERR_OVERLOADED, "intake queue is full")))
                    }
                    TryPush::Closed(job) => Err((job, "server is shutting down".to_string())),
                },
            };
            match submitted {
                Ok(depth) => {
                    shared.stats.note_queue_depth(depth);
                    let _ = tx.send(Pending::Scored { ticket, n_rows });
                }
                Err((rejected, msg)) => {
                    // complete the job ourselves so its drop backstop
                    // doesn't report a misleading `internal`
                    rejected.complete(Err(msg.clone()));
                    drop(ticket); // response goes out as Immediate below
                    shared.stats.n_errors.fetch_add(1, Ordering::Relaxed);
                    let _ = tx.send(Pending::Immediate(format_error(&msg)));
                }
            }
        }
        Ok(Request::Ping) => {
            let _ = tx.send(Pending::Immediate("ok".to_string()));
        }
        Ok(Request::Stats) => {
            let j = shared
                .stats
                .to_json(shared.forest.version(), shared.coalescer.len());
            let _ = tx.send(Pending::Immediate(j.to_string()));
        }
        Ok(Request::ModelInfo) => {
            let p = shared.forest.snapshot();
            let f = p.forest();
            let mut j = Json::obj();
            j.set("model_version", Json::Num(shared.forest.version() as f64))
                .set("n_outputs", Json::Num(f.n_outputs as f64))
                .set("n_trees", Json::Num(f.n_trees() as f64))
                .set("n_features_required", Json::Num(f.n_features_required() as f64))
                .set("layout", Json::Str(f.layout().as_str().to_string()))
                .set("path", Json::Str(shared.model_path.display().to_string()));
            let _ = tx.send(Pending::Immediate(j.to_string()));
        }
        Ok(Request::Shutdown) => {
            let _ = tx.send(Pending::Immediate("ok shutting down".to_string()));
            shared.signal_shutdown();
            return false;
        }
    }
    true
}

/// Writer half of one connection: emit responses strictly in request
/// order, flushing per line so single-row clients see low latency.
fn writer_loop(stream: TcpStream, rx: mpsc::Receiver<Pending>) {
    let mut out = std::io::BufWriter::new(stream);
    for pending in rx {
        let line = match pending {
            Pending::Immediate(s) => s,
            Pending::Scored { ticket, n_rows } => match ticket.wait() {
                Ok(scores) => {
                    let d = scores.len() / n_rows.max(1);
                    format_scores(&scores, d.max(1))
                }
                Err(e) => format_error(&e),
            },
        };
        if out.write_all(line.as_bytes()).is_err() || out.write_all(b"\n").is_err() {
            return;
        }
        if out.flush().is_err() {
            return;
        }
    }
}

/// One scoring worker: batch → snapshot → score, with a warm tile.
///
/// The whole loop runs under `catch_unwind`, so a panic that escapes
/// [`score_batch`]'s per-request isolation (a bug in batch handling
/// itself, or an injected `serve.worker.score:panic` that fires outside
/// the per-job guard) does not silently shrink the worker pool: jobs
/// still in the dying batch resolve to `!internal` via the [`Job`] drop
/// backstop, and the loop restarts with a fresh tile — the respawned
/// worker keeps draining, so shutdown still terminates.
fn worker_loop(shared: &Arc<Shared>, block_rows: usize, max_wait: Duration) {
    loop {
        let run = catch_unwind(AssertUnwindSafe(|| {
            let mut tile: Vec<f32> = Vec::new();
            while let Some(batch) = shared.coalescer.next_batch(block_rows, max_wait) {
                // one snapshot per batch: every job in it scores against a
                // single, internally consistent forest (hot-swap invariant)
                let pred = shared.forest.snapshot();
                score_batch(&pred, batch, block_rows, &mut tile, &shared.stats);
            }
        }));
        match run {
            Ok(()) => return, // coalescer closed and drained
            Err(_) => {
                shared.stats.n_worker_panics.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

/// Score one coalesced batch of jobs against `pred`'s compiled forest,
/// reusing `tile` as the gather buffer. Public because the serving
/// property test drives it directly (random batch boundaries, no
/// sockets).
///
/// Rows are gathered `required`-features-wide and driven through
/// `FlatForest::predict_block_into` in `block_rows`-sized blocks — the
/// same kernel and the same per-row arithmetic as offline
/// [`Predictor::raw`], which is what makes serving responses
/// bitwise-equal to offline predict by construction (exactly, under the
/// `v1`/`v2` layouts; within the model's
/// [`leaf_quant_error`](crate::predict::FlatForest::leaf_quant_error)
/// bound under `v2q`).
///
/// Degradation paths, per job:
///
/// * a job popped after its [`Job::deadline`] is shed with `!timeout`
///   (scoring it late would waste a block on an answer nobody reads);
/// * scoring runs under `catch_unwind`, so one request's panic (the
///   `serve.worker.score` fault point fires inside the guard) resolves
///   *that* job to `!internal` and the rest of the batch scores
///   normally.
pub fn score_batch(
    pred: &Predictor,
    jobs: Vec<Job>,
    block_rows: usize,
    tile: &mut Vec<f32>,
    stats: &ServeStats,
) {
    let t0 = Instant::now();
    let forest = pred.forest();
    let d = forest.n_outputs;
    let required = forest.n_features_required();
    let w = required.max(1);
    let block = block_rows.max(1);
    tile.resize(block * w, 0.0);
    let (mut n_jobs, mut n_rows) = (0u64, 0u64);
    for job in jobs {
        if let Some(deadline) = job.deadline {
            if Instant::now() > deadline {
                stats.n_timeouts.fetch_add(1, Ordering::Relaxed);
                stats.n_errors.fetch_add(1, Ordering::Relaxed);
                job.complete(Err(error_msg(ERR_TIMEOUT, "request expired in queue")));
                continue;
            }
        }
        if job.width < required {
            stats.n_errors.fetch_add(1, Ordering::Relaxed);
            job.complete(Err(format!(
                "request rows have {} features but the model splits on feature index {}",
                job.width,
                required - 1
            )));
            continue;
        }
        let scored = catch_unwind(AssertUnwindSafe(|| {
            fault::failpoint("serve.worker.score")?;
            let mut scores = vec![0.0f32; job.n_rows * d];
            let mut start = 0usize;
            while start < job.n_rows {
                let end = (start + block).min(job.n_rows);
                let rows = end - start;
                for i in 0..rows {
                    let src = (start + i) * job.width;
                    tile[i * w..(i + 1) * w].copy_from_slice(&job.rows[src..src + w]);
                }
                forest.predict_block_into(
                    &tile[..rows * w],
                    w,
                    rows,
                    &mut scores[start * d..end * d],
                );
                start = end;
            }
            Ok(scores)
        }));
        match scored {
            Ok(Ok(scores)) => {
                n_jobs += 1;
                n_rows += job.n_rows as u64;
                stats
                    .request_latency
                    .record(job.enqueued.elapsed().as_micros() as u64);
                job.complete(Ok(scores));
            }
            Ok(Err(e)) => {
                // injected `fail` (or future fallible scoring): this
                // request only
                stats.n_errors.fetch_add(1, Ordering::Relaxed);
                job.complete(Err(error_msg(ERR_INTERNAL, &e)));
            }
            Err(_panic) => {
                stats.n_worker_panics.fetch_add(1, Ordering::Relaxed);
                stats.n_errors.fetch_add(1, Ordering::Relaxed);
                job.complete(Err(error_msg(ERR_INTERNAL, "scoring worker panicked")));
            }
        }
    }
    if n_jobs > 0 {
        stats.record_batch(n_jobs, n_rows, t0.elapsed().as_micros() as u64);
    }
}

/// Longest backoff between reload attempts after repeated failures.
const SWAP_BACKOFF_CAP: Duration = Duration::from_secs(5);

/// Poll the model file and hot-swap on change. Only a *successfully
/// loaded* file updates the seen fingerprint, so a torn or mid-write
/// file is retried until its writer finishes (atomic rename never
/// exposes one).
///
/// A failed load (corrupt file, transient IO error, injected
/// `serve.swap.load` fault — even a panic inside the loader) never
/// disturbs the serving model: the failure is counted in
/// `swap_failures` and the retry interval backs off exponentially
/// (doubling per consecutive failure, capped at [`SWAP_BACKOFF_CAP`]),
/// so a persistently broken file does not turn the watcher into a busy
/// loop. The first success resets the backoff.
fn watcher_loop(shared: &Arc<Shared>, poll: Duration) {
    let mut seen = fingerprint(&shared.model_path);
    let tick = poll.min(Duration::from_millis(50)).max(Duration::from_millis(1));
    let mut elapsed = Duration::ZERO;
    let mut fail_streak = 0u32;
    while !shared.shutdown.load(Ordering::SeqCst) {
        std::thread::sleep(tick);
        elapsed += tick;
        let wait = backoff(poll, fail_streak);
        if elapsed < wait {
            continue;
        }
        elapsed = Duration::ZERO;
        let now = fingerprint(&shared.model_path);
        if now.is_none() || now == seen {
            continue;
        }
        let loaded = catch_unwind(AssertUnwindSafe(|| {
            fault::failpoint("serve.swap.load").and_then(|()| Ensemble::load(&shared.model_path))
        }))
        .unwrap_or_else(|_| Err("model loader panicked".to_string()));
        match loaded {
            Ok(model) => {
                // recompile into the same layout the server started with
                shared.forest.swap(Predictor::compile(&model, shared.predict_opts));
                shared.stats.n_reloads.fetch_add(1, Ordering::Relaxed);
                seen = now;
                fail_streak = 0;
            }
            Err(_) => {
                // keep serving the old model; retry after backoff
                shared.stats.n_swap_failures.fetch_add(1, Ordering::Relaxed);
                fail_streak = fail_streak.saturating_add(1);
            }
        }
    }
}

/// Reload-retry interval after `fail_streak` consecutive failures:
/// `poll * 2^streak`, capped (and never below `poll`).
fn backoff(poll: Duration, fail_streak: u32) -> Duration {
    poll.saturating_mul(1u32 << fail_streak.min(6)).min(SWAP_BACKOFF_CAP).max(poll)
}

/// How many bytes of the file's head and tail go into the content hash.
const FINGERPRINT_SPAN: usize = 4096;

/// Identity of the watched model file on disk.
///
/// (mtime, len) alone is not enough: a same-length rewrite landing
/// within the filesystem's mtime granularity (coarse on some systems)
/// would be invisible, and the stale model would keep serving. The
/// hash of the first and last [`FINGERPRINT_SPAN`] bytes catches any
/// such rewrite whose bytes differ near either end — O(1) IO per poll
/// regardless of model size, and model JSON carries its varying parts
/// (version counters, tree payload) in exactly those regions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct Fingerprint {
    mtime: SystemTime,
    len: u64,
    head_tail_hash: u64,
}

/// Content fingerprint of the watched model file.
fn fingerprint(path: &Path) -> Option<Fingerprint> {
    use std::io::{Seek, SeekFrom};
    let meta = std::fs::metadata(path).ok()?;
    let mtime = meta.modified().ok()?;
    let len = meta.len();
    let mut f = std::fs::File::open(path).ok()?;
    let span = FINGERPRINT_SPAN.min(len as usize);
    let mut buf = vec![0u8; span];
    f.read_exact(&mut buf).ok()?;
    let mut h = fnv1a64_with(0xcbf29ce484222325, &buf);
    if len as usize > span {
        f.seek(SeekFrom::End(-(span as i64))).ok()?;
        f.read_exact(&mut buf).ok()?;
        h = fnv1a64_with(h, &buf);
    }
    Some(Fingerprint { mtime, len, head_tail_hash: h })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A tiny two-tree forest plus jobs scored through `score_batch`
    /// must reproduce the per-row walker bits exactly — the socket-free
    /// core of the serving equality story.
    #[test]
    fn score_batch_matches_per_row_walker() {
        use crate::boosting::ensemble::{Ensemble, TrainHistory};
        use crate::boosting::losses::LossKind;
        use crate::tree::tree::{encode_leaf, Tree, TreeNode};
        let tree = Tree {
            n_outputs: 2,
            nodes: vec![TreeNode {
                feature: 1,
                bin: 0,
                threshold: 0.5,
                default_left: true,
                cats: None,
                left: encode_leaf(0),
                right: encode_leaf(1),
                gain: 1.0,
            }],
            leaf_values: vec![1.0, -1.0, 2.0, -2.0],
            n_leaves: 2,
        };
        let model = Ensemble {
            loss: LossKind::MSE,
            n_outputs: 2,
            base_score: vec![0.1, -0.1],
            trees: vec![tree],
            history: TrainHistory::default(),
        };
        let pred = Predictor::compile(&model, PredictOptions::default());
        let forest = pred.forest();
        let stats = ServeStats::new();
        let mut tile = Vec::new();

        // width 3 > required 2: extra features must be ignored
        let rows = vec![0.0, 0.0, 9.0, 0.0, 1.0, 9.0, 0.0, f32::NAN, 9.0];
        let (job, ticket) = Job::new(rows.clone(), 3, 3);
        score_batch(&pred, vec![job], 2, &mut tile, &stats);
        let got = ticket.wait().unwrap();
        for (i, want_leaf) in [(0usize, 0usize), (1, 1), (2, 0)] {
            let mut want = vec![0.1f32, -0.1];
            forest.add_leaf(0, want_leaf, &mut want);
            assert_eq!(&got[i * 2..i * 2 + 2], &want[..], "row {i}");
        }
        assert_eq!(stats.n_requests.load(Ordering::Relaxed), 1);
        assert_eq!(stats.n_rows.load(Ordering::Relaxed), 3);

        // too-narrow rows get an error, not a panic
        let (narrow, t2) = Job::new(vec![0.5], 1, 1);
        score_batch(&pred, vec![narrow], 2, &mut tile, &stats);
        let err = t2.wait().unwrap_err();
        assert!(err.contains("feature index 1"), "{err}");
        assert_eq!(stats.n_errors.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn serve_options_default_is_loopback_ephemeral() {
        let o = ServeOptions::default();
        assert_eq!(o.bind, "127.0.0.1");
        assert_eq!(o.port, 0);
        assert_eq!(o.block_rows, DEFAULT_BLOCK_ROWS);
        assert_eq!(o.poll_ms, 0);
        // hardening knobs default to the pre-hardening behavior:
        // no deadlines, blocking backpressure, generous size caps,
        // no idle reaping
        assert_eq!(o.deadline_ms, 0);
        assert_eq!(o.shed, ShedPolicy::Block);
        assert_eq!(o.max_rows, 4096);
        assert_eq!(o.max_line_bytes, 1 << 20);
        assert_eq!(o.idle_timeout_ms, 0);
        // layout defaults preserve the v1 bit-exact serving path
        assert_eq!(o.layout, ForestLayout::V1);
        assert!(!o.exact_leaves);
    }

    #[test]
    fn shed_policy_parses_its_cli_spellings() {
        for p in [ShedPolicy::Block, ShedPolicy::Drop] {
            assert_eq!(ShedPolicy::parse(p.as_str()), Ok(p));
        }
        assert!(ShedPolicy::parse("sometimes").is_err());
    }

    /// An expired job is shed with a structured timeout, not scored.
    #[test]
    fn score_batch_sheds_jobs_past_their_deadline() {
        use crate::boosting::ensemble::{Ensemble, TrainHistory};
        use crate::boosting::losses::LossKind;
        let model = Ensemble {
            loss: LossKind::MSE,
            n_outputs: 1,
            base_score: vec![0.5],
            trees: vec![],
            history: TrainHistory::default(),
        };
        let pred = Predictor::compile(&model, PredictOptions::default());
        let stats = ServeStats::new();
        let mut tile = Vec::new();
        let (mut expired, t_expired) = Job::new(vec![1.0], 1, 1);
        expired.deadline = Some(Instant::now() - Duration::from_millis(1));
        let (fresh, t_fresh) = Job::new(vec![1.0], 1, 1);
        score_batch(&pred, vec![expired, fresh], 4, &mut tile, &stats);
        let err = t_expired.wait().unwrap_err();
        assert!(err.starts_with(ERR_TIMEOUT), "{err}");
        assert_eq!(t_fresh.wait().unwrap(), vec![0.5]);
        assert_eq!(stats.n_timeouts.load(Ordering::Relaxed), 1);
        assert_eq!(stats.n_requests.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn swap_backoff_doubles_and_caps() {
        let poll = Duration::from_millis(100);
        assert_eq!(backoff(poll, 0), poll);
        assert_eq!(backoff(poll, 1), Duration::from_millis(200));
        assert_eq!(backoff(poll, 3), Duration::from_millis(800));
        assert_eq!(backoff(poll, 6), SWAP_BACKOFF_CAP);
        assert_eq!(backoff(poll, 60), SWAP_BACKOFF_CAP); // shift stays in range
        // backoff never dips below the poll interval, even for huge polls
        let slow = Duration::from_secs(30);
        assert_eq!(backoff(slow, 4), slow);
    }

    /// The regression that motivated content hashing: two models of the
    /// *same byte length* must fingerprint differently, because (mtime,
    /// len) can collide when a same-length rewrite lands within the
    /// filesystem's mtime granularity.
    #[test]
    fn fingerprint_distinguishes_same_length_rewrites() {
        let dir = std::env::temp_dir()
            .join(format!("sb_fingerprint_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.json");

        let a = vec![b'a'; 10_000]; // bigger than one hash span
        let mut b = a.clone();
        let mid = b.len() / 2;
        b[5] = b'x'; // head difference
        b[mid] = b'y'; // middle difference (outside both spans — allowed to miss)
        std::fs::write(&path, &a).unwrap();
        let fp_a = fingerprint(&path).unwrap();
        std::fs::write(&path, &b).unwrap();
        let fp_b = fingerprint(&path).unwrap();
        assert_eq!(fp_a.len, fp_b.len);
        assert_ne!(fp_a.head_tail_hash, fp_b.head_tail_hash);

        // tail-only difference is caught too
        let mut c = a.clone();
        let last = c.len() - 3;
        c[last] = b'z';
        std::fs::write(&path, &c).unwrap();
        let fp_c = fingerprint(&path).unwrap();
        assert_ne!(fp_a.head_tail_hash, fp_c.head_tail_hash);

        // short files (under one span) hash their whole contents
        std::fs::write(&path, b"tiny-a").unwrap();
        let small_a = fingerprint(&path).unwrap();
        std::fs::write(&path, b"tiny-b").unwrap();
        let small_b = fingerprint(&path).unwrap();
        assert_ne!(small_a.head_tail_hash, small_b.head_tail_hash);

        std::fs::remove_dir_all(&dir).ok();
    }
}
