//! The `sketchboost serve` daemon: accept loop, pipelined connection
//! handlers, micro-batching scoring workers, and the model hot-swap
//! watcher — std networking and threads only, no external crates.
//!
//! ## Thread layout
//!
//! * **accept** — one thread on a nonblocking listener; spawns a
//!   handler per connection and joins them all before it exits, so the
//!   drain in [`Server::stop`] only has to join this one handle to know
//!   every connection is gone.
//! * **per connection** — a *reader* (parses lines, submits jobs,
//!   answers control verbs) feeding a *writer* over an in-order
//!   channel. Responses stay FIFO per connection while the client
//!   pipelines requests — which is exactly what lets concurrent
//!   single-row clients coalesce server-side.
//! * **workers** — `n_workers` scoring loops: pull a batch from the
//!   [`Coalescer`], snapshot the [`SharedForest`] once per batch, score
//!   through the shared offline block kernel
//!   ([`FlatForest::predict_block_into`]) with a warm per-worker tile.
//! * **watcher** (optional) — polls the model path's (mtime, len) and
//!   atomically swaps in freshly loaded models; a failed load keeps
//!   the old model serving and retries next tick. Writers are expected
//!   to replace the file atomically (write-new + rename).
//!
//! ## Shutdown ordering (deadlock-free drain)
//!
//! `stop` sets the flag, then joins in dependency order: the accept
//! loop stops; readers notice the flag within one read-timeout tick,
//! stop submitting, and join their writers (which block on outstanding
//! [`JobTicket`]s — workers are still running here, so those tickets
//! all complete); once every connection is joined the coalescer is
//! closed; workers drain the remaining queue and exit; the watcher
//! exits on its next poll tick. No request whose submission succeeded
//! is ever dropped.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant, SystemTime};

use crate::boosting::ensemble::Ensemble;
use crate::predict::{FlatForest, SharedForest, DEFAULT_BLOCK_ROWS};
use crate::serve::protocol::{format_error, format_scores, parse_request, Request};
use crate::serve::queue::{Coalescer, Job, JobTicket};
use crate::serve::stats::ServeStats;
use crate::util::json::Json;

/// Knobs for the serving daemon (CLI: `sketchboost serve`).
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Address to bind (default loopback).
    pub bind: String,
    /// TCP port; `0` asks the OS for an ephemeral port (tests use this).
    pub port: u16,
    /// Scoring worker threads (each owns a warm tile buffer).
    pub n_workers: usize,
    /// Rows per scoring block — the coalescing target: a batch closes
    /// as soon as it holds this many rows.
    pub block_rows: usize,
    /// How long a batch waits for more requests once it has its first
    /// one, in microseconds. `0` still coalesces already-queued jobs.
    pub max_wait_us: u64,
    /// Bounded intake queue capacity, in jobs (backpressure bound).
    pub queue_cap: usize,
    /// Model-file poll interval for hot-swap; `0` disables watching.
    pub poll_ms: u64,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            bind: "127.0.0.1".to_string(),
            port: 0,
            n_workers: 1,
            block_rows: DEFAULT_BLOCK_ROWS,
            max_wait_us: 250,
            queue_cap: 1024,
            poll_ms: 0,
        }
    }
}

/// Everything the server's threads share.
struct Shared {
    forest: SharedForest,
    coalescer: Coalescer,
    stats: ServeStats,
    shutdown: AtomicBool,
    shutdown_cv: (Mutex<bool>, Condvar),
    model_path: PathBuf,
}

impl Shared {
    fn signal_shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        let (lock, cvar) = &self.shutdown_cv;
        *lock.lock().unwrap() = true;
        cvar.notify_all();
    }
}

/// A running daemon; drop-in for tests via an ephemeral port.
pub struct Server {
    shared: Arc<Shared>,
    addr: SocketAddr,
    accept: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    watcher: Option<JoinHandle<()>>,
}

impl Server {
    /// Load the model at `model_path` and start serving. Returns once
    /// the listener is bound and every thread is up.
    pub fn start(model_path: &Path, opts: &ServeOptions) -> Result<Server, String> {
        let model = Ensemble::load(model_path)?;
        let forest = FlatForest::from_ensemble(&model);
        let listener = TcpListener::bind((opts.bind.as_str(), opts.port))
            .map_err(|e| format!("bind {}:{}: {e}", opts.bind, opts.port))?;
        let addr = listener.local_addr().map_err(|e| e.to_string())?;
        listener.set_nonblocking(true).map_err(|e| e.to_string())?;

        let shared = Arc::new(Shared {
            forest: SharedForest::new(forest),
            coalescer: Coalescer::new(opts.queue_cap.max(1)),
            stats: ServeStats::new(),
            shutdown: AtomicBool::new(false),
            shutdown_cv: (Mutex::new(false), Condvar::new()),
            model_path: model_path.to_path_buf(),
        });

        let mut workers = Vec::new();
        let block_rows = opts.block_rows.max(1);
        let max_wait = Duration::from_micros(opts.max_wait_us);
        for _ in 0..opts.n_workers.max(1) {
            let sh = shared.clone();
            workers.push(std::thread::spawn(move || worker_loop(&sh, block_rows, max_wait)));
        }

        let watcher = if opts.poll_ms > 0 {
            let sh = shared.clone();
            let poll = Duration::from_millis(opts.poll_ms);
            Some(std::thread::spawn(move || watcher_loop(&sh, poll)))
        } else {
            None
        };

        let sh = shared.clone();
        let accept = std::thread::spawn(move || accept_loop(listener, &sh));

        Ok(Server { shared, addr, accept: Some(accept), workers, watcher })
    }

    /// The bound address (read the real port here when `port = 0`).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Version of the currently installed model (bumps on hot-swap).
    pub fn model_version(&self) -> u64 {
        self.shared.forest.version()
    }

    /// Block until shutdown is requested (`/shutdown` or [`Server::stop`]).
    pub fn wait(&self) {
        let (lock, cvar) = &self.shared.shutdown_cv;
        let mut down = lock.lock().unwrap();
        while !*down {
            down = cvar.wait(down).unwrap();
        }
    }

    /// Drain and stop every thread (see the module docs for the order).
    pub fn stop(mut self) {
        self.shared.signal_shutdown();
        if let Some(h) = self.accept.take() {
            let _ = h.join(); // joins every connection handler too
        }
        self.shared.coalescer.close();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        if let Some(h) = self.watcher.take() {
            let _ = h.join();
        }
    }
}

/// Accept connections until shutdown; join every handler before exit.
fn accept_loop(listener: TcpListener, shared: &Arc<Shared>) {
    let mut handlers: Vec<JoinHandle<()>> = Vec::new();
    while !shared.shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let sh = shared.clone();
                handlers.push(std::thread::spawn(move || handle_connection(stream, &sh)));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    }
    for h in handlers {
        let _ = h.join();
    }
}

/// A response the writer thread will emit, in submission order.
enum Pending {
    /// Already-formatted response line.
    Immediate(String),
    /// A scored request: wait on the ticket, then format.
    Scored { ticket: JobTicket, n_rows: usize },
}

/// Reader half of one connection: parse lines, submit jobs, keep the
/// writer fed in request order.
fn handle_connection(mut stream: TcpStream, shared: &Arc<Shared>) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(50)));
    let write_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let (tx, rx) = mpsc::channel::<Pending>();
    let writer = std::thread::spawn(move || writer_loop(write_half, rx));

    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 4096];
    'read: loop {
        if shared.shutdown.load(Ordering::SeqCst) {
            break;
        }
        let n = match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => n,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(_) => break,
        };
        buf.extend_from_slice(&chunk[..n]);
        // process every complete line; keep the partial tail buffered
        while let Some(eol) = buf.iter().position(|&b| b == b'\n') {
            let line: Vec<u8> = buf.drain(..=eol).collect();
            let line = String::from_utf8_lossy(&line[..eol]);
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            if !handle_line(line, shared, &tx) {
                break 'read;
            }
        }
    }
    drop(tx);
    let _ = writer.join();
}

/// Handle one request line; returns `false` when the connection's read
/// loop should end (shutdown requested).
fn handle_line(line: &str, shared: &Arc<Shared>, tx: &mpsc::Sender<Pending>) -> bool {
    match parse_request(line) {
        Err(e) => {
            shared.stats.n_errors.fetch_add(1, Ordering::Relaxed);
            let _ = tx.send(Pending::Immediate(format_error(&e)));
        }
        Ok(Request::Rows { rows, n_rows, width }) => {
            let (job, ticket) = Job::new(rows, n_rows, width);
            match shared.coalescer.submit(job) {
                Ok(()) => {
                    let _ = tx.send(Pending::Scored { ticket, n_rows });
                }
                Err(_rejected) => {
                    shared.stats.n_errors.fetch_add(1, Ordering::Relaxed);
                    let _ = tx.send(Pending::Immediate(format_error("server is shutting down")));
                }
            }
        }
        Ok(Request::Ping) => {
            let _ = tx.send(Pending::Immediate("ok".to_string()));
        }
        Ok(Request::Stats) => {
            let j = shared
                .stats
                .to_json(shared.forest.version(), shared.coalescer.len());
            let _ = tx.send(Pending::Immediate(j.to_string()));
        }
        Ok(Request::ModelInfo) => {
            let f = shared.forest.snapshot();
            let mut j = Json::obj();
            j.set("model_version", Json::Num(shared.forest.version() as f64))
                .set("n_outputs", Json::Num(f.n_outputs as f64))
                .set("n_trees", Json::Num(f.n_trees() as f64))
                .set("n_features_required", Json::Num(f.n_features_required() as f64))
                .set("path", Json::Str(shared.model_path.display().to_string()));
            let _ = tx.send(Pending::Immediate(j.to_string()));
        }
        Ok(Request::Shutdown) => {
            let _ = tx.send(Pending::Immediate("ok shutting down".to_string()));
            shared.signal_shutdown();
            return false;
        }
    }
    true
}

/// Writer half of one connection: emit responses strictly in request
/// order, flushing per line so single-row clients see low latency.
fn writer_loop(stream: TcpStream, rx: mpsc::Receiver<Pending>) {
    let mut out = std::io::BufWriter::new(stream);
    for pending in rx {
        let line = match pending {
            Pending::Immediate(s) => s,
            Pending::Scored { ticket, n_rows } => match ticket.wait() {
                Ok(scores) => {
                    let d = scores.len() / n_rows.max(1);
                    format_scores(&scores, d.max(1))
                }
                Err(e) => format_error(&e),
            },
        };
        if out.write_all(line.as_bytes()).is_err() || out.write_all(b"\n").is_err() {
            return;
        }
        if out.flush().is_err() {
            return;
        }
    }
}

/// One scoring worker: batch → snapshot → score, with a warm tile.
fn worker_loop(shared: &Arc<Shared>, block_rows: usize, max_wait: Duration) {
    let mut tile: Vec<f32> = Vec::new();
    while let Some(batch) = shared.coalescer.next_batch(block_rows, max_wait) {
        // one snapshot per batch: every job in it scores against a
        // single, internally consistent forest (hot-swap invariant)
        let forest = shared.forest.snapshot();
        score_batch(&forest, batch, block_rows, &mut tile, &shared.stats);
    }
}

/// Score one coalesced batch of jobs against `forest`, reusing `tile`
/// as the gather buffer. Public because the serving property test
/// drives it directly (random batch boundaries, no sockets).
///
/// Rows are gathered `required`-features-wide and driven through
/// [`FlatForest::predict_block_into`] in `block_rows`-sized blocks —
/// the same kernel and the same per-row arithmetic as offline
/// [`FlatForest::predict_raw_into`], which is what makes serving
/// responses bitwise-equal to offline predict by construction.
pub fn score_batch(
    forest: &FlatForest,
    jobs: Vec<Job>,
    block_rows: usize,
    tile: &mut Vec<f32>,
    stats: &ServeStats,
) {
    let t0 = Instant::now();
    let d = forest.n_outputs;
    let required = forest.n_features_required();
    let w = required.max(1);
    let block = block_rows.max(1);
    tile.resize(block * w, 0.0);
    let (mut n_jobs, mut n_rows) = (0u64, 0u64);
    for job in jobs {
        if job.width < required {
            stats.n_errors.fetch_add(1, Ordering::Relaxed);
            job.complete(Err(format!(
                "request rows have {} features but the model splits on feature index {}",
                job.width,
                required - 1
            )));
            continue;
        }
        let mut scores = vec![0.0f32; job.n_rows * d];
        let mut start = 0usize;
        while start < job.n_rows {
            let end = (start + block).min(job.n_rows);
            let rows = end - start;
            for i in 0..rows {
                let src = (start + i) * job.width;
                tile[i * w..(i + 1) * w].copy_from_slice(&job.rows[src..src + w]);
            }
            forest.predict_block_into(&tile[..rows * w], w, rows, &mut scores[start * d..end * d]);
            start = end;
        }
        n_jobs += 1;
        n_rows += job.n_rows as u64;
        stats
            .request_latency
            .record(job.enqueued.elapsed().as_micros() as u64);
        job.complete(Ok(scores));
    }
    if n_jobs > 0 {
        stats.record_batch(n_jobs, n_rows, t0.elapsed().as_micros() as u64);
    }
}

/// Poll the model file and hot-swap on change. Only a *successfully
/// loaded* file updates the seen fingerprint, so a torn or mid-write
/// file is retried until its writer finishes (atomic rename never
/// exposes one).
fn watcher_loop(shared: &Arc<Shared>, poll: Duration) {
    let mut seen = fingerprint(&shared.model_path);
    let tick = poll.min(Duration::from_millis(50)).max(Duration::from_millis(1));
    let mut elapsed = Duration::ZERO;
    while !shared.shutdown.load(Ordering::SeqCst) {
        std::thread::sleep(tick);
        elapsed += tick;
        if elapsed < poll {
            continue;
        }
        elapsed = Duration::ZERO;
        let now = fingerprint(&shared.model_path);
        if now.is_none() || now == seen {
            continue;
        }
        match Ensemble::load(&shared.model_path) {
            Ok(model) => {
                shared.forest.swap(FlatForest::from_ensemble(&model));
                shared.stats.n_reloads.fetch_add(1, Ordering::Relaxed);
                seen = now;
            }
            Err(_) => {
                // keep serving the old model; retry next tick
                shared.stats.n_reload_errors.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

/// (mtime, len) fingerprint of the watched model file.
fn fingerprint(path: &Path) -> Option<(SystemTime, u64)> {
    let meta = std::fs::metadata(path).ok()?;
    Some((meta.modified().ok()?, meta.len()))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A tiny two-tree forest plus jobs scored through `score_batch`
    /// must reproduce the per-row walker bits exactly — the socket-free
    /// core of the serving equality story.
    #[test]
    fn score_batch_matches_per_row_walker() {
        use crate::boosting::ensemble::{Ensemble, TrainHistory};
        use crate::boosting::losses::LossKind;
        use crate::tree::tree::{encode_leaf, Tree, TreeNode};
        let tree = Tree {
            n_outputs: 2,
            nodes: vec![TreeNode {
                feature: 1,
                bin: 0,
                threshold: 0.5,
                default_left: true,
                cats: None,
                left: encode_leaf(0),
                right: encode_leaf(1),
                gain: 1.0,
            }],
            leaf_values: vec![1.0, -1.0, 2.0, -2.0],
            n_leaves: 2,
        };
        let model = Ensemble {
            loss: LossKind::MSE,
            n_outputs: 2,
            base_score: vec![0.1, -0.1],
            trees: vec![tree],
            history: TrainHistory::default(),
        };
        let forest = FlatForest::from_ensemble(&model);
        let stats = ServeStats::new();
        let mut tile = Vec::new();

        // width 3 > required 2: extra features must be ignored
        let rows = vec![0.0, 0.0, 9.0, 0.0, 1.0, 9.0, 0.0, f32::NAN, 9.0];
        let (job, ticket) = Job::new(rows.clone(), 3, 3);
        score_batch(&forest, vec![job], 2, &mut tile, &stats);
        let got = ticket.wait().unwrap();
        for (i, want_leaf) in [(0usize, 0usize), (1, 1), (2, 0)] {
            let mut want = vec![0.1f32, -0.1];
            forest.add_leaf(0, want_leaf, &mut want);
            assert_eq!(&got[i * 2..i * 2 + 2], &want[..], "row {i}");
        }
        assert_eq!(stats.n_requests.load(Ordering::Relaxed), 1);
        assert_eq!(stats.n_rows.load(Ordering::Relaxed), 3);

        // too-narrow rows get an error, not a panic
        let (narrow, t2) = Job::new(vec![0.5], 1, 1);
        score_batch(&forest, vec![narrow], 2, &mut tile, &stats);
        let err = t2.wait().unwrap_err();
        assert!(err.contains("feature index 1"), "{err}");
        assert_eq!(stats.n_errors.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn serve_options_default_is_loopback_ephemeral() {
        let o = ServeOptions::default();
        assert_eq!(o.bind, "127.0.0.1");
        assert_eq!(o.port, 0);
        assert_eq!(o.block_rows, DEFAULT_BLOCK_ROWS);
        assert_eq!(o.poll_ms, 0);
    }
}
