//! Request intake: completion slots and the micro-batching coalescer.
//!
//! Each connection reader turns a parsed data line into a [`Job`] (the
//! rows to score) plus a [`JobTicket`] (where the response will appear)
//! and submits the job to the shared [`Coalescer`]. Scoring workers
//! pull *batches* of jobs: the first pop blocks until work arrives,
//! then the coalescer keeps popping until the batch holds at least the
//! worker's block-row budget or `max_wait` elapses — so concurrent
//! single-row requests merge into one cache-sized block for the batch
//! driver, while a lone request never waits longer than `max_wait`.
//!
//! ## Coalescing contract
//!
//! The unit of coalescing is the **whole request**: a job's rows always
//! travel together, so a worker scores all of them against one forest
//! snapshot — the "no torn response" half of the hot-swap invariant.
//! Batch boundaries never affect scores (each row only reads its own
//! tile slice), which the serving property in `rust/tests/properties.rs`
//! checks by re-batching random arrival orders.

use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::serve::protocol::{error_msg, ERR_INTERNAL};
use crate::util::threading::{BoundedQueue, PopResult, TryPush};

/// Where a job's result is delivered: filled exactly once by the
/// scoring worker, awaited by the connection's writer.
#[derive(Debug)]
struct Slot {
    state: Mutex<Option<Result<Vec<f32>, String>>>,
    done: Condvar,
}

/// One request's rows, travelling through the queue as a unit.
#[derive(Debug)]
pub struct Job {
    /// Row-major feature values, `n_rows * width` of them.
    pub rows: Vec<f32>,
    pub n_rows: usize,
    /// Parsed width of every row (may exceed what the model needs; the
    /// worker gathers only the leading required features).
    pub width: usize,
    /// Submission time, for per-request latency accounting.
    pub enqueued: Instant,
    /// Absolute expiry: a worker that pops this job after the deadline
    /// sheds it with a structured `timeout` error instead of scoring
    /// (`None` = never expires; set from `ServeOptions::deadline_ms`).
    pub deadline: Option<Instant>,
    slot: Arc<Slot>,
}

/// The caller's half of a [`Job`]: blocks until the worker completes it.
pub struct JobTicket {
    slot: Arc<Slot>,
}

impl Job {
    /// Pair a job with the ticket its submitter will wait on.
    pub fn new(rows: Vec<f32>, n_rows: usize, width: usize) -> (Job, JobTicket) {
        assert!(width > 0 && rows.len() == n_rows * width, "job shape");
        let slot = Arc::new(Slot { state: Mutex::new(None), done: Condvar::new() });
        let ticket = JobTicket { slot: slot.clone() };
        (Job { rows, n_rows, width, enqueued: Instant::now(), deadline: None, slot }, ticket)
    }

    /// Deliver the result (scores row-major, or an error message) and
    /// wake the waiting ticket. Consumes the job: exactly one delivery.
    pub fn complete(self, result: Result<Vec<f32>, String>) {
        // a panicked completer leaves plain data behind; recover the
        // lock rather than poisoning every ticket on the request path
        let mut state = self.slot.state.lock().unwrap_or_else(|e| e.into_inner());
        debug_assert!(state.is_none(), "job completed twice");
        *state = Some(result);
        self.slot.done.notify_all();
        // `self` drops here with the slot filled, so `Drop` is a no-op
    }
}

/// Panic-isolation backstop: a job dropped *without* being completed —
/// e.g. mid-batch during a scoring worker's unwind — still resolves
/// its ticket, with a structured `internal` error. The waiting writer
/// gets `!internal` instead of hanging forever on an orphaned slot,
/// which is what keeps the connection usable and the drain terminating
/// no matter where a worker dies.
impl Drop for Job {
    fn drop(&mut self) {
        let mut state = self.slot.state.lock().unwrap_or_else(|e| e.into_inner());
        if state.is_none() {
            *state = Some(Err(error_msg(ERR_INTERNAL, "request dropped by a worker failure")));
            self.slot.done.notify_all();
        }
    }
}

impl JobTicket {
    /// Block until the job completes and take its result.
    pub fn wait(self) -> Result<Vec<f32>, String> {
        // poison recovery on both acquire and re-acquire: the slot holds
        // plain data, and an aborted waiter must not kill later requests
        let mut state = self.slot.state.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if let Some(result) = state.take() {
                return result;
            }
            state = self.slot.done.wait(state).unwrap_or_else(|e| e.into_inner());
        }
    }
}

/// Merges concurrently submitted jobs into block-sized batches.
pub struct Coalescer {
    queue: BoundedQueue<Job>,
}

impl Coalescer {
    /// Coalescer over a bounded queue of at most `cap` pending jobs
    /// (submitters block when the queue is full — natural backpressure).
    pub fn new(cap: usize) -> Coalescer {
        Coalescer { queue: BoundedQueue::new(cap) }
    }

    /// Enqueue a job, blocking while the queue is full (bounded
    /// backpressure). `Ok` carries the queue depth right after the
    /// push (for high-water accounting); `Err(job)` once the coalescer
    /// is closed.
    pub fn submit(&self, job: Job) -> Result<usize, Job> {
        self.queue.push(job)
    }

    /// Enqueue a job only if there is room right now — the
    /// load-shedding submit: `Full(job)` hands the job back so the
    /// caller can answer `!overloaded` instead of parking the reader
    /// behind a saturated queue.
    pub fn try_submit(&self, job: Job) -> TryPush<Job> {
        self.queue.try_push(job)
    }

    /// Stop intake; workers drain the remaining jobs, then
    /// [`Coalescer::next_batch`] returns `None`.
    pub fn close(&self) {
        self.queue.close();
    }

    /// Jobs currently queued (snapshot).
    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Pull the next batch: block for the first job, then keep popping
    /// until the batch reaches `max_rows` rows or `max_wait` passes
    /// (measured from the first pop). Already-queued jobs coalesce even
    /// at `max_wait` zero; the last pop may overshoot `max_rows` —
    /// jobs are never split. `None` means closed and fully drained.
    pub fn next_batch(&self, max_rows: usize, max_wait: Duration) -> Option<Vec<Job>> {
        let first = self.queue.pop()?;
        let deadline = Instant::now() + max_wait;
        let mut rows = first.n_rows;
        let mut batch = vec![first];
        while rows < max_rows {
            match self.queue.pop_deadline(deadline) {
                PopResult::Item(job) => {
                    rows += job.n_rows;
                    batch.push(job);
                }
                PopResult::TimedOut | PopResult::Closed => break,
            }
        }
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ticket_receives_result_across_threads() {
        let (job, ticket) = Job::new(vec![1.0, 2.0], 1, 2);
        let worker = std::thread::spawn(move || job.complete(Ok(vec![0.5])));
        assert_eq!(ticket.wait(), Ok(vec![0.5]));
        worker.join().unwrap();
    }

    #[test]
    fn ticket_sees_result_even_if_completed_first() {
        let (job, ticket) = Job::new(vec![1.0], 1, 1);
        job.complete(Err("nope".to_string()));
        assert_eq!(ticket.wait(), Err("nope".to_string()));
    }

    #[test]
    #[should_panic(expected = "job shape")]
    fn job_rejects_bad_shape() {
        let _ = Job::new(vec![1.0, 2.0, 3.0], 2, 2);
    }

    /// The panic-isolation backstop: a job dropped without completion
    /// (as happens to batch-mates during a worker unwind) must resolve
    /// its ticket with a structured internal error, never hang it.
    #[test]
    fn dropped_job_poisons_its_ticket_with_internal_error() {
        let (job, ticket) = Job::new(vec![1.0], 1, 1);
        drop(job);
        let err = ticket.wait().unwrap_err();
        assert!(err.starts_with("internal"), "{err}");

        // ...and a ticket already waiting on another thread is woken
        let (job, ticket) = Job::new(vec![2.0], 1, 1);
        let waiter = std::thread::spawn(move || ticket.wait());
        std::thread::sleep(Duration::from_millis(20));
        drop(job);
        assert!(waiter.join().unwrap().unwrap_err().starts_with("internal"));
    }

    #[test]
    fn try_submit_sheds_when_full_and_rejects_when_closed() {
        use crate::util::threading::TryPush;
        let c = Coalescer::new(1);
        let (a, _ta) = Job::new(vec![1.0], 1, 1);
        assert!(matches!(c.try_submit(a), TryPush::Pushed(1)));
        let (b, tb) = Job::new(vec![2.0], 1, 1);
        match c.try_submit(b) {
            TryPush::Full(job) => drop(job), // shed: ticket resolves via Drop
            other => panic!("expected Full, got {other:?}"),
        }
        assert!(tb.wait().is_err());
        c.close();
        let (late, _tl) = Job::new(vec![3.0], 1, 1);
        assert!(matches!(c.try_submit(late), TryPush::Closed(_)));
    }

    #[test]
    fn queued_jobs_coalesce_without_waiting() {
        let c = Coalescer::new(16);
        for i in 0..5 {
            let (job, _ticket) = Job::new(vec![i as f32], 1, 1);
            c.submit(job).unwrap();
        }
        // five single-row jobs are already queued: a 4-row budget takes
        // exactly four of them even with a zero wait
        let batch = c.next_batch(4, Duration::ZERO).unwrap();
        assert_eq!(batch.len(), 4);
        assert_eq!(batch.iter().map(|j| j.n_rows).sum::<usize>(), 4);
        let rest = c.next_batch(4, Duration::ZERO).unwrap();
        assert_eq!(rest.len(), 1);
        assert_eq!(rest[0].rows, vec![4.0]);
    }

    #[test]
    fn oversized_job_is_never_split() {
        let c = Coalescer::new(16);
        let (job, _t) = Job::new(vec![0.0; 10 * 3], 10, 3);
        c.submit(job).unwrap();
        let batch = c.next_batch(4, Duration::ZERO).unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].n_rows, 10);
    }

    #[test]
    fn close_drains_then_ends() {
        let c = Coalescer::new(16);
        let (job, _t) = Job::new(vec![1.0], 1, 1);
        c.submit(job).unwrap();
        c.close();
        let (late, _t2) = Job::new(vec![2.0], 1, 1);
        assert!(c.submit(late).is_err());
        assert_eq!(c.next_batch(8, Duration::ZERO).unwrap().len(), 1);
        assert!(c.next_batch(8, Duration::ZERO).is_none());
    }

    #[test]
    fn next_batch_blocks_until_first_job() {
        let c = std::sync::Arc::new(Coalescer::new(4));
        let c2 = c.clone();
        let consumer = std::thread::spawn(move || {
            c2.next_batch(2, Duration::from_millis(1)).map(|b| b.len())
        });
        std::thread::sleep(Duration::from_millis(20));
        let (job, _t) = Job::new(vec![3.0], 1, 1);
        c.submit(job).unwrap();
        assert_eq!(consumer.join().unwrap(), Some(1));
    }
}
