//! Serving counters and latency accounting behind the `/stats` verb.
//!
//! All counters are relaxed atomics — `/stats` is a monitoring
//! snapshot, not a synchronization point. Latencies go into a fixed
//! power-of-two-bucket histogram (bucket `b` covers `[2^(b-1), 2^b)`
//! microseconds), so the reported p50/p99 are **upper bounds accurate
//! to 2×**, with zero allocation and no lock on the hot path. The
//! latency bench (`benches/serve_latency.rs`) computes exact
//! percentiles client-side; these are for live eyeballing.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::util::json::Json;

const N_BUCKETS: usize = 64;

/// Lock-free log-bucket latency histogram (microsecond samples).
pub struct LatencyHist {
    buckets: [AtomicU64; N_BUCKETS],
}

impl LatencyHist {
    pub fn new() -> LatencyHist {
        LatencyHist { buckets: std::array::from_fn(|_| AtomicU64::new(0)) }
    }

    /// Record one sample of `us` microseconds.
    pub fn record(&self, us: u64) {
        let b = (u64::BITS - us.leading_zeros()) as usize;
        self.buckets[b.min(N_BUCKETS - 1)].fetch_add(1, Ordering::Relaxed);
    }

    /// Approximate quantile `q` in microseconds: the upper edge of the
    /// bucket holding the q-th sample (0 if no samples yet).
    pub fn quantile(&self, q: f64) -> u64 {
        let counts: Vec<u64> = self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let target = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut seen = 0u64;
        for (b, &c) in counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return if b == 0 { 0 } else { (1u64 << b) - 1 };
            }
        }
        (1u64 << (N_BUCKETS - 1)) - 1
    }
}

impl Default for LatencyHist {
    fn default() -> Self {
        LatencyHist::new()
    }
}

/// Counters for one server instance, shared by every worker/connection.
///
/// Beyond throughput, the stats track every **degradation path** the
/// hardened server can take — shed requests, expired deadlines, worker
/// panics, failed hot-swaps, oversized requests, reaped idle
/// connections — so an operator (or the chaos suite) can account for
/// each departure from normal service exactly.
pub struct ServeStats {
    started: Instant,
    pub n_requests: AtomicU64,
    pub n_rows: AtomicU64,
    pub n_batches: AtomicU64,
    pub n_errors: AtomicU64,
    pub n_reloads: AtomicU64,
    /// Hot-swap reload attempts that failed to load (the old model
    /// stays live; the watcher retries with capped backoff).
    pub n_swap_failures: AtomicU64,
    /// Requests refused at intake because the queue was full
    /// (`--shed drop`).
    pub n_shed: AtomicU64,
    /// Requests that expired (`--deadline-ms`) before a worker scored
    /// them.
    pub n_timeouts: AtomicU64,
    /// Scoring-worker panics caught and isolated (the worker respawns).
    pub n_worker_panics: AtomicU64,
    /// Requests rejected for exceeding `--max-rows`/`--max-line-bytes`.
    pub n_too_large: AtomicU64,
    /// Connections reaped by `--idle-timeout-ms`.
    pub n_idle_closed: AtomicU64,
    /// Deepest the intake queue has ever been (high-water mark).
    pub queue_depth_hwm: AtomicU64,
    /// Submission → response, per request.
    pub request_latency: LatencyHist,
    /// Snapshot → scored, per batch.
    pub batch_latency: LatencyHist,
}

impl ServeStats {
    pub fn new() -> ServeStats {
        ServeStats {
            started: Instant::now(),
            n_requests: AtomicU64::new(0),
            n_rows: AtomicU64::new(0),
            n_batches: AtomicU64::new(0),
            n_errors: AtomicU64::new(0),
            n_reloads: AtomicU64::new(0),
            n_swap_failures: AtomicU64::new(0),
            n_shed: AtomicU64::new(0),
            n_timeouts: AtomicU64::new(0),
            n_worker_panics: AtomicU64::new(0),
            n_too_large: AtomicU64::new(0),
            n_idle_closed: AtomicU64::new(0),
            queue_depth_hwm: AtomicU64::new(0),
            request_latency: LatencyHist::new(),
            batch_latency: LatencyHist::new(),
        }
    }

    /// One scored batch of `n_jobs` requests totalling `n_rows` rows.
    pub fn record_batch(&self, n_jobs: u64, n_rows: u64, batch_us: u64) {
        self.n_batches.fetch_add(1, Ordering::Relaxed);
        self.n_requests.fetch_add(n_jobs, Ordering::Relaxed);
        self.n_rows.fetch_add(n_rows, Ordering::Relaxed);
        self.batch_latency.record(batch_us);
    }

    /// Fold a just-observed queue depth into the high-water mark.
    pub fn note_queue_depth(&self, depth: usize) {
        self.queue_depth_hwm.fetch_max(depth as u64, Ordering::Relaxed);
    }

    /// The `/stats` payload (one line of JSON once `.to_string()`-ed).
    pub fn to_json(&self, model_version: u64, queued_jobs: usize) -> Json {
        let n = |v: u64| Json::Num(v as f64);
        let requests = self.n_requests.load(Ordering::Relaxed);
        let rows = self.n_rows.load(Ordering::Relaxed);
        let batches = self.n_batches.load(Ordering::Relaxed);
        let mut j = Json::obj();
        j.set("uptime_s", Json::Num(self.started.elapsed().as_secs_f64()))
            .set("model_version", n(model_version))
            .set("n_requests", n(requests))
            .set("n_rows", n(rows))
            .set("n_batches", n(batches))
            .set("n_errors", n(self.n_errors.load(Ordering::Relaxed)))
            .set("n_reloads", n(self.n_reloads.load(Ordering::Relaxed)))
            .set("swap_failures", n(self.n_swap_failures.load(Ordering::Relaxed)))
            .set("shed", n(self.n_shed.load(Ordering::Relaxed)))
            .set("timeouts", n(self.n_timeouts.load(Ordering::Relaxed)))
            .set("worker_panics", n(self.n_worker_panics.load(Ordering::Relaxed)))
            .set("too_large", n(self.n_too_large.load(Ordering::Relaxed)))
            .set("idle_closed", n(self.n_idle_closed.load(Ordering::Relaxed)))
            .set("queue_depth_hwm", n(self.queue_depth_hwm.load(Ordering::Relaxed)))
            .set("queued_jobs", n(queued_jobs as u64))
            .set(
                "rows_per_batch",
                Json::Num(if batches == 0 { 0.0 } else { rows as f64 / batches as f64 }),
            )
            .set("request_p50_us_approx", n(self.request_latency.quantile(0.5)))
            .set("request_p99_us_approx", n(self.request_latency.quantile(0.99)))
            .set("batch_p50_us_approx", n(self.batch_latency.quantile(0.5)))
            .set("batch_p99_us_approx", n(self.batch_latency.quantile(0.99)));
        j
    }
}

impl Default for ServeStats {
    fn default() -> Self {
        ServeStats::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_bound_samples_within_2x() {
        let h = LatencyHist::new();
        assert_eq!(h.quantile(0.5), 0);
        for us in [3u64, 3, 3, 3, 3, 3, 3, 3, 3, 200] {
            h.record(us);
        }
        // p50 falls in the [2,3] bucket; p99 in [128,255]
        assert_eq!(h.quantile(0.5), 3);
        let p99 = h.quantile(0.99);
        assert!((200..=255).contains(&p99), "p99={p99}");
        // quantiles are monotone in q
        assert!(h.quantile(0.1) <= h.quantile(0.9));
    }

    #[test]
    fn histogram_handles_zero_and_huge_samples() {
        let h = LatencyHist::new();
        h.record(0);
        assert_eq!(h.quantile(0.5), 0);
        h.record(u64::MAX); // clamps into the last bucket, no panic
        assert!(h.quantile(1.0) > 0);
    }

    #[test]
    fn stats_json_has_the_monitoring_keys() {
        let s = ServeStats::new();
        s.record_batch(3, 40, 120);
        s.n_errors.fetch_add(1, Ordering::Relaxed);
        let j = s.to_json(7, 2);
        let line = j.to_string();
        let back = Json::parse(&line).unwrap();
        assert_eq!(back.get("model_version").unwrap().as_usize().unwrap(), 7);
        assert_eq!(back.get("n_requests").unwrap().as_usize().unwrap(), 3);
        assert_eq!(back.get("n_rows").unwrap().as_usize().unwrap(), 40);
        assert_eq!(back.get("n_errors").unwrap().as_usize().unwrap(), 1);
        assert_eq!(back.get("queued_jobs").unwrap().as_usize().unwrap(), 2);
        assert!(back.get("rows_per_batch").unwrap().as_f64().unwrap() > 13.0);
        assert!(!line.contains('\n'), "stats must be one line");
    }

    /// Every degradation path has its own key, zero on a quiet server.
    #[test]
    fn stats_json_exposes_degradation_counters() {
        let s = ServeStats::new();
        s.n_shed.fetch_add(2, Ordering::Relaxed);
        s.n_timeouts.fetch_add(3, Ordering::Relaxed);
        s.n_worker_panics.fetch_add(1, Ordering::Relaxed);
        s.n_swap_failures.fetch_add(4, Ordering::Relaxed);
        s.n_too_large.fetch_add(5, Ordering::Relaxed);
        s.n_idle_closed.fetch_add(6, Ordering::Relaxed);
        s.note_queue_depth(9);
        s.note_queue_depth(4); // high-water never regresses
        let back = Json::parse(&s.to_json(1, 0).to_string()).unwrap();
        let get = |k: &str| back.get(k).unwrap().as_usize().unwrap();
        assert_eq!(get("shed"), 2);
        assert_eq!(get("timeouts"), 3);
        assert_eq!(get("worker_panics"), 1);
        assert_eq!(get("swap_failures"), 4);
        assert_eq!(get("too_large"), 5);
        assert_eq!(get("idle_closed"), 6);
        assert_eq!(get("queue_depth_hwm"), 9);
    }
}
