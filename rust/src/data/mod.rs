//! Data substrate: dataset container, quantile binning, synthetic
//! workload generators (paper-dataset profiles), CSV I/O, CV splits,
//! and the out-of-core chunked binned store (DESIGN.md §2d).

pub mod binning;
pub mod chunked;
pub mod csv;
pub mod dataset;
pub mod profiles;
pub mod split;
pub mod store;
pub mod synthetic;

pub use binning::{BinnedDataset, BinnedSource};
pub use chunked::ChunkedBinned;
pub use dataset::{Dataset, FeatureKind, Targets};
