//! Data substrate: dataset container, quantile binning, synthetic
//! workload generators (paper-dataset profiles), CSV I/O, and CV splits.

pub mod binning;
pub mod csv;
pub mod dataset;
pub mod profiles;
pub mod split;
pub mod synthetic;

pub use binning::BinnedDataset;
pub use dataset::{Dataset, FeatureKind, Targets};
