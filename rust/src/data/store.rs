//! The on-disk chunked binned store (DESIGN.md §2d "Out-of-core binned
//! store") — zero-dependency: std I/O plus the in-repo JSON substrate.
//!
//! ## File layout
//!
//! ```text
//! offset 0   8 bytes   magic b"SBBINST1"
//! offset 8   8 bytes   u64 LE header offset (patched when the writer
//!                      finishes — the payload streams out first)
//! offset 16  ...       chunk payloads, back to back: chunk c holds
//!                      m * rows_c bytes, column-major *within the
//!                      chunk* (feature f, then row) — the exact layout
//!                      `ChunkCols` serves to the engines
//!            ...       targets payload (u32 LE labels for multiclass,
//!                      f32 LE row-major matrices otherwise)
//! tail       ...       JSON header: shapes, feature kinds, bin edges
//!                      (as u32 bit patterns, so thresholds round-trip
//!                      bit-exactly), per-chunk index entries with
//!                      FNV-1a checksums, and the targets descriptor
//! ```
//!
//! The header-at-tail + patched offset lets [`StoreWriter`] write in one
//! pass over a row stream without knowing the chunk count up front.
//! Loading is `data/chunked.rs`: `read_at` into a bounded pool of
//! recycled chunk buffers.

use std::fs::File;
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::Path;

use crate::data::binning::{BinSpec, BinnedDataset};
use crate::data::dataset::{FeatureKind, Targets};
use crate::util::json::Json;

pub const MAGIC: &[u8; 8] = b"SBBINST1";
pub const FORMAT: &str = "sketchboost-chunked-binned";
pub const VERSION: usize = 1;

/// Errors opening or validating a store file. `Io` is the environment,
/// `Format` is a malformed/truncated file, `Corrupt` is a chunk whose
/// bytes no longer match their recorded checksum.
#[derive(Debug)]
pub enum StoreError {
    Io(std::io::Error),
    Format(String),
    Corrupt { chunk: usize, detail: String },
}

impl std::fmt::Display for StoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StoreError::Io(e) => write!(f, "store i/o error: {e}"),
            StoreError::Format(m) => write!(f, "store format error: {m}"),
            StoreError::Corrupt { chunk, detail } => {
                write!(f, "store chunk {chunk} corrupt: {detail}")
            }
        }
    }
}

impl std::error::Error for StoreError {}

impl From<std::io::Error> for StoreError {
    fn from(e: std::io::Error) -> StoreError {
        StoreError::Io(e)
    }
}

fn format_err(msg: impl Into<String>) -> StoreError {
    StoreError::Format(msg.into())
}

// -- FNV-1a (64-bit) --------------------------------------------------------

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

#[inline]
pub fn fnv1a_update(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

// -- index entries ----------------------------------------------------------

/// One chunk's index entry (from the JSON header).
#[derive(Clone, Debug)]
pub struct ChunkMeta {
    /// Absolute file offset of the chunk payload.
    pub offset: u64,
    /// First global row the chunk covers.
    pub start: usize,
    /// Rows in the chunk.
    pub rows: usize,
    /// Payload size: `n_features * rows`.
    pub bytes: usize,
    /// FNV-1a over the payload bytes in file order.
    pub fnv: u64,
}

/// Everything the JSON header records.
pub struct StoreHeader {
    pub n_rows: usize,
    pub n_features: usize,
    pub max_bins: usize,
    /// Nominal rows per chunk (the last chunk may be ragged).
    pub chunk_rows: usize,
    pub kinds: Vec<FeatureKind>,
    pub edges: Vec<Vec<f32>>,
    pub n_bins: Vec<u16>,
    pub chunks: Vec<ChunkMeta>,
    pub targets_kind: String,
    pub n_outputs: usize,
    pub targets_offset: u64,
    pub targets_bytes: usize,
}

impl StoreHeader {
    pub fn spec(&self) -> BinSpec {
        BinSpec {
            max_bins: self.max_bins,
            kinds: self.kinds.clone(),
            edges: self.edges.clone(),
            n_bins: self.n_bins.clone(),
        }
    }
}

// -- writer -----------------------------------------------------------------

/// One-pass streaming writer: feed raw rows ([`StoreWriter::push_row`],
/// binned through the [`BinSpec`]) or pre-binned code rows
/// ([`StoreWriter::push_codes`]); chunks flush as they fill and the
/// header lands at the tail on [`StoreWriter::finish`].
pub struct StoreWriter {
    file: File,
    spec: BinSpec,
    chunk_rows: usize,
    /// Column-major staging for the in-progress chunk, stride
    /// `chunk_rows` (flushed ragged chunks compact on write).
    buf: Vec<u8>,
    buf_rows: usize,
    n_rows: usize,
    chunks: Vec<ChunkMeta>,
    offset: u64,
}

impl StoreWriter {
    pub fn create(path: &Path, spec: BinSpec, chunk_rows: usize) -> Result<StoreWriter, StoreError> {
        assert!(chunk_rows > 0, "chunk_rows must be positive");
        let m = spec.n_features();
        assert!(m > 0, "store needs at least one feature");
        let mut file = File::create(path)?;
        file.write_all(MAGIC)?;
        file.write_all(&0u64.to_le_bytes())?; // header offset, patched in finish
        Ok(StoreWriter {
            file,
            spec,
            chunk_rows,
            buf: vec![0u8; m * chunk_rows],
            buf_rows: 0,
            n_rows: 0,
            chunks: Vec::new(),
            offset: 16,
        })
    }

    /// Bin one raw feature row (NaN = missing) and append it.
    pub fn push_row(&mut self, row: &[f32]) -> Result<(), StoreError> {
        let m = self.spec.n_features();
        assert_eq!(row.len(), m, "row width");
        for (f, &x) in row.iter().enumerate() {
            self.buf[f * self.chunk_rows + self.buf_rows] = self.spec.code_of(f, x);
        }
        self.bump()
    }

    /// Append one already-binned code row (length `n_features`).
    pub fn push_codes(&mut self, codes: &[u8]) -> Result<(), StoreError> {
        let m = self.spec.n_features();
        assert_eq!(codes.len(), m, "code row width");
        for (f, &c) in codes.iter().enumerate() {
            self.buf[f * self.chunk_rows + self.buf_rows] = c;
        }
        self.bump()
    }

    fn bump(&mut self) -> Result<(), StoreError> {
        self.buf_rows += 1;
        self.n_rows += 1;
        if self.buf_rows == self.chunk_rows {
            self.flush_chunk()?;
        }
        Ok(())
    }

    fn flush_chunk(&mut self) -> Result<(), StoreError> {
        if self.buf_rows == 0 {
            return Ok(());
        }
        let m = self.spec.n_features();
        let r = self.buf_rows;
        let mut h = FNV_OFFSET;
        for f in 0..m {
            let col = &self.buf[f * self.chunk_rows..f * self.chunk_rows + r];
            self.file.write_all(col)?;
            h = fnv1a_update(h, col);
        }
        let bytes = m * r;
        self.chunks.push(ChunkMeta {
            offset: self.offset,
            start: self.n_rows - r,
            rows: r,
            bytes,
            fnv: h,
        });
        self.offset += bytes as u64;
        self.buf_rows = 0;
        Ok(())
    }

    /// Flush the ragged tail, write the targets payload and the JSON
    /// header, and patch the header offset at byte 8.
    pub fn finish(mut self, targets: &Targets) -> Result<(), StoreError> {
        self.flush_chunk()?;
        assert_eq!(
            targets.len(),
            self.n_rows,
            "targets rows must match pushed feature rows"
        );
        let (targets_kind, n_outputs, payload): (&str, usize, Vec<u8>) = match targets {
            Targets::Multiclass { labels, n_classes } => {
                let mut p = Vec::with_capacity(labels.len() * 4);
                for &l in labels {
                    p.extend_from_slice(&l.to_le_bytes());
                }
                ("multiclass", *n_classes, p)
            }
            Targets::Multilabel { labels, n_labels } => {
                let mut p = Vec::with_capacity(labels.len() * 4);
                for &v in labels {
                    p.extend_from_slice(&v.to_le_bytes());
                }
                ("multilabel", *n_labels, p)
            }
            Targets::Regression { values, n_targets } => {
                let mut p = Vec::with_capacity(values.len() * 4);
                for &v in values {
                    p.extend_from_slice(&v.to_le_bytes());
                }
                ("regression", *n_targets, p)
            }
        };
        let targets_offset = self.offset;
        self.file.write_all(&payload)?;
        self.offset += payload.len() as u64;

        let mut hdr = Json::obj();
        hdr.set("format", Json::Str(FORMAT.into()));
        hdr.set("version", Json::Num(VERSION as f64));
        hdr.set("n_rows", Json::Num(self.n_rows as f64));
        hdr.set("n_features", Json::Num(self.spec.n_features() as f64));
        hdr.set("max_bins", Json::Num(self.spec.max_bins as f64));
        hdr.set("chunk_rows", Json::Num(self.chunk_rows as f64));
        hdr.set(
            "kinds",
            Json::Arr(
                self.spec
                    .kinds
                    .iter()
                    .map(|k| {
                        Json::Str(match k {
                            FeatureKind::Numeric => "num".into(),
                            FeatureKind::Categorical => "cat".into(),
                        })
                    })
                    .collect(),
            ),
        );
        hdr.set(
            "n_bins",
            Json::Arr(self.spec.n_bins.iter().map(|&b| Json::Num(b as f64)).collect()),
        );
        // edges as u32 bit patterns: JSON float text would not be
        // guaranteed to round-trip f32 exactly, and split thresholds
        // must be bit-identical to the in-RAM path
        hdr.set(
            "edges_bits",
            Json::Arr(
                self.spec
                    .edges
                    .iter()
                    .map(|es| {
                        Json::Arr(es.iter().map(|&e| Json::Num(e.to_bits() as f64)).collect())
                    })
                    .collect(),
            ),
        );
        hdr.set(
            "chunks",
            Json::Arr(
                self.chunks
                    .iter()
                    .map(|c| {
                        let mut o = Json::obj();
                        o.set("offset", Json::Num(c.offset as f64));
                        o.set("start", Json::Num(c.start as f64));
                        o.set("rows", Json::Num(c.rows as f64));
                        o.set("bytes", Json::Num(c.bytes as f64));
                        // 64-bit checksum exceeds f64's exact-integer
                        // range; hex string keeps it lossless
                        o.set("fnv", Json::Str(format!("{:016x}", c.fnv)));
                        o
                    })
                    .collect(),
            ),
        );
        let mut tgt = Json::obj();
        tgt.set("kind", Json::Str(targets_kind.into()));
        tgt.set("n_outputs", Json::Num(n_outputs as f64));
        tgt.set("offset", Json::Num(targets_offset as f64));
        tgt.set("bytes", Json::Num(payload.len() as f64));
        hdr.set("targets", tgt);

        let header_offset = self.offset;
        self.file.write_all(hdr.to_string().as_bytes())?;
        self.file.seek(SeekFrom::Start(8))?;
        self.file.write_all(&header_offset.to_le_bytes())?;
        self.file.flush()?;
        Ok(())
    }
}

/// Write an in-RAM [`BinnedDataset`] (plus its targets) to a store
/// file. The store then carries the *same* edges and codes, so chunked
/// training from it is bitwise-identical to in-RAM training — the
/// contract `rust/tests/out_of_core.rs` asserts.
pub fn write_binned(
    path: &Path,
    binned: &BinnedDataset,
    targets: &Targets,
    chunk_rows: usize,
) -> Result<(), StoreError> {
    let mut w = StoreWriter::create(path, BinSpec::of(binned), chunk_rows)?;
    let n = binned.n_rows;
    let m = binned.n_features;
    let mut row = vec![0u8; m];
    for i in 0..n {
        for (f, slot) in row.iter_mut().enumerate() {
            *slot = binned.codes[f * n + i];
        }
        w.push_codes(&row)?;
    }
    w.finish(targets)
}

// -- reader -----------------------------------------------------------------

fn get_usize(obj: &Json, key: &str) -> Result<usize, StoreError> {
    obj.get(key)
        .and_then(|v| v.as_usize())
        .ok_or_else(|| format_err(format!("header field {key:?} missing or not an integer")))
}

/// Read and structurally validate the JSON header of a store file.
/// Catches truncation (header offset or any payload extent past EOF)
/// and malformed indexes; byte-level corruption inside chunk payloads
/// is [`verify_chunks`]'s job.
pub fn read_header(file: &mut File) -> Result<StoreHeader, StoreError> {
    let file_len = file.metadata()?.len();
    if file_len < 16 {
        return Err(format_err(format!("file too short ({file_len} bytes) for the 16-byte preamble")));
    }
    let mut pre = [0u8; 16];
    file.seek(SeekFrom::Start(0))?;
    file.read_exact(&mut pre)?;
    if &pre[..8] != MAGIC {
        return Err(format_err("bad magic (not a sketchboost chunked store)"));
    }
    let header_offset = u64::from_le_bytes(pre[8..16].try_into().unwrap());
    if header_offset < 16 || header_offset >= file_len {
        return Err(format_err(format!(
            "header offset {header_offset} out of range (file is {file_len} bytes; \
             truncated or never finished?)"
        )));
    }
    file.seek(SeekFrom::Start(header_offset))?;
    let mut text = String::new();
    file.read_to_string(&mut text)
        .map_err(|e| format_err(format!("header is not UTF-8 JSON: {e}")))?;
    let hdr = Json::parse(&text).map_err(|e| format_err(format!("header JSON: {e}")))?;

    let format = hdr.get("format").and_then(|v| v.as_str()).unwrap_or("");
    if format != FORMAT {
        return Err(format_err(format!("format {format:?} != {FORMAT:?}")));
    }
    let version = get_usize(&hdr, "version")?;
    if version != VERSION {
        return Err(format_err(format!("version {version} unsupported (want {VERSION})")));
    }
    let n_rows = get_usize(&hdr, "n_rows")?;
    let n_features = get_usize(&hdr, "n_features")?;
    let max_bins = get_usize(&hdr, "max_bins")?;
    let chunk_rows = get_usize(&hdr, "chunk_rows")?;
    if n_features == 0 || !(2..=256).contains(&max_bins) || chunk_rows == 0 {
        return Err(format_err("degenerate shape in header"));
    }

    let kind_strs = hdr
        .get("kinds")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| format_err("kinds missing"))?;
    let mut kinds = Vec::with_capacity(n_features);
    for k in kind_strs {
        kinds.push(match k.as_str() {
            Some("num") => FeatureKind::Numeric,
            Some("cat") => FeatureKind::Categorical,
            other => return Err(format_err(format!("bad feature kind {other:?}"))),
        });
    }
    if kinds.len() != n_features {
        return Err(format_err("kinds length != n_features"));
    }

    let n_bins_arr = hdr
        .get("n_bins")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| format_err("n_bins missing"))?;
    let mut n_bins = Vec::with_capacity(n_features);
    for b in n_bins_arr {
        let b = b.as_usize().ok_or_else(|| format_err("bad n_bins entry"))?;
        if b < 1 || b > max_bins {
            return Err(format_err(format!("n_bins entry {b} outside [1, {max_bins}]")));
        }
        n_bins.push(b as u16);
    }
    if n_bins.len() != n_features {
        return Err(format_err("n_bins length != n_features"));
    }

    let edges_arr = hdr
        .get("edges_bits")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| format_err("edges_bits missing"))?;
    if edges_arr.len() != n_features {
        return Err(format_err("edges_bits length != n_features"));
    }
    let mut edges = Vec::with_capacity(n_features);
    for es in edges_arr {
        let es = es.as_arr().ok_or_else(|| format_err("edges_bits entry not an array"))?;
        let mut col = Vec::with_capacity(es.len());
        for e in es {
            let bits = e
                .as_f64()
                .filter(|x| x.fract() == 0.0 && *x >= 0.0 && *x <= u32::MAX as f64)
                .ok_or_else(|| format_err("bad edge bit pattern"))?;
            col.push(f32::from_bits(bits as u32));
        }
        edges.push(col);
    }

    let chunk_arr = hdr
        .get("chunks")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| format_err("chunks index missing"))?;
    let mut chunks = Vec::with_capacity(chunk_arr.len());
    let mut next_start = 0usize;
    let mut next_offset = 16u64;
    for (c, entry) in chunk_arr.iter().enumerate() {
        let offset = get_usize(entry, "offset")? as u64;
        let start = get_usize(entry, "start")?;
        let rows = get_usize(entry, "rows")?;
        let bytes = get_usize(entry, "bytes")?;
        let fnv_hex = entry
            .get("fnv")
            .and_then(|v| v.as_str())
            .ok_or_else(|| format_err(format!("chunk {c}: fnv missing")))?;
        let fnv = u64::from_str_radix(fnv_hex, 16)
            .map_err(|_| format_err(format!("chunk {c}: bad fnv {fnv_hex:?}")))?;
        if start != next_start || offset != next_offset {
            return Err(format_err(format!(
                "chunk {c}: index not contiguous (start {start} offset {offset}, \
                 expected {next_start} / {next_offset})"
            )));
        }
        if rows == 0 || bytes != n_features * rows {
            return Err(format_err(format!(
                "chunk {c}: bytes {bytes} != n_features * rows ({n_features} * {rows})"
            )));
        }
        if offset + bytes as u64 > header_offset {
            return Err(format_err(format!(
                "chunk {c}: payload [{offset}, {}) runs past the header at {header_offset} \
                 (truncated?)",
                offset + bytes as u64
            )));
        }
        next_start = start + rows;
        next_offset = offset + bytes as u64;
        chunks.push(ChunkMeta { offset, start, rows, bytes, fnv });
    }
    if next_start != n_rows {
        return Err(format_err(format!(
            "chunks cover {next_start} rows, header says {n_rows}"
        )));
    }

    let tgt = hdr.get("targets").ok_or_else(|| format_err("targets descriptor missing"))?;
    let targets_kind = tgt
        .get("kind")
        .and_then(|v| v.as_str())
        .ok_or_else(|| format_err("targets.kind missing"))?
        .to_string();
    let n_outputs = get_usize(tgt, "n_outputs")?;
    let targets_offset = get_usize(tgt, "offset")? as u64;
    let targets_bytes = get_usize(tgt, "bytes")?;
    if targets_offset < next_offset || targets_offset + targets_bytes as u64 > header_offset {
        return Err(format_err("targets payload extent out of range (truncated?)"));
    }

    Ok(StoreHeader {
        n_rows,
        n_features,
        max_bins,
        chunk_rows,
        kinds,
        edges,
        n_bins,
        chunks,
        targets_kind,
        n_outputs,
        targets_offset,
        targets_bytes,
    })
}

/// Decode the targets payload named by the header.
pub fn read_targets(file: &File, h: &StoreHeader) -> Result<Targets, StoreError> {
    use std::os::unix::fs::FileExt;
    let mut payload = vec![0u8; h.targets_bytes];
    file.read_exact_at(&mut payload, h.targets_offset)?;
    let n = h.n_rows;
    let d = h.n_outputs;
    let want = |bytes: usize| -> Result<(), StoreError> {
        if h.targets_bytes != bytes {
            Err(format_err(format!(
                "targets payload {} bytes, expected {bytes} for {} x {d} {}",
                h.targets_bytes, n, h.targets_kind
            )))
        } else {
            Ok(())
        }
    };
    match h.targets_kind.as_str() {
        "multiclass" => {
            want(4 * n)?;
            let labels: Vec<u32> = payload
                .chunks_exact(4)
                .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            if let Some(&bad) = labels.iter().find(|&&l| l as usize >= d) {
                return Err(format_err(format!("label {bad} >= n_classes {d}")));
            }
            Ok(Targets::Multiclass { labels, n_classes: d })
        }
        "multilabel" => {
            want(4 * n * d)?;
            let labels: Vec<f32> = payload
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            Ok(Targets::Multilabel { labels, n_labels: d })
        }
        "regression" => {
            want(4 * n * d)?;
            let values: Vec<f32> = payload
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            Ok(Targets::Regression { values, n_targets: d })
        }
        other => Err(format_err(format!("unknown targets kind {other:?}"))),
    }
}

/// Stream every chunk and check its FNV-1a checksum against the index.
pub fn verify_chunks(file: &File, h: &StoreHeader) -> Result<(), StoreError> {
    use std::os::unix::fs::FileExt;
    let mut buf = Vec::new();
    for (c, meta) in h.chunks.iter().enumerate() {
        buf.resize(meta.bytes, 0);
        file.read_exact_at(&mut buf, meta.offset)?;
        let got = fnv1a_update(FNV_OFFSET, &buf);
        if got != meta.fnv {
            return Err(StoreError::Corrupt {
                chunk: c,
                detail: format!("checksum {got:016x} != recorded {:016x}", meta.fnv),
            });
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{make_multiclass, FeatureSpec};

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("sb_store_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn header_round_trips_and_edges_are_bit_exact() {
        let ds = make_multiclass(100, FeatureSpec::guyon(5), 3, 1.5, 3);
        let binned = BinnedDataset::from_dataset(&ds, 16);
        let path = tmp("hdr.bin");
        write_binned(&path, &binned, &ds.targets, 32).unwrap();
        let mut f = File::open(&path).unwrap();
        let h = read_header(&mut f).unwrap();
        assert_eq!(h.n_rows, 100);
        assert_eq!(h.n_features, 5);
        assert_eq!(h.max_bins, 16);
        assert_eq!(h.chunks.len(), 4, "32-row chunks over 100 rows");
        assert_eq!(h.chunks[3].rows, 4, "ragged tail");
        for f_ix in 0..5 {
            let (a, b) = (&h.edges[f_ix], &binned.edges[f_ix]);
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(b.iter()) {
                assert_eq!(x.to_bits(), y.to_bits(), "edge must round-trip bit-exactly");
            }
        }
        assert_eq!(h.n_bins, binned.n_bins);
        verify_chunks(&f, &h).unwrap();
        let t = read_targets(&f, &h).unwrap();
        assert_eq!(t, ds.targets);
    }

    #[test]
    fn truncated_file_is_a_format_error() {
        let ds = make_multiclass(60, FeatureSpec::guyon(4), 3, 1.5, 5);
        let binned = BinnedDataset::from_dataset(&ds, 8);
        let path = tmp("trunc.bin");
        write_binned(&path, &binned, &ds.targets, 16).unwrap();
        let len = std::fs::metadata(&path).unwrap().len();
        let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(len / 2).unwrap();
        drop(f);
        let mut f = File::open(&path).unwrap();
        match read_header(&mut f) {
            Err(StoreError::Format(_)) => {}
            other => panic!("expected Format error, got {other:?}"),
        }
    }

    #[test]
    fn flipped_chunk_byte_is_a_corrupt_error() {
        let ds = make_multiclass(60, FeatureSpec::guyon(4), 3, 1.5, 5);
        let binned = BinnedDataset::from_dataset(&ds, 8);
        let path = tmp("corrupt.bin");
        write_binned(&path, &binned, &ds.targets, 16).unwrap();
        // flip one code byte inside chunk 1's payload
        let mut bytes = std::fs::read(&path).unwrap();
        let mut f = File::open(&path).unwrap();
        let h = read_header(&mut f).unwrap();
        let at = h.chunks[1].offset as usize + 3;
        bytes[at] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        let mut f = File::open(&path).unwrap();
        let h = read_header(&mut f).unwrap(); // structure still fine
        match verify_chunks(&f, &h) {
            Err(StoreError::Corrupt { chunk: 1, .. }) => {}
            other => panic!("expected Corrupt {{ chunk: 1 }}, got {other:?}"),
        }
    }

    #[test]
    fn streaming_writer_matches_write_binned() {
        let ds = make_multiclass(80, FeatureSpec::guyon(4), 3, 1.5, 9);
        let binned = BinnedDataset::from_dataset(&ds, 16);
        let a = tmp("bulk.bin");
        let b = tmp("stream.bin");
        write_binned(&a, &binned, &ds.targets, 17).unwrap();
        // push the raw rows through the spec: same edges -> same codes
        let mut w = StoreWriter::create(&b, BinSpec::of(&binned), 17).unwrap();
        for i in 0..ds.n_rows {
            w.push_row(&ds.row(i)).unwrap();
        }
        w.finish(&ds.targets).unwrap();
        assert_eq!(std::fs::read(&a).unwrap(), std::fs::read(&b).unwrap());
    }
}
