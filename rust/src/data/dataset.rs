//! In-memory dataset representation.
//!
//! Features are stored column-major (`f * n + i`), mirroring Py-Boost's
//! device layout: binning, histogram building, and split application all
//! stream one feature column at a time, so column-major keeps the hot
//! loops sequential. Targets cover the paper's three task families.

/// Task targets. `d` below is the model's output dimension.
#[derive(Clone, Debug, PartialEq)]
pub enum Targets {
    /// Class index per row; `d` = number of classes.
    Multiclass { labels: Vec<u32>, n_classes: usize },
    /// Row-major n x d {0,1} indicator matrix.
    Multilabel { labels: Vec<f32>, n_labels: usize },
    /// Row-major n x d real targets.
    Regression { values: Vec<f32>, n_targets: usize },
}

impl Targets {
    pub fn n_outputs(&self) -> usize {
        match self {
            Targets::Multiclass { n_classes, .. } => *n_classes,
            Targets::Multilabel { n_labels, .. } => *n_labels,
            Targets::Regression { n_targets, .. } => *n_targets,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Targets::Multiclass { labels, .. } => labels.len(),
            Targets::Multilabel { labels, n_labels } => {
                if *n_labels == 0 { 0 } else { labels.len() / n_labels }
            }
            Targets::Regression { values, n_targets } => {
                if *n_targets == 0 { 0 } else { values.len() / n_targets }
            }
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Gather a row subset (by index) into a new Targets of the same kind.
    pub fn gather(&self, rows: &[u32]) -> Targets {
        match self {
            Targets::Multiclass { labels, n_classes } => Targets::Multiclass {
                labels: rows.iter().map(|&i| labels[i as usize]).collect(),
                n_classes: *n_classes,
            },
            Targets::Multilabel { labels, n_labels } => {
                let d = *n_labels;
                let mut out = Vec::with_capacity(rows.len() * d);
                for &i in rows {
                    let i = i as usize;
                    out.extend_from_slice(&labels[i * d..(i + 1) * d]);
                }
                Targets::Multilabel { labels: out, n_labels: d }
            }
            Targets::Regression { values, n_targets } => {
                let d = *n_targets;
                let mut out = Vec::with_capacity(rows.len() * d);
                for &i in rows {
                    let i = i as usize;
                    out.extend_from_slice(&values[i * d..(i + 1) * d]);
                }
                Targets::Regression { values: out, n_targets: d }
            }
        }
    }
}

/// How a feature column is interpreted by binning, split search, and
/// routing (DESIGN.md "Missing values & categorical splits").
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum FeatureKind {
    /// Ordinal values quantile-binned to threshold candidates.
    #[default]
    Numeric,
    /// Raw values are small non-negative integer category ids; splits
    /// are category-set partitions, not thresholds. NaN = missing.
    Categorical,
}

/// Dense feature matrix (Py-Boost's data model, Appendix B.1, extended
/// with first-class missing values and categorical columns: NaN in any
/// column is an explicit *missing* value routed by a per-split learned
/// default direction, and columns marked [`FeatureKind::Categorical`]
/// hold integer category ids).
#[derive(Clone, Debug)]
pub struct Dataset {
    pub n_rows: usize,
    pub n_features: usize,
    /// Column-major: features[f * n_rows + i].
    pub features: Vec<f32>,
    pub targets: Targets,
    /// Per-feature interpretation; `Numeric` unless marked otherwise
    /// (see [`Dataset::mark_categorical`]).
    pub kinds: Vec<FeatureKind>,
}

impl Dataset {
    pub fn new(n_rows: usize, n_features: usize, features: Vec<f32>, targets: Targets) -> Dataset {
        assert_eq!(features.len(), n_rows * n_features, "feature buffer size");
        assert_eq!(targets.len(), n_rows, "targets/rows mismatch");
        Dataset {
            n_rows,
            n_features,
            features,
            targets,
            kinds: vec![FeatureKind::Numeric; n_features],
        }
    }

    /// Mark feature columns as categorical (raw values must be integer
    /// category ids in `[0, 255]`, or NaN for missing).
    pub fn mark_categorical(&mut self, cols: &[usize]) {
        for &f in cols {
            assert!(f < self.n_features, "categorical column {f} out of range");
            self.kinds[f] = FeatureKind::Categorical;
        }
    }

    /// Build from a row-major buffer (as loaded from CSV).
    pub fn from_row_major(
        n_rows: usize,
        n_features: usize,
        rows: &[f32],
        targets: Targets,
    ) -> Dataset {
        assert_eq!(rows.len(), n_rows * n_features);
        let mut cols = vec![0.0f32; rows.len()];
        for i in 0..n_rows {
            for f in 0..n_features {
                cols[f * n_rows + i] = rows[i * n_features + f];
            }
        }
        Dataset::new(n_rows, n_features, cols, targets)
    }

    #[inline]
    pub fn column(&self, f: usize) -> &[f32] {
        &self.features[f * self.n_rows..(f + 1) * self.n_rows]
    }

    #[inline]
    pub fn value(&self, row: usize, f: usize) -> f32 {
        self.features[f * self.n_rows + row]
    }

    pub fn n_outputs(&self) -> usize {
        self.targets.n_outputs()
    }

    /// Row subset as a new dataset (used by CV and train/test splits).
    /// Feature kinds carry over.
    pub fn gather(&self, rows: &[u32]) -> Dataset {
        let n = rows.len();
        let mut feats = vec![0.0f32; n * self.n_features];
        for f in 0..self.n_features {
            let src = self.column(f);
            let dst = &mut feats[f * n..(f + 1) * n];
            for (j, &i) in rows.iter().enumerate() {
                dst[j] = src[i as usize];
            }
        }
        let mut out = Dataset::new(n, self.n_features, feats, self.targets.gather(rows));
        out.kinds.copy_from_slice(&self.kinds);
        out
    }

    /// One row's feature values (row-major order), for prediction APIs.
    pub fn row(&self, i: usize) -> Vec<f32> {
        (0..self.n_features).map(|f| self.value(i, f)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        // rows: [1,10], [2,20], [3,30]
        Dataset::from_row_major(
            3,
            2,
            &[1.0, 10.0, 2.0, 20.0, 3.0, 30.0],
            Targets::Multiclass { labels: vec![0, 1, 0], n_classes: 2 },
        )
    }

    #[test]
    fn row_major_transposes() {
        let d = toy();
        assert_eq!(d.column(0), &[1.0, 2.0, 3.0]);
        assert_eq!(d.column(1), &[10.0, 20.0, 30.0]);
        assert_eq!(d.value(1, 1), 20.0);
        assert_eq!(d.row(2), vec![3.0, 30.0]);
    }

    #[test]
    fn gather_subset() {
        let d = toy();
        let g = d.gather(&[2, 0]);
        assert_eq!(g.n_rows, 2);
        assert_eq!(g.column(0), &[3.0, 1.0]);
        match g.targets {
            Targets::Multiclass { ref labels, .. } => assert_eq!(labels, &vec![0, 0]),
            _ => panic!(),
        }
    }

    #[test]
    fn gather_multilabel_rows() {
        let t = Targets::Multilabel { labels: vec![1., 0., 0., 1., 1., 1.], n_labels: 2 };
        let g = t.gather(&[2, 1]);
        match g {
            Targets::Multilabel { labels, .. } => assert_eq!(labels, vec![1., 1., 0., 1.]),
            _ => panic!(),
        }
    }

    #[test]
    #[should_panic]
    fn size_mismatch_panics() {
        Dataset::new(3, 2, vec![0.0; 5], Targets::Regression { values: vec![0.0; 3], n_targets: 1 });
    }

    #[test]
    fn kinds_default_numeric_and_propagate_through_gather() {
        let mut d = toy();
        assert_eq!(d.kinds, vec![FeatureKind::Numeric; 2]);
        d.mark_categorical(&[1]);
        assert_eq!(d.kinds[1], FeatureKind::Categorical);
        let g = d.gather(&[0, 2]);
        assert_eq!(g.kinds, vec![FeatureKind::Numeric, FeatureKind::Categorical]);
    }

    #[test]
    #[should_panic]
    fn mark_categorical_rejects_out_of_range() {
        toy().mark_categorical(&[5]);
    }

    #[test]
    fn outputs_dimension() {
        assert_eq!(toy().n_outputs(), 2);
        let t = Targets::Regression { values: vec![0.0; 12], n_targets: 4 };
        assert_eq!(t.n_outputs(), 4);
        assert_eq!(t.len(), 3);
    }
}
