//! Train/test splitting and k-fold cross-validation (experiment design of
//! Appendix B.2: 80/20 split, then 5-fold CV on the train set with the
//! validation fold driving early stopping).

use crate::data::dataset::Dataset;
use crate::util::rng::Rng;

/// Random row split into (train, test) with `test_frac` in the test set.
pub fn train_test_split(ds: &Dataset, test_frac: f64, seed: u64) -> (Dataset, Dataset) {
    assert!((0.0..1.0).contains(&test_frac));
    let mut rng = Rng::new(seed);
    let mut idx: Vec<u32> = (0..ds.n_rows as u32).collect();
    rng.shuffle(&mut idx);
    let n_test = ((ds.n_rows as f64) * test_frac).round() as usize;
    let n_test = n_test.clamp(1, ds.n_rows - 1);
    let (test_idx, train_idx) = idx.split_at(n_test);
    (ds.gather(train_idx), ds.gather(test_idx))
}

/// Index folds for k-fold CV. Returns `k` (train_rows, valid_rows) pairs.
pub fn kfold_indices(n: usize, k: usize, seed: u64) -> Vec<(Vec<u32>, Vec<u32>)> {
    assert!(k >= 2 && k <= n, "need 2 <= k <= n");
    let mut rng = Rng::new(seed);
    let mut idx: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut idx);
    let mut folds = Vec::with_capacity(k);
    let base = n / k;
    let extra = n % k;
    let mut start = 0usize;
    for f in 0..k {
        let len = base + usize::from(f < extra);
        let valid: Vec<u32> = idx[start..start + len].to_vec();
        let mut train = Vec::with_capacity(n - len);
        train.extend_from_slice(&idx[..start]);
        train.extend_from_slice(&idx[start + len..]);
        folds.push((train, valid));
        start += len;
    }
    folds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Targets;
    use crate::util::proptest::run_prop;

    fn toy(n: usize) -> Dataset {
        Dataset::new(
            n,
            1,
            (0..n).map(|i| i as f32).collect(),
            Targets::Regression { values: vec![0.0; n], n_targets: 1 },
        )
    }

    #[test]
    fn split_sizes() {
        let (tr, te) = train_test_split(&toy(100), 0.2, 0);
        assert_eq!(tr.n_rows, 80);
        assert_eq!(te.n_rows, 20);
    }

    #[test]
    fn split_is_partition() {
        let (tr, te) = train_test_split(&toy(50), 0.3, 1);
        let mut all: Vec<i64> = tr
            .column(0)
            .iter()
            .chain(te.column(0).iter())
            .map(|&x| x as i64)
            .collect();
        all.sort_unstable();
        assert_eq!(all, (0..50).collect::<Vec<i64>>());
    }

    #[test]
    fn kfold_partitions_everything() {
        run_prop("kfold partition", 20, |g| {
            let n = g.usize_in(10, 200);
            let k = g.usize_in(2, 5.min(n));
            let folds = kfold_indices(n, k, g.seed);
            assert_eq!(folds.len(), k);
            let mut all_valid: Vec<u32> = Vec::new();
            for (tr, va) in &folds {
                assert_eq!(tr.len() + va.len(), n);
                // disjoint within a fold
                let mut t = tr.clone();
                t.extend_from_slice(va);
                t.sort_unstable();
                t.dedup();
                assert_eq!(t.len(), n);
                all_valid.extend_from_slice(va);
            }
            // valid folds tile [0, n)
            all_valid.sort_unstable();
            assert_eq!(all_valid, (0..n as u32).collect::<Vec<u32>>());
        });
    }

    #[test]
    fn fold_sizes_balanced() {
        let folds = kfold_indices(103, 5, 7);
        let sizes: Vec<usize> = folds.iter().map(|(_, v)| v.len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 103);
        assert!(sizes.iter().all(|&s| s == 20 || s == 21));
    }

    #[test]
    #[should_panic]
    fn kfold_rejects_k1() {
        kfold_indices(10, 1, 0);
    }
}
