//! Synthetic stand-ins for the paper's evaluation datasets.
//!
//! Each profile records the real dataset's shape (Table 5 / Appendix B.6)
//! and a CPU-budget scale factor for rows (and, for the very wide MoA /
//! Delicious / MNIST-family sets, features). The generators keep the
//! output dimension `d` exact — d is the variable the paper's claims are
//! about — and preserve task type and rough n/m ratios. See DESIGN.md
//! section Substitutions.

use crate::data::dataset::Dataset;
use crate::data::synthetic::{make_multiclass, make_multilabel, make_multitask, FeatureSpec};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskKind {
    Multiclass,
    Multilabel,
    Multitask,
}

/// A named dataset profile.
#[derive(Clone, Copy, Debug)]
pub struct Profile {
    pub name: &'static str,
    pub task: TaskKind,
    /// the real dataset's shape (rows, features, outputs)
    pub paper_rows: usize,
    pub paper_features: usize,
    pub outputs: usize,
    /// scaled shape used by default in this repo's benches
    pub rows: usize,
    pub features: usize,
    /// latent rank for multilabel/multitask (inter-output correlation)
    pub rank: usize,
}

/// Table 5 datasets (the paper's main evaluation).
pub const MAIN: [Profile; 9] = [
    Profile { name: "otto", task: TaskKind::Multiclass, paper_rows: 61_878, paper_features: 93, outputs: 9, rows: 6000, features: 93, rank: 0 },
    Profile { name: "sf-crime", task: TaskKind::Multiclass, paper_rows: 878_049, paper_features: 10, outputs: 39, rows: 8000, features: 10, rank: 0 },
    Profile { name: "helena", task: TaskKind::Multiclass, paper_rows: 65_196, paper_features: 27, outputs: 100, rows: 6000, features: 27, rank: 0 },
    Profile { name: "dionis", task: TaskKind::Multiclass, paper_rows: 416_188, paper_features: 60, outputs: 355, rows: 6000, features: 60, rank: 0 },
    Profile { name: "mediamill", task: TaskKind::Multilabel, paper_rows: 43_907, paper_features: 120, outputs: 101, rows: 4000, features: 120, rank: 8 },
    Profile { name: "moa", task: TaskKind::Multilabel, paper_rows: 23_814, paper_features: 876, outputs: 206, rows: 2000, features: 220, rank: 12 },
    Profile { name: "delicious", task: TaskKind::Multilabel, paper_rows: 16_105, paper_features: 500, outputs: 983, rows: 1500, features: 125, rank: 16 },
    Profile { name: "rf1", task: TaskKind::Multitask, paper_rows: 9_125, paper_features: 64, outputs: 8, rows: 4000, features: 64, rank: 3 },
    Profile { name: "scm20d", task: TaskKind::Multitask, paper_rows: 8_966, paper_features: 61, outputs: 16, rows: 4000, features: 61, rank: 4 },
];

/// Appendix B.6 datasets (the GBDT-MO comparison).
pub const GBDTMO: [Profile; 4] = [
    Profile { name: "mnist", task: TaskKind::Multiclass, paper_rows: 70_000, paper_features: 784, outputs: 10, rows: 4000, features: 196, rank: 0 },
    Profile { name: "caltech", task: TaskKind::Multiclass, paper_rows: 9_144, paper_features: 324, outputs: 101, rows: 2000, features: 162, rank: 0 },
    Profile { name: "nus-wide", task: TaskKind::Multilabel, paper_rows: 269_648, paper_features: 128, outputs: 81, rows: 3000, features: 128, rank: 8 },
    Profile { name: "mnist-reg", task: TaskKind::Multitask, paper_rows: 70_000, paper_features: 392, outputs: 24, rows: 3000, features: 98, rank: 6 },
];

impl Profile {
    pub fn by_name(name: &str) -> Option<Profile> {
        MAIN.iter().chain(GBDTMO.iter()).find(|p| p.name == name).copied()
    }

    /// Generate the scaled synthetic dataset for this profile.
    pub fn generate(&self, seed: u64) -> Dataset {
        self.generate_sized(self.rows, seed)
    }

    /// Generate with an explicit row count (benches shrink further).
    pub fn generate_sized(&self, rows: usize, seed: u64) -> Dataset {
        let spec = FeatureSpec::guyon(self.features);
        match self.task {
            TaskKind::Multiclass => make_multiclass(rows, spec, self.outputs, 1.6, seed),
            TaskKind::Multilabel => make_multilabel(rows, spec, self.outputs, self.rank, seed),
            TaskKind::Multitask => make_multitask(rows, spec, self.outputs, self.rank, 0.3, seed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Targets;

    #[test]
    fn lookup_by_name() {
        assert_eq!(Profile::by_name("otto").unwrap().outputs, 9);
        assert_eq!(Profile::by_name("mnist").unwrap().outputs, 10);
        assert!(Profile::by_name("nope").is_none());
    }

    #[test]
    fn all_profiles_generate() {
        for p in MAIN.iter().chain(GBDTMO.iter()) {
            let ds = p.generate_sized(200, 1);
            assert_eq!(ds.n_rows, 200, "{}", p.name);
            assert_eq!(ds.n_features, p.features, "{}", p.name);
            assert_eq!(ds.n_outputs(), p.outputs, "{}", p.name);
            let ok = matches!(
                (&ds.targets, p.task),
                (Targets::Multiclass { .. }, TaskKind::Multiclass)
                    | (Targets::Multilabel { .. }, TaskKind::Multilabel)
                    | (Targets::Regression { .. }, TaskKind::Multitask)
            );
            assert!(ok, "task kind mismatch for {}", p.name);
        }
    }

    #[test]
    fn output_dims_match_paper() {
        // d is the variable the paper's claims are about: never scale it.
        let d: Vec<usize> = MAIN.iter().map(|p| p.outputs).collect();
        assert_eq!(d, vec![9, 39, 100, 355, 101, 206, 983, 8, 16]);
    }
}
