//! Synthetic stand-ins for the paper's evaluation datasets.
//!
//! Each profile records the real dataset's shape (Table 5 / Appendix B.6)
//! and a CPU-budget scale factor for rows (and, for the very wide MoA /
//! Delicious / MNIST-family sets, features). The generators keep the
//! output dimension `d` exact — d is the variable the paper's claims are
//! about — and preserve task type and rough n/m ratios. See DESIGN.md
//! section Substitutions.
//!
//! The [`SPARSE`] profiles open the sparse/categorical workload class
//! the real datasets live in (MoA et al. are sparse and category-heavy):
//! `missing_rate` injects NaN into feature cells and `n_categorical`
//! switches the leading columns to integer category ids driven by a
//! categorical generative rule (`synthetic::make_categorical_multitask`).

use crate::data::dataset::Dataset;
use crate::data::synthetic::{
    inject_missing, make_categorical_multitask, make_multiclass, make_multilabel,
    make_multitask, FeatureSpec,
};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskKind {
    Multiclass,
    Multilabel,
    Multitask,
}

/// A named dataset profile.
#[derive(Clone, Copy, Debug)]
pub struct Profile {
    pub name: &'static str,
    pub task: TaskKind,
    /// the real dataset's shape (rows, features, outputs)
    pub paper_rows: usize,
    pub paper_features: usize,
    pub outputs: usize,
    /// scaled shape used by default in this repo's benches
    pub rows: usize,
    pub features: usize,
    /// latent rank for multilabel/multitask (inter-output correlation)
    pub rank: usize,
    /// fraction of feature cells replaced with NaN after generation
    pub missing_rate: f32,
    /// leading feature columns generated as categorical ids (0 = none;
    /// implies a categorical generative rule — Multitask only)
    pub n_categorical: usize,
    /// category cardinality of the categorical columns
    pub cardinality: usize,
}

/// Table 5 datasets (the paper's main evaluation).
pub const MAIN: [Profile; 9] = [
    Profile { name: "otto", task: TaskKind::Multiclass, paper_rows: 61_878, paper_features: 93, outputs: 9, rows: 6000, features: 93, rank: 0, missing_rate: 0.0, n_categorical: 0, cardinality: 0 },
    Profile { name: "sf-crime", task: TaskKind::Multiclass, paper_rows: 878_049, paper_features: 10, outputs: 39, rows: 8000, features: 10, rank: 0, missing_rate: 0.0, n_categorical: 0, cardinality: 0 },
    Profile { name: "helena", task: TaskKind::Multiclass, paper_rows: 65_196, paper_features: 27, outputs: 100, rows: 6000, features: 27, rank: 0, missing_rate: 0.0, n_categorical: 0, cardinality: 0 },
    Profile { name: "dionis", task: TaskKind::Multiclass, paper_rows: 416_188, paper_features: 60, outputs: 355, rows: 6000, features: 60, rank: 0, missing_rate: 0.0, n_categorical: 0, cardinality: 0 },
    Profile { name: "mediamill", task: TaskKind::Multilabel, paper_rows: 43_907, paper_features: 120, outputs: 101, rows: 4000, features: 120, rank: 8, missing_rate: 0.0, n_categorical: 0, cardinality: 0 },
    Profile { name: "moa", task: TaskKind::Multilabel, paper_rows: 23_814, paper_features: 876, outputs: 206, rows: 2000, features: 220, rank: 12, missing_rate: 0.0, n_categorical: 0, cardinality: 0 },
    Profile { name: "delicious", task: TaskKind::Multilabel, paper_rows: 16_105, paper_features: 500, outputs: 983, rows: 1500, features: 125, rank: 16, missing_rate: 0.0, n_categorical: 0, cardinality: 0 },
    Profile { name: "rf1", task: TaskKind::Multitask, paper_rows: 9_125, paper_features: 64, outputs: 8, rows: 4000, features: 64, rank: 3, missing_rate: 0.0, n_categorical: 0, cardinality: 0 },
    Profile { name: "scm20d", task: TaskKind::Multitask, paper_rows: 8_966, paper_features: 61, outputs: 16, rows: 4000, features: 61, rank: 4, missing_rate: 0.0, n_categorical: 0, cardinality: 0 },
];

/// Appendix B.6 datasets (the GBDT-MO comparison).
pub const GBDTMO: [Profile; 4] = [
    Profile { name: "mnist", task: TaskKind::Multiclass, paper_rows: 70_000, paper_features: 784, outputs: 10, rows: 4000, features: 196, rank: 0, missing_rate: 0.0, n_categorical: 0, cardinality: 0 },
    Profile { name: "caltech", task: TaskKind::Multiclass, paper_rows: 9_144, paper_features: 324, outputs: 101, rows: 2000, features: 162, rank: 0, missing_rate: 0.0, n_categorical: 0, cardinality: 0 },
    Profile { name: "nus-wide", task: TaskKind::Multilabel, paper_rows: 269_648, paper_features: 128, outputs: 81, rows: 3000, features: 128, rank: 8, missing_rate: 0.0, n_categorical: 0, cardinality: 0 },
    Profile { name: "mnist-reg", task: TaskKind::Multitask, paper_rows: 70_000, paper_features: 392, outputs: 24, rows: 3000, features: 98, rank: 6, missing_rate: 0.0, n_categorical: 0, cardinality: 0 },
];

/// Sparse / categorical workload profiles (the data regime the real
/// multilabel sets live in; `rust/tests/missing_categorical.rs` and the
/// CI smoke-train run on these).
pub const SPARSE: [Profile; 2] = [
    // MoA-shaped multilabel with a quarter of the cells missing
    Profile { name: "moa-nan", task: TaskKind::Multilabel, paper_rows: 23_814, paper_features: 876, outputs: 206, rows: 2000, features: 220, rank: 12, missing_rate: 0.25, n_categorical: 0, cardinality: 0 },
    // multitask regression driven by scattered category subsets, with a
    // sprinkle of missing cells — native categorical splits must beat
    // codes-as-ordinal here (acceptance-tested)
    Profile { name: "cat-rule", task: TaskKind::Multitask, paper_rows: 0, paper_features: 0, outputs: 8, rows: 4000, features: 24, rank: 0, missing_rate: 0.05, n_categorical: 16, cardinality: 12 },
];

/// Tiny profiles for CI smoke jobs (train + serve in seconds on a
/// 2-core runner). `moa-small` keeps MoA's multilabel task shape at a
/// width a shell client can type (64 features).
pub const SMOKE: [Profile; 1] = [
    Profile { name: "moa-small", task: TaskKind::Multilabel, paper_rows: 23_814, paper_features: 876, outputs: 24, rows: 800, features: 64, rank: 6, missing_rate: 0.0, n_categorical: 0, cardinality: 0 },
];

impl Profile {
    pub fn by_name(name: &str) -> Option<Profile> {
        MAIN.iter()
            .chain(GBDTMO.iter())
            .chain(SPARSE.iter())
            .chain(SMOKE.iter())
            .find(|p| p.name == name)
            .copied()
    }

    /// Feature columns that hold category ids (for CLI / config wiring;
    /// the generated dataset also carries the marks itself).
    pub fn categorical_columns(&self) -> Vec<usize> {
        (0..self.n_categorical).collect()
    }

    /// Generate the scaled synthetic dataset for this profile.
    pub fn generate(&self, seed: u64) -> Dataset {
        self.generate_sized(self.rows, seed)
    }

    /// Generate with an explicit row count (benches shrink further).
    pub fn generate_sized(&self, rows: usize, seed: u64) -> Dataset {
        let mut ds = if self.n_categorical > 0 {
            debug_assert_eq!(self.task, TaskKind::Multitask);
            make_categorical_multitask(
                rows,
                self.n_categorical,
                self.cardinality,
                self.features - self.n_categorical,
                self.outputs,
                0.3,
                seed,
            )
        } else {
            let spec = FeatureSpec::guyon(self.features);
            match self.task {
                TaskKind::Multiclass => make_multiclass(rows, spec, self.outputs, 1.6, seed),
                TaskKind::Multilabel => make_multilabel(rows, spec, self.outputs, self.rank, seed),
                TaskKind::Multitask => {
                    make_multitask(rows, spec, self.outputs, self.rank, 0.3, seed)
                }
            }
        };
        if self.missing_rate > 0.0 {
            inject_missing(&mut ds, self.missing_rate, seed);
        }
        ds
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::{FeatureKind, Targets};

    #[test]
    fn lookup_by_name() {
        assert_eq!(Profile::by_name("otto").unwrap().outputs, 9);
        assert_eq!(Profile::by_name("mnist").unwrap().outputs, 10);
        assert_eq!(Profile::by_name("moa-nan").unwrap().outputs, 206);
        assert_eq!(Profile::by_name("cat-rule").unwrap().n_categorical, 16);
        let small = Profile::by_name("moa-small").unwrap();
        assert_eq!((small.features, small.outputs), (64, 24));
        assert!(Profile::by_name("nope").is_none());
    }

    #[test]
    fn all_profiles_generate() {
        for p in MAIN.iter().chain(GBDTMO.iter()).chain(SPARSE.iter()).chain(SMOKE.iter()) {
            let ds = p.generate_sized(200, 1);
            assert_eq!(ds.n_rows, 200, "{}", p.name);
            assert_eq!(ds.n_features, p.features, "{}", p.name);
            assert_eq!(ds.n_outputs(), p.outputs, "{}", p.name);
            let ok = matches!(
                (&ds.targets, p.task),
                (Targets::Multiclass { .. }, TaskKind::Multiclass)
                    | (Targets::Multilabel { .. }, TaskKind::Multilabel)
                    | (Targets::Regression { .. }, TaskKind::Multitask)
            );
            assert!(ok, "task kind mismatch for {}", p.name);
        }
    }

    #[test]
    fn sparse_profiles_carry_their_structure() {
        let nan = Profile::by_name("moa-nan").unwrap().generate_sized(300, 2);
        let frac = nan.features.iter().filter(|v| v.is_nan()).count() as f64
            / nan.features.len() as f64;
        assert!((frac - 0.25).abs() < 0.03, "nan fraction {frac}");

        let cat = Profile::by_name("cat-rule").unwrap();
        let ds = cat.generate_sized(300, 2);
        assert_eq!(cat.categorical_columns(), (0..16).collect::<Vec<_>>());
        for f in 0..ds.n_features {
            let want = if f < 16 { FeatureKind::Categorical } else { FeatureKind::Numeric };
            assert_eq!(ds.kinds[f], want, "feature {f}");
        }
        // missing cells exist on categorical columns too
        assert!(ds.column(0).iter().any(|v| v.is_nan()) || ds.column(1).iter().any(|v| v.is_nan()));
    }

    #[test]
    fn output_dims_match_paper() {
        // d is the variable the paper's claims are about: never scale it.
        let d: Vec<usize> = MAIN.iter().map(|p| p.outputs).collect();
        assert_eq!(d, vec![9, 39, 100, 355, 101, 206, 983, 8, 16]);
    }
}
