//! Synthetic dataset generators.
//!
//! The paper evaluates on 13 public datasets (Kaggle/OpenML/Mulan) we
//! cannot download in this environment; DESIGN.md section Substitutions
//! documents the replacement. These generators produce workloads with the
//! same *structural* parameters that drive the paper's effects: sample
//! count n, feature count m (informative / linear-combination / redundant
//! split, following Guyon's `make_classification` design used by the
//! paper's own Appendix B.7 synthetic experiment), output dimension d,
//! and inter-output correlation (cluster structure for multiclass, latent
//! low-rank factors for multilabel/multitask — which is exactly when
//! sketching can work: G has small stable rank).

use crate::data::dataset::{Dataset, Targets};
use crate::util::rng::Rng;

/// Feature-block design shared by all generators (Guyon-style).
#[derive(Clone, Copy, Debug)]
pub struct FeatureSpec {
    pub n_informative: usize,
    /// features that are random linear combinations of informative ones
    pub n_linear: usize,
    /// pure-noise features
    pub n_redundant: usize,
}

impl FeatureSpec {
    pub fn total(&self) -> usize {
        self.n_informative + self.n_linear + self.n_redundant
    }

    /// The paper's B.7 split for m=100: 10 informative, 20 linear, 70 noise.
    pub fn guyon(m: usize) -> FeatureSpec {
        let n_informative = (m / 10).max(2);
        let n_linear = (m / 5).min(m - n_informative);
        FeatureSpec {
            n_informative,
            n_linear,
            n_redundant: m - n_informative - n_linear,
        }
    }
}

/// Fill the linear-combination and noise blocks given the informative
/// block; returns a column-major feature buffer of spec.total() columns.
fn expand_features(
    inf: &[f32], // column-major n x n_informative
    n: usize,
    spec: FeatureSpec,
    rng: &mut Rng,
) -> Vec<f32> {
    let m = spec.total();
    let mut cols = vec![0.0f32; n * m];
    cols[..n * spec.n_informative].copy_from_slice(inf);
    // linear combinations
    for j in 0..spec.n_linear {
        let mut w = vec![0.0f32; spec.n_informative];
        rng.fill_gaussian(&mut w, 1.0);
        let dst_off = (spec.n_informative + j) * n;
        for f in 0..spec.n_informative {
            let src = &inf[f * n..(f + 1) * n];
            let wf = w[f];
            for i in 0..n {
                cols[dst_off + i] += wf * src[i];
            }
        }
    }
    // noise
    let noise_off = (spec.n_informative + spec.n_linear) * n;
    rng.fill_gaussian(&mut cols[noise_off..], 1.0);
    cols
}

/// Multiclass: class centroids at hypercube vertices + Gaussian scatter
/// (the structure of Guyon's make_classification, as used in App. B.7).
pub fn make_multiclass(
    n: usize,
    spec: FeatureSpec,
    n_classes: usize,
    class_sep: f32,
    seed: u64,
) -> Dataset {
    assert!(n_classes >= 2);
    let mut rng = Rng::new(seed);
    let p = spec.n_informative;
    // centroid per class: random sign pattern scaled by class_sep
    let mut centroids = vec![0.0f32; n_classes * p];
    for c in &mut centroids {
        *c = if rng.next_u64() & 1 == 0 { class_sep } else { -class_sep };
    }
    // make centroids distinct even at small p by adding gaussian offsets
    for c in centroids.iter_mut() {
        *c += (rng.next_gaussian() * 0.5) as f32;
    }
    let mut labels = vec![0u32; n];
    let mut inf = vec![0.0f32; n * p];
    for i in 0..n {
        let y = rng.next_below(n_classes) as u32;
        labels[i] = y;
        for f in 0..p {
            inf[f * n + i] =
                centroids[y as usize * p + f] + (rng.next_gaussian()) as f32;
        }
    }
    let cols = expand_features(&inf, n, spec, &mut rng);
    Dataset::new(n, spec.total(), cols, Targets::Multiclass { labels, n_classes })
}

/// Multilabel: latent low-rank factors drive correlated Bernoulli labels.
/// `rank` controls the stable rank of the induced gradient matrix — small
/// rank is the regime where sketching provably wins (Props. A.4/A.5).
pub fn make_multilabel(
    n: usize,
    spec: FeatureSpec,
    n_labels: usize,
    rank: usize,
    seed: u64,
) -> Dataset {
    let mut rng = Rng::new(seed);
    let p = spec.n_informative;
    let r = rank.max(1).min(n_labels);
    // latent factors z in R^r; features see z through A; labels through W
    let mut a = vec![0.0f32; r * p];
    rng.fill_gaussian(&mut a, 1.0);
    let mut w = vec![0.0f32; r * n_labels];
    rng.fill_gaussian(&mut w, 1.5);
    let mut bias = vec![0.0f32; n_labels];
    for b in bias.iter_mut() {
        *b = (rng.next_gaussian() * 0.5 - 1.0) as f32; // sparse-ish labels
    }
    let mut labels = vec![0.0f32; n * n_labels];
    let mut inf = vec![0.0f32; n * p];
    let mut z = vec![0.0f32; r];
    for i in 0..n {
        rng.fill_gaussian(&mut z, 1.0);
        for f in 0..p {
            let mut v = 0.0f32;
            for t in 0..r {
                v += z[t] * a[t * p + f];
            }
            inf[f * n + i] = v + (rng.next_gaussian() * 0.3) as f32;
        }
        for l in 0..n_labels {
            let mut logit = bias[l];
            for t in 0..r {
                logit += z[t] * w[t * n_labels + l];
            }
            let prob = 1.0 / (1.0 + (-logit as f64).exp());
            labels[i * n_labels + l] = if rng.next_f64() < prob { 1.0 } else { 0.0 };
        }
    }
    let cols = expand_features(&inf, n, spec, &mut rng);
    Dataset::new(n, spec.total(), cols, Targets::Multilabel { labels, n_labels })
}

/// Multitask regression: targets are low-rank linear + sinusoidal maps of
/// the informative features plus noise (nonlinearity gives trees work).
pub fn make_multitask(
    n: usize,
    spec: FeatureSpec,
    n_targets: usize,
    rank: usize,
    noise: f32,
    seed: u64,
) -> Dataset {
    let mut rng = Rng::new(seed);
    let p = spec.n_informative;
    let r = rank.max(1).min(n_targets);
    let mut mix = vec![0.0f32; p * r]; // features -> latent
    rng.fill_gaussian(&mut mix, 1.0);
    let mut head = vec![0.0f32; r * n_targets]; // latent -> targets
    rng.fill_gaussian(&mut head, 1.0);
    let mut inf = vec![0.0f32; n * p];
    rng.fill_gaussian(&mut inf, 1.0);
    let mut values = vec![0.0f32; n * n_targets];
    let mut lat = vec![0.0f32; r];
    for i in 0..n {
        for t in 0..r {
            let mut v = 0.0f32;
            for f in 0..p {
                v += inf[f * n + i] * mix[f * r + t];
            }
            // bounded nonlinearity so trees (piecewise-constant) can fit it
            lat[t] = v + (v * 0.7).sin();
        }
        for j in 0..n_targets {
            let mut y = 0.0f32;
            for t in 0..r {
                y += lat[t] * head[t * n_targets + j];
            }
            values[i * n_targets + j] = y + (rng.next_gaussian() as f32) * noise;
        }
    }
    let cols = expand_features(&inf, n, spec, &mut rng);
    Dataset::new(n, spec.total(), cols, Targets::Regression { values, n_targets })
}

/// Replace a `rate` fraction of feature cells with NaN (missing),
/// deterministically per seed. Targets are untouched. Works on
/// categorical columns too — a missing category id is just a missing
/// value.
pub fn inject_missing(ds: &mut Dataset, rate: f32, seed: u64) {
    let mut rng = Rng::new(seed ^ 0x4d49_5353); // "MISS"
    for v in ds.features.iter_mut() {
        if rng.next_f32() < rate {
            *v = f32::NAN;
        }
    }
}

/// Multitask regression whose generative rule is *categorical*: the
/// first `n_cat` feature columns hold category ids in `[0, cards)`, and
/// each target is a weighted sum of per-feature subset indicators
/// `[id ∈ S_f]` for random *scattered* subsets `S_f`, plus Gaussian
/// noise and `n_noise` pure-noise numeric columns. Because the subsets
/// are scattered across id order, one category-set split isolates each
/// rule while an ordinal scan over the ids needs many splits — the
/// workload where native categorical splits must win
/// (`rust/tests/missing_categorical.rs` asserts exactly that).
pub fn make_categorical_multitask(
    n: usize,
    n_cat: usize,
    cards: usize,
    n_noise: usize,
    n_targets: usize,
    noise: f32,
    seed: u64,
) -> Dataset {
    assert!(n_cat >= 1 && (2..=255).contains(&cards));
    let m = n_cat + n_noise;
    let mut rng = Rng::new(seed);
    // one scattered, non-trivial subset per categorical feature
    let mut member = vec![false; n_cat * cards];
    for f in 0..n_cat {
        let row = &mut member[f * cards..(f + 1) * cards];
        loop {
            for b in row.iter_mut() {
                *b = rng.next_u64() & 1 == 1;
            }
            if row.iter().any(|&b| b) && row.iter().any(|&b| !b) {
                break;
            }
        }
    }
    let mut w = vec![0.0f32; n_cat * n_targets];
    rng.fill_gaussian(&mut w, 1.0);
    let mut cols = vec![0.0f32; n * m];
    let mut values = vec![0.0f32; n * n_targets];
    for i in 0..n {
        for f in 0..n_cat {
            let id = rng.next_below(cards);
            cols[f * n + i] = id as f32;
            if member[f * cards + id] {
                for j in 0..n_targets {
                    values[i * n_targets + j] += w[f * n_targets + j];
                }
            }
        }
        for j in 0..n_targets {
            values[i * n_targets + j] += (rng.next_gaussian() as f32) * noise;
        }
    }
    rng.fill_gaussian(&mut cols[n_cat * n..], 1.0);
    let mut ds = Dataset::new(n, m, cols, Targets::Regression { values, n_targets });
    let cat_cols: Vec<usize> = (0..n_cat).collect();
    ds.mark_categorical(&cat_cols);
    ds
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn guyon_spec_partitions() {
        let s = FeatureSpec::guyon(100);
        assert_eq!(s.total(), 100);
        assert_eq!(s.n_informative, 10);
        assert_eq!(s.n_linear, 20);
        assert_eq!(s.n_redundant, 70);
    }

    #[test]
    fn multiclass_shapes_and_label_range() {
        let ds = make_multiclass(500, FeatureSpec::guyon(20), 7, 1.5, 1);
        assert_eq!(ds.n_rows, 500);
        assert_eq!(ds.n_features, 20);
        match &ds.targets {
            Targets::Multiclass { labels, n_classes } => {
                assert_eq!(*n_classes, 7);
                assert!(labels.iter().all(|&l| l < 7));
                // all classes present at n=500
                let mut seen = vec![false; 7];
                for &l in labels {
                    seen[l as usize] = true;
                }
                assert!(seen.iter().all(|&s| s));
            }
            _ => panic!(),
        }
    }

    #[test]
    fn multiclass_is_learnable_signal() {
        // informative features must separate classes: per-class means of
        // feature 0 should differ substantially vs. within-class std.
        let ds = make_multiclass(2000, FeatureSpec::guyon(10), 3, 2.0, 3);
        let labels = match &ds.targets {
            Targets::Multiclass { labels, .. } => labels.clone(),
            _ => panic!(),
        };
        let col = ds.column(0);
        let mut sums = [0.0f64; 3];
        let mut counts = [0usize; 3];
        for i in 0..ds.n_rows {
            sums[labels[i] as usize] += col[i] as f64;
            counts[labels[i] as usize] += 1;
        }
        let means: Vec<f64> = (0..3).map(|c| sums[c] / counts[c] as f64).collect();
        let spread = means
            .iter()
            .fold(f64::MIN, |a, &b| a.max(b))
            - means.iter().fold(f64::MAX, |a, &b| a.min(b));
        assert!(spread > 0.5, "classes not separated: {means:?}");
    }

    #[test]
    fn multilabel_binary_and_correlated() {
        let ds = make_multilabel(800, FeatureSpec::guyon(15), 12, 3, 5);
        match &ds.targets {
            Targets::Multilabel { labels, n_labels } => {
                assert_eq!(*n_labels, 12);
                assert!(labels.iter().all(|&v| v == 0.0 || v == 1.0));
                let n = 800;
                // some label must be on at least sometimes
                let on: f32 = labels.iter().sum();
                assert!(on > 0.0 && (on as usize) < n * 12);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn multitask_low_rank_targets_correlate() {
        let ds = make_multitask(1000, FeatureSpec::guyon(10), 8, 2, 0.05, 7);
        let values = match &ds.targets {
            Targets::Regression { values, .. } => values.clone(),
            _ => panic!(),
        };
        // rank-2 structure: gram matrix of targets must be rank-deficient;
        // check total variance vs top-2 crude proxy: pairwise |corr| high
        // for at least one pair.
        let n = 1000usize;
        let d = 8usize;
        let col = |j: usize| -> Vec<f32> { (0..n).map(|i| values[i * d + j]).collect() };
        let c0 = col(0);
        let mut best = 0.0f64;
        for j in 1..d {
            let cj = col(j);
            let corr = correlation(&c0, &cj).abs();
            best = best.max(corr);
        }
        assert!(best > 0.5, "no correlated target pair: best |corr| = {best}");
    }

    fn correlation(a: &[f32], b: &[f32]) -> f64 {
        let n = a.len() as f64;
        let ma = a.iter().map(|&x| x as f64).sum::<f64>() / n;
        let mb = b.iter().map(|&x| x as f64).sum::<f64>() / n;
        let (mut sab, mut saa, mut sbb) = (0.0, 0.0, 0.0);
        for i in 0..a.len() {
            let da = a[i] as f64 - ma;
            let db = b[i] as f64 - mb;
            sab += da * db;
            saa += da * da;
            sbb += db * db;
        }
        sab / (saa.sqrt() * sbb.sqrt() + 1e-12)
    }

    #[test]
    fn inject_missing_hits_roughly_the_rate_and_is_deterministic() {
        let mut a = make_multiclass(500, FeatureSpec::guyon(10), 3, 1.0, 1);
        let mut b = a.clone();
        inject_missing(&mut a, 0.2, 7);
        inject_missing(&mut b, 0.2, 7);
        let nan_a: Vec<bool> = a.features.iter().map(|v| v.is_nan()).collect();
        let nan_b: Vec<bool> = b.features.iter().map(|v| v.is_nan()).collect();
        assert_eq!(nan_a, nan_b);
        let frac = nan_a.iter().filter(|&&x| x).count() as f64 / nan_a.len() as f64;
        assert!((frac - 0.2).abs() < 0.03, "nan fraction {frac}");
        // targets untouched
        match (&a.targets, &b.targets) {
            (Targets::Multiclass { labels: la, .. }, Targets::Multiclass { labels: lb, .. }) => {
                assert_eq!(la, lb)
            }
            _ => panic!(),
        }
    }

    #[test]
    fn categorical_multitask_shapes_and_signal() {
        use crate::data::dataset::FeatureKind;
        let ds = make_categorical_multitask(800, 4, 8, 3, 5, 0.1, 3);
        assert_eq!(ds.n_rows, 800);
        assert_eq!(ds.n_features, 7);
        assert_eq!(ds.n_outputs(), 5);
        for f in 0..7 {
            let want = if f < 4 { FeatureKind::Categorical } else { FeatureKind::Numeric };
            assert_eq!(ds.kinds[f], want, "feature {f}");
        }
        // categorical columns hold integer ids below the cardinality
        for f in 0..4 {
            for &x in ds.column(f) {
                assert!(x >= 0.0 && x < 8.0 && x.fract() == 0.0, "bad id {x}");
            }
        }
        // the rule is real: conditioning target 0 on feature 0's subset
        // membership must separate the means
        let values = match &ds.targets {
            Targets::Regression { values, .. } => values,
            _ => panic!(),
        };
        let col = ds.column(0);
        let mut by_id = vec![(0.0f64, 0usize); 8];
        for i in 0..800 {
            let e = &mut by_id[col[i] as usize];
            e.0 += values[i * 5] as f64;
            e.1 += 1;
        }
        let means: Vec<f64> = by_id
            .iter()
            .filter(|(_, c)| *c > 0)
            .map(|(s, c)| s / *c as f64)
            .collect();
        let spread = means.iter().fold(f64::MIN, |a, &b| a.max(b))
            - means.iter().fold(f64::MAX, |a, &b| a.min(b));
        assert!(spread > 0.3, "per-category means not separated: {means:?}");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = make_multiclass(100, FeatureSpec::guyon(10), 3, 1.0, 42);
        let b = make_multiclass(100, FeatureSpec::guyon(10), 3, 1.0, 42);
        assert_eq!(a.features, b.features);
    }

    #[test]
    fn different_seed_differs() {
        let a = make_multiclass(100, FeatureSpec::guyon(10), 3, 1.0, 1);
        let b = make_multiclass(100, FeatureSpec::guyon(10), 3, 1.0, 2);
        assert_ne!(a.features, b.features);
    }
}
