//! Out-of-core binned source: pages the `data/store.rs` chunk payloads
//! in on demand through a bounded pool of recycled buffers and serves
//! them through the [`BinnedSource`] histogram input contract, so the
//! engine and tree builder train from disk exactly as they do from RAM
//! (DESIGN.md §2d).
//!
//! Residency is pure caching: which chunks happen to be pooled never
//! changes a single bit of the training result — the determinism
//! contract lives entirely in the chunk *plan* (the ascending row
//! partition recorded in the store header).

use std::fs::File;
use std::os::unix::fs::FileExt;
use std::path::Path;
use std::sync::{Arc, Mutex};

use crate::data::binning::{BinSpec, BinnedDataset, BinnedSource, ChunkCols};
use crate::data::dataset::{FeatureKind, Targets};
use crate::data::store::{read_header, read_targets, verify_chunks, StoreError, StoreHeader};

struct PoolInner {
    /// Resident chunks in LRU order (front = coldest). The `Arc` count
    /// doubles as a pin: entries some thread is still reading
    /// (`strong_count > 1`) are never evicted.
    resident: Vec<(usize, Arc<Vec<u8>>)>,
    /// Retired buffers awaiting reuse (keeps steady-state at zero
    /// allocation once the pool is warm).
    free: Vec<Vec<u8>>,
}

/// Bounded pool of recycled chunk buffers. Loads happen under the pool
/// lock: that serializes disk reads (memcpy-speed on page-cached files)
/// but guarantees each chunk is read exactly once however many engine
/// shards race for it, with no double-buffering.
struct ChunkPool {
    inner: Mutex<PoolInner>,
    /// Target resident-chunk count. Temporarily exceeded when more than
    /// `budget` chunks are pinned by concurrent readers — the pool
    /// over-allocates rather than deadlocks.
    budget: usize,
}

impl ChunkPool {
    fn new(budget: usize) -> ChunkPool {
        ChunkPool {
            inner: Mutex::new(PoolInner { resident: Vec::new(), free: Vec::new() }),
            budget: budget.max(1),
        }
    }

    /// Get chunk `c` resident, loading via `load` on a miss.
    fn acquire(&self, c: usize, bytes: usize, load: impl FnOnce(&mut [u8])) -> Arc<Vec<u8>> {
        let mut g = self.inner.lock().unwrap();
        if let Some(pos) = g.resident.iter().position(|(id, _)| *id == c) {
            let entry = g.resident.remove(pos);
            let arc = entry.1.clone();
            g.resident.push(entry); // refresh to MRU
            return arc;
        }
        let mut buf = g.free.pop().unwrap_or_default();
        buf.resize(bytes, 0);
        load(&mut buf);
        let arc = Arc::new(buf);
        g.resident.push((c, arc.clone()));
        // evict coldest idle entries down to budget; pinned ones
        // (readers still hold the Arc) stay
        let mut i = 0;
        while g.resident.len() > self.budget && i < g.resident.len() - 1 {
            if Arc::strong_count(&g.resident[i].1) == 1 {
                let (_, a) = g.resident.remove(i);
                if let Ok(v) = Arc::try_unwrap(a) {
                    g.free.push(v);
                }
            } else {
                i += 1;
            }
        }
        arc
    }
}

/// An on-disk chunked binned dataset, opened from a `sketchboost bin`
/// store file. Implements [`BinnedSource`], so `Booster::fit_chunked`
/// trains from it with the unchanged engine/builder stack; only
/// `O(n_features * chunk_rows * pool_chunks)` code bytes are ever
/// resident.
pub struct ChunkedBinned {
    file: File,
    header: StoreHeader,
    targets: Targets,
    pool: ChunkPool,
}

impl ChunkedBinned {
    /// Open a store, structurally validating the header (truncation and
    /// malformed indexes surface as [`StoreError::Format`]). `pool_chunks`
    /// bounds how many chunks stay resident at once.
    pub fn open(path: &Path, pool_chunks: usize) -> Result<ChunkedBinned, StoreError> {
        let mut file = File::open(path)?;
        let header = read_header(&mut file)?;
        let targets = read_targets(&file, &header)?;
        Ok(ChunkedBinned { file, header, targets, pool: ChunkPool::new(pool_chunks) })
    }

    /// [`ChunkedBinned::open`] plus a streaming FNV-1a pass over every
    /// chunk payload ([`StoreError::Corrupt`] on mismatch).
    pub fn open_verified(path: &Path, pool_chunks: usize) -> Result<ChunkedBinned, StoreError> {
        let cb = ChunkedBinned::open(path, pool_chunks)?;
        verify_chunks(&cb.file, &cb.header)?;
        Ok(cb)
    }

    pub fn targets(&self) -> &Targets {
        &self.targets
    }

    pub fn n_outputs(&self) -> usize {
        self.targets.n_outputs()
    }

    pub fn spec(&self) -> BinSpec {
        self.header.spec()
    }

    /// Nominal rows per chunk (the tail may be ragged).
    pub fn chunk_rows(&self) -> usize {
        self.header.chunk_rows
    }

    pub fn header(&self) -> &StoreHeader {
        &self.header
    }

    /// Load the whole store into an in-RAM [`BinnedDataset`] (tests and
    /// small-data escapes; defeats the point for big data).
    pub fn to_binned(&self) -> BinnedDataset {
        let n = self.header.n_rows;
        let m = self.header.n_features;
        let mut codes = vec![0u8; n * m];
        for c in 0..self.header.chunks.len() {
            self.with_chunk(c, &mut |cols| {
                for f in 0..m {
                    codes[f * n + cols.start..f * n + cols.start + cols.len]
                        .copy_from_slice(cols.col(f));
                }
            });
        }
        BinnedDataset {
            n_rows: n,
            n_features: m,
            codes,
            edges: self.header.edges.clone(),
            n_bins: self.header.n_bins.clone(),
            max_bins: self.header.max_bins,
            kinds: self.header.kinds.clone(),
        }
    }
}

impl BinnedSource for ChunkedBinned {
    fn n_rows(&self) -> usize {
        self.header.n_rows
    }
    fn n_features(&self) -> usize {
        self.header.n_features
    }
    fn max_bins(&self) -> usize {
        self.header.max_bins
    }
    fn kinds(&self) -> &[FeatureKind] {
        &self.header.kinds
    }
    fn threshold_value(&self, f: usize, b: usize) -> f32 {
        debug_assert_eq!(self.header.kinds[f], FeatureKind::Numeric);
        let e = &self.header.edges[f];
        if e.is_empty() {
            f32::INFINITY
        } else {
            e[b.saturating_sub(1).min(e.len() - 1)]
        }
    }
    fn n_chunks(&self) -> usize {
        self.header.chunks.len()
    }
    fn chunk_range(&self, c: usize) -> std::ops::Range<usize> {
        let m = &self.header.chunks[c];
        m.start..m.start + m.rows
    }
    fn with_chunk(&self, c: usize, body: &mut dyn FnMut(ChunkCols<'_>)) {
        let meta = &self.header.chunks[c];
        let buf = self.pool.acquire(c, meta.bytes, |dst| {
            // The store was structurally validated at open; a read
            // failure here is an environment fault (device error,
            // file deleted under us) with no recovery path mid-train.
            self.file
                .read_exact_at(dst, meta.offset)
                .unwrap_or_else(|e| panic!("chunked store: reading chunk {c}: {e}"));
        });
        body(ChunkCols { codes: &buf, start: meta.start, len: meta.rows });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::store::write_binned;
    use crate::data::synthetic::{inject_missing, make_multiclass, FeatureSpec};

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("sb_chunked_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn sample() -> (BinnedDataset, Targets) {
        let mut ds = make_multiclass(150, FeatureSpec::guyon(6), 3, 1.5, 11);
        inject_missing(&mut ds, 0.1, 5);
        let binned = BinnedDataset::from_dataset(&ds, 32);
        (binned, ds.targets)
    }

    #[test]
    fn round_trips_every_chunk_byte() {
        let (binned, targets) = sample();
        for &chunk_rows in &[150usize, 64, 1] {
            let path = tmp(&format!("rt_{chunk_rows}.bin"));
            write_binned(&path, &binned, &targets, chunk_rows).unwrap();
            let cb = ChunkedBinned::open_verified(&path, 2).unwrap();
            assert_eq!(cb.n_rows(), binned.n_rows);
            assert_eq!(cb.n_features(), binned.n_features);
            assert_eq!(cb.max_bins(), binned.max_bins);
            assert_eq!(cb.kinds(), &binned.kinds[..]);
            assert_eq!(cb.targets(), &targets);
            let back = cb.to_binned();
            assert_eq!(back.codes, binned.codes, "chunk_rows={chunk_rows}");
            assert_eq!(back.n_bins, binned.n_bins);
            for f in 0..binned.n_features {
                for (a, e) in back.edges[f].iter().zip(binned.edges[f].iter()) {
                    assert_eq!(a.to_bits(), e.to_bits());
                }
            }
        }
    }

    #[test]
    fn chunk_ranges_partition_rows_ascending() {
        let (binned, targets) = sample();
        let path = tmp("plan.bin");
        write_binned(&path, &binned, &targets, 40).unwrap();
        let cb = ChunkedBinned::open(&path, 2).unwrap();
        assert_eq!(cb.n_chunks(), 4); // 40+40+40+30
        let mut next = 0;
        for c in 0..cb.n_chunks() {
            let r = cb.chunk_range(c);
            assert_eq!(r.start, next);
            assert!(r.end > r.start);
            next = r.end;
        }
        assert_eq!(next, cb.n_rows());
    }

    #[test]
    fn pool_recycles_buffers_within_budget() {
        let (binned, targets) = sample();
        let path = tmp("pool.bin");
        write_binned(&path, &binned, &targets, 10).unwrap(); // 15 chunks
        let cb = ChunkedBinned::open(&path, 3).unwrap();
        // several full sweeps through all chunks with a 3-chunk budget
        for _ in 0..4 {
            for c in 0..cb.n_chunks() {
                cb.with_chunk(c, &mut |cols| {
                    assert_eq!(cols.len, cb.chunk_range(c).len());
                });
            }
        }
        let g = cb.pool.inner.lock().unwrap();
        assert!(
            g.resident.len() <= 3,
            "resident {} exceeds budget with no pins outstanding",
            g.resident.len()
        );
        // free list holds retired buffers, ready for reuse
        assert!(g.resident.len() + g.free.len() <= 4);
    }

    #[test]
    fn concurrent_readers_see_consistent_chunks() {
        let (binned, targets) = sample();
        let path = tmp("conc.bin");
        write_binned(&path, &binned, &targets, 16).unwrap();
        let cb = ChunkedBinned::open(&path, 2).unwrap();
        let expected = &binned;
        std::thread::scope(|s| {
            for t in 0..4 {
                let cb = &cb;
                s.spawn(move || {
                    for round in 0..3 {
                        for c in 0..cb.n_chunks() {
                            let c = (c + t + round) % cb.n_chunks();
                            cb.with_chunk(c, &mut |cols| {
                                let r = cols.start;
                                for f in 0..expected.n_features {
                                    assert_eq!(
                                        cols.code(f, r),
                                        expected.codes[f * expected.n_rows + r]
                                    );
                                }
                            });
                        }
                    }
                });
            }
        });
    }

    #[test]
    fn missing_file_is_io_error() {
        match ChunkedBinned::open(&tmp("nope.bin"), 2) {
            Err(StoreError::Io(_)) => {}
            other => panic!("expected Io error, got {:?}", other.map(|_| ())),
        }
    }
}
