//! Quantile binning: continuous features -> u8 bin codes (histogram
//! algorithm, max 256 bins — Py-Boost's limit, Appendix B.1).
//!
//! Bin semantics: for edges e_0 < e_1 < ... < e_{B-2}, a value x maps to
//! the number of edges with e < x... precisely `bin(x) = #{j : x > e_j}`,
//! so bin b contains (e_{b-1}, e_b]. A split "left = bins <= b" therefore
//! corresponds to the raw-value predicate `x <= e_b`, which is what the
//! tree stores as its float threshold for inference on unbinned data.
//! NaN maps to bin 0 (missing-as-smallest policy).

use crate::data::dataset::Dataset;

/// Per-feature quantization of a dataset.
#[derive(Clone, Debug)]
pub struct BinnedDataset {
    pub n_rows: usize,
    pub n_features: usize,
    /// Column-major bin codes: codes[f * n_rows + i].
    pub codes: Vec<u8>,
    /// Ascending split-candidate edges per feature; bin b <-> x <= edges[b].
    pub edges: Vec<Vec<f32>>,
    /// Number of distinct bins actually used per feature (= edges.len()+1).
    pub n_bins: Vec<u16>,
    /// The global bin budget histograms are sized to (power of two helps
    /// the kernels; always >= max(n_bins)).
    pub max_bins: usize,
}

impl BinnedDataset {
    /// Quantile-bin every feature of `ds` into at most `max_bins` bins.
    pub fn from_dataset(ds: &Dataset, max_bins: usize) -> BinnedDataset {
        assert!((2..=256).contains(&max_bins), "max_bins must be in [2, 256]");
        let n = ds.n_rows;
        let mut codes = vec![0u8; n * ds.n_features];
        let mut edges_all = Vec::with_capacity(ds.n_features);
        let mut n_bins = Vec::with_capacity(ds.n_features);
        for f in 0..ds.n_features {
            let col = ds.column(f);
            let edges = quantile_edges(col, max_bins);
            let dst = &mut codes[f * n..(f + 1) * n];
            for (i, &x) in col.iter().enumerate() {
                dst[i] = bin_of(&edges, x);
            }
            n_bins.push((edges.len() + 1) as u16);
            edges_all.push(edges);
        }
        BinnedDataset {
            n_rows: n,
            n_features: ds.n_features,
            codes,
            edges: edges_all,
            n_bins,
            max_bins,
        }
    }

    #[inline]
    pub fn column(&self, f: usize) -> &[u8] {
        &self.codes[f * self.n_rows..(f + 1) * self.n_rows]
    }

    /// Raw-value threshold for split "left = bins <= b" on feature f.
    pub fn threshold_value(&self, f: usize, b: usize) -> f32 {
        let e = &self.edges[f];
        if e.is_empty() {
            f32::INFINITY // constant feature: degenerate split
        } else {
            e[b.min(e.len() - 1)]
        }
    }
}

/// Compute up to `max_bins - 1` ascending, deduplicated quantile edges.
pub fn quantile_edges(col: &[f32], max_bins: usize) -> Vec<f32> {
    let mut vals: Vec<f32> = col.iter().copied().filter(|x| !x.is_nan()).collect();
    if vals.is_empty() {
        return Vec::new();
    }
    vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = vals.len();
    let n_edges = max_bins - 1;
    let mut edges = Vec::with_capacity(n_edges);
    for q in 1..=n_edges {
        // midpoint-free plain quantile on the sorted sample
        let pos = (q as f64 / max_bins as f64 * n as f64) as usize;
        let e = vals[pos.min(n - 1)];
        if edges.last().map(|&last| e > last).unwrap_or(true) {
            edges.push(e);
        }
    }
    // A trailing edge equal to the max puts all rows <= it: harmless but
    // wasteful; drop it so the last bin is non-empty.
    if edges.last() == vals.last() && !edges.is_empty() {
        edges.pop();
    }
    edges
}

/// bin(x) = #{j : x > e_j}; NaN -> 0.
#[inline]
pub fn bin_of(edges: &[f32], x: f32) -> u8 {
    if x.is_nan() {
        return 0;
    }
    // binary search for the first edge >= x
    let mut lo = 0usize;
    let mut hi = edges.len();
    while lo < hi {
        let mid = (lo + hi) / 2;
        if x > edges[mid] {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    lo as u8
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Targets;
    use crate::util::proptest::run_prop;

    fn ds_from_col(col: Vec<f32>) -> Dataset {
        let n = col.len();
        Dataset::new(
            n,
            1,
            col,
            Targets::Regression { values: vec![0.0; n], n_targets: 1 },
        )
    }

    #[test]
    fn bin_of_basics() {
        let edges = vec![1.0, 2.0, 3.0];
        assert_eq!(bin_of(&edges, 0.5), 0);
        assert_eq!(bin_of(&edges, 1.0), 0); // x <= e_0
        assert_eq!(bin_of(&edges, 1.5), 1);
        assert_eq!(bin_of(&edges, 3.0), 2);
        assert_eq!(bin_of(&edges, 9.0), 3);
        assert_eq!(bin_of(&edges, f32::NAN), 0);
    }

    #[test]
    fn constant_feature_one_bin() {
        let b = BinnedDataset::from_dataset(&ds_from_col(vec![5.0; 10]), 16);
        assert_eq!(b.n_bins[0], 1);
        assert!(b.column(0).iter().all(|&c| c == 0));
    }

    #[test]
    fn uniform_feature_fills_bins() {
        let col: Vec<f32> = (0..1000).map(|i| i as f32).collect();
        let b = BinnedDataset::from_dataset(&ds_from_col(col), 16);
        assert!(b.n_bins[0] >= 15, "n_bins={}", b.n_bins[0]);
        // roughly balanced occupancy
        let mut counts = [0usize; 16];
        for &c in b.column(0) {
            counts[c as usize] += 1;
        }
        let used = counts.iter().filter(|&&c| c > 0).count();
        assert!(used >= 15);
        assert!(counts.iter().filter(|&&c| c > 0).all(|&c| c >= 40));
    }

    #[test]
    fn binning_is_monotone() {
        run_prop("binning monotone", 30, |g| {
            let n = g.usize_in(10, 300);
            let col = g.vec_gaussian(n, 3.0);
            let bins = *g.choose(&[2usize, 8, 64, 256]);
            let b = BinnedDataset::from_dataset(&ds_from_col(col.clone()), bins);
            let codes = b.column(0);
            for i in 0..n {
                for j in 0..n {
                    if col[i] < col[j] {
                        assert!(
                            codes[i] <= codes[j],
                            "monotonicity violated: x {} < {} but bin {} > {}",
                            col[i], col[j], codes[i], codes[j]
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn split_predicate_matches_bins() {
        // For every feature edge b: (bin <= b) == (x <= threshold_value(b))
        run_prop("bin/threshold equivalence", 20, |g| {
            let n = g.usize_in(20, 200);
            let col = g.vec_gaussian(n, 2.0);
            let b = BinnedDataset::from_dataset(&ds_from_col(col.clone()), 16);
            let codes = b.column(0);
            for bin in 0..b.edges[0].len() {
                let t = b.threshold_value(0, bin);
                for i in 0..n {
                    assert_eq!(
                        codes[i] as usize <= bin,
                        col[i] <= t,
                        "x={} bin={} b={} t={}",
                        col[i], codes[i], bin, t
                    );
                }
            }
        });
    }

    #[test]
    fn nan_goes_to_bin_zero() {
        let mut col: Vec<f32> = (0..100).map(|i| i as f32).collect();
        col[7] = f32::NAN;
        let b = BinnedDataset::from_dataset(&ds_from_col(col), 8);
        assert_eq!(b.column(0)[7], 0);
    }

    #[test]
    fn duplicate_heavy_feature_dedupes_edges() {
        let mut col = vec![0.0f32; 900];
        col.extend(vec![1.0f32; 100]);
        let b = BinnedDataset::from_dataset(&ds_from_col(col), 64);
        assert!(b.n_bins[0] <= 2, "n_bins={}", b.n_bins[0]);
    }

    #[test]
    #[should_panic]
    fn max_bins_over_256_rejected() {
        BinnedDataset::from_dataset(&ds_from_col(vec![1.0, 2.0]), 300);
    }
}
