//! Quantization: feature columns -> u8 bin codes (histogram algorithm,
//! max 256 bins — Py-Boost's limit, Appendix B.1), with an explicit
//! missing bin and native categorical codes.
//!
//! ## Bin layout (DESIGN.md "Missing values & categorical splits")
//!
//! **Bin 0 of every feature is the missing bin**: NaN always maps there,
//! whether the feature is numeric or categorical, and split search
//! learns a per-split default direction for it instead of hard-coding
//! "missing is the smallest value".
//!
//! * **Numeric** features quantile-bin into *value bins* `1..=E+1` for
//!   `E` ascending deduplicated edges: `bin(x) = 1 + #{j : x > e_j}`.
//!   A split "left = value bins <= b" (b >= 1) is exactly the raw-value
//!   predicate `x <= e_{b-1}`, which is what the tree stores as its
//!   float threshold for inference on unbinned data.
//! * **Categorical** features hold integer category ids; `bin(id) =
//!   id + 1` — codes are category ids shifted past the missing bin, no
//!   quantile edges. Split search partitions *category sets*
//!   (LightGBM-style sorted one-vs-rest prefixes), never thresholds.
//!
//! Because one bin is reserved for missing, a `max_bins` budget leaves
//! `max_bins - 1` value bins (i.e. at most `max_bins - 2` numeric edges,
//! and category ids `0..max_bins-1`).

use crate::data::dataset::{Dataset, FeatureKind};

/// The reserved per-feature missing bin (NaN maps here for every
/// feature kind; split search routes it by a learned default).
pub const MISSING_BIN: u8 = 0;

/// Per-feature quantization of a dataset.
#[derive(Clone, Debug)]
pub struct BinnedDataset {
    pub n_rows: usize,
    pub n_features: usize,
    /// Column-major bin codes: codes[f * n_rows + i]. Code 0 = missing.
    pub codes: Vec<u8>,
    /// Ascending split-candidate edges per numeric feature; value bin b
    /// (>= 1) <-> x <= edges[b - 1]. Empty for categorical features.
    pub edges: Vec<Vec<f32>>,
    /// Number of distinct bins actually used per feature, *including*
    /// the missing bin (numeric: edges.len() + 2; categorical:
    /// max category id + 2).
    pub n_bins: Vec<u16>,
    /// The global bin budget histograms are sized to (power of two helps
    /// the kernels; always >= max(n_bins)).
    pub max_bins: usize,
    /// Per-feature interpretation, copied from the dataset.
    pub kinds: Vec<FeatureKind>,
}

impl BinnedDataset {
    /// Bin every feature of `ds` into at most `max_bins` bins (one of
    /// which is the reserved missing bin). Numeric columns quantile-bin;
    /// columns marked [`FeatureKind::Categorical`] on the dataset take
    /// the category-id code path.
    pub fn from_dataset(ds: &Dataset, max_bins: usize) -> BinnedDataset {
        BinnedDataset::from_dataset_with_kinds(ds, max_bins, &ds.kinds)
    }

    /// [`BinnedDataset::from_dataset`] with an explicit per-feature kind
    /// override (the trainer merges `GBDTConfig::categorical_features`
    /// into the dataset's own marks this way).
    pub fn from_dataset_with_kinds(
        ds: &Dataset,
        max_bins: usize,
        kinds: &[FeatureKind],
    ) -> BinnedDataset {
        assert!((2..=256).contains(&max_bins), "max_bins must be in [2, 256]");
        assert_eq!(kinds.len(), ds.n_features, "kinds per feature");
        let n = ds.n_rows;
        let mut codes = vec![0u8; n * ds.n_features];
        let mut edges_all = Vec::with_capacity(ds.n_features);
        let mut n_bins = Vec::with_capacity(ds.n_features);
        for f in 0..ds.n_features {
            let col = ds.column(f);
            let dst = &mut codes[f * n..(f + 1) * n];
            match kinds[f] {
                FeatureKind::Numeric => {
                    // one bin is reserved for missing: budget E <= max_bins - 2 edges
                    let edges = quantile_edges(col, max_bins - 1);
                    for (i, &x) in col.iter().enumerate() {
                        dst[i] = bin_of(&edges, x);
                    }
                    n_bins.push((edges.len() + 2) as u16);
                    edges_all.push(edges);
                }
                FeatureKind::Categorical => {
                    let mut max_code = 0u8;
                    for (i, &x) in col.iter().enumerate() {
                        let code = cat_bin_of(x, max_bins, f);
                        dst[i] = code;
                        max_code = max_code.max(code);
                    }
                    n_bins.push(max_code as u16 + 1);
                    edges_all.push(Vec::new());
                }
            }
        }
        BinnedDataset {
            n_rows: n,
            n_features: ds.n_features,
            codes,
            edges: edges_all,
            n_bins,
            max_bins,
            kinds: kinds.to_vec(),
        }
    }

    #[inline]
    pub fn column(&self, f: usize) -> &[u8] {
        &self.codes[f * self.n_rows..(f + 1) * self.n_rows]
    }

    /// Raw-value threshold for the numeric split "left = value bins <= b"
    /// (b >= 1): `x <= edges[b - 1]`.
    pub fn threshold_value(&self, f: usize, b: usize) -> f32 {
        debug_assert_eq!(self.kinds[f], FeatureKind::Numeric);
        let e = &self.edges[f];
        if e.is_empty() {
            f32::INFINITY // constant feature: degenerate split
        } else {
            e[b.saturating_sub(1).min(e.len() - 1)]
        }
    }
}

/// Compute up to `budget - 1` ascending, deduplicated quantile edges
/// (`budget` = number of value bins available to this feature).
pub fn quantile_edges(col: &[f32], budget: usize) -> Vec<f32> {
    let mut vals: Vec<f32> = col.iter().copied().filter(|x| !x.is_nan()).collect();
    if vals.is_empty() {
        return Vec::new();
    }
    vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = vals.len();
    let n_edges = budget - 1;
    let mut edges = Vec::with_capacity(n_edges);
    for q in 1..=n_edges {
        // midpoint-free plain quantile on the sorted sample
        let pos = (q as f64 / budget as f64 * n as f64) as usize;
        let e = vals[pos.min(n - 1)];
        if edges.last().map(|&last| e > last).unwrap_or(true) {
            edges.push(e);
        }
    }
    // A trailing edge equal to the max puts all rows <= it: harmless but
    // wasteful; drop it so the last bin is non-empty.
    if edges.last() == vals.last() && !edges.is_empty() {
        edges.pop();
    }
    edges
}

/// Numeric code: `bin(x) = 1 + #{j : x > e_j}`; NaN -> [`MISSING_BIN`].
#[inline]
pub fn bin_of(edges: &[f32], x: f32) -> u8 {
    if x.is_nan() {
        return MISSING_BIN;
    }
    // binary search for the first edge >= x
    let mut lo = 0usize;
    let mut hi = edges.len();
    while lo < hi {
        let mid = (lo + hi) / 2;
        if x > edges[mid] {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    1 + lo as u8
}

/// Categorical code: `id + 1`; NaN -> [`MISSING_BIN`]. Panics on values
/// that are not integer category ids in `[0, max_bins - 2]` — with
/// distinct messages for malformed values vs. ids past the bin budget
/// (the latter is fixed by raising `max_bins`).
#[inline]
pub fn cat_bin_of(x: f32, max_bins: usize, f: usize) -> u8 {
    if x.is_nan() {
        return MISSING_BIN;
    }
    let id = x as i64;
    assert!(
        id >= 0 && id as f32 == x,
        "categorical feature {f}: value {x} is not an integer category id"
    );
    assert!(
        (id as usize) < max_bins - 1,
        "categorical feature {f}: category id {id} exceeds the bin budget \
         ([0, {}] with max_bins = {max_bins}); raise max_bins (`--bins`)",
        max_bins - 2
    );
    id as u8 + 1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Targets;
    use crate::util::proptest::run_prop;

    fn ds_from_col(col: Vec<f32>) -> Dataset {
        let n = col.len();
        Dataset::new(
            n,
            1,
            col,
            Targets::Regression { values: vec![0.0; n], n_targets: 1 },
        )
    }

    fn cat_ds_from_col(col: Vec<f32>) -> Dataset {
        let mut ds = ds_from_col(col);
        ds.mark_categorical(&[0]);
        ds
    }

    #[test]
    fn bin_of_basics() {
        let edges = vec![1.0, 2.0, 3.0];
        assert_eq!(bin_of(&edges, 0.5), 1);
        assert_eq!(bin_of(&edges, 1.0), 1); // x <= e_0
        assert_eq!(bin_of(&edges, 1.5), 2);
        assert_eq!(bin_of(&edges, 3.0), 3);
        assert_eq!(bin_of(&edges, 9.0), 4);
        assert_eq!(bin_of(&edges, f32::NAN), MISSING_BIN);
    }

    #[test]
    fn constant_feature_one_value_bin() {
        let b = BinnedDataset::from_dataset(&ds_from_col(vec![5.0; 10]), 16);
        assert_eq!(b.n_bins[0], 2); // missing bin + one value bin
        assert!(b.column(0).iter().all(|&c| c == 1));
    }

    #[test]
    fn uniform_feature_fills_bins() {
        let col: Vec<f32> = (0..1000).map(|i| i as f32).collect();
        let b = BinnedDataset::from_dataset(&ds_from_col(col), 16);
        assert!(b.n_bins[0] >= 15, "n_bins={}", b.n_bins[0]);
        // roughly balanced occupancy over the value bins; missing bin empty
        let mut counts = [0usize; 16];
        for &c in b.column(0) {
            counts[c as usize] += 1;
        }
        assert_eq!(counts[MISSING_BIN as usize], 0);
        let used = counts.iter().filter(|&&c| c > 0).count();
        assert!(used >= 14);
        assert!(counts.iter().filter(|&&c| c > 0).all(|&c| c >= 40));
    }

    #[test]
    fn binning_is_monotone() {
        run_prop("binning monotone", 30, |g| {
            let n = g.usize_in(10, 300);
            let col = g.vec_gaussian(n, 3.0);
            let bins = *g.choose(&[2usize, 8, 64, 256]);
            let b = BinnedDataset::from_dataset(&ds_from_col(col.clone()), bins);
            let codes = b.column(0);
            for i in 0..n {
                for j in 0..n {
                    if col[i] < col[j] {
                        assert!(
                            codes[i] <= codes[j],
                            "monotonicity violated: x {} < {} but bin {} > {}",
                            col[i], col[j], codes[i], codes[j]
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn split_predicate_matches_bins() {
        // For every candidate b >= 1: (bin <= b) == (x <= threshold_value(b))
        run_prop("bin/threshold equivalence", 20, |g| {
            let n = g.usize_in(20, 200);
            let col = g.vec_gaussian(n, 2.0);
            let b = BinnedDataset::from_dataset(&ds_from_col(col.clone()), 16);
            let codes = b.column(0);
            for bin in 1..=b.edges[0].len() {
                let t = b.threshold_value(0, bin);
                for i in 0..n {
                    assert_eq!(
                        codes[i] as usize <= bin,
                        col[i] <= t,
                        "x={} bin={} b={} t={}",
                        col[i], codes[i], bin, t
                    );
                }
            }
        });
    }

    #[test]
    fn nan_goes_to_missing_bin_zero() {
        let mut col: Vec<f32> = (0..100).map(|i| i as f32).collect();
        col[7] = f32::NAN;
        let b = BinnedDataset::from_dataset(&ds_from_col(col), 8);
        assert_eq!(b.column(0)[7], MISSING_BIN);
        assert!(b.column(0).iter().enumerate().all(|(i, &c)| i == 7 || c >= 1));
    }

    #[test]
    fn categorical_codes_are_shifted_ids() {
        let b = BinnedDataset::from_dataset(
            &cat_ds_from_col(vec![0.0, 3.0, 1.0, f32::NAN, 3.0]),
            16,
        );
        assert_eq!(b.kinds[0], FeatureKind::Categorical);
        assert_eq!(b.column(0), &[1, 4, 2, MISSING_BIN, 4]);
        assert_eq!(b.n_bins[0], 5); // ids 0..=3 -> codes 1..=4, plus missing
        assert!(b.edges[0].is_empty());
    }

    #[test]
    #[should_panic(expected = "integer category id")]
    fn categorical_rejects_non_integer() {
        BinnedDataset::from_dataset(&cat_ds_from_col(vec![0.0, 1.5]), 16);
    }

    #[test]
    #[should_panic(expected = "exceeds the bin budget")]
    fn categorical_rejects_out_of_budget_ids() {
        // max_bins = 8 leaves ids 0..=6
        BinnedDataset::from_dataset(&cat_ds_from_col(vec![7.0]), 8);
    }

    #[test]
    fn duplicate_heavy_feature_dedupes_edges() {
        let mut col = vec![0.0f32; 900];
        col.extend(vec![1.0f32; 100]);
        let b = BinnedDataset::from_dataset(&ds_from_col(col), 64);
        assert!(b.n_bins[0] <= 3, "n_bins={}", b.n_bins[0]);
    }

    #[test]
    #[should_panic]
    fn max_bins_over_256_rejected() {
        BinnedDataset::from_dataset(&ds_from_col(vec![1.0, 2.0]), 300);
    }
}
