//! Quantization: feature columns -> u8 bin codes (histogram algorithm,
//! max 256 bins — Py-Boost's limit, Appendix B.1), with an explicit
//! missing bin and native categorical codes.
//!
//! ## Bin layout (DESIGN.md "Missing values & categorical splits")
//!
//! **Bin 0 of every feature is the missing bin**: NaN always maps there,
//! whether the feature is numeric or categorical, and split search
//! learns a per-split default direction for it instead of hard-coding
//! "missing is the smallest value".
//!
//! * **Numeric** features quantile-bin into *value bins* `1..=E+1` for
//!   `E` ascending deduplicated edges: `bin(x) = 1 + #{j : x > e_j}`.
//!   A split "left = value bins <= b" (b >= 1) is exactly the raw-value
//!   predicate `x <= e_{b-1}`, which is what the tree stores as its
//!   float threshold for inference on unbinned data.
//! * **Categorical** features hold integer category ids; `bin(id) =
//!   id + 1` — codes are category ids shifted past the missing bin, no
//!   quantile edges. Split search partitions *category sets*
//!   (LightGBM-style sorted one-vs-rest prefixes), never thresholds.
//!
//! Because one bin is reserved for missing, a `max_bins` budget leaves
//! `max_bins - 1` value bins (i.e. at most `max_bins - 2` numeric edges,
//! and category ids `0..max_bins-1`).

use crate::data::dataset::{Dataset, FeatureKind};
use crate::util::rng::Rng;

/// The reserved per-feature missing bin (NaN maps here for every
/// feature kind; split search routes it by a learned default).
pub const MISSING_BIN: u8 = 0;

/// Per-feature quantization of a dataset.
#[derive(Clone, Debug)]
pub struct BinnedDataset {
    pub n_rows: usize,
    pub n_features: usize,
    /// Column-major bin codes: codes[f * n_rows + i]. Code 0 = missing.
    pub codes: Vec<u8>,
    /// Ascending split-candidate edges per numeric feature; value bin b
    /// (>= 1) <-> x <= edges[b - 1]. Empty for categorical features.
    pub edges: Vec<Vec<f32>>,
    /// Number of distinct bins actually used per feature, *including*
    /// the missing bin (numeric: edges.len() + 2; categorical:
    /// max category id + 2).
    pub n_bins: Vec<u16>,
    /// The global bin budget histograms are sized to (power of two helps
    /// the kernels; always >= max(n_bins)).
    pub max_bins: usize,
    /// Per-feature interpretation, copied from the dataset.
    pub kinds: Vec<FeatureKind>,
}

impl BinnedDataset {
    /// Bin every feature of `ds` into at most `max_bins` bins (one of
    /// which is the reserved missing bin). Numeric columns quantile-bin;
    /// columns marked [`FeatureKind::Categorical`] on the dataset take
    /// the category-id code path.
    pub fn from_dataset(ds: &Dataset, max_bins: usize) -> BinnedDataset {
        BinnedDataset::from_dataset_with_kinds(ds, max_bins, &ds.kinds)
    }

    /// [`BinnedDataset::from_dataset`] with an explicit per-feature kind
    /// override (the trainer merges `GBDTConfig::categorical_features`
    /// into the dataset's own marks this way).
    pub fn from_dataset_with_kinds(
        ds: &Dataset,
        max_bins: usize,
        kinds: &[FeatureKind],
    ) -> BinnedDataset {
        assert!((2..=256).contains(&max_bins), "max_bins must be in [2, 256]");
        assert_eq!(kinds.len(), ds.n_features, "kinds per feature");
        let n = ds.n_rows;
        let mut codes = vec![0u8; n * ds.n_features];
        let mut edges_all = Vec::with_capacity(ds.n_features);
        let mut n_bins = Vec::with_capacity(ds.n_features);
        for f in 0..ds.n_features {
            let col = ds.column(f);
            let dst = &mut codes[f * n..(f + 1) * n];
            match kinds[f] {
                FeatureKind::Numeric => {
                    // one bin is reserved for missing: budget E <= max_bins - 2 edges
                    let edges = quantile_edges(col, max_bins - 1);
                    for (i, &x) in col.iter().enumerate() {
                        dst[i] = bin_of(&edges, x);
                    }
                    n_bins.push((edges.len() + 2) as u16);
                    edges_all.push(edges);
                }
                FeatureKind::Categorical => {
                    let mut max_code = 0u8;
                    for (i, &x) in col.iter().enumerate() {
                        let code = cat_bin_of(x, max_bins, f);
                        dst[i] = code;
                        max_code = max_code.max(code);
                    }
                    n_bins.push(max_code as u16 + 1);
                    edges_all.push(Vec::new());
                }
            }
        }
        BinnedDataset {
            n_rows: n,
            n_features: ds.n_features,
            codes,
            edges: edges_all,
            n_bins,
            max_bins,
            kinds: kinds.to_vec(),
        }
    }

    #[inline]
    pub fn column(&self, f: usize) -> &[u8] {
        &self.codes[f * self.n_rows..(f + 1) * self.n_rows]
    }

    /// Raw-value threshold for the numeric split "left = value bins <= b"
    /// (b >= 1): `x <= edges[b - 1]`.
    pub fn threshold_value(&self, f: usize, b: usize) -> f32 {
        debug_assert_eq!(self.kinds[f], FeatureKind::Numeric);
        let e = &self.edges[f];
        if e.is_empty() {
            f32::INFINITY // constant feature: degenerate split
        } else {
            e[b.saturating_sub(1).min(e.len() - 1)]
        }
    }
}

/// One resident chunk of bin codes, column-major **within the chunk**:
/// feature `f` of global row `r` (with `start <= r < start + len`) is
/// `codes[f * len + (r - start)]`.
pub struct ChunkCols<'a> {
    pub codes: &'a [u8],
    /// First global row this chunk covers.
    pub start: usize,
    /// Rows in this chunk.
    pub len: usize,
}

impl<'a> ChunkCols<'a> {
    /// This chunk's slice of feature `f`'s column.
    #[inline]
    pub fn col(&self, f: usize) -> &'a [u8] {
        &self.codes[f * self.len..(f + 1) * self.len]
    }

    /// Bin code of (global) `row` on feature `f`.
    #[inline]
    pub fn code(&self, f: usize, row: usize) -> u8 {
        self.codes[f * self.len + (row - self.start)]
    }
}

/// The histogram input contract: binned feature codes served as one or
/// more row chunks. [`BinnedDataset`] is the trivial one-chunk in-RAM
/// implementor; `data/chunked.rs::ChunkedBinned` pages chunks in from
/// the on-disk store. The engine and the tree builder consume
/// `&dyn BinnedSource`, so the whole training loop runs unchanged over
/// either.
///
/// ## Determinism contract (DESIGN.md §2d)
///
/// Chunks partition `0..n_rows` into consecutive ascending ranges:
/// `chunk_range(0).start == 0`, `chunk_range(c).end ==
/// chunk_range(c + 1).start`, and `chunk_range(n_chunks - 1).end ==
/// n_rows`. Because the builder keeps every node's rows ascending,
/// iterating chunks in order visits any node's rows in exactly the
/// in-RAM order — which is what makes chunked training bitwise-identical
/// to in-RAM training (`rust/tests/out_of_core.rs`).
pub trait BinnedSource: Sync {
    fn n_rows(&self) -> usize;
    fn n_features(&self) -> usize;
    /// The global bin budget histograms are sized to.
    fn max_bins(&self) -> usize;
    fn kinds(&self) -> &[FeatureKind];
    /// Raw-value threshold for the numeric split "left = value bins <= b".
    fn threshold_value(&self, f: usize, b: usize) -> f32;
    fn n_chunks(&self) -> usize;
    /// Global row range `[start, end)` of chunk `c` (see the trait docs
    /// for the partition invariants).
    fn chunk_range(&self, c: usize) -> std::ops::Range<usize>;
    /// Run `body` with chunk `c` resident. May be called concurrently
    /// from engine worker threads; implementations must tolerate the
    /// same chunk being requested from several threads at once.
    fn with_chunk(&self, c: usize, body: &mut dyn FnMut(ChunkCols<'_>));
    /// The whole matrix, if it is resident anyway — the engines take
    /// this fast path to keep the in-RAM hot loops byte-for-byte intact.
    fn as_in_ram(&self) -> Option<&BinnedDataset> {
        None
    }
}

impl BinnedSource for BinnedDataset {
    fn n_rows(&self) -> usize {
        self.n_rows
    }
    fn n_features(&self) -> usize {
        self.n_features
    }
    fn max_bins(&self) -> usize {
        self.max_bins
    }
    fn kinds(&self) -> &[FeatureKind] {
        &self.kinds
    }
    fn threshold_value(&self, f: usize, b: usize) -> f32 {
        BinnedDataset::threshold_value(self, f, b)
    }
    fn n_chunks(&self) -> usize {
        1
    }
    fn chunk_range(&self, c: usize) -> std::ops::Range<usize> {
        debug_assert_eq!(c, 0);
        0..self.n_rows
    }
    fn with_chunk(&self, c: usize, body: &mut dyn FnMut(ChunkCols<'_>)) {
        debug_assert_eq!(c, 0);
        body(ChunkCols { codes: &self.codes, start: 0, len: self.n_rows });
    }
    fn as_in_ram(&self) -> Option<&BinnedDataset> {
        Some(self)
    }
}

/// A dataset-free description of one binning: everything needed to map
/// raw feature values to codes (and back to split thresholds). This is
/// what the on-disk store header carries.
#[derive(Clone, Debug)]
pub struct BinSpec {
    pub max_bins: usize,
    pub kinds: Vec<FeatureKind>,
    /// Ascending split-candidate edges per numeric feature (empty for
    /// categorical).
    pub edges: Vec<Vec<f32>>,
    /// Bins actually used per feature, including the missing bin.
    pub n_bins: Vec<u16>,
}

impl BinSpec {
    pub fn of(b: &BinnedDataset) -> BinSpec {
        BinSpec {
            max_bins: b.max_bins,
            kinds: b.kinds.clone(),
            edges: b.edges.clone(),
            n_bins: b.n_bins.clone(),
        }
    }

    pub fn n_features(&self) -> usize {
        self.kinds.len()
    }

    /// Bin a raw feature value exactly as [`BinnedDataset`] would.
    #[inline]
    pub fn code_of(&self, f: usize, x: f32) -> u8 {
        match self.kinds[f] {
            FeatureKind::Numeric => bin_of(&self.edges[f], x),
            FeatureKind::Categorical => cat_bin_of(x, self.max_bins, f),
        }
    }
}

/// One-pass streaming edge construction: per-feature deterministic
/// reservoir samples stand in for the full column, so quantile edges
/// for an out-of-core source come from a single pass over the rows
/// without materializing the feature matrix (XGBoost's out-of-core
/// sketch plays the same role; see PAPERS.md).
///
/// Deterministic: one seeded [`Rng`] drives every replacement decision,
/// so the same row stream always yields the same edges. When a feature
/// has at most `capacity` non-missing values the reservoir *is* the
/// column and the edges equal the in-RAM [`quantile_edges`] exactly;
/// beyond that they are a sampled approximation (the trade the
/// streaming path buys its O(m * capacity) memory bound with).
pub struct StreamingQuantiles {
    max_bins: usize,
    kinds: Vec<FeatureKind>,
    capacity: usize,
    rng: Rng,
    /// Per-feature reservoir of non-NaN values.
    reservoirs: Vec<Vec<f32>>,
    /// Non-NaN values seen per feature (drives replacement odds).
    seen: Vec<u64>,
    /// Per-categorical-feature max code (0 until a value shows up).
    max_code: Vec<u8>,
    n_rows: usize,
}

/// Default per-feature reservoir size (64 KiB of f32 per feature).
pub const STREAM_RESERVOIR: usize = 16 * 1024;

impl StreamingQuantiles {
    pub fn new(max_bins: usize, kinds: &[FeatureKind], capacity: usize, seed: u64) -> Self {
        assert!((2..=256).contains(&max_bins), "max_bins must be in [2, 256]");
        assert!(capacity > 0, "reservoir capacity must be positive");
        let m = kinds.len();
        StreamingQuantiles {
            max_bins,
            kinds: kinds.to_vec(),
            capacity,
            rng: Rng::new(seed ^ 0x5b1e_55ed),
            reservoirs: vec![Vec::new(); m],
            seen: vec![0; m],
            max_code: vec![0; m],
            n_rows: 0,
        }
    }

    /// Feed one raw feature row (length `m`; NaN = missing).
    pub fn push_row(&mut self, row: &[f32]) {
        assert_eq!(row.len(), self.kinds.len(), "row width");
        for (f, &x) in row.iter().enumerate() {
            if x.is_nan() {
                continue;
            }
            match self.kinds[f] {
                FeatureKind::Numeric => {
                    self.seen[f] += 1;
                    let res = &mut self.reservoirs[f];
                    if res.len() < self.capacity {
                        res.push(x);
                    } else {
                        // Algorithm R: replace slot j < cap with prob cap/seen
                        let j = self.rng.next_below(self.seen[f] as usize);
                        if j < self.capacity {
                            res[j] = x;
                        }
                    }
                }
                FeatureKind::Categorical => {
                    let code = cat_bin_of(x, self.max_bins, f);
                    self.max_code[f] = self.max_code[f].max(code);
                }
            }
        }
        self.n_rows += 1;
    }

    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    /// Close the pass and produce the binning spec.
    pub fn finish(self) -> BinSpec {
        let m = self.kinds.len();
        let mut edges = Vec::with_capacity(m);
        let mut n_bins = Vec::with_capacity(m);
        for f in 0..m {
            match self.kinds[f] {
                FeatureKind::Numeric => {
                    let e = quantile_edges(&self.reservoirs[f], self.max_bins - 1);
                    n_bins.push((e.len() + 2) as u16);
                    edges.push(e);
                }
                FeatureKind::Categorical => {
                    n_bins.push(self.max_code[f] as u16 + 1);
                    edges.push(Vec::new());
                }
            }
        }
        BinSpec { max_bins: self.max_bins, kinds: self.kinds, edges, n_bins }
    }
}

/// Compute up to `budget - 1` ascending, deduplicated quantile edges
/// (`budget` = number of value bins available to this feature).
pub fn quantile_edges(col: &[f32], budget: usize) -> Vec<f32> {
    let mut vals: Vec<f32> = col.iter().copied().filter(|x| !x.is_nan()).collect();
    if vals.is_empty() {
        return Vec::new();
    }
    vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = vals.len();
    let n_edges = budget - 1;
    let mut edges = Vec::with_capacity(n_edges);
    for q in 1..=n_edges {
        // midpoint-free plain quantile on the sorted sample
        let pos = (q as f64 / budget as f64 * n as f64) as usize;
        let e = vals[pos.min(n - 1)];
        if edges.last().map(|&last| e > last).unwrap_or(true) {
            edges.push(e);
        }
    }
    // A trailing edge equal to the max puts all rows <= it: harmless but
    // wasteful; drop it so the last bin is non-empty.
    if edges.last() == vals.last() && !edges.is_empty() {
        edges.pop();
    }
    edges
}

/// Numeric code: `bin(x) = 1 + #{j : x > e_j}`; NaN -> [`MISSING_BIN`].
#[inline]
pub fn bin_of(edges: &[f32], x: f32) -> u8 {
    if x.is_nan() {
        return MISSING_BIN;
    }
    // binary search for the first edge >= x
    let mut lo = 0usize;
    let mut hi = edges.len();
    while lo < hi {
        let mid = (lo + hi) / 2;
        if x > edges[mid] {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    1 + lo as u8
}

/// Categorical code: `id + 1`; NaN -> [`MISSING_BIN`]. Panics on values
/// that are not integer category ids in `[0, max_bins - 2]` — with
/// distinct messages for malformed values vs. ids past the bin budget
/// (the latter is fixed by raising `max_bins`).
#[inline]
pub fn cat_bin_of(x: f32, max_bins: usize, f: usize) -> u8 {
    if x.is_nan() {
        return MISSING_BIN;
    }
    let id = x as i64;
    assert!(
        id >= 0 && id as f32 == x,
        "categorical feature {f}: value {x} is not an integer category id"
    );
    assert!(
        (id as usize) < max_bins - 1,
        "categorical feature {f}: category id {id} exceeds the bin budget \
         ([0, {}] with max_bins = {max_bins}); raise max_bins (`--bins`)",
        max_bins - 2
    );
    id as u8 + 1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Targets;
    use crate::util::proptest::run_prop;

    fn ds_from_col(col: Vec<f32>) -> Dataset {
        let n = col.len();
        Dataset::new(
            n,
            1,
            col,
            Targets::Regression { values: vec![0.0; n], n_targets: 1 },
        )
    }

    fn cat_ds_from_col(col: Vec<f32>) -> Dataset {
        let mut ds = ds_from_col(col);
        ds.mark_categorical(&[0]);
        ds
    }

    #[test]
    fn bin_of_basics() {
        let edges = vec![1.0, 2.0, 3.0];
        assert_eq!(bin_of(&edges, 0.5), 1);
        assert_eq!(bin_of(&edges, 1.0), 1); // x <= e_0
        assert_eq!(bin_of(&edges, 1.5), 2);
        assert_eq!(bin_of(&edges, 3.0), 3);
        assert_eq!(bin_of(&edges, 9.0), 4);
        assert_eq!(bin_of(&edges, f32::NAN), MISSING_BIN);
    }

    #[test]
    fn constant_feature_one_value_bin() {
        let b = BinnedDataset::from_dataset(&ds_from_col(vec![5.0; 10]), 16);
        assert_eq!(b.n_bins[0], 2); // missing bin + one value bin
        assert!(b.column(0).iter().all(|&c| c == 1));
    }

    #[test]
    fn uniform_feature_fills_bins() {
        let col: Vec<f32> = (0..1000).map(|i| i as f32).collect();
        let b = BinnedDataset::from_dataset(&ds_from_col(col), 16);
        assert!(b.n_bins[0] >= 15, "n_bins={}", b.n_bins[0]);
        // roughly balanced occupancy over the value bins; missing bin empty
        let mut counts = [0usize; 16];
        for &c in b.column(0) {
            counts[c as usize] += 1;
        }
        assert_eq!(counts[MISSING_BIN as usize], 0);
        let used = counts.iter().filter(|&&c| c > 0).count();
        assert!(used >= 14);
        assert!(counts.iter().filter(|&&c| c > 0).all(|&c| c >= 40));
    }

    #[test]
    fn binning_is_monotone() {
        run_prop("binning monotone", 30, |g| {
            let n = g.usize_in(10, 300);
            let col = g.vec_gaussian(n, 3.0);
            let bins = *g.choose(&[2usize, 8, 64, 256]);
            let b = BinnedDataset::from_dataset(&ds_from_col(col.clone()), bins);
            let codes = b.column(0);
            for i in 0..n {
                for j in 0..n {
                    if col[i] < col[j] {
                        assert!(
                            codes[i] <= codes[j],
                            "monotonicity violated: x {} < {} but bin {} > {}",
                            col[i], col[j], codes[i], codes[j]
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn split_predicate_matches_bins() {
        // For every candidate b >= 1: (bin <= b) == (x <= threshold_value(b))
        run_prop("bin/threshold equivalence", 20, |g| {
            let n = g.usize_in(20, 200);
            let col = g.vec_gaussian(n, 2.0);
            let b = BinnedDataset::from_dataset(&ds_from_col(col.clone()), 16);
            let codes = b.column(0);
            for bin in 1..=b.edges[0].len() {
                let t = b.threshold_value(0, bin);
                for i in 0..n {
                    assert_eq!(
                        codes[i] as usize <= bin,
                        col[i] <= t,
                        "x={} bin={} b={} t={}",
                        col[i], codes[i], bin, t
                    );
                }
            }
        });
    }

    #[test]
    fn nan_goes_to_missing_bin_zero() {
        let mut col: Vec<f32> = (0..100).map(|i| i as f32).collect();
        col[7] = f32::NAN;
        let b = BinnedDataset::from_dataset(&ds_from_col(col), 8);
        assert_eq!(b.column(0)[7], MISSING_BIN);
        assert!(b.column(0).iter().enumerate().all(|(i, &c)| i == 7 || c >= 1));
    }

    #[test]
    fn categorical_codes_are_shifted_ids() {
        let b = BinnedDataset::from_dataset(
            &cat_ds_from_col(vec![0.0, 3.0, 1.0, f32::NAN, 3.0]),
            16,
        );
        assert_eq!(b.kinds[0], FeatureKind::Categorical);
        assert_eq!(b.column(0), &[1, 4, 2, MISSING_BIN, 4]);
        assert_eq!(b.n_bins[0], 5); // ids 0..=3 -> codes 1..=4, plus missing
        assert!(b.edges[0].is_empty());
    }

    #[test]
    #[should_panic(expected = "integer category id")]
    fn categorical_rejects_non_integer() {
        BinnedDataset::from_dataset(&cat_ds_from_col(vec![0.0, 1.5]), 16);
    }

    #[test]
    #[should_panic(expected = "exceeds the bin budget")]
    fn categorical_rejects_out_of_budget_ids() {
        // max_bins = 8 leaves ids 0..=6
        BinnedDataset::from_dataset(&cat_ds_from_col(vec![7.0]), 8);
    }

    #[test]
    fn duplicate_heavy_feature_dedupes_edges() {
        let mut col = vec![0.0f32; 900];
        col.extend(vec![1.0f32; 100]);
        let b = BinnedDataset::from_dataset(&ds_from_col(col), 64);
        assert!(b.n_bins[0] <= 3, "n_bins={}", b.n_bins[0]);
    }

    #[test]
    #[should_panic]
    fn max_bins_over_256_rejected() {
        BinnedDataset::from_dataset(&ds_from_col(vec![1.0, 2.0]), 300);
    }

    #[test]
    fn binned_dataset_is_the_one_chunk_source() {
        let col: Vec<f32> = (0..50).map(|i| (i % 7) as f32).collect();
        let b = BinnedDataset::from_dataset(&ds_from_col(col), 8);
        let src: &dyn BinnedSource = &b;
        assert_eq!(src.n_rows(), 50);
        assert_eq!(src.n_features(), 1);
        assert_eq!(src.n_chunks(), 1);
        assert_eq!(src.chunk_range(0), 0..50);
        assert!(src.as_in_ram().is_some());
        let mut seen = Vec::new();
        src.with_chunk(0, &mut |cols| {
            assert_eq!(cols.start, 0);
            assert_eq!(cols.len, 50);
            assert_eq!(cols.col(0), b.column(0));
            seen.extend((0..50).map(|r| cols.code(0, r)));
        });
        assert_eq!(&seen[..], b.column(0));
    }

    #[test]
    fn spec_code_of_matches_from_dataset() {
        let mut col: Vec<f32> = (0..200).map(|i| ((i * 37) % 91) as f32 * 0.25).collect();
        col[13] = f32::NAN;
        let ds = ds_from_col(col.clone());
        let b = BinnedDataset::from_dataset(&ds, 16);
        let spec = BinSpec::of(&b);
        for (i, &x) in col.iter().enumerate() {
            assert_eq!(spec.code_of(0, x), b.column(0)[i], "row {i}");
        }
    }

    #[test]
    fn streaming_edges_exact_when_column_fits_reservoir() {
        // non-NaN count <= capacity: the reservoir IS the column, so the
        // streaming edges must equal the in-RAM quantile edges bit-for-bit
        let mut col: Vec<f32> = (0..500).map(|i| ((i * 17) % 163) as f32).collect();
        col[3] = f32::NAN;
        col[77] = f32::NAN;
        let ds = ds_from_col(col.clone());
        let b = BinnedDataset::from_dataset(&ds, 16);
        let mut sq = StreamingQuantiles::new(16, &[FeatureKind::Numeric], 1024, 42);
        for &x in &col {
            sq.push_row(&[x]);
        }
        assert_eq!(sq.n_rows(), 500);
        let spec = sq.finish();
        assert_eq!(spec.edges[0].len(), b.edges[0].len());
        for (a, e) in spec.edges[0].iter().zip(b.edges[0].iter()) {
            assert_eq!(a.to_bits(), e.to_bits());
        }
        assert_eq!(spec.n_bins, b.n_bins);
    }

    #[test]
    fn streaming_is_deterministic_and_bounded() {
        let kinds = [FeatureKind::Numeric, FeatureKind::Categorical];
        let run = || {
            let mut sq = StreamingQuantiles::new(32, &kinds, 64, 7);
            for i in 0..5000usize {
                let x = ((i * 29) % 1009) as f32 * 0.5;
                let c = (i % 9) as f32;
                sq.push_row(&[x, c]);
            }
            sq.finish()
        };
        let (a, b) = (run(), run());
        assert_eq!(a.edges[0], b.edges[0], "same stream + seed => same edges");
        assert!(a.edges[0].len() <= 31);
        assert_eq!(a.n_bins[1], 10, "cat ids 0..=8 -> codes 1..=9, plus missing");
        assert!(a.edges[1].is_empty());
    }
}
