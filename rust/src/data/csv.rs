//! Numeric CSV I/O for the CLI (`sketchboost train --data file.csv`).
//!
//! Format: optional header row; all cells numeric (NaN/empty allowed for
//! features). Target columns are named on load: the last `d` columns for
//! multilabel/regression, or a single integer class column for
//! multiclass. This is deliberately minimal — the paper pipeline feeds
//! everything through the synthetic generators; CSV exists so real data
//! can be dropped in.

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use crate::data::dataset::{Dataset, Targets};

#[derive(Debug)]
pub struct CsvError(pub String);

impl std::fmt::Display for CsvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "csv error: {}", self.0)
    }
}

impl std::error::Error for CsvError {}

fn parse_cell(s: &str) -> Result<f32, CsvError> {
    let t = s.trim();
    if t.is_empty() || t.eq_ignore_ascii_case("nan") {
        return Ok(f32::NAN);
    }
    t.parse::<f32>()
        .map_err(|_| CsvError(format!("bad numeric cell {t:?}")))
}

/// Raw numeric table (row-major) as read from disk.
pub struct Table {
    pub n_rows: usize,
    pub n_cols: usize,
    pub cells: Vec<f32>,
    pub header: Option<Vec<String>>,
}

pub fn read_table(path: &Path) -> Result<Table, Box<dyn std::error::Error>> {
    let f = std::fs::File::open(path)?;
    let reader = std::io::BufReader::new(f);
    let mut cells: Vec<f32> = Vec::new();
    let mut n_cols = 0usize;
    let mut n_rows = 0usize;
    let mut header: Option<Vec<String>> = None;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let fields: Vec<&str> = line.split(',').collect();
        if lineno == 0 {
            // header if any field fails to parse as a number
            let numeric = fields.iter().all(|f| parse_cell(f).is_ok());
            if !numeric {
                header = Some(fields.iter().map(|s| s.trim().to_string()).collect());
                n_cols = fields.len();
                continue;
            }
        }
        if n_cols == 0 {
            n_cols = fields.len();
        } else if fields.len() != n_cols {
            return Err(Box::new(CsvError(format!(
                "row {lineno}: expected {n_cols} fields, got {}",
                fields.len()
            ))));
        }
        for f in &fields {
            cells.push(parse_cell(f)?);
        }
        n_rows += 1;
    }
    Ok(Table { n_rows, n_cols, cells, header })
}

/// Stream a numeric CSV row by row without materializing the table —
/// the out-of-core `sketchboost bin --stream` path reads the file twice
/// through this (pass 1: streaming quantiles; pass 2: chunk payloads),
/// so peak memory stays one row. Header detection, NaN/empty cells, and
/// ragged-row errors match [`read_table`] exactly. Returns the number
/// of data rows; `body` sees each parsed row in file order.
pub fn stream_rows(
    path: &Path,
    body: &mut dyn FnMut(&[f32]) -> Result<(), Box<dyn std::error::Error>>,
) -> Result<usize, Box<dyn std::error::Error>> {
    let f = std::fs::File::open(path)?;
    let reader = std::io::BufReader::new(f);
    let mut n_cols = 0usize;
    let mut n_rows = 0usize;
    let mut row: Vec<f32> = Vec::new();
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let fields: Vec<&str> = line.split(',').collect();
        if lineno == 0 && !fields.iter().all(|f| parse_cell(f).is_ok()) {
            n_cols = fields.len(); // header row
            continue;
        }
        if n_cols == 0 {
            n_cols = fields.len();
        } else if fields.len() != n_cols {
            return Err(Box::new(CsvError(format!(
                "row {lineno}: expected {n_cols} fields, got {}",
                fields.len()
            ))));
        }
        row.clear();
        for f in &fields {
            row.push(parse_cell(f)?);
        }
        body(&row)?;
        n_rows += 1;
    }
    Ok(n_rows)
}

/// Load a dataset whose last `n_targets` columns are the targets.
pub fn load_dataset(
    path: &Path,
    task: &str,
    n_targets: usize,
) -> Result<Dataset, Box<dyn std::error::Error>> {
    let t = read_table(path)?;
    let tgt_cols = if task == "multiclass" { 1 } else { n_targets };
    if t.n_cols <= tgt_cols {
        return Err(Box::new(CsvError("no feature columns left".into())));
    }
    let m = t.n_cols - tgt_cols;
    let mut rows = vec![0.0f32; t.n_rows * m];
    for i in 0..t.n_rows {
        rows[i * m..(i + 1) * m].copy_from_slice(&t.cells[i * t.n_cols..i * t.n_cols + m]);
    }
    let targets = match task {
        "multiclass" => {
            let labels: Vec<u32> = (0..t.n_rows)
                .map(|i| t.cells[i * t.n_cols + m] as u32)
                .collect();
            let n_classes = n_targets.max(labels.iter().copied().max().unwrap_or(0) as usize + 1);
            Targets::Multiclass { labels, n_classes }
        }
        "multilabel" => {
            let mut labels = vec![0.0f32; t.n_rows * n_targets];
            for i in 0..t.n_rows {
                for j in 0..n_targets {
                    labels[i * n_targets + j] = t.cells[i * t.n_cols + m + j];
                }
            }
            Targets::Multilabel { labels, n_labels: n_targets }
        }
        "regression" | "multitask" => {
            let mut values = vec![0.0f32; t.n_rows * n_targets];
            for i in 0..t.n_rows {
                for j in 0..n_targets {
                    values[i * n_targets + j] = t.cells[i * t.n_cols + m + j];
                }
            }
            Targets::Regression { values, n_targets }
        }
        other => return Err(Box::new(CsvError(format!("unknown task {other:?}")))),
    };
    Ok(Dataset::from_row_major(t.n_rows, m, &rows, targets))
}

/// [`load_dataset`] plus a categorical-column spec: the listed feature
/// column indices are marked [`crate::data::FeatureKind::Categorical`]
/// (cells must then be integer category ids, or NaN/empty for missing).
/// Prediction on a saved model does not need the spec — the model's
/// splits carry their category sets.
pub fn load_dataset_spec(
    path: &Path,
    task: &str,
    n_targets: usize,
    categorical: &[usize],
) -> Result<Dataset, Box<dyn std::error::Error>> {
    let mut ds = load_dataset(path, task, n_targets)?;
    for &f in categorical {
        if f >= ds.n_features {
            return Err(Box::new(CsvError(format!(
                "categorical column {f} out of range ({} feature columns)",
                ds.n_features
            ))));
        }
        // Reject malformed category cells (non-integer / negative /
        // unrepresentable) here, as a load error. Whether the ids also
        // fit the *training* bin budget depends on `max_bins`, which is
        // chosen later — ids past the budget are reported by binning
        // with a message naming the budget (`data/binning.rs::cat_bin_of`).
        for (i, &x) in ds.column(f).iter().enumerate() {
            let id = x as i64;
            if !x.is_nan() && (id < 0 || id > 255 || id as f32 != x) {
                return Err(Box::new(CsvError(format!(
                    "categorical column {f}, row {i}: {x} is not an integer \
                     category id in [0, 255] (or NaN/empty for missing)"
                ))));
            }
        }
    }
    ds.mark_categorical(categorical);
    Ok(ds)
}

/// Load a feature-only CSV (no target columns) for scoring with a saved
/// model (`sketchboost predict`). Every column is a feature; the dataset
/// carries dummy targets (prediction never reads them).
pub fn load_features(path: &Path) -> Result<Dataset, Box<dyn std::error::Error>> {
    let t = read_table(path)?;
    let targets = Targets::Regression { values: vec![0.0; t.n_rows], n_targets: 1 };
    Ok(Dataset::from_row_major(t.n_rows, t.n_cols, &t.cells, targets))
}

/// Write a row-major `[n, d]` prediction matrix to CSV with a
/// `p0..p{d-1}` header (`sketchboost predict --out`).
pub fn write_predictions(path: &Path, preds: &[f32], d: usize) -> std::io::Result<()> {
    assert!(d > 0 && preds.len() % d == 0, "predictions must be [n, {d}]");
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    for j in 0..d {
        write!(w, "p{j}{}", if j + 1 == d { "\n" } else { "," })?;
    }
    for row in preds.chunks(d) {
        for (j, v) in row.iter().enumerate() {
            write!(w, "{}{}", v, if j + 1 == d { "\n" } else { "," })?;
        }
    }
    w.flush()
}

/// Write a dataset to CSV (features then targets), for `gen-data`.
pub fn write_dataset(path: &Path, ds: &Dataset) -> std::io::Result<()> {
    let f = std::fs::File::create(path)?;
    let mut w = BufWriter::new(f);
    let d = ds.n_outputs();
    // header
    for j in 0..ds.n_features {
        write!(w, "f{j},")?;
    }
    match &ds.targets {
        Targets::Multiclass { .. } => writeln!(w, "label")?,
        _ => {
            for j in 0..d {
                write!(w, "y{j}{}", if j + 1 == d { "\n" } else { "," })?;
            }
        }
    }
    for i in 0..ds.n_rows {
        for j in 0..ds.n_features {
            write!(w, "{},", ds.value(i, j))?;
        }
        match &ds.targets {
            Targets::Multiclass { labels, .. } => writeln!(w, "{}", labels[i])?,
            Targets::Multilabel { labels, n_labels } => {
                for j in 0..*n_labels {
                    write!(
                        w,
                        "{}{}",
                        labels[i * n_labels + j],
                        if j + 1 == *n_labels { "\n".to_string() } else { ",".to_string() }
                    )?;
                }
            }
            Targets::Regression { values, n_targets } => {
                for j in 0..*n_targets {
                    write!(
                        w,
                        "{}{}",
                        values[i * n_targets + j],
                        if j + 1 == *n_targets { "\n".to_string() } else { ",".to_string() }
                    )?;
                }
            }
        }
    }
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{make_multiclass, FeatureSpec};

    #[test]
    fn roundtrip_multiclass() {
        let ds = make_multiclass(50, FeatureSpec::guyon(5), 3, 1.0, 1);
        let dir = std::env::temp_dir().join("sb_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("mc.csv");
        write_dataset(&path, &ds).unwrap();
        let back = load_dataset(&path, "multiclass", 3).unwrap();
        assert_eq!(back.n_rows, 50);
        assert_eq!(back.n_features, 5);
        assert_eq!(back.n_outputs(), 3);
        for i in 0..50 {
            for f in 0..5 {
                assert!((back.value(i, f) - ds.value(i, f)).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn feature_only_load_and_prediction_write() {
        let dir = std::env::temp_dir().join("sb_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("feat.csv");
        std::fs::write(&path, "a,b\n1.0,2.0\n3.0,nan\n").unwrap();
        let ds = load_features(&path).unwrap();
        assert_eq!((ds.n_rows, ds.n_features), (2, 2));
        assert!(ds.value(1, 1).is_nan());

        let out = dir.join("preds.csv");
        write_predictions(&out, &[0.5, 0.5, 0.25, 0.75], 2).unwrap();
        let text = std::fs::read_to_string(&out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines[0], "p0,p1");
        assert_eq!(lines.len(), 3);
        assert_eq!(lines[2], "0.25,0.75");
    }

    #[test]
    fn categorical_spec_marks_columns() {
        use crate::data::dataset::FeatureKind;
        let dir = std::env::temp_dir().join("sb_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cat.csv");
        std::fs::write(&path, "c,x,y\n2,0.5,1.0\n,1.5,2.0\n0,2.5,3.0\n").unwrap();
        let ds = load_dataset_spec(&path, "regression", 1, &[0]).unwrap();
        assert_eq!(ds.kinds[0], FeatureKind::Categorical);
        assert_eq!(ds.kinds[1], FeatureKind::Numeric);
        assert!(ds.value(1, 0).is_nan(), "empty cell is missing");
        assert_eq!(ds.value(0, 0), 2.0);
        // out-of-range spec is a csv error, not a panic
        assert!(load_dataset_spec(&path, "regression", 1, &[5]).is_err());
        // and so is a non-integer cell in a declared categorical column
        let bad = dir.join("badcat.csv");
        std::fs::write(&bad, "c,y\n1.5,0.0\n").unwrap();
        assert!(load_dataset_spec(&bad, "regression", 1, &[0]).is_err());
        // negative ids too
        let neg = dir.join("negcat.csv");
        std::fs::write(&neg, "c,y\n-1,0.0\n").unwrap();
        assert!(load_dataset_spec(&neg, "regression", 1, &[0]).is_err());
    }

    #[test]
    fn parses_nan_and_empty() {
        let dir = std::env::temp_dir().join("sb_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("nan.csv");
        std::fs::write(&path, "a,b,y\n1.0,,0\nnan,2.0,1\n").unwrap();
        let ds = load_dataset(&path, "multiclass", 2).unwrap();
        assert!(ds.value(0, 1).is_nan());
        assert!(ds.value(1, 0).is_nan());
    }

    #[test]
    fn stream_rows_matches_read_table() {
        let ds = make_multiclass(40, FeatureSpec::guyon(4), 3, 1.0, 9);
        let dir = std::env::temp_dir().join("sb_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("stream.csv");
        write_dataset(&path, &ds).unwrap();
        let t = read_table(&path).unwrap();
        let mut streamed: Vec<f32> = Vec::new();
        let n = stream_rows(&path, &mut |row| {
            assert_eq!(row.len(), t.n_cols);
            streamed.extend_from_slice(row);
            Ok(())
        })
        .unwrap();
        assert_eq!(n, t.n_rows);
        assert_eq!(streamed.len(), t.cells.len());
        // bit-for-bit the same parse as the materializing reader
        for (a, b) in streamed.iter().zip(&t.cells) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // ragged rows fail the same way
        let bad = dir.join("stream_bad.csv");
        std::fs::write(&bad, "1,2,3\n1,2\n").unwrap();
        assert!(stream_rows(&bad, &mut |_| Ok(())).is_err());
    }

    #[test]
    fn rejects_ragged() {
        let dir = std::env::temp_dir().join("sb_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.csv");
        std::fs::write(&path, "1,2,3\n1,2\n").unwrap();
        assert!(read_table(&path).is_err());
    }

    #[test]
    fn header_detected() {
        let dir = std::env::temp_dir().join("sb_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("hdr.csv");
        std::fs::write(&path, "x,y\n1,2\n3,4\n").unwrap();
        let t = read_table(&path).unwrap();
        assert_eq!(t.n_rows, 2);
        assert!(t.header.is_some());
    }
}
