//! `sblint` — the repo's invariants-as-code lint (see `sketchboost::lint`).
//!
//! Usage:
//!
//! ```text
//! cargo run --release --bin sblint [-- --root <repo-root>]
//! ```
//!
//! Walks `rust/src`, `rust/tests`, and `benches` under the root
//! (defaulting to the workspace root this binary was built from),
//! prints one `path:line: [rule] message` per finding, and exits
//! nonzero iff anything was found. Suppress a finding with
//! `// LINT-ALLOW(<rule>): <reason>` — see DESIGN.md "Invariants as
//! code".

use std::path::PathBuf;
use std::process::ExitCode;

use sketchboost::lint;

fn main() -> ExitCode {
    let mut root: Option<PathBuf> = None;
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--root" => match args.next() {
                Some(r) => root = Some(PathBuf::from(r)),
                None => {
                    eprintln!("sblint: --root needs a path");
                    return ExitCode::from(2);
                }
            },
            "--help" | "-h" => {
                eprintln!("usage: sblint [--root <repo-root>]");
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("sblint: unknown argument {other:?} (try --help)");
                return ExitCode::from(2);
            }
        }
    }
    // CARGO_MANIFEST_DIR is rust/; the lint root is the repo root above it
    let root = root.unwrap_or_else(|| {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..")
    });

    let diags = lint::run(&root);
    for d in &diags {
        println!("{}", d.render());
    }
    if diags.is_empty() {
        eprintln!("sblint: clean ({} dirs checked)", lint::LINT_DIRS.len());
        ExitCode::SUCCESS
    } else {
        eprintln!("sblint: {} finding(s)", diags.len());
        ExitCode::FAILURE
    }
}
