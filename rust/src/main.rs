//! SketchBoost CLI launcher.
//!
//! Subcommands:
//!   train              train on a dataset profile, CSV file, or chunked store
//!   bin                write a CSV/profile as an on-disk chunked binned store
//!   predict            batch-score a CSV with a saved model (FlatForest)
//!   serve              TCP daemon with request coalescing + model hot-swap
//!   evaluate           load a saved model and score a dataset
//!   gen-data           write a synthetic profile dataset to CSV
//!   bench-synth        quick Figure-1-style scaling run
//!   inspect-artifacts  list the AOT artifact manifest
//!
//! Run `sketchboost <command> --help` for options.

use std::process::ExitCode;

use sketchboost::baselines::one_vs_all::fit_one_vs_all;
use sketchboost::boosting::metrics::Metric;
use sketchboost::boosting::trainer::{GBDTConfig, GBDT};
use sketchboost::data::binning::{BinnedDataset, StreamingQuantiles, STREAM_RESERVOIR};
use sketchboost::data::csv;
use sketchboost::data::dataset::{FeatureKind, Targets};
use sketchboost::data::profiles::Profile;
use sketchboost::data::split::train_test_split;
use sketchboost::data::store::StoreWriter;
use sketchboost::data::{store, ChunkedBinned};
use sketchboost::engine::{EngineOpts, MissingPolicy, XlaEngine};
use sketchboost::prelude::*;
use sketchboost::util::bench::{fmt_secs, time_once, Table};
use sketchboost::util::cli::{usage, Args};

fn main() -> ExitCode {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("");
    let result = match cmd {
        "train" => cmd_train(&args),
        "bin" => cmd_bin(&args),
        "predict" => cmd_predict(&args),
        "serve" => cmd_serve(&args),
        "evaluate" => cmd_evaluate(&args),
        "cv" => cmd_cv(&args),
        "gen-data" => cmd_gen_data(&args),
        "bench-synth" => cmd_bench_synth(&args),
        "inspect-artifacts" => cmd_inspect(&args),
        "inspect-model" => cmd_inspect_model(&args),
        _ => {
            eprint!("{}", top_usage());
            return ExitCode::from(2);
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn top_usage() -> String {
    "SketchBoost: fast multioutput GBDT (NeurIPS 2022 reproduction)\n\n\
     Usage: sketchboost <command> [options]\n\n\
     Commands:\n\
     \x20 train              train a model (see `train --help`)\n\
     \x20 bin                write a chunked binned store for out-of-core training (see `bin --help`)\n\
     \x20 predict            batch-score a CSV with a saved model (see `predict --help`)\n\
     \x20 serve              micro-batching TCP model server (see `serve --help`)\n\
     \x20 evaluate           score a saved model on a dataset\n\
     \x20 cv                 5-fold cross-validation (paper Appendix B.2)\n\
     \x20 gen-data           write a synthetic profile dataset to CSV\n\
     \x20 bench-synth        Figure-1-style time-vs-classes scaling run\n\
     \x20 inspect-artifacts  list AOT artifacts + shapes\n\
     \x20 inspect-model      feature importances + tree dump of a model\n"
        .to_string()
}

fn load_data(args: &Args) -> Result<Dataset, Box<dyn std::error::Error>> {
    if let Some(path) = args.get("data") {
        let task = args.get_str("task", "multiclass");
        let d = args.get_usize("outputs", 2);
        let cats = args.get_usize_list("categorical", &[]);
        Ok(csv::load_dataset_spec(std::path::Path::new(path), &task, d, &cats)?)
    } else {
        let name = args.get_str("profile", "otto");
        let p = Profile::by_name(&name)
            .ok_or_else(|| format!("unknown profile {name:?} (see data/profiles.rs)"))?;
        let rows = args.get_usize("rows", p.rows);
        // profiles with categorical columns mark the dataset themselves
        Ok(p.generate_sized(rows, args.get_u64("data-seed", 42)))
    }
}

fn config_from_args(args: &Args, targets: &Targets) -> GBDTConfig {
    if let Some(path) = args.get("config") {
        let mut cfg = sketchboost::config::load_config(std::path::Path::new(path))
            .unwrap_or_else(|e| panic!("--config {path}: {e}"));
        assert_eq!(
            cfg.n_outputs,
            targets.n_outputs(),
            "--config outputs != dataset outputs"
        );
        cfg.verbose = args.flag("verbose") || cfg.verbose;
        cfg.n_threads = args.get_usize("threads", cfg.n_threads);
        // run-shape flags stay overridable on top of a config file
        cfg.early_stopping_rounds =
            args.get_usize("early-stop", cfg.early_stopping_rounds);
        if args.get("categorical").is_some() {
            cfg.categorical_features = args.get_usize_list("categorical", &[]);
        }
        if let Some(p) = args.get("missing") {
            cfg.missing_policy = MissingPolicy::parse(p)
                .unwrap_or_else(|| panic!("unknown missing policy {p:?} (learn|left)"));
        }
        return cfg;
    }
    let mut cfg = GBDTConfig::for_targets(targets);
    cfg.n_rounds = args.get_usize("rounds", 100);
    cfg.learning_rate = args.get_f32("lr", 0.05);
    cfg.max_depth = args.get_usize("depth", 6);
    cfg.lambda_l2 = args.get_f32("lambda", 1.0);
    cfg.min_data_in_leaf = args.get_usize("min-data", 1);
    cfg.subsample = args.get_f32("subsample", 1.0);
    cfg.colsample = args.get_f32("colsample", 1.0);
    cfg.max_bins = args.get_usize("bins", 64);
    cfg.seed = args.get_u64("seed", 42);
    cfg.early_stopping_rounds = args.get_usize("early-stop", 0);
    cfg.n_threads = args.get_usize("threads", 1);
    cfg.verbose = args.flag("verbose");
    let k = args.get_usize("k", 5);
    let sk = args.get_str("sketch", "full");
    cfg.sketch = SketchConfig::parse(&sk, k)
        .unwrap_or_else(|| panic!("unknown sketch {sk:?} (full|top|rs|rp|svd)"));
    cfg.categorical_features = args.get_usize_list("categorical", &[]);
    let mp = args.get_str("missing", "learn");
    cfg.missing_policy = MissingPolicy::parse(&mp)
        .unwrap_or_else(|| panic!("unknown missing policy {mp:?} (learn|left)"));
    cfg
}

fn cmd_train(args: &Args) -> Result<(), Box<dyn std::error::Error>> {
    if args.flag("help") {
        print!(
            "{}",
            usage(
                "sketchboost train [options]",
                "Train a SketchBoost model.",
                &[
                    ("--profile NAME", "synthetic profile (default otto); see data/profiles.rs"),
                    ("--rows N", "override profile row count"),
                    ("--data FILE", "CSV instead of a profile (with --task, --outputs)"),
                    ("--categorical LIST", "comma-separated feature columns holding category ids (e.g. 0,3,7)"),
                    ("--missing P", "missing-value routing: learn (per-split default) | left (legacy)"),
                    ("--sketch S", "full | top | rs | rp | svd (default full)"),
                    ("--k K", "sketch dimension (default 5)"),
                    ("--rounds N", "boosting rounds (default 100)"),
                    ("--lr F", "learning rate (default 0.05)"),
                    ("--depth N", "max tree depth (default 6)"),
                    ("--bins N", "max histogram bins (default 64)"),
                    ("--threads N", "engine worker threads; 0 = all cores (default 1)"),
                    ("--early-stop N", "early stopping patience (default off)"),
                    ("--eval-every N", "log train/valid metrics every N rounds"),
                    ("--checkpoint FILE", "save model JSON during training ({round} in FILE gets the round number)"),
                    ("--checkpoint-every N", "checkpoint period in rounds (default 10)"),
                    ("--time-budget SECS", "stop training once the wall clock exceeds SECS"),
                    ("--strategy S", "single-tree | one-vs-all (default single-tree)"),
                    ("--engine E", "native | xla (default native)"),
                    ("--test-frac F", "holdout fraction (default 0.2)"),
                    ("--out FILE", "save the model JSON"),
                    ("--out-of-core", "train through an on-disk chunked store (bit-identical to in-RAM)"),
                    ("--store FILE", "existing store from `sketchboost bin`; trains on it directly (implies --out-of-core, no holdout)"),
                    ("--chunk-rows N", "rows per chunk when auto-binning under --out-of-core (default 16384)"),
                    ("--chunk-pool N", "resident chunk budget for the loader pool (default 8)"),
                ],
            )
        );
        return Ok(());
    }
    if let Some(path) = args.get("store") {
        return cmd_train_store(args, std::path::Path::new(path));
    }
    let ds = load_data(args)?;
    let (train, test) = train_test_split(&ds, args.get_f32("test-frac", 0.2) as f64, 7);
    let mut cfg = config_from_args(args, &ds.targets);
    let strategy = args.get_str("strategy", "single-tree");
    let engine = args.get_str("engine", "native");
    let out_of_core = args.flag("out-of-core");
    println!(
        "training: n={} m={} d={} loss={} sketch={} engine={engine} strategy={strategy}",
        train.n_rows,
        train.n_features,
        train.n_outputs(),
        cfg.loss.name(),
        cfg.sketch.name(),
    );

    if strategy == "one-vs-all" {
        // the one-vs-all baseline trains outside the Booster session:
        // callback flags would be silently dead there, so reject them
        for flag in ["eval-every", "checkpoint", "checkpoint-every", "time-budget"] {
            if args.get(flag).is_some() {
                return Err(format!(
                    "--{flag} attaches a training-session callback and is not \
                     supported with --strategy one-vs-all (--early-stop works)"
                )
                .into());
            }
        }
        if out_of_core {
            return Err("--out-of-core needs --strategy single-tree".into());
        }
        let (model, secs) = time_once(|| fit_one_vs_all(&cfg, &train, Some(&test)));
        report_scores("one-vs-all", &model.predict_raw(&test), &test, secs);
        return Ok(());
    }

    let booster = assemble_booster(args, &mut cfg)?;

    let (model, secs) = if out_of_core {
        if engine != "native" {
            return Err("--out-of-core requires --engine native".into());
        }
        // bin the train split into a scratch store, then run the
        // chunked session over it — bit-identical to the in-RAM fit on
        // the same split (the CI smoke step pins this end to end)
        let chunk_rows = args.get_usize("chunk-rows", 16384);
        let pool = args.get_usize("chunk-pool", 8);
        let dir = std::env::temp_dir().join("sketchboost_ooc");
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(format!("train_{}.sbbin", std::process::id()));
        let binned =
            BinnedDataset::from_dataset_with_kinds(&train, cfg.max_bins, &cfg.merged_kinds(&train));
        store::write_binned(&path, &binned, &train.targets, chunk_rows)?;
        drop(binned); // out-of-core from here on
        let chunked = ChunkedBinned::open(&path, pool)?;
        println!(
            "out-of-core: store {} ({} chunks x {chunk_rows} rows, pool {pool})",
            path.display(),
            chunked.header().chunks.len(),
        );
        let r = time_once(|| booster.fit_chunked(&chunked, Some(&test)));
        std::fs::remove_file(&path).ok();
        r
    } else {
        match engine.as_str() {
            "native" => time_once(|| booster.fit(&train, Some(&test))),
            "xla" => {
                let mut eng = XlaEngine::with_opts(
                    &args.get_str("tag", "e2e"),
                    EngineOpts::threads(cfg.n_threads),
                )?;
                println!("xla engine: {}", eng.describe());
                time_once(|| booster.fit_with_engine(&train, Some(&test), &mut eng))
            }
            other => return Err(format!("unknown engine {other:?}").into()),
        }
    };
    report_scores(cfg.sketch.name(), &model.predict_raw(&test), &test, secs);
    println!("trees: {}, nodes: {}", model.n_trees(), model.n_nodes());
    if let Some(out) = args.get("out") {
        model.save(std::path::Path::new(out))?;
        println!("model saved to {out}");
    }
    Ok(())
}

/// The callback-driven session shared by every train path:
/// `Booster::from_config` wires early stopping + the default verbose
/// logger from the config; the flags here attach the rest.
fn assemble_booster(
    args: &Args,
    cfg: &mut GBDTConfig,
) -> Result<Booster, Box<dyn std::error::Error>> {
    let eval_every = args.get_usize("eval-every", 0);
    if eval_every > 0 {
        cfg.verbose = false; // --eval-every supersedes the 10-round default
    }
    let mut booster = Booster::from_config(cfg);
    if eval_every > 0 {
        booster = booster.callback(EvalLogger::every(eval_every));
    }
    if let Some(path) = args.get("checkpoint") {
        booster =
            booster.callback(Checkpoint::every(path, args.get_usize("checkpoint-every", 10)));
    } else if args.get("checkpoint-every").is_some() {
        return Err("--checkpoint-every needs --checkpoint FILE".into());
    }
    let time_budget = args.get_f32("time-budget", 0.0);
    if time_budget > 0.0 {
        booster = booster.callback(TimeBudget::seconds(time_budget as f64));
    }
    Ok(booster)
}

/// `train --store FILE`: the fully out-of-core path — the feature
/// matrix never exists in RAM, only the store's chunk pool plus the
/// targets from its header. No holdout (the store is one fixed split);
/// history carries the train metric.
fn cmd_train_store(
    args: &Args,
    store_path: &std::path::Path,
) -> Result<(), Box<dyn std::error::Error>> {
    let strategy = args.get_str("strategy", "single-tree");
    if strategy != "single-tree" {
        return Err("--store needs --strategy single-tree".into());
    }
    if args.get_str("engine", "native") != "native" {
        return Err("--store requires --engine native".into());
    }
    let pool = args.get_usize("chunk-pool", 8);
    let chunked = ChunkedBinned::open(store_path, pool)?;
    let h = chunked.header();
    let mut cfg = config_from_args(args, chunked.targets());
    println!(
        "training (out-of-core): n={} m={} d={} loss={} sketch={} store={} ({} chunks x {} rows, pool {pool})",
        h.n_rows,
        h.n_features,
        chunked.n_outputs(),
        cfg.loss.name(),
        cfg.sketch.name(),
        store_path.display(),
        h.chunks.len(),
        h.chunk_rows,
    );
    let booster = assemble_booster(args, &mut cfg)?;
    let (model, secs) = time_once(|| booster.fit_chunked(&chunked, None));
    let last = model.history.train_loss.last().copied().unwrap_or(f64::NAN);
    println!(
        "[{}] train loss = {last:.5}, time = {}",
        cfg.sketch.name(),
        fmt_secs(secs)
    );
    println!("trees: {}, nodes: {}", model.n_trees(), model.n_nodes());
    if let Some(out) = args.get("out") {
        model.save(std::path::Path::new(out))?;
        println!("model saved to {out}");
    }
    Ok(())
}

/// `sketchboost bin`: write a dataset as an on-disk chunked binned
/// store for out-of-core training.
fn cmd_bin(args: &Args) -> Result<(), Box<dyn std::error::Error>> {
    if args.flag("help") {
        print!(
            "{}",
            usage(
                "sketchboost bin --out FILE [options]",
                "Bin a dataset into an on-disk chunked store (train --store / --out-of-core).",
                &[
                    ("--out FILE", "store file to write (required)"),
                    ("--profile NAME", "synthetic profile (default otto); see data/profiles.rs"),
                    ("--rows N", "override profile row count"),
                    ("--data FILE", "CSV instead of a profile (with --task, --outputs)"),
                    ("--task S", "multiclass | multilabel | regression (default multiclass)"),
                    ("--outputs N", "target columns / classes (default 2)"),
                    ("--categorical LIST", "feature columns holding category ids (e.g. 0,3,7)"),
                    ("--bins N", "max histogram bins (default 64)"),
                    ("--chunk-rows N", "rows per chunk (default 16384)"),
                    ("--stream", "two-pass streaming CSV binning: reservoir quantiles, one-row memory (needs --data)"),
                    ("--seed N", "reservoir seed under --stream (default 42)"),
                ],
            )
        );
        return Ok(());
    }
    let out = args.get("out").ok_or("bin needs --out FILE (the store to write)")?;
    let out = std::path::Path::new(out);
    let chunk_rows = args.get_usize("chunk-rows", 16384);
    let max_bins = args.get_usize("bins", 64);
    if args.flag("stream") {
        return cmd_bin_stream(args, out, chunk_rows, max_bins);
    }
    // exact path: bin in RAM with the same quantile code training uses,
    // so a store written here reproduces in-RAM training bit for bit
    let ds = load_data(args)?;
    let binned = BinnedDataset::from_dataset(&ds, max_bins);
    store::write_binned(out, &binned, &ds.targets, chunk_rows)?;
    let n_chunks = (ds.n_rows + chunk_rows - 1) / chunk_rows;
    println!(
        "wrote {} ({} rows x {} features, {} outputs, {} chunks x {chunk_rows} rows, bins {max_bins})",
        out.display(),
        ds.n_rows,
        ds.n_features,
        ds.n_outputs(),
        n_chunks,
    );
    Ok(())
}

/// `bin --stream`: two passes over the CSV, never holding more than one
/// row of features — pass 1 feeds per-feature reservoir quantiles
/// (exact when a column's non-NaN count fits the reservoir), pass 2
/// bins rows straight into chunk payloads. Targets accumulate in RAM
/// (they are O(n*d), the same budget training itself needs).
fn cmd_bin_stream(
    args: &Args,
    out: &std::path::Path,
    chunk_rows: usize,
    max_bins: usize,
) -> Result<(), Box<dyn std::error::Error>> {
    let data = args.get("data").ok_or("--stream needs --data FILE (CSV)")?;
    let data = std::path::Path::new(data);
    let task = args.get_str("task", "multiclass");
    let d = args.get_usize("outputs", 2);
    let cats = args.get_usize_list("categorical", &[]);
    let tgt_cols = if task == "multiclass" { 1 } else { d };
    let seed = args.get_u64("seed", 42);

    // pass 1: per-feature reservoirs -> bin edges
    let mut sq: Option<StreamingQuantiles> = None;
    let mut m = 0usize;
    csv::stream_rows(data, &mut |row| {
        if sq.is_none() {
            if row.len() <= tgt_cols {
                return Err("no feature columns left".into());
            }
            m = row.len() - tgt_cols;
            let mut kinds = vec![FeatureKind::Numeric; m];
            for &f in &cats {
                if f >= m {
                    return Err(format!(
                        "categorical column {f} out of range ({m} feature columns)"
                    )
                    .into());
                }
                kinds[f] = FeatureKind::Categorical;
            }
            sq = Some(StreamingQuantiles::new(max_bins, &kinds, STREAM_RESERVOIR, seed));
        }
        sq.as_mut().unwrap().push_row(&row[..m]);
        Ok(())
    })?;
    let sq = sq.ok_or("empty csv: nothing to bin")?;
    let n = sq.n_rows();
    let spec = sq.finish();

    // pass 2: bin each row into chunk payloads + collect targets
    let mut w = StoreWriter::create(out, spec, chunk_rows)?;
    let mut labels_u32: Vec<u32> = Vec::new();
    let mut values_f32: Vec<f32> = Vec::new();
    csv::stream_rows(data, &mut |row| {
        w.push_row(&row[..m])?;
        if task == "multiclass" {
            labels_u32.push(row[m] as u32);
        } else {
            values_f32.extend_from_slice(&row[m..]);
        }
        Ok(())
    })?;
    let targets = match task.as_str() {
        "multiclass" => {
            let n_classes =
                d.max(labels_u32.iter().copied().max().unwrap_or(0) as usize + 1);
            Targets::Multiclass { labels: labels_u32, n_classes }
        }
        "multilabel" => Targets::Multilabel { labels: values_f32, n_labels: d },
        "regression" | "multitask" => Targets::Regression { values: values_f32, n_targets: d },
        other => return Err(format!("unknown task {other:?}").into()),
    };
    w.finish(&targets)?;
    let n_chunks = (n + chunk_rows - 1) / chunk_rows;
    println!(
        "wrote {} ({n} rows x {m} features, {} outputs, {} chunks x {chunk_rows} rows, bins {max_bins}, streamed)",
        out.display(),
        targets.n_outputs(),
        n_chunks,
    );
    Ok(())
}

fn report_scores(label: &str, preds: &[f32], test: &Dataset, secs: f64) {
    let primary = Metric::primary(&test.targets);
    let secondary = Metric::secondary(&test.targets);
    println!(
        "[{label}] test {} = {:.5}, {} = {:.4}, time = {}",
        primary.name(),
        primary.eval(preds, &test.targets),
        secondary.name(),
        secondary.eval(preds, &test.targets),
        fmt_secs(secs),
    );
}

fn cmd_evaluate(args: &Args) -> Result<(), Box<dyn std::error::Error>> {
    let model_path = args
        .get("model")
        .ok_or("evaluate needs --model FILE (a model saved by train --out)")?;
    let model = Ensemble::load(std::path::Path::new(model_path))?;
    let ds = load_data(args)?;
    let opts = PredictOptions::threads(args.get_usize("threads", 1));
    let pred = Predictor::compile(&model, opts);
    let (preds, secs) = time_once(|| pred.raw(&ds));
    report_scores("saved-model", &preds, &ds, secs);
    Ok(())
}

/// Batch inference: load a saved model, score a CSV (or synthetic
/// profile) through the FlatForest path, report throughput, optionally
/// write the predictions.
fn cmd_predict(args: &Args) -> Result<(), Box<dyn std::error::Error>> {
    if args.flag("help") {
        print!(
            "{}",
            usage(
                "sketchboost predict --model FILE [options]",
                "Batch-score a dataset with a saved model (batched parallel FlatForest).",
                &[
                    ("--model FILE", "model JSON saved by train --out (required)"),
                    ("--data FILE", "feature-only CSV to score (all columns are features)"),
                    ("--labeled", "the CSV also has target columns (with --task, --outputs); reports metrics"),
                    ("--task S", "with --labeled: multiclass | multilabel | regression"),
                    ("--outputs N", "with --labeled: number of target columns"),
                    ("--profile NAME", "score a synthetic profile instead of a CSV (implies metrics)"),
                    ("--threads N", "worker threads over row blocks; 0 = all cores (default 1)"),
                    ("--block N", "rows per block (default 512)"),
                    ("--layout S", "forest layout: v1 | v2 | v2q (default v1; v2 is bit-identical, v2q quantizes)"),
                    ("--exact-leaves", "with --layout v2q: keep f32 leaves (bit-identical output)"),
                    ("--raw", "write raw scores instead of probabilities"),
                    ("--out FILE", "write predictions CSV (header p0..p{d-1})"),
                ],
            )
        );
        return Ok(());
    }
    let model_path = args
        .get("model")
        .ok_or("predict needs --model FILE (a model saved by train --out)")?;
    let model = Ensemble::load(std::path::Path::new(model_path))?;
    let mut opts = PredictOptions::threads(args.get_usize("threads", 1))
        .with_block_rows(args.get_usize("block", 512))
        .with_exact_leaves(args.flag("exact-leaves"));
    if let Some(s) = args.get("layout") {
        opts = opts.with_layout(ForestLayout::parse(s)?);
    }
    // feature-only CSV by default; --labeled / --profile routes through
    // the target-aware loader and also reports metrics
    let labeled = args.flag("labeled") || args.get("data").is_none();
    let ds = if labeled {
        load_data(args)?
    } else {
        csv::load_features(std::path::Path::new(args.get("data").unwrap()))?
    };
    let pred = Predictor::compile(&model, opts);
    let flat = pred.forest();
    if ds.n_features < flat.n_features_required() {
        return Err(format!(
            "dataset has {} feature columns but the model splits on feature index {} \
             (needs >= {} features)",
            ds.n_features,
            flat.n_features_required() - 1,
            flat.n_features_required(),
        )
        .into());
    }
    let (raw, secs) = time_once(|| pred.raw(&ds));
    println!(
        "predict: n={} m={} d={} trees={} nodes={} layout={} threads={} block={} time={} ({:.1}k rows/s)",
        ds.n_rows,
        ds.n_features,
        model.n_outputs,
        flat.n_trees(),
        flat.n_nodes(),
        flat.layout().as_str(),
        opts.n_threads,
        opts.block_rows,
        fmt_secs(secs),
        ds.n_rows as f64 / secs.max(1e-12) / 1e3,
    );
    if labeled {
        if ds.n_outputs() == model.n_outputs {
            report_scores("predict", &raw, &ds, secs);
        } else {
            eprintln!(
                "warning: dataset outputs ({}) != model outputs ({}); skipping metrics",
                ds.n_outputs(),
                model.n_outputs
            );
        }
    }
    if let Some(out) = args.get("out") {
        let mut preds = raw;
        if !args.flag("raw") {
            model.apply_link(&mut preds);
        }
        csv::write_predictions(std::path::Path::new(out), &preds, model.n_outputs)?;
        println!("predictions written to {out}");
    }
    Ok(())
}

/// The serving daemon: load a model, bind, and block until `/shutdown`
/// (or a signal kills the process; in-flight batches drain either way
/// on `/shutdown`).
fn cmd_serve(args: &Args) -> Result<(), Box<dyn std::error::Error>> {
    if args.flag("help") {
        print!(
            "{}",
            usage(
                "sketchboost serve --model FILE [options]",
                "Serve a saved model over TCP with micro-batching (line protocol: \
                 CSV rows in, scores out; /stats, /model, /ping, /shutdown).",
                &[
                    ("--model FILE", "model JSON saved by train --out (required)"),
                    ("--config FILE", "serve options JSON (flags below override it)"),
                    ("--bind ADDR", "listen address (default 127.0.0.1)"),
                    ("--port N", "TCP port; 0 = OS-assigned ephemeral (default 0)"),
                    ("--threads N", "scoring worker threads (default 1)"),
                    ("--block N", "rows per scoring block = coalescing target (default 512)"),
                    ("--max-wait-us N", "batch linger once it has one request, µs (default 250)"),
                    ("--queue N", "pending-job queue capacity (default 1024)"),
                    ("--watch", "hot-swap the model when --model's file changes"),
                    ("--poll-ms N", "watch poll interval (default 200, implies --watch)"),
                    ("--deadline-ms N", "shed requests queued longer than N ms (default 0 = off)"),
                    ("--shed POLICY", "full-queue policy: block | drop (default block)"),
                    ("--max-rows N", "max rows per request, larger get !too_large (default 4096)"),
                    ("--max-line-bytes N", "max request line bytes (default 1048576)"),
                    ("--idle-timeout-ms N", "close idle connections after N ms (default 0 = off)"),
                    ("--layout S", "forest layout: v1 | v2 | v2q (default v1; hot-swaps recompile into it)"),
                    ("--exact-leaves", "with --layout v2q: keep f32 leaves (bit-identical scores)"),
                ],
            )
        );
        return Ok(());
    }
    let model_path = args
        .get("model")
        .ok_or("serve needs --model FILE (a model saved by train --out)")?;
    let mut opts = match args.get("config") {
        Some(path) => sketchboost::config::load_serve_options(std::path::Path::new(path))?,
        None => sketchboost::serve::ServeOptions::default(),
    };
    if let Some(bind) = args.get("bind") {
        opts.bind = bind.to_string();
    }
    let port = args.get_usize("port", opts.port as usize);
    opts.port = u16::try_from(port).map_err(|_| format!("--port {port} out of range"))?;
    opts.n_workers = args.get_usize("threads", opts.n_workers);
    opts.block_rows = args.get_usize("block", opts.block_rows);
    opts.max_wait_us = args.get_u64("max-wait-us", opts.max_wait_us);
    opts.queue_cap = args.get_usize("queue", opts.queue_cap);
    if args.flag("watch") || args.get("poll-ms").is_some() {
        opts.poll_ms = args.get_u64("poll-ms", if opts.poll_ms > 0 { opts.poll_ms } else { 200 });
    }
    opts.deadline_ms = args.get_u64("deadline-ms", opts.deadline_ms);
    if let Some(policy) = args.get("shed") {
        opts.shed = sketchboost::serve::ShedPolicy::parse(policy)?;
    }
    opts.max_rows = args.get_usize("max-rows", opts.max_rows);
    opts.max_line_bytes = args.get_usize("max-line-bytes", opts.max_line_bytes);
    opts.idle_timeout_ms = args.get_u64("idle-timeout-ms", opts.idle_timeout_ms);
    if let Some(s) = args.get("layout") {
        opts.layout = ForestLayout::parse(s)?;
    }
    if args.flag("exact-leaves") {
        opts.exact_leaves = true;
    }

    let server = sketchboost::serve::Server::start(std::path::Path::new(model_path), &opts)?;
    println!(
        "serving {model_path} on {} (workers={} block={} max_wait_us={} layout={} shed={}{}{}{})",
        server.addr(),
        opts.n_workers.max(1),
        opts.block_rows.max(1),
        opts.max_wait_us,
        opts.layout.as_str(),
        opts.shed.as_str(),
        if opts.deadline_ms > 0 {
            format!(" deadline={}ms", opts.deadline_ms)
        } else {
            String::new()
        },
        if opts.idle_timeout_ms > 0 {
            format!(" idle_timeout={}ms", opts.idle_timeout_ms)
        } else {
            String::new()
        },
        if opts.poll_ms > 0 {
            format!(" watch={}ms", opts.poll_ms)
        } else {
            String::new()
        },
    );
    server.wait();
    println!("shutdown requested; draining");
    server.stop();
    println!("bye");
    Ok(())
}

/// 5-fold CV exactly as the paper's Appendix B.2 evaluation stage.
fn cmd_cv(args: &Args) -> Result<(), Box<dyn std::error::Error>> {
    let ds = load_data(args)?;
    let cfg = config_from_args(args, &ds.targets);
    let k = args.get_usize("folds", 5);
    let metric = cfg.metric();
    println!(
        "{k}-fold CV on n={} m={} d={} (sketch={}, {} rounds)",
        ds.n_rows,
        ds.n_features,
        ds.n_outputs(),
        cfg.sketch.name(),
        cfg.n_rounds
    );
    let folds = GBDT::fit_cv(&cfg, &ds, k);
    let losses: Vec<f64> = folds.iter().map(|(_, l)| *l).collect();
    let mean = losses.iter().sum::<f64>() / k as f64;
    let var = losses.iter().map(|l| (l - mean) * (l - mean)).sum::<f64>() / (k - 1).max(1) as f64;
    for (i, l) in losses.iter().enumerate() {
        println!("fold {i}: {} = {l:.5}", metric.name());
    }
    println!("mean = {mean:.5} +/- {:.5}", var.sqrt());
    Ok(())
}

/// Print feature importances + the first tree of a saved model.
fn cmd_inspect_model(args: &Args) -> Result<(), Box<dyn std::error::Error>> {
    use sketchboost::boosting::inspect::ImportanceKind;
    let model_path = args.get("model").ok_or("inspect-model needs --model FILE")?;
    let model = Ensemble::load(std::path::Path::new(model_path))?;
    println!(
        "model: {} trees, {} nodes, {} outputs, loss = {}",
        model.n_trees(),
        model.n_nodes(),
        model.n_outputs,
        model.loss.name()
    );
    let max_feature = model
        .trees
        .iter()
        .flat_map(|t| t.nodes.iter().map(|n| n.feature as usize))
        .max()
        .unwrap_or(0);
    let top = model.top_features(max_feature + 1, ImportanceKind::TotalGain, 10);
    let mut t = Table::new(&["feature", "total gain"]);
    for (f, gain) in top {
        t.row(&[format!("f{f}"), format!("{gain:.3}")]);
    }
    t.print();
    if !model.trees.is_empty() {
        println!("\ntree 0:\n{}", model.dump_tree(0));
    }
    Ok(())
}

fn cmd_gen_data(args: &Args) -> Result<(), Box<dyn std::error::Error>> {
    let name = args.get_str("profile", "otto");
    let p = Profile::by_name(&name).ok_or_else(|| format!("unknown profile {name:?}"))?;
    let rows = args.get_usize("rows", p.rows);
    let ds = p.generate_sized(rows, args.get_u64("data-seed", 42));
    let out = args.get_str("out", &format!("{name}.csv"));
    csv::write_dataset(std::path::Path::new(&out), &ds)?;
    println!("wrote {rows} rows x {} features ({} outputs) to {out}", p.features, p.outputs);
    Ok(())
}

/// Figure-1-style quick scaling run from the CLI.
fn cmd_bench_synth(args: &Args) -> Result<(), Box<dyn std::error::Error>> {
    use sketchboost::data::synthetic::{make_multiclass, FeatureSpec};
    let rows = args.get_usize("rows", 4000);
    let m = args.get_usize("features", 50);
    let rounds = args.get_usize("rounds", 20);
    let classes = args.get_usize_list("classes", &[5, 10, 25, 50]);
    let k = args.get_usize("k", 5);
    let threads = args.get_usize("threads", 1);
    let mut table = Table::new(&["classes", "one-vs-all", "single-tree full", "sketch rp k"]);
    for &d in &classes {
        let ds = make_multiclass(rows, FeatureSpec::guyon(m), d, 1.6, 1);
        let mut cfg = GBDTConfig::multiclass(d);
        cfg.n_rounds = rounds;
        cfg.max_depth = 6;
        cfg.max_bins = 64;
        cfg.n_threads = threads;
        let (_, t_ova) = time_once(|| fit_one_vs_all(&cfg, &ds, None));
        let (_, t_full) = time_once(|| GBDT::fit(&cfg, &ds, None));
        let mut cfg_rp = cfg.clone();
        cfg_rp.sketch = SketchConfig::RandomProjection { k };
        let (_, t_rp) = time_once(|| GBDT::fit(&cfg_rp, &ds, None));
        table.row(&[
            d.to_string(),
            fmt_secs(t_ova),
            fmt_secs(t_full),
            fmt_secs(t_rp),
        ]);
    }
    table.print();
    Ok(())
}

fn cmd_inspect(_args: &Args) -> Result<(), Box<dyn std::error::Error>> {
    use sketchboost::runtime::registry::{artifacts_available, ArtifactRegistry};
    if !artifacts_available() {
        return Err("no artifacts found; run `make artifacts`".into());
    }
    let reg = ArtifactRegistry::open_default()?;
    println!("lambda = {}", reg.lambda);
    let mut t = Table::new(&["artifact", "op", "chunk", "d", "k", "m", "bins", "nodes"]);
    for name in reg.names() {
        let s = reg.signature(name).unwrap();
        t.row(&[
            name.to_string(),
            s.op.clone(),
            s.chunk.to_string(),
            s.d.to_string(),
            s.k.to_string(),
            s.m.to_string(),
            s.bins.to_string(),
            s.nodes.to_string(),
        ]);
    }
    t.print();
    Ok(())
}
