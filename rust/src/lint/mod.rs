//! `sblint`: the project's invariants, enforced as code.
//!
//! The crate's value proposition — bit-deterministic parallel training
//! and serving on top of an unsafe disjoint-write core — rests on
//! conventions that used to live only in prose (DESIGN.md §7 and the
//! SAFETY comments around `DisjointSlice`). This module turns them into
//! named, individually suppressible lint rules, run by the `sblint`
//! binary and gated in CI:
//!
//! | rule            | invariant                                              |
//! |-----------------|--------------------------------------------------------|
//! | `unsafe-safety` | every `unsafe` carries a `// SAFETY:` / `# Safety`     |
//! | `disjoint`      | every `range_mut` call names its partition (`DISJOINT:`)|
//! | `determinism`   | no unordered maps / clocks / env in deterministic mods |
//! | `serve-unwrap`  | no `unwrap`/`expect` on the serve request path         |
//! | `registry`      | fault points ↔ error codes ↔ counters ↔ chaos ↔ benches|
//! | `pragma`        | every `LINT-ALLOW` is well-formed and gives a reason   |
//!
//! Suppress a single finding with `// LINT-ALLOW(<rule>): <reason>` on
//! (or directly above) the offending line. See DESIGN.md "Invariants as
//! code" for the catalog and the add-a-rule procedure.

pub mod registry;
pub mod rules;
pub mod scan;

use std::fs;
use std::path::Path;

pub use rules::Diagnostic;

/// The directories `sblint` walks, relative to the repo root.
pub const LINT_DIRS: &[&str] = &["rust/src", "rust/tests", "benches"];

/// Lint every `.rs` file under [`LINT_DIRS`] plus the cross-registry
/// checks. Returns all findings, sorted by path then line; empty means
/// the tree is clean.
pub fn run(root: &Path) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    for dir in LINT_DIRS {
        for rel in registry::rs_files_under(root, dir) {
            match fs::read_to_string(root.join(&rel)) {
                Ok(text) => {
                    let scanned = scan::scan_source(&rel, root.join(&rel), &text);
                    diags.extend(rules::check_file(&scanned));
                }
                Err(e) => diags.push(Diagnostic {
                    rel_path: rel.clone(),
                    line: 1,
                    rule: rules::RULE_REGISTRY,
                    message: format!("unreadable: {e}"),
                }),
            }
        }
    }
    diags.extend(registry::check_registries(root));
    diags.sort_by(|a, b| (&a.rel_path, a.line).cmp(&(&b.rel_path, b.line)));
    diags
}
