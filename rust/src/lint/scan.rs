//! The sblint source scanner: a small, dependency-free line/token pass.
//!
//! `sblint` deliberately avoids `syn` (the crate's zero-external-deps
//! rule), so every rule works on a *scanned* view of each source file:
//!
//! * [`Line::code`] — the line with comments stripped and the contents
//!   of string/char literals blanked to spaces (delimiters kept). Rules
//!   that look for tokens (`unsafe`, `.unwrap()`, `HashMap`) match
//!   here, so a string containing the word "unsafe" never trips R1.
//! * [`Line::comment`] — the comment text on the line (`//`, `///`,
//!   `//!`, and `/* */` bodies). `SAFETY:`/`DISJOINT:`/`LINT-ALLOW`
//!   grammar lives here.
//! * [`Line::raw`] — the untouched source line. Only the cross-registry
//!   checks read this (they extract names *out of* string literals,
//!   e.g. `fault::point("serve.worker.score")`).
//! * [`Line::in_test`] — whether the line sits inside a
//!   `#[cfg(test)] mod … { … }` block. The determinism and serve-unwrap
//!   rules skip test code; the `SAFETY`/`DISJOINT` rules do not (unsafe
//!   in a test still needs its invariant written down).
//!
//! The scanner is conservative where Rust's lexis is genuinely hard
//! without a real lexer (lifetimes vs char literals are disambiguated
//! by lookahead; nested block comments are depth-counted; raw strings
//! track their `#` count). It has line-level granularity on purpose:
//! every project invariant the lint enforces is already written as a
//! line-adjacent comment convention.

use std::path::PathBuf;

/// One scanned source line (see module docs for the three views).
#[derive(Debug, Clone)]
pub struct Line {
    pub code: String,
    pub comment: String,
    pub raw: String,
    pub in_test: bool,
}

impl Line {
    /// True when the line carries no code at all (blank, or
    /// comment-only once literals/comments are stripped).
    pub fn is_code_empty(&self) -> bool {
        self.code.trim().is_empty()
    }

    /// True when the line's code is only an attribute (`#[…]`/`#![…]`).
    pub fn is_attr_only(&self) -> bool {
        let t = self.code.trim();
        (t.starts_with("#[") || t.starts_with("#![")) && t.ends_with(']')
    }
}

/// A fully scanned file.
#[derive(Debug)]
pub struct ScannedFile {
    /// Path relative to the lint root, `/`-separated.
    pub rel_path: String,
    /// Absolute (or as-given) path, for diagnostics.
    pub path: PathBuf,
    pub lines: Vec<Line>,
}

/// Lexer state that survives line breaks.
#[derive(Debug, Clone, Copy, PartialEq)]
enum State {
    Code,
    /// `/* … */`, with nesting depth.
    Block(u32),
    /// `"…"`, possibly continued across lines via `\` or verbatim.
    Str,
    /// `r##"…"##` with the given number of `#`s.
    RawStr(u32),
}

/// Scan `text` into per-line code/comment views (no test-mod marking
/// yet — [`scan_source`] runs both passes).
fn lex_lines(text: &str) -> Vec<Line> {
    let mut out = Vec::new();
    let mut state = State::Code;
    for raw_line in text.lines() {
        let b = raw_line.as_bytes();
        let mut code = String::with_capacity(b.len());
        let mut comment = String::new();
        let mut i = 0usize;
        while i < b.len() {
            match state {
                State::Block(depth) => {
                    if b[i] == b'*' && i + 1 < b.len() && b[i + 1] == b'/' {
                        state = if depth > 1 { State::Block(depth - 1) } else { State::Code };
                        i += 2;
                    } else if b[i] == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
                        state = State::Block(depth + 1);
                        i += 2;
                    } else {
                        comment.push(b[i] as char);
                        i += 1;
                    }
                }
                State::Str => {
                    if b[i] == b'\\' {
                        code.push(' ');
                        if i + 1 < b.len() {
                            code.push(' ');
                        }
                        i += 2; // skip the escaped char (possibly past EOL)
                    } else if b[i] == b'"' {
                        code.push('"');
                        state = State::Code;
                        i += 1;
                    } else {
                        code.push(' ');
                        i += 1;
                    }
                }
                State::RawStr(hashes) => {
                    if b[i] == b'"' {
                        let h = hashes as usize;
                        if i + 1 + h <= b.len() && b[i + 1..i + 1 + h].iter().all(|&c| c == b'#') {
                            code.push('"');
                            for _ in 0..h {
                                code.push('#');
                            }
                            state = State::Code;
                            i += 1 + h;
                            continue;
                        }
                    }
                    code.push(' ');
                    i += 1;
                }
                State::Code => {
                    let c = b[i];
                    if c == b'/' && i + 1 < b.len() && b[i + 1] == b'/' {
                        // line comment (also ///, //!): rest of line
                        comment.push_str(raw_line[i..].trim_start_matches('/'));
                        i = b.len();
                    } else if c == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
                        state = State::Block(1);
                        i += 2;
                    } else if c == b'"' {
                        code.push('"');
                        state = State::Str;
                        i += 1;
                    } else if (c == b'r' || c == b'b')
                        && !prev_is_ident(&code)
                        && raw_prefix_len(&b[i..]).is_some()
                    {
                        let (skip, hashes) = raw_prefix_len(&b[i..]).unwrap();
                        for &p in &b[i..i + skip] {
                            code.push(p as char);
                        }
                        state = State::RawStr(hashes);
                        i += skip;
                    } else if c == b'\'' {
                        // char literal vs lifetime: a literal is '\…' or
                        // exactly one char then ' — anything else is a
                        // lifetime and stays code
                        if i + 1 < b.len() && b[i + 1] == b'\\' {
                            code.push('\'');
                            i += 2; // the opening quote and the backslash
                            if i < b.len() {
                                code.push(' '); // the escaped char (handles '\'')
                                i += 1;
                            }
                            while i < b.len() && b[i] != b'\'' {
                                code.push(' ');
                                i += 1;
                            }
                            if i < b.len() {
                                code.push('\'');
                                i += 1;
                            }
                        } else if i + 2 < b.len() && b[i + 2] == b'\'' {
                            code.push('\'');
                            code.push(' ');
                            code.push('\'');
                            i += 3;
                        } else {
                            code.push('\'');
                            i += 1;
                        }
                    } else {
                        code.push(c as char);
                        i += 1;
                    }
                }
            }
        }
        // a normal string left open at EOL continues on the next line
        out.push(Line {
            code,
            comment,
            raw: raw_line.to_string(),
            in_test: false,
        });
    }
    out
}

/// Is the last pushed code char part of an identifier? (Guards the raw
/// string prefix check so `attr` in `attrs` never reads as `r"…"`.)
fn prev_is_ident(code: &str) -> bool {
    code.chars().last().is_some_and(|c| c.is_ascii_alphanumeric() || c == '_')
}

/// If `b` starts a raw (byte) string prefix — `r"`, `r#"`, `br##"`, … —
/// return (prefix length including the opening quote, hash count).
fn raw_prefix_len(b: &[u8]) -> Option<(usize, u32)> {
    let mut i = 0usize;
    if b[i] == b'b' {
        i += 1;
    }
    if i >= b.len() || b[i] != b'r' {
        return None;
    }
    i += 1;
    let mut hashes = 0u32;
    while i < b.len() && b[i] == b'#' {
        hashes += 1;
        i += 1;
    }
    if i < b.len() && b[i] == b'"' {
        Some((i + 1, hashes))
    } else {
        None
    }
}

/// Mark lines inside `#[cfg(test)] mod … { … }` blocks via brace-depth
/// tracking on the code view (string braces are already blanked).
fn mark_test_mods(lines: &mut [Line]) {
    let mut depth = 0i64;
    let mut pending_cfg_test = false;
    // depth at which a test mod was entered; in_test while depth > it
    let mut test_entry: Option<i64> = None;
    for line in lines.iter_mut() {
        let code = line.code.clone();
        let trimmed = code.trim();
        if let Some(entry) = test_entry {
            line.in_test = depth > entry;
        }
        if trimmed.contains("#[cfg(test)]") {
            pending_cfg_test = true;
        } else if pending_cfg_test && !trimmed.is_empty() {
            if trimmed.starts_with("mod ") || trimmed.starts_with("pub mod ") {
                if test_entry.is_none() {
                    test_entry = Some(depth);
                    line.in_test = true;
                }
                pending_cfg_test = false;
            } else if !line.is_attr_only() {
                pending_cfg_test = false;
            }
        }
        for c in code.chars() {
            match c {
                '{' => depth += 1,
                '}' => {
                    depth -= 1;
                    if let Some(entry) = test_entry {
                        if depth <= entry {
                            test_entry = None;
                        }
                    }
                }
                _ => {}
            }
        }
    }
}

/// Scan one source file into the views every rule consumes.
pub fn scan_source(rel_path: &str, path: PathBuf, text: &str) -> ScannedFile {
    let mut lines = lex_lines(text);
    mark_test_mods(&mut lines);
    ScannedFile { rel_path: rel_path.to_string(), path, lines }
}

/// Does `code` contain `word` as a standalone token (not a substring of
/// a longer identifier)?
pub fn has_token(code: &str, word: &str) -> bool {
    let mut start = 0usize;
    while let Some(pos) = code[start..].find(word) {
        let at = start + pos;
        let before_ok = at == 0
            || !code[..at]
                .chars()
                .next_back()
                .is_some_and(|c| c.is_ascii_alphanumeric() || c == '_');
        let end = at + word.len();
        let after_ok = end >= code.len()
            || !code[end..]
                .chars()
                .next()
                .is_some_and(|c| c.is_ascii_alphanumeric() || c == '_');
        if before_ok && after_ok {
            return true;
        }
        start = at + word.len();
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scan(text: &str) -> ScannedFile {
        scan_source("rust/src/x.rs", PathBuf::from("x.rs"), text)
    }

    #[test]
    fn strings_and_comments_leave_the_code_view() {
        let f = scan("let s = \"unsafe { }\"; // unsafe here too\nunsafe { x() }\n");
        assert!(!has_token(&f.lines[0].code, "unsafe"), "{:?}", f.lines[0].code);
        assert!(f.lines[0].comment.contains("unsafe here too"));
        assert!(has_token(&f.lines[1].code, "unsafe"));
    }

    #[test]
    fn raw_strings_and_escapes_are_blanked() {
        let f = scan(r####"let a = r#"has "quotes" and unsafe"#; let b = "esc\"unsafe";"####);
        assert!(!has_token(&f.lines[0].code, "unsafe"), "{:?}", f.lines[0].code);
        // code after both literals survives
        assert!(f.lines[0].code.contains("let b"));
    }

    #[test]
    fn block_comments_nest_and_span_lines() {
        let f = scan("/* outer /* inner */ still comment */ code();\n/* open\nunsafe\n*/ tail();\n");
        assert!(f.lines[0].code.contains("code()"));
        assert!(f.lines[0].comment.contains("still comment"));
        assert!(!has_token(&f.lines[2].code, "unsafe"));
        assert!(f.lines[3].code.contains("tail()"));
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let f = scan("fn f<'a>(x: &'a str) -> char { 'x' }\nlet c = '{';\nlet d = '\\n';\n");
        assert!(f.lines[0].code.contains("&'a str"));
        // brace inside a char literal must not affect depth tracking
        assert!(!f.lines[1].code.contains('{'), "{:?}", f.lines[1].code);
        assert!(f.lines[2].code.contains("let d"));
    }

    #[test]
    fn cfg_test_mod_is_marked_to_its_closing_brace() {
        let src = "fn prod() {}\n#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() { x.unwrap(); }\n}\nfn after() {}\n";
        let f = scan(src);
        assert!(!f.lines[0].in_test);
        assert!(f.lines[2].in_test, "mod line");
        assert!(f.lines[4].in_test, "test body");
        assert!(!f.lines[6].in_test, "code after the mod");
    }

    #[test]
    fn multiline_string_keeps_blanking() {
        let f = scan("let s = \"line one\nunsafe two\";\nunsafe { real() }\n");
        assert!(!has_token(&f.lines[1].code, "unsafe"));
        assert!(has_token(&f.lines[2].code, "unsafe"));
    }

    #[test]
    fn token_boundaries_are_respected() {
        assert!(has_token("unsafe {", "unsafe"));
        assert!(!has_token("unsafer()", "unsafe"));
        assert!(!has_token("an_unsafe_name", "unsafe"));
        assert!(has_token("x.unwrap()", ".unwrap()"));
    }
}
