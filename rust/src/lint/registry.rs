//! R5: cross-registry consistency.
//!
//! The serve stack's failure-mode contract (DESIGN.md §7) is spread
//! across five places that must agree:
//!
//! 1. the fault-point table in `util/fault.rs` module docs,
//! 2. the actual `fault::point`/`fault::failpoint` call sites,
//! 3. the `ERR_*` error-code constants in `serve/protocol.rs`,
//! 4. the `/stats` counter keys in `serve/stats.rs`,
//! 5. the chaos coverage in `rust/tests/serve_chaos.rs`;
//!
//! plus the perf contract: every tracked claim key in a `BENCH_*.json`
//! trajectory must exist in the bench source that regenerates it, and
//! the schema tags must match. Each check here turns "the table rotted"
//! from a code-review hope into a failing lint.

use std::fs;
use std::path::Path;

use crate::lint::rules::{Diagnostic, RULE_REGISTRY};
use crate::lint::scan::scan_source;
use crate::util::json::Json;

/// Which degradation counter each wire error code increments. Adding a
/// new `ERR_*` code without extending this map is itself a diagnostic:
/// DESIGN.md §7 says every failure mode ships code + counter + chaos
/// coverage together.
const CODE_COUNTERS: &[(&str, &str)] = &[
    ("timeout", "timeouts"),
    ("overloaded", "shed"),
    ("too_large", "too_large"),
    ("internal", "worker_panics"),
];

fn diag(rel_path: &str, line: usize, message: String) -> Diagnostic {
    Diagnostic { rel_path: rel_path.to_string(), line, rule: RULE_REGISTRY, message }
}

fn read(root: &Path, rel: &str) -> Option<String> {
    fs::read_to_string(root.join(rel)).ok()
}

/// Extract the first `` `name` ``-quoted cell of every table row in the
/// fault-point doc table, with its 1-based line number.
fn doc_table_points(fault_src: &str) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    for (idx, line) in fault_src.lines().enumerate() {
        let Some(rest) = line.trim_start().strip_prefix("//!") else { continue };
        let t = rest.trim();
        if !t.starts_with('|') {
            continue;
        }
        // first cell: between the leading `|` and the next `|`
        let cell = t[1..].split('|').next().unwrap_or("").trim();
        if let Some(name) = cell.strip_prefix('`').and_then(|c| c.strip_suffix('`')) {
            if !name.is_empty() {
                out.push((name.to_string(), idx + 1));
            }
        }
    }
    out
}

/// Find `fault::point("…")` / `fault::failpoint("…")` call sites in one
/// file: the *code view* must contain the call (so doc comments and
/// string literals mentioning the API don't count), and the point name
/// is then pulled out of the raw line's string literal.
fn fault_call_sites(rel: &str, src: &str) -> Vec<(String, usize)> {
    let scanned = scan_source(rel, root_free_path(rel), src);
    let mut out = Vec::new();
    for (idx, line) in scanned.lines.iter().enumerate() {
        for needle in ["fault::point(", "fault::failpoint("] {
            if !line.code.contains(needle) {
                continue;
            }
            // the code view proves this is a real call (not a comment or
            // string mention); the name itself lives in the raw line's
            // string literal, right after the needle
            let Some(pos) = line.raw.find(needle) else { continue };
            let at = pos + needle.len();
            let rest = &line.raw[at..];
            if let Some(stripped) = rest.strip_prefix('"') {
                if let Some(end) = stripped.find('"') {
                    out.push((stripped[..end].to_string(), idx + 1));
                }
            }
        }
    }
    out
}

fn root_free_path(rel: &str) -> std::path::PathBuf {
    std::path::PathBuf::from(rel)
}

/// Parse `pub const ERR_NAME: &str = "code";` lines out of protocol.rs.
fn err_consts(protocol_src: &str) -> Vec<(String, String, usize)> {
    let mut out = Vec::new();
    for (idx, line) in protocol_src.lines().enumerate() {
        let t = line.trim();
        let Some(rest) = t.strip_prefix("pub const ERR_") else { continue };
        let Some(colon) = rest.find(':') else { continue };
        let name = format!("ERR_{}", rest[..colon].trim());
        let Some(q1) = rest.find('"') else { continue };
        let Some(q2) = rest[q1 + 1..].find('"') else { continue };
        out.push((name, rest[q1 + 1..q1 + 1 + q2].to_string(), idx + 1));
    }
    out
}

/// List `*.rs` files under `root/<dir>` (recursive, sorted), as
/// `/`-separated paths relative to `root`.
pub fn rs_files_under(root: &Path, dir: &str) -> Vec<String> {
    fn walk(base: &Path, cur: &Path, out: &mut Vec<String>) {
        let Ok(entries) = fs::read_dir(cur) else { return };
        let mut entries: Vec<_> = entries.flatten().map(|e| e.path()).collect();
        entries.sort();
        for p in entries {
            if p.is_dir() {
                walk(base, &p, out);
            } else if p.extension().is_some_and(|e| e == "rs") {
                if let Ok(rel) = p.strip_prefix(base) {
                    out.push(rel.to_string_lossy().replace('\\', "/"));
                }
            }
        }
    }
    let mut out = Vec::new();
    walk(root, &root.join(dir), &mut out);
    out
}

/// Run every cross-registry check against the tree at `root`.
pub fn check_registries(root: &Path) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    check_fault_registry(root, &mut diags);
    check_error_code_registry(root, &mut diags);
    check_bench_registry(root, &mut diags);
    diags
}

const FAULT_RS: &str = "rust/src/util/fault.rs";
const PROTOCOL_RS: &str = "rust/src/serve/protocol.rs";
const STATS_RS: &str = "rust/src/serve/stats.rs";
const CHAOS_RS: &str = "rust/tests/serve_chaos.rs";

fn check_fault_registry(root: &Path, diags: &mut Vec<Diagnostic>) {
    let Some(fault_src) = read(root, FAULT_RS) else {
        diags.push(diag(FAULT_RS, 1, "missing (fault-point registry lives here)".into()));
        return;
    };
    let table = doc_table_points(&fault_src);
    if table.is_empty() {
        diags.push(diag(
            FAULT_RS,
            1,
            "no fault-point doc table found (expected `//! | \\`point\\` | … |` rows)".into(),
        ));
    }

    // every call site anywhere in rust/src, except the registry itself
    let mut sites: Vec<(String, String, usize)> = Vec::new();
    for rel in rs_files_under(root, "rust/src") {
        if rel == FAULT_RS {
            continue;
        }
        if let Some(src) = read(root, &rel) {
            for (name, line) in fault_call_sites(&rel, &src) {
                sites.push((name, rel.clone(), line));
            }
        }
    }

    let chaos = read(root, CHAOS_RS).unwrap_or_default();
    for (point, line) in &table {
        if !sites.iter().any(|(n, _, _)| n == point) {
            diags.push(diag(
                FAULT_RS,
                *line,
                format!(
                    "fault point `{point}` is documented in the registry table but has no \
                     fault::point/failpoint call site under rust/src"
                ),
            ));
        }
        if !chaos.contains(point.as_str()) {
            diags.push(diag(
                FAULT_RS,
                *line,
                format!(
                    "fault point `{point}` has no coverage in {CHAOS_RS} — every registered \
                     point needs a chaos test exercising it"
                ),
            ));
        }
    }
    for (name, rel, line) in &sites {
        if !table.iter().any(|(p, _)| p == name) {
            diags.push(diag(
                rel,
                *line,
                format!(
                    "fault point `{name}` is armed here but missing from the registry table \
                     in {FAULT_RS} module docs"
                ),
            ));
        }
    }
}

fn check_error_code_registry(root: &Path, diags: &mut Vec<Diagnostic>) {
    let Some(protocol_src) = read(root, PROTOCOL_RS) else {
        diags.push(diag(PROTOCOL_RS, 1, "missing (error-code registry lives here)".into()));
        return;
    };
    let consts = err_consts(&protocol_src);
    if consts.is_empty() {
        diags.push(diag(
            PROTOCOL_RS,
            1,
            "no `pub const ERR_…: &str = \"…\";` constants found".into(),
        ));
    }
    let stats = read(root, STATS_RS).unwrap_or_default();
    let chaos = read(root, CHAOS_RS).unwrap_or_default();

    // where may a code be *used*? every serve module except its definition
    let serve_srcs: Vec<(String, String)> = rs_files_under(root, "rust/src/serve")
        .into_iter()
        .filter(|rel| rel != PROTOCOL_RS)
        .filter_map(|rel| read(root, &rel).map(|s| (rel, s)))
        .collect();

    for (name, code, line) in &consts {
        if !serve_srcs.iter().any(|(_, src)| src.contains(name.as_str())) {
            diags.push(diag(
                PROTOCOL_RS,
                *line,
                format!("error code {name} (\"{code}\") is defined but never used outside {PROTOCOL_RS}"),
            ));
        }
        if !chaos.contains(code.as_str()) {
            diags.push(diag(
                PROTOCOL_RS,
                *line,
                format!(
                    "error code \"{code}\" has no coverage in {CHAOS_RS} — every wire error \
                     needs a chaos test asserting a structural `!{code}` response"
                ),
            ));
        }
        match CODE_COUNTERS.iter().find(|(c, _)| c == code) {
            None => diags.push(diag(
                PROTOCOL_RS,
                *line,
                format!(
                    "error code \"{code}\" has no entry in sblint's CODE_COUNTERS map \
                     (rust/src/lint/registry.rs) — per DESIGN.md §7 a new failure mode \
                     ships an error code, a /stats counter, and a chaos test together; \
                     name its counter in the map"
                ),
            )),
            Some((_, counter)) => {
                let key = format!("\"{counter}\"");
                if !stats.contains(&key) {
                    diags.push(diag(
                        STATS_RS,
                        1,
                        format!(
                            "error code \"{code}\" maps to /stats counter \"{counter}\" \
                             but {STATS_RS} never emits that key"
                        ),
                    ));
                }
            }
        }
    }
}

fn check_bench_registry(root: &Path, diags: &mut Vec<Diagnostic>) {
    let Ok(entries) = fs::read_dir(root) else { return };
    let mut bench_jsons: Vec<String> = entries
        .flatten()
        .filter_map(|e| e.file_name().into_string().ok())
        .filter(|n| n.starts_with("BENCH_") && n.ends_with(".json"))
        .collect();
    bench_jsons.sort();

    for fname in bench_jsons {
        let Some(text) = read(root, &fname) else { continue };
        let parsed = match Json::parse(&text) {
            Ok(j) => j,
            Err(e) => {
                diags.push(diag(&fname, 1, format!("not parseable as JSON: {e:?}")));
                continue;
            }
        };
        let Some(obj) = parsed.as_obj() else {
            diags.push(diag(&fname, 1, "top level is not a JSON object".into()));
            continue;
        };
        let Some(schema) = obj.get("schema").and_then(|s| s.as_str()) else {
            diags.push(diag(&fname, 1, "missing \"schema\": \"<bench>/<version>\" tag".into()));
            continue;
        };
        let bench_name = schema.split('/').next().unwrap_or("");
        let bench_rel = format!("benches/{bench_name}.rs");
        let Some(bench_src) = read(root, &bench_rel) else {
            diags.push(diag(
                &fname,
                1,
                format!("schema \"{schema}\" names {bench_rel}, which does not exist"),
            ));
            continue;
        };
        if !bench_src.contains(&format!("\"{schema}\"")) {
            diags.push(diag(
                &bench_rel,
                1,
                format!(
                    "does not emit schema tag \"{schema}\" claimed by {fname} — bump both \
                     sides together when the trajectory format changes"
                ),
            ));
        }
        // tracked claims: top-level objects carrying a "metric" field
        for (key, val) in obj {
            let is_claim = val.as_obj().is_some_and(|o| o.contains_key("metric"));
            if is_claim && !bench_src.contains(&format!("\"{key}\"")) {
                diags.push(diag(
                    &bench_rel,
                    1,
                    format!(
                        "claim key \"{key}\" tracked in {fname} is never written by this \
                         bench — the regenerated trajectory would silently drop it"
                    ),
                ));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn doc_table_parsing_skips_header_and_separator() {
        let src = "//! | point | kind |\n//! |-------|------|\n//! | `a.b` | failpoint |\n";
        let pts = doc_table_points(src);
        assert_eq!(pts.len(), 1);
        assert_eq!(pts[0].0, "a.b");
        assert_eq!(pts[0].1, 3);
    }

    #[test]
    fn call_sites_ignore_comments_and_plain_strings() {
        let src = "// fault::point(\"doc.mention\")\nlet s = \"fault::failpoint(\";\nfault::failpoint(\"real.site\")?;\n";
        let sites = fault_call_sites("rust/src/x.rs", src);
        assert_eq!(sites.len(), 1, "{sites:?}");
        assert_eq!(sites[0], ("real.site".to_string(), 3));
    }

    #[test]
    fn err_const_parsing() {
        let src = "pub const ERR_TIMEOUT: &str = \"timeout\";\nconst OTHER: &str = \"x\";\n";
        let c = err_consts(src);
        assert_eq!(c, vec![("ERR_TIMEOUT".to_string(), "timeout".to_string(), 1)]);
    }
}
