//! The sblint rule catalog (R1–R4) and the `LINT-ALLOW` pragma grammar.
//!
//! Each rule is a named, individually suppressible invariant (see
//! DESIGN.md "Invariants as code" for the catalog and the procedure for
//! adding one). Suppression is always explicit and always carries a
//! reason:
//!
//! ```text
//! // LINT-ALLOW(<rule>): <reason>
//! ```
//!
//! A pragma on a code line suppresses that rule on that line; a pragma
//! on a comment-only line suppresses it on the next line that has code.
//! A malformed pragma (unknown shape, empty rule, missing reason) is
//! itself a diagnostic (`pragma`), so a typo'd suppression can never
//! silently disable a rule.

use crate::lint::scan::{has_token, Line, ScannedFile};

/// One lint finding. `line` is 1-based.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    pub rel_path: String,
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

impl Diagnostic {
    pub fn render(&self) -> String {
        format!("{}:{}: [{}] {}", self.rel_path, self.line, self.rule, self.message)
    }
}

/// Rule names (the pragma vocabulary).
pub const RULE_UNSAFE_SAFETY: &str = "unsafe-safety";
pub const RULE_DISJOINT: &str = "disjoint";
pub const RULE_DETERMINISM: &str = "determinism";
pub const RULE_SERVE_UNWRAP: &str = "serve-unwrap";
pub const RULE_REGISTRY: &str = "registry";
pub const RULE_PRAGMA: &str = "pragma";

/// Every rule a pragma may name.
pub const ALL_RULES: &[&str] = &[
    RULE_UNSAFE_SAFETY,
    RULE_DISJOINT,
    RULE_DETERMINISM,
    RULE_SERVE_UNWRAP,
    RULE_REGISTRY,
    RULE_PRAGMA,
];

/// Modules whose code must be a pure function of its inputs (R3): same
/// data + config ⇒ same bits, for any thread count, on any host.
const DETERMINISTIC_DIRS: &[&str] = &[
    "rust/src/engine/",
    "rust/src/tree/",
    "rust/src/sketch/",
    "rust/src/predict/",
    "rust/src/boosting/",
];

/// The serve request path (R4): files whose reader/writer/worker loops
/// must never abort the process on a per-request failure.
const SERVE_REQUEST_PATH: &[&str] = &[
    "rust/src/serve/protocol.rs",
    "rust/src/serve/queue.rs",
    "rust/src/serve/server.rs",
];

/// A parsed `LINT-ALLOW(rule): reason` pragma, anchored to the line it
/// suppresses.
#[derive(Debug)]
struct Allow {
    /// 0-based index of the line the pragma suppresses.
    target: usize,
    rule: String,
}

/// Extract pragmas (and malformed-pragma diagnostics) from a file.
fn collect_allows(file: &ScannedFile) -> (Vec<Allow>, Vec<Diagnostic>) {
    let mut allows = Vec::new();
    let mut diags = Vec::new();
    for (idx, line) in file.lines.iter().enumerate() {
        // a pragma must *start* the comment — prose that merely
        // mentions the LINT-ALLOW marker (like these docs) is not a
        // suppression attempt
        let trimmed = line.comment.trim_start();
        if !trimmed.starts_with("LINT-ALLOW") {
            continue;
        }
        let rest = &trimmed["LINT-ALLOW".len()..];
        let parsed = (|| -> Result<String, String> {
            let rest = rest
                .strip_prefix('(')
                .ok_or("expected `LINT-ALLOW(<rule>): <reason>`")?;
            let close = rest.find(')').ok_or("unclosed `(` in LINT-ALLOW")?;
            let rule = rest[..close].trim();
            if rule.is_empty() {
                return Err("empty rule name in LINT-ALLOW".to_string());
            }
            if !ALL_RULES.contains(&rule) {
                return Err(format!(
                    "unknown rule {rule:?} in LINT-ALLOW (known: {})",
                    ALL_RULES.join(", ")
                ));
            }
            let after = rest[close + 1..].trim_start();
            let reason = after.strip_prefix(':').map(str::trim).unwrap_or("");
            if reason.is_empty() {
                return Err(format!(
                    "LINT-ALLOW({rule}) needs a reason: `LINT-ALLOW({rule}): <why this is sound>`"
                ));
            }
            Ok(rule.to_string())
        })();
        match parsed {
            Err(msg) => diags.push(Diagnostic {
                rel_path: file.rel_path.clone(),
                line: idx + 1,
                rule: RULE_PRAGMA,
                message: msg.to_string(),
            }),
            Ok(rule) => {
                // a comment-only pragma line covers the next code line
                let target = if file.lines[idx].is_code_empty() {
                    file.lines[idx + 1..]
                        .iter()
                        .position(|l| !l.is_code_empty())
                        .map(|off| idx + 1 + off)
                        .unwrap_or(idx)
                } else {
                    idx
                };
                allows.push(Allow { target, rule });
            }
        }
    }
    (allows, diags)
}

fn allowed(allows: &[Allow], idx: usize, rule: &str) -> bool {
    allows.iter().any(|a| a.target == idx && a.rule == rule)
}

/// The comment context of line `idx`: its own trailing comment plus the
/// contiguous block of comment/attribute-only lines directly above it.
/// This is where `SAFETY:` / `DISJOINT:` / `# Safety` must live.
fn comment_context(lines: &[Line], idx: usize) -> String {
    let mut ctx = lines[idx].comment.clone();
    let mut j = idx;
    while j > 0 {
        j -= 1;
        let l = &lines[j];
        // a comment-only line continues the block even when its text is
        // empty (a bare `///` separator inside a rustdoc section); only
        // a genuinely blank line or code breaks it
        if l.is_code_empty() && !l.raw.trim().is_empty() {
            ctx.push('\n');
            ctx.push_str(&l.comment);
        } else if l.is_attr_only() {
            ctx.push('\n');
            ctx.push_str(&l.comment);
        } else {
            break;
        }
    }
    ctx
}

/// Run R1–R4 over one scanned file. (R5, the cross-registry check,
/// needs the whole tree — see [`crate::lint::registry`].)
pub fn check_file(file: &ScannedFile) -> Vec<Diagnostic> {
    let (allows, mut diags) = collect_allows(file);
    let is_deterministic_module =
        DETERMINISTIC_DIRS.iter().any(|d| file.rel_path.starts_with(d));
    let on_request_path = SERVE_REQUEST_PATH.contains(&file.rel_path.as_str());

    for (idx, line) in file.lines.iter().enumerate() {
        let code = line.code.as_str();
        if code.trim().is_empty() {
            continue;
        }
        let mut push = |rule: &'static str, message: String| {
            if !allowed(&allows, idx, rule) {
                diags.push(Diagnostic {
                    rel_path: file.rel_path.clone(),
                    line: idx + 1,
                    rule,
                    message,
                });
            }
        };

        // R1: every unsafe block/fn/impl carries its invariant.
        if has_token(code, "unsafe") {
            let ctx = comment_context(&file.lines, idx);
            if !ctx.contains("SAFETY:") && !ctx.contains("# Safety") {
                push(
                    RULE_UNSAFE_SAFETY,
                    "`unsafe` without a `// SAFETY:` comment (state the invariant that \
                     makes this sound; `# Safety` rustdoc sections also count)"
                        .to_string(),
                );
            }
        }

        // R2: range_mut call sites name their partition.
        if code.contains("range_mut(") && !code.contains("fn range_mut") {
            let ctx = comment_context(&file.lines, idx);
            if !ctx.contains("DISJOINT:") {
                push(
                    RULE_DISJOINT,
                    "`range_mut` call without a `// DISJOINT:` comment naming the \
                     partition that makes concurrent ranges disjoint"
                        .to_string(),
                );
            }
        }

        // R3: deterministic modules stay pure in their inputs.
        if is_deterministic_module && !line.in_test {
            for (needle, what) in [
                ("HashMap", "`HashMap` (iteration order is nondeterministic; use `BTreeMap` or a `Vec`)"),
                ("HashSet", "`HashSet` (iteration order is nondeterministic; use `BTreeSet` or a sorted `Vec`)"),
                ("Instant::now", "`Instant::now` (wall-clock reads)"),
                ("SystemTime", "`SystemTime` (wall-clock reads)"),
                ("std::env::", "`std::env` (environment reads)"),
                ("env::var", "`env::var` (environment reads)"),
            ] {
                if code.contains(needle) {
                    push(
                        RULE_DETERMINISM,
                        format!(
                            "{what} in a deterministic module — engine/, tree/, sketch/, \
                             predict/, boosting/ must be pure functions of their inputs \
                             (same data + config => same bits)"
                        ),
                    );
                    break; // one finding per line, even if needles overlap
                }
            }
        }

        // R4: the serve request path never aborts on a per-request error.
        if on_request_path && !line.in_test {
            for needle in [".unwrap()", ".expect("] {
                if code.contains(needle) {
                    push(
                        RULE_SERVE_UNWRAP,
                        format!(
                            "`{needle}` on the serve request path — return a structured \
                             `!internal` error or recover the lock with \
                             `unwrap_or_else(PoisonError::into_inner)`"
                        ),
                    );
                    break; // one finding per line
                }
            }
        }
    }
    diags
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::scan::scan_source;
    use std::path::PathBuf;

    fn check(rel: &str, src: &str) -> Vec<Diagnostic> {
        check_file(&scan_source(rel, PathBuf::from(rel), src))
    }

    #[test]
    fn pragma_on_comment_line_covers_next_code_line() {
        let src = "// LINT-ALLOW(serve-unwrap): provably non-poisoned\nlet x = m.lock().unwrap();\n";
        assert!(check("rust/src/serve/queue.rs", src).is_empty());
    }

    #[test]
    fn malformed_pragma_is_its_own_diagnostic() {
        let d = check("rust/src/serve/queue.rs", "// LINT-ALLOW(serve-unwrap) no colon\nf();\n");
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].rule, RULE_PRAGMA);
        let d = check("rust/src/x.rs", "// LINT-ALLOW(not-a-rule): whatever\n");
        assert_eq!(d.len(), 1, "{d:?}");
        assert!(d[0].message.contains("unknown rule"));
    }

    #[test]
    fn unsafe_accepts_rustdoc_safety_section() {
        let src = "/// Does things.\n///\n/// # Safety\n///\n/// Caller upholds X.\npub unsafe fn f() {}\n";
        assert!(check("rust/src/util/x.rs", src).is_empty());
    }

    #[test]
    fn determinism_rule_skips_test_mods_and_other_modules() {
        let in_test =
            "#[cfg(test)]\nmod tests {\n    fn t() { let _ = std::env::var(\"X\"); }\n}\n";
        assert!(check("rust/src/engine/x.rs", in_test).is_empty());
        let elsewhere = "fn f() { let _ = Instant::now(); }\n";
        assert!(check("rust/src/serve/x.rs", elsewhere).is_empty());
    }
}
