//! The ensemble compiled for batched inference, in one of three layouts.
//!
//! [`FlatForest`] is the serving-side twin of the training-side
//! [`Tree`]/[`Ensemble`] representation. [`FlatForest::compile`] picks a
//! [`ForestLayout`]:
//!
//! * **V1** — the original structure-of-arrays form: every tree's split
//!   nodes packed back-to-back into parallel arrays (feature /
//!   threshold / default / cat / left / right). Traversal touches small
//!   flat arrays instead of chasing 24-byte `TreeNode` structs.
//! * **V2Exact** — an interleaved, 16-byte cache-line-aligned node
//!   record ([`NodeRec`]): feature id + default/categorical flags packed
//!   into one `u32`, the f32 threshold bit-cast into a second, children
//!   in the remaining two. One record = one load; trees whose nodes are
//!   all numeric and all default-left additionally run a branch-free
//!   8-row micro-tiled walk. Output is **bitwise identical** to V1.
//! * **V2Quantized** — same record, but numeric thresholds are replaced
//!   by u16 *bin codes* over per-feature sorted distinct-threshold
//!   tables built from the forest itself, so the inner compare is an
//!   integer compare and each row's features quantize once per block
//!   instead of re-comparing floats per node. Because every node
//!   threshold is an entry of its feature's table, `x <= t` and
//!   `code(x) <= code(t)` are equivalent for *all* inputs — routing is
//!   exactly V1's. Leaf values optionally compress to f16-style u16
//!   (half precision); [`LayoutOptions::exact_leaves`] is the escape
//!   hatch that keeps f32 leaves and makes V2Quantized bitwise-exact
//!   too. [`FlatForest::leaf_quant_error`] reports the worst-case
//!   output error introduced by leaf compression (0.0 when exact).
//!
//! Routing semantics are *identical* to [`Tree::leaf_for_raw`] in every
//! layout: NaN routes by the split's learned `default_left`, categorical
//! splits by category-set membership ([`CatSet`]), numeric splits by
//! `x <= threshold`. `rust/tests/predict_equivalence.rs` and
//! `rust/tests/missing_categorical.rs` pin bitwise equality of the
//! layouts across sketches, depths, losses, thread counts, and
//! NaN-bearing/categorical inputs.

use crate::baselines::one_vs_all::OvaModel;
use crate::boosting::ensemble::Ensemble;
use crate::tree::tree::{CatSet, Tree};

/// Which node/leaf layout [`FlatForest::compile`] produces.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ForestLayout {
    /// Parallel SoA arrays (the original layout; the compatibility
    /// default everywhere).
    #[default]
    V1,
    /// Interleaved 16-byte node records, f32 thresholds. Bitwise
    /// identical to V1.
    V2Exact,
    /// Interleaved records with u16 bin-code thresholds (integer
    /// compares; routing still exact) and, unless
    /// [`LayoutOptions::exact_leaves`] is set, f16 leaf values.
    V2Quantized,
}

impl ForestLayout {
    /// Parse the CLI/config spelling: `v1`, `v2`, `v2q`.
    pub fn parse(s: &str) -> Result<ForestLayout, String> {
        match s {
            "v1" => Ok(ForestLayout::V1),
            "v2" => Ok(ForestLayout::V2Exact),
            "v2q" => Ok(ForestLayout::V2Quantized),
            other => Err(format!(
                "unknown forest layout {other:?} (expected \"v1\", \"v2\", or \"v2q\")"
            )),
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            ForestLayout::V1 => "v1",
            ForestLayout::V2Exact => "v2",
            ForestLayout::V2Quantized => "v2q",
        }
    }
}

/// Compile-time layout knobs for [`FlatForest::compile`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LayoutOptions {
    pub layout: ForestLayout,
    /// Only meaningful under [`ForestLayout::V2Quantized`]: keep leaf
    /// values in f32 (the exactness escape hatch — quantized thresholds
    /// route identically, so with exact leaves the whole output is
    /// bitwise-identical to V1).
    pub exact_leaves: bool,
}

impl LayoutOptions {
    pub fn v1() -> LayoutOptions {
        LayoutOptions::default()
    }

    pub fn v2_exact() -> LayoutOptions {
        LayoutOptions { layout: ForestLayout::V2Exact, exact_leaves: false }
    }

    pub fn v2_quantized() -> LayoutOptions {
        LayoutOptions { layout: ForestLayout::V2Quantized, exact_leaves: false }
    }

    pub fn with_layout(mut self, layout: ForestLayout) -> LayoutOptions {
        self.layout = layout;
        self
    }

    pub fn with_exact_leaves(mut self, exact: bool) -> LayoutOptions {
        self.exact_leaves = exact;
        self
    }
}

// --- f32 <-> IEEE binary16 bit conversion (no `f16` type at MSRV 1.70) --

/// Round-to-nearest-even f32 -> binary16 bits. Overflow saturates to
/// infinity; NaN stays NaN (payload truncated; the quiet bit is forced
/// only when truncation alone would collapse the NaN into an infinity).
pub(crate) fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp32 = ((bits >> 23) & 0xff) as i32;
    let man = bits & 0x007f_ffff;
    if exp32 == 0xff {
        // Inf / NaN: truncate the payload; only when truncation would
        // lose NaN-ness entirely (nonzero payload, top 10 bits all
        // zero) force the quiet bit. ORing the low bit unconditionally
        // corrupted payloads of genuine NaNs and broke Inf round-trips.
        let payload = (man >> 13) as u16;
        return sign | 0x7c00 | payload | ((u16::from(man != 0 && payload == 0)) << 9);
    }
    let exp = exp32 - 127 + 15; // rebias into binary16
    if exp >= 0x1f {
        return sign | 0x7c00; // overflow -> +/-Inf
    }
    if exp <= 0 {
        // subnormal (or zero) in binary16
        if exp < -10 {
            return sign; // underflows to +/-0 even after rounding
        }
        let man = man | 0x0080_0000; // make the implicit leading 1 explicit
        let shift = (14 - exp) as u32; // 14..=24
        let half = man >> shift;
        let rem = man & ((1u32 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let round_up = rem > halfway || (rem == halfway && (half & 1) == 1);
        return sign | (half + u32::from(round_up)) as u16;
    }
    let half = ((exp as u32) << 10) | (man >> 13);
    let rem = man & 0x1fff;
    let round_up = rem > 0x1000 || (rem == 0x1000 && (half & 1) == 1);
    // a mantissa carry may bump the exponent (correct: 0x3ff rounds to
    // the next power of two) and may carry into Inf (correct saturation)
    sign | (half + u32::from(round_up)) as u16
}

/// Exact binary16 bits -> f32 (every binary16 value is representable).
pub(crate) fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x03ff) as u32;
    let bits = if exp == 0x1f {
        sign | 0x7f80_0000 | (man << 13) // Inf / NaN
    } else if exp == 0 {
        if man == 0 {
            sign // +/-0
        } else {
            // subnormal: value = man * 2^-24; normalize for f32
            let p = 31 - man.leading_zeros(); // highest set bit, 0..=9
            let e = p + 103; // (p - 24) + 127
            sign | (e << 23) | ((man << (23 - p)) & 0x007f_ffff)
        }
    } else {
        sign | ((exp + 112) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

// --- layout v2 node record ---------------------------------------------

/// Bits 0..=29 of [`NodeRec::ffl`]: the split feature index.
const FEAT_MASK: u32 = (1 << 30) - 1;
/// [`NodeRec::ffl`] flag: categorical split (`key` indexes `cat_sets`).
const CAT_BIT: u32 = 1 << 30;
/// [`NodeRec::ffl`] flag: NaN routes left at this node.
const DEFAULT_LEFT_BIT: u32 = 1 << 31;

/// One interleaved split node: 16 bytes, 16-byte aligned, so a record
/// never straddles a cache line and traversal is one load per node.
#[derive(Clone, Copy, Debug)]
#[repr(C, align(16))]
struct NodeRec {
    /// feature index | [`CAT_BIT`] | [`DEFAULT_LEFT_BIT`]
    ffl: u32,
    /// Numeric nodes: f32 threshold bits (V2Exact) or the threshold's
    /// bin code, `<= u16::MAX` (V2Quantized). Categorical nodes: index
    /// into the pooled `cat_sets` (both variants).
    key: u32,
    /// Children keep the tree-local encoding: `>= 0` is a node index
    /// relative to the tree's first node, `< 0` encodes leaf `!child`.
    left: i32,
    right: i32,
}

/// Per-feature quantization tables for [`ForestLayout::V2Quantized`].
///
/// `edges` holds each feature's **sorted distinct split thresholds**
/// (taken from the forest itself — every trained threshold is a binned
/// edge, so this is the model's full resolution), concatenated;
/// `offsets[f]..offsets[f+1]` is feature `f`'s slice. Codes:
///
/// * `0` — missing (NaN); compares `<=` any node code, and node codes
///   start at 1, so a plain integer compare routes NaN left — exactly
///   what default-left trees need, and non-default-left nodes test for
///   0 explicitly.
/// * numeric feature: `code(x) = 1 + #{edges < x}`; a node with
///   threshold `t` stores `code(t)`, and `x <= t  <=>  code(x) <=
///   code(t)` for **all** finite `x` because `t` is itself an edge.
/// * categorical feature: integer id in `0..=255` codes as `id + 2`
///   (so 0 stays "missing" and 1 means "not a representable id" —
///   never a member, like V1's `contains_value` on such inputs).
#[derive(Clone, Debug)]
struct QuantMap {
    edges: Vec<f32>,
    /// len `n_features_required + 1`
    offsets: Vec<u32>,
    /// features that appear in categorical splits (coded by id)
    is_cat: Vec<bool>,
}

impl QuantMap {
    /// Build the per-feature code tables, or say why this forest cannot
    /// be quantized (a feature split both ways, or more distinct
    /// thresholds than u16 codes can index). `FlatForest::compile`
    /// treats an `Err` as "fall back to V2Exact", never a panic — any
    /// valid forest stays servable.
    fn try_build(soa: &SoaNodes, n_features: usize) -> Result<QuantMap, String> {
        let mut per: Vec<Vec<f32>> = vec![Vec::new(); n_features];
        let mut is_cat = vec![false; n_features];
        for i in 0..soa.feature.len() {
            let f = soa.feature[i] as usize;
            if soa.cat_idx[i] >= 0 {
                is_cat[f] = true;
            } else {
                per[f].push(soa.threshold[i]);
            }
        }
        let mut edges = Vec::new();
        let mut offsets = Vec::with_capacity(n_features + 1);
        offsets.push(0u32);
        for (f, mut ts) in per.into_iter().enumerate() {
            if is_cat[f] && !ts.is_empty() {
                return Err(format!(
                    "feature {f} is split both numerically and categorically; cannot quantize"
                ));
            }
            ts.sort_by(|a, b| a.partial_cmp(b).expect("split thresholds are finite"));
            ts.dedup();
            if ts.len() > u16::MAX as usize - 1 {
                return Err(format!(
                    "feature {f} has {} distinct thresholds; v2q codes cap at {}",
                    ts.len(),
                    u16::MAX - 1
                ));
            }
            edges.extend_from_slice(&ts);
            offsets.push(edges.len() as u32);
        }
        Ok(QuantMap { edges, offsets, is_cat })
    }

    #[inline]
    fn edges_of(&self, f: usize) -> &[f32] {
        &self.edges[self.offsets[f] as usize..self.offsets[f + 1] as usize]
    }

    /// Bin code of value `x` of feature `f` (see type docs).
    #[inline]
    fn code_of(&self, f: usize, x: f32) -> u16 {
        if x.is_nan() {
            return 0;
        }
        if self.is_cat[f] {
            let id = x as i64;
            if id >= 0 && id < 256 && id as f32 == x {
                (id + 2) as u16
            } else {
                1
            }
        } else {
            (1 + self.edges_of(f).partition_point(|&e| e < x)) as u16
        }
    }

    /// Code stored in a numeric node whose threshold is `t` (which is
    /// guaranteed to be one of feature `f`'s edges).
    fn code_of_threshold(&self, f: usize, t: f32) -> u32 {
        1 + self.edges_of(f).partition_point(|&e| e < t) as u32
    }

    /// Recover the f32 threshold a numeric node's code stands for (used
    /// by the per-row float walker, which has no quantized row).
    #[inline]
    fn threshold_of(&self, f: usize, code: u32) -> f32 {
        self.edges_of(f)[(code - 1) as usize]
    }

    /// Quantize a row-major block (`n_rows` rows of `width` features)
    /// into `codes`, same shape. Features beyond the tables (the model
    /// never splits on them) code as 0.
    fn quantize_tile(&self, tile: &[f32], width: usize, n_rows: usize, codes: &mut Vec<u16>) {
        codes.clear();
        codes.resize(n_rows * width, 0);
        let nf = self.offsets.len() - 1;
        for i in 0..n_rows {
            let row = &tile[i * width..(i + 1) * width];
            let dst = &mut codes[i * width..(i + 1) * width];
            for f in 0..width.min(nf) {
                dst[f] = self.code_of(f, row[f]);
            }
        }
    }
}

/// The original parallel-arrays node storage (layout V1, and the
/// intermediate every compile goes through).
#[derive(Clone, Debug, Default)]
struct SoaNodes {
    feature: Vec<u32>,
    threshold: Vec<f32>,
    /// where NaN routes at this node (1 = left)
    default_left: Vec<u8>,
    /// `>= 0`: index into `cat_sets` (categorical node); `-1`: numeric
    cat_idx: Vec<i32>,
    /// children keep the tree-local encoding: `>= 0` is a node index
    /// relative to the tree's first node, `< 0` encodes leaf `!child`.
    left: Vec<i32>,
    right: Vec<i32>,
}

#[derive(Clone, Debug)]
enum Nodes {
    V1(SoaNodes),
    V2 { recs: Vec<NodeRec> },
    V2Q { recs: Vec<NodeRec>, map: QuantMap },
}

/// Leaf-value storage: f32 (exact) or compressed binary16 bits.
#[derive(Clone, Debug)]
enum Leaves {
    Exact(Vec<f32>),
    Half(Vec<u16>),
}

impl Leaves {
    fn len(&self) -> usize {
        match self {
            Leaves::Exact(v) => v.len(),
            Leaves::Half(v) => v.len(),
        }
    }
}

/// Rows per micro-tile in the branch-free v2 walk: enough independent
/// traversal chains to hide load latency and feed auto-vectorization,
/// small enough that the cursor array lives in registers.
const LANES: usize = 8;

/// A tree ensemble compiled for batched inference (see module docs).
///
/// Supports both tree shapes the repo trains: the paper's single-tree
/// strategy (vector leaves of `n_outputs` values, added to the whole
/// output row) and the one-vs-all baseline (scalar leaves added to one
/// output column).
#[derive(Clone, Debug)]
pub struct FlatForest {
    pub n_outputs: usize,
    pub base_score: Vec<f32>,
    layout: ForestLayout,
    /// per-node storage, all trees packed back-to-back (layout-dependent)
    nodes: Nodes,
    /// pooled category sets referenced by categorical nodes (typically few)
    cat_sets: Vec<CatSet>,
    // --- per-tree offset tables (len n_trees + 1) ------------------------
    node_offset: Vec<u32>,
    value_offset: Vec<u32>,
    /// `-1` = vector leaf (`n_outputs` values per leaf); `j >= 0` =
    /// scalar leaf added into output column `j` (one-vs-all trees).
    out_col: Vec<i32>,
    /// all trees' leaf values, concatenated (`value_offset` indexes in)
    leaves: Leaves,
    /// per tree: non-empty, all-numeric, all-default-left — eligible
    /// for the branch-free micro-tiled walk (v2 layouts only)
    hot: Vec<bool>,
    /// 1 + the largest feature index any node references (0 if all
    /// trees are stumps); prediction validates input width against it
    n_features_required: usize,
    /// worst-case |exact - compressed| over any output cell introduced
    /// by f16 leaf compression (0.0 for exact leaves / V1 / V2Exact)
    leaf_quant_error: f32,
}

impl FlatForest {
    fn empty(n_outputs: usize, base_score: Vec<f32>) -> FlatForest {
        assert_eq!(base_score.len(), n_outputs, "base score width");
        FlatForest {
            n_outputs,
            base_score,
            layout: ForestLayout::V1,
            nodes: Nodes::V1(SoaNodes::default()),
            cat_sets: Vec::new(),
            node_offset: vec![0],
            value_offset: vec![0],
            out_col: Vec::new(),
            leaves: Leaves::Exact(Vec::new()),
            hot: Vec::new(),
            n_features_required: 0,
            leaf_quant_error: 0.0,
        }
    }

    fn reserve(&mut self, n_nodes: usize, n_values: usize, n_trees: usize) {
        if let Nodes::V1(soa) = &mut self.nodes {
            soa.feature.reserve(n_nodes);
            soa.threshold.reserve(n_nodes);
            soa.default_left.reserve(n_nodes);
            soa.cat_idx.reserve(n_nodes);
            soa.left.reserve(n_nodes);
            soa.right.reserve(n_nodes);
        }
        if let Leaves::Exact(vals) = &mut self.leaves {
            vals.reserve(n_values);
        }
        self.node_offset.reserve(n_trees);
        self.value_offset.reserve(n_trees);
        self.out_col.reserve(n_trees);
        self.hot.reserve(n_trees);
    }

    /// Append one tree. `out_col = None` for a vector-leaf tree (must
    /// have `tree.n_outputs == self.n_outputs`), `Some(j)` for a
    /// univariate tree whose scalar leaves add into output column `j`.
    fn push_tree(&mut self, tree: &Tree, out_col: Option<usize>) {
        match out_col {
            None => assert_eq!(tree.n_outputs, self.n_outputs, "vector tree width"),
            Some(j) => {
                assert_eq!(tree.n_outputs, 1, "one-vs-all trees are univariate");
                assert!(j < self.n_outputs, "output column {j} out of range");
            }
        }
        debug_assert!(tree.validate().is_ok());
        let soa = match &mut self.nodes {
            Nodes::V1(soa) => soa,
            _ => unreachable!("trees are appended before layout conversion"),
        };
        let mut hot = !tree.nodes.is_empty();
        for nd in &tree.nodes {
            soa.feature.push(nd.feature);
            soa.threshold.push(nd.threshold);
            soa.default_left.push(u8::from(nd.default_left));
            soa.cat_idx.push(match &nd.cats {
                Some(cats) => {
                    self.cat_sets.push(*cats);
                    (self.cat_sets.len() - 1) as i32
                }
                None => -1,
            });
            soa.left.push(nd.left);
            soa.right.push(nd.right);
            hot &= nd.cats.is_none() && nd.default_left;
            self.n_features_required = self.n_features_required.max(nd.feature as usize + 1);
        }
        match &mut self.leaves {
            Leaves::Exact(vals) => vals.extend_from_slice(&tree.leaf_values),
            Leaves::Half(_) => unreachable!("trees are appended before leaf compression"),
        }
        self.node_offset.push(soa.feature.len() as u32);
        self.value_offset.push(self.leaves.len() as u32);
        self.out_col.push(out_col.map_or(-1, |j| j as i32));
        self.hot.push(hot);
    }

    /// Compile a trained single-tree-strategy model in the requested
    /// layout.
    pub fn compile(model: &Ensemble, opts: LayoutOptions) -> FlatForest {
        let mut ff = FlatForest::empty(model.n_outputs, model.base_score.clone());
        ff.reserve(
            model.trees.iter().map(|t| t.nodes.len()).sum(),
            model.trees.iter().map(|t| t.leaf_values.len()).sum(),
            model.trees.len(),
        );
        for tree in &model.trees {
            ff.push_tree(tree, None);
        }
        ff.apply_layout(opts);
        ff
    }

    /// Compile a one-vs-all baseline model (univariate trees tagged with
    /// their output column) in the requested layout.
    pub fn compile_ova(model: &OvaModel, opts: LayoutOptions) -> FlatForest {
        let mut ff = FlatForest::empty(model.n_outputs, model.base_score.clone());
        ff.reserve(
            model.trees.iter().map(|(_, t)| t.nodes.len()).sum(),
            model.trees.iter().map(|(_, t)| t.leaf_values.len()).sum(),
            model.trees.len(),
        );
        for (j, tree) in &model.trees {
            ff.push_tree(tree, Some(*j as usize));
        }
        ff.apply_layout(opts);
        ff
    }

    /// [`FlatForest::compile`] with the compatibility default (V1).
    pub fn from_ensemble(model: &Ensemble) -> FlatForest {
        FlatForest::compile(model, LayoutOptions::default())
    }

    /// [`FlatForest::compile_ova`] with the compatibility default (V1).
    pub fn from_ova(model: &OvaModel) -> FlatForest {
        FlatForest::compile_ova(model, LayoutOptions::default())
    }

    /// Convert the freshly-built V1 arrays into the requested layout.
    fn apply_layout(&mut self, opts: LayoutOptions) {
        if opts.layout == ForestLayout::V1 {
            return;
        }
        let soa = match std::mem::replace(&mut self.nodes, Nodes::V1(SoaNodes::default())) {
            Nodes::V1(soa) => soa,
            _ => unreachable!("apply_layout runs once, on V1 arrays"),
        };
        let rec_of = |i: usize, key: u32| -> NodeRec {
            let f = soa.feature[i];
            assert!(f <= FEAT_MASK, "feature index {f} overflows the v2 node record");
            let mut ffl = f;
            if soa.cat_idx[i] >= 0 {
                ffl |= CAT_BIT;
            }
            if soa.default_left[i] != 0 {
                ffl |= DEFAULT_LEFT_BIT;
            }
            NodeRec { ffl, key, left: soa.left[i], right: soa.right[i] }
        };
        match opts.layout {
            ForestLayout::V2Exact => {
                let recs = (0..soa.feature.len())
                    .map(|i| {
                        let key = if soa.cat_idx[i] >= 0 {
                            soa.cat_idx[i] as u32
                        } else {
                            soa.threshold[i].to_bits()
                        };
                        rec_of(i, key)
                    })
                    .collect();
                self.nodes = Nodes::V2 { recs };
            }
            ForestLayout::V2Quantized => {
                match QuantMap::try_build(&soa, self.n_features_required) {
                    Ok(map) => {
                        let recs = (0..soa.feature.len())
                            .map(|i| {
                                let key = if soa.cat_idx[i] >= 0 {
                                    soa.cat_idx[i] as u32
                                } else {
                                    map.code_of_threshold(
                                        soa.feature[i] as usize,
                                        soa.threshold[i],
                                    )
                                };
                                rec_of(i, key)
                            })
                            .collect();
                        self.nodes = Nodes::V2Q { recs, map };
                        if !opts.exact_leaves {
                            self.compress_leaves();
                        }
                    }
                    Err(_why) => {
                        // unquantizable forest (e.g. > 65534 distinct
                        // thresholds on one feature): serve it in the
                        // exact interleaved layout instead of panicking
                        let recs = (0..soa.feature.len())
                            .map(|i| {
                                let key = if soa.cat_idx[i] >= 0 {
                                    soa.cat_idx[i] as u32
                                } else {
                                    soa.threshold[i].to_bits()
                                };
                                rec_of(i, key)
                            })
                            .collect();
                        self.nodes = Nodes::V2 { recs };
                        self.layout = ForestLayout::V2Exact;
                        return;
                    }
                }
            }
            ForestLayout::V1 => unreachable!(),
        }
        self.layout = opts.layout;
    }

    /// Replace f32 leaves with binary16 bits and record the worst-case
    /// per-cell output error: each row receives exactly one leaf per
    /// tree, so summing every tree's largest encode error bounds |Δ| of
    /// any output cell (up to f32 accumulation slop).
    fn compress_leaves(&mut self) {
        let exact = match &self.leaves {
            Leaves::Exact(vals) => vals,
            Leaves::Half(_) => return,
        };
        let mut half = Vec::with_capacity(exact.len());
        let mut bound = 0.0f32;
        for t in 0..self.n_trees() {
            let lo = self.value_offset[t] as usize;
            let hi = self.value_offset[t + 1] as usize;
            let mut worst = 0.0f32;
            for &v in &exact[lo..hi] {
                let h = f32_to_f16_bits(v);
                half.push(h);
                worst = worst.max((v - f16_bits_to_f32(h)).abs());
            }
            bound += worst;
        }
        self.leaves = Leaves::Half(half);
        self.leaf_quant_error = bound;
    }

    /// The layout this forest was compiled into.
    pub fn layout(&self) -> ForestLayout {
        self.layout
    }

    /// Worst-case absolute output error any cell can accrue from f16
    /// leaf compression; 0.0 for exact-leaf layouts. Thresholds never
    /// contribute: quantized routing is exact by construction.
    pub fn leaf_quant_error(&self) -> f32 {
        self.leaf_quant_error
    }

    pub fn n_trees(&self) -> usize {
        self.out_col.len()
    }

    pub fn n_nodes(&self) -> usize {
        *self.node_offset.last().unwrap() as usize
    }

    /// Minimum input feature width any prediction row must have
    /// (1 + the largest feature index referenced by any split node).
    pub fn n_features_required(&self) -> usize {
        self.n_features_required
    }

    /// Leaf index of `row` (row-major feature values) in tree `t` — the
    /// flat-array mirror of [`Tree::leaf_for_raw`]: NaN routes by the
    /// node's learned default, categorical nodes by set membership.
    /// Identical in every layout (V2Quantized recovers the f32
    /// threshold its code stands for).
    #[inline]
    pub fn leaf_of(&self, t: usize, row: &[f32]) -> usize {
        let base = self.node_offset[t] as usize;
        if base == self.node_offset[t + 1] as usize {
            return 0; // stump: single leaf
        }
        match &self.nodes {
            Nodes::V1(soa) => self.leaf_of_v1(soa, base, row),
            Nodes::V2 { recs } => self.leaf_of_v2(recs, base, row, None),
            Nodes::V2Q { recs, map } => self.leaf_of_v2(recs, base, row, Some(map)),
        }
    }

    fn leaf_of_v1(&self, soa: &SoaNodes, base: usize, row: &[f32]) -> usize {
        let mut child: i32 = 0; // tree-local node index
        loop {
            let i = base + child as usize;
            let x = row[soa.feature[i] as usize];
            let go_left = if x.is_nan() {
                soa.default_left[i] != 0
            } else {
                let ci = soa.cat_idx[i];
                if ci >= 0 {
                    self.cat_sets[ci as usize].contains_value(x)
                } else {
                    x <= soa.threshold[i]
                }
            };
            let next = if go_left { soa.left[i] } else { soa.right[i] };
            if next < 0 {
                return !next as usize;
            }
            child = next;
        }
    }

    fn leaf_of_v2(
        &self,
        recs: &[NodeRec],
        base: usize,
        row: &[f32],
        map: Option<&QuantMap>,
    ) -> usize {
        let mut child: i32 = 0;
        loop {
            let r = &recs[base + child as usize];
            let f = (r.ffl & FEAT_MASK) as usize;
            let x = row[f];
            let go_left = if x.is_nan() {
                r.ffl & DEFAULT_LEFT_BIT != 0
            } else if r.ffl & CAT_BIT != 0 {
                self.cat_sets[r.key as usize].contains_value(x)
            } else {
                let t = match map {
                    Some(m) => m.threshold_of(f, r.key),
                    None => f32::from_bits(r.key),
                };
                x <= t
            };
            let next = if go_left { r.left } else { r.right };
            if next < 0 {
                return !next as usize;
            }
            child = next;
        }
    }

    /// Quantized-row walker: same routing as [`FlatForest::leaf_of`],
    /// driven by pre-computed bin codes instead of floats.
    fn leaf_of_codes(&self, recs: &[NodeRec], base: usize, codes: &[u16]) -> usize {
        let mut child: i32 = 0;
        loop {
            let r = &recs[base + child as usize];
            let c = codes[(r.ffl & FEAT_MASK) as usize] as u32;
            let go_left = if c == 0 {
                r.ffl & DEFAULT_LEFT_BIT != 0
            } else if r.ffl & CAT_BIT != 0 {
                c >= 2 && self.cat_sets[r.key as usize].contains(c - 2)
            } else {
                c <= r.key
            };
            let next = if go_left { r.left } else { r.right };
            if next < 0 {
                return !next as usize;
            }
            child = next;
        }
    }

    /// Add every tree's leaf contribution for a row-major block into
    /// `out` (which the caller has already seeded with the base score).
    /// Per output cell, trees accumulate in ascending order in **every**
    /// layout — the determinism contract `predict_block_into` documents.
    pub(crate) fn accumulate_block(
        &self,
        tile: &[f32],
        width: usize,
        n_rows: usize,
        out: &mut [f32],
    ) {
        match &self.nodes {
            Nodes::V1(_) => {
                let d = self.n_outputs;
                for t in 0..self.n_trees() {
                    for i in 0..n_rows {
                        let leaf = self.leaf_of(t, &tile[i * width..(i + 1) * width]);
                        self.add_leaf(t, leaf, &mut out[i * d..(i + 1) * d]);
                    }
                }
            }
            Nodes::V2 { recs } => self.accumulate_v2(recs, None, tile, width, n_rows, out),
            Nodes::V2Q { recs, map } => with_code_scratch(|codes| {
                map.quantize_tile(tile, width, n_rows, codes);
                self.accumulate_v2(recs, Some((map, codes)), tile, width, n_rows, out);
            }),
        }
    }

    /// Layout-v2 block walk: tree-major like V1, but trees flagged
    /// `hot` (non-empty, all numeric, all default-left) route
    /// [`LANES`] rows at once through a branch-free cursor loop — the
    /// select compiles to `cmp`+`cmov`/blend and the 8 independent
    /// chains keep the load ports busy. `quant` carries the bin-code
    /// tile for V2Quantized; `None` means V2Exact (float compares).
    fn accumulate_v2(
        &self,
        recs: &[NodeRec],
        quant: Option<(&QuantMap, &[u16])>,
        tile: &[f32],
        width: usize,
        n_rows: usize,
        out: &mut [f32],
    ) {
        let d = self.n_outputs;
        for t in 0..self.n_trees() {
            let base = self.node_offset[t] as usize;
            if base == self.node_offset[t + 1] as usize {
                for i in 0..n_rows {
                    self.add_leaf(t, 0, &mut out[i * d..(i + 1) * d]);
                }
                continue;
            }
            if self.hot[t] {
                let mut i = 0;
                while i + LANES <= n_rows {
                    let mut cur = [0i32; LANES];
                    loop {
                        let mut live = false;
                        for (l, c) in cur.iter_mut().enumerate() {
                            let r = recs[base + c.max(0) as usize];
                            let f = (r.ffl & FEAT_MASK) as usize;
                            let go_right = match quant {
                                None => tile[(i + l) * width + f] > f32::from_bits(r.key),
                                Some((_, codes)) => {
                                    codes[(i + l) * width + f] as u32 > r.key
                                }
                            };
                            // NaN: `x > t` is false, and a bin code of 0
                            // is <= any node code — either way the row
                            // goes left, the hot tree's default.
                            let next = if go_right { r.right } else { r.left };
                            *c = if *c < 0 { *c } else { next };
                            live |= *c >= 0;
                        }
                        if !live {
                            break;
                        }
                    }
                    for (l, c) in cur.iter().enumerate() {
                        let row = i + l;
                        self.add_leaf(t, !*c as usize, &mut out[row * d..(row + 1) * d]);
                    }
                    i += LANES;
                }
                for i in i..n_rows {
                    let leaf = match quant {
                        None => {
                            self.leaf_of_v2(recs, base, &tile[i * width..(i + 1) * width], None)
                        }
                        Some((_, codes)) => {
                            self.leaf_of_codes(recs, base, &codes[i * width..(i + 1) * width])
                        }
                    };
                    self.add_leaf(t, leaf, &mut out[i * d..(i + 1) * d]);
                }
            } else {
                for i in 0..n_rows {
                    let leaf = match quant {
                        None => {
                            self.leaf_of_v2(recs, base, &tile[i * width..(i + 1) * width], None)
                        }
                        Some((_, codes)) => {
                            self.leaf_of_codes(recs, base, &codes[i * width..(i + 1) * width])
                        }
                    };
                    self.add_leaf(t, leaf, &mut out[i * d..(i + 1) * d]);
                }
            }
        }
    }

    /// Add tree `t`'s contribution for `leaf` into the output row
    /// (`out.len() == n_outputs`).
    #[inline]
    pub fn add_leaf(&self, t: usize, leaf: usize, out: &mut [f32]) {
        let vo = self.value_offset[t] as usize;
        let col = self.out_col[t];
        match &self.leaves {
            Leaves::Exact(vals) => {
                if col < 0 {
                    let d = self.n_outputs;
                    let v = &vals[vo + leaf * d..vo + (leaf + 1) * d];
                    for (o, &lv) in out.iter_mut().zip(v.iter()) {
                        *o += lv;
                    }
                } else {
                    out[col as usize] += vals[vo + leaf];
                }
            }
            Leaves::Half(vals) => {
                if col < 0 {
                    let d = self.n_outputs;
                    let v = &vals[vo + leaf * d..vo + (leaf + 1) * d];
                    for (o, &h) in out.iter_mut().zip(v.iter()) {
                        *o += f16_bits_to_f32(h);
                    }
                } else {
                    out[col as usize] += f16_bits_to_f32(vals[vo + leaf]);
                }
            }
        }
    }

    /// Number of leaves in tree `t`.
    pub fn n_leaves(&self, t: usize) -> usize {
        let values = (self.value_offset[t + 1] - self.value_offset[t]) as usize;
        let width = if self.out_col[t] < 0 { self.n_outputs } else { 1 };
        values / width
    }
}

/// Run `f` with this thread's reusable bin-code scratch buffer (the
/// quantized mirror of a block tile; one per worker thread, reused
/// across blocks so the hot loop never allocates).
fn with_code_scratch<R>(f: impl FnOnce(&mut Vec<u16>) -> R) -> R {
    thread_local! {
        static SCRATCH: std::cell::RefCell<Vec<u16>> = std::cell::RefCell::new(Vec::new());
    }
    SCRATCH.with(|s| f(&mut s.borrow_mut()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::boosting::ensemble::TrainHistory;
    use crate::boosting::losses::LossKind;
    use crate::tree::tree::{encode_leaf, TreeNode};

    fn all_layouts() -> [LayoutOptions; 4] {
        [
            LayoutOptions::v1(),
            LayoutOptions::v2_exact(),
            LayoutOptions::v2_quantized(),
            LayoutOptions::v2_quantized().with_exact_leaves(true),
        ]
    }

    /// x0 <= 0.5 ? leaf0 : (x1 <= 2.0 ? leaf1 : leaf2), d = 2; NaN at
    /// the root defaults left, at the inner node right
    fn toy_tree() -> Tree {
        Tree {
            n_outputs: 2,
            nodes: vec![
                TreeNode { feature: 0, bin: 3, threshold: 0.5, default_left: true, cats: None, left: encode_leaf(0), right: 1, gain: 1.0 },
                TreeNode { feature: 1, bin: 1, threshold: 2.0, default_left: false, cats: None, left: encode_leaf(1), right: encode_leaf(2), gain: 0.5 },
            ],
            leaf_values: vec![1.0, -1.0, 2.0, -2.0, 3.0, -3.0],
            n_leaves: 3,
        }
    }

    fn toy_model() -> Ensemble {
        Ensemble {
            loss: LossKind::MSE,
            n_outputs: 2,
            base_score: vec![0.25, -0.25],
            trees: vec![
                toy_tree(),
                Tree { n_outputs: 2, nodes: vec![], leaf_values: vec![0.5, 0.5], n_leaves: 1 },
            ],
            history: TrainHistory::default(),
        }
    }

    #[test]
    fn routing_matches_per_row_walker_in_every_layout() {
        let model = toy_model();
        for opts in all_layouts() {
            let ff = FlatForest::compile(&model, opts);
            assert_eq!(ff.layout(), opts.layout);
            assert_eq!(ff.n_trees(), 2);
            assert_eq!(ff.n_nodes(), 2);
            assert_eq!(ff.n_leaves(0), 3);
            assert_eq!(ff.n_leaves(1), 1);
            for row in [
                vec![0.0f32, 0.0],
                vec![1.0, 1.0],
                vec![1.0, 5.0],
                vec![0.5, 9.0],          // boundary goes left
                vec![f32::NAN, 9.0],     // NaN defaults left at the root
                vec![1.0, f32::NAN],     // NaN defaults right at the inner node
                vec![f32::NAN, f32::NAN],
            ] {
                for t in 0..2 {
                    assert_eq!(
                        ff.leaf_of(t, &row),
                        model.trees[t].leaf_for_raw(&row),
                        "row {row:?} tree {t} layout {:?}",
                        opts.layout
                    );
                }
            }
        }
    }

    #[test]
    fn tracks_required_feature_width() {
        let model = toy_model();
        let ff = FlatForest::from_ensemble(&model);
        assert_eq!(ff.layout(), ForestLayout::V1); // compatibility default
        assert_eq!(ff.n_features_required(), 2); // splits on f0 and f1
        let stump_only = Ensemble {
            trees: vec![Tree { n_outputs: 2, nodes: vec![], leaf_values: vec![0.0, 0.0], n_leaves: 1 }],
            ..model
        };
        assert_eq!(FlatForest::from_ensemble(&stump_only).n_features_required(), 0);
    }

    #[test]
    fn add_leaf_accumulates_vector_values() {
        let ff = FlatForest::from_ensemble(&toy_model());
        let mut out = vec![10.0f32, 20.0];
        ff.add_leaf(0, 2, &mut out); // leaf2 = [3, -3]
        assert_eq!(out, vec![13.0, 17.0]);
        ff.add_leaf(1, 0, &mut out); // stump leaf = [0.5, 0.5]
        assert_eq!(out, vec![13.5, 17.5]);
    }

    #[test]
    fn categorical_nodes_route_by_pooled_sets_in_every_layout() {
        use crate::tree::tree::CatSet;
        // tree 0: cat feature 0, ids {1, 3} left, missing right;
        // tree 1: numeric splits on f1/f2 (exercises the numeric path
        // next to a pooled set; distinct features keep f0 purely
        // categorical so the quantized layout accepts the model)
        let cat_tree = Tree {
            n_outputs: 2,
            nodes: vec![TreeNode {
                feature: 0,
                bin: 0,
                threshold: 0.0,
                default_left: false,
                cats: Some(CatSet::from_ids([1u32, 3])),
                left: encode_leaf(0),
                right: encode_leaf(1),
                gain: 1.0,
            }],
            leaf_values: vec![1.0, 1.0, -1.0, -1.0],
            n_leaves: 2,
        };
        let num_tree = Tree {
            n_outputs: 2,
            nodes: vec![
                TreeNode { feature: 1, bin: 3, threshold: 0.5, default_left: true, cats: None, left: encode_leaf(0), right: 1, gain: 1.0 },
                TreeNode { feature: 2, bin: 1, threshold: 2.0, default_left: false, cats: None, left: encode_leaf(1), right: encode_leaf(2), gain: 0.5 },
            ],
            leaf_values: vec![1.0, -1.0, 2.0, -2.0, 3.0, -3.0],
            n_leaves: 3,
        };
        let model = Ensemble {
            loss: LossKind::MSE,
            n_outputs: 2,
            base_score: vec![0.0, 0.0],
            trees: vec![cat_tree, num_tree],
            history: TrainHistory::default(),
        };
        for opts in all_layouts() {
            let ff = FlatForest::compile(&model, opts);
            for row in [
                vec![1.0f32, 0.0, 0.0],
                vec![3.0, 5.0, 5.0],
                vec![0.0, 1.0, 1.0],
                vec![2.5, 1.0, 3.0],          // non-integer: not a member -> right
                vec![9.0, 1.0, 2.0],          // unseen id -> right
                vec![255.0, 1.0, 2.0],        // edge of the id range
                vec![256.0, 1.0, 2.0],        // just past it -> right
                vec![-1.0, 1.0, 2.0],         // negative -> right
                vec![f32::NAN, 1.0, 2.0],     // missing -> default right
            ] {
                for t in 0..2 {
                    assert_eq!(
                        ff.leaf_of(t, &row),
                        model.trees[t].leaf_for_raw(&row),
                        "row {row:?} tree {t} layout {:?}",
                        opts.layout
                    );
                }
            }
        }
    }

    #[test]
    fn ova_trees_write_one_column_in_every_layout() {
        let uni = Tree {
            n_outputs: 1,
            nodes: vec![TreeNode {
                feature: 0,
                bin: 0,
                threshold: 0.0,
                default_left: true,
                cats: None,
                left: encode_leaf(0),
                right: encode_leaf(1),
                gain: 0.0,
            }],
            leaf_values: vec![-5.0, 5.0],
            n_leaves: 2,
        };
        let ova = OvaModel {
            loss: LossKind::MSE,
            n_outputs: 3,
            base_score: vec![0.0; 3],
            trees: vec![(2, uni.clone()), (0, uni)],
            history: TrainHistory::default(),
        };
        for opts in all_layouts() {
            let ff = FlatForest::compile_ova(&ova, opts);
            assert_eq!(ff.n_trees(), 2);
            assert_eq!(ff.n_leaves(0), 2);
            let mut out = vec![0.0f32; 3];
            ff.add_leaf(0, ff.leaf_of(0, &[1.0]), &mut out); // right leaf -> col 2
            ff.add_leaf(1, ff.leaf_of(1, &[-1.0]), &mut out); // left leaf -> col 0
            assert_eq!(out, vec![-5.0, 0.0, 5.0], "layout {:?}", opts.layout);
        }
    }

    #[test]
    #[should_panic]
    fn rejects_width_mismatch() {
        let mut ff = FlatForest::empty(3, vec![0.0; 3]);
        ff.push_tree(&toy_tree(), None); // d = 2 tree into d = 3 forest
    }

    #[test]
    fn layout_spellings_round_trip() {
        for l in [ForestLayout::V1, ForestLayout::V2Exact, ForestLayout::V2Quantized] {
            assert_eq!(ForestLayout::parse(l.as_str()), Ok(l));
        }
        assert!(ForestLayout::parse("v3").is_err());
        assert_eq!(ForestLayout::default(), ForestLayout::V1);
    }

    #[test]
    fn f16_round_trip_and_rounding() {
        // exactly representable values survive the round trip
        for v in [0.0f32, -0.0, 1.0, -2.5, 0.5, 65504.0, -65504.0, 6.103_515_6e-5] {
            let back = f16_bits_to_f32(f32_to_f16_bits(v));
            assert_eq!(back.to_bits(), v.to_bits(), "value {v}");
        }
        // signed zero keeps its sign
        assert!(f16_bits_to_f32(f32_to_f16_bits(-0.0)).is_sign_negative());
        // round-to-nearest-even at the half-ulp boundary: 1 + 2^-11 ties
        // to even (1.0); 1 + 3*2^-11 ties up to 1 + 2^-9... check both
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1.0 + 0.000_488_281_25)), 1.0);
        let up = f16_bits_to_f32(f32_to_f16_bits(1.0 + 3.0 * 0.000_488_281_25));
        assert_eq!(up, 1.0 + 2.0 * 0.000_976_562_5);
        // overflow saturates to infinity, NaN stays NaN
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1.0e6)), f32::INFINITY);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(-1.0e6)), f32::NEG_INFINITY);
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        // subnormal round trip: smallest positive binary16 value
        let tiny = f16_bits_to_f32(1);
        assert_eq!(tiny, 5.960_464_5e-8);
        assert_eq!(f32_to_f16_bits(tiny), 1);
        // encode error of a non-representable value is within half an ulp
        let v = 0.1f32;
        let err = (v - f16_bits_to_f32(f32_to_f16_bits(v))).abs();
        assert!(err > 0.0 && err <= 0.000_048_83, "err {err}");
    }

    #[test]
    fn f16_every_bit_pattern_round_trips() {
        // binary16 -> f32 is exact, so encoding back must reproduce the
        // original bits for all 65536 patterns — zeros, subnormals,
        // normals, infinities, and every NaN payload
        for h in 0..=u16::MAX {
            let back = f32_to_f16_bits(f16_bits_to_f32(h));
            assert_eq!(back, h, "bits {h:#06x}");
        }
    }

    #[test]
    fn f16_nan_payloads_and_infinities() {
        // infinities map to the canonical f16 infinities
        assert_eq!(f32_to_f16_bits(f32::INFINITY), 0x7c00);
        assert_eq!(f32_to_f16_bits(f32::NEG_INFINITY), 0xfc00);
        // a quiet NaN's payload truncates cleanly: the quiet bit (f32
        // mantissa bit 22) lands on f16 mantissa bit 9, nothing else set
        assert_eq!(f32_to_f16_bits(f32::from_bits(0x7fc0_0000)), 0x7e00);
        assert_eq!(f32_to_f16_bits(f32::from_bits(0xffc0_0000)), 0xfe00);
        // high payload bits survive the shift untouched
        assert_eq!(f32_to_f16_bits(f32::from_bits(0x7f80_4000)), 0x7c02);
        assert_eq!(f32_to_f16_bits(f32::from_bits(0x7fbf_e000)), 0x7dff);
        // a NaN whose payload lives only in the truncated low 13 bits
        // must stay NaN (quiet bit forced), not collapse into infinity
        let sig = f32_to_f16_bits(f32::from_bits(0x7f80_0001));
        assert_eq!(sig, 0x7e00);
        assert!(f16_bits_to_f32(sig).is_nan());
        assert_eq!(f32_to_f16_bits(f32::from_bits(0xff80_1fff)), 0xfe00);
    }

    #[test]
    fn unquantizable_forest_falls_back_to_exact_layout() {
        // 65535 distinct thresholds on one feature exceed the u16 code
        // space; compile must degrade to V2Exact, not panic, and the
        // served predictions stay bitwise-equal to V1
        let n = (u16::MAX as usize) + 1; // 65536 stumps, 65535+1 thresholds
        let trees: Vec<Tree> = (0..n)
            .map(|i| Tree {
                n_outputs: 1,
                nodes: vec![TreeNode {
                    feature: 0,
                    bin: 0,
                    threshold: i as f32,
                    default_left: i % 2 == 0,
                    cats: None,
                    left: encode_leaf(0),
                    right: encode_leaf(1),
                    gain: 1.0,
                }],
                leaf_values: vec![-1.0e-4, 1.0e-4],
                n_leaves: 2,
            })
            .collect();
        let model = Ensemble {
            loss: LossKind::MSE,
            n_outputs: 1,
            base_score: vec![0.0],
            trees,
            history: TrainHistory::default(),
        };
        let v2q = FlatForest::compile(&model, LayoutOptions::v2_quantized());
        assert_eq!(v2q.layout(), ForestLayout::V2Exact, "fallback layout");
        let v1 = FlatForest::compile(&model, LayoutOptions::v1());
        for row in [[-1.0f32], [0.0], [17.5], [65534.0], [1.0e9], [f32::NAN]] {
            for t in [0usize, 1, 17, n - 1] {
                assert_eq!(v2q.leaf_of(t, &row), v1.leaf_of(t, &row), "row {row:?} tree {t}");
            }
        }
    }

    #[test]
    fn quantized_codes_reproduce_threshold_compares() {
        // one feature, thresholds {-1.0, 0.5, 2.0}; codes must order
        // every probe exactly as the float compares do
        let model = Ensemble {
            loss: LossKind::MSE,
            n_outputs: 1,
            base_score: vec![0.0],
            trees: vec![Tree {
                n_outputs: 1,
                nodes: vec![
                    TreeNode { feature: 0, bin: 0, threshold: 0.5, default_left: true, cats: None, left: 1, right: 2, gain: 1.0 },
                    TreeNode { feature: 0, bin: 0, threshold: -1.0, default_left: true, cats: None, left: encode_leaf(0), right: encode_leaf(1), gain: 1.0 },
                    TreeNode { feature: 0, bin: 0, threshold: 2.0, default_left: false, cats: None, left: encode_leaf(2), right: encode_leaf(3), gain: 1.0 },
                ],
                leaf_values: vec![0.0, 1.0, 2.0, 3.0],
                n_leaves: 4,
            }],
            history: TrainHistory::default(),
        };
        let ff = FlatForest::compile(&model, LayoutOptions::v2_quantized());
        let map = match &ff.nodes {
            Nodes::V2Q { map, .. } => map,
            _ => unreachable!(),
        };
        assert_eq!(map.edges_of(0), &[-1.0, 0.5, 2.0]);
        // codes: (-inf,-1] -> 1, (-1,0.5] -> 2, (0.5,2] -> 3, (2,inf) -> 4
        for (x, want) in [
            (-5.0f32, 1u16), (-1.0, 1), (-0.999, 2), (0.5, 2),
            (0.500_01, 3), (2.0, 3), (2.000_1, 4), (f32::INFINITY, 4),
        ] {
            assert_eq!(map.code_of(0, x), want, "x = {x}");
        }
        assert_eq!(map.code_of(0, f32::NAN), 0);
        // node codes are the threshold ranks + 1
        assert_eq!(map.code_of_threshold(0, 0.5), 2);
        assert_eq!(map.threshold_of(0, 2), 0.5);
        // and routing agrees with the reference walker everywhere
        for x in [-5.0f32, -1.0, -0.5, 0.5, 0.6, 2.0, 3.0, f32::NAN] {
            assert_eq!(ff.leaf_of(0, &[x]), model.trees[0].leaf_for_raw(&[x]), "x = {x}");
        }
    }

    #[test]
    fn quantized_block_path_matches_per_row_walker() {
        // drive accumulate_block directly (the code-tile path) against
        // leaf_of (the float path) over a mixed default-left tree so
        // both the hot micro-tile and the scalar code walk run
        let model = toy_model();
        for opts in [LayoutOptions::v2_exact(), LayoutOptions::v2_quantized().with_exact_leaves(true)] {
            let ff = FlatForest::compile(&model, opts);
            let v1 = FlatForest::from_ensemble(&model);
            let n_rows = 13; // 8-lane group + 5-row remainder
            let width = 2;
            let mut tile = vec![0.0f32; n_rows * width];
            for i in 0..n_rows {
                tile[i * width] = (i as f32) * 0.31 - 1.5;
                tile[i * width + 1] = (i as f32) * 0.77 - 3.0;
            }
            tile[5 * width] = f32::NAN;
            tile[9 * width + 1] = f32::NAN;
            let mut got = vec![0.0f32; n_rows * 2];
            let mut want = vec![0.0f32; n_rows * 2];
            for row in got.chunks_mut(2) {
                row.copy_from_slice(&ff.base_score);
            }
            for row in want.chunks_mut(2) {
                row.copy_from_slice(&v1.base_score);
            }
            ff.accumulate_block(&tile, width, n_rows, &mut got);
            v1.accumulate_block(&tile, width, n_rows, &mut want);
            for (i, (a, b)) in want.iter().zip(&got).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "cell {i} layout {:?}", opts.layout);
            }
        }
    }

    #[test]
    fn leaf_quant_error_bounds_half_precision_leaves() {
        let model = toy_model();
        // exact layouts report zero error
        for opts in [LayoutOptions::v1(), LayoutOptions::v2_exact(), LayoutOptions::v2_quantized().with_exact_leaves(true)] {
            assert_eq!(FlatForest::compile(&model, opts).leaf_quant_error(), 0.0);
        }
        // half-precision leaves: toy values are all f16-representable,
        // so the bound is 0 and outputs stay exact
        let ff = FlatForest::compile(&model, LayoutOptions::v2_quantized());
        assert_eq!(ff.leaf_quant_error(), 0.0);
        // a non-representable leaf value yields a positive, honest bound
        let mut skewed = toy_model();
        skewed.trees[1].leaf_values = vec![0.100_000_024, -0.3];
        let ffq = FlatForest::compile(&skewed, LayoutOptions::v2_quantized());
        let bound = ffq.leaf_quant_error();
        assert!(bound > 0.0 && bound < 1.0e-3, "bound {bound}");
        let exact = FlatForest::compile(&skewed, LayoutOptions::v2_quantized().with_exact_leaves(true));
        let mut a = vec![0.0f32; 2];
        let mut b = vec![0.0f32; 2];
        a.copy_from_slice(&ffq.base_score);
        b.copy_from_slice(&exact.base_score);
        ffq.add_leaf(1, 0, &mut a);
        exact.add_leaf(1, 0, &mut b);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() <= bound, "delta {} bound {bound}", (x - y).abs());
        }
    }
}
