//! The ensemble compiled into structure-of-arrays form.
//!
//! [`FlatForest`] is the serving-side twin of the training-side
//! [`Tree`]/[`Ensemble`] representation: every tree's split nodes are
//! packed back-to-back into four parallel arrays (feature / threshold /
//! left / right), the leaf-value matrices are concatenated into one
//! contiguous buffer, and per-tree offset tables say where each tree's
//! nodes and values start. Traversal touches four small flat arrays
//! instead of chasing 24-byte `TreeNode` structs, and the layout is the
//! stepping stone to an XLA/GPU predict path (the same arrays upload as
//! device tensors).
//!
//! Routing semantics are *identical* to [`Tree::leaf_for_raw`]: NaN
//! routes by the split's learned `default_left`, categorical splits by
//! category-set membership ([`CatSet`]), numeric splits by `x <=
//! threshold`. `rust/tests/predict_equivalence.rs` and
//! `rust/tests/missing_categorical.rs` pin bitwise equality of the two
//! paths across sketches, depths, losses, thread counts, and
//! NaN-bearing/categorical inputs.

use crate::baselines::one_vs_all::OvaModel;
use crate::boosting::ensemble::Ensemble;
use crate::tree::tree::{CatSet, Tree};

/// A tree ensemble compiled for batched inference (see module docs).
///
/// Supports both tree shapes the repo trains: the paper's single-tree
/// strategy (vector leaves of `n_outputs` values, added to the whole
/// output row) and the one-vs-all baseline (scalar leaves added to one
/// output column).
#[derive(Clone, Debug)]
pub struct FlatForest {
    pub n_outputs: usize,
    pub base_score: Vec<f32>,
    // --- per-node SoA, all trees packed back-to-back ---------------------
    feature: Vec<u32>,
    threshold: Vec<f32>,
    /// where NaN routes at this node (1 = left)
    default_left: Vec<u8>,
    /// `>= 0`: index into `cat_sets` (categorical node); `-1`: numeric
    cat_idx: Vec<i32>,
    /// children keep the tree-local encoding: `>= 0` is a node index
    /// relative to the tree's first node, `< 0` encodes leaf `!child`.
    left: Vec<i32>,
    right: Vec<i32>,
    /// pooled category sets referenced by `cat_idx` (typically few)
    cat_sets: Vec<CatSet>,
    // --- per-tree offset tables (len n_trees + 1) ------------------------
    node_offset: Vec<u32>,
    value_offset: Vec<u32>,
    /// `-1` = vector leaf (`n_outputs` values per leaf); `j >= 0` =
    /// scalar leaf added into output column `j` (one-vs-all trees).
    out_col: Vec<i32>,
    /// all trees' leaf values, concatenated (`value_offset` indexes in)
    leaf_values: Vec<f32>,
    /// 1 + the largest feature index any node references (0 if all
    /// trees are stumps); prediction validates input width against it
    n_features_required: usize,
}

impl FlatForest {
    fn empty(n_outputs: usize, base_score: Vec<f32>) -> FlatForest {
        assert_eq!(base_score.len(), n_outputs, "base score width");
        FlatForest {
            n_outputs,
            base_score,
            feature: Vec::new(),
            threshold: Vec::new(),
            default_left: Vec::new(),
            cat_idx: Vec::new(),
            left: Vec::new(),
            right: Vec::new(),
            cat_sets: Vec::new(),
            node_offset: vec![0],
            value_offset: vec![0],
            out_col: Vec::new(),
            leaf_values: Vec::new(),
            n_features_required: 0,
        }
    }

    fn reserve(&mut self, n_nodes: usize, n_values: usize, n_trees: usize) {
        self.feature.reserve(n_nodes);
        self.threshold.reserve(n_nodes);
        self.default_left.reserve(n_nodes);
        self.cat_idx.reserve(n_nodes);
        self.left.reserve(n_nodes);
        self.right.reserve(n_nodes);
        self.leaf_values.reserve(n_values);
        self.node_offset.reserve(n_trees);
        self.value_offset.reserve(n_trees);
        self.out_col.reserve(n_trees);
    }

    /// Append one tree. `out_col = None` for a vector-leaf tree (must
    /// have `tree.n_outputs == self.n_outputs`), `Some(j)` for a
    /// univariate tree whose scalar leaves add into output column `j`.
    fn push_tree(&mut self, tree: &Tree, out_col: Option<usize>) {
        match out_col {
            None => assert_eq!(tree.n_outputs, self.n_outputs, "vector tree width"),
            Some(j) => {
                assert_eq!(tree.n_outputs, 1, "one-vs-all trees are univariate");
                assert!(j < self.n_outputs, "output column {j} out of range");
            }
        }
        debug_assert!(tree.validate().is_ok());
        for nd in &tree.nodes {
            self.feature.push(nd.feature);
            self.threshold.push(nd.threshold);
            self.default_left.push(u8::from(nd.default_left));
            self.cat_idx.push(match &nd.cats {
                Some(cats) => {
                    self.cat_sets.push(*cats);
                    (self.cat_sets.len() - 1) as i32
                }
                None => -1,
            });
            self.left.push(nd.left);
            self.right.push(nd.right);
            self.n_features_required = self.n_features_required.max(nd.feature as usize + 1);
        }
        self.leaf_values.extend_from_slice(&tree.leaf_values);
        self.node_offset.push(self.feature.len() as u32);
        self.value_offset.push(self.leaf_values.len() as u32);
        self.out_col.push(out_col.map_or(-1, |j| j as i32));
    }

    /// Compile a trained single-tree-strategy model.
    pub fn from_ensemble(model: &Ensemble) -> FlatForest {
        let mut ff = FlatForest::empty(model.n_outputs, model.base_score.clone());
        ff.reserve(
            model.trees.iter().map(|t| t.nodes.len()).sum(),
            model.trees.iter().map(|t| t.leaf_values.len()).sum(),
            model.trees.len(),
        );
        for tree in &model.trees {
            ff.push_tree(tree, None);
        }
        ff
    }

    /// Compile a one-vs-all baseline model (univariate trees tagged with
    /// their output column).
    pub fn from_ova(model: &OvaModel) -> FlatForest {
        let mut ff = FlatForest::empty(model.n_outputs, model.base_score.clone());
        ff.reserve(
            model.trees.iter().map(|(_, t)| t.nodes.len()).sum(),
            model.trees.iter().map(|(_, t)| t.leaf_values.len()).sum(),
            model.trees.len(),
        );
        for (j, tree) in &model.trees {
            ff.push_tree(tree, Some(*j as usize));
        }
        ff
    }

    pub fn n_trees(&self) -> usize {
        self.out_col.len()
    }

    pub fn n_nodes(&self) -> usize {
        self.feature.len()
    }

    /// Minimum input feature width any prediction row must have
    /// (1 + the largest feature index referenced by any split node).
    pub fn n_features_required(&self) -> usize {
        self.n_features_required
    }

    /// Leaf index of `row` (row-major feature values) in tree `t` — the
    /// flat-array mirror of [`Tree::leaf_for_raw`]: NaN routes by the
    /// node's learned default, categorical nodes by set membership.
    #[inline]
    pub fn leaf_of(&self, t: usize, row: &[f32]) -> usize {
        let base = self.node_offset[t] as usize;
        if base == self.node_offset[t + 1] as usize {
            return 0; // stump: single leaf
        }
        let mut child: i32 = 0; // tree-local node index
        loop {
            let i = base + child as usize;
            let x = row[self.feature[i] as usize];
            let go_left = if x.is_nan() {
                self.default_left[i] != 0
            } else {
                let ci = self.cat_idx[i];
                if ci >= 0 {
                    self.cat_sets[ci as usize].contains_value(x)
                } else {
                    x <= self.threshold[i]
                }
            };
            let next = if go_left { self.left[i] } else { self.right[i] };
            if next < 0 {
                return !next as usize;
            }
            child = next;
        }
    }

    /// Add tree `t`'s contribution for `leaf` into the output row
    /// (`out.len() == n_outputs`).
    #[inline]
    pub fn add_leaf(&self, t: usize, leaf: usize, out: &mut [f32]) {
        let vo = self.value_offset[t] as usize;
        let col = self.out_col[t];
        if col < 0 {
            let d = self.n_outputs;
            let v = &self.leaf_values[vo + leaf * d..vo + (leaf + 1) * d];
            for (o, &lv) in out.iter_mut().zip(v.iter()) {
                *o += lv;
            }
        } else {
            out[col as usize] += self.leaf_values[vo + leaf];
        }
    }

    /// Number of leaves in tree `t`.
    pub fn n_leaves(&self, t: usize) -> usize {
        let values = (self.value_offset[t + 1] - self.value_offset[t]) as usize;
        let width = if self.out_col[t] < 0 { self.n_outputs } else { 1 };
        values / width
    }
}

/// A hot-swappable handle to the forest being served.
///
/// Readers take an `Arc` snapshot and score against it for as long as
/// they like; [`SharedForest::swap`] flips the shared pointer to a new
/// forest without waiting for readers, so a swap can never tear a
/// snapshot mid-batch — a reader either holds the old forest entirely
/// or the new one entirely. The old forest is freed when its last
/// in-flight snapshot drops. A monotone version counter identifies
/// which model produced a given response (`serve` reports it under
/// `/stats`).
#[derive(Debug)]
pub struct SharedForest {
    current: std::sync::Mutex<std::sync::Arc<FlatForest>>,
    version: std::sync::atomic::AtomicU64,
}

impl SharedForest {
    /// Wrap `forest` as version 1.
    pub fn new(forest: FlatForest) -> SharedForest {
        SharedForest {
            current: std::sync::Mutex::new(std::sync::Arc::new(forest)),
            version: std::sync::atomic::AtomicU64::new(1),
        }
    }

    /// The forest to score the next batch against. The lock is held only
    /// long enough to clone the `Arc` (pointer-sized critical section).
    pub fn snapshot(&self) -> std::sync::Arc<FlatForest> {
        self.current.lock().unwrap().clone()
    }

    /// Version of the forest currently installed (starts at 1, bumps on
    /// every [`SharedForest::swap`]).
    pub fn version(&self) -> u64 {
        self.version.load(std::sync::atomic::Ordering::Acquire)
    }

    /// Install `forest` as the new current model and return its version.
    /// In-flight snapshots keep the old forest alive until they drop.
    pub fn swap(&self, forest: FlatForest) -> u64 {
        let mut cur = self.current.lock().unwrap();
        *cur = std::sync::Arc::new(forest);
        self.version.fetch_add(1, std::sync::atomic::Ordering::AcqRel) + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::boosting::ensemble::TrainHistory;
    use crate::boosting::losses::LossKind;
    use crate::tree::tree::{encode_leaf, TreeNode};

    /// x0 <= 0.5 ? leaf0 : (x1 <= 2.0 ? leaf1 : leaf2), d = 2; NaN at
    /// the root defaults left, at the inner node right
    fn toy_tree() -> Tree {
        Tree {
            n_outputs: 2,
            nodes: vec![
                TreeNode { feature: 0, bin: 3, threshold: 0.5, default_left: true, cats: None, left: encode_leaf(0), right: 1, gain: 1.0 },
                TreeNode { feature: 1, bin: 1, threshold: 2.0, default_left: false, cats: None, left: encode_leaf(1), right: encode_leaf(2), gain: 0.5 },
            ],
            leaf_values: vec![1.0, -1.0, 2.0, -2.0, 3.0, -3.0],
            n_leaves: 3,
        }
    }

    fn toy_model() -> Ensemble {
        Ensemble {
            loss: LossKind::MSE,
            n_outputs: 2,
            base_score: vec![0.25, -0.25],
            trees: vec![
                toy_tree(),
                Tree { n_outputs: 2, nodes: vec![], leaf_values: vec![0.5, 0.5], n_leaves: 1 },
            ],
            history: TrainHistory::default(),
        }
    }

    #[test]
    fn routing_matches_per_row_walker() {
        let model = toy_model();
        let ff = FlatForest::from_ensemble(&model);
        assert_eq!(ff.n_trees(), 2);
        assert_eq!(ff.n_nodes(), 2);
        assert_eq!(ff.n_leaves(0), 3);
        assert_eq!(ff.n_leaves(1), 1);
        for row in [
            vec![0.0f32, 0.0],
            vec![1.0, 1.0],
            vec![1.0, 5.0],
            vec![0.5, 9.0],          // boundary goes left
            vec![f32::NAN, 9.0],     // NaN defaults left at the root
            vec![1.0, f32::NAN],     // NaN defaults right at the inner node
            vec![f32::NAN, f32::NAN],
        ] {
            for t in 0..2 {
                assert_eq!(
                    ff.leaf_of(t, &row),
                    model.trees[t].leaf_for_raw(&row),
                    "row {row:?} tree {t}"
                );
            }
        }
    }

    #[test]
    fn tracks_required_feature_width() {
        let model = toy_model();
        let ff = FlatForest::from_ensemble(&model);
        assert_eq!(ff.n_features_required(), 2); // splits on f0 and f1
        let stump_only = Ensemble {
            trees: vec![Tree { n_outputs: 2, nodes: vec![], leaf_values: vec![0.0, 0.0], n_leaves: 1 }],
            ..model
        };
        assert_eq!(FlatForest::from_ensemble(&stump_only).n_features_required(), 0);
    }

    #[test]
    fn add_leaf_accumulates_vector_values() {
        let ff = FlatForest::from_ensemble(&toy_model());
        let mut out = vec![10.0f32, 20.0];
        ff.add_leaf(0, 2, &mut out); // leaf2 = [3, -3]
        assert_eq!(out, vec![13.0, 17.0]);
        ff.add_leaf(1, 0, &mut out); // stump leaf = [0.5, 0.5]
        assert_eq!(out, vec![13.5, 17.5]);
    }

    #[test]
    fn categorical_nodes_route_by_pooled_sets() {
        use crate::tree::tree::CatSet;
        // tree 0: cat feature 0, ids {1, 3} left, missing right;
        // tree 1: numeric (exercises the -1 cat_idx path next to a pooled set)
        let cat_tree = Tree {
            n_outputs: 2,
            nodes: vec![TreeNode {
                feature: 0,
                bin: 0,
                threshold: 0.0,
                default_left: false,
                cats: Some(CatSet::from_ids([1u32, 3])),
                left: encode_leaf(0),
                right: encode_leaf(1),
                gain: 1.0,
            }],
            leaf_values: vec![1.0, 1.0, -1.0, -1.0],
            n_leaves: 2,
        };
        let model = Ensemble {
            loss: LossKind::MSE,
            n_outputs: 2,
            base_score: vec![0.0, 0.0],
            trees: vec![cat_tree, toy_tree()],
            history: TrainHistory::default(),
        };
        let ff = FlatForest::from_ensemble(&model);
        for row in [
            vec![1.0f32, 0.0],
            vec![3.0, 5.0],
            vec![0.0, 1.0],
            vec![2.5, 1.0],          // non-integer: not a member -> right
            vec![9.0, 1.0],          // unseen id -> right
            vec![f32::NAN, 1.0],     // missing -> default right
        ] {
            for t in 0..2 {
                assert_eq!(
                    ff.leaf_of(t, &row),
                    model.trees[t].leaf_for_raw(&row),
                    "row {row:?} tree {t}"
                );
            }
        }
    }

    #[test]
    fn ova_trees_write_one_column() {
        let uni = Tree {
            n_outputs: 1,
            nodes: vec![TreeNode {
                feature: 0,
                bin: 0,
                threshold: 0.0,
                default_left: true,
                cats: None,
                left: encode_leaf(0),
                right: encode_leaf(1),
                gain: 0.0,
            }],
            leaf_values: vec![-5.0, 5.0],
            n_leaves: 2,
        };
        let ova = OvaModel {
            loss: LossKind::MSE,
            n_outputs: 3,
            base_score: vec![0.0; 3],
            trees: vec![(2, uni.clone()), (0, uni)],
            history: TrainHistory::default(),
        };
        let ff = FlatForest::from_ova(&ova);
        assert_eq!(ff.n_trees(), 2);
        assert_eq!(ff.n_leaves(0), 2);
        let mut out = vec![0.0f32; 3];
        ff.add_leaf(0, ff.leaf_of(0, &[1.0]), &mut out); // right leaf -> col 2
        ff.add_leaf(1, ff.leaf_of(1, &[-1.0]), &mut out); // left leaf -> col 0
        assert_eq!(out, vec![-5.0, 0.0, 5.0]);
    }

    #[test]
    #[should_panic]
    fn rejects_width_mismatch() {
        let mut ff = FlatForest::empty(3, vec![0.0; 3]);
        ff.push_tree(&toy_tree(), None); // d = 2 tree into d = 3 forest
    }

    #[test]
    fn shared_forest_swaps_without_tearing_snapshots() {
        let shared = SharedForest::new(FlatForest::from_ensemble(&toy_model()));
        assert_eq!(shared.version(), 1);
        let old = shared.snapshot();
        let stump_only = Ensemble {
            trees: vec![Tree { n_outputs: 2, nodes: vec![], leaf_values: vec![9.0, 9.0], n_leaves: 1 }],
            ..toy_model()
        };
        assert_eq!(shared.swap(FlatForest::from_ensemble(&stump_only)), 2);
        assert_eq!(shared.version(), 2);
        // the pre-swap snapshot still scores with the old trees
        assert_eq!(old.n_trees(), 2);
        let fresh = shared.snapshot();
        assert_eq!(fresh.n_trees(), 1);
        let mut out = vec![0.0f32; 2];
        fresh.add_leaf(0, 0, &mut out);
        assert_eq!(out, vec![9.0, 9.0]);
    }
}
