//! Blocked, parallel batch prediction over a [`FlatForest`].
//!
//! The driver splits the input rows into cache-sized **blocks** and, for
//! each block: gathers the block from the column-major [`Dataset`] into
//! a row-major scratch tile, seeds the output rows with the base score,
//! then drives the *whole block* through each tree in turn — so one
//! tree's node arrays stay hot in cache across all rows of the block
//! before the next tree is touched (the batch-traversal layout of
//! Mitchell et al.'s GPU predictor, on CPU).
//!
//! ## Determinism contract
//!
//! Parallelism is over row blocks only. Block boundaries are a pure
//! function of `(n_rows, block_rows)` (an atomic cursor advanced in
//! `block_rows` steps from 0), each block writes a disjoint output
//! range, and within a row every output cell accumulates its trees in
//! ascending tree order — exactly the order the per-row reference
//! walker uses. Results are therefore **bit-identical** to the naive
//! walker for every thread count and block size
//! (`rust/tests/predict_equivalence.rs` enforces this).

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::data::dataset::Dataset;
use crate::predict::flat::{FlatForest, ForestLayout, LayoutOptions};
use crate::util::threading::{DisjointSlice, ThreadPool};

/// Default rows per block: with the default feature widths a block tile
/// stays ~64–128 KiB, inside L2, while amortizing the per-block gather.
pub const DEFAULT_BLOCK_ROWS: usize = 512;

/// Knobs for batched prediction (a builder: chain the `with_*` methods).
#[derive(Clone, Copy, Debug)]
pub struct PredictOptions {
    /// Worker threads over row blocks; `0` = all cores. Bit-identical
    /// output for every value (see module docs).
    pub n_threads: usize,
    /// Rows per block (the unit of work-stealing and cache blocking).
    pub block_rows: usize,
    /// Node/leaf layout the forest compiles into (see [`ForestLayout`]).
    /// Consumed at compile time by [`Predictor`](crate::predict::Predictor)
    /// and the serve daemon; ignored by an already-compiled forest.
    pub layout: LayoutOptions,
}

impl Default for PredictOptions {
    fn default() -> Self {
        PredictOptions {
            n_threads: 1,
            block_rows: DEFAULT_BLOCK_ROWS,
            layout: LayoutOptions::default(),
        }
    }
}

impl PredictOptions {
    /// Default blocking with an explicit thread count.
    pub fn threads(n_threads: usize) -> PredictOptions {
        PredictOptions { n_threads, ..PredictOptions::default() }
    }

    pub fn with_threads(mut self, n_threads: usize) -> PredictOptions {
        self.n_threads = n_threads;
        self
    }

    pub fn with_block_rows(mut self, block_rows: usize) -> PredictOptions {
        self.block_rows = block_rows;
        self
    }

    pub fn with_layout(mut self, layout: ForestLayout) -> PredictOptions {
        self.layout.layout = layout;
        self
    }

    /// Keep f32 leaf values under [`ForestLayout::V2Quantized`] (the
    /// bitwise-exactness escape hatch; no effect on other layouts).
    pub fn with_exact_leaves(mut self, exact: bool) -> PredictOptions {
        self.layout.exact_leaves = exact;
        self
    }
}

impl FlatForest {
    /// The one block driver every batched output shares: validate input
    /// width, split `0..n_rows` into `block_rows`-sized blocks via an
    /// atomic cursor, gather each block into a row-major tile, and hand
    /// `(tile, rows_in_block, dst)` to `per_block`, where `dst` is the
    /// block's disjoint `width`-wide output range.
    ///
    /// All of the disjointness reasoning lives here, once: block starts
    /// are distinct multiples of `block_rows`, so the row ranges — and
    /// therefore the `out` ranges handed to `per_block` — are pairwise
    /// disjoint across workers, which is exactly what
    /// [`DisjointSlice::range_mut`] requires.
    fn for_each_block<T, F>(
        &self,
        ds: &Dataset,
        opts: &PredictOptions,
        width: usize,
        out: &mut [T],
        per_block: F,
    ) where
        T: Send,
        F: Fn(&[f32], usize, &mut [T]) + Sync,
    {
        let n = ds.n_rows;
        assert_eq!(out.len(), n * width, "output buffer size");
        assert!(
            ds.n_features >= self.n_features_required(),
            "dataset has {} features but the model splits on feature index {}",
            ds.n_features,
            self.n_features_required().saturating_sub(1),
        );
        if n == 0 || width == 0 {
            return;
        }
        let m = ds.n_features;
        let block = opts.block_rows.max(1);
        let pool = ThreadPool::new(opts.n_threads);
        let out_cells = DisjointSlice::new(out);
        let cursor = AtomicUsize::new(0);
        pool.broadcast(|_worker| {
            let mut tile = vec![0.0f32; block * m];
            loop {
                let start = cursor.fetch_add(block, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                let end = (start + block).min(n);
                gather_block(ds, start, end, &mut tile);
                // SAFETY: `end <= n` and `out` holds `n * width` cells,
                // so the range is in bounds.
                // DISJOINT: partitioned by row block — the atomic cursor
                // hands each `[start, end)` block to exactly one worker.
                let dst = unsafe { out_cells.range_mut(start * width..end * width) };
                per_block(&tile, end - start, dst);
            }
        });
    }

    /// Score one row-major block in place: `out[i]` = base score plus the
    /// leaf values of row `i`, trees accumulated in ascending order — the
    /// exact per-row recipe of the naive walker, so every caller that
    /// feeds rows through this function (the offline driver below, the
    /// serving workers in `serve::server`) produces bit-identical scores
    /// regardless of how rows were grouped into blocks.
    ///
    /// `tile` holds `n_rows` rows of `width` features each, row-major;
    /// `width` must cover every feature the forest splits on. Re-entrant:
    /// takes `&self` and only caller-owned buffers, so any number of
    /// threads may score disjoint blocks of one shared forest at once.
    pub fn predict_block_into(
        &self,
        tile: &[f32],
        width: usize,
        n_rows: usize,
        out: &mut [f32],
    ) {
        let d = self.n_outputs;
        assert!(
            width >= self.n_features_required(),
            "block is {} features wide but the model splits on feature index {}",
            width,
            self.n_features_required().saturating_sub(1),
        );
        assert!(tile.len() >= n_rows * width, "tile holds fewer than n_rows rows");
        assert_eq!(out.len(), n_rows * d, "output buffer size");
        if n_rows == 0 || d == 0 {
            return;
        }
        for row in out.chunks_mut(d) {
            row.copy_from_slice(&self.base_score);
        }
        // layout-dispatched inner loop (flat.rs): V1 walks the SoA
        // arrays per row, V2 layouts run the tree-major record walk
        // with the 8-row micro-tile on hot trees — all three accumulate
        // trees in ascending order per cell, preserving the contract.
        self.accumulate_block(tile, width, n_rows, out);
    }

    /// Raw scores, row-major `[n_rows, n_outputs]`, written into `out`.
    pub fn predict_raw_into(&self, ds: &Dataset, opts: &PredictOptions, out: &mut [f32]) {
        let d = self.n_outputs;
        let m = ds.n_features;
        self.for_each_block(ds, opts, d, out, |tile, rows, dst| {
            self.predict_block_into(&tile[..rows * m], m, rows, dst);
        });
    }

    /// Raw scores, row-major `[n_rows, n_outputs]`.
    pub fn predict_raw(&self, ds: &Dataset, opts: &PredictOptions) -> Vec<f32> {
        let mut out = vec![0.0f32; ds.n_rows * self.n_outputs];
        self.predict_raw_into(ds, opts, &mut out);
        out
    }

    /// Leaf index of every row in every tree, row-major
    /// `[n_rows, n_trees]` — the batched "apply" output.
    pub fn predict_leaf_indices(&self, ds: &Dataset, opts: &PredictOptions) -> Vec<u32> {
        let nt = self.n_trees();
        let m = ds.n_features;
        let mut out = vec![0u32; ds.n_rows * nt];
        self.for_each_block(ds, opts, nt, &mut out, |tile, rows, dst| {
            for t in 0..nt {
                for i in 0..rows {
                    dst[i * nt + t] = self.leaf_of(t, &tile[i * m..(i + 1) * m]) as u32;
                }
            }
        });
        out
    }
}

/// Gather rows `start..end` of the column-major dataset into the
/// row-major `tile` (`tile[i * m + f]` = feature `f` of row `start + i`).
#[inline]
fn gather_block(ds: &Dataset, start: usize, end: usize, tile: &mut [f32]) {
    let m = ds.n_features;
    for f in 0..m {
        let col = &ds.column(f)[start..end];
        for (i, &v) in col.iter().enumerate() {
            tile[i * m + f] = v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Targets;

    /// Tiny dataset with adversarial block edges: 23 rows, 3 features.
    fn toy_ds() -> Dataset {
        let n = 23usize;
        let mut cols = vec![0.0f32; n * 3];
        for f in 0..3 {
            for i in 0..n {
                cols[f * n + i] = (i as f32) * 0.37 - (f as f32) * 1.1;
            }
        }
        cols[5] = f32::NAN; // feature 0, row 5
        Dataset::new(n, 3, cols, Targets::Regression { values: vec![0.0; n * 2], n_targets: 2 })
    }

    fn toy_forest() -> (crate::boosting::ensemble::Ensemble, FlatForest) {
        use crate::boosting::ensemble::{Ensemble, TrainHistory};
        use crate::boosting::losses::LossKind;
        use crate::tree::tree::{encode_leaf, Tree, TreeNode};
        let t0 = Tree {
            n_outputs: 2,
            nodes: vec![
                TreeNode { feature: 0, bin: 0, threshold: 2.0, default_left: false, cats: None, left: encode_leaf(0), right: 1, gain: 1.0 },
                TreeNode { feature: 2, bin: 0, threshold: 1.5, default_left: true, cats: None, left: encode_leaf(1), right: encode_leaf(2), gain: 0.4 },
            ],
            leaf_values: vec![0.1, -0.1, 0.2, -0.2, 0.3, -0.3],
            n_leaves: 3,
        };
        let t1 = Tree {
            n_outputs: 2,
            nodes: vec![TreeNode {
                feature: 1,
                bin: 0,
                threshold: 0.0,
                default_left: true,
                cats: None,
                left: encode_leaf(0),
                right: encode_leaf(1),
                gain: 0.2,
            }],
            leaf_values: vec![-1.0, 1.0, 1.0, -1.0],
            n_leaves: 2,
        };
        let model = Ensemble {
            loss: LossKind::MSE,
            n_outputs: 2,
            base_score: vec![0.5, -0.5],
            trees: vec![t0, t1],
            history: TrainHistory::default(),
        };
        let ff = FlatForest::from_ensemble(&model);
        (model, ff)
    }

    /// Per-row reference: base score + trees in order, one row at a time.
    fn reference(model: &crate::boosting::ensemble::Ensemble, ds: &Dataset) -> Vec<f32> {
        let d = model.n_outputs;
        let mut out = vec![0.0f32; ds.n_rows * d];
        for i in 0..ds.n_rows {
            let row = ds.row(i);
            let o = &mut out[i * d..(i + 1) * d];
            o.copy_from_slice(&model.base_score);
            for t in &model.trees {
                t.predict_into(&row, o);
            }
        }
        out
    }

    #[test]
    fn blocked_matches_reference_for_ragged_blocks_and_threads() {
        let ds = toy_ds();
        let (model, ff) = toy_forest();
        let want = reference(&model, &ds);
        for threads in [1usize, 2, 4] {
            for block in [1usize, 4, 7, 23, 64] {
                let got = ff.predict_raw(
                    &ds,
                    &PredictOptions::threads(threads).with_block_rows(block),
                );
                assert_eq!(got, want, "threads={threads} block={block}");
            }
        }
    }

    #[test]
    fn leaf_indices_match_per_row_walker() {
        let ds = toy_ds();
        let (model, ff) = toy_forest();
        let got = ff.predict_leaf_indices(&ds, &PredictOptions::threads(2).with_block_rows(5));
        assert_eq!(got.len(), ds.n_rows * 2);
        for i in 0..ds.n_rows {
            let row = ds.row(i);
            for (t, tree) in model.trees.iter().enumerate() {
                assert_eq!(got[i * 2 + t] as usize, tree.leaf_for_raw(&row), "row {i} tree {t}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "splits on feature index")]
    fn too_narrow_dataset_is_rejected_before_any_worker_runs() {
        let (_, ff) = toy_forest(); // splits reference feature 2
        let ds = Dataset::new(
            4,
            2,
            vec![0.0; 8],
            Targets::Regression { values: vec![0.0; 8], n_targets: 2 },
        );
        let _ = ff.predict_raw(&ds, &PredictOptions::default());
    }

    #[test]
    fn empty_dataset_is_fine() {
        let (_, ff) = toy_forest();
        let ds = Dataset::new(0, 3, vec![], Targets::Regression { values: vec![], n_targets: 2 });
        assert!(ff.predict_raw(&ds, &PredictOptions::default()).is_empty());
        assert!(ff.predict_leaf_indices(&ds, &PredictOptions::default()).is_empty());
    }

    #[test]
    fn predict_block_into_matches_reference_row_grouping_free() {
        let ds = toy_ds();
        let (model, ff) = toy_forest();
        let want = reference(&model, &ds);
        let m = ds.n_features;
        let d = ff.n_outputs;
        // score the same rows in arbitrary block groupings; every grouping
        // must reproduce the reference bits because each row only sees
        // its own tile slice
        for sizes in [vec![23usize], vec![1; 23], vec![5, 9, 9], vec![22, 1]] {
            let mut got = vec![0.0f32; ds.n_rows * d];
            let mut start = 0usize;
            let mut tile = vec![0.0f32; 23 * m];
            for n in sizes {
                gather_block(&ds, start, start + n, &mut tile);
                ff.predict_block_into(
                    &tile[..n * m],
                    m,
                    n,
                    &mut got[start * d..(start + n) * d],
                );
                start += n;
            }
            assert_eq!(start, ds.n_rows);
            for (i, (a, b)) in want.iter().zip(&got).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "cell {i}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "splits on feature index")]
    fn predict_block_into_rejects_narrow_width() {
        let (_, ff) = toy_forest(); // splits reference feature 2
        let tile = vec![0.0f32; 4];
        let mut out = vec![0.0f32; 4];
        ff.predict_block_into(&tile, 2, 2, &mut out);
    }

    #[test]
    fn gather_block_is_row_major() {
        let ds = toy_ds();
        let mut tile = vec![0.0f32; 4 * 3];
        gather_block(&ds, 2, 6, &mut tile);
        for i in 0..4 {
            for f in 0..3 {
                let want = ds.value(2 + i, f);
                let got = tile[i * 3 + f];
                assert!(got == want || (got.is_nan() && want.is_nan()));
            }
        }
    }
}
