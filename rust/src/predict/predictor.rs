//! The unified prediction facade.
//!
//! [`Predictor`] is the one front door for scoring a trained model: it
//! compiles the model into a [`FlatForest`] **once** (in the layout
//! [`PredictOptions::layout`] requests) and exposes every output the
//! scattered convenience methods used to produce — raw margins, linked
//! predictions, leaf indices — against that single compiled forest.
//! The legacy entry points (`Ensemble::predict_raw/_with/predict/...`,
//! `OvaModel::predict_raw/...`, `Ensemble::predict_leaf_indices*`) are
//! kept as `#[doc(hidden)]` delegates onto this facade, so they are
//! provably pure renames (`rust/tests/predict_equivalence.rs` pins the
//! bits); the `*_naive` walkers stay public — they are the reference
//! oracles, not conveniences.
//!
//! [`SharedForest`] (the serve daemon's hot-swappable model handle)
//! lives here too: it hands out `Arc<Predictor>` snapshots so the
//! serving workers consume the same facade as the offline CLI.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::baselines::one_vs_all::OvaModel;
use crate::boosting::ensemble::Ensemble;
use crate::boosting::losses::{self, LossKind};
use crate::data::dataset::Dataset;
use crate::predict::batch::PredictOptions;
use crate::predict::flat::FlatForest;

/// A model compiled for scoring: forest + link + batching knobs.
///
/// Construction is the only place layout matters
/// ([`PredictOptions::layout`] is consumed by
/// [`FlatForest::compile`]); after that every call scores against the
/// same compiled forest, so repeated scoring pays the O(total nodes)
/// compile exactly once — the thing the legacy per-call convenience
/// methods could not offer.
#[derive(Clone, Debug)]
pub struct Predictor {
    forest: FlatForest,
    loss: LossKind,
    opts: PredictOptions,
}

impl Predictor {
    /// Compile a single-tree-strategy model for scoring.
    pub fn compile(model: &Ensemble, opts: PredictOptions) -> Predictor {
        Predictor {
            forest: FlatForest::compile(model, opts.layout),
            loss: model.loss,
            opts,
        }
    }

    /// Compile a one-vs-all baseline model for scoring.
    pub fn compile_ova(model: &OvaModel, opts: PredictOptions) -> Predictor {
        Predictor {
            forest: FlatForest::compile_ova(model, opts.layout),
            loss: model.loss,
            opts,
        }
    }

    /// Raw scores (margins), row-major `[n_rows, n_outputs]`.
    pub fn raw(&self, ds: &Dataset) -> Vec<f32> {
        self.forest.predict_raw(ds, &self.opts)
    }

    /// Raw scores written into a caller-owned buffer.
    pub fn raw_into(&self, ds: &Dataset, out: &mut [f32]) {
        self.forest.predict_raw_into(ds, &self.opts, out)
    }

    /// Predictions on the loss's output scale (softmax / sigmoid /
    /// identity — whatever link the model was trained with).
    pub fn predict(&self, ds: &Dataset) -> Vec<f32> {
        let mut raw = self.raw(ds);
        self.apply_link(&mut raw);
        raw
    }

    /// Map raw scores to the loss's output scale in place.
    pub fn apply_link(&self, raw: &mut [f32]) {
        losses::apply_link(self.loss, raw, self.forest.n_outputs);
    }

    /// Leaf index of every row in every tree, row-major
    /// `[n_rows, n_trees]` (the batched "apply" output).
    pub fn leaf_indices(&self, ds: &Dataset) -> Vec<u32> {
        self.forest.predict_leaf_indices(ds, &self.opts)
    }

    /// The compiled forest (serving workers score blocks against it
    /// directly via [`FlatForest::predict_block_into`]).
    pub fn forest(&self) -> &FlatForest {
        &self.forest
    }

    pub fn n_outputs(&self) -> usize {
        self.forest.n_outputs
    }

    pub fn loss(&self) -> LossKind {
        self.loss
    }

    pub fn options(&self) -> &PredictOptions {
        &self.opts
    }
}

/// A hot-swappable handle to the predictor being served.
///
/// Readers take an `Arc` snapshot and score against it for as long as
/// they like; [`SharedForest::swap`] flips the shared pointer to a new
/// predictor without waiting for readers, so a swap can never tear a
/// snapshot mid-batch — a reader either holds the old model entirely
/// or the new one entirely. The old predictor is freed when its last
/// in-flight snapshot drops. A monotone version counter identifies
/// which model produced a given response (`serve` reports it under
/// `/stats`).
#[derive(Debug)]
pub struct SharedForest {
    current: Mutex<Arc<Predictor>>,
    version: AtomicU64,
}

impl SharedForest {
    /// Wrap `pred` as version 1.
    pub fn new(pred: Predictor) -> SharedForest {
        SharedForest {
            current: Mutex::new(Arc::new(pred)),
            version: AtomicU64::new(1),
        }
    }

    /// The predictor to score the next batch against. The lock is held
    /// only long enough to clone the `Arc` (pointer-sized critical
    /// section).
    pub fn snapshot(&self) -> Arc<Predictor> {
        self.current.lock().unwrap().clone()
    }

    /// Version of the model currently installed (starts at 1, bumps on
    /// every [`SharedForest::swap`]).
    pub fn version(&self) -> u64 {
        self.version.load(Ordering::Acquire)
    }

    /// Install `pred` as the new current model and return its version.
    /// In-flight snapshots keep the old predictor alive until they drop.
    pub fn swap(&self, pred: Predictor) -> u64 {
        let mut cur = self.current.lock().unwrap();
        *cur = Arc::new(pred);
        self.version.fetch_add(1, Ordering::AcqRel) + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::boosting::ensemble::TrainHistory;
    use crate::data::dataset::Targets;
    use crate::predict::flat::{ForestLayout, LayoutOptions};
    use crate::tree::tree::{encode_leaf, Tree, TreeNode};

    fn toy_model() -> Ensemble {
        Ensemble {
            loss: LossKind::MSE,
            n_outputs: 2,
            base_score: vec![0.25, -0.25],
            trees: vec![
                Tree {
                    n_outputs: 2,
                    nodes: vec![
                        TreeNode { feature: 0, bin: 3, threshold: 0.5, default_left: true, cats: None, left: encode_leaf(0), right: 1, gain: 1.0 },
                        TreeNode { feature: 1, bin: 1, threshold: 2.0, default_left: false, cats: None, left: encode_leaf(1), right: encode_leaf(2), gain: 0.5 },
                    ],
                    leaf_values: vec![1.0, -1.0, 2.0, -2.0, 3.0, -3.0],
                    n_leaves: 3,
                },
                Tree { n_outputs: 2, nodes: vec![], leaf_values: vec![0.5, 0.5], n_leaves: 1 },
            ],
            history: TrainHistory::default(),
        }
    }

    fn toy_ds() -> Dataset {
        let n = 9usize;
        let mut cols = vec![0.0f32; n * 2];
        for f in 0..2 {
            for i in 0..n {
                cols[f * n + i] = (i as f32) * 0.41 - (f as f32) * 0.9;
            }
        }
        cols[3] = f32::NAN;
        Dataset::new(n, 2, cols, Targets::Regression { values: vec![0.0; n * 2], n_targets: 2 })
    }

    #[test]
    fn facade_matches_legacy_methods_bitwise() {
        let model = toy_model();
        let ds = toy_ds();
        let opts = PredictOptions::threads(2).with_block_rows(3);
        let pred = Predictor::compile(&model, opts);
        assert_eq!(pred.raw(&ds), model.predict_raw_with(&ds, &opts));
        assert_eq!(pred.predict(&ds), model.predict_with(&ds, &opts));
        assert_eq!(pred.leaf_indices(&ds), model.predict_leaf_indices_with(&ds, &opts));
        assert_eq!(pred.n_outputs(), 2);
        assert_eq!(pred.loss(), LossKind::MSE);
        assert_eq!(pred.options().n_threads, 2);
    }

    #[test]
    fn facade_layouts_agree_with_v1_bits() {
        let model = toy_model();
        let ds = toy_ds();
        let want = Predictor::compile(&model, PredictOptions::default()).raw(&ds);
        for layout in [ForestLayout::V2Exact, ForestLayout::V2Quantized] {
            let pred = Predictor::compile(
                &model,
                PredictOptions::default().with_layout(layout).with_exact_leaves(true),
            );
            assert_eq!(pred.forest().layout(), layout);
            let got = pred.raw(&ds);
            for (i, (a, b)) in want.iter().zip(&got).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "cell {i} layout {layout:?}");
            }
        }
    }

    #[test]
    fn compile_honors_layout_options() {
        let model = toy_model();
        let opts = PredictOptions {
            layout: LayoutOptions::v2_quantized(),
            ..PredictOptions::default()
        };
        let pred = Predictor::compile(&model, opts);
        assert_eq!(pred.forest().layout(), ForestLayout::V2Quantized);
    }

    #[test]
    fn shared_forest_swaps_without_tearing_snapshots() {
        let model = toy_model();
        let shared = SharedForest::new(Predictor::compile(&model, PredictOptions::default()));
        assert_eq!(shared.version(), 1);
        let old = shared.snapshot();
        let stump_only = Ensemble {
            trees: vec![Tree { n_outputs: 2, nodes: vec![], leaf_values: vec![9.0, 9.0], n_leaves: 1 }],
            ..toy_model()
        };
        let next = Predictor::compile(&stump_only, PredictOptions::default());
        assert_eq!(shared.swap(next), 2);
        assert_eq!(shared.version(), 2);
        // the pre-swap snapshot still scores with the old trees
        assert_eq!(old.forest().n_trees(), 2);
        let fresh = shared.snapshot();
        assert_eq!(fresh.forest().n_trees(), 1);
        let mut out = vec![0.0f32; 2];
        fresh.forest().add_leaf(0, 0, &mut out);
        assert_eq!(out, vec![9.0, 9.0]);
    }
}
