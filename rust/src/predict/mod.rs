//! Batched parallel inference.
//!
//! Training optimizes the *fit* hot path; this module is the serving
//! half: [`FlatForest`] compiles a trained [`Ensemble`](crate::boosting::Ensemble)
//! (or one-vs-all baseline) into one of three node layouts (see
//! [`ForestLayout`]: SoA arrays, interleaved 16-byte records, or
//! quantized records with integer threshold compares), and the blocked
//! batch driver ([`FlatForest::predict_raw_into`]) streams cache-sized
//! row blocks through all trees, parallelized over blocks with the
//! deterministic [`ThreadPool`](crate::util::threading::ThreadPool).
//! [`Predictor`] is the front door that owns the compile + scoring
//! knobs; the serve daemon snapshots it through [`SharedForest`].
//!
//! Outputs are bit-identical to the per-row reference walker
//! ([`Ensemble::predict_raw_naive`](crate::boosting::Ensemble::predict_raw_naive))
//! for every thread count and block size. See DESIGN.md section
//! "Inference model (FlatForest)".

pub mod batch;
pub mod flat;
pub mod predictor;

pub use batch::{PredictOptions, DEFAULT_BLOCK_ROWS};
pub use flat::{FlatForest, ForestLayout, LayoutOptions};
pub use predictor::{Predictor, SharedForest};
