//! Tiny CLI argument parser (offline build: no `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.
//! Typed getters with defaults keep call sites short; `usage()` renders a
//! help block from the registered option descriptions.

use std::collections::BTreeMap;

/// Parsed command line: positionals plus `--key` options.
#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut a = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    a.opts.insert(k.to_string(), v.to_string());
                } else {
                    // `--key value` if next token isn't another option,
                    // otherwise a bare flag.
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = it.next().unwrap();
                            a.opts.insert(body.to_string(), v);
                        }
                        _ => a.flags.push(body.to_string()),
                    }
                }
            } else {
                a.positional.push(tok);
            }
        }
        a
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
            || self
                .opts
                .get(name)
                .map(|v| v == "true" || v == "1")
                .unwrap_or(false)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_f32(&self, name: &str, default: f32) -> f32 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects a number, got {v:?}")))
            .unwrap_or(default)
    }

    /// Comma-separated list of integers, e.g. `--ks 1,2,5,10`.
    pub fn get_usize_list(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.get(name) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.trim()
                        .parse()
                        .unwrap_or_else(|_| panic!("--{name}: bad integer {s:?}"))
                })
                .collect(),
        }
    }
}

/// Render a usage block from (option, description) pairs.
pub fn usage(cmd: &str, summary: &str, opts: &[(&str, &str)]) -> String {
    let mut s = format!("{summary}\n\nUsage: {cmd}\n\nOptions:\n");
    let w = opts.iter().map(|(o, _)| o.len()).max().unwrap_or(0);
    for (o, d) in opts {
        s.push_str(&format!("  {o:<w$}  {d}\n"));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|t| t.to_string()))
    }

    #[test]
    fn positional_and_options() {
        let a = parse("train --rounds 50 data.csv --lr=0.1");
        assert_eq!(a.positional, vec!["train", "data.csv"]);
        assert_eq!(a.get_usize("rounds", 0), 50);
        assert_eq!(a.get_f32("lr", 0.0), 0.1);
    }

    #[test]
    fn flags() {
        let a = parse("--verbose --out x.json");
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.get("out"), Some("x.json"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("--a --b v");
        assert!(a.flag("a"));
        assert_eq!(a.get("b"), Some("v"));
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.get_usize("depth", 6), 6);
        assert_eq!(a.get_str("loss", "ce"), "ce");
    }

    #[test]
    fn lists() {
        let a = parse("--ks 1,2,5");
        assert_eq!(a.get_usize_list("ks", &[9]), vec![1, 2, 5]);
        assert_eq!(a.get_usize_list("other", &[9]), vec![9]);
    }

    #[test]
    #[should_panic]
    fn bad_int_panics() {
        parse("--rounds abc").get_usize("rounds", 1);
    }
}
