//! Small property-based testing helper (offline build: no `proptest`).
//!
//! `run_prop` drives a property closure over `cases` independently seeded
//! random cases; on failure it panics with the failing case's seed so the
//! case can be replayed deterministically (`replay_prop`). Generators are
//! plain methods on `Gen`, which wraps the library RNG.

use crate::util::rng::Rng;

/// Case-local random generator handed to property closures.
pub struct Gen {
    pub rng: Rng,
    pub seed: u64,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.rng.next_below(hi - lo + 1)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.next_f32() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn vec_gaussian(&mut self, len: usize, sigma: f64) -> Vec<f32> {
        let mut v = vec![0.0f32; len];
        self.rng.fill_gaussian(&mut v, sigma);
        v
    }

    pub fn vec_u32_below(&mut self, len: usize, bound: usize) -> Vec<u32> {
        (0..len).map(|_| self.rng.next_below(bound) as u32).collect()
    }

    /// Gaussian vector with a `nan_rate` fraction of cells missing (NaN).
    pub fn vec_gaussian_nan(&mut self, len: usize, sigma: f64, nan_rate: f32) -> Vec<f32> {
        let mut v = self.vec_gaussian(len, sigma);
        for x in v.iter_mut() {
            if self.rng.next_f32() < nan_rate {
                *x = f32::NAN;
            }
        }
        v
    }

    /// Integer category ids in `[0, cards)` as f32 (the raw encoding of
    /// a categorical feature column), with a `nan_rate` fraction missing.
    pub fn vec_cat_values(&mut self, len: usize, cards: usize, nan_rate: f32) -> Vec<f32> {
        (0..len)
            .map(|_| {
                if self.rng.next_f32() < nan_rate {
                    f32::NAN
                } else {
                    self.rng.next_below(cards) as f32
                }
            })
            .collect()
    }

    /// Pick one element from a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.next_below(xs.len())]
    }
}

/// Run `prop` over `cases` random cases; panic with the failing seed.
pub fn run_prop<F: FnMut(&mut Gen)>(name: &str, cases: usize, mut prop: F) {
    let mut meta = Rng::new(0x5EED ^ fxhash(name));
    for case in 0..cases {
        let seed = meta.next_u64();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut g = Gen { rng: Rng::new(seed), seed };
            prop(&mut g);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| payload.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property '{name}' failed at case {case}/{cases} (seed {seed:#x}): {msg}\n\
                 replay with replay_prop(\"{name}\", {seed:#x}, prop)"
            );
        }
    }
}

/// Re-run a single failing case by seed.
pub fn replay_prop<F: FnMut(&mut Gen)>(_name: &str, seed: u64, mut prop: F) {
    let mut g = Gen { rng: Rng::new(seed), seed };
    prop(&mut g);
}

fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Assert two f32 slices are elementwise close.
#[track_caller]
pub fn assert_close(a: &[f32], b: &[f32], rtol: f32, atol: f32) {
    assert_eq!(a.len(), b.len(), "length mismatch: {} vs {}", a.len(), b.len());
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        assert!(
            (x - y).abs() <= tol || (x.is_nan() && y.is_nan()),
            "mismatch at {i}: {x} vs {y} (tol {tol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn props_pass() {
        run_prop("addition commutes", 50, |g| {
            let a = g.f32_in(-10.0, 10.0);
            let b = g.f32_in(-10.0, 10.0);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn failing_prop_reports_seed() {
        let r = std::panic::catch_unwind(|| {
            run_prop("always fails", 3, |_| panic!("boom"));
        });
        let msg = match r {
            Err(p) => p.downcast_ref::<String>().unwrap().clone(),
            Ok(()) => panic!("should have failed"),
        };
        assert!(msg.contains("seed"), "{msg}");
        assert!(msg.contains("boom"), "{msg}");
    }

    #[test]
    fn gen_ranges() {
        run_prop("gen ranges", 30, |g| {
            let x = g.usize_in(3, 7);
            assert!((3..=7).contains(&x));
            let f = g.f32_in(-1.0, 1.0);
            assert!((-1.0..=1.0).contains(&f));
            let v = g.vec_u32_below(10, 4);
            assert!(v.iter().all(|&u| u < 4));
        });
    }

    #[test]
    fn gen_nan_and_cat_vectors() {
        run_prop("gen nan/cat", 20, |g| {
            let v = g.vec_gaussian_nan(200, 1.0, 0.3);
            let nans = v.iter().filter(|x| x.is_nan()).count();
            assert!(nans > 0 && nans < 200, "nan_rate 0.3 -> mixed: {nans}");
            assert!(g.vec_gaussian_nan(50, 1.0, 0.0).iter().all(|x| !x.is_nan()));
            let c = g.vec_cat_values(200, 5, 0.2);
            for x in &c {
                assert!(
                    x.is_nan() || (*x >= 0.0 && *x < 5.0 && x.fract() == 0.0),
                    "bad cat value {x}"
                );
            }
        });
    }

    #[test]
    fn assert_close_accepts_equal() {
        assert_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-5, 1e-6);
    }

    #[test]
    #[should_panic]
    fn assert_close_rejects_far() {
        assert_close(&[1.0], &[2.0], 1e-5, 1e-6);
    }
}
