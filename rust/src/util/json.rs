//! Minimal JSON parser + writer (offline build: no `serde`).
//!
//! Covers the subset the library needs: the artifact manifest written by
//! `python/compile/aot.py`, training configs, saved models, and bench
//! result files. Numbers parse to f64; the accessor helpers do the usual
//! lossy-but-checked narrowing.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Objects use a BTreeMap so serialization is stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- constructors ------------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, val: Json) -> &mut Json {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        } else {
            panic!("Json::set on non-object");
        }
        self
    }

    pub fn from_f64_slice(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn from_f32_slice(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // -- accessors ---------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 && x <= usize::MAX as f64 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_f64().map(|x| x as f32))
            .collect()
    }

    // -- writer ------------------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Pretty printer with 2-space indent (for configs / reports).
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, depth: usize) {
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..(depth + 1) * 2 {
                        out.push(' ');
                    }
                    e.write_pretty(out, depth + 1);
                }
                out.push('\n');
                for _ in 0..depth * 2 {
                    out.push(' ');
                }
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..(depth + 1) * 2 {
                        out.push(' ');
                    }
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, depth + 1);
                }
                out.push('\n');
                for _ in 0..depth * 2 {
                    out.push(' ');
                }
                out.push('}');
            }
            _ => self.write(out),
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.is_finite() {
        if x.fract() == 0.0 && x.abs() < 1e15 {
            let _ = write!(out, "{}", x as i64);
        } else {
            let _ = write!(out, "{x}");
        }
    } else {
        // JSON has no inf/nan; models never contain them, but be safe.
        out.push_str("null");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // surrogate pairs unsupported (not needed here)
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for t in ["null", "true", "false", "0", "-1", "3.5", "\"hi\""] {
            let v = Json::parse(t).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64().unwrap(), 1.0);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn parses_manifest_like() {
        let t = r#"{"lambda": 1.0, "artifacts": {"hist_test":
            {"file": "hist_test.hlo.txt", "chunk": 256, "bins": 16}}}"#;
        let v = Json::parse(t).unwrap();
        let a = v.get("artifacts").unwrap().get("hist_test").unwrap();
        assert_eq!(a.get("chunk").unwrap().as_usize().unwrap(), 256);
    }

    #[test]
    fn rejects_garbage() {
        for t in ["", "{", "[1,", "{\"a\" 1}", "tru", "1 2", "\"\\q\""] {
            assert!(Json::parse(t).is_err(), "should reject {t:?}");
        }
    }

    #[test]
    fn exponent_numbers() {
        assert_eq!(Json::parse("1e3").unwrap().as_f64().unwrap(), 1000.0);
        assert_eq!(Json::parse("-2.5E-2").unwrap().as_f64().unwrap(), -0.025);
    }

    #[test]
    fn writer_escapes() {
        let v = Json::Str("a\"b\\c\nd".to_string());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn stable_object_order() {
        let mut o = Json::obj();
        o.set("z", Json::Num(1.0));
        o.set("a", Json::Num(2.0));
        assert_eq!(o.to_string(), r#"{"a":2,"z":1}"#);
    }

    #[test]
    fn pretty_roundtrips() {
        let t = r#"{"a": [1, 2], "b": {"c": true}}"#;
        let v = Json::parse(t).unwrap();
        assert_eq!(Json::parse(&v.to_pretty()).unwrap(), v);
    }

    #[test]
    fn as_usize_rejects_fractional_and_negative() {
        assert_eq!(Json::Num(3.5).as_usize(), None);
        assert_eq!(Json::Num(-1.0).as_usize(), None);
        assert_eq!(Json::Num(7.0).as_usize(), Some(7));
    }
}
