//! Dependency-free data parallelism (offline build: no `rayon`).
//!
//! A [`ThreadPool`] of scoped workers plus a chunked work queue, built on
//! `std::thread::scope` and one atomic cursor. Workers are spawned per
//! top-level call and borrow the caller's data directly — no `'static`
//! bounds, no channels, no unsafe lifetime erasure. A pool of one thread
//! runs every job inline on the caller, so the single-thread path pays no
//! synchronization or spawn cost at all.
//!
//! ## Determinism contract
//!
//! The pool intentionally exposes only primitives whose *numeric result*
//! cannot depend on the number of workers or on scheduling order:
//!
//! * [`ThreadPool::for_each_chunk`] — a dynamic queue over item chunks.
//!   Which worker runs a chunk is non-deterministic; callers must make
//!   each chunk's effect independent of every other chunk (disjoint
//!   writes). Chunk *boundaries* are a pure function of `(n_items,
//!   chunk)`, never of the thread count.
//! * [`shard_bounds`] — the fixed partition the engine uses for
//!   thread-local histogram shards. It depends only on the item count and
//!   shard count, so the shards (and therefore the per-shard f32
//!   accumulation order) are identical for any pool size.
//! * [`reduce_shards`] — deterministic reduction: every output cell sums
//!   its shard cells in ascending shard order. Parallelism is across
//!   *cells*, which never reorders the per-cell additions.
//!
//! Together these make `n_threads = 1` and `n_threads = N` produce
//! bit-identical results (`rust/tests/parallel_determinism.rs` enforces
//! this end-to-end).

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};

/// A fixed-width pool of scoped workers (see module docs).
#[derive(Clone, Debug)]
pub struct ThreadPool {
    n_threads: usize,
}

impl ThreadPool {
    /// Pool with `n_threads` workers; `0` means "all available cores".
    pub fn new(n_threads: usize) -> ThreadPool {
        let n = match n_threads {
            0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            n => n,
        };
        ThreadPool { n_threads: n.max(1) }
    }

    pub fn n_threads(&self) -> usize {
        self.n_threads
    }

    /// Run `f(worker_id)` once per worker, concurrently. Worker 0 runs on
    /// the calling thread; a pool of one thread calls `f(0)` inline.
    pub fn broadcast<F>(&self, f: F)
    where
        F: Fn(usize) + Sync,
    {
        if self.n_threads == 1 {
            f(0);
            return;
        }
        std::thread::scope(|s| {
            let fr = &f;
            for t in 1..self.n_threads {
                s.spawn(move || fr(t));
            }
            fr(0);
        });
    }

    /// Chunked dynamic work queue: split `0..n_items` into chunks of
    /// `chunk` items (last one may be short) and have workers pull chunks
    /// from a shared cursor, calling `f(start..end)` per chunk.
    ///
    /// Chunk boundaries depend only on `(n_items, chunk)`; worker
    /// assignment is dynamic, so `f`'s effects must be independent across
    /// chunks (e.g. writes to disjoint output ranges).
    pub fn for_each_chunk<F>(&self, n_items: usize, chunk: usize, f: F)
    where
        F: Fn(Range<usize>) + Sync,
    {
        if n_items == 0 {
            return;
        }
        let chunk = chunk.max(1);
        if self.n_threads == 1 || n_items <= chunk {
            let mut start = 0;
            while start < n_items {
                let end = (start + chunk).min(n_items);
                f(start..end);
                start = end;
            }
            return;
        }
        let cursor = AtomicUsize::new(0);
        self.broadcast(|_worker| loop {
            let start = cursor.fetch_add(chunk, Ordering::Relaxed);
            if start >= n_items {
                break;
            }
            f(start..(start + chunk).min(n_items));
        });
    }
}

impl Default for ThreadPool {
    fn default() -> Self {
        ThreadPool::new(1)
    }
}

/// The fixed contiguous partition of `0..n_items` into `n_shards` ranges:
/// shard `s` is `[start, end)` with sizes differing by at most one (the
/// first `n_items % n_shards` shards are one longer). Pure in its inputs,
/// so the partition is identical for every thread count.
pub fn shard_bounds(n_items: usize, n_shards: usize, s: usize) -> (usize, usize) {
    debug_assert!(s < n_shards);
    let base = n_items / n_shards;
    let rem = n_items % n_shards;
    let start = s * base + s.min(rem);
    let end = start + base + usize::from(s < rem);
    (start, end)
}

/// Deterministically accumulate `n_shards` equal-length shard buffers
/// (concatenated in `shards`) into `out` (`out[c] += Σ_s shard_s[c]`).
///
/// Every cell adds its shard values in ascending shard order — the same
/// order a single thread would use — and the pool parallelizes across
/// cell ranges only, so the result is bit-identical for any pool size.
pub fn reduce_shards(pool: &ThreadPool, shards: &[f32], n_shards: usize, out: &mut [f32]) {
    let len = out.len();
    assert_eq!(shards.len(), n_shards * len, "shards must be n_shards * out.len()");
    if n_shards == 0 || len == 0 {
        return;
    }
    let out_cells = DisjointSlice::new(out);
    pool.for_each_chunk(len, 16 * 1024, |r| {
        // Safety: chunk ranges from the queue are disjoint sub-ranges of
        // `0..len`, so every cell is written by exactly one worker.
        let dst = unsafe { out_cells.range_mut(r.clone()) };
        for s in 0..n_shards {
            let src = &shards[s * len + r.start..s * len + r.end];
            for (d, &v) in dst.iter_mut().zip(src) {
                *d += v;
            }
        }
    });
}

/// A shared view of a mutable slice for *disjoint* parallel writes.
///
/// The pool's queue hands each worker distinct ranges; this wrapper lets
/// those workers write their ranges without locking. All safety rests on
/// the caller's disjointness guarantee (see [`DisjointSlice::range_mut`]).
pub struct DisjointSlice<'a, T> {
    ptr: *mut T,
    len: usize,
    _borrow: std::marker::PhantomData<&'a mut [T]>,
}

unsafe impl<T: Send> Send for DisjointSlice<'_, T> {}
unsafe impl<T: Send> Sync for DisjointSlice<'_, T> {}

impl<'a, T> DisjointSlice<'a, T> {
    pub fn new(slice: &'a mut [T]) -> DisjointSlice<'a, T> {
        DisjointSlice {
            ptr: slice.as_mut_ptr(),
            len: slice.len(),
            _borrow: std::marker::PhantomData,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Mutable view of `range`.
    ///
    /// # Safety
    ///
    /// Concurrent callers must pass pairwise-disjoint ranges; `range`
    /// must lie within `0..self.len()` (checked with `debug_assert`).
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn range_mut(&self, range: Range<usize>) -> &mut [T] {
        debug_assert!(range.start <= range.end && range.end <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(range.start), range.end - range.start)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    #[test]
    fn broadcast_runs_every_worker_once() {
        for n in [1usize, 2, 4, 7] {
            let pool = ThreadPool::new(n);
            assert_eq!(pool.n_threads(), n);
            let seen = Mutex::new(vec![0usize; n]);
            pool.broadcast(|w| {
                seen.lock().unwrap()[w] += 1;
            });
            assert_eq!(*seen.lock().unwrap(), vec![1usize; n]);
        }
    }

    #[test]
    fn zero_threads_means_auto() {
        assert!(ThreadPool::new(0).n_threads() >= 1);
    }

    #[test]
    fn chunk_boundaries_are_thread_count_independent() {
        // The set of chunk ranges must be exactly the serial partition of
        // 0..n into `chunk`-sized pieces, for every pool width.
        let n = 103;
        let chunk = 8;
        let want: Vec<(usize, usize)> =
            (0..n).step_by(chunk).map(|s| (s, (s + chunk).min(n))).collect();
        for threads in [1usize, 2, 4, 8] {
            let pool = ThreadPool::new(threads);
            let got = Mutex::new(Vec::new());
            pool.for_each_chunk(n, chunk, |r| {
                got.lock().unwrap().push((r.start, r.end));
            });
            let mut got = got.into_inner().unwrap();
            got.sort_unstable();
            assert_eq!(got, want, "threads = {threads}");
        }
    }

    #[test]
    fn for_each_chunk_covers_every_item_exactly_once() {
        let n = 1000;
        let mut hits = vec![0u8; n];
        let pool = ThreadPool::new(4);
        let cells = DisjointSlice::new(&mut hits);
        pool.for_each_chunk(n, 13, |r| {
            let dst = unsafe { cells.range_mut(r) };
            for v in dst {
                *v += 1;
            }
        });
        assert!(hits.iter().all(|&h| h == 1));
    }

    #[test]
    fn shard_bounds_partition() {
        for (n, s) in [(10usize, 3usize), (7, 7), (2048, 5), (5, 1), (0, 2)] {
            let mut covered = 0usize;
            let mut prev_end = 0usize;
            for i in 0..s {
                let (a, b) = shard_bounds(n, s, i);
                assert_eq!(a, prev_end, "shards must be contiguous");
                assert!(b >= a);
                // balanced: sizes differ by at most one
                assert!(b - a <= n / s + 1);
                covered += b - a;
                prev_end = b;
            }
            assert_eq!(covered, n);
            assert_eq!(prev_end, n);
        }
    }

    /// The reduction must add shards in ascending shard order per cell —
    /// checked with values whose f32 sum is order-sensitive, against a
    /// serial left-to-right reference, for several pool widths.
    #[test]
    fn reduce_shards_is_order_deterministic() {
        let len = 37;
        let n_shards = 5;
        // adversarial magnitudes: reordering these changes the f32 sum
        let mut shards = vec![0.0f32; n_shards * len];
        for s in 0..n_shards {
            for c in 0..len {
                shards[s * len + c] =
                    (1.0 + c as f32) * 10f32.powi(s as i32 - 2) * if s % 2 == 0 { 1.0 } else { -1.0 };
            }
        }
        let mut want = vec![0.5f32; len];
        for s in 0..n_shards {
            for c in 0..len {
                want[c] += shards[s * len + c];
            }
        }
        for threads in [1usize, 2, 4] {
            let pool = ThreadPool::new(threads);
            let mut out = vec![0.5f32; len];
            reduce_shards(&pool, &shards, n_shards, &mut out);
            // bitwise equality, not approximate
            assert_eq!(out, want, "threads = {threads}");
        }
    }

    #[test]
    #[should_panic]
    fn reduce_shards_rejects_length_mismatch() {
        let pool = ThreadPool::new(1);
        let shards = vec![0.0f32; 7];
        let mut out = vec![0.0f32; 3];
        reduce_shards(&pool, &shards, 2, &mut out);
    }
}
