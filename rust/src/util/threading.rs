//! Dependency-free data parallelism (offline build: no `rayon`).
//!
//! A [`ThreadPool`] of scoped workers plus a chunked work queue, built on
//! `std::thread::scope` and one atomic cursor. Workers are spawned per
//! top-level call and borrow the caller's data directly — no `'static`
//! bounds, no channels, no unsafe lifetime erasure. A pool of one thread
//! runs every job inline on the caller, so the single-thread path pays no
//! synchronization or spawn cost at all.
//!
//! ## Determinism contract
//!
//! The pool intentionally exposes only primitives whose *numeric result*
//! cannot depend on the number of workers or on scheduling order:
//!
//! * [`ThreadPool::for_each_chunk`] — a dynamic queue over item chunks.
//!   Which worker runs a chunk is non-deterministic; callers must make
//!   each chunk's effect independent of every other chunk (disjoint
//!   writes). Chunk *boundaries* are a pure function of `(n_items,
//!   chunk)`, never of the thread count.
//! * [`shard_bounds`] — the fixed partition the engine uses for
//!   thread-local histogram shards. It depends only on the item count and
//!   shard count, so the shards (and therefore the per-shard f32
//!   accumulation order) are identical for any pool size.
//! * [`reduce_shards`] — deterministic reduction: every output cell sums
//!   its shard cells in ascending shard order. Parallelism is across
//!   *cells*, which never reorders the per-cell additions.
//!
//! Together these make `n_threads = 1` and `n_threads = N` produce
//! bit-identical results (`rust/tests/parallel_determinism.rs` enforces
//! this end-to-end).

use std::collections::VecDeque;
use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Instant;

/// A fixed-width pool of scoped workers (see module docs).
#[derive(Clone, Debug)]
pub struct ThreadPool {
    n_threads: usize,
}

impl ThreadPool {
    /// Pool with `n_threads` workers; `0` means "all available cores".
    pub fn new(n_threads: usize) -> ThreadPool {
        let n = match n_threads {
            0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            n => n,
        };
        ThreadPool { n_threads: n.max(1) }
    }

    pub fn n_threads(&self) -> usize {
        self.n_threads
    }

    /// Run `f(worker_id)` once per worker, concurrently. Worker 0 runs on
    /// the calling thread; a pool of one thread calls `f(0)` inline.
    pub fn broadcast<F>(&self, f: F)
    where
        F: Fn(usize) + Sync,
    {
        if self.n_threads == 1 {
            f(0);
            return;
        }
        std::thread::scope(|s| {
            let fr = &f;
            for t in 1..self.n_threads {
                s.spawn(move || fr(t));
            }
            fr(0);
        });
    }

    /// Chunked dynamic work queue: split `0..n_items` into chunks of
    /// `chunk` items (last one may be short) and have workers pull chunks
    /// from a shared cursor, calling `f(start..end)` per chunk.
    ///
    /// Chunk boundaries depend only on `(n_items, chunk)`; worker
    /// assignment is dynamic, so `f`'s effects must be independent across
    /// chunks (e.g. writes to disjoint output ranges).
    pub fn for_each_chunk<F>(&self, n_items: usize, chunk: usize, f: F)
    where
        F: Fn(Range<usize>) + Sync,
    {
        if n_items == 0 {
            return;
        }
        let chunk = chunk.max(1);
        if self.n_threads == 1 || n_items <= chunk {
            let mut start = 0;
            while start < n_items {
                let end = (start + chunk).min(n_items);
                f(start..end);
                start = end;
            }
            return;
        }
        let cursor = AtomicUsize::new(0);
        self.broadcast(|_worker| loop {
            let start = cursor.fetch_add(chunk, Ordering::Relaxed);
            if start >= n_items {
                break;
            }
            f(start..(start + chunk).min(n_items));
        });
    }
}

impl Default for ThreadPool {
    fn default() -> Self {
        ThreadPool::new(1)
    }
}

/// The fixed contiguous partition of `0..n_items` into `n_shards` ranges:
/// shard `s` is `[start, end)` with sizes differing by at most one (the
/// first `n_items % n_shards` shards are one longer). Pure in its inputs,
/// so the partition is identical for every thread count.
pub fn shard_bounds(n_items: usize, n_shards: usize, s: usize) -> (usize, usize) {
    debug_assert!(s < n_shards);
    let base = n_items / n_shards;
    let rem = n_items % n_shards;
    let start = s * base + s.min(rem);
    let end = start + base + usize::from(s < rem);
    (start, end)
}

/// Deterministically accumulate `n_shards` equal-length shard buffers
/// (concatenated in `shards`) into `out` (`out[c] += Σ_s shard_s[c]`).
///
/// Every cell adds its shard values in ascending shard order — the same
/// order a single thread would use — and the pool parallelizes across
/// cell ranges only, so the result is bit-identical for any pool size.
pub fn reduce_shards(pool: &ThreadPool, shards: &[f32], n_shards: usize, out: &mut [f32]) {
    let len = out.len();
    assert_eq!(shards.len(), n_shards * len, "shards must be n_shards * out.len()");
    if n_shards == 0 || len == 0 {
        return;
    }
    let out_cells = DisjointSlice::new(out);
    pool.for_each_chunk(len, 16 * 1024, |r| {
        // SAFETY: chunk ranges from the queue lie within `0..len` and
        // every cell is written by exactly one worker.
        // DISJOINT: partitioned by output cell range — `for_each_chunk`
        // hands each `r` out once, and chunks never overlap.
        let dst = unsafe { out_cells.range_mut(r.clone()) };
        for s in 0..n_shards {
            let src = &shards[s * len + r.start..s * len + r.end];
            for (d, &v) in dst.iter_mut().zip(src) {
                *d += v;
            }
        }
    });
}

/// A shared view of a mutable slice for *disjoint* parallel writes.
///
/// The pool's queue hands each worker distinct ranges; this wrapper lets
/// those workers write their ranges without locking. All safety rests on
/// the caller's disjointness guarantee (see [`DisjointSlice::range_mut`]).
pub struct DisjointSlice<'a, T> {
    ptr: *mut T,
    len: usize,
    _borrow: std::marker::PhantomData<&'a mut [T]>,
}

// SAFETY: the wrapper is a pointer + length into a `&mut [T]` whose
// borrow it holds; moving it across threads moves no `T`, so `T: Send`
// suffices.
unsafe impl<T: Send> Send for DisjointSlice<'_, T> {}
// SAFETY: sharing `&DisjointSlice` only permits `range_mut`, whose own
// contract (pairwise-disjoint ranges) makes concurrent use race-free;
// `T: Send` because disjoint &mut access hands values between threads.
unsafe impl<T: Send> Sync for DisjointSlice<'_, T> {}

impl<'a, T> DisjointSlice<'a, T> {
    pub fn new(slice: &'a mut [T]) -> DisjointSlice<'a, T> {
        DisjointSlice {
            ptr: slice.as_mut_ptr(),
            len: slice.len(),
            _borrow: std::marker::PhantomData,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Mutable view of `range`.
    ///
    /// # Safety
    ///
    /// Concurrent callers must pass pairwise-disjoint ranges; `range`
    /// must lie within `0..self.len()` (checked with `debug_assert`).
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn range_mut(&self, range: Range<usize>) -> &mut [T] {
        debug_assert!(range.start <= range.end && range.end <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(range.start), range.end - range.start)
    }
}

/// Outcome of a [`BoundedQueue::pop_deadline`] call.
#[derive(Debug, PartialEq, Eq)]
pub enum PopResult<T> {
    /// An item was dequeued before the deadline.
    Item(T),
    /// The deadline passed with the queue still empty (and open).
    TimedOut,
    /// The queue is closed and fully drained.
    Closed,
}

/// Outcome of a non-blocking [`BoundedQueue::try_push`] call. The
/// rejected item travels back to the caller in both failure arms, so a
/// load-shedding producer can still answer its client.
#[derive(Debug, PartialEq, Eq)]
pub enum TryPush<T> {
    /// Enqueued; the payload is the queue depth right after the push
    /// (for high-water-mark accounting without a second lock).
    Pushed(usize),
    /// The queue is at capacity — the shedding hook.
    Full(T),
    /// The queue is closed.
    Closed(T),
}

/// A bounded multi-producer/multi-consumer FIFO on `Mutex` + `Condvar`
/// (offline build: no `crossbeam`). Producers block while the queue is
/// at capacity; consumers block while it is empty. [`BoundedQueue::close`]
/// stops new pushes immediately but lets consumers drain what is already
/// queued — the shutdown half of the serving drain contract
/// (`serve::server` relies on this ordering).
pub struct BoundedQueue<T> {
    inner: Mutex<QueueInner<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    cap: usize,
}

struct QueueInner<T> {
    items: VecDeque<T>,
    closed: bool,
}

impl<T> BoundedQueue<T> {
    /// Queue holding at most `cap` items (`cap` is clamped to ≥ 1).
    pub fn new(cap: usize) -> BoundedQueue<T> {
        BoundedQueue {
            inner: Mutex::new(QueueInner { items: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            cap: cap.max(1),
        }
    }

    /// Enqueue `item`, blocking while the queue is at capacity. Returns
    /// the depth right after the push, or the item back as `Err` if the
    /// queue is (or becomes) closed.
    pub fn push(&self, item: T) -> Result<usize, T> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if inner.closed {
                return Err(item);
            }
            if inner.items.len() < self.cap {
                inner.items.push_back(item);
                self.not_empty.notify_one();
                return Ok(inner.items.len());
            }
            inner = self.not_full.wait(inner).unwrap();
        }
    }

    /// Enqueue `item` only if there is room right now — the
    /// load-shedding variant: a full queue returns the item instead of
    /// parking the producer.
    pub fn try_push(&self, item: T) -> TryPush<T> {
        let mut inner = self.inner.lock().unwrap();
        if inner.closed {
            return TryPush::Closed(item);
        }
        if inner.items.len() >= self.cap {
            return TryPush::Full(item);
        }
        inner.items.push_back(item);
        self.not_empty.notify_one();
        TryPush::Pushed(inner.items.len())
    }

    /// Dequeue the oldest item, blocking while the queue is empty.
    /// Returns `None` only once the queue is closed *and* drained.
    pub fn pop(&self) -> Option<T> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some(item) = inner.items.pop_front() {
                self.not_full.notify_one();
                return Some(item);
            }
            if inner.closed {
                return None;
            }
            inner = self.not_empty.wait(inner).unwrap();
        }
    }

    /// Dequeue the oldest item, waiting at most until `deadline`. The
    /// coalescer uses this to cap how long a batch waits for company.
    pub fn pop_deadline(&self, deadline: Instant) -> PopResult<T> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some(item) = inner.items.pop_front() {
                self.not_full.notify_one();
                return PopResult::Item(item);
            }
            if inner.closed {
                return PopResult::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return PopResult::TimedOut;
            }
            let (guard, timeout) =
                self.not_empty.wait_timeout(inner, deadline - now).unwrap();
            inner = guard;
            if timeout.timed_out() && inner.items.is_empty() && !inner.closed {
                return PopResult::TimedOut;
            }
        }
    }

    /// Close the queue: pending and future `push` calls fail, consumers
    /// drain the remaining items and then see `None`/`Closed`.
    pub fn close(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Items currently queued (a snapshot; racy by nature).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    #[test]
    fn broadcast_runs_every_worker_once() {
        for n in [1usize, 2, 4, 7] {
            let pool = ThreadPool::new(n);
            assert_eq!(pool.n_threads(), n);
            let seen = Mutex::new(vec![0usize; n]);
            pool.broadcast(|w| {
                seen.lock().unwrap()[w] += 1;
            });
            assert_eq!(*seen.lock().unwrap(), vec![1usize; n]);
        }
    }

    #[test]
    fn zero_threads_means_auto() {
        assert!(ThreadPool::new(0).n_threads() >= 1);
    }

    #[test]
    fn chunk_boundaries_are_thread_count_independent() {
        // The set of chunk ranges must be exactly the serial partition of
        // 0..n into `chunk`-sized pieces, for every pool width.
        let n = 103;
        let chunk = 8;
        let want: Vec<(usize, usize)> =
            (0..n).step_by(chunk).map(|s| (s, (s + chunk).min(n))).collect();
        for threads in [1usize, 2, 4, 8] {
            let pool = ThreadPool::new(threads);
            let got = Mutex::new(Vec::new());
            pool.for_each_chunk(n, chunk, |r| {
                got.lock().unwrap().push((r.start, r.end));
            });
            let mut got = got.into_inner().unwrap();
            got.sort_unstable();
            assert_eq!(got, want, "threads = {threads}");
        }
    }

    #[test]
    fn for_each_chunk_covers_every_item_exactly_once() {
        // Miri interprets every access; a smaller n keeps the run in
        // seconds while still spanning many chunks (130 / 13 = 10)
        let n = if cfg!(miri) { 130 } else { 1000 };
        let mut hits = vec![0u8; n];
        let pool = ThreadPool::new(4);
        let cells = DisjointSlice::new(&mut hits);
        pool.for_each_chunk(n, 13, |r| {
            // SAFETY: in-bounds — `for_each_chunk` only yields ranges
            // within `0..n`, which is `hits.len()`.
            // DISJOINT: partitioned by chunk — each range is handed to
            // exactly one worker (the property this test asserts).
            let dst = unsafe { cells.range_mut(r) };
            for v in dst {
                *v += 1;
            }
        });
        assert!(hits.iter().all(|&h| h == 1));
    }

    #[test]
    fn shard_bounds_partition() {
        for (n, s) in [(10usize, 3usize), (7, 7), (2048, 5), (5, 1), (0, 2)] {
            let mut covered = 0usize;
            let mut prev_end = 0usize;
            for i in 0..s {
                let (a, b) = shard_bounds(n, s, i);
                assert_eq!(a, prev_end, "shards must be contiguous");
                assert!(b >= a);
                // balanced: sizes differ by at most one
                assert!(b - a <= n / s + 1);
                covered += b - a;
                prev_end = b;
            }
            assert_eq!(covered, n);
            assert_eq!(prev_end, n);
        }
    }

    /// The reduction must add shards in ascending shard order per cell —
    /// checked with values whose f32 sum is order-sensitive, against a
    /// serial left-to-right reference, for several pool widths.
    #[test]
    fn reduce_shards_is_order_deterministic() {
        let len = 37;
        let n_shards = 5;
        // adversarial magnitudes: reordering these changes the f32 sum
        let mut shards = vec![0.0f32; n_shards * len];
        for s in 0..n_shards {
            for c in 0..len {
                shards[s * len + c] =
                    (1.0 + c as f32) * 10f32.powi(s as i32 - 2) * if s % 2 == 0 { 1.0 } else { -1.0 };
            }
        }
        let mut want = vec![0.5f32; len];
        for s in 0..n_shards {
            for c in 0..len {
                want[c] += shards[s * len + c];
            }
        }
        for threads in [1usize, 2, 4] {
            let pool = ThreadPool::new(threads);
            let mut out = vec![0.5f32; len];
            reduce_shards(&pool, &shards, n_shards, &mut out);
            // bitwise equality, not approximate
            assert_eq!(out, want, "threads = {threads}");
        }
    }

    #[test]
    #[should_panic]
    fn reduce_shards_rejects_length_mismatch() {
        let pool = ThreadPool::new(1);
        let shards = vec![0.0f32; 7];
        let mut out = vec![0.0f32; 3];
        reduce_shards(&pool, &shards, 2, &mut out);
    }

    #[test]
    fn queue_is_fifo() {
        let q = BoundedQueue::new(8);
        for i in 0..5 {
            q.push(i).unwrap();
        }
        assert_eq!(q.len(), 5);
        for i in 0..5 {
            assert_eq!(q.pop(), Some(i));
        }
    }

    #[test]
    fn queue_push_blocks_at_capacity_until_pop() {
        let q = std::sync::Arc::new(BoundedQueue::new(2));
        q.push(1).unwrap();
        q.push(2).unwrap();
        let q2 = q.clone();
        let producer = std::thread::spawn(move || q2.push(3));
        // the producer must be parked until a slot frees up
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some(1));
        producer.join().unwrap().unwrap();
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
    }

    #[test]
    fn try_push_sheds_at_capacity_instead_of_blocking() {
        let q = BoundedQueue::new(2);
        assert_eq!(q.try_push(1), TryPush::Pushed(1));
        assert_eq!(q.push(2), Ok(2)); // blocking push reports depth too
        // full: the item comes straight back, no parking
        assert_eq!(q.try_push(3), TryPush::Full(3));
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.try_push(3), TryPush::Pushed(2));
        q.close();
        assert_eq!(q.try_push(4), TryPush::Closed(4));
        // shedding never lost an accepted item
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn queue_close_drains_then_ends() {
        let q = BoundedQueue::new(4);
        q.push("a").unwrap();
        q.push("b").unwrap();
        q.close();
        assert_eq!(q.push("c"), Err("c"));
        assert_eq!(q.pop(), Some("a"));
        assert_eq!(q.pop(), Some("b"));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn queue_close_wakes_blocked_consumer() {
        let q = std::sync::Arc::new(BoundedQueue::<u32>::new(4));
        let q2 = q.clone();
        let consumer = std::thread::spawn(move || q2.pop());
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        assert_eq!(consumer.join().unwrap(), None);
    }

    #[test]
    fn queue_pop_deadline_times_out_then_delivers() {
        let q = BoundedQueue::<u32>::new(4);
        let t0 = Instant::now();
        let r = q.pop_deadline(t0 + std::time::Duration::from_millis(15));
        assert_eq!(r, PopResult::TimedOut);
        assert!(t0.elapsed() >= std::time::Duration::from_millis(10));
        q.push(7).unwrap();
        assert_eq!(
            q.pop_deadline(Instant::now() + std::time::Duration::from_millis(100)),
            PopResult::Item(7)
        );
        q.close();
        assert_eq!(
            q.pop_deadline(Instant::now() + std::time::Duration::from_millis(5)),
            PopResult::Closed
        );
    }

    #[test]
    fn queue_pop_deadline_prefers_items_over_expired_deadline() {
        // audit pins: an available item wins even when the deadline is
        // already in the past — the item check precedes the clock check
        let q = BoundedQueue::new(4);
        q.push(1u32).unwrap();
        q.push(2).unwrap();
        let past = Instant::now() - std::time::Duration::from_millis(50);
        assert_eq!(q.pop_deadline(past), PopResult::Item(1));
        assert_eq!(q.pop_deadline(past), PopResult::Item(2));
        // drained + open + past deadline -> TimedOut, not a hang
        assert_eq!(q.pop_deadline(past), PopResult::TimedOut);
    }

    #[test]
    fn queue_pop_deadline_drains_closed_queue_before_reporting_closed() {
        // Closed is only reported once the queue is also empty; queued
        // items survive close() and beat both the clock and the flag
        let q = BoundedQueue::new(4);
        q.push(9u32).unwrap();
        q.close();
        let past = Instant::now() - std::time::Duration::from_millis(1);
        assert_eq!(q.pop_deadline(past), PopResult::Item(9));
        assert_eq!(q.pop_deadline(past), PopResult::Closed);
    }

    #[test]
    fn queue_pop_deadline_wakes_for_late_producer() {
        // a push while the consumer is parked inside wait_timeout must
        // deliver the item (the loop re-checks items after every wake,
        // so spurious wakeups and real notifies behave alike)
        let q = std::sync::Arc::new(BoundedQueue::<u32>::new(2));
        let q2 = q.clone();
        let consumer = std::thread::spawn(move || {
            q2.pop_deadline(Instant::now() + std::time::Duration::from_secs(30))
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.push(42).unwrap();
        assert_eq!(consumer.join().unwrap(), PopResult::Item(42));
    }

    #[test]
    fn queue_mpmc_delivers_every_item_once() {
        let q = std::sync::Arc::new(BoundedQueue::new(3));
        let seen = std::sync::Arc::new(Mutex::new(Vec::new()));
        let mut consumers = Vec::new();
        for _ in 0..3 {
            let (q2, s2) = (q.clone(), seen.clone());
            consumers.push(std::thread::spawn(move || {
                while let Some(v) = q2.pop() {
                    s2.lock().unwrap().push(v);
                }
            }));
        }
        // scaled under Miri: contention, not volume, is what this checks
        let per: u32 = if cfg!(miri) { 8 } else { 50 };
        let mut producers = Vec::new();
        for p in 0..2u32 {
            let q2 = q.clone();
            producers.push(std::thread::spawn(move || {
                for i in 0..per {
                    q2.push(p * 100 + i).unwrap();
                }
            }));
        }
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        for c in consumers {
            c.join().unwrap();
        }
        let mut got = seen.lock().unwrap().clone();
        got.sort_unstable();
        let mut want: Vec<u32> = (0..per).chain(100..100 + per).collect();
        want.sort_unstable();
        assert_eq!(got, want);
    }
}
