//! Deterministic pseudo-random generation (offline build: no `rand` crate).
//!
//! xoshiro256++ seeded through splitmix64, plus the samplers the trainer
//! needs: uniforms, Gaussians (Box–Muller), index permutations, and
//! weighted categorical sampling (for the Random Sampling sketch and MVS-
//! style row subsampling). Every stochastic component in the library draws
//! from an explicitly seeded `Rng`, so training runs are reproducible
//! bit-for-bit for a given seed.

/// xoshiro256++ PRNG (public domain algorithm by Blackman & Vigna).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box–Muller variate
    spare: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator; any u64 (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Derive an independent stream (for per-fold / per-round RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n). n must be > 0.
    #[inline]
    pub fn next_below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free variant is overkill here;
        // 53-bit uniform scaled is unbiased enough for n << 2^32.
        (self.next_f64() * n as f64) as usize % n
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn next_gaussian(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        loop {
            let u1 = self.next_f64();
            let u2 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    /// Fill `out` with N(0, sigma^2) f32 samples.
    pub fn fill_gaussian(&mut self, out: &mut [f32], sigma: f64) {
        for v in out.iter_mut() {
            *v = (self.next_gaussian() * sigma) as f32;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// `k` indices sampled without replacement from [0, n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<u32> {
        let mut idx: Vec<u32> = (0..n as u32).collect();
        let k = k.min(n);
        for i in 0..k {
            let j = i + self.next_below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// One draw from a categorical distribution given *cumulative* weights
    /// (ascending, last = total). Used by the Random Sampling sketch.
    pub fn next_categorical(&mut self, cumsum: &[f64]) -> usize {
        let total = *cumsum.last().expect("empty cumsum");
        debug_assert!(total > 0.0);
        let u = self.next_f64() * total;
        match cumsum.binary_search_by(|w| w.partial_cmp(&u).unwrap()) {
            Ok(i) => (i + 1).min(cumsum.len() - 1),
            Err(i) => i,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Rng::new(7);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.next_gaussian();
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn next_below_bounds() {
        let mut r = Rng::new(9);
        for n in [1usize, 2, 3, 17, 1000] {
            for _ in 0..200 {
                assert!(r.next_below(n) < n);
            }
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_unique() {
        let mut r = Rng::new(13);
        let idx = r.sample_indices(50, 20);
        assert_eq!(idx.len(), 20);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 20);
        assert!(s.iter().all(|&i| i < 50));
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(17);
        // weights 1:3 -> ~25%/75%
        let cumsum = [1.0, 4.0];
        let mut counts = [0usize; 2];
        for _ in 0..8000 {
            counts[r.next_categorical(&cumsum)] += 1;
        }
        let frac = counts[1] as f64 / 8000.0;
        assert!((frac - 0.75).abs() < 0.03, "frac={frac}");
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(5);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
