//! Minimal error type for the runtime/engine plumbing (offline build: no
//! `anyhow`). A string-backed error that implements `std::error::Error`,
//! so `?` converts it into `Box<dyn Error>` at the CLI boundary.

use std::fmt;

/// String-backed error used across [`crate::runtime`] and
/// [`crate::engine::XlaEngine`].
#[derive(Debug)]
pub struct Error(String);

impl Error {
    /// Build an error from anything displayable.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error(m.to_string())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(s: String) -> Error {
        Error(s)
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Error {
        Error(s.to_string())
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error(e.to_string())
    }
}

/// Result alias used by the runtime layer.
pub type Result<T> = std::result::Result<T, Error>;

/// `ensure!(cond, "format", args...)` — early-return an [`Error`] when the
/// condition fails (the `anyhow::ensure!` shape the runtime code uses).
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::util::error::Error::msg(format!($($arg)+)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn displays_message_and_boxes() {
        let e = Error::msg(format!("bad {}", 7));
        assert_eq!(e.to_string(), "bad 7");
        let b: Box<dyn std::error::Error> = Box::new(e);
        assert_eq!(b.to_string(), "bad 7");
    }

    fn ensured(x: usize) -> Result<usize> {
        crate::ensure!(x < 10, "x too big: {x}");
        Ok(x)
    }

    #[test]
    fn ensure_macro_early_returns() {
        assert_eq!(ensured(3).unwrap(), 3);
        assert!(ensured(30).is_err());
    }
}
