//! Named fault points for deterministic chaos testing.
//!
//! Production code marks its failure-relevant sites with
//! [`point`] (`fault::point("serve.worker.score")` — infallible sites,
//! where only `panic`/`delay` make sense) or [`failpoint`]
//! (`fault::failpoint("serve.swap.load")?` — fallible sites, where an
//! injected `fail` surfaces as an `Err`). In a normal build both
//! compile to empty `#[inline(always)]` functions: no globals, no
//! branches, zero cost. Under the `fault-injection` cargo feature the
//! hooks consult the installed [`FaultPlan`], so a chaos test can make
//! a worker panic on exactly the third request it scores, or a model
//! hot-swap fail on its first load attempt — **reproducibly**. All
//! randomness comes from [`crate::util::rng::Rng`] seeded by the plan,
//! so a `(plan, seed)` pair replays bit-for-bit.
//!
//! Whole-process runs (the `sketchboost serve` binary under a chaos
//! harness) read the plan from the `SB_FAULT_PLAN` environment
//! variable, seeded by `SB_FAULT_SEED` (default 0). In-process tests
//! use [`install`], which also serializes plan-using tests through a
//! global lock — fault points are process-global, so two concurrent
//! tests with different plans would otherwise contaminate each other.
//!
//! ## Plan grammar
//!
//! Entries are separated by `;`:
//!
//! ```text
//! <point>:<action>[<trigger>]
//!   action  := panic | fail | delay-<ms>
//!   trigger := @<k>    fire on exactly the k-th hit (1-based)
//!            | @<k>+   fire on the k-th hit and every one after
//!            | %<p>    fire each hit with probability p (seeded rng)
//!            | (none)  fire on every hit
//! ```
//!
//! Example: `serve.worker.score:panic@3;serve.swap.load:fail@1` — the
//! scoring worker panics on the third request it processes, and the
//! first hot-swap load attempt fails.
//!
//! ## Registered points
//!
//! | point                | kind      | effect of firing                         |
//! |----------------------|-----------|------------------------------------------|
//! | `serve.worker.score` | failpoint | per-request scoring (panic → `!internal`)|
//! | `serve.swap.load`    | failpoint | model hot-swap load (fail → keep old)    |

use std::time::Duration;

use crate::util::rng::Rng;

/// FNV-1a 64-bit over `bytes`, continuing from `state`. Used to derive
/// per-point rng streams and by the hot-swap watcher's content
/// fingerprint — a stable, dependency-free hash, not a cryptographic
/// one.
pub fn fnv1a64_with(state: u64, bytes: &[u8]) -> u64 {
    let mut h = state;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// FNV-1a 64-bit from the standard offset basis.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    fnv1a64_with(0xcbf29ce484222325, bytes)
}

/// What an armed fault point does when its trigger fires.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FaultAction {
    /// Panic at the point (`point` and `failpoint`).
    Panic,
    /// Return an injected error (`failpoint` only; ignored by `point`,
    /// which has no error channel).
    Fail,
    /// Sleep for the given duration, then continue normally.
    Delay(Duration),
}

/// When a rule fires, relative to the per-rule hit counter.
#[derive(Clone, Debug, PartialEq)]
enum Trigger {
    Always,
    /// Exactly the k-th hit (1-based).
    Nth(u64),
    /// The k-th hit and every one after.
    From(u64),
    /// Each hit independently with probability p, drawn from the
    /// rule's seeded rng stream.
    Prob(f64),
}

#[derive(Clone, Debug)]
struct Rule {
    point: String,
    action: FaultAction,
    trigger: Trigger,
    hits: u64,
    rng: Rng,
}

/// A parsed, seeded fault schedule. Deterministic: the fire pattern is
/// a pure function of `(spec, seed, hit order)` — counter triggers
/// (`@k`, `@k+`) do not even depend on the seed.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    rules: Vec<Rule>,
}

impl FaultPlan {
    /// A plan with no rules: every fault point is a no-op. Installing
    /// it still takes the global test lock, which is how fault-free
    /// serve tests shield themselves from concurrently installed plans.
    pub fn empty() -> FaultPlan {
        FaultPlan { rules: Vec::new() }
    }

    /// Parse a plan from the grammar in the module docs. Each rule's
    /// probability stream is seeded from `(seed, point name)`, so two
    /// plans parsed from the same `(spec, seed)` replay identically.
    pub fn parse(spec: &str, seed: u64) -> Result<FaultPlan, String> {
        let mut rules = Vec::new();
        for entry in spec.split(';') {
            let entry = entry.trim();
            if entry.is_empty() {
                continue;
            }
            let (point, rest) = entry
                .split_once(':')
                .ok_or_else(|| format!("fault rule {entry:?}: expected <point>:<action>"))?;
            let point = point.trim();
            if point.is_empty() {
                return Err(format!("fault rule {entry:?}: empty point name"));
            }
            let rest = rest.trim();
            let (action_str, trigger) = if let Some((a, t)) = rest.split_once('@') {
                let trigger = match t.strip_suffix('+') {
                    Some(k) => Trigger::From(
                        k.parse().map_err(|_| format!("fault rule {entry:?}: bad @{t}"))?,
                    ),
                    None => Trigger::Nth(
                        t.parse().map_err(|_| format!("fault rule {entry:?}: bad @{t}"))?,
                    ),
                };
                if let Trigger::Nth(0) | Trigger::From(0) = trigger {
                    return Err(format!("fault rule {entry:?}: hit counts are 1-based"));
                }
                (a, trigger)
            } else if let Some((a, p)) = rest.split_once('%') {
                let p: f64 =
                    p.parse().map_err(|_| format!("fault rule {entry:?}: bad %{p}"))?;
                if !(0.0..=1.0).contains(&p) {
                    return Err(format!("fault rule {entry:?}: probability outside [0, 1]"));
                }
                (a, Trigger::Prob(p))
            } else {
                (rest, Trigger::Always)
            };
            let action = match action_str.trim() {
                "panic" => FaultAction::Panic,
                "fail" => FaultAction::Fail,
                a => match a.strip_prefix("delay-") {
                    Some(ms) => FaultAction::Delay(Duration::from_millis(
                        ms.parse()
                            .map_err(|_| format!("fault rule {entry:?}: bad delay {ms:?}"))?,
                    )),
                    None => {
                        return Err(format!(
                            "fault rule {entry:?}: unknown action {a:?} \
                             (expected panic | fail | delay-<ms>)"
                        ))
                    }
                },
            };
            rules.push(Rule {
                point: point.to_string(),
                action,
                trigger,
                hits: 0,
                rng: Rng::new(seed ^ fnv1a64(point.as_bytes())),
            });
        }
        Ok(FaultPlan { rules })
    }

    /// Record one hit at `name` on every matching rule; the first rule
    /// whose trigger fires returns its action.
    pub fn hit(&mut self, name: &str) -> Option<FaultAction> {
        let mut fired = None;
        for rule in self.rules.iter_mut() {
            if rule.point != name {
                continue;
            }
            rule.hits += 1;
            let fire = match rule.trigger {
                Trigger::Always => true,
                Trigger::Nth(k) => rule.hits == k,
                Trigger::From(k) => rule.hits >= k,
                Trigger::Prob(p) => rule.rng.next_f64() < p,
            };
            if fire && fired.is_none() {
                fired = Some(rule.action.clone());
            }
        }
        fired
    }

    /// How many times `name` has been hit (max across its rules; 0 if
    /// the plan has no rule for it — unplanned points are not counted).
    pub fn hits(&self, name: &str) -> u64 {
        self.rules
            .iter()
            .filter(|r| r.point == name)
            .map(|r| r.hits)
            .max()
            .unwrap_or(0)
    }

    /// Number of rules in the plan.
    pub fn len(&self) -> usize {
        self.rules.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rules.is_empty()
    }
}

// ---------------------------------------------------------------------
// the global hooks
// ---------------------------------------------------------------------

/// Hit an infallible fault point. No-op unless the `fault-injection`
/// feature is on and the active plan fires `panic` or `delay` here
/// (`fail` is ignored — this site has no error channel).
#[cfg(not(feature = "fault-injection"))]
#[inline(always)]
pub fn point(_name: &str) {}

/// Hit a fallible fault point. Always `Ok(())` unless the
/// `fault-injection` feature is on and the active plan fires here.
#[cfg(not(feature = "fault-injection"))]
#[inline(always)]
pub fn failpoint(_name: &str) -> Result<(), String> {
    Ok(())
}

#[cfg(feature = "fault-injection")]
pub use active::{failpoint, hits, install, point, FaultGuard};

#[cfg(feature = "fault-injection")]
mod active {
    use super::{FaultAction, FaultPlan};
    use std::sync::{Mutex, MutexGuard, Once};

    /// The installed plan (`None` until env init or `install`).
    static PLAN: Mutex<Option<FaultPlan>> = Mutex::new(None);
    /// One-shot initialization from `SB_FAULT_PLAN` / `SB_FAULT_SEED`.
    static ENV_INIT: Once = Once::new();
    /// Serializes in-process tests that install plans.
    static TEST_LOCK: Mutex<()> = Mutex::new(());

    fn plan_guard() -> MutexGuard<'static, Option<FaultPlan>> {
        ENV_INIT.call_once(|| {
            if let Ok(spec) = std::env::var("SB_FAULT_PLAN") {
                let seed = std::env::var("SB_FAULT_SEED")
                    .ok()
                    .and_then(|s| s.parse().ok())
                    .unwrap_or(0);
                match FaultPlan::parse(&spec, seed) {
                    Ok(p) => *PLAN.lock().unwrap() = Some(p),
                    Err(e) => eprintln!("[fault] ignoring bad SB_FAULT_PLAN: {e}"),
                }
            }
        });
        // a panic injected *while holding* this lock is impossible —
        // actions fire after the guard drops — but recover anyway
        PLAN.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn fire(name: &str) -> Option<FaultAction> {
        plan_guard().as_mut().and_then(|p| p.hit(name))
    }

    /// See the no-op twin for the contract.
    pub fn point(name: &str) {
        match fire(name) {
            Some(FaultAction::Panic) => panic!("injected fault: {name}"),
            Some(FaultAction::Delay(d)) => std::thread::sleep(d),
            Some(FaultAction::Fail) | None => {}
        }
    }

    /// See the no-op twin for the contract.
    pub fn failpoint(name: &str) -> Result<(), String> {
        match fire(name) {
            Some(FaultAction::Panic) => panic!("injected fault: {name}"),
            Some(FaultAction::Delay(d)) => {
                std::thread::sleep(d);
                Ok(())
            }
            Some(FaultAction::Fail) => Err(format!("injected fault: {name}")),
            None => Ok(()),
        }
    }

    /// Hit count recorded for `name` by the active plan (0 if no plan
    /// or no rule — assertions should plan the points they count).
    pub fn hits(name: &str) -> u64 {
        plan_guard().as_ref().map_or(0, |p| p.hits(name))
    }

    /// Keeps an installed plan active (and other plan users excluded)
    /// until dropped.
    pub struct FaultGuard {
        _lock: MutexGuard<'static, ()>,
    }

    impl Drop for FaultGuard {
        fn drop(&mut self) {
            *PLAN.lock().unwrap_or_else(|e| e.into_inner()) = None;
        }
    }

    /// Install `plan` as the process-wide fault schedule until the
    /// returned guard drops. Tests that exercise fault points — even
    /// with an [`FaultPlan::empty`] plan — must hold one of these, so
    /// plans never overlap across concurrently running tests.
    pub fn install(plan: FaultPlan) -> FaultGuard {
        let lock = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        // mark env init done so a later first-hit cannot clobber the
        // installed plan with the environment one
        ENV_INIT.call_once(|| {});
        *PLAN.lock().unwrap_or_else(|e| e.into_inner()) = Some(plan);
        FaultGuard { _lock: lock }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_readme_grammar() {
        let p = FaultPlan::parse("serve.worker.score:panic@3;serve.swap.load:fail@1", 0).unwrap();
        assert_eq!(p.len(), 2);
        let p = FaultPlan::parse("a:delay-50;b:fail@2+;c:panic%0.5; ;", 7).unwrap();
        assert_eq!(p.len(), 3);
        assert!(FaultPlan::parse("", 0).unwrap().is_empty());
    }

    #[test]
    fn rejects_malformed_rules() {
        for bad in [
            "noaction",
            "p:explode",
            "p:panic@zero",
            "p:panic@0",
            "p:fail%1.5",
            "p:delay-abc",
            ":panic",
        ] {
            assert!(FaultPlan::parse(bad, 0).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn nth_fires_exactly_once_and_from_fires_onward() {
        let mut p = FaultPlan::parse("x:panic@3;y:fail@2+", 0).unwrap();
        let fires: Vec<bool> = (0..5).map(|_| p.hit("x").is_some()).collect();
        assert_eq!(fires, [false, false, true, false, false]);
        assert_eq!(p.hits("x"), 5);
        let fires: Vec<bool> = (0..4).map(|_| p.hit("y").is_some()).collect();
        assert_eq!(fires, [false, true, true, true]);
        assert!(p.hit("unplanned").is_none());
        assert_eq!(p.hits("unplanned"), 0);
    }

    #[test]
    fn always_fires_every_hit_with_the_right_action() {
        let mut p = FaultPlan::parse("x:delay-10", 0).unwrap();
        for _ in 0..3 {
            assert_eq!(p.hit("x"), Some(FaultAction::Delay(Duration::from_millis(10))));
        }
    }

    /// The probabilistic trigger must replay bit-for-bit for a seed and
    /// diverge across seeds — the heart of "every chaos test is
    /// reproducible".
    #[test]
    fn probabilistic_schedule_is_seed_deterministic() {
        let pattern = |seed: u64| -> Vec<bool> {
            let mut p = FaultPlan::parse("x:fail%0.35", seed).unwrap();
            (0..200).map(|_| p.hit("x").is_some()).collect()
        };
        assert_eq!(pattern(42), pattern(42));
        assert_ne!(pattern(42), pattern(43));
        let fired = pattern(42).iter().filter(|&&f| f).count();
        assert!((30..=110).contains(&fired), "p=0.35 over 200 hits fired {fired}");
    }

    #[test]
    fn fnv_is_stable_and_content_sensitive() {
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), fnv1a64(b"a"));
        assert_ne!(fnv1a64(b"model-a"), fnv1a64(b"model-b"));
        // chaining is the same as hashing the concatenation
        assert_eq!(fnv1a64_with(fnv1a64(b"ab"), b"cd"), fnv1a64(b"abcd"));
    }
}
