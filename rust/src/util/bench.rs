//! Micro/macro benchmark harness (offline build: no `criterion`).
//!
//! `benches/*.rs` are `harness = false` binaries built on this module:
//! warmup + timed repetitions, robust summary statistics, and aligned
//! markdown table rendering so every bench prints the same rows/series
//! the paper's tables and figures report. Results can also be dumped to
//! JSON under `results/` for EXPERIMENTS.md.

use std::time::Instant;

use crate::util::json::Json;

/// Summary of repeated timed runs, in seconds.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub label: String,
    pub reps: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub median: f64,
}

impl Measurement {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("label", Json::Str(self.label.clone()));
        o.set("reps", Json::Num(self.reps as f64));
        o.set("mean_s", Json::Num(self.mean));
        o.set("std_s", Json::Num(self.std));
        o.set("min_s", Json::Num(self.min));
        o.set("median_s", Json::Num(self.median));
        o
    }
}

/// Time `f` with `warmup` unmeasured and `reps` measured repetitions.
pub fn bench<F: FnMut()>(label: &str, warmup: usize, reps: usize, mut f: F) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    summarize(label, &times)
}

/// Time a single run (for end-to-end training cells where reps are too
/// expensive; the paper's Table 2/4 are single-fold timings too).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

pub fn summarize(label: &str, times: &[f64]) -> Measurement {
    let reps = times.len();
    let mean = times.iter().sum::<f64>() / reps as f64;
    let var = if reps > 1 {
        times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / (reps - 1) as f64
    } else {
        0.0
    };
    let mut sorted = times.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Measurement {
        label: label.to_string(),
        reps,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        median: sorted[reps / 2],
    }
}

/// Human-scale duration formatting.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.1}ms", s * 1e3)
    } else if s < 120.0 {
        format!("{s:.2}s")
    } else {
        format!("{:.1}min", s / 60.0)
    }
}

/// Aligned markdown-style table printer for bench reports.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut w = vec![0usize; ncol];
        for c in 0..ncol {
            w[c] = self.header[c].len();
            for r in &self.rows {
                w[c] = w[c].max(r[c].len());
            }
        }
        let mut s = String::new();
        let line = |cells: &[String], w: &[usize]| -> String {
            let mut l = String::from("|");
            for (c, cell) in cells.iter().enumerate() {
                l.push_str(&format!(" {:<width$} |", cell, width = w[c]));
            }
            l.push('\n');
            l
        };
        s.push_str(&line(&self.header, &w));
        let mut sep = String::from("|");
        for width in &w {
            sep.push_str(&format!("{}-|", "-".repeat(width + 2 - 1)));
        }
        sep.push('\n');
        s.push_str(&sep);
        for r in &self.rows {
            s.push_str(&line(r, &w));
        }
        s
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Write a bench-result JSON file under `results/` (created on demand).
pub fn write_results(name: &str, value: &Json) -> std::io::Result<std::path::PathBuf> {
    let dir = std::path::Path::new("results");
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("{name}.json"));
    std::fs::write(&path, value.to_pretty())?;
    Ok(path)
}

/// Write a machine-readable bench-result JSON at the *workspace root*
/// (the committed `BENCH_*.json` perf trajectory; CI uploads it as a
/// workflow artifact).
///
/// The root is resolved at runtime by walking up from the current
/// directory to the first ancestor containing both `Cargo.toml` and the
/// `rust/` package dir (cargo runs benches with the package dir as cwd,
/// so this is normally one level up). Only if no ancestor matches —
/// e.g. the binary is run outside any checkout — does it fall back to
/// the compile-time `CARGO_MANIFEST_DIR`, which may not exist on a
/// machine other than the build host.
pub fn write_results_at_root(
    file_name: &str,
    value: &Json,
) -> std::io::Result<std::path::PathBuf> {
    let runtime_root = std::env::current_dir().ok().and_then(|cwd| {
        cwd.ancestors()
            .find(|a| a.join("Cargo.toml").is_file() && a.join("rust").is_dir())
            .map(|a| a.to_path_buf())
    });
    let root = runtime_root.unwrap_or_else(|| {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .unwrap_or_else(|| std::path::Path::new("."))
            .to_path_buf()
    });
    let path = root.join(file_name);
    std::fs::write(&path, value.to_pretty())?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_reps() {
        let mut n = 0usize;
        let m = bench("x", 2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(m.reps, 5);
        assert!(m.mean >= 0.0 && m.min <= m.median);
    }

    #[test]
    fn summarize_stats() {
        let m = summarize("s", &[1.0, 2.0, 3.0]);
        assert!((m.mean - 2.0).abs() < 1e-12);
        assert!((m.std - 1.0).abs() < 1e-12);
        assert_eq!(m.min, 1.0);
        assert_eq!(m.median, 2.0);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_secs(3e-9).ends_with("ns"));
        assert!(fmt_secs(3e-5).ends_with("µs"));
        assert!(fmt_secs(3e-2).ends_with("ms"));
        assert!(fmt_secs(3.0).ends_with('s'));
        assert!(fmt_secs(300.0).ends_with("min"));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "time"]);
        t.row(&["a".into(), "1.0s".into()]);
        t.row(&["longer".into(), "2.0s".into()]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
    }

    #[test]
    #[should_panic]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn measurement_json() {
        let m = summarize("lbl", &[0.5]);
        let j = m.to_json();
        assert_eq!(j.get("label").unwrap().as_str().unwrap(), "lbl");
        assert_eq!(j.get("reps").unwrap().as_usize().unwrap(), 1);
    }
}
