//! Substrate utilities built from scratch for the offline environment:
//! RNG (no `rand`), JSON (no `serde`), CLI parsing (no `clap`), bench
//! harness (no `criterion`), a property-testing helper (no `proptest`),
//! a scoped thread pool (no `rayon`), and a string error (no `anyhow`).

pub mod bench;
pub mod cli;
pub mod error;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod threading;
