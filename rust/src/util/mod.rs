//! Substrate utilities built from scratch for the offline environment:
//! RNG (no `rand`), JSON (no `serde`), CLI parsing (no `clap`), bench
//! harness (no `criterion`), a property-testing helper (no `proptest`),
//! a scoped thread pool (no `rayon`), a string error (no `anyhow`),
//! and named fault points for chaos testing (no `fail` crate).

pub mod bench;
pub mod cli;
pub mod error;
pub mod fault;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod threading;
