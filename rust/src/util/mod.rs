//! Substrate utilities built from scratch for the offline environment:
//! RNG (no `rand`), JSON (no `serde`), CLI parsing (no `clap`), bench
//! harness (no `criterion`), and a property-testing helper (no `proptest`).

pub mod bench;
pub mod cli;
pub mod json;
pub mod proptest;
pub mod rng;
