//! Multivariate decision tree representation (the paper's single-tree
//! strategy: one tree predicts all `d` outputs; each leaf holds a vector
//! value v_j in R^d, eq. 3).

use crate::data::binning::BinnedDataset;

/// Internal split node. Children encode either another internal node
/// (index >= 0 into `Tree::nodes`) or a leaf (`!leaf_id`, i.e. negative).
#[derive(Clone, Debug, PartialEq)]
pub struct TreeNode {
    pub feature: u32,
    /// split on quantized codes: left iff code <= bin
    pub bin: u8,
    /// equivalent raw-value threshold: left iff x <= threshold (NaN left)
    pub threshold: f32,
    pub left: i32,
    pub right: i32,
    /// impurity improvement this split achieved (for diagnostics)
    pub gain: f32,
}

/// A fitted multivariate decision tree.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Tree {
    pub n_outputs: usize,
    /// empty iff the tree is a single leaf
    pub nodes: Vec<TreeNode>,
    /// row-major [n_leaves, n_outputs]
    pub leaf_values: Vec<f32>,
    pub n_leaves: usize,
}

#[inline]
pub fn is_leaf(child: i32) -> bool {
    child < 0
}

#[inline]
pub fn leaf_id(child: i32) -> usize {
    !child as usize
}

#[inline]
pub fn encode_leaf(id: usize) -> i32 {
    !(id as i32)
}

impl Tree {
    /// Leaf index for a row of the *binned* training matrix.
    pub fn leaf_for_binned(&self, binned: &BinnedDataset, row: usize) -> usize {
        if self.nodes.is_empty() {
            return 0;
        }
        let mut node = 0i32;
        loop {
            let nd = &self.nodes[node as usize];
            let code = binned.codes[nd.feature as usize * binned.n_rows + row];
            let child = if code <= nd.bin { nd.left } else { nd.right };
            if is_leaf(child) {
                return leaf_id(child);
            }
            node = child;
        }
    }

    /// Leaf index for a raw (unbinned) feature row.
    /// NaN goes left, matching the binning policy (NaN -> bin 0).
    pub fn leaf_for_raw(&self, row: &[f32]) -> usize {
        if self.nodes.is_empty() {
            return 0;
        }
        let mut node = 0i32;
        loop {
            let nd = &self.nodes[node as usize];
            let x = row[nd.feature as usize];
            let go_left = x.is_nan() || x <= nd.threshold;
            let child = if go_left { nd.left } else { nd.right };
            if is_leaf(child) {
                return leaf_id(child);
            }
            node = child;
        }
    }

    /// Add this tree's contribution for a raw feature row into `out`.
    #[inline]
    pub fn predict_into(&self, row: &[f32], out: &mut [f32]) {
        let leaf = self.leaf_for_raw(row);
        let v = &self.leaf_values[leaf * self.n_outputs..(leaf + 1) * self.n_outputs];
        for (o, &lv) in out.iter_mut().zip(v.iter()) {
            *o += lv;
        }
    }

    /// Scale all leaf values (the trainer applies the learning rate here).
    pub fn scale_leaves(&mut self, factor: f32) {
        for v in self.leaf_values.iter_mut() {
            *v *= factor;
        }
    }

    /// Tree depth (0 for a single-leaf tree).
    pub fn depth(&self) -> usize {
        fn walk(nodes: &[TreeNode], node: i32) -> usize {
            if is_leaf(node) {
                return 0;
            }
            let nd = &nodes[node as usize];
            1 + walk(nodes, nd.left).max(walk(nodes, nd.right))
        }
        if self.nodes.is_empty() {
            0
        } else {
            walk(&self.nodes, 0)
        }
    }

    /// Structural invariants; used by tests and debug assertions.
    pub fn validate(&self) -> Result<(), String> {
        if self.leaf_values.len() != self.n_leaves * self.n_outputs {
            return Err(format!(
                "leaf buffer {} != {} * {}",
                self.leaf_values.len(),
                self.n_leaves,
                self.n_outputs
            ));
        }
        if self.nodes.is_empty() {
            if self.n_leaves != 1 {
                return Err("stump must have exactly one leaf".into());
            }
            return Ok(());
        }
        // every node reachable exactly once; every leaf id used exactly once
        let mut node_seen = vec![false; self.nodes.len()];
        let mut leaf_seen = vec![false; self.n_leaves];
        let mut stack = vec![0i32];
        while let Some(c) = stack.pop() {
            if is_leaf(c) {
                let l = leaf_id(c);
                if l >= self.n_leaves {
                    return Err(format!("leaf id {l} out of range"));
                }
                if leaf_seen[l] {
                    return Err(format!("leaf {l} reached twice"));
                }
                leaf_seen[l] = true;
            } else {
                let i = c as usize;
                if i >= self.nodes.len() {
                    return Err(format!("node id {i} out of range"));
                }
                if node_seen[i] {
                    return Err(format!("node {i} reached twice"));
                }
                node_seen[i] = true;
                stack.push(self.nodes[i].left);
                stack.push(self.nodes[i].right);
            }
        }
        if !node_seen.iter().all(|&s| s) {
            return Err("unreachable internal node".into());
        }
        if !leaf_seen.iter().all(|&s| s) {
            return Err("unused leaf id".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::{Dataset, Targets};

    /// x0 <= 0.5 ? leaf0 : (x1 <= 2.0 ? leaf1 : leaf2)
    fn toy_tree() -> Tree {
        Tree {
            n_outputs: 2,
            nodes: vec![
                TreeNode { feature: 0, bin: 3, threshold: 0.5, left: encode_leaf(0), right: 1, gain: 1.0 },
                TreeNode { feature: 1, bin: 1, threshold: 2.0, left: encode_leaf(1), right: encode_leaf(2), gain: 0.5 },
            ],
            leaf_values: vec![1.0, -1.0, 2.0, -2.0, 3.0, -3.0],
            n_leaves: 3,
        }
    }

    #[test]
    fn leaf_encoding_roundtrip() {
        for id in [0usize, 1, 5, 1000] {
            assert!(is_leaf(encode_leaf(id)));
            assert_eq!(leaf_id(encode_leaf(id)), id);
        }
        assert!(!is_leaf(0));
        assert!(!is_leaf(7));
    }

    #[test]
    fn raw_routing() {
        let t = toy_tree();
        assert_eq!(t.leaf_for_raw(&[0.0, 0.0]), 0);
        assert_eq!(t.leaf_for_raw(&[1.0, 1.0]), 1);
        assert_eq!(t.leaf_for_raw(&[1.0, 5.0]), 2);
        // boundary goes left
        assert_eq!(t.leaf_for_raw(&[0.5, 9.0]), 0);
        // NaN goes left at every node
        assert_eq!(t.leaf_for_raw(&[f32::NAN, 9.0]), 0);
        assert_eq!(t.leaf_for_raw(&[1.0, f32::NAN]), 1);
    }

    #[test]
    fn predict_accumulates() {
        let t = toy_tree();
        let mut out = vec![10.0f32, 20.0];
        t.predict_into(&[1.0, 5.0], &mut out);
        assert_eq!(out, vec![13.0, 17.0]);
    }

    #[test]
    fn binned_routing_matches_bins() {
        // one feature, codes: [0, 2, 4]; split at bin 1
        let ds = Dataset::new(
            3,
            1,
            vec![0.0, 2.0, 4.0],
            Targets::Regression { values: vec![0.0; 3], n_targets: 1 },
        );
        let binned = BinnedDataset::from_dataset(&ds, 8);
        let t = Tree {
            n_outputs: 1,
            nodes: vec![TreeNode {
                feature: 0,
                bin: binned.column(0)[0],
                threshold: 0.0,
                left: encode_leaf(0),
                right: encode_leaf(1),
                gain: 0.0,
            }],
            leaf_values: vec![-5.0, 5.0],
            n_leaves: 2,
        };
        assert_eq!(t.leaf_for_binned(&binned, 0), 0);
        assert_eq!(t.leaf_for_binned(&binned, 1), 1);
        assert_eq!(t.leaf_for_binned(&binned, 2), 1);
    }

    #[test]
    fn stump_routes_to_leaf_zero() {
        let t = Tree { n_outputs: 1, nodes: vec![], leaf_values: vec![7.0], n_leaves: 1 };
        assert_eq!(t.leaf_for_raw(&[1.0, 2.0]), 0);
        assert_eq!(t.depth(), 0);
        t.validate().unwrap();
    }

    #[test]
    fn depth_and_validate() {
        let t = toy_tree();
        assert_eq!(t.depth(), 2);
        t.validate().unwrap();
    }

    #[test]
    fn validate_catches_duplicate_leaf() {
        let mut t = toy_tree();
        t.nodes[1].right = encode_leaf(1); // leaf 1 twice, leaf 2 unused
        assert!(t.validate().is_err());
    }

    #[test]
    fn validate_catches_bad_buffer() {
        let mut t = toy_tree();
        t.leaf_values.pop();
        assert!(t.validate().is_err());
    }

    #[test]
    fn scale_leaves_applies_lr() {
        let mut t = toy_tree();
        t.scale_leaves(0.1);
        assert!((t.leaf_values[0] - 0.1).abs() < 1e-7);
        assert!((t.leaf_values[5] + 0.3).abs() < 1e-7);
    }
}
