//! Multivariate decision tree representation (the paper's single-tree
//! strategy: one tree predicts all `d` outputs; each leaf holds a vector
//! value v_j in R^d, eq. 3), with sparsity-aware routing: every split
//! carries a learned `default_left` direction for missing values, and
//! categorical splits route by category-*set* membership ([`CatSet`])
//! instead of a threshold.

use crate::data::binning::{BinnedDataset, ChunkCols, MISSING_BIN};

/// A set of category ids (0..=255) routed to the left child of a
/// categorical split — a fixed 256-bit bitset, `Copy` so routing and
/// the partition loop stay allocation-free.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CatSet {
    blocks: [u64; 4],
}

impl CatSet {
    pub fn new() -> CatSet {
        CatSet::default()
    }

    /// Build from category ids.
    pub fn from_ids<I: IntoIterator<Item = u32>>(ids: I) -> CatSet {
        let mut s = CatSet::new();
        for id in ids {
            s.insert(id);
        }
        s
    }

    pub fn insert(&mut self, id: u32) {
        assert!(id < 256, "category id {id} out of range");
        self.blocks[(id >> 6) as usize] |= 1u64 << (id & 63);
    }

    #[inline]
    pub fn contains(&self, id: u32) -> bool {
        id < 256 && (self.blocks[(id >> 6) as usize] >> (id & 63)) & 1 == 1
    }

    /// Membership test for a raw feature value: true iff `x` is exactly
    /// an integer category id in the set. Non-integer, negative,
    /// out-of-range, and NaN values are not members (NaN is handled by
    /// the split's `default_left` before this is consulted).
    #[inline]
    pub fn contains_value(&self, x: f32) -> bool {
        let id = x as i64;
        id >= 0 && id < 256 && id as f32 == x && self.contains(id as u32)
    }

    pub fn len(&self) -> usize {
        self.blocks.iter().map(|b| b.count_ones() as usize).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.blocks.iter().all(|&b| b == 0)
    }

    /// Ascending category ids (for serialization and display).
    pub fn ids(&self) -> impl Iterator<Item = u32> + '_ {
        (0u32..256).filter(move |&id| self.contains(id))
    }
}

/// Internal split node. Children encode either another internal node
/// (index >= 0 into `Tree::nodes`) or a leaf (`!leaf_id`, i.e. negative).
#[derive(Clone, Debug, PartialEq)]
pub struct TreeNode {
    pub feature: u32,
    /// numeric split on quantized codes: left iff 1 <= code <= bin
    /// (code 0 = missing routes per `default_left`); 0 for categorical
    pub bin: u8,
    /// numeric raw-value threshold: left iff x <= threshold; 0.0 for
    /// categorical splits (`cats` is authoritative there)
    pub threshold: f32,
    /// where missing values (NaN / code 0) go — learned per split
    pub default_left: bool,
    /// categorical split: the category-id set routed left (None = numeric)
    pub cats: Option<CatSet>,
    pub left: i32,
    pub right: i32,
    /// impurity improvement this split achieved (for diagnostics)
    pub gain: f32,
}

/// A fitted multivariate decision tree.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Tree {
    pub n_outputs: usize,
    /// empty iff the tree is a single leaf
    pub nodes: Vec<TreeNode>,
    /// row-major [n_leaves, n_outputs]
    pub leaf_values: Vec<f32>,
    pub n_leaves: usize,
}

#[inline]
pub fn is_leaf(child: i32) -> bool {
    child < 0
}

#[inline]
pub fn leaf_id(child: i32) -> usize {
    !child as usize
}

#[inline]
pub fn encode_leaf(id: usize) -> i32 {
    !(id as i32)
}

impl Tree {
    /// Leaf index for a row of the *binned* training matrix. Missing
    /// codes route by the split's learned default; categorical codes by
    /// set membership (code = category id + 1).
    pub fn leaf_for_binned(&self, binned: &BinnedDataset, row: usize) -> usize {
        if self.nodes.is_empty() {
            return 0;
        }
        let mut node = 0i32;
        loop {
            let nd = &self.nodes[node as usize];
            let code = binned.codes[nd.feature as usize * binned.n_rows + row];
            let go_left = if code == MISSING_BIN {
                nd.default_left
            } else {
                match &nd.cats {
                    Some(cats) => cats.contains(code as u32 - 1),
                    None => code <= nd.bin,
                }
            };
            let child = if go_left { nd.left } else { nd.right };
            if is_leaf(child) {
                return leaf_id(child);
            }
            node = child;
        }
    }

    /// [`Tree::leaf_for_binned`] against one resident chunk of an
    /// out-of-core source: identical routing, with codes read from the
    /// chunk's column-major slab. `row` must lie in the chunk's range.
    pub fn leaf_for_chunk(&self, cols: &ChunkCols<'_>, row: usize) -> usize {
        if self.nodes.is_empty() {
            return 0;
        }
        let mut node = 0i32;
        loop {
            let nd = &self.nodes[node as usize];
            let code = cols.code(nd.feature as usize, row);
            let go_left = if code == MISSING_BIN {
                nd.default_left
            } else {
                match &nd.cats {
                    Some(cats) => cats.contains(code as u32 - 1),
                    None => code <= nd.bin,
                }
            };
            let child = if go_left { nd.left } else { nd.right };
            if is_leaf(child) {
                return leaf_id(child);
            }
            node = child;
        }
    }

    /// Leaf index for a raw (unbinned) feature row. NaN routes by the
    /// split's learned `default_left`; categorical values (category ids)
    /// by set membership.
    pub fn leaf_for_raw(&self, row: &[f32]) -> usize {
        if self.nodes.is_empty() {
            return 0;
        }
        let mut node = 0i32;
        loop {
            let nd = &self.nodes[node as usize];
            let x = row[nd.feature as usize];
            let go_left = if x.is_nan() {
                nd.default_left
            } else {
                match &nd.cats {
                    Some(cats) => cats.contains_value(x),
                    None => x <= nd.threshold,
                }
            };
            let child = if go_left { nd.left } else { nd.right };
            if is_leaf(child) {
                return leaf_id(child);
            }
            node = child;
        }
    }

    /// Add this tree's contribution for a raw feature row into `out`.
    #[inline]
    pub fn predict_into(&self, row: &[f32], out: &mut [f32]) {
        let leaf = self.leaf_for_raw(row);
        let v = &self.leaf_values[leaf * self.n_outputs..(leaf + 1) * self.n_outputs];
        for (o, &lv) in out.iter_mut().zip(v.iter()) {
            *o += lv;
        }
    }

    /// Scale all leaf values (the trainer applies the learning rate here).
    pub fn scale_leaves(&mut self, factor: f32) {
        for v in self.leaf_values.iter_mut() {
            *v *= factor;
        }
    }

    /// Tree depth (0 for a single-leaf tree).
    pub fn depth(&self) -> usize {
        fn walk(nodes: &[TreeNode], node: i32) -> usize {
            if is_leaf(node) {
                return 0;
            }
            let nd = &nodes[node as usize];
            1 + walk(nodes, nd.left).max(walk(nodes, nd.right))
        }
        if self.nodes.is_empty() {
            0
        } else {
            walk(&self.nodes, 0)
        }
    }

    /// Structural invariants; used by tests and debug assertions.
    pub fn validate(&self) -> Result<(), String> {
        if self.leaf_values.len() != self.n_leaves * self.n_outputs {
            return Err(format!(
                "leaf buffer {} != {} * {}",
                self.leaf_values.len(),
                self.n_leaves,
                self.n_outputs
            ));
        }
        if self.nodes.is_empty() {
            if self.n_leaves != 1 {
                return Err("stump must have exactly one leaf".into());
            }
            return Ok(());
        }
        // every node reachable exactly once; every leaf id used exactly once
        let mut node_seen = vec![false; self.nodes.len()];
        let mut leaf_seen = vec![false; self.n_leaves];
        let mut stack = vec![0i32];
        while let Some(c) = stack.pop() {
            if is_leaf(c) {
                let l = leaf_id(c);
                if l >= self.n_leaves {
                    return Err(format!("leaf id {l} out of range"));
                }
                if leaf_seen[l] {
                    return Err(format!("leaf {l} reached twice"));
                }
                leaf_seen[l] = true;
            } else {
                let i = c as usize;
                if i >= self.nodes.len() {
                    return Err(format!("node id {i} out of range"));
                }
                if node_seen[i] {
                    return Err(format!("node {i} reached twice"));
                }
                node_seen[i] = true;
                stack.push(self.nodes[i].left);
                stack.push(self.nodes[i].right);
            }
        }
        if !node_seen.iter().all(|&s| s) {
            return Err("unreachable internal node".into());
        }
        if !leaf_seen.iter().all(|&s| s) {
            return Err("unused leaf id".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::{Dataset, Targets};

    /// x0 <= 0.5 ? leaf0 : (x1 <= 2.0 ? leaf1 : leaf2); missing left
    fn toy_tree() -> Tree {
        Tree {
            n_outputs: 2,
            nodes: vec![
                TreeNode { feature: 0, bin: 3, threshold: 0.5, default_left: true, cats: None, left: encode_leaf(0), right: 1, gain: 1.0 },
                TreeNode { feature: 1, bin: 1, threshold: 2.0, default_left: true, cats: None, left: encode_leaf(1), right: encode_leaf(2), gain: 0.5 },
            ],
            leaf_values: vec![1.0, -1.0, 2.0, -2.0, 3.0, -3.0],
            n_leaves: 3,
        }
    }

    #[test]
    fn leaf_encoding_roundtrip() {
        for id in [0usize, 1, 5, 1000] {
            assert!(is_leaf(encode_leaf(id)));
            assert_eq!(leaf_id(encode_leaf(id)), id);
        }
        assert!(!is_leaf(0));
        assert!(!is_leaf(7));
    }

    #[test]
    fn raw_routing() {
        let t = toy_tree();
        assert_eq!(t.leaf_for_raw(&[0.0, 0.0]), 0);
        assert_eq!(t.leaf_for_raw(&[1.0, 1.0]), 1);
        assert_eq!(t.leaf_for_raw(&[1.0, 5.0]), 2);
        // boundary goes left
        assert_eq!(t.leaf_for_raw(&[0.5, 9.0]), 0);
        // NaN follows default_left = true at every node here
        assert_eq!(t.leaf_for_raw(&[f32::NAN, 9.0]), 0);
        assert_eq!(t.leaf_for_raw(&[1.0, f32::NAN]), 1);
    }

    #[test]
    fn raw_routing_honors_default_right() {
        let mut t = toy_tree();
        t.nodes[0].default_left = false;
        // NaN at the root now goes right, then x1 routes normally
        assert_eq!(t.leaf_for_raw(&[f32::NAN, 1.0]), 1);
        assert_eq!(t.leaf_for_raw(&[f32::NAN, 5.0]), 2);
        t.nodes[1].default_left = false;
        assert_eq!(t.leaf_for_raw(&[1.0, f32::NAN]), 2);
    }

    #[test]
    fn cat_set_membership() {
        let s = CatSet::from_ids([0u32, 3, 200]);
        assert!(s.contains(0) && s.contains(3) && s.contains(200));
        assert!(!s.contains(1) && !s.contains(255));
        assert_eq!(s.len(), 3);
        assert_eq!(s.ids().collect::<Vec<_>>(), vec![0, 3, 200]);
        // raw-value membership: exact integer ids only
        assert!(s.contains_value(3.0));
        assert!(!s.contains_value(3.5));
        assert!(!s.contains_value(-1.0));
        assert!(!s.contains_value(f32::NAN));
        assert!(!s.contains_value(1e9));
        assert!(CatSet::new().is_empty());
    }

    #[test]
    fn categorical_routing_by_set_membership() {
        // cat feature 0: ids {1, 4} left, everything else right; missing right
        let t = Tree {
            n_outputs: 1,
            nodes: vec![TreeNode {
                feature: 0,
                bin: 0,
                threshold: 0.0,
                default_left: false,
                cats: Some(CatSet::from_ids([1u32, 4])),
                left: encode_leaf(0),
                right: encode_leaf(1),
                gain: 1.0,
            }],
            leaf_values: vec![-5.0, 5.0],
            n_leaves: 2,
        };
        assert_eq!(t.leaf_for_raw(&[1.0]), 0);
        assert_eq!(t.leaf_for_raw(&[4.0]), 0);
        assert_eq!(t.leaf_for_raw(&[0.0]), 1);
        assert_eq!(t.leaf_for_raw(&[2.0]), 1);
        assert_eq!(t.leaf_for_raw(&[9.0]), 1); // unseen category -> right
        assert_eq!(t.leaf_for_raw(&[f32::NAN]), 1); // missing -> default right
    }

    #[test]
    fn predict_accumulates() {
        let t = toy_tree();
        let mut out = vec![10.0f32, 20.0];
        t.predict_into(&[1.0, 5.0], &mut out);
        assert_eq!(out, vec![13.0, 17.0]);
    }

    #[test]
    fn binned_routing_matches_bins() {
        // one feature, values [0, 2, 4, NaN]; split at the first row's
        // value bin, missing defaults right
        let ds = Dataset::new(
            4,
            1,
            vec![0.0, 2.0, 4.0, f32::NAN],
            Targets::Regression { values: vec![0.0; 4], n_targets: 1 },
        );
        let binned = BinnedDataset::from_dataset(&ds, 8);
        assert_eq!(binned.column(0)[3], 0, "NaN lands in the missing bin");
        let t = Tree {
            n_outputs: 1,
            nodes: vec![TreeNode {
                feature: 0,
                bin: binned.column(0)[0],
                threshold: 0.0,
                default_left: false,
                cats: None,
                left: encode_leaf(0),
                right: encode_leaf(1),
                gain: 0.0,
            }],
            leaf_values: vec![-5.0, 5.0],
            n_leaves: 2,
        };
        assert_eq!(t.leaf_for_binned(&binned, 0), 0);
        assert_eq!(t.leaf_for_binned(&binned, 1), 1);
        assert_eq!(t.leaf_for_binned(&binned, 2), 1);
        assert_eq!(t.leaf_for_binned(&binned, 3), 1, "missing follows default");

        // chunked routing agrees row for row (2-row chunks, ragged pairs)
        for start in [0usize, 2] {
            let len = 2;
            let mut codes = vec![0u8; len];
            codes.copy_from_slice(&binned.column(0)[start..start + len]);
            let cols = ChunkCols { codes: &codes, start, len };
            for r in start..start + len {
                assert_eq!(t.leaf_for_chunk(&cols, r), t.leaf_for_binned(&binned, r));
            }
        }
    }

    #[test]
    fn stump_routes_to_leaf_zero() {
        let t = Tree { n_outputs: 1, nodes: vec![], leaf_values: vec![7.0], n_leaves: 1 };
        assert_eq!(t.leaf_for_raw(&[1.0, 2.0]), 0);
        assert_eq!(t.depth(), 0);
        t.validate().unwrap();
    }

    #[test]
    fn depth_and_validate() {
        let t = toy_tree();
        assert_eq!(t.depth(), 2);
        t.validate().unwrap();
    }

    #[test]
    fn validate_catches_duplicate_leaf() {
        let mut t = toy_tree();
        t.nodes[1].right = encode_leaf(1); // leaf 1 twice, leaf 2 unused
        assert!(t.validate().is_err());
    }

    #[test]
    fn validate_catches_bad_buffer() {
        let mut t = toy_tree();
        t.leaf_values.pop();
        assert!(t.validate().is_err());
    }

    #[test]
    fn scale_leaves_applies_lr() {
        let mut t = toy_tree();
        t.scale_leaves(0.1);
        assert!((t.leaf_values[0] - 0.1).abs() < 1e-7);
        assert!((t.leaf_values[5] + 0.3).abs() < 1e-7);
    }
}
