//! Best-split selection from histograms + gain tensors (paper eq. 4).

use crate::engine::ScoreMode;

/// A chosen split for one frontier node.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SplitDecision {
    pub feature: usize,
    /// left = codes <= bin
    pub bin: u8,
    /// S(left) + S(right) - S(parent): the (unhalved) information gain
    pub gain: f32,
    pub count_left: usize,
    pub count_right: usize,
}

/// S(R) and |R| (or Σh in HessL2 mode) for one frontier slot, computed
/// from its histogram totals over feature 0 (every feature's bins
/// partition the same node, so any feature gives the same totals).
/// `scratch` is a caller-pooled k-wide f64 buffer (resized here), so the
/// per-level decide loop stays allocation-free.
#[allow(clippy::too_many_arguments)]
pub fn node_score(
    hist: &[f32],
    slot: usize,
    m: usize,
    bins: usize,
    k1: usize,
    lam: f32,
    mode: ScoreMode,
    scratch: &mut Vec<f64>,
) -> (f64, f64) {
    let k = scoring_k(k1, mode);
    let base = slot * m * bins * k1; // feature 0
    scratch.clear();
    scratch.resize(k, 0.0);
    let gsum = scratch;
    let mut denom = 0.0f64;
    let mut count = 0.0f64;
    for b in 0..bins {
        let cell = &hist[base + b * k1..base + (b + 1) * k1];
        for c in 0..k {
            gsum[c] += cell[c] as f64;
        }
        count += cell[k1 - 1] as f64;
        denom += match mode {
            ScoreMode::CountL2 => cell[k1 - 1] as f64,
            ScoreMode::HessL2 => (k..2 * k).map(|c| cell[c] as f64).sum::<f64>(),
        };
    }
    let s: f64 = gsum.iter().map(|g| g * g).sum::<f64>() / (denom + lam as f64);
    (s, count)
}

#[inline]
pub fn scoring_k(k1: usize, mode: ScoreMode) -> usize {
    match mode {
        ScoreMode::CountL2 => k1 - 1,
        ScoreMode::HessL2 => (k1 - 1) / 2,
    }
}

/// Pick the best admissible split for `slot` from the engine's gain
/// tensor, enforcing `min_data_in_leaf` on both children and requiring
/// `gain - parent_score > min_gain`.
#[allow(clippy::too_many_arguments)]
pub fn best_split(
    gains: &[f32],
    hist: &[f32],
    slot: usize,
    m: usize,
    bins: usize,
    k1: usize,
    parent_score: f64,
    parent_count: f64,
    min_data: usize,
    min_gain: f32,
    feature_mask: Option<&[bool]>,
) -> Option<SplitDecision> {
    let mut best: Option<SplitDecision> = None;
    for f in 0..m {
        if let Some(mask) = feature_mask {
            if !mask[f] {
                continue;
            }
        }
        let hbase = (slot * m + f) * bins * k1;
        let gbase = (slot * m + f) * bins;
        let mut cum_count = 0.0f64;
        // last bin is the degenerate all-left split: excluded by the
        // count_right >= min_data check below.
        for b in 0..bins {
            cum_count += hist[hbase + b * k1 + (k1 - 1)] as f64;
            let count_left = cum_count;
            let count_right = parent_count - cum_count;
            if count_left < min_data as f64 || count_right < min_data as f64 {
                continue;
            }
            let gain = gains[gbase + b] as f64 - parent_score;
            if gain <= min_gain as f64 {
                continue;
            }
            let better = match &best {
                None => true,
                Some(prev) => gain > prev.gain as f64,
            };
            if better {
                best = Some(SplitDecision {
                    feature: f,
                    bin: b as u8,
                    gain: gain as f32,
                    count_left: count_left as usize,
                    count_right: count_right as usize,
                });
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{ComputeEngine, NativeEngine};

    /// hist with one feature, 4 bins, k=1 (+count): bins carry gradient
    /// +2, +2, -2, -2 with 5 rows each -> perfect split at bin 1.
    fn separable_hist() -> Vec<f32> {
        let k1 = 2;
        let mut h = vec![0.0f32; 4 * k1];
        let g = [2.0f32, 2.0, -2.0, -2.0];
        for b in 0..4 {
            h[b * k1] = g[b];
            h[b * k1 + 1] = 5.0;
        }
        h
    }

    fn gains_of(hist: &[f32], bins: usize, k1: usize) -> Vec<f32> {
        let mut out = Vec::new();
        NativeEngine::new().split_gains(hist, 1, 1, bins, k1, 1.0, ScoreMode::CountL2, &mut out);
        out
    }

    #[test]
    fn node_score_totals() {
        let h = separable_hist();
        let (s, count) =
            node_score(&h, 0, 1, 4, 2, 1.0, ScoreMode::CountL2, &mut Vec::new());
        assert!((count - 20.0).abs() < 1e-9);
        // total gradient = 0 -> S(R) = 0
        assert!(s.abs() < 1e-9);
    }

    #[test]
    fn best_split_finds_boundary() {
        let h = separable_hist();
        let gains = gains_of(&h, 4, 2);
        let dec = best_split(&gains, &h, 0, 1, 4, 2, 0.0, 20.0, 1, 0.0, None).unwrap();
        assert_eq!(dec.feature, 0);
        assert_eq!(dec.bin, 1);
        assert_eq!(dec.count_left, 10);
        assert_eq!(dec.count_right, 10);
        // gain = 16/11 + 16/11
        assert!((dec.gain as f64 - 2.0 * 16.0 / 11.0).abs() < 1e-5);
    }

    #[test]
    fn min_data_blocks_unbalanced() {
        let h = separable_hist();
        let gains = gains_of(&h, 4, 2);
        // min_data 11 > any achievable side
        assert!(best_split(&gains, &h, 0, 1, 4, 2, 0.0, 20.0, 11, 0.0, None).is_none());
        // min_data 10: only the middle split remains admissible
        let dec = best_split(&gains, &h, 0, 1, 4, 2, 0.0, 20.0, 10, 0.0, None).unwrap();
        assert_eq!(dec.bin, 1);
    }

    #[test]
    fn min_gain_blocks_weak_splits() {
        let h = separable_hist();
        let gains = gains_of(&h, 4, 2);
        assert!(best_split(&gains, &h, 0, 1, 4, 2, 0.0, 20.0, 1, 100.0, None).is_none());
    }

    #[test]
    fn feature_mask_excludes() {
        let h = separable_hist();
        let gains = gains_of(&h, 4, 2);
        let mask = vec![false];
        assert!(best_split(&gains, &h, 0, 1, 4, 2, 0.0, 20.0, 1, 0.0, Some(&mask)).is_none());
    }

    #[test]
    fn degenerate_last_bin_never_chosen() {
        // all mass in bin 0: no split leaves the right side populated
        let k1 = 2;
        let mut h = vec![0.0f32; 4 * k1];
        h[0] = 3.0;
        h[1] = 10.0;
        let gains = gains_of(&h, 4, k1);
        assert!(best_split(&gains, &h, 0, 1, 4, k1, 0.0, 10.0, 1, 0.0, None).is_none());
    }

    #[test]
    fn hess_mode_node_score() {
        // k=1 HessL2: channels [g, h, count]
        let k1 = 3;
        let h = vec![
            2.0, 4.0, 10.0, // bin 0
            1.0, 2.0, 5.0, // bin 1
        ];
        let (s, count) =
            node_score(&h, 0, 1, 2, k1, 1.0, ScoreMode::HessL2, &mut Vec::new());
        assert!((count - 15.0).abs() < 1e-9);
        // (2+1)^2 / (4+2+1)
        assert!((s - 9.0 / 7.0).abs() < 1e-6, "s={s}");
    }
}
