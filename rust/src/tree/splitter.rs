//! Best-split selection from histograms + gain tensors (paper eq. 4),
//! sparsity-aware: candidates carry a learned missing-value direction
//! and categorical features are scanned as sorted category-set prefixes
//! (see the `ComputeEngine::split_gains` contract in `engine/`).

use crate::data::dataset::FeatureKind;
use crate::engine::{categorical_order, CatScratch, ScanSpec, ScoreMode};
use crate::tree::tree::CatSet;

/// A chosen split for one frontier node.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SplitDecision {
    pub feature: usize,
    /// numeric: left = value bins 1..=bin (missing per `default_left`);
    /// 0 for categorical splits
    pub bin: u8,
    /// categorical: the category-id set routed left (None = numeric)
    pub cats: Option<CatSet>,
    /// where the missing bin routes
    pub default_left: bool,
    /// S(left) + S(right) - S(parent): the (unhalved) information gain
    pub gain: f32,
    pub count_left: usize,
    pub count_right: usize,
}

/// S(R) and |R| (or Σh in HessL2 mode) for one frontier slot, computed
/// from its histogram totals over feature 0 (every feature's bins
/// partition the same node — missing bin included — so any feature
/// gives the same totals). `scratch` is a caller-pooled k-wide f64
/// buffer (resized here), so the per-level decide loop stays
/// allocation-free.
#[allow(clippy::too_many_arguments)]
pub fn node_score(
    hist: &[f32],
    slot: usize,
    m: usize,
    bins: usize,
    k1: usize,
    lam: f32,
    mode: ScoreMode,
    scratch: &mut Vec<f64>,
) -> (f64, f64) {
    let k = scoring_k(k1, mode);
    let base = slot * m * bins * k1; // feature 0
    scratch.clear();
    scratch.resize(k, 0.0);
    let gsum = scratch;
    let mut denom = 0.0f64;
    let mut count = 0.0f64;
    for b in 0..bins {
        let cell = &hist[base + b * k1..base + (b + 1) * k1];
        for c in 0..k {
            gsum[c] += cell[c] as f64;
        }
        count += cell[k1 - 1] as f64;
        denom += match mode {
            ScoreMode::CountL2 => cell[k1 - 1] as f64,
            ScoreMode::HessL2 => (k..2 * k).map(|c| cell[c] as f64).sum::<f64>(),
        };
    }
    let s: f64 = gsum.iter().map(|g| g * g).sum::<f64>() / (denom + lam as f64);
    (s, count)
}

#[inline]
pub fn scoring_k(k1: usize, mode: ScoreMode) -> usize {
    mode.scoring_k(k1)
}

/// Pick the best admissible split for `slot` from the engine's gain +
/// default tensors, enforcing `min_data_in_leaf` on both children
/// (missing mass counted on its default side) and requiring
/// `gain - parent_score > min_gain`.
///
/// The engine commits each candidate's missing direction **by gain
/// alone**; if that direction then fails `min_data_in_leaf` the
/// candidate is discarded (the gain of the other direction is not in
/// the tensor). This is a deliberate precision/bandwidth trade-off —
/// emitting both directions would double the gain buffers — pinned by
/// `missing_counts_follow_the_learned_default` below.
///
/// Admissibility per feature kind:
///
/// * **Numeric** candidates additionally need at least one non-missing
///   row on each side — "missing only" sides have no representable raw
///   threshold (checked structurally: a non-empty value bin must exist
///   at or below the candidate and another above it).
/// * **Categorical** candidates are prefixes of [`categorical_order`];
///   the winning prefix is reconstructed into a [`CatSet`] of category
///   ids (`bin - 1`). A right side holding only missing rows is fine —
///   "not in set" routes unseen categories right at serve time.
///
/// `cat_scratch` is the caller-pooled ordering scratch (the same order
/// the engine used — both call [`categorical_order`] on the same
/// histogram, which is pure).
#[allow(clippy::too_many_arguments)]
pub fn best_split(
    gains: &[f32],
    defaults: &[u8],
    hist: &[f32],
    slot: usize,
    spec: &ScanSpec,
    parent_score: f64,
    parent_count: f64,
    min_data: usize,
    min_gain: f32,
    feature_mask: Option<&[bool]>,
    cat_scratch: &mut CatScratch,
) -> Option<SplitDecision> {
    let (m, bins, k1) = (spec.m, spec.bins, spec.k1);
    let min_data = min_data as f64;
    let mut best: Option<SplitDecision> = None;
    // Categorical winners carry their prefix length; the set is
    // reconstructed at the end. The decide loop re-derives each
    // categorical feature's ordering from the histogram (pure, so it
    // matches the engine's) rather than shipping the order through the
    // engine API — the serial decide loop is off the hot path, but if a
    // profile ever shows these sorts, have split_gains emit per-candidate
    // left-counts into a pooled buffer like `defaults`.
    let mut best_cat_prefix: Option<usize> = None;
    for f in 0..m {
        if let Some(mask) = feature_mask {
            if !mask[f] {
                continue;
            }
        }
        let hbase = (slot * m + f) * bins * k1;
        let gbase = (slot * m + f) * bins;
        let count_of = |b: usize| hist[hbase + b * k1 + (k1 - 1)] as f64;
        let miss_count = count_of(0);
        match spec.kinds[f] {
            FeatureKind::Numeric => {
                // highest non-empty value bin: candidates at or past it
                // leave no non-missing row on the right
                let mut top = 0usize;
                for b in 1..bins {
                    if count_of(b) > 0.0 {
                        top = b;
                    }
                }
                let mut cum = 0.0f64; // non-missing rows at or below b
                for b in 1..bins {
                    cum += count_of(b);
                    if cum <= 0.0 || b >= top {
                        // no non-missing row on one side: no threshold
                        if b >= top {
                            break;
                        }
                        continue;
                    }
                    let default_left = defaults[gbase + b] != 0;
                    let count_left = if default_left { cum + miss_count } else { cum };
                    let count_right = parent_count - count_left;
                    if count_left < min_data || count_right < min_data {
                        continue;
                    }
                    let gain = gains[gbase + b] as f64 - parent_score;
                    if gain <= min_gain as f64 {
                        continue;
                    }
                    let better = match &best {
                        None => true,
                        Some(prev) => gain > prev.gain as f64,
                    };
                    if better {
                        best = Some(SplitDecision {
                            feature: f,
                            bin: b as u8,
                            cats: None,
                            default_left,
                            gain: gain as f32,
                            count_left: count_left as usize,
                            count_right: count_right as usize,
                        });
                        best_cat_prefix = None;
                    }
                }
            }
            FeatureKind::Categorical => {
                categorical_order(
                    &hist[hbase..hbase + bins * k1],
                    bins,
                    k1,
                    spec.mode,
                    spec.lam,
                    cat_scratch,
                );
                let mut cum = 0.0f64;
                for (j, &b) in cat_scratch.order.iter().enumerate() {
                    cum += count_of(b as usize);
                    let default_left = defaults[gbase + j] != 0;
                    let count_left = if default_left { cum + miss_count } else { cum };
                    let count_right = parent_count - count_left;
                    if count_left < min_data || count_right < min_data {
                        continue;
                    }
                    let gain = gains[gbase + j] as f64 - parent_score;
                    if gain <= min_gain as f64 {
                        continue;
                    }
                    let better = match &best {
                        None => true,
                        Some(prev) => gain > prev.gain as f64,
                    };
                    if better {
                        best = Some(SplitDecision {
                            feature: f,
                            bin: 0,
                            cats: Some(CatSet::new()), // reconstructed below
                            default_left,
                            gain: gain as f32,
                            count_left: count_left as usize,
                            count_right: count_right as usize,
                        });
                        best_cat_prefix = Some(j);
                    }
                }
            }
        }
    }
    // reconstruct the winning categorical prefix into a category-id set
    if let (Some(dec), Some(prefix)) = (best.as_mut(), best_cat_prefix) {
        let hbase = (slot * m + dec.feature) * bins * k1;
        categorical_order(
            &hist[hbase..hbase + bins * k1],
            bins,
            k1,
            spec.mode,
            spec.lam,
            cat_scratch,
        );
        dec.cats = Some(CatSet::from_ids(
            cat_scratch.order[..=prefix].iter().map(|&b| b as u32 - 1),
        ));
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{ComputeEngine, MissingPolicy, NativeEngine};

    fn numeric_spec(m: usize, bins: usize, k1: usize, kinds: &[FeatureKind]) -> ScanSpec<'_> {
        ScanSpec {
            n_slots: 1,
            m,
            bins,
            k1,
            lam: 1.0,
            mode: ScoreMode::CountL2,
            kinds,
            missing: MissingPolicy::Learn,
        }
    }

    /// hist with one feature, 5 bins (0 = missing, empty), k=1 (+count):
    /// value bins carry gradient +2, +2, -2, -2 with 5 rows each ->
    /// perfect split after value bin 2.
    fn separable_hist() -> Vec<f32> {
        let k1 = 2;
        let mut h = vec![0.0f32; 5 * k1];
        let g = [0.0f32, 2.0, 2.0, -2.0, -2.0];
        let cnt = [0.0f32, 5.0, 5.0, 5.0, 5.0];
        for b in 0..5 {
            h[b * k1] = g[b];
            h[b * k1 + 1] = cnt[b];
        }
        h
    }

    fn scan(hist: &[f32], spec: &ScanSpec) -> (Vec<f32>, Vec<u8>) {
        let mut gains = Vec::new();
        let mut dfl = Vec::new();
        NativeEngine::new().split_gains(hist, spec, &mut gains, &mut dfl);
        (gains, dfl)
    }

    fn pick(
        hist: &[f32],
        spec: &ScanSpec,
        parent_score: f64,
        parent_count: f64,
        min_data: usize,
        min_gain: f32,
        mask: Option<&[bool]>,
    ) -> Option<SplitDecision> {
        let (gains, dfl) = scan(hist, spec);
        best_split(
            &gains,
            &dfl,
            hist,
            0,
            spec,
            parent_score,
            parent_count,
            min_data,
            min_gain,
            mask,
            &mut CatScratch::default(),
        )
    }

    #[test]
    fn node_score_totals() {
        let h = separable_hist();
        let (s, count) = node_score(&h, 0, 1, 5, 2, 1.0, ScoreMode::CountL2, &mut Vec::new());
        assert!((count - 20.0).abs() < 1e-9);
        // total gradient = 0 -> S(R) = 0
        assert!(s.abs() < 1e-9);
    }

    #[test]
    fn best_split_finds_boundary() {
        let h = separable_hist();
        let kinds = [FeatureKind::Numeric];
        let dec = pick(&h, &numeric_spec(1, 5, 2, &kinds), 0.0, 20.0, 1, 0.0, None).unwrap();
        assert_eq!(dec.feature, 0);
        assert_eq!(dec.bin, 2);
        assert!(dec.cats.is_none());
        assert!(dec.default_left, "no missing rows: ties default left");
        assert_eq!(dec.count_left, 10);
        assert_eq!(dec.count_right, 10);
        // gain = 16/11 + 16/11
        assert!((dec.gain as f64 - 2.0 * 16.0 / 11.0).abs() < 1e-5);
    }

    #[test]
    fn min_data_blocks_unbalanced() {
        let h = separable_hist();
        let kinds = [FeatureKind::Numeric];
        let spec = numeric_spec(1, 5, 2, &kinds);
        // min_data 11 > any achievable side
        assert!(pick(&h, &spec, 0.0, 20.0, 11, 0.0, None).is_none());
        // min_data 10: only the middle split remains admissible
        let dec = pick(&h, &spec, 0.0, 20.0, 10, 0.0, None).unwrap();
        assert_eq!(dec.bin, 2);
    }

    #[test]
    fn min_gain_blocks_weak_splits() {
        let h = separable_hist();
        let kinds = [FeatureKind::Numeric];
        assert!(pick(&h, &numeric_spec(1, 5, 2, &kinds), 0.0, 20.0, 1, 100.0, None).is_none());
    }

    #[test]
    fn feature_mask_excludes() {
        let h = separable_hist();
        let kinds = [FeatureKind::Numeric];
        let mask = vec![false];
        assert!(pick(&h, &numeric_spec(1, 5, 2, &kinds), 0.0, 20.0, 1, 0.0, Some(&mask)).is_none());
    }

    #[test]
    fn degenerate_one_sided_candidates_never_chosen() {
        // all value mass in bin 1 (+ missing rows in bin 0): no numeric
        // candidate leaves a non-missing row on both sides, so there is
        // no split even though "missing vs rest" would score
        let k1 = 2;
        let mut h = vec![0.0f32; 5 * k1];
        h[0] = -3.0; // missing g
        h[1] = 4.0; // missing count
        h[2] = 3.0; // bin 1 g
        h[3] = 10.0; // bin 1 count
        let kinds = [FeatureKind::Numeric];
        assert!(pick(&h, &numeric_spec(1, 5, 2, &kinds), 0.0, 14.0, 1, 0.0, None).is_none());
    }

    #[test]
    fn missing_counts_follow_the_learned_default() {
        // value bins separable; missing gradient aligns with the right
        // side, so the default goes right and min_data must see the
        // missing mass on the right
        let k1 = 2;
        let h = vec![
            -2.0, 6.0, // missing: g=-2, 6 rows
            4.0, 5.0, // bin 1
            -4.0, 5.0, // bin 2
        ];
        let kinds = [FeatureKind::Numeric];
        let spec = numeric_spec(1, 3, k1, &kinds);
        let dec = pick(&h, &spec, 0.0, 16.0, 1, 0.0, None).unwrap();
        assert_eq!(dec.bin, 1);
        assert!(!dec.default_left, "missing belongs with the negative side");
        assert_eq!(dec.count_left, 5);
        assert_eq!(dec.count_right, 11);
        // with min_data = 6 the left side (5 rows, missing routed right)
        // is inadmissible
        assert!(pick(&h, &spec, 0.0, 16.0, 6, 0.0, None).is_none());
    }

    #[test]
    fn categorical_winner_reconstructs_the_sorted_prefix() {
        // cat ids 0..=2 (bins 1..=3): g = [+6, -6, +2], cnt 4 each ->
        // order [1, 3, 2], best prefix = {bin1, bin3} = ids {0, 2}
        let k1 = 2;
        let h = vec![
            0.0, 0.0, // missing
            6.0, 4.0, // id 0
            -6.0, 4.0, // id 1
            2.0, 4.0, // id 2
        ];
        let kinds = [FeatureKind::Categorical];
        let spec = ScanSpec {
            n_slots: 1,
            m: 1,
            bins: 4,
            k1,
            lam: 1.0,
            mode: ScoreMode::CountL2,
            kinds: &kinds,
            missing: MissingPolicy::Learn,
        };
        let dec = pick(&h, &spec, 0.0, 12.0, 1, 0.0, None).unwrap();
        let cats = dec.cats.expect("categorical decision");
        assert_eq!(cats.ids().collect::<Vec<_>>(), vec![0, 2]);
        assert_eq!(dec.bin, 0);
        assert_eq!(dec.count_left, 8);
        assert_eq!(dec.count_right, 4);
        // the isolated set is non-contiguous in id order: the ordinal
        // scan over the same histogram can at best cut {id0} | {id1, id2}
        // or {id0, id1} | {id2} — strictly worse
        let kinds_num = [FeatureKind::Numeric];
        let spec_num = ScanSpec { kinds: &kinds_num, ..spec };
        let ord = pick(&h, &spec_num, 0.0, 12.0, 1, 0.0, None).unwrap();
        assert!(dec.gain > ord.gain, "{} vs {}", dec.gain, ord.gain);
    }

    #[test]
    fn hess_mode_node_score() {
        // k=1 HessL2: channels [g, h, count]
        let k1 = 3;
        let h = vec![
            2.0, 4.0, 10.0, // bin 0
            1.0, 2.0, 5.0, // bin 1
        ];
        let (s, count) = node_score(&h, 0, 1, 2, k1, 1.0, ScoreMode::HessL2, &mut Vec::new());
        assert!((count - 15.0).abs() < 1e-9);
        // (2+1)^2 / (4+2+1)
        assert!((s - 9.0 / 7.0).abs() < 1e-6, "s={s}");
    }
}
