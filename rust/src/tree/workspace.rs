//! Pooled, reusable buffers for the depth-wise tree builder — the
//! allocation-free training core (DESIGN.md "Memory model & row
//! partitioning").
//!
//! ## Row partitioning
//!
//! Instead of a per-global-row `node_of_row` map plus a filter scan at
//! every level, the builder keeps the active rows in one buffer that is
//! **stably partitioned in place at each split**: every frontier node
//! owns a contiguous `[start, end)` range ([`SlotRange`]), and the
//! gathered `[nr, k1]` channel matrix is kept in the same partition
//! order alongside it. The payoff:
//!
//! * histogram accumulation streams each node's rows sequentially with a
//!   constant output base — no per-row slot lookup, and no per-level
//!   re-gather of channel rows inside the engine;
//! * sibling subtraction selects the smaller child as a *range*, not by
//!   re-scanning the full row list against a flag array;
//! * the stable partition preserves the relative (ascending) row order
//!   inside every node, so per-histogram-cell f32 accumulation order is
//!   unchanged and ensembles stay bit-identical to the pre-partitioning
//!   implementation (`rust/tests/partition_equivalence.rs`).
//!
//! ## Pooling
//!
//! One `TreeWorkspace` lives across every tree of a training run (the
//! trainer and both baselines hold one next to their engine). Every
//! buffer below is `clear()`ed and `resize()`d per tree/level, which
//! reuses capacity — after buffers have grown to their high-water mark
//! (typically the first tree), steady-state tree building performs **no
//! heap allocation** in the per-level loop (`rust/tests/alloc_free.rs`
//! counts allocations to enforce this; the returned [`Tree`] itself and
//! its leaf values are the only per-tree allocations left).
//!
//! [`Tree`]: crate::tree::Tree

use crate::engine::{CatScratch, LeafSums, SlotRange};
use crate::tree::tree::CatSet;

/// Where a frontier slot hangs in the partially-built tree.
#[derive(Clone, Copy)]
pub(crate) enum Parent {
    Root,
    Child { node: usize, is_left: bool },
}

/// How a split routes non-missing codes (missing routes by the split's
/// `default_left`). `Copy` so the partition loop stays allocation-free.
#[derive(Clone, Copy)]
pub(crate) enum SplitRule {
    /// left iff 1 <= code <= bin
    Numeric { bin: u8 },
    /// left iff the code's category id (code - 1) is in the set
    Categorical { cats: CatSet },
}

/// Per-slot decision of one level.
pub(crate) enum Outcome {
    Leaf(u32),
    Split {
        feature: u32,
        rule: SplitRule,
        default_left: bool,
        left_slot: u32,
        right_slot: u32,
    },
}

/// Bookkeeping for one split: which new slots it produced and the
/// histogram-count sizes that pick the smaller child for sibling
/// subtraction (weighted counts when `row_weights` are in play — the
/// same tie-breaking the historical builder used).
#[derive(Clone, Copy)]
pub(crate) struct SplitInfo {
    pub parent_slot: u32,
    pub left: u32,
    pub right: u32,
    pub count_left: usize,
    pub count_right: usize,
}

/// Reusable buffers for [`build_tree_in`](crate::tree::builder::build_tree_in).
///
/// Construct once (cheap: every buffer starts empty) and pass to every
/// build; see the module docs for the pooling contract.
#[derive(Default)]
pub struct TreeWorkspace {
    /// Active row ids, stably partitioned: slot `s` of the current
    /// frontier owns `rows[segs[s].range()]`, each segment ascending.
    pub(crate) rows: Vec<u32>,
    /// `[nr, k1]` channel matrix parallel to `rows` by position.
    pub(crate) chan: Vec<f32>,
    /// Partition targets for the next level (ping-pong with `rows`/`chan`).
    /// The stable partition keeps each segment's rows ascending — the
    /// invariant the chunked routing arm in `tree/builder.rs` leans on
    /// to visit each chunk's share of a segment as one contiguous run.
    pub(crate) rows_next: Vec<u32>,
    pub(crate) chan_next: Vec<f32>,
    /// Right-child staging for the single-pass stable partition.
    pub(crate) right_rows: Vec<u32>,
    pub(crate) right_chan: Vec<f32>,
    /// Per-frontier-slot row ranges (and the next level's).
    pub(crate) segs: Vec<SlotRange>,
    pub(crate) segs_next: Vec<SlotRange>,
    /// Sibling subtraction: the smaller child of every split.
    pub(crate) small_segs: Vec<SlotRange>,
    /// Histogram ping-pong: current level and next level.
    pub(crate) hist: Vec<f32>,
    pub(crate) hist_next: Vec<f32>,
    /// Split-gain output, filled by `ComputeEngine::split_gains`.
    pub(crate) gains: Vec<f32>,
    /// Per-candidate missing-direction output, parallel to `gains`.
    pub(crate) defaults: Vec<u8>,
    /// f64 scratch for `node_score`.
    pub(crate) score_scratch: Vec<f64>,
    /// Categorical ordering scratch for `best_split`.
    pub(crate) cat_scratch: CatScratch,
    /// Global row -> leaf id (SENTINEL outside the sampled rows).
    pub(crate) leaf_of_row: Vec<u32>,
    /// Exact per-leaf derivative sums, filled by `ComputeEngine::leaf_sums`.
    pub(crate) sums: LeafSums,
    /// Frontier bookkeeping.
    pub(crate) frontier: Vec<Parent>,
    pub(crate) new_frontier: Vec<Parent>,
    pub(crate) outcomes: Vec<Outcome>,
    pub(crate) split_info: Vec<SplitInfo>,
    pub(crate) slot_leaf: Vec<u32>,
}

impl TreeWorkspace {
    pub fn new() -> TreeWorkspace {
        TreeWorkspace::default()
    }

    /// Global row -> leaf id of the most recent build (`SENTINEL` for
    /// rows outside the sampled set). Valid until the next build.
    pub fn leaf_of_row(&self) -> &[u32] {
        &self.leaf_of_row
    }

    /// Move the leaf map out (used by the convenience wrapper
    /// [`build_tree`](crate::tree::builder::build_tree); pooled callers
    /// should borrow [`leaf_of_row`](Self::leaf_of_row) instead).
    pub fn take_leaf_of_row(&mut self) -> Vec<u32> {
        std::mem::take(&mut self.leaf_of_row)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workspace_starts_empty_and_returns_leaf_map() {
        let mut ws = TreeWorkspace::new();
        assert!(ws.leaf_of_row().is_empty());
        ws.leaf_of_row = vec![1, 2, 3];
        let taken = ws.take_leaf_of_row();
        assert_eq!(taken, vec![1, 2, 3]);
        assert!(ws.leaf_of_row().is_empty());
    }
}
