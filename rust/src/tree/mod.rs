//! Multivariate decision trees: representation, depth-wise builder with
//! sketched split scoring + sibling subtraction, and split selection.

pub mod builder;
pub mod splitter;
#[allow(clippy::module_inception)]
pub mod tree;

pub use builder::{build_tree, BuildParams, SENTINEL};
pub use tree::{Tree, TreeNode};
