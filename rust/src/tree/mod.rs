//! Multivariate decision trees: representation, depth-wise builder with
//! sketched split scoring + sibling subtraction over a stably
//! partitioned row buffer, pooled build workspace, and split selection.

pub mod builder;
pub mod splitter;
#[allow(clippy::module_inception)]
pub mod tree;
pub mod workspace;

pub use builder::{build_tree, build_tree_in, BuildParams, SENTINEL};
pub use tree::{CatSet, Tree, TreeNode};
pub use workspace::TreeWorkspace;
