//! Depth-wise multivariate tree builder (the paper's single-tree
//! strategy; Py-Boost supports only depth-wise growth, Appendix B.1).
//!
//! Per level: engine accumulates histograms over the *sketched* scoring
//! channels, the splitter picks the best (feature, bin) per frontier
//! node, rows are routed to children, and the next level's histograms use
//! the sibling-subtraction trick (only the smaller child is accumulated;
//! the larger one is parent − sibling). Leaf values are computed exactly
//! from the full gradient/hessian matrices (paper: the sketch is used
//! "only in building histograms and finding the tree structure").
//!
//! The builder itself is single-threaded and engine-agnostic: data
//! parallelism lives inside the [`ComputeEngine`] ops, whose contract
//! (see `engine/`) guarantees bit-identical results for every thread
//! count. That is what lets the sibling subtraction below — an exact
//! f32 cancellation against the parent histogram — stay valid when the
//! engine builds histograms on multiple threads.

use crate::data::binning::BinnedDataset;
use crate::engine::{ComputeEngine, ScoreMode};
use crate::tree::splitter::{best_split, node_score, SplitDecision};
use crate::tree::tree::{encode_leaf, Tree, TreeNode};

pub const SENTINEL: u32 = u32::MAX;

/// Inputs for building one tree. All matrices are row-major over the
/// *global* row index of `binned` (0..n); `rows` selects the active
/// (possibly subsampled) training rows.
pub struct BuildParams<'a> {
    pub binned: &'a BinnedDataset,
    pub rows: &'a [u32],
    /// full gradients [n, d] (leaf values)
    pub g: &'a [f32],
    /// full hessians [n, d] (leaf values)
    pub h: &'a [f32],
    pub d: usize,
    /// sketched scoring channels [n, kc] (split search); may alias g
    pub score_g: &'a [f32],
    pub kc: usize,
    /// sketched hessian channels (only for ScoreMode::HessL2)
    pub score_h: Option<&'a [f32]>,
    pub mode: ScoreMode,
    pub max_depth: usize,
    pub lambda: f32,
    pub min_data_in_leaf: usize,
    pub min_gain: f32,
    pub feature_mask: Option<&'a [bool]>,
    /// GBDT-MO (sparse): keep only the top-K |v| outputs per leaf
    pub sparse_topk: Option<usize>,
    /// per-row scoring weights parallel to `rows` (GOSS/MVS up-weighting;
    /// applied to every histogram channel including the count). Leaf
    /// values stay unweighted (exact over the kept rows).
    pub row_weights: Option<&'a [f32]>,
}

/// Where a frontier slot hangs in the partially-built tree.
#[derive(Clone, Copy)]
enum Parent {
    Root,
    Child { node: usize, is_left: bool },
}

enum Outcome {
    Leaf(usize),
    Split { feature: usize, bin: u8, left_slot: u32, right_slot: u32 },
}

/// Build one tree. Also returns `leaf_of_row` (global row -> leaf id,
/// SENTINEL for rows outside `rows`) so the trainer can update
/// predictions without re-routing.
pub fn build_tree(p: &BuildParams, engine: &mut dyn ComputeEngine) -> (Tree, Vec<u32>) {
    let n = p.binned.n_rows;
    let m = p.binned.n_features;
    let bins = p.binned.max_bins;
    let k1 = p.mode.channels(p.kc);
    assert!(p.max_depth >= 1, "max_depth must be >= 1");
    assert!(p.min_data_in_leaf >= 1, "min_data_in_leaf must be >= 1");
    if p.mode == ScoreMode::HessL2 {
        assert!(p.score_h.is_some(), "HessL2 scoring needs hessian channels");
    }

    // Per-row channel matrix [n, k1]: scoring grads (+ hessians) + valid.
    if let Some(w) = p.row_weights {
        assert_eq!(w.len(), p.rows.len(), "row_weights parallel to rows");
    }
    let mut chan = vec![0.0f32; n * k1];
    for (j, &r) in p.rows.iter().enumerate() {
        let r = r as usize;
        let w = p.row_weights.map(|w| w[j]).unwrap_or(1.0);
        let dst = &mut chan[r * k1..(r + 1) * k1];
        dst[..p.kc].copy_from_slice(&p.score_g[r * p.kc..(r + 1) * p.kc]);
        if let (ScoreMode::HessL2, Some(sh)) = (p.mode, p.score_h) {
            dst[p.kc..2 * p.kc].copy_from_slice(&sh[r * p.kc..(r + 1) * p.kc]);
        }
        dst[k1 - 1] = 1.0;
        if w != 1.0 {
            for v in dst.iter_mut() {
                *v *= w;
            }
        }
    }

    let mut node_of_row = vec![SENTINEL; n];
    for &r in p.rows {
        node_of_row[r as usize] = 0;
    }
    let mut leaf_of_row = vec![SENTINEL; n];

    let mut nodes: Vec<TreeNode> = Vec::new();
    let mut n_leaves = 0usize;
    let mut frontier: Vec<Parent> = vec![Parent::Root];
    let mut rows_cur: Vec<u32> = p.rows.to_vec();
    let mut is_root_leaf = false;

    let slice_sz = m * bins * k1;
    let mut hist = vec![0.0f32; slice_sz];
    engine.histograms(p.binned, &rows_cur, &node_of_row, &chan, k1, 1, &mut hist);

    let settle_leaf =
        |parent: Parent,
         nodes: &mut Vec<TreeNode>,
         n_leaves: &mut usize,
         is_root_leaf: &mut bool|
         -> usize {
            let id = *n_leaves;
            *n_leaves += 1;
            match parent {
                Parent::Root => *is_root_leaf = true,
                Parent::Child { node, is_left } => {
                    let c = encode_leaf(id);
                    if is_left {
                        nodes[node].left = c;
                    } else {
                        nodes[node].right = c;
                    }
                }
            }
            id
        };

    for depth in 0..p.max_depth {
        let n_slots = frontier.len();
        let gains = engine.split_gains(&hist, n_slots, m, bins, k1, p.lambda, p.mode);

        // decide each slot
        let mut outcomes: Vec<Outcome> = Vec::with_capacity(n_slots);
        let mut new_frontier: Vec<Parent> = Vec::new();
        let mut split_info: Vec<(usize, u32, u32, usize, usize)> = Vec::new(); // (parent_slot, l, r, cl, cr)
        for (slot, &parent) in frontier.iter().enumerate() {
            let (pscore, pcount) = node_score(&hist, slot, m, bins, k1, p.lambda, p.mode);
            let dec: Option<SplitDecision> = if pcount < (2 * p.min_data_in_leaf) as f64 {
                None
            } else {
                best_split(
                    &gains,
                    &hist,
                    slot,
                    m,
                    bins,
                    k1,
                    pscore,
                    pcount,
                    p.min_data_in_leaf,
                    p.min_gain,
                    p.feature_mask,
                )
            };
            match dec {
                None => {
                    let id = settle_leaf(parent, &mut nodes, &mut n_leaves, &mut is_root_leaf);
                    outcomes.push(Outcome::Leaf(id));
                }
                Some(d) => {
                    let node_idx = nodes.len();
                    nodes.push(TreeNode {
                        feature: d.feature as u32,
                        bin: d.bin,
                        threshold: p.binned.threshold_value(d.feature, d.bin as usize),
                        left: 0,
                        right: 0,
                        gain: d.gain,
                    });
                    match parent {
                        Parent::Root => {}
                        Parent::Child { node, is_left } => {
                            if is_left {
                                nodes[node].left = node_idx as i32;
                            } else {
                                nodes[node].right = node_idx as i32;
                            }
                        }
                    }
                    let left_slot = new_frontier.len() as u32;
                    new_frontier.push(Parent::Child { node: node_idx, is_left: true });
                    let right_slot = new_frontier.len() as u32;
                    new_frontier.push(Parent::Child { node: node_idx, is_left: false });
                    split_info.push((slot, left_slot, right_slot, d.count_left, d.count_right));
                    outcomes.push(Outcome::Split {
                        feature: d.feature,
                        bin: d.bin,
                        left_slot,
                        right_slot,
                    });
                }
            }
        }

        // route rows to children / settle leaves
        let mut next_rows: Vec<u32> = Vec::with_capacity(rows_cur.len());
        for &r in &rows_cur {
            let slot = node_of_row[r as usize] as usize;
            match &outcomes[slot] {
                Outcome::Leaf(id) => {
                    leaf_of_row[r as usize] = *id as u32;
                    node_of_row[r as usize] = SENTINEL;
                }
                Outcome::Split { feature, bin, left_slot, right_slot } => {
                    let code = p.binned.codes[feature * n + r as usize];
                    let ns = if code <= *bin { *left_slot } else { *right_slot };
                    node_of_row[r as usize] = ns;
                    next_rows.push(r);
                }
            }
        }
        rows_cur = next_rows;

        if new_frontier.is_empty() {
            frontier = new_frontier;
            break;
        }
        frontier = new_frontier;
        if depth + 1 == p.max_depth {
            break; // children become leaves below; skip their histograms
        }

        // next-level histograms with sibling subtraction
        let n_new = frontier.len();
        let mut small_flag = vec![false; n_new];
        for &(_, l, r, cl, cr) in &split_info {
            if cl <= cr {
                small_flag[l as usize] = true;
            } else {
                small_flag[r as usize] = true;
            }
        }
        let small_rows: Vec<u32> = rows_cur
            .iter()
            .copied()
            .filter(|&r| small_flag[node_of_row[r as usize] as usize])
            .collect();
        let mut new_hist = vec![0.0f32; n_new * slice_sz];
        engine.histograms(
            p.binned,
            &small_rows,
            &node_of_row,
            &chan,
            k1,
            n_new,
            &mut new_hist,
        );
        for &(parent_slot, l, r, cl, cr) in &split_info {
            let (small, big) = if cl <= cr { (l, r) } else { (r, l) };
            let pbase = parent_slot * slice_sz;
            let sbase = small as usize * slice_sz;
            let bbase = big as usize * slice_sz;
            for i in 0..slice_sz {
                new_hist[bbase + i] = hist[pbase + i] - new_hist[sbase + i];
            }
        }
        hist = new_hist;
    }

    // remaining frontier slots become leaves
    let mut slot_leaf: Vec<u32> = Vec::with_capacity(frontier.len());
    for &parent in &frontier {
        let id = settle_leaf(parent, &mut nodes, &mut n_leaves, &mut is_root_leaf);
        slot_leaf.push(id as u32);
    }
    for &r in &rows_cur {
        leaf_of_row[r as usize] = slot_leaf[node_of_row[r as usize] as usize];
    }

    // exact leaf values from the full derivative matrices (eq. 3)
    let sums = engine.leaf_sums(p.rows, &leaf_of_row, p.g, p.h, p.d, n_leaves);
    let mut leaf_values = vec![0.0f32; n_leaves * p.d];
    for l in 0..n_leaves {
        for j in 0..p.d {
            let gs = sums.gsum[l * p.d + j];
            let hs = sums.hsum[l * p.d + j];
            leaf_values[l * p.d + j] = -gs / (hs + p.lambda);
        }
    }
    if let Some(topk) = p.sparse_topk {
        sparsify_leaves(&mut leaf_values, n_leaves, p.d, topk);
    }

    let tree = Tree {
        n_outputs: p.d,
        nodes: if is_root_leaf { Vec::new() } else { nodes },
        leaf_values,
        n_leaves,
    };
    debug_assert!(tree.validate().is_ok(), "{:?}", tree.validate());
    (tree, leaf_of_row)
}

/// GBDT-MO (sparse): keep only the top-K outputs by |v| per leaf.
fn sparsify_leaves(values: &mut [f32], n_leaves: usize, d: usize, topk: usize) {
    if topk >= d {
        return;
    }
    let mut idx: Vec<usize> = Vec::with_capacity(d);
    for l in 0..n_leaves {
        let row = &mut values[l * d..(l + 1) * d];
        idx.clear();
        idx.extend(0..d);
        idx.sort_by(|&a, &b| row[b].abs().partial_cmp(&row[a].abs()).unwrap());
        for &j in &idx[topk..] {
            row[j] = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::{Dataset, Targets};
    use crate::engine::NativeEngine;
    use crate::util::proptest::run_prop;
    use crate::util::rng::Rng;

    /// 1-feature dataset where gradient sign flips at x = 0.
    fn sign_problem(n: usize, seed: u64) -> (BinnedDataset, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let mut x = vec![0.0f32; n];
        rng.fill_gaussian(&mut x, 1.0);
        let g: Vec<f32> = x.iter().map(|&v| if v <= 0.0 { 1.0 } else { -1.0 }).collect();
        let h = vec![1.0f32; n];
        let ds = Dataset::new(
            n,
            1,
            x,
            Targets::Regression { values: vec![0.0; n], n_targets: 1 },
        );
        (BinnedDataset::from_dataset(&ds, 32), g, h)
    }

    fn params<'a>(
        binned: &'a BinnedDataset,
        rows: &'a [u32],
        g: &'a [f32],
        h: &'a [f32],
        max_depth: usize,
    ) -> BuildParams<'a> {
        BuildParams {
            binned,
            rows,
            g,
            h,
            d: 1,
            score_g: g,
            kc: 1,
            score_h: None,
            mode: ScoreMode::CountL2,
            max_depth,
            lambda: 1.0,
            min_data_in_leaf: 1,
            min_gain: 0.0,
            feature_mask: None,
            sparse_topk: None,
            row_weights: None,
        }
    }

    #[test]
    fn splits_sign_problem_at_zero() {
        let (binned, g, h) = sign_problem(400, 1);
        let rows: Vec<u32> = (0..400).collect();
        let mut eng = NativeEngine::new();
        let (tree, leaf_of_row) = build_tree(&params(&binned, &rows, &g, &h, 1), &mut eng);
        assert_eq!(tree.n_leaves, 2);
        assert_eq!(tree.nodes.len(), 1);
        tree.validate().unwrap();
        // threshold near 0 (within a bin width)
        assert!(tree.nodes[0].threshold.abs() < 0.3, "t={}", tree.nodes[0].threshold);
        // leaf values have opposite signs: -sum(g)/(count+lam)
        let v0 = tree.leaf_values[tree.leaf_for_raw(&[-2.0])];
        let v1 = tree.leaf_values[tree.leaf_for_raw(&[2.0])];
        assert!(v0 < 0.0 && v1 > 0.0, "v0={v0} v1={v1}");
        // leaf_of_row consistent with routing
        for r in 0..400usize {
            assert_eq!(leaf_of_row[r] as usize, tree.leaf_for_binned(&binned, r));
        }
    }

    #[test]
    fn stump_when_no_gain() {
        // constant gradient: no split improves the score
        let (binned, _, h) = sign_problem(100, 2);
        let g = vec![1.0f32; 100];
        let rows: Vec<u32> = (0..100).collect();
        let mut eng = NativeEngine::new();
        let (tree, _) = build_tree(&params(&binned, &rows, &g, &h, 3), &mut eng);
        assert_eq!(tree.n_leaves, 1);
        assert!(tree.nodes.is_empty());
        // leaf value = -100/(100+1)
        assert!((tree.leaf_values[0] + 100.0 / 101.0).abs() < 1e-5);
    }

    #[test]
    fn respects_max_depth() {
        let (binned, g, h) = sign_problem(500, 3);
        // noisy gradients force deep trees if allowed
        let mut rng = Rng::new(9);
        let gn: Vec<f32> = g.iter().map(|&v| v + rng.next_gaussian() as f32).collect();
        let rows: Vec<u32> = (0..500).collect();
        let mut eng = NativeEngine::new();
        for depth in 1..=4 {
            let (tree, _) = build_tree(&params(&binned, &rows, &gn, &h, depth), &mut eng);
            assert!(tree.depth() <= depth, "depth {} > {}", tree.depth(), depth);
            assert!(tree.n_leaves <= 1 << depth);
            tree.validate().unwrap();
        }
    }

    #[test]
    fn min_data_in_leaf_enforced() {
        let (binned, g, h) = sign_problem(300, 4);
        let mut rng = Rng::new(10);
        let gn: Vec<f32> = g.iter().map(|&v| v + 0.5 * rng.next_gaussian() as f32).collect();
        let rows: Vec<u32> = (0..300).collect();
        let mut eng = NativeEngine::new();
        let mut p = params(&binned, &rows, &gn, &h, 5);
        p.min_data_in_leaf = 40;
        let (tree, leaf_of_row) = build_tree(&p, &mut eng);
        let mut counts = vec![0usize; tree.n_leaves];
        for r in 0..300usize {
            counts[leaf_of_row[r] as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c >= 40), "counts {counts:?}");
    }

    #[test]
    fn subsampled_rows_only() {
        let (binned, g, h) = sign_problem(200, 5);
        let rows: Vec<u32> = (0..200).filter(|&r| r % 2 == 0).collect();
        let mut eng = NativeEngine::new();
        let (_, leaf_of_row) = build_tree(&params(&binned, &rows, &g, &h, 2), &mut eng);
        for r in 0..200usize {
            if r % 2 == 0 {
                assert_ne!(leaf_of_row[r], SENTINEL);
            } else {
                assert_eq!(leaf_of_row[r], SENTINEL);
            }
        }
    }

    #[test]
    fn leaf_of_row_partitions_rows() {
        run_prop("leaf_of_row partitions", 10, |gen| {
            let n = gen.usize_in(50, 300);
            let (binned, _, h) = sign_problem(n, gen.seed);
            let g = gen.vec_gaussian(n, 1.0);
            let rows: Vec<u32> = (0..n as u32).collect();
            let mut eng = NativeEngine::new();
            let depth = gen.usize_in(1, 4);
            let (tree, leaf_of_row) = build_tree(&params(&binned, &rows, &g, &h, depth), &mut eng);
            tree.validate().unwrap();
            // every row lands in a valid leaf that matches tree routing
            for r in 0..n {
                let l = leaf_of_row[r] as usize;
                assert!(l < tree.n_leaves);
                assert_eq!(l, tree.leaf_for_binned(&binned, r));
            }
        });
    }

    #[test]
    fn subtraction_equals_direct_histograms() {
        // depth-2 build must match a build where subtraction is disabled;
        // we verify indirectly: leaf values of depth-2 tree equal the
        // exact per-leaf -sum(g)/(count+lam).
        let (binned, g, h) = sign_problem(300, 7);
        let mut rng = Rng::new(11);
        let gn: Vec<f32> = g.iter().map(|&v| v + 0.3 * rng.next_gaussian() as f32).collect();
        let rows: Vec<u32> = (0..300).collect();
        let mut eng = NativeEngine::new();
        let (tree, leaf_of_row) = build_tree(&params(&binned, &rows, &gn, &h, 2), &mut eng);
        let mut gsum = vec![0.0f64; tree.n_leaves];
        let mut cnt = vec![0.0f64; tree.n_leaves];
        for r in 0..300usize {
            gsum[leaf_of_row[r] as usize] += gn[r] as f64;
            cnt[leaf_of_row[r] as usize] += 1.0;
        }
        for l in 0..tree.n_leaves {
            let want = -(gsum[l] / (cnt[l] + 1.0)) as f32;
            assert!(
                (tree.leaf_values[l] - want).abs() < 1e-4,
                "leaf {l}: {} vs {want}",
                tree.leaf_values[l]
            );
        }
    }

    #[test]
    fn sparse_topk_zeroes_small_outputs() {
        let mut v = vec![
            3.0, -1.0, 0.5, -4.0, // leaf 0
            0.1, 0.2, 0.3, 0.4, // leaf 1
        ];
        sparsify_leaves(&mut v, 2, 4, 2);
        assert_eq!(&v[0..4], &[3.0, 0.0, 0.0, -4.0]);
        assert_eq!(&v[4..8], &[0.0, 0.0, 0.3, 0.4]);
    }

    #[test]
    fn multioutput_leaf_values() {
        // d=2: gradients differ per output; leaf values computed per output
        let (binned, _, _) = sign_problem(100, 8);
        let mut g = vec![0.0f32; 200];
        let mut h = vec![0.0f32; 200];
        for r in 0..100 {
            let x = binned.column(0)[r];
            g[r * 2] = if x < 10 { 1.0 } else { -1.0 };
            g[r * 2 + 1] = 0.5;
            h[r * 2] = 1.0;
            h[r * 2 + 1] = 2.0;
        }
        let rows: Vec<u32> = (0..100).collect();
        // scoring on output 0 only
        let score: Vec<f32> = (0..100).map(|r| g[r * 2]).collect();
        let p = BuildParams {
            binned: &binned,
            rows: &rows,
            g: &g,
            h: &h,
            d: 2,
            score_g: &score,
            kc: 1,
            score_h: None,
            mode: ScoreMode::CountL2,
            max_depth: 1,
            lambda: 1.0,
            min_data_in_leaf: 1,
            min_gain: 0.0,
            feature_mask: None,
            sparse_topk: None,
            row_weights: None,
        };
        let mut eng = NativeEngine::new();
        let (tree, leaf_of_row) = build_tree(&p, &mut eng);
        assert_eq!(tree.n_outputs, 2);
        // output-1 leaf value: -0.5*c / (2c + 1) per leaf with c rows
        for l in 0..tree.n_leaves {
            let c = (0..100).filter(|&r| leaf_of_row[r] == l as u32).count() as f32;
            let want = -(0.5 * c) / (2.0 * c + 1.0);
            assert!((tree.leaf_values[l * 2 + 1] - want).abs() < 1e-5);
        }
    }
}
