//! Depth-wise multivariate tree builder (the paper's single-tree
//! strategy; Py-Boost supports only depth-wise growth, Appendix B.1).
//!
//! Per level: engine accumulates histograms over the *sketched* scoring
//! channels, the splitter picks the best (feature, bin) per frontier
//! node, rows are routed to children by a **stable in-place partition**
//! of one shared row buffer (every frontier node owns a contiguous
//! `[start, end)` range — see `tree/workspace.rs`), and the next level's
//! histograms use the sibling-subtraction trick (only the smaller child
//! is accumulated; the larger one is parent − sibling, both plain
//! ranges). Leaf values are computed exactly from the full
//! gradient/hessian matrices (paper: the sketch is used "only in
//! building histograms and finding the tree structure").
//!
//! All per-level state lives in a caller-owned [`TreeWorkspace`] so
//! steady-state training reuses every buffer across levels and trees;
//! [`build_tree_in`] is the pooled entry point and [`build_tree`] a
//! convenience wrapper that allocates a fresh workspace.
//!
//! The builder itself is single-threaded and engine-agnostic: data
//! parallelism lives inside the [`ComputeEngine`] ops, whose contract
//! (see `engine/`) guarantees bit-identical results for every thread
//! count. That is what lets the sibling subtraction below — an exact
//! f32 cancellation against the parent histogram — stay valid when the
//! engine builds histograms on multiple threads. The stable partition
//! preserves the ascending row order inside every node, so per-cell
//! accumulation order (and therefore every result bit) matches the
//! historical flag-routed builder (`rust/tests/partition_equivalence.rs`).

use crate::data::binning::{BinnedSource, MISSING_BIN};
use crate::engine::{ComputeEngine, MissingPolicy, ScanSpec, ScoreMode, SlotRange};
use crate::tree::splitter::{best_split, node_score, SplitDecision};
use crate::tree::tree::{encode_leaf, Tree, TreeNode};
use crate::tree::workspace::{Outcome, Parent, SplitInfo, SplitRule, TreeWorkspace};

pub const SENTINEL: u32 = u32::MAX;

/// Inputs for building one tree. All matrices are row-major over the
/// *global* row index of `binned` (0..n); `rows` selects the active
/// (possibly subsampled) training rows.
pub struct BuildParams<'a> {
    /// Binned feature codes: the in-RAM [`crate::data::BinnedDataset`]
    /// (a `&BinnedDataset` coerces here) or the out-of-core
    /// `ChunkedBinned` store. Same codes + same chunk plan build the
    /// bit-identical tree (`rust/tests/out_of_core.rs`).
    pub binned: &'a dyn BinnedSource,
    pub rows: &'a [u32],
    /// full gradients [n, d] (leaf values)
    pub g: &'a [f32],
    /// full hessians [n, d] (leaf values)
    pub h: &'a [f32],
    pub d: usize,
    /// sketched scoring channels [n, kc] (split search); may alias g
    pub score_g: &'a [f32],
    pub kc: usize,
    /// sketched hessian channels (only for ScoreMode::HessL2)
    pub score_h: Option<&'a [f32]>,
    pub mode: ScoreMode,
    pub max_depth: usize,
    pub lambda: f32,
    pub min_data_in_leaf: usize,
    pub min_gain: f32,
    pub feature_mask: Option<&'a [bool]>,
    /// GBDT-MO (sparse): keep only the top-K |v| outputs per leaf
    pub sparse_topk: Option<usize>,
    /// per-row scoring weights parallel to `rows` (GOSS/MVS up-weighting;
    /// applied to every histogram channel including the count). Leaf
    /// values stay unweighted (exact over the kept rows).
    pub row_weights: Option<&'a [f32]>,
    /// how split search treats the missing bin (learned default
    /// direction vs. the legacy always-left policy)
    pub missing: MissingPolicy,
}

/// Build one tree with a freshly allocated [`TreeWorkspace`]. Also
/// returns `leaf_of_row` (global row -> leaf id, SENTINEL for rows
/// outside `rows`) so the caller can update predictions without
/// re-routing. Training loops should prefer [`build_tree_in`] with a
/// pooled workspace.
pub fn build_tree(p: &BuildParams, engine: &mut dyn ComputeEngine) -> (Tree, Vec<u32>) {
    let mut ws = TreeWorkspace::new();
    let tree = build_tree_in(p, engine, &mut ws);
    let leaf_of_row = ws.take_leaf_of_row();
    (tree, leaf_of_row)
}

/// Build one tree reusing the caller's [`TreeWorkspace`]; the leaf map
/// of this build stays readable via [`TreeWorkspace::leaf_of_row`].
/// After the workspace buffers have grown to their high-water mark, the
/// per-level loop performs no heap allocation (see `tree/workspace.rs`).
pub fn build_tree_in(
    p: &BuildParams,
    engine: &mut dyn ComputeEngine,
    ws: &mut TreeWorkspace,
) -> Tree {
    let n = p.binned.n_rows();
    let m = p.binned.n_features();
    let bins = p.binned.max_bins();
    // split routing takes the in-RAM column walk when the whole matrix
    // is resident; otherwise the chunk-outer walk below (identical
    // per-row decisions, identical row order — see the routing loop)
    let ram = p.binned.as_in_ram();
    let k1 = p.mode.channels(p.kc);
    assert!(p.max_depth >= 1, "max_depth must be >= 1");
    assert!(p.min_data_in_leaf >= 1, "min_data_in_leaf must be >= 1");
    if p.mode == ScoreMode::HessL2 {
        assert!(p.score_h.is_some(), "HessL2 scoring needs hessian channels");
    }
    // the stable partition keeps each node's rows in the input order;
    // ascending input keeps the merged-rank shard alignment exact
    // (engine/native.rs) — every sampler in boosting/sampling.rs sorts
    debug_assert!(
        p.rows.windows(2).all(|w| w[0] < w[1]),
        "rows must be strictly ascending"
    );
    if let Some(w) = p.row_weights {
        assert_eq!(w.len(), p.rows.len(), "row_weights parallel to rows");
    }

    // Gather rows and the compact [nr, k1] channel matrix in partition
    // order: scoring grads (+ hessians) + valid/count channel. From here
    // on, channel rows travel with their row ids through every split —
    // the engine never re-gathers them.
    let nr = p.rows.len();
    ws.rows.clear();
    ws.rows.extend_from_slice(p.rows);
    ws.chan.clear();
    ws.chan.resize(nr * k1, 0.0);
    for (j, &r) in p.rows.iter().enumerate() {
        let r = r as usize;
        let w = p.row_weights.map(|w| w[j]).unwrap_or(1.0);
        let dst = &mut ws.chan[j * k1..(j + 1) * k1];
        dst[..p.kc].copy_from_slice(&p.score_g[r * p.kc..(r + 1) * p.kc]);
        if let (ScoreMode::HessL2, Some(sh)) = (p.mode, p.score_h) {
            dst[p.kc..2 * p.kc].copy_from_slice(&sh[r * p.kc..(r + 1) * p.kc]);
        }
        dst[k1 - 1] = 1.0;
        if w != 1.0 {
            for v in dst.iter_mut() {
                *v *= w;
            }
        }
    }
    ws.rows_next.clear();
    ws.rows_next.resize(nr, 0);
    ws.chan_next.clear();
    ws.chan_next.resize(nr * k1, 0.0);
    ws.leaf_of_row.clear();
    ws.leaf_of_row.resize(n, SENTINEL);

    // root: one segment covering every sampled row
    ws.segs.clear();
    ws.segs.push(SlotRange::new(0, 0, nr as u32));
    ws.frontier.clear();
    ws.frontier.push(Parent::Root);

    let slice_sz = m * bins * k1;
    ws.hist.clear();
    ws.hist.resize(slice_sz, 0.0);
    engine.histograms(p.binned, &ws.rows, &ws.chan, k1, &ws.segs, 1, &mut ws.hist);

    let mut nodes: Vec<TreeNode> = Vec::new();
    let mut n_leaves = 0usize;
    let mut is_root_leaf = false;

    let settle_leaf =
        |parent: Parent,
         nodes: &mut Vec<TreeNode>,
         n_leaves: &mut usize,
         is_root_leaf: &mut bool|
         -> usize {
            let id = *n_leaves;
            *n_leaves += 1;
            match parent {
                Parent::Root => *is_root_leaf = true,
                Parent::Child { node, is_left } => {
                    let c = encode_leaf(id);
                    if is_left {
                        nodes[node].left = c;
                    } else {
                        nodes[node].right = c;
                    }
                }
            }
            id
        };

    for depth in 0..p.max_depth {
        let n_slots = ws.frontier.len();
        let spec = ScanSpec {
            n_slots,
            m,
            bins,
            k1,
            lam: p.lambda,
            mode: p.mode,
            kinds: p.binned.kinds(),
            missing: p.missing,
        };
        engine.split_gains(&ws.hist, &spec, &mut ws.gains, &mut ws.defaults);

        // decide each slot
        ws.outcomes.clear();
        ws.new_frontier.clear();
        ws.split_info.clear();
        for (slot, &parent) in ws.frontier.iter().enumerate() {
            let (pscore, pcount) = node_score(
                &ws.hist,
                slot,
                m,
                bins,
                k1,
                p.lambda,
                p.mode,
                &mut ws.score_scratch,
            );
            let dec: Option<SplitDecision> = if pcount < (2 * p.min_data_in_leaf) as f64 {
                None
            } else {
                best_split(
                    &ws.gains,
                    &ws.defaults,
                    &ws.hist,
                    slot,
                    &spec,
                    pscore,
                    pcount,
                    p.min_data_in_leaf,
                    p.min_gain,
                    p.feature_mask,
                    &mut ws.cat_scratch,
                )
            };
            match dec {
                None => {
                    let id = settle_leaf(parent, &mut nodes, &mut n_leaves, &mut is_root_leaf);
                    ws.outcomes.push(Outcome::Leaf(id as u32));
                }
                Some(d) => {
                    let node_idx = nodes.len();
                    let threshold = match d.cats {
                        None => p.binned.threshold_value(d.feature, d.bin as usize),
                        Some(_) => 0.0,
                    };
                    nodes.push(TreeNode {
                        feature: d.feature as u32,
                        bin: d.bin,
                        threshold,
                        default_left: d.default_left,
                        cats: d.cats,
                        left: 0,
                        right: 0,
                        gain: d.gain,
                    });
                    match parent {
                        Parent::Root => {}
                        Parent::Child { node, is_left } => {
                            if is_left {
                                nodes[node].left = node_idx as i32;
                            } else {
                                nodes[node].right = node_idx as i32;
                            }
                        }
                    }
                    let left_slot = ws.new_frontier.len() as u32;
                    ws.new_frontier.push(Parent::Child { node: node_idx, is_left: true });
                    let right_slot = ws.new_frontier.len() as u32;
                    ws.new_frontier.push(Parent::Child { node: node_idx, is_left: false });
                    ws.split_info.push(SplitInfo {
                        parent_slot: slot as u32,
                        left: left_slot,
                        right: right_slot,
                        count_left: d.count_left,
                        count_right: d.count_right,
                    });
                    ws.outcomes.push(Outcome::Split {
                        feature: d.feature as u32,
                        rule: match d.cats {
                            None => SplitRule::Numeric { bin: d.bin },
                            Some(cats) => SplitRule::Categorical { cats },
                        },
                        default_left: d.default_left,
                        left_slot,
                        right_slot,
                    });
                }
            }
        }

        // route: stable in-place partition of every split slot's range
        // (lefts stream to the ping-pong buffer, rights stage in a
        // scratch run appended after — both children keep ascending
        // order); leaf slots settle their rows and drop out
        let mut write = 0usize;
        ws.segs_next.clear();
        for (slot, outcome) in ws.outcomes.iter().enumerate() {
            let seg = ws.segs[slot];
            match outcome {
                Outcome::Leaf(id) => {
                    for pos in seg.range() {
                        ws.leaf_of_row[ws.rows[pos] as usize] = *id;
                    }
                }
                Outcome::Split { feature, rule, default_left, left_slot, right_slot } => {
                    ws.right_rows.clear();
                    ws.right_chan.clear();
                    let start = write;
                    if let Some(ram) = ram {
                        let col = ram.column(*feature as usize);
                        for pos in seg.range() {
                            let r = ws.rows[pos];
                            let crow = &ws.chan[pos * k1..(pos + 1) * k1];
                            let code = col[r as usize];
                            let go_left = if code == MISSING_BIN {
                                *default_left
                            } else {
                                match rule {
                                    SplitRule::Numeric { bin } => code <= *bin,
                                    SplitRule::Categorical { cats } => {
                                        cats.contains(code as u32 - 1)
                                    }
                                }
                            };
                            if go_left {
                                ws.rows_next[write] = r;
                                ws.chan_next[write * k1..(write + 1) * k1].copy_from_slice(crow);
                                write += 1;
                            } else {
                                ws.right_rows.push(r);
                                ws.right_chan.extend_from_slice(crow);
                            }
                        }
                    } else {
                        // chunk-outer walk: the segment's rows are
                        // ascending, so each chunk's share is one
                        // contiguous sub-range and visiting chunks in
                        // ascending order replays the exact row order
                        // of the in-RAM pass above — same decisions,
                        // same writes, bit-identical partition
                        let f = *feature as usize;
                        let (a, b) = (seg.start as usize, seg.end as usize);
                        let mut pos = a;
                        while pos < b {
                            let c = chunk_of(p.binned, ws.rows[pos] as usize);
                            let cr = p.binned.chunk_range(c);
                            let hi = pos
                                + ws.rows[pos..b].partition_point(|&r| (r as usize) < cr.end);
                            let rows = &ws.rows[..];
                            let chan = &ws.chan[..];
                            let rows_next = &mut ws.rows_next[..];
                            let chan_next = &mut ws.chan_next[..];
                            let right_rows = &mut ws.right_rows;
                            let right_chan = &mut ws.right_chan;
                            let write_ref = &mut write;
                            p.binned.with_chunk(c, &mut |cols| {
                                for pos in pos..hi {
                                    let r = rows[pos];
                                    let crow = &chan[pos * k1..(pos + 1) * k1];
                                    let code = cols.code(f, r as usize);
                                    let go_left = if code == MISSING_BIN {
                                        *default_left
                                    } else {
                                        match rule {
                                            SplitRule::Numeric { bin } => code <= *bin,
                                            SplitRule::Categorical { cats } => {
                                                cats.contains(code as u32 - 1)
                                            }
                                        }
                                    };
                                    let w = *write_ref;
                                    if go_left {
                                        rows_next[w] = r;
                                        chan_next[w * k1..(w + 1) * k1].copy_from_slice(crow);
                                        *write_ref += 1;
                                    } else {
                                        right_rows.push(r);
                                        right_chan.extend_from_slice(crow);
                                    }
                                }
                            });
                            pos = hi;
                        }
                    }
                    let mid = write;
                    let nright = ws.right_rows.len();
                    ws.rows_next[write..write + nright].copy_from_slice(&ws.right_rows);
                    ws.chan_next[write * k1..(write + nright) * k1]
                        .copy_from_slice(&ws.right_chan);
                    write += nright;
                    ws.segs_next.push(SlotRange::new(*left_slot, start as u32, mid as u32));
                    ws.segs_next.push(SlotRange::new(*right_slot, mid as u32, write as u32));
                }
            }
        }
        std::mem::swap(&mut ws.rows, &mut ws.rows_next);
        std::mem::swap(&mut ws.chan, &mut ws.chan_next);
        std::mem::swap(&mut ws.segs, &mut ws.segs_next);

        if ws.new_frontier.is_empty() {
            ws.frontier.clear();
            break;
        }
        std::mem::swap(&mut ws.frontier, &mut ws.new_frontier);
        if depth + 1 == p.max_depth {
            break; // children become leaves below; skip their histograms
        }

        // next-level histograms with sibling subtraction: accumulate only
        // the smaller child of every split (its contiguous range), then
        // big = parent − small
        let n_new = ws.frontier.len();
        ws.small_segs.clear();
        for si in &ws.split_info {
            let small = if si.count_left <= si.count_right { si.left } else { si.right };
            debug_assert_eq!(ws.segs[small as usize].slot, small);
            ws.small_segs.push(ws.segs[small as usize]);
        }
        ws.hist_next.clear();
        ws.hist_next.resize(n_new * slice_sz, 0.0);
        engine.histograms(
            p.binned,
            &ws.rows,
            &ws.chan,
            k1,
            &ws.small_segs,
            n_new,
            &mut ws.hist_next,
        );
        for si in &ws.split_info {
            let (small, big) = if si.count_left <= si.count_right {
                (si.left, si.right)
            } else {
                (si.right, si.left)
            };
            let pbase = si.parent_slot as usize * slice_sz;
            let sbase = small as usize * slice_sz;
            let bbase = big as usize * slice_sz;
            for i in 0..slice_sz {
                ws.hist_next[bbase + i] = ws.hist[pbase + i] - ws.hist_next[sbase + i];
            }
        }
        std::mem::swap(&mut ws.hist, &mut ws.hist_next);
    }

    // remaining frontier slots become leaves
    ws.slot_leaf.clear();
    for &parent in &ws.frontier {
        let id = settle_leaf(parent, &mut nodes, &mut n_leaves, &mut is_root_leaf);
        ws.slot_leaf.push(id as u32);
    }
    for seg in &ws.segs {
        let id = ws.slot_leaf[seg.slot as usize];
        for pos in seg.range() {
            ws.leaf_of_row[ws.rows[pos] as usize] = id;
        }
    }

    // exact leaf values from the full derivative matrices (eq. 3)
    engine.leaf_sums(p.rows, &ws.leaf_of_row, p.g, p.h, p.d, n_leaves, &mut ws.sums);
    let mut leaf_values = vec![0.0f32; n_leaves * p.d];
    for l in 0..n_leaves {
        for j in 0..p.d {
            let gs = ws.sums.gsum[l * p.d + j];
            let hs = ws.sums.hsum[l * p.d + j];
            leaf_values[l * p.d + j] = -gs / (hs + p.lambda);
        }
    }
    if let Some(topk) = p.sparse_topk {
        sparsify_leaves(&mut leaf_values, n_leaves, p.d, topk);
    }

    let tree = Tree {
        n_outputs: p.d,
        nodes: if is_root_leaf { Vec::new() } else { nodes },
        leaf_values,
        n_leaves,
    };
    debug_assert!(tree.validate().is_ok(), "{:?}", tree.validate());
    tree
}

/// Index of the chunk holding global row `r`. Chunks partition
/// `0..n_rows` in ascending order, so this is a plain binary search.
fn chunk_of(src: &dyn BinnedSource, r: usize) -> usize {
    let (mut lo, mut hi) = (0usize, src.n_chunks());
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        if src.chunk_range(mid).end <= r {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    lo
}

/// GBDT-MO (sparse): keep only the top-K outputs by |v| per leaf.
fn sparsify_leaves(values: &mut [f32], n_leaves: usize, d: usize, topk: usize) {
    if topk >= d {
        return;
    }
    let mut idx: Vec<usize> = Vec::with_capacity(d);
    for l in 0..n_leaves {
        let row = &mut values[l * d..(l + 1) * d];
        idx.clear();
        idx.extend(0..d);
        idx.sort_by(|&a, &b| row[b].abs().partial_cmp(&row[a].abs()).unwrap());
        for &j in &idx[topk..] {
            row[j] = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::binning::BinnedDataset;
    use crate::data::dataset::{Dataset, Targets};
    use crate::engine::NativeEngine;
    use crate::util::proptest::run_prop;
    use crate::util::rng::Rng;

    /// 1-feature dataset where gradient sign flips at x = 0.
    fn sign_problem(n: usize, seed: u64) -> (BinnedDataset, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let mut x = vec![0.0f32; n];
        rng.fill_gaussian(&mut x, 1.0);
        let g: Vec<f32> = x.iter().map(|&v| if v <= 0.0 { 1.0 } else { -1.0 }).collect();
        let h = vec![1.0f32; n];
        let ds = Dataset::new(
            n,
            1,
            x,
            Targets::Regression { values: vec![0.0; n], n_targets: 1 },
        );
        (BinnedDataset::from_dataset(&ds, 32), g, h)
    }

    fn params<'a>(
        binned: &'a BinnedDataset,
        rows: &'a [u32],
        g: &'a [f32],
        h: &'a [f32],
        max_depth: usize,
    ) -> BuildParams<'a> {
        BuildParams {
            binned,
            rows,
            g,
            h,
            d: 1,
            score_g: g,
            kc: 1,
            score_h: None,
            mode: ScoreMode::CountL2,
            max_depth,
            lambda: 1.0,
            min_data_in_leaf: 1,
            min_gain: 0.0,
            feature_mask: None,
            sparse_topk: None,
            row_weights: None,
            missing: MissingPolicy::Learn,
        }
    }

    #[test]
    fn splits_sign_problem_at_zero() {
        let (binned, g, h) = sign_problem(400, 1);
        let rows: Vec<u32> = (0..400).collect();
        let mut eng = NativeEngine::new();
        let (tree, leaf_of_row) = build_tree(&params(&binned, &rows, &g, &h, 1), &mut eng);
        assert_eq!(tree.n_leaves, 2);
        assert_eq!(tree.nodes.len(), 1);
        tree.validate().unwrap();
        // threshold near 0 (within a bin width)
        assert!(tree.nodes[0].threshold.abs() < 0.3, "t={}", tree.nodes[0].threshold);
        // leaf values have opposite signs: -sum(g)/(count+lam)
        let v0 = tree.leaf_values[tree.leaf_for_raw(&[-2.0])];
        let v1 = tree.leaf_values[tree.leaf_for_raw(&[2.0])];
        assert!(v0 < 0.0 && v1 > 0.0, "v0={v0} v1={v1}");
        // leaf_of_row consistent with routing
        for r in 0..400usize {
            assert_eq!(leaf_of_row[r] as usize, tree.leaf_for_binned(&binned, r));
        }
    }

    #[test]
    fn stump_when_no_gain() {
        // constant gradient: no split improves the score
        let (binned, _, h) = sign_problem(100, 2);
        let g = vec![1.0f32; 100];
        let rows: Vec<u32> = (0..100).collect();
        let mut eng = NativeEngine::new();
        let (tree, _) = build_tree(&params(&binned, &rows, &g, &h, 3), &mut eng);
        assert_eq!(tree.n_leaves, 1);
        assert!(tree.nodes.is_empty());
        // leaf value = -100/(100+1)
        assert!((tree.leaf_values[0] + 100.0 / 101.0).abs() < 1e-5);
    }

    #[test]
    fn respects_max_depth() {
        let (binned, g, h) = sign_problem(500, 3);
        // noisy gradients force deep trees if allowed
        let mut rng = Rng::new(9);
        let gn: Vec<f32> = g.iter().map(|&v| v + rng.next_gaussian() as f32).collect();
        let rows: Vec<u32> = (0..500).collect();
        let mut eng = NativeEngine::new();
        for depth in 1..=4 {
            let (tree, _) = build_tree(&params(&binned, &rows, &gn, &h, depth), &mut eng);
            assert!(tree.depth() <= depth, "depth {} > {}", tree.depth(), depth);
            assert!(tree.n_leaves <= 1 << depth);
            tree.validate().unwrap();
        }
    }

    #[test]
    fn min_data_in_leaf_enforced() {
        let (binned, g, h) = sign_problem(300, 4);
        let mut rng = Rng::new(10);
        let gn: Vec<f32> = g.iter().map(|&v| v + 0.5 * rng.next_gaussian() as f32).collect();
        let rows: Vec<u32> = (0..300).collect();
        let mut eng = NativeEngine::new();
        let mut p = params(&binned, &rows, &gn, &h, 5);
        p.min_data_in_leaf = 40;
        let (tree, leaf_of_row) = build_tree(&p, &mut eng);
        let mut counts = vec![0usize; tree.n_leaves];
        for r in 0..300usize {
            counts[leaf_of_row[r] as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c >= 40), "counts {counts:?}");
    }

    #[test]
    fn subsampled_rows_only() {
        let (binned, g, h) = sign_problem(200, 5);
        let rows: Vec<u32> = (0..200).filter(|&r| r % 2 == 0).collect();
        let mut eng = NativeEngine::new();
        let (_, leaf_of_row) = build_tree(&params(&binned, &rows, &g, &h, 2), &mut eng);
        for r in 0..200usize {
            if r % 2 == 0 {
                assert_ne!(leaf_of_row[r], SENTINEL);
            } else {
                assert_eq!(leaf_of_row[r], SENTINEL);
            }
        }
    }

    #[test]
    fn leaf_of_row_partitions_rows() {
        run_prop("leaf_of_row partitions", 10, |gen| {
            let n = gen.usize_in(50, 300);
            let (binned, _, h) = sign_problem(n, gen.seed);
            let g = gen.vec_gaussian(n, 1.0);
            let rows: Vec<u32> = (0..n as u32).collect();
            let mut eng = NativeEngine::new();
            let depth = gen.usize_in(1, 4);
            let (tree, leaf_of_row) = build_tree(&params(&binned, &rows, &g, &h, depth), &mut eng);
            tree.validate().unwrap();
            // every row lands in a valid leaf that matches tree routing
            for r in 0..n {
                let l = leaf_of_row[r] as usize;
                assert!(l < tree.n_leaves);
                assert_eq!(l, tree.leaf_for_binned(&binned, r));
            }
        });
    }

    #[test]
    fn subtraction_equals_direct_histograms() {
        // depth-2 build must match a build where subtraction is disabled;
        // we verify indirectly: leaf values of depth-2 tree equal the
        // exact per-leaf -sum(g)/(count+lam).
        let (binned, g, h) = sign_problem(300, 7);
        let mut rng = Rng::new(11);
        let gn: Vec<f32> = g.iter().map(|&v| v + 0.3 * rng.next_gaussian() as f32).collect();
        let rows: Vec<u32> = (0..300).collect();
        let mut eng = NativeEngine::new();
        let (tree, leaf_of_row) = build_tree(&params(&binned, &rows, &gn, &h, 2), &mut eng);
        let mut gsum = vec![0.0f64; tree.n_leaves];
        let mut cnt = vec![0.0f64; tree.n_leaves];
        for r in 0..300usize {
            gsum[leaf_of_row[r] as usize] += gn[r] as f64;
            cnt[leaf_of_row[r] as usize] += 1.0;
        }
        for l in 0..tree.n_leaves {
            let want = -(gsum[l] / (cnt[l] + 1.0)) as f32;
            assert!(
                (tree.leaf_values[l] - want).abs() < 1e-4,
                "leaf {l}: {} vs {want}",
                tree.leaf_values[l]
            );
        }
    }

    #[test]
    fn nan_rows_follow_the_learned_default() {
        // x > 0 carries g = -1; x <= 0 carries g = +1; a fifth of the
        // rows are missing and carry g = -1 — the learned default must
        // send them right, with the negative-gradient side
        let n = 500;
        let mut rng = Rng::new(21);
        let mut x = vec![0.0f32; n];
        rng.fill_gaussian(&mut x, 1.0);
        let mut g = vec![0.0f32; n];
        for i in 0..n {
            if i % 5 == 0 {
                x[i] = f32::NAN;
                g[i] = -1.0;
            } else {
                g[i] = if x[i] <= 0.0 { 1.0 } else { -1.0 };
            }
        }
        let h = vec![1.0f32; n];
        let ds = Dataset::new(
            n,
            1,
            x,
            Targets::Regression { values: vec![0.0; n], n_targets: 1 },
        );
        let binned = BinnedDataset::from_dataset(&ds, 32);
        let rows: Vec<u32> = (0..n as u32).collect();
        let mut eng = NativeEngine::new();
        let (tree, leaf_of_row) = build_tree(&params(&binned, &rows, &g, &h, 1), &mut eng);
        assert_eq!(tree.n_leaves, 2);
        assert!(!tree.nodes[0].default_left, "missing aligns with the right side");
        // raw NaN routes with the x > 0 rows
        assert_eq!(tree.leaf_for_raw(&[f32::NAN]), tree.leaf_for_raw(&[3.0]));
        for r in 0..n {
            assert_eq!(leaf_of_row[r] as usize, tree.leaf_for_binned(&binned, r));
        }
    }

    #[test]
    fn categorical_build_isolates_a_scattered_set() {
        // 6 categories; g = +1 for ids {0, 3, 5}, -1 for {1, 2, 4}: one
        // categorical split isolates the scattered set exactly
        let n = 600;
        let x: Vec<f32> = (0..n).map(|i| (i % 6) as f32).collect();
        let g: Vec<f32> = (0..n)
            .map(|i| if matches!(i % 6, 0 | 3 | 5) { 1.0 } else { -1.0 })
            .collect();
        let h = vec![1.0f32; n];
        let mut ds = Dataset::new(
            n,
            1,
            x,
            Targets::Regression { values: vec![0.0; n], n_targets: 1 },
        );
        ds.mark_categorical(&[0]);
        let binned = BinnedDataset::from_dataset(&ds, 32);
        let rows: Vec<u32> = (0..n as u32).collect();
        let mut eng = NativeEngine::new();
        let (tree, leaf_of_row) = build_tree(&params(&binned, &rows, &g, &h, 1), &mut eng);
        assert_eq!(tree.n_leaves, 2);
        let cats = tree.nodes[0].cats.expect("categorical split");
        let mut ids: Vec<u32> = cats.ids().collect();
        // the split may put either side of the partition "left"
        if !ids.contains(&0) {
            ids = (0..6u32).filter(|i| !ids.contains(i)).collect();
        }
        assert_eq!(ids, vec![0, 3, 5]);
        // routing consistency, binned vs raw
        for r in 0..n {
            assert_eq!(leaf_of_row[r] as usize, tree.leaf_for_binned(&binned, r));
            assert_eq!(tree.leaf_for_binned(&binned, r), tree.leaf_for_raw(&[(r % 6) as f32]));
        }
    }

    #[test]
    fn sparse_topk_zeroes_small_outputs() {
        let mut v = vec![
            3.0, -1.0, 0.5, -4.0, // leaf 0
            0.1, 0.2, 0.3, 0.4, // leaf 1
        ];
        sparsify_leaves(&mut v, 2, 4, 2);
        assert_eq!(&v[0..4], &[3.0, 0.0, 0.0, -4.0]);
        assert_eq!(&v[4..8], &[0.0, 0.0, 0.3, 0.4]);
    }

    /// Test-only chunked facade over an in-RAM matrix: same codes, no
    /// `as_in_ram` fast path, so the builder takes the chunk-outer
    /// routing arm.
    struct Chunked<'a> {
        b: &'a BinnedDataset,
        chunk: usize,
    }

    impl BinnedSource for Chunked<'_> {
        fn n_rows(&self) -> usize {
            self.b.n_rows
        }
        fn n_features(&self) -> usize {
            self.b.n_features
        }
        fn max_bins(&self) -> usize {
            self.b.max_bins
        }
        fn kinds(&self) -> &[crate::data::FeatureKind] {
            &self.b.kinds
        }
        fn threshold_value(&self, f: usize, b: usize) -> f32 {
            self.b.threshold_value(f, b)
        }
        fn n_chunks(&self) -> usize {
            (self.b.n_rows + self.chunk - 1) / self.chunk
        }
        fn chunk_range(&self, c: usize) -> std::ops::Range<usize> {
            let start = c * self.chunk;
            start..(start + self.chunk).min(self.b.n_rows)
        }
        fn with_chunk(&self, c: usize, body: &mut dyn FnMut(crate::data::binning::ChunkCols<'_>)) {
            let cr = self.chunk_range(c);
            let len = cr.len();
            let mut codes = vec![0u8; self.b.n_features * len];
            for f in 0..self.b.n_features {
                codes[f * len..(f + 1) * len].copy_from_slice(&self.b.column(f)[cr.clone()]);
            }
            body(crate::data::binning::ChunkCols { codes: &codes, start: cr.start, len });
        }
    }

    #[test]
    fn chunked_source_builds_bit_identical_tree() {
        let (binned, g, h) = sign_problem(313, 17);
        let mut rng = Rng::new(3);
        let gn: Vec<f32> = g.iter().map(|&v| v + 0.4 * rng.next_gaussian() as f32).collect();
        let rows: Vec<u32> = (0..313).filter(|&r| r % 7 != 3).collect();
        let mut eng = NativeEngine::new();
        let (want, want_leaves) = build_tree(&params(&binned, &rows, &gn, &h, 4), &mut eng);
        for chunk in [313usize, 64, 1] {
            let src = Chunked { b: &binned, chunk };
            let mut p = params(&binned, &rows, &gn, &h, 4);
            p.binned = &src;
            let (got, got_leaves) = build_tree(&p, &mut eng);
            assert_eq!(got, want, "chunk={chunk}");
            assert_eq!(got_leaves, want_leaves, "chunk={chunk}");
        }
    }

    #[test]
    fn multioutput_leaf_values() {
        // d=2: gradients differ per output; leaf values computed per output
        let (binned, _, _) = sign_problem(100, 8);
        let mut g = vec![0.0f32; 200];
        let mut h = vec![0.0f32; 200];
        for r in 0..100 {
            let x = binned.column(0)[r];
            g[r * 2] = if x < 10 { 1.0 } else { -1.0 };
            g[r * 2 + 1] = 0.5;
            h[r * 2] = 1.0;
            h[r * 2 + 1] = 2.0;
        }
        let rows: Vec<u32> = (0..100).collect();
        // scoring on output 0 only
        let score: Vec<f32> = (0..100).map(|r| g[r * 2]).collect();
        let p = BuildParams {
            binned: &binned,
            rows: &rows,
            g: &g,
            h: &h,
            d: 2,
            score_g: &score,
            kc: 1,
            score_h: None,
            mode: ScoreMode::CountL2,
            max_depth: 1,
            lambda: 1.0,
            min_data_in_leaf: 1,
            min_gain: 0.0,
            feature_mask: None,
            sparse_topk: None,
            row_weights: None,
            missing: MissingPolicy::Learn,
        };
        let mut eng = NativeEngine::new();
        let (tree, leaf_of_row) = build_tree(&p, &mut eng);
        assert_eq!(tree.n_outputs, 2);
        // output-1 leaf value: -0.5*c / (2c + 1) per leaf with c rows
        for l in 0..tree.n_leaves {
            let c = (0..100).filter(|&r| leaf_of_row[r] == l as u32).count() as f32;
            let want = -(0.5 * c) / (2.0 * c + 1.0);
            assert!((tree.leaf_values[l * 2 + 1] - want).abs() < 1e-5);
        }
    }
}
