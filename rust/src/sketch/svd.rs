//! Truncated-SVD sketch via subspace (block power) iteration
//! (Appendix A.1: the optimal deterministic sketch, error ≤ σ²_{k+1}(G)).
//!
//! The paper excludes SVD from the main method set because exact SVD is
//! O(min(nd², n²d)); we implement the randomized subspace-iteration
//! variant at O(nd·k·iters) as an *ablation* so the bench suite can show
//! where the quality/cost trade-off sits relative to the three cheap
//! sketches.

use crate::util::rng::Rng;

/// Rank-k sketch G_k = G·V_k where V_k approximates the top-k right
/// singular subspace of row-major `g` [n, d].
pub fn truncated_svd_sketch(
    g: &[f32],
    n: usize,
    d: usize,
    k: usize,
    iters: usize,
    rng: &mut Rng,
) -> Vec<f32> {
    let k = k.min(d).max(1);
    // V: d x k orthonormal basis, randomly initialized
    let mut v = vec![0.0f32; d * k];
    rng.fill_gaussian(&mut v, 1.0);
    orthonormalize(&mut v, d, k);
    let mut gv = vec![0.0f32; n * k];
    for _ in 0..iters.max(1) {
        // GV: n x k
        matmul(g, n, d, &v, k, &mut gv);
        // V <- Gᵀ(GV): d x k, then re-orthonormalize
        matmul_t(g, n, d, &gv, k, &mut v);
        orthonormalize(&mut v, d, k);
    }
    matmul(g, n, d, &v, k, &mut gv);
    gv
}

/// out[n,k] = a[n,d] @ b[d,k]
fn matmul(a: &[f32], n: usize, d: usize, b: &[f32], k: usize, out: &mut [f32]) {
    out.fill(0.0);
    for i in 0..n {
        let ai = &a[i * d..(i + 1) * d];
        let oi = &mut out[i * k..(i + 1) * k];
        for (j, &av) in ai.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let bj = &b[j * k..(j + 1) * k];
            for c in 0..k {
                oi[c] += av * bj[c];
            }
        }
    }
}

/// out[d,k] = aᵀ[d,n] @ b[n,k]  (a given row-major [n,d])
fn matmul_t(a: &[f32], n: usize, d: usize, b: &[f32], k: usize, out: &mut [f32]) {
    out.fill(0.0);
    for i in 0..n {
        let ai = &a[i * d..(i + 1) * d];
        let bi = &b[i * k..(i + 1) * k];
        for (j, &av) in ai.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let oj = &mut out[j * k..(j + 1) * k];
            for c in 0..k {
                oj[c] += av * bi[c];
            }
        }
    }
}

/// Modified Gram–Schmidt on the k columns of row-major v [d, k].
fn orthonormalize(v: &mut [f32], d: usize, k: usize) {
    for c in 0..k {
        // subtract projections on previous columns
        for p in 0..c {
            let mut dot = 0.0f64;
            for j in 0..d {
                dot += v[j * k + c] as f64 * v[j * k + p] as f64;
            }
            for j in 0..d {
                v[j * k + c] -= (dot as f32) * v[j * k + p];
            }
        }
        let mut norm = 0.0f64;
        for j in 0..d {
            norm += (v[j * k + c] as f64).powi(2);
        }
        let norm = norm.sqrt();
        if norm < 1e-12 {
            // degenerate column: re-randomize deterministically
            for j in 0..d {
                v[j * k + c] = if (j + c) % 2 == 0 { 1.0 } else { -1.0 };
            }
            orthonormalize_col(v, d, k, c);
        } else {
            let inv = (1.0 / norm) as f32;
            for j in 0..d {
                v[j * k + c] *= inv;
            }
        }
    }
}

fn orthonormalize_col(v: &mut [f32], d: usize, k: usize, c: usize) {
    for p in 0..c {
        let mut dot = 0.0f64;
        for j in 0..d {
            dot += v[j * k + c] as f64 * v[j * k + p] as f64;
        }
        for j in 0..d {
            v[j * k + c] -= (dot as f32) * v[j * k + p];
        }
    }
    let mut norm = 0.0f64;
    for j in 0..d {
        norm += (v[j * k + c] as f64).powi(2);
    }
    let inv = (1.0 / norm.sqrt().max(1e-12)) as f32;
    for j in 0..d {
        v[j * k + c] *= inv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Frobenius norm of G Gᵀ - G_k G_kᵀ (the Lemma A.1 quantity, upper
    /// bounds the operator norm).
    fn gram_error(g: &[f32], gk: &[f32], n: usize, d: usize, k: usize) -> f64 {
        let mut err = 0.0f64;
        for i in 0..n {
            for j in 0..n {
                let mut gij = 0.0f64;
                for c in 0..d {
                    gij += g[i * d + c] as f64 * g[j * d + c] as f64;
                }
                let mut kij = 0.0f64;
                for c in 0..k {
                    kij += gk[i * k + c] as f64 * gk[j * k + c] as f64;
                }
                err += (gij - kij) * (gij - kij);
            }
        }
        err.sqrt()
    }

    fn low_rank_matrix(n: usize, d: usize, r: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut u = vec![0.0f32; n * r];
        let mut w = vec![0.0f32; r * d];
        rng.fill_gaussian(&mut u, 1.0);
        rng.fill_gaussian(&mut w, 1.0);
        let mut g = vec![0.0f32; n * d];
        for i in 0..n {
            for j in 0..d {
                let mut s = 0.0f32;
                for t in 0..r {
                    s += u[i * r + t] * w[t * d + j];
                }
                g[i * d + j] = s;
            }
        }
        g
    }

    #[test]
    fn exact_recovery_of_low_rank() {
        // rank-2 matrix, k=2 sketch: gram error must be ~0
        let (n, d) = (20, 10);
        let g = low_rank_matrix(n, d, 2, 1);
        let mut rng = Rng::new(0);
        let gk = truncated_svd_sketch(&g, n, d, 2, 12, &mut rng);
        let gnorm: f64 = g.iter().map(|&x| (x as f64).powi(2)).sum::<f64>();
        let err = gram_error(&g, &gk, n, d, 2);
        assert!(err < 1e-2 * gnorm, "err={err} gnorm={gnorm}");
    }

    #[test]
    fn svd_beats_random_columns_on_low_rank() {
        let (n, d, k) = (30, 15, 3);
        let g = low_rank_matrix(n, d, 3, 5);
        let mut rng = Rng::new(2);
        let gk = truncated_svd_sketch(&g, n, d, k, 10, &mut rng);
        let svd_err = gram_error(&g, &gk, n, d, k);
        // naive: first k columns
        let mut naive = vec![0.0f32; n * k];
        for i in 0..n {
            for c in 0..k {
                naive[i * k + c] = g[i * d + c];
            }
        }
        let naive_err = gram_error(&g, &naive, n, d, k);
        assert!(svd_err < naive_err, "svd {svd_err} vs naive {naive_err}");
    }

    #[test]
    fn basis_is_orthonormal() {
        let mut rng = Rng::new(3);
        let (d, k) = (12, 4);
        let mut v = vec![0.0f32; d * k];
        rng.fill_gaussian(&mut v, 2.0);
        orthonormalize(&mut v, d, k);
        for a in 0..k {
            for b in 0..k {
                let mut dot = 0.0f64;
                for j in 0..d {
                    dot += v[j * k + a] as f64 * v[j * k + b] as f64;
                }
                let want = if a == b { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < 1e-4, "({a},{b}) dot={dot}");
            }
        }
    }

    #[test]
    fn handles_zero_matrix() {
        let g = vec![0.0f32; 10 * 4];
        let mut rng = Rng::new(4);
        let gk = truncated_svd_sketch(&g, 10, 4, 2, 5, &mut rng);
        assert!(gk.iter().all(|&x| x.abs() < 1e-6));
    }
}
