//! Sketched split scoring (paper section 3 + Appendix A).
//!
//! A sketch replaces the n x d gradient matrix G with an n x k matrix G_k
//! (k << d) *for split search only*; leaf values always use the full G/H.
//! The approximation error `sup_R |S_G(R) - S_{G_k}(R)|` is bounded by
//! `||G Gᵀ - G_k G_kᵀ||` (Lemma A.1), which each strategy controls:
//!
//! * [`SketchConfig::TopOutputs`]       — error ≤ Σ_{j>k} ‖g_(j)‖²  (Prop. A.3)
//! * [`SketchConfig::RandomSampling`]   — ≲ √(sr(G)·log)·‖G‖²/√k    (Prop. A.4)
//! * [`SketchConfig::RandomProjection`] — ≲ √(sr(G))·‖G‖²/√k        (Prop. A.5)
//! * [`SketchConfig::TruncatedSvd`]     — ≤ σ²_{k+1}(G), optimal    (Prop. A.2)

use crate::engine::ComputeEngine;
use crate::util::rng::Rng;

pub mod analysis;
pub mod svd;

/// Which sketch to apply before the split search.
///
/// Besides the approximation error (module docs), the choice sets the
/// histogram channel width `k1 = k + 1` that the engine's parallel
/// histogram path accumulates per row: each thread-local shard buffer is
/// `n_slots * m * bins * k1` floats, so smaller `k` means cheaper shards
/// *and* a cheaper deterministic reduction — sketching and threading
/// compound. The shard partition depends only on the row count and
/// histogram shape, so every variant is bit-identical across thread
/// counts (`rust/tests/parallel_determinism.rs`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SketchConfig {
    /// No sketch ("SketchBoost Full" — the CatBoost single-tree regime).
    ///
    /// Parallel path: scoring histograms are `d + 1` channels wide — the
    /// widest shards and the only variant that routinely hits the
    /// dynamic-width accumulation kernel (`hist_pass_dyn`), so it gains
    /// the most wall-clock from threading and pays the largest shard
    /// memory (bounded by the engine's reduction-cost cap).
    None,
    /// Keep the k columns of G with largest Euclidean norm (section 3.1).
    ///
    /// Parallel path: a column gather feeds `k + 1`-channel histograms;
    /// `k = 1`/`k = 5` hit the unrolled `k1 = 2`/`k1 = 6` kernels.
    /// Deterministic for any thread count (ties break by column index).
    TopOutputs { k: usize },
    /// Sample k columns i.i.d. with p_i ∝ ‖g_i‖², scaled by 1/√(k·p_i)
    /// (section 3.2).
    ///
    /// Parallel path: same gathered `k + 1`-channel histograms as
    /// `TopOutputs`; the sampling randomness comes from the per-round
    /// seeded RNG, not from scheduling, so threads never change it.
    RandomSampling { k: usize },
    /// G_k = G·Π with Π ~ N(0, 1/k) entries (section 3.3).
    ///
    /// Parallel path: the projection gemm stays serial (it is off the
    /// critical path — EXPERIMENTS.md §Perf); the resulting `k + 1`
    /// channels then flow through the sharded histogram build. The
    /// paper-default `k = 5` uses the unrolled `k1 = 6` kernel.
    RandomProjection { k: usize },
    /// Best rank-k sketch via truncated SVD (Appendix A.1; O(nd·k·iters),
    /// implemented with subspace power iteration). Ablation baseline.
    ///
    /// Parallel path: the power iteration is serial and dominates for
    /// large `iters`; histogram threading only speeds up the per-level
    /// accumulation that follows, so expect smaller end-to-end gains
    /// than the section-3 sketches.
    TruncatedSvd { k: usize, iters: usize },
}

impl SketchConfig {
    pub fn name(&self) -> &'static str {
        match self {
            SketchConfig::None => "full",
            SketchConfig::TopOutputs { .. } => "top-outputs",
            SketchConfig::RandomSampling { .. } => "random-sampling",
            SketchConfig::RandomProjection { .. } => "random-projection",
            SketchConfig::TruncatedSvd { .. } => "truncated-svd",
        }
    }

    pub fn parse(s: &str, k: usize) -> Option<SketchConfig> {
        match s {
            "full" | "none" => Some(SketchConfig::None),
            "top" | "top-outputs" | "topk" => Some(SketchConfig::TopOutputs { k }),
            "sampling" | "random-sampling" | "rs" => Some(SketchConfig::RandomSampling { k }),
            "projection" | "random-projection" | "rp" => {
                Some(SketchConfig::RandomProjection { k })
            }
            "svd" | "truncated-svd" => Some(SketchConfig::TruncatedSvd { k, iters: 8 }),
            _ => None,
        }
    }

    /// Effective number of scoring columns for output dimension d.
    pub fn k_effective(&self, d: usize) -> usize {
        match self {
            SketchConfig::None => d,
            SketchConfig::TopOutputs { k }
            | SketchConfig::RandomSampling { k }
            | SketchConfig::RandomProjection { k }
            | SketchConfig::TruncatedSvd { k, .. } => (*k).min(d).max(1),
        }
    }

    /// Build the sketch of row-major `g` [n, d].
    ///
    /// Returns `None` when the sketch is the identity (Full, or k >= d for
    /// the column-selection sketches), so the caller can use `g` directly
    /// without a copy. `Some((g_k, k))` otherwise, `g_k` row-major [n, k].
    pub fn apply(
        &self,
        g: &[f32],
        n: usize,
        d: usize,
        rng: &mut Rng,
        engine: &mut dyn ComputeEngine,
    ) -> Option<(Vec<f32>, usize)> {
        let k = self.k_effective(d);
        match self {
            SketchConfig::None => None,
            _ if k >= d && !matches!(self, SketchConfig::RandomProjection { .. }) => None,
            SketchConfig::TopOutputs { .. } => {
                let norms = column_sq_norms(g, n, d);
                let mut idx: Vec<usize> = (0..d).collect();
                idx.sort_by(|&a, &b| norms[b].partial_cmp(&norms[a]).unwrap());
                idx.truncate(k);
                Some((gather_columns(g, n, d, &idx, None), k))
            }
            SketchConfig::RandomSampling { .. } => {
                let norms = column_sq_norms(g, n, d);
                let total: f64 = norms.iter().sum();
                if total <= 0.0 {
                    // all-zero gradients: any sketch works; take first k
                    let idx: Vec<usize> = (0..k).collect();
                    return Some((gather_columns(g, n, d, &idx, None), k));
                }
                let mut cumsum = Vec::with_capacity(d);
                let mut acc = 0.0f64;
                for &w in &norms {
                    acc += w;
                    cumsum.push(acc);
                }
                // i.i.d. with replacement, as in the paper
                let idx: Vec<usize> = (0..k).map(|_| rng.next_categorical(&cumsum)).collect();
                // scale column i by 1/sqrt(k * p_i) for unbiasedness
                let scales: Vec<f32> = idx
                    .iter()
                    .map(|&i| {
                        let p = norms[i] / total;
                        (1.0 / (k as f64 * p).sqrt()) as f32
                    })
                    .collect();
                Some((gather_columns(g, n, d, &idx, Some(&scales)), k))
            }
            SketchConfig::RandomProjection { .. } => {
                let sigma = 1.0 / (k as f64).sqrt();
                let mut proj = vec![0.0f32; d * k];
                rng.fill_gaussian(&mut proj, sigma);
                let mut out = vec![0.0f32; n * k];
                engine.sketch_project(g, n, d, &proj, k, &mut out);
                Some((out, k))
            }
            SketchConfig::TruncatedSvd { iters, .. } => {
                Some((svd::truncated_svd_sketch(g, n, d, k, *iters, rng), k))
            }
        }
    }
}

/// Squared Euclidean norm of each of the d columns of row-major g [n, d].
pub fn column_sq_norms(g: &[f32], n: usize, d: usize) -> Vec<f64> {
    let mut norms = vec![0.0f64; d];
    for i in 0..n {
        let row = &g[i * d..(i + 1) * d];
        for (j, &v) in row.iter().enumerate() {
            norms[j] += (v as f64) * (v as f64);
        }
    }
    norms
}

/// Gather columns `idx` (with optional per-column scaling) into a new
/// row-major [n, idx.len()] matrix.
pub fn gather_columns(
    g: &[f32],
    n: usize,
    d: usize,
    idx: &[usize],
    scales: Option<&[f32]>,
) -> Vec<f32> {
    let k = idx.len();
    let mut out = vec![0.0f32; n * k];
    for i in 0..n {
        let row = &g[i * d..(i + 1) * d];
        let dst = &mut out[i * k..(i + 1) * k];
        match scales {
            None => {
                for (c, &j) in idx.iter().enumerate() {
                    dst[c] = row[j];
                }
            }
            Some(s) => {
                for (c, &j) in idx.iter().enumerate() {
                    dst[c] = row[j] * s[c];
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::NativeEngine;
    use crate::util::proptest::run_prop;

    fn toy_g(n: usize, d: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut g = vec![0.0f32; n * d];
        rng.fill_gaussian(&mut g, 1.0);
        // give columns very different norms
        for i in 0..n {
            for j in 0..d {
                g[i * d + j] *= (j + 1) as f32;
            }
        }
        g
    }

    #[test]
    fn column_norms_correct() {
        let g = vec![1.0f32, 2.0, 3.0, 4.0]; // [[1,2],[3,4]]
        let n = column_sq_norms(&g, 2, 2);
        assert!((n[0] - 10.0).abs() < 1e-9);
        assert!((n[1] - 20.0).abs() < 1e-9);
    }

    #[test]
    fn top_outputs_selects_largest() {
        let n = 50;
        let d = 6;
        let g = toy_g(n, d, 1);
        let mut rng = Rng::new(0);
        let mut eng = NativeEngine::new();
        let (gk, k) = SketchConfig::TopOutputs { k: 2 }
            .apply(&g, n, d, &mut rng, &mut eng)
            .unwrap();
        assert_eq!(k, 2);
        // largest-norm columns are d-1 and d-2 by construction
        for i in 0..n {
            assert_eq!(gk[i * 2], g[i * d + d - 1]);
            assert_eq!(gk[i * 2 + 1], g[i * d + d - 2]);
        }
    }

    #[test]
    fn full_and_oversized_k_are_identity() {
        let g = toy_g(10, 3, 2);
        let mut rng = Rng::new(0);
        let mut eng = NativeEngine::new();
        assert!(SketchConfig::None.apply(&g, 10, 3, &mut rng, &mut eng).is_none());
        assert!(SketchConfig::TopOutputs { k: 5 }
            .apply(&g, 10, 3, &mut rng, &mut eng)
            .is_none());
    }

    #[test]
    fn random_sampling_prefers_heavy_columns() {
        let n = 30;
        let d = 10;
        // column d-1 carries almost all mass
        let mut g = vec![0.01f32; n * d];
        for i in 0..n {
            g[i * d + d - 1] = 10.0;
        }
        let mut eng = NativeEngine::new();
        let mut heavy = 0usize;
        let mut draws = 0usize;
        for seed in 0..50 {
            let mut rng = Rng::new(seed);
            let (gk, k) = SketchConfig::RandomSampling { k: 2 }
                .apply(&g, n, d, &mut rng, &mut eng)
                .unwrap();
            draws += k;
            for c in 0..k {
                // the heavy column scaled by 1/sqrt(k p) is still >> 0.01
                if gk[c].abs() > 1.0 {
                    heavy += 1;
                }
            }
        }
        assert!(heavy as f64 / draws as f64 > 0.9, "{heavy}/{draws}");
    }

    #[test]
    fn random_sampling_unbiased_gram() {
        // E[G_k G_kᵀ] = G Gᵀ: check one diagonal entry across many seeds
        let n = 8;
        let d = 12;
        let g = toy_g(n, d, 3);
        let mut eng = NativeEngine::new();
        let true_norm: f64 = g[0..d].iter().map(|&x| (x as f64) * (x as f64)).sum();
        let mut est = 0.0f64;
        let trials = 600;
        for seed in 0..trials {
            let mut rng = Rng::new(seed);
            let (gk, k) = SketchConfig::RandomSampling { k: 4 }
                .apply(&g, n, d, &mut rng, &mut eng)
                .unwrap();
            est += gk[0..k].iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>();
        }
        est /= trials as f64;
        assert!(
            (est - true_norm).abs() / true_norm < 0.15,
            "estimate {est} vs true {true_norm}"
        );
    }

    #[test]
    fn random_projection_shape_and_scale() {
        run_prop("rp preserves norms in expectation-ish", 10, |gen| {
            let n = gen.usize_in(5, 40);
            let d = gen.usize_in(8, 30);
            let k = 6;
            let g = gen.vec_gaussian(n * d, 1.0);
            let mut rng = Rng::new(gen.seed);
            let mut eng = NativeEngine::new();
            let (gk, kk) = SketchConfig::RandomProjection { k }
                .apply(&g, n, d, &mut rng, &mut eng)
                .unwrap();
            assert_eq!(kk, k);
            assert_eq!(gk.len(), n * k);
            // JL: squared row norms preserved within a loose factor
            for i in 0..n.min(5) {
                let orig: f64 = g[i * d..(i + 1) * d]
                    .iter()
                    .map(|&x| (x as f64) * (x as f64))
                    .sum();
                let proj: f64 = gk[i * k..(i + 1) * k]
                    .iter()
                    .map(|&x| (x as f64) * (x as f64))
                    .sum();
                if orig > 1.0 {
                    assert!(proj / orig > 0.05 && proj / orig < 20.0, "{proj} vs {orig}");
                }
            }
        });
    }

    #[test]
    fn zero_gradients_dont_crash_sampling() {
        let g = vec![0.0f32; 20 * 5];
        let mut rng = Rng::new(1);
        let mut eng = NativeEngine::new();
        let (gk, k) = SketchConfig::RandomSampling { k: 2 }
            .apply(&g, 20, 5, &mut rng, &mut eng)
            .unwrap();
        assert_eq!(k, 2);
        assert!(gk.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn parse_strategies() {
        assert_eq!(SketchConfig::parse("rp", 5), Some(SketchConfig::RandomProjection { k: 5 }));
        assert_eq!(SketchConfig::parse("full", 5), Some(SketchConfig::None));
        assert!(SketchConfig::parse("bogus", 5).is_none());
    }
}
