//! Empirical verification of the paper's Appendix-A theory.
//!
//! The propositions bound `Error(S_G, S_{G_k}) = sup_R |S_G(R) − S_{G_k}(R)|`
//! through `‖GGᵀ − G_kG_kᵀ‖` (Lemma A.1) in terms of spectral quantities
//! of G: dropped column norms (A.3, Top Outputs), `√(sr(G))·‖G‖²/√k`
//! (A.4/A.5, random sketches), `σ²_{k+1}(G)` (A.2, SVD). This module
//! computes those quantities on *actual* gradient matrices harvested
//! during training, plus a Monte-Carlo estimate of the score error over
//! random leaves, so `benches/sketch_error.rs` can check the theory's
//! ordering empirically (the paper never plots these; we add it as an
//! ablation).

use crate::util::rng::Rng;

/// Spectral diagnostics of a gradient matrix.
#[derive(Clone, Debug)]
pub struct GradientSpectrum {
    /// squared spectral norm estimate ‖G‖² (power iteration)
    pub sq_spectral_norm: f64,
    /// squared Frobenius norm ‖G‖²_F
    pub sq_frobenius_norm: f64,
    /// stable rank sr(G) = ‖G‖²_F / ‖G‖²
    pub stable_rank: f64,
    /// column squared norms, descending
    pub col_sq_norms_sorted: Vec<f64>,
}

/// Compute the spectrum diagnostics of row-major g [n, d].
pub fn gradient_spectrum(g: &[f32], n: usize, d: usize, seed: u64) -> GradientSpectrum {
    let sq_frobenius_norm: f64 = g.iter().map(|&x| (x as f64) * (x as f64)).sum();
    let sq_spectral_norm = top_singular_value_sq(g, n, d, 30, seed);
    let mut cols = crate::sketch::column_sq_norms(g, n, d);
    cols.sort_by(|a, b| b.partial_cmp(a).unwrap());
    GradientSpectrum {
        sq_spectral_norm,
        sq_frobenius_norm,
        stable_rank: sq_frobenius_norm / sq_spectral_norm.max(1e-300),
        col_sq_norms_sorted: cols,
    }
}

/// ‖G‖² via power iteration on GᵀG.
pub fn top_singular_value_sq(g: &[f32], n: usize, d: usize, iters: usize, seed: u64) -> f64 {
    let mut rng = Rng::new(seed);
    let mut v = vec![0.0f64; d];
    for x in v.iter_mut() {
        *x = rng.next_gaussian();
    }
    normalize(&mut v);
    let mut lambda = 0.0f64;
    let mut gv = vec![0.0f64; n];
    for _ in 0..iters {
        // gv = G v
        for (i, gvi) in gv.iter_mut().enumerate() {
            let row = &g[i * d..(i + 1) * d];
            *gvi = row.iter().zip(v.iter()).map(|(&a, &b)| a as f64 * b).sum();
        }
        // v = Gᵀ gv
        v.iter_mut().for_each(|x| *x = 0.0);
        for (i, &gvi) in gv.iter().enumerate() {
            let row = &g[i * d..(i + 1) * d];
            for (j, &a) in row.iter().enumerate() {
                v[j] += a as f64 * gvi;
            }
        }
        lambda = v.iter().map(|x| x * x).sum::<f64>().sqrt();
        if lambda <= 0.0 {
            return 0.0;
        }
        let inv = 1.0 / lambda;
        v.iter_mut().for_each(|x| *x *= inv);
    }
    lambda // after v normalized, ‖GᵀG v‖ -> top eigenvalue of GᵀG = ‖G‖²
}

fn normalize(v: &mut [f64]) {
    let norm = v.iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-300);
    v.iter_mut().for_each(|x| *x /= norm);
}

/// Monte-Carlo estimate of `sup_R |S_G(R) − S_{G_k}(R)|`: sample random
/// leaves R (random row subsets of several sizes) and take the max score
/// gap. A lower bound on the true sup, adequate for *comparing*
/// strategies at fixed trials.
pub fn score_error_estimate(
    g: &[f32],
    gk: &[f32],
    n: usize,
    d: usize,
    k: usize,
    lam: f64,
    trials: usize,
    rng: &mut Rng,
) -> f64 {
    let mut worst = 0.0f64;
    let sizes = [n / 20, n / 4, n / 2, (3 * n) / 4, n];
    for t in 0..trials {
        let size = sizes[t % sizes.len()].max(1);
        let rows = rng.sample_indices(n, size);
        let sg = region_score(g, d, &rows, lam);
        let sk = region_score(gk, k, &rows, lam);
        worst = worst.max((sg - sk).abs());
    }
    worst
}

/// S(R) = Σ_j (Σ_{i∈R} g_ij)² / (|R| + λ) for an explicit row set.
pub fn region_score(g: &[f32], d: usize, rows: &[u32], lam: f64) -> f64 {
    let mut sums = vec![0.0f64; d];
    for &r in rows {
        let row = &g[r as usize * d..(r as usize + 1) * d];
        for (j, &v) in row.iter().enumerate() {
            sums[j] += v as f64;
        }
    }
    sums.iter().map(|s| s * s).sum::<f64>() / (rows.len() as f64 + lam)
}

/// The Appendix-A theoretical bounds, for comparison against measured
/// errors (all are bounds on the *operator-norm* proxy of Lemma A.1).
pub struct TheoryBounds {
    /// A.3: Σ_{j>k} ‖g_(j)‖²
    pub top_outputs: f64,
    /// A.4/A.5 shape: √(sr(G)) · ‖G‖² / √k (constants dropped)
    pub random_sketch: f64,
}

pub fn theory_bounds(spec: &GradientSpectrum, k: usize) -> TheoryBounds {
    let dropped: f64 = spec.col_sq_norms_sorted.iter().skip(k).sum();
    TheoryBounds {
        top_outputs: dropped,
        random_sketch: spec.stable_rank.sqrt() * spec.sq_spectral_norm
            / (k as f64).sqrt(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::NativeEngine;
    use crate::sketch::SketchConfig;
    use crate::util::proptest::run_prop;

    fn gaussian(n: usize, d: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        let mut g = vec![0.0f32; n * d];
        rng.fill_gaussian(&mut g, 1.0);
        g
    }

    #[test]
    fn spectral_norm_of_rank_one() {
        // G = u vᵀ has ‖G‖² = ‖u‖²‖v‖², sr = 1
        let n = 20;
        let d = 6;
        let u: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).sin()).collect();
        let v: Vec<f64> = (0..d).map(|j| 1.0 + j as f64).collect();
        let mut g = vec![0.0f32; n * d];
        for i in 0..n {
            for j in 0..d {
                g[i * d + j] = (u[i] * v[j]) as f32;
            }
        }
        let spec = gradient_spectrum(&g, n, d, 1);
        let want: f64 = u.iter().map(|x| x * x).sum::<f64>() * v.iter().map(|x| x * x).sum::<f64>();
        assert!(
            (spec.sq_spectral_norm - want).abs() < 1e-3 * want,
            "{} vs {want}",
            spec.sq_spectral_norm
        );
        assert!((spec.stable_rank - 1.0).abs() < 1e-3, "sr={}", spec.stable_rank);
    }

    #[test]
    fn stable_rank_bounds() {
        run_prop("1 <= sr <= d", 15, |gen| {
            let n = gen.usize_in(5, 40);
            let d = gen.usize_in(2, 10);
            let g = gen.vec_gaussian(n * d, 1.0);
            let spec = gradient_spectrum(&g, n, d, gen.seed);
            assert!(spec.stable_rank >= 0.99, "sr={}", spec.stable_rank);
            assert!(spec.stable_rank <= d as f64 + 1e-6, "sr={}", spec.stable_rank);
        });
    }

    #[test]
    fn frobenius_equals_column_norm_sum() {
        let g = gaussian(30, 5, 2);
        let spec = gradient_spectrum(&g, 30, 5, 3);
        let col_sum: f64 = spec.col_sq_norms_sorted.iter().sum();
        assert!((col_sum - spec.sq_frobenius_norm).abs() < 1e-6 * spec.sq_frobenius_norm);
    }

    #[test]
    fn region_score_matches_hand_calc() {
        // two rows, d=2: sums = (4, 6), |R|=2, lam=1 -> (16+36)/3
        let g = vec![1.0f32, 2.0, 3.0, 4.0];
        let s = region_score(&g, 2, &[0, 1], 1.0);
        assert!((s - 52.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn svd_sketch_has_smallest_measured_error_on_low_rank() {
        // Low-rank G: SVD error ~ 0; random sketches larger; checks the
        // A.2-vs-A.4 ordering empirically.
        let n = 60;
        let d = 12;
        let r = 2;
        let mut rng = Rng::new(5);
        let mut u = vec![0.0f32; n * r];
        let mut w = vec![0.0f32; r * d];
        rng.fill_gaussian(&mut u, 1.0);
        rng.fill_gaussian(&mut w, 1.0);
        let mut g = vec![0.0f32; n * d];
        for i in 0..n {
            for j in 0..d {
                for t in 0..r {
                    g[i * d + j] += u[i * r + t] * w[t * d + j];
                }
            }
        }
        let mut eng = NativeEngine::new();
        let k = 2;
        let mut errs = std::collections::BTreeMap::new();
        for sketch in [
            SketchConfig::TruncatedSvd { k, iters: 10 },
            SketchConfig::RandomSampling { k },
            SketchConfig::TopOutputs { k },
        ] {
            let mut srng = Rng::new(7);
            let (gk, kk) = sketch.apply(&g, n, d, &mut srng, &mut eng).unwrap();
            let mut erng = Rng::new(9);
            let e = score_error_estimate(&g, &gk, n, d, kk, 1.0, 100, &mut erng);
            errs.insert(sketch.name().to_string(), e);
        }
        let svd = errs["truncated-svd"];
        assert!(
            svd <= errs["random-sampling"] + 1e-6 && svd <= errs["top-outputs"] + 1e-6,
            "svd {svd} not smallest: {errs:?}"
        );
    }

    #[test]
    fn theory_bounds_shrink_with_k() {
        let g = gaussian(50, 10, 11);
        let spec = gradient_spectrum(&g, 50, 10, 13);
        let b2 = theory_bounds(&spec, 2);
        let b5 = theory_bounds(&spec, 5);
        assert!(b5.top_outputs <= b2.top_outputs);
        assert!(b5.random_sketch < b2.random_sketch);
    }
}
