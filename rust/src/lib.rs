//! # SketchBoost
//!
//! A rust + JAX/Pallas reproduction of *SketchBoost: Fast Gradient Boosted
//! Decision Tree for Multioutput Problems* (Iosipoi & Vakhrushev, NeurIPS
//! 2022).
//!
//! The library is a complete multioutput GBDT framework (the paper's
//! Py-Boost analogue) with the paper's three sketched split-scoring
//! strategies as first-class features:
//!
//! * [`sketch::SketchConfig::TopOutputs`] — keep the k largest-norm
//!   gradient columns (section 3.1);
//! * [`sketch::SketchConfig::RandomSampling`] — importance-sample columns
//!   with probability ∝ ‖g_i‖² (section 3.2);
//! * [`sketch::SketchConfig::RandomProjection`] — Gaussian sketch
//!   `G_k = GΠ` (section 3.3);
//! * plus the Appendix A.1 Truncated-SVD sketch as an ablation baseline.
//!
//! Architecture (see DESIGN.md): layer 3 is this rust coordinator (the
//! training system); layer 2 is the per-round JAX compute graph; layer 1
//! is the Pallas kernels inside it. Layers 1–2 are AOT-lowered to HLO
//! text at build time and executed from rust via PJRT ([`runtime`],
//! [`engine::XlaEngine`], build feature `pjrt`); the pure-rust
//! [`engine::NativeEngine`] is the numerically identical fast path, and
//! runs the histogram build + split scan on a thread pool
//! ([`util::threading`]) with bit-deterministic results for any
//! `n_threads`. The training core keeps rows stably partitioned into
//! contiguous per-node ranges and pools all per-level buffers in a
//! reusable [`tree::TreeWorkspace`], so steady-state tree building is
//! allocation-free (DESIGN.md "Memory model & row partitioning").
//! Inference runs through [`predict::Predictor`] — the ensemble
//! compiled once into flat node tables (SoA, interleaved 16-byte
//! records, or quantized integer-compare records; see
//! [`predict::ForestLayout`]), driven block-of-rows at a time in
//! parallel, bit-identical to the per-row reference walker for every
//! thread count (DESIGN.md "Inference model"). The [`serve`]
//! module puts that predictor behind a dependency-free TCP daemon
//! (`sketchboost serve`) that coalesces concurrent requests into the
//! same cache-sized blocks and hot-swaps models without ever tearing a
//! response (DESIGN.md "Serving model"); under load or failure it
//! degrades structurally — deadlines, load shedding, panic isolation —
//! with every degradation counted in `/stats` and chaos-tested through
//! the deterministic fault points in [`util::fault`] (DESIGN.md
//! "Failure model").
//!
//! The training API is open (DESIGN.md "Training session & extension
//! points"): losses, metrics, and per-round behavior plug in through
//! the [`boosting::Objective`], [`boosting::EvalMetric`], and
//! [`boosting::Callback`] traits, composed by the [`boosting::Booster`]
//! builder — `GBDT::fit` is a thin, bit-exact wrapper over it, and the
//! closed `LossKind`/`Metric` enums are the built-in trait instances.
//! `examples/custom_objective.rs` trains a user-defined quantile loss
//! without touching any core file.
//!
//! ```no_run
//! use sketchboost::prelude::*;
//!
//! let ds = profiles::Profile::by_name("otto").unwrap().generate(42);
//! let (train, test) = split::train_test_split(&ds, 0.2, 0);
//! let mut cfg = GBDTConfig::multiclass(9);
//! cfg.sketch = SketchConfig::RandomProjection { k: 5 };
//! cfg.n_rounds = 100;
//! cfg.n_threads = 4; // parallel histograms + split scan; same bits as 1
//! let model = GBDT::fit(&cfg, &train, Some(&test));
//! let probs = model.predict(&test);
//! assert_eq!(probs.len(), test.n_rows * 9);
//! ```

pub mod baselines;
pub mod boosting;
pub mod config;
pub mod data;
pub mod engine;
pub mod lint;
pub mod predict;
pub mod runtime;
pub mod serve;
pub mod sketch;
pub mod tree;
pub mod util;

/// Convenience re-exports for examples and downstream users.
pub mod prelude {
    pub use crate::boosting::booster::Booster;
    pub use crate::boosting::callback::{
        Callback, Checkpoint, EarlyStopping, EvalLogger, RoundContext, TimeBudget,
    };
    pub use crate::boosting::ensemble::Ensemble;
    pub use crate::boosting::eval::EvalMetric;
    pub use crate::boosting::losses::LossKind;
    pub use crate::boosting::metrics::Metric;
    pub use crate::boosting::objective::Objective;
    pub use crate::boosting::trainer::{GBDTConfig, GBDT};
    pub use crate::data::profiles;
    pub use crate::data::split;
    pub use crate::data::{BinnedDataset, Dataset, FeatureKind, Targets};
    pub use crate::engine::MissingPolicy;
    pub use crate::predict::{
        FlatForest, ForestLayout, LayoutOptions, PredictOptions, Predictor, SharedForest,
    };
    pub use crate::serve::{ServeOptions, Server, ShedPolicy};
    pub use crate::sketch::SketchConfig;
    pub use crate::tree::CatSet;
}
