//! GBDT-MO baselines (Zhang & Jung 2021), reproduced for the Appendix
//! B.6 comparison (Tables 3/4/14/15).
//!
//! GBDT-MO differs from the CatBoost/SketchBoost regime in two ways the
//! paper calls out:
//!  1. it uses second-order information in the split score too, which
//!     doubles histogram cost (hessian histograms) — `use_hess_split`;
//!  2. its "sparse" variant constrains each leaf to its top-K outputs —
//!     `sparse_leaves`.
//! Both are native features of the trainer; this module packages them as
//! named baseline configurations so the benches read like the paper.
//! The configs inherit every shared knob — including `n_threads`, which
//! `GBDT::fit` forwards to the engine as [`crate::engine::EngineOpts`] —
//! so baseline timings parallelize exactly like SketchBoost's, and they
//! run through the same pooled [`crate::tree::TreeWorkspace`] training
//! core (range-partitioned rows, reused histogram buffers), so the
//! GBDT-MO comparison measures the hessian-histogram cost difference,
//! not allocator noise. Because these are plain [`GBDTConfig`]s, they
//! compose with the open training API too: feed one to
//! [`crate::boosting::booster::Booster`] to train a GBDT-MO baseline
//! with callbacks (checkpointing, time budgets) — bit-identical to
//! `GBDT::fit` on the same config, as the test below pins.

use crate::boosting::trainer::GBDTConfig;
use crate::data::dataset::Dataset;
use crate::sketch::SketchConfig;

/// GBDT-MO Full: single-tree, hessian-weighted split scoring, no sketch.
pub fn gbdt_mo_full_config(ds: &Dataset) -> GBDTConfig {
    let mut cfg = GBDTConfig::for_dataset(ds);
    cfg.sketch = SketchConfig::None;
    cfg.use_hess_split = true;
    cfg
}

/// GBDT-MO (sparse): additionally constrain leaves to top-K outputs.
pub fn gbdt_mo_sparse_config(ds: &Dataset, sparsity_k: usize) -> GBDTConfig {
    let mut cfg = gbdt_mo_full_config(ds);
    cfg.sparse_leaves = Some(sparsity_k.max(1));
    cfg
}

/// CatBoost-multioutput stand-in: the paper states SketchBoost Full *is*
/// the CatBoost single-tree algorithm (first-order split search, diagonal
/// hessian leaves), so the baseline config is Full with no sketch.
pub fn catboost_config(ds: &Dataset) -> GBDTConfig {
    let mut cfg = GBDTConfig::for_dataset(ds);
    cfg.sketch = SketchConfig::None;
    cfg.use_hess_split = false;
    cfg
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::boosting::trainer::GBDT;
    use crate::data::synthetic::{make_multitask, FeatureSpec};

    #[test]
    fn configs_have_expected_flags() {
        let ds = make_multitask(100, FeatureSpec::guyon(6), 4, 2, 0.1, 1);
        let full = gbdt_mo_full_config(&ds);
        assert!(full.use_hess_split && full.sparse_leaves.is_none());
        let sparse = gbdt_mo_sparse_config(&ds, 2);
        assert_eq!(sparse.sparse_leaves, Some(2));
        let cat = catboost_config(&ds);
        assert!(!cat.use_hess_split);
    }

    #[test]
    fn gbdt_mo_trains_and_sparse_constrains() {
        let ds = make_multitask(300, FeatureSpec::guyon(8), 6, 2, 0.1, 2);
        let mut cfg = gbdt_mo_sparse_config(&ds, 3);
        cfg.n_rounds = 10;
        cfg.max_depth = 3;
        cfg.max_bins = 16;
        cfg.learning_rate = 0.3;
        let m = GBDT::fit(&cfg, &ds, None);
        for t in &m.trees {
            for l in 0..t.n_leaves {
                let nz = t.leaf_values[l * 6..(l + 1) * 6]
                    .iter()
                    .filter(|&&v| v != 0.0)
                    .count();
                assert!(nz <= 3, "leaf {l} has {nz} nonzero outputs");
            }
        }
        assert!(
            m.history.train_loss.first().unwrap() > m.history.train_loss.last().unwrap()
        );
    }

    #[test]
    fn gbdt_mo_config_through_booster_matches_gbdt_fit() {
        use crate::boosting::booster::Booster;
        let ds = make_multitask(200, FeatureSpec::guyon(6), 4, 2, 0.1, 3);
        let mut cfg = gbdt_mo_full_config(&ds);
        cfg.n_rounds = 6;
        cfg.max_depth = 3;
        cfg.max_bins = 16;
        let a = GBDT::fit(&cfg, &ds, None);
        let b = Booster::new(&cfg).fit(&ds, None);
        assert_eq!(a.trees, b.trees);
        assert_eq!(a.base_score, b.base_score);
    }
}
