//! Baseline algorithms the paper compares against, built on the same
//! substrate so comparisons isolate the algorithmic difference:
//! one-vs-all (XGBoost strategy), GBDT-MO full/sparse, and the CatBoost
//! single-tree stand-in.

pub mod gbdt_mo;
pub mod one_vs_all;

pub use gbdt_mo::{catboost_config, gbdt_mo_full_config, gbdt_mo_sparse_config};
pub use one_vs_all::{fit_one_vs_all, OvaModel};
