//! One-versus-all baseline (the XGBoost/LightGBM multioutput strategy).
//!
//! Each boosting round fits `d` univariate trees, one per output, on that
//! output's gradient column — so per-round cost is proportional to d in
//! *tree count* rather than histogram width. This is the strategy Figure
//! 1 shows scaling linearly in the number of classes; sharing all other
//! code with the single-tree trainer makes the comparison isolate exactly
//! the strategy choice.

use crate::boosting::ensemble::TrainHistory;
use crate::boosting::losses::LossKind;
use crate::boosting::trainer::GBDTConfig;
use crate::data::binning::BinnedDataset;
use crate::data::dataset::Dataset;
use crate::engine::{ComputeEngine, EngineOpts, NativeEngine, ScoreMode};
use crate::predict::PredictOptions;
use crate::tree::builder::{build_tree_in, BuildParams, SENTINEL};
use crate::tree::tree::Tree;
use crate::tree::workspace::TreeWorkspace;
use crate::util::rng::Rng;

/// One-vs-all model: per round, one univariate tree per output.
#[derive(Clone, Debug)]
pub struct OvaModel {
    pub loss: LossKind,
    pub n_outputs: usize,
    pub base_score: Vec<f32>,
    /// (output index, tree with n_outputs = 1)
    pub trees: Vec<(u32, Tree)>,
    pub history: TrainHistory,
}

impl OvaModel {
    /// Raw scores through the batched flat path (univariate trees
    /// compiled with their output column; bit-identical to
    /// [`OvaModel::predict_raw_naive`] for every thread count). Legacy
    /// convenience — prefer
    /// [`Predictor::compile_ova`](crate::predict::Predictor::compile_ova).
    #[doc(hidden)]
    pub fn predict_raw(&self, ds: &Dataset) -> Vec<f32> {
        self.predict_raw_with(ds, &PredictOptions::default())
    }

    /// Legacy convenience: [`OvaModel::predict_raw`] with explicit knobs.
    #[doc(hidden)]
    pub fn predict_raw_with(&self, ds: &Dataset, opts: &PredictOptions) -> Vec<f32> {
        crate::predict::Predictor::compile_ova(self, *opts).raw(ds)
    }

    /// Reference per-row walker, kept as the equivalence-test oracle
    /// (`rust/tests/predict_equivalence.rs`).
    pub fn predict_raw_naive(&self, ds: &Dataset) -> Vec<f32> {
        let d = self.n_outputs;
        let mut out = vec![0.0f32; ds.n_rows * d];
        let mut row = vec![0.0f32; ds.n_features];
        for i in 0..ds.n_rows {
            for (f, r) in row.iter_mut().enumerate() {
                *r = ds.value(i, f);
            }
            let o = &mut out[i * d..(i + 1) * d];
            o.copy_from_slice(&self.base_score);
            for (j, t) in &self.trees {
                let leaf = t.leaf_for_raw(&row);
                o[*j as usize] += t.leaf_values[leaf];
            }
        }
        out
    }

    pub fn n_trees(&self) -> usize {
        self.trees.len()
    }
}

/// Train a one-vs-all ensemble. `cfg.sketch` is ignored (sketching is
/// meaningless at d = 1 — the paper's point is that one-vs-all pays the
/// d-factor in trees instead).
pub fn fit_one_vs_all(cfg: &GBDTConfig, train: &Dataset, valid: Option<&Dataset>) -> OvaModel {
    // the baselines honor `cfg.n_threads` exactly like the trainer, so
    // the Figure-1 strategy comparison stays apples-to-apples
    let mut engine = NativeEngine::with_opts(EngineOpts::threads(cfg.n_threads));
    fit_one_vs_all_with_engine(cfg, train, valid, &mut engine)
}

pub fn fit_one_vs_all_with_engine(
    cfg: &GBDTConfig,
    train: &Dataset,
    valid: Option<&Dataset>,
    engine: &mut dyn ComputeEngine,
) -> OvaModel {
    let n = train.n_rows;
    let d = cfg.n_outputs;
    // same feature-kind merge (and its bounds diagnostics) as the
    // single-tree Booster session
    let kinds = cfg.merged_kinds(train);
    let binned = BinnedDataset::from_dataset_with_kinds(train, cfg.max_bins, &kinds);
    let metric = cfg.metric();
    let mut rng = Rng::new(cfg.seed);

    let base_score = cfg.loss.base_score(&train.targets);
    let mut preds = vec![0.0f32; n * d];
    for row in preds.chunks_mut(d) {
        row.copy_from_slice(&base_score);
    }
    let mut valid_state: Option<(Vec<f32>, Vec<Vec<f32>>)> = valid.map(|v| {
        let mut vp = vec![0.0f32; v.n_rows * d];
        for row in vp.chunks_mut(d) {
            row.copy_from_slice(&base_score);
        }
        ((vp), (0..v.n_rows).map(|i| v.row(i)).collect())
    });

    let mut g = vec![0.0f32; n * d];
    let mut h = vec![0.0f32; n * d];
    let mut gcol = vec![0.0f32; n];
    let mut hcol = vec![0.0f32; n];
    let all_rows: Vec<u32> = (0..n as u32).collect();
    // pooled across all d trees of every round, exactly like the
    // single-tree trainer (tree/workspace.rs) — the Figure-1 strategy
    // comparison keeps both code paths allocation-free in steady state
    let mut ws = TreeWorkspace::new();

    let mut trees: Vec<(u32, Tree)> = Vec::new();
    let mut history = TrainHistory::default();
    let mut best_loss = f64::INFINITY;
    let mut best_round = 0usize;

    for round in 0..cfg.n_rounds {
        // the fused loss of the pre-update predictions: reused below as
        // the free train metric in cheap mode (same contract as the
        // single-tree Booster session — no second O(n*d) evaluation)
        let grad_loss = engine.grad_hess(cfg.loss, &preds, &train.targets, &mut g, &mut h);
        let mut round_rng = rng.fork(round as u64);

        let sampled: Option<Vec<u32>> = if cfg.subsample < 1.0 {
            let keep = ((n as f64) * cfg.subsample as f64).round().max(1.0) as usize;
            let mut idx = round_rng.sample_indices(n, keep);
            idx.sort_unstable();
            Some(idx)
        } else {
            None
        };
        let rows: &[u32] = sampled.as_deref().unwrap_or(&all_rows);

        for j in 0..d {
            for r in 0..n {
                gcol[r] = g[r * d + j];
                hcol[r] = h[r * d + j];
            }
            let params = BuildParams {
                binned: &binned,
                rows,
                g: &gcol,
                h: &hcol,
                d: 1,
                score_g: &gcol,
                kc: 1,
                score_h: None,
                mode: ScoreMode::CountL2,
                max_depth: cfg.max_depth,
                lambda: cfg.lambda_l2,
                min_data_in_leaf: cfg.min_data_in_leaf,
                min_gain: cfg.min_gain,
                feature_mask: None,
                sparse_topk: None,
                row_weights: None,
                missing: cfg.missing_policy,
            };
            let mut tree = build_tree_in(&params, engine, &mut ws);
            tree.scale_leaves(cfg.learning_rate);
            let leaf_of_row = ws.leaf_of_row();
            for r in 0..n {
                let leaf = if leaf_of_row[r] != SENTINEL {
                    leaf_of_row[r] as usize
                } else {
                    tree.leaf_for_binned(&binned, r)
                };
                preds[r * d + j] += tree.leaf_values[leaf];
            }
            if let (Some(v), Some((vp, vrows))) = (valid, valid_state.as_mut()) {
                for i in 0..v.n_rows {
                    let leaf = tree.leaf_for_raw(&vrows[i]);
                    vp[i * d + j] += tree.leaf_values[leaf];
                }
            }
            trees.push((j as u32, tree));
        }

        // train metric, same contract as the single-tree Booster
        // session: full evaluation when asked for; with no validation
        // set, the gradient pass's free loss (one round stale —
        // measured before this round's d trees); with a validation set
        // and eval_train off, nothing (valid tracking is what matters)
        if cfg.eval_train {
            history.train_loss.push(metric.eval(&preds, &train.targets));
        } else if valid.is_none() {
            history.train_loss.push(grad_loss);
        }
        let mut stop = false;
        if let (Some(v), Some((vp, _))) = (valid, valid_state.as_ref()) {
            let vl = metric.eval(vp, &v.targets);
            history.valid_loss.push(vl);
            if vl < best_loss {
                best_loss = vl;
                best_round = round;
            } else if cfg.early_stopping_rounds > 0
                && round - best_round >= cfg.early_stopping_rounds
            {
                stop = true;
            }
        } else {
            best_round = round;
        }
        if stop {
            break;
        }
    }
    if valid.is_some() && cfg.early_stopping_rounds > 0 {
        trees.truncate((best_round + 1) * d);
    }
    history.best_round = best_round;

    OvaModel { loss: cfg.loss, n_outputs: d, base_score, trees, history }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::boosting::metrics::Metric;
    use crate::data::synthetic::{make_multiclass, FeatureSpec};

    #[test]
    fn ova_learns_multiclass() {
        let ds = make_multiclass(500, FeatureSpec::guyon(10), 4, 2.0, 1);
        let mut cfg = GBDTConfig::multiclass(4);
        cfg.n_rounds = 20;
        cfg.learning_rate = 0.3;
        cfg.max_depth = 3;
        cfg.max_bins = 16;
        let model = fit_one_vs_all(&cfg, &ds, None);
        assert_eq!(model.n_trees(), 20 * 4); // d trees per round
        let acc = Metric::Accuracy.eval(&model.predict_raw(&ds), &ds.targets);
        assert!(acc > 0.8, "acc {acc}");
        let hist = &model.history.train_loss;
        assert!(hist.first().unwrap() > hist.last().unwrap());
    }

    #[test]
    fn ova_flat_path_matches_naive() {
        let ds = make_multiclass(300, FeatureSpec::guyon(8), 3, 2.0, 4);
        let mut cfg = GBDTConfig::multiclass(3);
        cfg.n_rounds = 5;
        cfg.max_bins = 16;
        let model = fit_one_vs_all(&cfg, &ds, None);
        let naive = model.predict_raw_naive(&ds);
        for threads in [1usize, 2, 4] {
            let opts = PredictOptions::threads(threads).with_block_rows(64);
            assert_eq!(model.predict_raw_with(&ds, &opts), naive, "threads {threads}");
        }
    }

    #[test]
    fn ova_tree_count_scales_with_d() {
        for d in [2usize, 5] {
            let ds = make_multiclass(200, FeatureSpec::guyon(6), d, 2.0, 2);
            let mut cfg = GBDTConfig::multiclass(d);
            cfg.n_rounds = 3;
            cfg.max_bins = 8;
            let model = fit_one_vs_all(&cfg, &ds, None);
            assert_eq!(model.n_trees(), 3 * d);
            // every tree is univariate
            assert!(model.trees.iter().all(|(_, t)| t.n_outputs == 1));
        }
    }

    #[test]
    fn ova_early_stopping() {
        let ds = make_multiclass(400, FeatureSpec::guyon(8), 3, 1.5, 3);
        let (train, valid) = crate::data::split::train_test_split(&ds, 0.3, 0);
        let mut cfg = GBDTConfig::multiclass(3);
        cfg.n_rounds = 100;
        cfg.learning_rate = 0.5;
        cfg.max_bins = 16;
        cfg.early_stopping_rounds = 5;
        let model = fit_one_vs_all(&cfg, &train, Some(&valid));
        assert!(model.n_trees() < 100 * 3);
        assert_eq!(model.n_trees() % 3, 0, "whole rounds only");
    }
}
