//! Model inspection: feature importances, staged prediction, leaf
//! indices, and human-readable tree dumps — the introspection surface a
//! production GBDT framework ships (XGBoost/CatBoost parity features).

use crate::boosting::ensemble::Ensemble;
use crate::boosting::metrics::Metric;
use crate::data::dataset::Dataset;
use crate::predict::PredictOptions;
use crate::tree::tree::{is_leaf, leaf_id, Tree};

/// How to weight splits when accumulating feature importance.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ImportanceKind {
    /// number of splits on the feature
    SplitCount,
    /// total impurity gain contributed by the feature's splits
    TotalGain,
}

impl Ensemble {
    /// Per-feature importance over the whole ensemble.
    pub fn feature_importance(&self, n_features: usize, kind: ImportanceKind) -> Vec<f64> {
        let mut imp = vec![0.0f64; n_features];
        for tree in &self.trees {
            for node in &tree.nodes {
                let f = node.feature as usize;
                debug_assert!(f < n_features);
                match kind {
                    ImportanceKind::SplitCount => imp[f] += 1.0,
                    ImportanceKind::TotalGain => imp[f] += node.gain.max(0.0) as f64,
                }
            }
        }
        imp
    }

    /// Features ranked by importance (descending), with scores.
    pub fn top_features(
        &self,
        n_features: usize,
        kind: ImportanceKind,
        top: usize,
    ) -> Vec<(usize, f64)> {
        let imp = self.feature_importance(n_features, kind);
        let mut ranked: Vec<(usize, f64)> = imp.into_iter().enumerate().collect();
        ranked.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        ranked.truncate(top);
        ranked
    }

    /// Metric value after each prefix of trees (cheap learning-curve
    /// recovery for a saved model; Figure-3-style analysis post hoc).
    pub fn staged_eval(&self, ds: &Dataset, metric: Metric, every: usize) -> Vec<(usize, f64)> {
        let d = self.n_outputs;
        let every = every.max(1);
        let mut preds = vec![0.0f32; ds.n_rows * d];
        for row in preds.chunks_mut(d) {
            row.copy_from_slice(&self.base_score);
        }
        let rows: Vec<Vec<f32>> = (0..ds.n_rows).map(|i| ds.row(i)).collect();
        let mut out = Vec::new();
        for (t, tree) in self.trees.iter().enumerate() {
            for (i, row) in rows.iter().enumerate() {
                tree.predict_into(row, &mut preds[i * d..(i + 1) * d]);
            }
            if (t + 1) % every == 0 || t + 1 == self.trees.len() {
                out.push((t + 1, metric.eval(&preds, &ds.targets)));
            }
        }
        out
    }

    /// Leaf index of every row in every tree — the "apply" output used
    /// for embedding/feature-engineering pipelines. Row-major
    /// `[n_rows, n_trees]`. Legacy convenience — prefer
    /// [`Predictor::leaf_indices`](crate::predict::Predictor::leaf_indices).
    #[doc(hidden)]
    pub fn predict_leaf_indices(&self, ds: &Dataset) -> Vec<u32> {
        self.predict_leaf_indices_with(ds, &PredictOptions::default())
    }

    /// Legacy convenience: leaf indices with explicit batching knobs.
    #[doc(hidden)]
    pub fn predict_leaf_indices_with(&self, ds: &Dataset, opts: &PredictOptions) -> Vec<u32> {
        crate::predict::Predictor::compile(self, *opts).leaf_indices(ds)
    }

    /// Reference per-row walker for the leaf-index output (oracle for
    /// `rust/tests/predict_equivalence.rs`).
    pub fn predict_leaf_indices_naive(&self, ds: &Dataset) -> Vec<u32> {
        let mut out = Vec::with_capacity(ds.n_rows * self.trees.len());
        let mut row = vec![0.0f32; ds.n_features];
        for i in 0..ds.n_rows {
            for (f, r) in row.iter_mut().enumerate() {
                *r = ds.value(i, f);
            }
            for tree in &self.trees {
                out.push(tree.leaf_for_raw(&row) as u32);
            }
        }
        out
    }

    /// Human-readable dump of one tree.
    pub fn dump_tree(&self, index: usize) -> String {
        dump_tree(&self.trees[index])
    }
}

/// Render a tree as an indented text diagram.
pub fn dump_tree(tree: &Tree) -> String {
    let mut s = String::new();
    if tree.nodes.is_empty() {
        s.push_str(&format!("leaf0: {:?}\n", head(&tree.leaf_values, tree.n_outputs)));
        return s;
    }
    fn walk(tree: &Tree, child: i32, depth: usize, s: &mut String) {
        let pad = "  ".repeat(depth);
        if is_leaf(child) {
            let l = leaf_id(child);
            let v = &tree.leaf_values[l * tree.n_outputs..(l + 1) * tree.n_outputs];
            s.push_str(&format!("{pad}leaf{l}: {:?}\n", head(v, tree.n_outputs)));
        } else {
            let n = &tree.nodes[child as usize];
            let rule = match &n.cats {
                Some(cats) => {
                    let ids: Vec<String> = cats.ids().map(|i| i.to_string()).collect();
                    format!("f{} in {{{}}}", n.feature, ids.join(","))
                }
                None => format!("f{} <= {:.4}", n.feature, n.threshold),
            };
            let dfl = if n.default_left { "" } else { " nan->right" };
            s.push_str(&format!("{pad}[{rule}{dfl}] gain={:.3}\n", n.gain));
            walk(tree, n.left, depth + 1, s);
            walk(tree, n.right, depth + 1, s);
        }
    }
    walk(tree, 0, 0, &mut s);
    s
}

fn head(v: &[f32], d: usize) -> Vec<f32> {
    v.iter().copied().take(d.min(4)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::boosting::trainer::{GBDTConfig, GBDT};
    use crate::data::synthetic::{make_multiclass, FeatureSpec};
    use crate::prelude::SketchConfig;

    fn model_and_data() -> (Ensemble, Dataset) {
        let ds = make_multiclass(
            600,
            FeatureSpec { n_informative: 4, n_linear: 2, n_redundant: 4 },
            3,
            2.0,
            1,
        );
        let mut cfg = GBDTConfig::multiclass(3);
        cfg.n_rounds = 15;
        cfg.max_depth = 3;
        cfg.max_bins = 16;
        cfg.learning_rate = 0.3;
        (GBDT::fit(&cfg, &ds, None), ds)
    }

    #[test]
    fn importance_favors_informative_features() {
        let (model, ds) = model_and_data();
        let imp = model.feature_importance(ds.n_features, ImportanceKind::TotalGain);
        assert_eq!(imp.len(), 10);
        // informative (0..4) + linear combos (4..6) carry signal; pure
        // noise features (6..10) should collectively matter less
        let signal: f64 = imp[..6].iter().sum();
        let noise: f64 = imp[6..].iter().sum();
        assert!(signal > noise, "signal {signal} vs noise {noise}");
    }

    #[test]
    fn split_count_and_gain_rankings_defined() {
        let (model, ds) = model_and_data();
        let top = model.top_features(ds.n_features, ImportanceKind::SplitCount, 3);
        assert_eq!(top.len(), 3);
        assert!(top[0].1 >= top[1].1 && top[1].1 >= top[2].1);
        let total_splits: f64 = model
            .feature_importance(ds.n_features, ImportanceKind::SplitCount)
            .iter()
            .sum();
        assert_eq!(total_splits as usize, model.n_nodes());
    }

    #[test]
    fn staged_eval_monotone_in_trees() {
        let (model, ds) = model_and_data();
        let stages = model.staged_eval(&ds, Metric::CrossEntropy, 5);
        assert_eq!(stages.last().unwrap().0, model.n_trees());
        // train CE at the last stage beats the first stage
        assert!(stages.last().unwrap().1 < stages.first().unwrap().1);
        // final stage equals full-model eval
        let full = Metric::CrossEntropy.eval(&model.predict_raw(&ds), &ds.targets);
        assert!((stages.last().unwrap().1 - full).abs() < 1e-9);
    }

    #[test]
    fn leaf_indices_shape_and_range() {
        let (model, ds) = model_and_data();
        let leaves = model.predict_leaf_indices(&ds);
        assert_eq!(leaves.len(), ds.n_rows * model.n_trees());
        for (i, &l) in leaves.iter().enumerate() {
            let tree = &model.trees[i % model.n_trees()];
            assert!((l as usize) < tree.n_leaves);
        }
        // the batched path must agree with the per-row walker exactly
        assert_eq!(leaves, model.predict_leaf_indices_naive(&ds));
        let opts = PredictOptions::threads(4).with_block_rows(33);
        assert_eq!(model.predict_leaf_indices_with(&ds, &opts), leaves);
    }

    #[test]
    fn dump_tree_mentions_features_and_leaves() {
        let (model, _) = model_and_data();
        let dump = model.dump_tree(0);
        assert!(dump.contains("[f"));
        assert!(dump.contains("leaf"));
        assert!(dump.lines().count() >= 3);
    }

    #[test]
    fn sketched_model_importances_work_too() {
        let ds = make_multiclass(400, FeatureSpec::guyon(10), 4, 2.0, 2);
        let mut cfg = GBDTConfig::multiclass(4);
        cfg.n_rounds = 8;
        cfg.max_bins = 16;
        cfg.sketch = SketchConfig::RandomProjection { k: 2 };
        let model = GBDT::fit(&cfg, &ds, None);
        let imp = model.feature_importance(10, ImportanceKind::TotalGain);
        assert!(imp.iter().sum::<f64>() > 0.0);
    }
}
