//! Boosting layer: losses, metrics, the training loop, and the trained
//! ensemble model.

pub mod ensemble;
pub mod inspect;
pub mod losses;
pub mod metrics;
pub mod sampling;
pub mod trainer;

pub use ensemble::Ensemble;
pub use losses::LossKind;
pub use metrics::Metric;
pub use trainer::{GBDTConfig, GBDT};
