//! Boosting layer: losses, metrics, the training session, and the
//! trained ensemble model.
//!
//! The training API is open (PR 4): [`Objective`], [`EvalMetric`], and
//! [`Callback`] are the extension traits, [`Booster`] is the
//! builder/session that composes them, and the closed [`LossKind`] /
//! [`Metric`] enums remain as the built-in trait instances. `GBDT::fit`
//! wraps the builder bit-exactly.

pub mod booster;
pub mod callback;
pub mod ensemble;
pub mod eval;
pub mod inspect;
pub mod losses;
pub mod metrics;
pub mod objective;
pub mod sampling;
pub mod trainer;

pub use booster::Booster;
pub use callback::{
    Callback, Checkpoint, EarlyStopping, EvalLogger, HistoryRecorder, RoundContext, TimeBudget,
};
pub use ensemble::Ensemble;
pub use eval::EvalMetric;
pub use losses::LossKind;
pub use metrics::Metric;
pub use objective::Objective;
pub use trainer::{GBDTConfig, GBDT};
